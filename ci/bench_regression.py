#!/usr/bin/env python3
"""p50 regression check for BENCH_*.json artifacts.

The bench harness (rust/src/util/bench.rs) reports the *median* (p50)
seconds-per-op for each benchmark when BENCH_JSON_DIR is set:

    { "bench": "optimizer_step", "stat": "p50",
      "labels": {"backend": "sequential"},
      "results": [ {"name": "60m adamw steady step (2w)", "value": 0.0123,
                    "labels": {"method": "adamw", "fmt": "f32", "scale": "60m"}}, ... ] }

Usage:
    ci/bench_regression.py --current BENCH_x.json [--baseline old.json]
                           [--fallback-baseline run1.json] [--threshold 0.30]

* With a baseline: fail (exit 1) if any benchmark's current p50 exceeds
  baseline * (1 + threshold). Benchmarks present on only one side are
  reported but never fail the check (benches come and go).
* Comparisons are label-aware (like-for-like only):
  - artifact-level `labels` (the execution backend) must match between
    baseline and current — diffing a threaded artifact against a
    sequential baseline is an error, not a regression;
  - an entry only compares against a baseline entry with the identical
    per-entry label set (method/fmt/scale cell coordinates). A name
    collision with different labels is reported as RELABELED and
    treated as added+removed, never as a regression.
* `--baseline` may name a file that does not exist yet (the promoted
  in-repo baseline slot, ci/baselines/). When it is missing and
  `--fallback-baseline` is given, that file is used instead — CI runs
  the benches twice on the same runner and gates run 2 against run 1,
  so the threshold check is ENFORCED on every run even before a
  baseline is promoted. A missing fallback is an error.
* Without any baseline argument: validate the artifact's shape, print
  the table, exit 0 (legacy bootstrap mode).

The default threshold is 30%: shared CI runners are noisy and the smoke
configuration (BENCH_MS small) takes few samples, so anything tighter
flakes. Tighten it once a pinned-runner baseline is promoted to
ci/baselines/.
"""

import argparse
import json
import os
import sys


def load(path):
    with open(path) as f:
        doc = json.load(f)
    if "results" not in doc or not isinstance(doc["results"], list):
        sys.exit(f"{path}: not a bench artifact (missing 'results' list)")
    out = {}
    for entry in doc["results"]:
        if "name" not in entry or "value" not in entry:
            sys.exit(f"{path}: malformed entry {entry!r}")
        labels = entry.get("labels", {})
        if not isinstance(labels, dict):
            sys.exit(f"{path}: entry labels must be an object: {entry!r}")
        out[entry["name"]] = (float(entry["value"]), labels)
    artifact_labels = doc.get("labels", {})
    if not isinstance(artifact_labels, dict):
        sys.exit(f"{path}: artifact labels must be an object")
    return doc.get("bench", "?"), artifact_labels, out


def fmt_labels(labels):
    return "{" + ",".join(f"{k}={v}" for k, v in sorted(labels.items())) + "}"


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--current", required=True, help="freshly produced BENCH_*.json")
    ap.add_argument("--baseline", help="baseline BENCH_*.json to compare against")
    ap.add_argument(
        "--fallback-baseline",
        help="baseline used when --baseline does not exist "
        "(a same-runner rerun artifact; keeps the gate enforcing)",
    )
    ap.add_argument(
        "--threshold",
        type=float,
        default=0.30,
        help="allowed p50 regression fraction (default 0.30 = +30%%)",
    )
    args = ap.parse_args()

    bench, cur_artifact_labels, cur = load(args.current)
    if not cur:
        sys.exit(f"{args.current}: empty results")
    baseline = args.baseline
    if baseline and not os.path.exists(baseline):
        if args.fallback_baseline:
            print(f"[{bench}] no promoted baseline at {baseline}; "
                  f"gating against same-runner rerun {args.fallback_baseline}")
            baseline = args.fallback_baseline
        else:
            sys.exit(f"{baseline}: baseline not found and no --fallback-baseline given")
    if not baseline:
        print(f"[{bench}] no baseline — artifact validated, {len(cur)} entries:")
        for name, (v, labels) in cur.items():
            suffix = f"  {fmt_labels(labels)}" if labels else ""
            print(f"  {name:<50} {v:.6g}{suffix}")
        return

    _, base_artifact_labels, base = load(baseline)
    # Artifact-level like-for-like gate: refuse to diff artifacts from
    # different backends (or any future artifact-level coordinate).
    if base_artifact_labels != cur_artifact_labels:
        sys.exit(
            f"[{bench}] artifacts are not comparable: baseline labels "
            f"{fmt_labels(base_artifact_labels)} != current "
            f"{fmt_labels(cur_artifact_labels)}"
        )

    failures = []
    for name, (v, labels) in sorted(cur.items()):
        if name not in base:
            print(f"  NEW       {name:<50} {v:.6g}")
            continue
        b, base_labels = base[name]
        if labels != base_labels:
            # Same name, different cell coordinates: not the same
            # measurement — report, never gate.
            print(f"  RELABELED {name:<50} {fmt_labels(base_labels)} -> {fmt_labels(labels)}")
            continue
        ratio = v / b if b > 0 else float("inf")
        status = "OK"
        if ratio > 1.0 + args.threshold:
            status = "REGRESSED"
            failures.append((name, b, v, ratio))
        print(f"  {status:<9} {name:<50} {b:.6g} -> {v:.6g}  ({ratio - 1.0:+.1%})")
    for name in sorted(set(base) - set(cur)):
        print(f"  GONE      {name}")

    if failures:
        print(f"\n[{bench}] {len(failures)} benchmark(s) regressed beyond "
              f"+{args.threshold:.0%} p50 threshold:", file=sys.stderr)
        for name, b, v, ratio in failures:
            print(f"  {name}: {b:.6g} -> {v:.6g} ({ratio - 1.0:+.1%})", file=sys.stderr)
        sys.exit(1)
    print(f"\n[{bench}] p50 check passed ({len(cur)} benchmarks).")


if __name__ == "__main__":
    main()
