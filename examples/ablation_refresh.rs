//! Subspace-refresh ablation (Fig. 3 workloads, runnable standalone).
//!
//! Sweeps the refresh interval K and the refresh mechanism (randomized
//! sketches vs dense + exact SVD) on the 60M-proxy pre-training problem
//! and prints the loss/byte trade-off table the paper plots.
//!
//! Run: `cargo run --release --example ablation_refresh -- [--steps 400]`

use tsr::exp::{run_proxy, MethodCfg};
use tsr::exp::runs::{proxy_spec, proxy_tsr_cfg};
use tsr::optim::RefreshKind;
use tsr::util::bench::fmt_bytes;
use tsr::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let steps = args.get_usize("steps", 400);
    let workers = args.get_usize("workers", 4);
    let spec = proxy_spec("60m");
    println!(
        "refresh ablation on {} ({} params), {steps} steps, {workers} workers\n",
        spec.name,
        spec.param_count()
    );

    println!("(c) refresh interval K:");
    println!(
        "{:>6} {:>12} {:>12} {:>12} {:>12}",
        "K", "final loss", "bytes/step", "peak", "refresh avg"
    );
    for k in [20usize, 50, 100, 200] {
        let mut cfg = proxy_tsr_cfg("60m");
        cfg.refresh_every = k;
        cfg.refresh_emb = k;
        let out = run_proxy(&spec, &MethodCfg::Tsr(cfg), steps, workers, 0.02, 0.02, 3);
        let (refresh_avg, _steady) = out.ledger.refresh_split();
        println!(
            "{:>6} {:>12.4} {:>12} {:>12} {:>12}",
            k,
            out.metrics.final_loss(),
            fmt_bytes(out.ledger.bytes_per_step()),
            fmt_bytes(out.ledger.peak_bytes() as f64),
            fmt_bytes(refresh_avg),
        );
    }

    println!("\n(b) refresh mechanism at K=25:");
    for (label, kind) in [
        ("randomized sketches (paper)", RefreshKind::Randomized),
        ("dense all-reduce + exact SVD", RefreshKind::ExactDense),
    ] {
        let mut cfg = proxy_tsr_cfg("60m");
        cfg.refresh_every = 25;
        cfg.refresh_emb = 25;
        cfg.refresh_kind = kind;
        let out = run_proxy(&spec, &MethodCfg::Tsr(cfg), steps, workers, 0.02, 0.02, 3);
        println!(
            "  {:<30} loss {:>8.4}  bytes/step {:>10}  peak {:>10}",
            label,
            out.metrics.final_loss(),
            fmt_bytes(out.ledger.bytes_per_step()),
            fmt_bytes(out.ledger.peak_bytes() as f64),
        );
    }
    println!("\nRandomized refresh cuts peak bytes with no loss penalty — Fig. 3(b).");
}
