//! Communication-budget planner: given a cluster topology and a model
//! scale, print projected per-step synchronization time for each method
//! — the deployment-facing use of the paper's byte accounting.
//!
//! Run: `cargo run --release --example comm_budget -- \
//!         [--scale 1b] [--nodes 4] [--gpus 8] [--link pcie|nvlink|ethernet]`

use tsr::comm::Topology;
use tsr::exp::{adamw_profile, onesided_profile, tsr_profile, TsrParams};
use tsr::model::ModelSpec;
use tsr::util::bench::fmt_bytes;
use tsr::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let scale = args.get_or("scale", "1b");
    let nodes = args.get_usize("nodes", 4);
    let gpus = args.get_usize("gpus", 8);
    let link = args.get_or("link", "pcie");
    let spec = ModelSpec::by_name(scale).expect("unknown scale (60m|130m|350m|1b|roberta)");
    let topo = match link {
        "nvlink" => Topology::single_node(nodes * gpus),
        "ethernet" => Topology::ethernet(nodes, gpus),
        _ => Topology::multi_node(nodes, gpus),
    };
    println!(
        "model {} ({} params)  cluster {}x{} ({} workers, {link} cross-node)\n",
        spec.name,
        spec.param_count(),
        nodes,
        gpus,
        topo.workers()
    );

    let profiles = [
        ("adamw (dense)", adamw_profile(&spec)),
        ("galore (one-sided r=512)", onesided_profile(&spec, 512, 200)),
        (
            "tsr r=512(256) K=100",
            tsr_profile(
                &spec,
                TsrParams {
                    rank: 512,
                    k_refresh: 100,
                    rank_emb: 256,
                    k_refresh_emb: 100,
                    oversample: 8,
                },
            ),
        ),
    ];
    println!(
        "{:<26} {:>12} {:>12} {:>14} {:>14}",
        "METHOD", "BYTES/STEP", "PEAK", "SYNC TIME/STEP", "PEAK SYNC TIME"
    );
    for (name, p) in &profiles {
        println!(
            "{:<26} {:>12} {:>12} {:>13.2}ms {:>13.2}ms",
            name,
            fmt_bytes(p.bytes_per_step),
            fmt_bytes(p.peak_bytes),
            1e3 * topo.allreduce_time(p.bytes_per_step as usize),
            1e3 * topo.allreduce_time(p.peak_bytes as usize),
        );
    }
    let dense = profiles[0].1.bytes_per_step;
    let tsr = profiles[2].1.bytes_per_step;
    println!(
        "\nTSR reduces steady-state synchronization volume {:.1}x on this cluster.",
        dense / tsr
    );
}
