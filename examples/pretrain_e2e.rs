//! End-to-end pre-training driver — proves all three layers compose:
//!
//!   L1 Pallas tiled-matmul kernel (inside the artifact's LM head, fwd+bwd)
//!   L2 JAX transformer fwd/bwd, AOT-lowered to HLO text
//!   L3 Rust coordinator: N simulated data-parallel workers, TSR-Adam
//!      core synchronization over the simulated interconnect
//!
//! Trains the `e2e` artifact (a ~13M-parameter LLaMA-style model; use
//! `--manifest artifacts/tiny_manifest.json` for the 0.3M smoke config)
//! on the synthetic corpus for a few hundred steps and logs the loss
//! curve, byte curve and wall time. Recorded in EXPERIMENTS.md.
//!
//! Run:  make artifacts && cargo run --release --example pretrain_e2e -- \
//!         [--manifest artifacts/e2e_manifest.json] [--steps 300]
//!         [--method tsr|adamw|galore] [--workers 4]

use tsr::comm::Topology;
use tsr::data::{Batcher, SyntheticCorpus};
use tsr::exp::MethodCfg;
use tsr::optim::onesided::OneSidedRefresh;
use tsr::optim::{AdamHyper, LrSchedule, TsrConfig};
use tsr::train::pjrt_source::PjrtSource;
use tsr::train::{GradSource, Trainer};
use tsr::util::bench::fmt_bytes;
use tsr::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let manifest_path = args.get_or("manifest", "artifacts/e2e_manifest.json");
    let steps = args.get_usize("steps", 300);
    let workers = args.get_usize("workers", 4);
    let method = args.get_or("method", "tsr").to_string();

    let manifest = match tsr::runtime::Manifest::load(manifest_path) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}\nrun `make artifacts` first");
            std::process::exit(1);
        }
    };
    let engine = tsr::runtime::Engine::cpu().expect("pjrt");
    println!(
        "e2e pretraining: {} — vocab {}, hidden {}, layers {} ({} params) on {}",
        manifest.name,
        manifest.vocab,
        manifest.hidden,
        manifest.layers,
        manifest.param_count(),
        engine.platform()
    );
    let model = engine.load_model(manifest.clone()).expect("compile artifact");
    let corpus = SyntheticCorpus::new(manifest.vocab, 0xC0FFEE);
    let batcher = Batcher::new(corpus, workers, manifest.batch, manifest.seq, 0xDA7A);
    let mut source = PjrtSource::new(model, batcher);
    let blocks = source.blocks().to_vec();

    let rank = args.get_usize("rank", (manifest.hidden / 4).max(8));
    let rank_emb = args.get_usize("rank-emb", (manifest.hidden / 8).max(8));
    let k = args.get_usize("k", 50);
    let mcfg = match method.as_str() {
        "adamw" => MethodCfg::Adam,
        "galore" => MethodCfg::OneSided {
            rank,
            k,
            refresh: OneSidedRefresh::RandomizedSvd,
        },
        _ => MethodCfg::Tsr(TsrConfig {
            rank,
            rank_emb,
            refresh_every: k,
            refresh_emb: k,
            oversample: 8,
            ..Default::default()
        }),
    };
    let hyper = AdamHyper {
        lr: args.get_f64("lr", 0.003) as f32,
        ..Default::default()
    };
    let mut opt = mcfg.build(&blocks, hyper, workers);
    let mut params = source.init_params(42);
    let mut trainer = Trainer::new(
        Topology::multi_node(2, workers.div_ceil(2)),
        LrSchedule::paper(steps),
    );
    trainer.verbose = true;
    trainer.log_every = args.get_usize("log-every", 20);

    let t0 = std::time::Instant::now();
    let (metrics, ledger) = trainer.run(&mut source, opt.as_mut(), &mut params, steps);
    let wall = t0.elapsed().as_secs_f64();

    println!("\n==== e2e result: {} ====", mcfg.label());
    println!("loss curve      : {:.4} -> {:.4}", metrics.loss[0], metrics.final_loss());
    println!("bytes/step      : {}", fmt_bytes(ledger.bytes_per_step()));
    println!("peak bytes      : {}", fmt_bytes(ledger.peak_bytes() as f64));
    println!(
        "cumulative bytes: {}",
        fmt_bytes(*metrics.cum_bytes.last().unwrap_or(&0) as f64)
    );
    println!("wall time       : {wall:.1}s ({:.3}s/step incl. fwd+bwd)", wall / steps as f64);
    let _ = std::fs::create_dir_all("results");
    let out = format!("results/e2e_{}.json", mcfg.label());
    std::fs::write(&out, metrics.to_json().to_string_pretty()).unwrap();
    let csv = format!("results/e2e_{}.csv", mcfg.label());
    metrics.write_csv(&csv).unwrap();
    println!("-> wrote {out} and {csv}");
}
