//! Quickstart: the TSR-Adam public API in ~60 lines.
//!
//! Builds a small data-parallel training problem, runs dense AdamW and
//! TSR-Adam side by side, and prints the communication ledger — the
//! paper's headline comparison in miniature.
//!
//! Run: `cargo run --release --example quickstart`

use tsr::comm::Topology;
use tsr::model::ModelSpec;
use tsr::optim::{AdamHyper, DenseAdamW, LrSchedule, TsrAdam, TsrConfig};
use tsr::train::gradsim::QuadraticSim;
use tsr::train::{GradSource, Trainer};
use tsr::util::bench::fmt_bytes;

fn main() {
    // A proxy transformer: vocab 2000, hidden 128, 4 layers (~1M params).
    let spec = ModelSpec::proxy(2000, 128, 344, 4, 4);
    let workers = 4;
    let steps = 200;
    println!(
        "model {} ({} params), {} workers, {} steps\n",
        spec.name,
        spec.param_count(),
        workers,
        steps
    );

    for method in ["adamw", "tsr"] {
        // Synthetic objective with low-rank gradient structure (the
        // regime where TSR's approximation floor is small — Remark 1).
        let mut sim = QuadraticSim::new(&spec, workers, 16, 0.02, 7);
        let blocks = sim.blocks().to_vec();
        let hyper = AdamHyper {
            lr: 0.02,
            ..Default::default()
        };
        let mut opt: Box<dyn tsr::optim::DistOptimizer> = match method {
            "adamw" => Box::new(DenseAdamW::new(&blocks, hyper)),
            _ => Box::new(TsrAdam::new(
                &blocks,
                hyper,
                TsrConfig {
                    rank: 64,
                    rank_emb: 16,
                    refresh_every: 50,
                    refresh_emb: 50,
                    oversample: 8,
                    ..Default::default()
                },
            )),
        };
        let mut params = sim.init_params(1);
        let trainer = Trainer::new(Topology::multi_node(2, 2), LrSchedule::paper(steps));
        let (metrics, ledger) = trainer.run(&mut sim, opt.as_mut(), &mut params, steps);

        println!("== {} ==", opt.name());
        println!("  final loss : {:.4}", metrics.final_loss());
        println!("  bytes/step : {}", fmt_bytes(ledger.bytes_per_step()));
        println!("  peak bytes : {}", fmt_bytes(ledger.peak_bytes() as f64));
        println!(
            "  total comm : {}",
            fmt_bytes(*metrics.cum_bytes.last().unwrap() as f64)
        );
        println!("  state elems: {}", opt.state_elements());
        println!("  sim comm t : {:.3}s\n", ledger.sim_time);
    }
    println!("TSR reaches comparable loss with a fraction of the bytes — Fig. 1 in miniature.");
}
