"""AOT lowering: JAX (L2 + L1) -> HLO text artifacts for the Rust runtime.

HLO *text* is the interchange format, NOT ``lowered.compile().serialize()``
-- jax >= 0.5 emits HloModuleProto with 64-bit instruction ids that the
image's xla_extension 0.5.1 rejects; the text parser reassigns ids
(see /opt/xla-example/README.md).

Artifacts written (under --out-dir, default ../artifacts):
  {name}.hlo.txt           the train step: (params..., tokens) -> (loss, *grads)
  {name}_manifest.json     shapes + param order for the Rust runtime
  core_project.hlo.txt     standalone L1 core-projection kernel artifact
  adam_core.hlo.txt        standalone fused core-AdamW kernel artifact

Run via ``make artifacts`` (a no-op when outputs are newer than inputs).
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels.adam_core import adam_core_update
from .kernels.tsr_core import core_project
from .model import ModelConfig, param_specs, train_step


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_model(cfg: ModelConfig, name: str, out_dir: str):
    specs = param_specs(cfg)
    arg_shapes = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s, _ in specs]
    arg_shapes.append(
        jax.ShapeDtypeStruct((cfg.batch, cfg.seq + 1), jnp.int32)
    )
    step = train_step(cfg)
    print(f"lowering {name}: {len(specs)} params, batch={cfg.batch}, seq={cfg.seq} ...")
    lowered = jax.jit(step).lower(*arg_shapes)
    text = to_hlo_text(lowered)
    hlo_path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(hlo_path, "w") as f:
        f.write(text)
    manifest = {
        "name": name,
        "hlo": f"{name}.hlo.txt",
        "vocab": cfg.vocab,
        "hidden": cfg.hidden,
        "intermediate": cfg.intermediate,
        "heads": cfg.heads,
        "layers": cfg.layers,
        "batch": cfg.batch,
        "seq": cfg.seq,
        "params": [
            {"name": n, "shape": list(s), "class": c} for n, s, c in specs
        ],
    }
    mpath = os.path.join(out_dir, f"{name}_manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"  wrote {hlo_path} ({len(text)} chars) + manifest")


def lower_kernels(out_dir: str, m=256, n=128, r=16):
    """Standalone L1 kernel artifacts (prove the kernels load from Rust)."""
    u = jax.ShapeDtypeStruct((m, r), jnp.float32)
    g = jax.ShapeDtypeStruct((m, n), jnp.float32)
    v = jax.ShapeDtypeStruct((n, r), jnp.float32)
    lowered = jax.jit(core_project).lower(u, g, v)
    path = os.path.join(out_dir, "core_project.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    print(f"  wrote {path} (core {m}x{n} rank {r})")

    c = jax.ShapeDtypeStruct((r, r), jnp.float32)
    t = jax.ShapeDtypeStruct((), jnp.float32)
    lowered = jax.jit(adam_core_update).lower(c, c, c, t)
    path = os.path.join(out_dir, "adam_core.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    print(f"  wrote {path} (fused core-AdamW, r={r})")


CONFIGS = {
    # Smoke/integration config: compiles in seconds, used by cargo tests.
    # Small head tiles → multi-step accumulation grid is exercised.
    "tiny": ModelConfig(vocab=512, hidden=64, intermediate=172, heads=4, layers=2,
                        batch=4, seq=32, head_bm=32, head_bk=64, head_bn=128),
    # End-to-end config for examples/pretrain_e2e (~13M params). Large
    # head tiles keep the interpret-mode grid small (sequential on CPU);
    # the BlockSpec schedule is what carries to real TPUs.
    "e2e": ModelConfig(vocab=8192, hidden=256, intermediate=688, heads=8, layers=6,
                       batch=8, seq=64, head_bm=512, head_bk=256, head_bn=2048),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--configs", default="tiny,e2e")
    args = ap.parse_args()
    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)
    for name in args.configs.split(","):
        name = name.strip()
        if not name:
            continue
        if name not in CONFIGS:
            sys.exit(f"unknown config {name!r}; have {sorted(CONFIGS)}")
        lower_model(CONFIGS[name], name, out_dir)
    lower_kernels(out_dir)
    # Stamp file for make's dependency tracking.
    with open(os.path.join(out_dir, ".stamp"), "w") as f:
        f.write("ok\n")
    print("artifacts complete")


if __name__ == "__main__":
    main()
