"""L1 Pallas kernel: fused core-space AdamW moment update (paper SS3.4).

Given the synchronized core C-bar and the r x r moments (m, v), computes
in one fused elementwise pass:

    m' = b1 m + (1-b1) C
    v' = b2 v + (1-b2) C*C
    D  = (m'/(1-b1^t)) / (sqrt(v'/(1-b2^t)) + eps)

The step index t arrives as a (1, 1) scalar input so a single compiled
artifact serves every step.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _adam_kernel(b1, b2, eps, c_ref, m_ref, v_ref, t_ref, mo_ref, vo_ref, d_ref):
    t = t_ref[0, 0]
    c = c_ref[...]
    m_new = b1 * m_ref[...] + (1.0 - b1) * c
    v_new = b2 * v_ref[...] + (1.0 - b2) * c * c
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t
    mo_ref[...] = m_new
    vo_ref[...] = v_new
    d_ref[...] = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)


@functools.partial(jax.jit, static_argnames=("beta1", "beta2", "eps"))
def adam_core_update(c, m, v, t, beta1: float = 0.9, beta2: float = 0.999, eps: float = 1e-8):
    """Returns (m', v', D) for the core AdamW update; all r x r."""
    r1, r2 = c.shape
    assert m.shape == c.shape and v.shape == c.shape
    t_arr = jnp.asarray(t, dtype=c.dtype).reshape(1, 1)
    kernel = functools.partial(_adam_kernel, beta1, beta2, eps)
    shape = jax.ShapeDtypeStruct((r1, r2), c.dtype)
    return pl.pallas_call(
        kernel,
        out_shape=(shape, shape, shape),
        interpret=True,
    )(c, m, v, t_arr)
