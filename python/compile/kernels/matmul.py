"""L1 Pallas kernel: tiled matrix multiplication.

The MXU-oriented workhorse used by the L2 model for its largest matmul
(the tied LM head). The BlockSpec grid expresses the HBM->VMEM schedule:
(bm x bk) and (bk x bn) tiles stream through VMEM while the (bm x bn)
output tile accumulates across the k axis of the grid.

CPU execution uses interpret=True (real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot run); the tiling structure is the
TPU-relevant artifact, see DESIGN.md #4 (Hardware adaptation).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, y_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=o_ref.dtype
    )


def _pad_to(x, rows, cols):
    pr, pc = rows - x.shape[0], cols - x.shape[1]
    if pr == 0 and pc == 0:
        return x
    return jnp.pad(x, ((0, pr), (0, pc)))


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def matmul(x, y, bm: int = 32, bk: int = 32, bn: int = 32):
    """C = x @ y via the Pallas tiled kernel (pads to tile multiples)."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"matmul dim mismatch {x.shape} @ {y.shape}"
    bm = min(bm, max(8, m))
    bk = min(bk, max(8, k))
    bn = min(bn, max(8, n))
    mp = (m + bm - 1) // bm * bm
    kp = (k + bk - 1) // bk * bk
    np_ = (n + bn - 1) // bn * bn
    xp = _pad_to(x, mp, kp)
    yp = _pad_to(y, kp, np_)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=True,
    )(xp, yp)
    return out[:m, :n]
