"""Pure-jnp correctness oracles for every L1 Pallas kernel.

pytest (python/tests/test_kernels.py) sweeps shapes/dtypes with
hypothesis and asserts allclose between each kernel and its oracle.
"""

import jax.numpy as jnp


def matmul_ref(x, y):
    return jnp.dot(x, y)


def core_project_ref(u, g, v):
    """C = U^T G V — the two-sided core (paper §3.3)."""
    return u.T @ g @ v


def lift_ref(u, d, v):
    """ΔW = U D Vᵀ (paper §3.4)."""
    return u @ d @ v.T


def adam_core_ref(c, m, v, t, beta1=0.9, beta2=0.999, eps=1e-8):
    """Reference core AdamW moment update + normalized direction."""
    m_new = beta1 * m + (1.0 - beta1) * c
    v_new = beta2 * v + (1.0 - beta2) * c * c
    bc1 = 1.0 - beta1 ** t
    bc2 = 1.0 - beta2 ** t
    d = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
    return m_new, v_new, d
