"""L1 Pallas kernels for TSR's compute hot-spots (paper SS3.3-3.4):

* ``core_project`` -- the two-sided projection C = U^T G V (r x r).
  The grid tiles G as (bm x bn) blocks streamed through VMEM; the U and
  V panels for the active tile rows/cols stay resident, and the tiny
  r x r core accumulates across the whole grid. This is the TPU
  re-thinking of the paper's GPU implementation: instead of a
  threadblock-per-tile reduction tree, the sequential TPU grid
  accumulates into a VMEM-resident core (DESIGN.md #4).

* ``lift`` -- Delta W = U D V^T, tiled over the (m x n) output.

Both are verified against the pure-jnp oracles in ``ref.py``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _core_kernel(u_ref, g_ref, v_ref, o_ref):
    @pl.when((pl.program_id(0) == 0) & (pl.program_id(1) == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    gv = jnp.dot(g_ref[...], v_ref[...], preferred_element_type=o_ref.dtype)
    o_ref[...] += jnp.dot(u_ref[...].T, gv, preferred_element_type=o_ref.dtype)


def _pad_rows(x, rows):
    if x.shape[0] == rows:
        return x
    return jnp.pad(x, ((0, rows - x.shape[0]), (0, 0)))


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def core_project(u, g, v, bm: int = 64, bn: int = 64):
    """C = U^T @ G @ V with G tiled (bm x bn); U, V panels per tile."""
    m, n = g.shape
    mu, r = u.shape
    nv, r2 = v.shape
    assert mu == m and nv == n and r == r2, (u.shape, g.shape, v.shape)
    bm = min(bm, max(8, m))
    bn = min(bn, max(8, n))
    mp = (m + bm - 1) // bm * bm
    np_ = (n + bn - 1) // bn * bn
    gp = jnp.pad(g, ((0, mp - m), (0, np_ - n)))
    up = _pad_rows(u, mp)
    vp = _pad_rows(v, np_)
    return pl.pallas_call(
        _core_kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, r), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bn, r), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((r, r), lambda i, j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((r, r), g.dtype),
        interpret=True,
    )(up, gp, vp)


def _lift_kernel(u_ref, d_ref, v_ref, o_ref):
    ud = jnp.dot(u_ref[...], d_ref[...], preferred_element_type=o_ref.dtype)
    o_ref[...] = jnp.dot(ud, v_ref[...].T, preferred_element_type=o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def lift(u, d, v, bm: int = 64, bn: int = 64):
    """Delta W = U @ D @ V^T, tiled over the (m x n) output grid."""
    m, r = u.shape
    n, r2 = v.shape
    assert d.shape == (r, r) and r == r2
    bm = min(bm, max(8, m))
    bn = min(bn, max(8, n))
    mp = (m + bm - 1) // bm * bm
    np_ = (n + bn - 1) // bn * bn
    up = _pad_rows(u, mp)
    vp = _pad_rows(v, np_)
    out = pl.pallas_call(
        _lift_kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, r), lambda i, j: (i, 0)),
            pl.BlockSpec((r, r), lambda i, j: (0, 0)),
            pl.BlockSpec((bn, r), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), u.dtype),
        interpret=True,
    )(up, d, vp)
    return out[:m, :n]
