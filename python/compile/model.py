"""L2: LLaMA-style transformer forward + loss + grads in JAX.

Build-time only — lowered once by ``aot.py`` to HLO text; the Rust L3
coordinator executes the artifact via PJRT and never imports Python.

Parameter layout mirrors ``rust/src/model/registry.rs::ModelSpec::blocks``
exactly (same names, same order, tied embeddings), so the Rust side can
zip manifest params with its optimizer blocks 1:1.

The LM head (the model's largest matmul) routes through the L1 Pallas
tiled-matmul kernel so the compiled artifact contains the kernel on the
real hot path.
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels.matmul import matmul as pallas_matmul


from functools import partial


@partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _head_matmul(x, et, bm, bk, bn):
    """logits = x @ Eᵀ through the Pallas tiled kernel, with an explicit
    VJP so both the forward and backward matmuls run the L1 kernel
    (pallas_call has no automatic transpose rule)."""
    return pallas_matmul(x, et, bm=bm, bk=bk, bn=bn)


def _head_fwd(x, et, bm, bk, bn):
    return pallas_matmul(x, et, bm=bm, bk=bk, bn=bn), (x, et)


def _head_bwd(bm, bk, bn, res, dlogits):
    x, et = res
    dx = pallas_matmul(dlogits, et.T, bm=bm, bk=bn, bn=bk)  # (m, h)
    det = pallas_matmul(x.T, dlogits, bm=bk, bk=bm, bn=bn)  # (h, V)
    return dx, det


_head_matmul.defvjp(_head_fwd, _head_bwd)


@dataclass(frozen=True)
class ModelConfig:
    vocab: int = 512
    hidden: int = 64
    intermediate: int = 172
    heads: int = 4
    layers: int = 2
    batch: int = 4
    seq: int = 32
    # Lower the LM head through the Pallas kernel (interpret=True). The
    # pure-jnp path is used for A/B numerics tests.
    use_pallas_head: bool = True
    # Head-kernel tile sizes. interpret=True executes the grid
    # sequentially, so production configs use large tiles (full-K
    # reduction) to keep the grid small; on real TPU the same BlockSpecs
    # express the HBM→VMEM schedule (DESIGN.md §4).
    head_bm: int = 64
    head_bk: int = 256
    head_bn: int = 512

    @property
    def head_dim(self):
        assert self.hidden % self.heads == 0
        return self.hidden // self.heads


def param_specs(cfg: ModelConfig):
    """(name, shape, class) for every block — MUST match the Rust registry."""
    specs = [("embed_tokens", (cfg.vocab, cfg.hidden), "embedding")]
    for l in range(cfg.layers):
        for proj in ("q_proj", "k_proj", "v_proj", "o_proj"):
            specs.append((f"layers.{l}.attn.{proj}", (cfg.hidden, cfg.hidden), "linear"))
        specs.append((f"layers.{l}.mlp.gate", (cfg.hidden, cfg.intermediate), "linear"))
        specs.append((f"layers.{l}.mlp.up", (cfg.hidden, cfg.intermediate), "linear"))
        specs.append((f"layers.{l}.mlp.down", (cfg.intermediate, cfg.hidden), "linear"))
        specs.append((f"layers.{l}.attn_norm", (cfg.hidden,), "vector"))
        specs.append((f"layers.{l}.mlp_norm", (cfg.hidden,), "vector"))
    specs.append(("final_norm", (cfg.hidden,), "vector"))
    return specs


def init_params(cfg: ModelConfig, key):
    """Standard init (norms→1, embed→0.02σ, linear→1/√fan_in)."""
    params = []
    for name, shape, klass in param_specs(cfg):
        key, sub = jax.random.split(key)
        if klass == "vector":
            params.append(jnp.ones(shape, jnp.float32))
        elif klass == "embedding":
            params.append(0.02 * jax.random.normal(sub, shape, jnp.float32))
        else:
            scale = 1.0 / jnp.sqrt(shape[0])
            params.append(scale * jax.random.normal(sub, shape, jnp.float32))
    return params


def _rmsnorm(x, w):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + 1e-6) * w


def _rope(x, positions):
    """Rotary position embedding over the last dim (per head)."""
    b, h, s, d = x.shape
    half = d // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions[:, None].astype(jnp.float32) * freqs[None, :]  # (s, half)
    cos = jnp.cos(angles)[None, None]
    sin = jnp.sin(angles)[None, None]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def forward(cfg: ModelConfig, params, tokens):
    """tokens: int32 [batch, seq+1]; returns mean next-token CE loss."""
    inputs = tokens[:, :-1]
    targets = tokens[:, 1:]
    b, s = inputs.shape
    it = iter(params)
    embed = next(it)

    x = embed[inputs]  # (b, s, h)
    positions = jnp.arange(s)
    causal = jnp.tril(jnp.ones((s, s), jnp.bool_))

    per_layer = []
    for _ in range(cfg.layers):
        q = next(it); k = next(it); v = next(it); o = next(it)
        gate = next(it); up = next(it); down = next(it)
        attn_norm = next(it); mlp_norm = next(it)
        per_layer.append((q, k, v, o, gate, up, down, attn_norm, mlp_norm))
    final_norm = next(it)

    scale = 1.0 / jnp.sqrt(cfg.head_dim)
    for (q, k, v, o, gate, up, down, attn_norm, mlp_norm) in per_layer:
        h = _rmsnorm(x, attn_norm)
        def heads(t):  # (b, s, h) -> (b, nh, s, hd)
            return t.reshape(b, s, cfg.heads, cfg.head_dim).transpose(0, 2, 1, 3)
        qh = _rope(heads(h @ q), positions)
        kh = _rope(heads(h @ k), positions)
        vh = heads(h @ v)
        att = (qh @ kh.transpose(0, 1, 3, 2)) * scale
        att = jnp.where(causal[None, None], att, -1e30)
        att = jax.nn.softmax(att, axis=-1)
        ctx = (att @ vh).transpose(0, 2, 1, 3).reshape(b, s, cfg.hidden)
        x = x + ctx @ o

        h = _rmsnorm(x, mlp_norm)
        x = x + (jax.nn.silu(h @ gate) * (h @ up)) @ down

    x = _rmsnorm(x, final_norm)
    # Tied LM head — the Pallas tiled matmul on the hot path.
    flat = x.reshape(b * s, cfg.hidden)
    if cfg.use_pallas_head:
        logits = _head_matmul(flat, embed.T, cfg.head_bm, cfg.head_bk, cfg.head_bn)
    else:
        logits = flat @ embed.T
    logits = logits.reshape(b, s, cfg.vocab)

    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def train_step(cfg: ModelConfig):
    """Returns f(params..., tokens) -> (loss, *grads) for AOT lowering."""

    def loss_fn(params, tokens):
        return forward(cfg, params, tokens)

    def step(*args):
        params = list(args[:-1])
        tokens = args[-1]
        loss, grads = jax.value_and_grad(loss_fn)(params, tokens)
        return (loss, *grads)

    return step
