"""AOT pipeline tests: HLO-text lowering and manifest consistency.

The heavyweight artifacts are built by `make artifacts`; here we lower a
micro-config end to end (fast) and validate the manifest contract the
Rust runtime depends on.
"""

import json
import os

import jax
import jax.numpy as jnp

from compile.aot import to_hlo_text, CONFIGS
from compile.model import ModelConfig, param_specs, train_step


def test_micro_config_lowers_to_hlo_text():
    cfg = ModelConfig(vocab=32, hidden=16, intermediate=24, heads=2, layers=1,
                      batch=2, seq=8, head_bm=8, head_bk=16, head_bn=32)
    specs = param_specs(cfg)
    args = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s, _ in specs]
    args.append(jax.ShapeDtypeStruct((cfg.batch, cfg.seq + 1), jnp.int32))
    lowered = jax.jit(train_step(cfg)).lower(*args)
    text = to_hlo_text(lowered)
    assert text.startswith("HloModule")
    # The tuple return must carry loss + one grad per param.
    assert "ROOT" in text
    assert len(text) > 1000


def test_param_specs_match_rust_block_convention():
    for cfg in CONFIGS.values():
        specs = param_specs(cfg)
        names = [n for n, _, _ in specs]
        # Mirrors rust/src/model/registry.rs order exactly.
        assert names[0] == "embed_tokens"
        for l in range(cfg.layers):
            base = 1 + l * 9
            assert names[base] == f"layers.{l}.attn.q_proj"
            assert names[base + 4] == f"layers.{l}.mlp.gate"
            assert names[base + 7] == f"layers.{l}.attn_norm"
        assert names[-1] == "final_norm"


def test_existing_manifests_are_consistent():
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    found = False
    for name in ("tiny", "e2e"):
        path = os.path.join(art, f"{name}_manifest.json")
        if not os.path.exists(path):
            continue
        found = True
        with open(path) as f:
            m = json.load(f)
        assert os.path.exists(os.path.join(art, m["hlo"]))
        cfg = CONFIGS[name]
        specs = param_specs(cfg)
        assert len(m["params"]) == len(specs)
        for got, (n, s, c) in zip(m["params"], specs):
            assert got["name"] == n
            assert tuple(got["shape"]) == tuple(s)
            assert got["class"] == c
        assert m["vocab"] == cfg.vocab and m["seq"] == cfg.seq
    if not found:
        import pytest
        pytest.skip("no artifacts built yet (run `make artifacts`)")
