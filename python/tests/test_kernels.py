"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes; fixed-seed numpy draws the values (keeping
each case deterministic and fast under interpret=True).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.adam_core import adam_core_update
from compile.kernels.matmul import matmul
from compile.kernels.tsr_core import core_project, lift

RNG = np.random.default_rng(0)


def randm(*shape, dtype=np.float32):
    return RNG.standard_normal(shape).astype(dtype)


dims = st.integers(min_value=1, max_value=96)
ranks = st.integers(min_value=1, max_value=24)


class TestMatmul:
    @settings(max_examples=25, deadline=None)
    @given(m=dims, k=dims, n=dims)
    def test_matches_ref(self, m, k, n):
        x, y = randm(m, k), randm(k, n)
        got = np.asarray(matmul(jnp.asarray(x), jnp.asarray(y)))
        want = np.asarray(ref.matmul_ref(jnp.asarray(x), jnp.asarray(y)))
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_non_divisible_tiles(self):
        # Shapes that do NOT divide the block sizes exercise the padding.
        x, y = randm(33, 47), randm(47, 65)
        got = np.asarray(matmul(jnp.asarray(x), jnp.asarray(y), bm=16, bk=16, bn=16))
        np.testing.assert_allclose(got, x @ y, rtol=2e-4, atol=2e-4)

    def test_identity(self):
        x = randm(24, 24)
        got = np.asarray(matmul(jnp.asarray(x), jnp.eye(24, dtype=np.float32)))
        np.testing.assert_allclose(got, x, rtol=1e-5, atol=1e-5)


class TestCoreProject:
    @settings(max_examples=25, deadline=None)
    @given(m=dims, n=dims, r=ranks)
    def test_matches_ref(self, m, n, r):
        r = min(r, m, n)
        u, g, v = randm(m, r), randm(m, n), randm(n, r)
        got = np.asarray(core_project(jnp.asarray(u), jnp.asarray(g), jnp.asarray(v)))
        want = u.T @ g @ v
        np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)

    def test_orthonormal_projection_preserves_core_energy(self):
        # With orthonormal U, V and G = U C V^T, the projection recovers C.
        m, n, r = 48, 40, 6
        u, _ = np.linalg.qr(randm(m, r))
        v, _ = np.linalg.qr(randm(n, r))
        c = randm(r, r)
        g = u @ c @ v.T
        got = np.asarray(
            core_project(jnp.asarray(u.astype(np.float32)),
                         jnp.asarray(g.astype(np.float32)),
                         jnp.asarray(v.astype(np.float32)))
        )
        np.testing.assert_allclose(got, c, rtol=1e-3, atol=1e-3)

    def test_tile_sweep(self):
        u, g, v = randm(70, 5), randm(70, 50), randm(50, 5)
        want = u.T @ g @ v
        for bm, bn in [(8, 8), (16, 32), (64, 64)]:
            got = np.asarray(
                core_project(jnp.asarray(u), jnp.asarray(g), jnp.asarray(v), bm=bm, bn=bn)
            )
            np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


class TestLift:
    @settings(max_examples=20, deadline=None)
    @given(m=dims, n=dims, r=ranks)
    def test_matches_ref(self, m, n, r):
        r = min(r, m, n)
        u, d, v = randm(m, r), randm(r, r), randm(n, r)
        got = np.asarray(lift(jnp.asarray(u), jnp.asarray(d), jnp.asarray(v)))
        want = u @ d @ v.T
        np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)

    def test_roundtrip_with_core(self):
        # lift(core_project(G)) is the paper's reconstructed gradient
        # (eq. 5): for orthonormal bases it's the double projection of G.
        m, n, r = 32, 28, 4
        u, _ = np.linalg.qr(randm(m, r))
        v, _ = np.linalg.qr(randm(n, r))
        g = randm(m, n)
        u32, v32 = u.astype(np.float32), v.astype(np.float32)
        c = core_project(jnp.asarray(u32), jnp.asarray(g), jnp.asarray(v32))
        ghat = np.asarray(lift(jnp.asarray(u32), c, jnp.asarray(v32)))
        want = u @ (u.T @ g @ v) @ v.T
        np.testing.assert_allclose(ghat, want, rtol=1e-3, atol=1e-3)


class TestAdamCore:
    @settings(max_examples=15, deadline=None)
    @given(r=st.integers(min_value=1, max_value=32), t=st.integers(min_value=1, max_value=1000))
    def test_matches_ref(self, r, t):
        c, m, v = randm(r, r), randm(r, r), np.abs(randm(r, r))
        got_m, got_v, got_d = adam_core_update(
            jnp.asarray(c), jnp.asarray(m), jnp.asarray(v), float(t)
        )
        want_m, want_v, want_d = ref.adam_core_ref(c, m, v, float(t))
        np.testing.assert_allclose(np.asarray(got_m), np.asarray(want_m), rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(got_v), np.asarray(want_v), rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(got_d), np.asarray(want_d), rtol=1e-4, atol=1e-5)

    def test_first_step_direction_is_sign(self):
        # At t=1 with zero moments, D ≈ sign(C) (bias correction cancels).
        c = randm(8, 8)
        z = np.zeros((8, 8), np.float32)
        _, _, d = adam_core_update(jnp.asarray(c), jnp.asarray(z), jnp.asarray(z), 1.0)
        np.testing.assert_allclose(np.asarray(d), np.sign(c), rtol=1e-2, atol=1e-2)


class TestDtypes:
    @pytest.mark.parametrize("dtype", [np.float32])
    def test_matmul_dtype(self, dtype):
        x, y = randm(17, 19, dtype=dtype), randm(19, 23, dtype=dtype)
        got = np.asarray(matmul(jnp.asarray(x), jnp.asarray(y)))
        assert got.dtype == dtype
        np.testing.assert_allclose(got, x @ y, rtol=5e-3, atol=5e-3)
