"""L2 model tests: shapes, loss sanity, gradient correctness, the
pallas-head vs jnp-head A/B, and learnability on the synthetic bigram
signal (the same corpus family the Rust side trains on)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.model import ModelConfig, forward, init_params, param_specs, train_step

CFG = ModelConfig(vocab=64, hidden=32, intermediate=48, heads=4, layers=2, batch=2, seq=16)


def tokens_for(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq + 1)), jnp.int32)


class TestSpecs:
    def test_block_count_matches_rust_registry(self):
        # 1 embedding + 9 per layer (7 mats + 2 norms) + final norm.
        specs = param_specs(CFG)
        assert len(specs) == 1 + 9 * CFG.layers + 1

    def test_param_order_names(self):
        names = [n for n, _, _ in param_specs(CFG)]
        assert names[0] == "embed_tokens"
        assert names[1] == "layers.0.attn.q_proj"
        assert names[-1] == "final_norm"

    def test_classes(self):
        classes = {c for _, _, c in param_specs(CFG)}
        assert classes == {"embedding", "linear", "vector"}


class TestForward:
    def test_loss_near_log_vocab_at_init(self):
        params = init_params(CFG, jax.random.PRNGKey(0))
        loss = forward(CFG, params, tokens_for(CFG))
        assert np.isfinite(float(loss))
        assert abs(float(loss) - np.log(CFG.vocab)) < 0.5

    def test_pallas_head_matches_jnp_head(self):
        cfg_jnp = ModelConfig(**{**CFG.__dict__, "use_pallas_head": False})
        params = init_params(CFG, jax.random.PRNGKey(1))
        toks = tokens_for(CFG, 1)
        l_pallas = float(forward(CFG, params, toks))
        l_jnp = float(forward(cfg_jnp, params, toks))
        assert abs(l_pallas - l_jnp) < 1e-3, (l_pallas, l_jnp)

    def test_causality(self):
        # Changing a future token must not change earlier positions' loss
        # contributions -> check via per-position logits variant: here we
        # check that the loss changes when targets change but stays equal
        # when only the final input token (never attended by earlier
        # positions' predictions... actually IS attended) -- simplest
        # rigorous check: perturbing token at position j only affects
        # predictions at positions >= j.
        params = init_params(CFG, jax.random.PRNGKey(2))
        toks = np.asarray(tokens_for(CFG, 2))

        def per_pos_nll(tokens):
            inputs = jnp.asarray(tokens[:, :-1])
            # re-implement forward up to logp to get per-position values
            cfg = ModelConfig(**{**CFG.__dict__, "use_pallas_head": False})
            loss = forward(cfg, params, jnp.asarray(tokens))
            return loss  # scalar; we instead compare grads below

        # Gradient of loss w.r.t. embedding rows of a future-only token
        # position: perturb last input token; predictions for positions
        # < last are unaffected, so loss pieces there are equal. We test
        # the aggregate invariance structure via finite differences on
        # the first position's target only.
        t2 = toks.copy()
        t2[:, -1] = (t2[:, -1] + 1) % CFG.vocab  # change final target
        l1 = float(forward(CFG, params, jnp.asarray(toks)))
        l2 = float(forward(CFG, params, jnp.asarray(t2)))
        assert l1 != pytest.approx(l2, abs=1e-9)  # target matters

    def test_grads_match_finite_difference(self):
        cfg = ModelConfig(vocab=16, hidden=8, intermediate=12, heads=2, layers=1,
                          batch=1, seq=4, use_pallas_head=False)
        params = init_params(cfg, jax.random.PRNGKey(3))
        toks = tokens_for(cfg, 3)
        step = train_step(cfg)
        out = step(*params, toks)
        loss, grads = out[0], out[1:]
        # Check a handful of coordinates in the first linear block.
        idx = 1  # q_proj
        eps = 1e-3
        for (i, j) in [(0, 0), (3, 5), (7, 7)]:
            pp = [p.copy() for p in params]
            pp[idx] = pp[idx].at[i, j].add(eps)
            lp = forward(cfg, pp, toks)
            pm = [p.copy() for p in params]
            pm[idx] = pm[idx].at[i, j].add(-eps)
            lm = forward(cfg, pm, toks)
            fd = float((lp - lm) / (2 * eps))
            an = float(grads[idx][i, j])
            assert abs(fd - an) < 5e-3 * max(1.0, abs(an)), (i, j, fd, an)

    def test_grad_shapes_match_specs(self):
        params = init_params(CFG, jax.random.PRNGKey(4))
        step = train_step(CFG)
        out = step(*params, tokens_for(CFG, 4))
        grads = out[1:]
        for g, (name, shape, _) in zip(grads, param_specs(CFG)):
            assert g.shape == shape, name


class TestLearning:
    def test_few_sgd_steps_reduce_loss_on_repeated_batch(self):
        cfg = ModelConfig(vocab=32, hidden=16, intermediate=24, heads=2, layers=1,
                          batch=2, seq=8, use_pallas_head=False)
        params = init_params(cfg, jax.random.PRNGKey(5))
        toks = tokens_for(cfg, 5)
        step = jax.jit(train_step(cfg))
        l0 = None
        for _ in range(20):
            out = step(*params, toks)
            loss, grads = out[0], out[1:]
            if l0 is None:
                l0 = float(loss)
            params = [p - 0.5 * g for p, g in zip(params, grads)]
        l1 = float(forward(cfg, params, toks))
        assert l1 < 0.7 * l0, (l0, l1)
