//! Native-LM step timings (DESIGN.md §10): the manual fwd+bwd pass in
//! isolation, and the full train step (fwd+bwd + sync + optimizer) for
//! dense AdamW vs TSR-Adam on the 64-vocab / 2-layer model at the
//! `--source lm` CLI defaults. This is the `lm_step` leg of CI's
//! bench-smoke job (p50 JSON artifact gated by `ci/bench_regression.py`).
//!
//! Run: `cargo bench --bench lm_step`

use tsr::comm::{CommLedger, Topology};
use tsr::exp::lm_curves::lm_tsr_cfg;
use tsr::exp::MethodCfg;
use tsr::optim::{AdamHyper, StepCtx};
use tsr::train::lm_source::LmSource;
use tsr::train::GradSource;
use tsr::util::bench::Bencher;

fn main() {
    let mut b = Bencher::new();
    let workers = 2;
    let mut source = LmSource::small(workers, 1);
    let blocks = source.blocks().to_vec();
    let mut params = source.init_params(2);
    let mut grads = tsr::optim::alloc_worker_grads(&blocks, workers);
    let topo = Topology::multi_node(2, 1);
    // Honour TSR_BACKEND so the smoke job can also time the threaded
    // backend; resolved once, outside the timed loops.
    let exec = tsr::exec::ExecBackend::from_env();

    b.bench("lm fwd+bwd compute (2w v64 h32 l2 b4 s16)", || {
        source.compute(&params, 0, &mut grads);
    });

    // The canonical TSR config the lm-curves table reports and the
    // acceptance test asserts — the bench times that exact setting.
    for (label, cfg) in [
        ("adamw", MethodCfg::Adam),
        ("tsr", MethodCfg::Tsr(lm_tsr_cfg(source.model().hidden))),
    ] {
        let mut opt = cfg.build(&blocks, AdamHyper::default(), workers);
        let mut ledger = CommLedger::new();
        b.bench(&format!("lm {label} full step (fwd+bwd+sync)"), || {
            source.compute(&params, 0, &mut grads);
            opt.step(&mut StepCtx {
                params: &mut params,
                grads: &mut grads,
                ledger: &mut ledger,
                topo: &topo,
                lr_mult: 1.0,
                exec: &exec,
            });
            ledger.end_step();
        });
    }

    // CI bench-smoke artifact (no-op unless BENCH_JSON_DIR is set).
    b.write_json("lm_step");
}
