//! Micro-benchmarks: linalg kernels, collectives, and the TSR hot path
//! (core projection + lift) at representative block shapes.
//!
//! Run: `cargo bench --bench micro` (BENCH_MS=200 for a quick pass).

use tsr::comm::collective::ring_allreduce_mean;
use tsr::linalg::{core_project, lift, matmul, orth, rsvd, svd_gram, Matrix};
use tsr::util::bench::Bencher;
use tsr::util::rng::Xoshiro256;

fn main() {
    let mut b = Bencher::new();
    let mut rng = Xoshiro256::new(42);

    // --- matmul at LLaMA block shapes (60M scale: h=512, f=1376) ---
    for &(m, k, n, label) in &[
        (512usize, 512usize, 512usize, "matmul 512x512x512 (qkv/o)"),
        (512, 1376, 512, "matmul 512x1376x512 (mlp.down)"),
        (256, 256, 256, "matmul 256^3"),
    ] {
        let x = Matrix::gaussian(m, k, 1.0, &mut rng);
        let y = Matrix::gaussian(k, n, 1.0, &mut rng);
        b.bench(label, || {
            std::hint::black_box(matmul(&x, &y));
        });
    }

    // --- the TSR hot path: Uᵀ G V and U D Vᵀ at paper rank configs ---
    for &(m, n, r, label) in &[
        (512usize, 512usize, 256usize, "core_project 512x512 r=256 (60M)"),
        (2048, 2048, 512, "core_project 2048x2048 r=512 (1B)"),
        (32000, 512, 64, "core_project 32000x512 r=64 (emb 60M)"),
    ] {
        let g = Matrix::gaussian(m, n, 1.0, &mut rng);
        let u = orth(&Matrix::gaussian(m, r, 1.0, &mut rng));
        let v = orth(&Matrix::gaussian(n, r, 1.0, &mut rng));
        b.bench(label, || {
            std::hint::black_box(core_project(&u, &g, &v));
        });
        let d = Matrix::gaussian(r, r, 1.0, &mut rng);
        b.bench(&format!("lift {m}x{n} r={r}"), || {
            std::hint::black_box(lift(&u, &d, &v));
        });
    }

    // --- refresh building blocks ---
    let g = Matrix::gaussian(512, 512, 1.0, &mut rng);
    b.bench("orth(Y) 512x72 (sketch QR)", || {
        let y = Matrix::gaussian(512, 72, 1.0, &mut rng);
        std::hint::black_box(orth(&y));
    });
    let bmat = Matrix::gaussian(72, 512, 1.0, &mut rng);
    b.bench("svd_gram 72x512 (refresh small SVD)", || {
        std::hint::black_box(svd_gram(&bmat));
    });
    b.bench("rsvd 512x512 r=64 q=1 (centralized)", || {
        let mut r2 = Xoshiro256::new(9);
        std::hint::black_box(rsvd(&g, 64, 8, 1, &mut r2));
    });

    // --- collectives: r² core vs dense payloads, 8 workers ---
    for &(rows, cols, label) in &[
        (256usize, 256usize, "ring all-reduce 256x256 core (8w)"),
        (512, 1376, "ring all-reduce 512x1376 dense (8w)"),
    ] {
        let base: Vec<Matrix> = (0..8)
            .map(|_| Matrix::gaussian(rows, cols, 1.0, &mut rng))
            .collect();
        b.bench(label, || {
            let mut ws = base.clone();
            std::hint::black_box(ring_allreduce_mean(&mut ws));
        });
    }

    println!("\nmicro bench done ({} entries)", b.results().len());
}
