//! Table 3 "UPDATE TIME" column: wall-clock of one full optimizer step
//! at real paper scales on this host (gradients pre-synthesized, so this
//! isolates sync + projection + moment update + lift).
//!
//! 60M and 130M run at full scale; 350M/1B per-block extrapolation is
//! printed to keep bench memory bounded (the full-scale path is
//! available via `tsr table3`).
//!
//! Run: `cargo bench --bench optimizer_step`

use tsr::comm::{CommLedger, Topology};
use tsr::exp::MethodCfg;
use tsr::model::ModelSpec;
use tsr::optim::onesided::OneSidedRefresh;
use tsr::optim::{AdamHyper, StepCtx, TsrConfig};
use tsr::train::gradsim::QuadraticSim;
use tsr::train::GradSource;
use tsr::util::bench::Bencher;

fn bench_scale(b: &mut Bencher, scale: &str, galore_rank: usize, tsr_rank: usize, tsr_emb: usize) {
    let spec = ModelSpec::by_name(scale).unwrap();
    let workers = 2;
    let mut sim = QuadraticSim::new(&spec, workers, 8, 0.0, 1);
    let blocks = sim.blocks().to_vec();
    let mut params = sim.init_params(2);
    let mut grads = tsr::optim::alloc_worker_grads(&blocks, workers);
    sim.compute(&params, 0, &mut grads);
    let topo = Topology::multi_node(2, 1);
    // Honour TSR_BACKEND so the smoke job can also time the threaded
    // backend; resolved once, outside the timed loops.
    let exec = tsr::exec::ExecBackend::from_env();

    for (label, cfg) in [
        ("adamw", MethodCfg::Adam),
        (
            "galore",
            MethodCfg::OneSided {
                rank: galore_rank,
                k: 200,
                refresh: OneSidedRefresh::RandomizedSvd,
            },
        ),
        (
            "tsr",
            MethodCfg::Tsr(TsrConfig {
                rank: tsr_rank,
                rank_emb: tsr_emb,
                refresh_every: 100,
                refresh_emb: 100,
                oversample: 8,
                ..Default::default()
            }),
        ),
        ("signadam", MethodCfg::Sign { k_var: 1000 }),
        ("topk", MethodCfg::TopK { keep_frac: 0.005 }),
    ] {
        // Cell coordinates for ci/bench_regression.py: a baseline entry
        // only compares against a candidate with the identical label
        // set, so renamed/moved cells read as added+removed, never as a
        // bogus regression.
        b.set_labels(&[("method", label), ("fmt", "f32"), ("scale", scale)]);
        let mut opt = cfg.build(&blocks, AdamHyper::default(), workers);
        let mut ledger = CommLedger::new();
        // First step performs the (init) refresh — time it separately:
        // the paper's "UPDATE TIME" column is the refresh-amortized
        // average over one interval, which is where TSR's cheap rSVD
        // beats GaLore's dense-gradient SVD.
        let t0 = std::time::Instant::now();
        opt.step(&mut StepCtx {
            params: &mut params,
            grads: &mut grads,
            ledger: &mut ledger,
            topo: &topo,
            lr_mult: 1.0,
            exec: &exec,
        });
        ledger.end_step();
        let refresh_secs = t0.elapsed().as_secs_f64();
        let steady = b.bench(&format!("{scale} {label} steady step ({workers}w)"), || {
            opt.step(&mut StepCtx {
                params: &mut params,
                grads: &mut grads,
                ledger: &mut ledger,
                topo: &topo,
                lr_mult: 1.0,
                exec: &exec,
            });
            ledger.end_step();
        });
        // Refresh-amortized reporting for every method with a periodic
        // dense/sketch event; the interval comes from the config itself
        // so it cannot drift from the method list above. Top-k has flat
        // per-step traffic (no refresh to amortize), adamw is all-dense.
        let k = match &cfg {
            MethodCfg::OneSided { k, .. } => *k as f64,
            MethodCfg::Tsr(c) => c.refresh_every as f64,
            MethodCfg::Sign { k_var } => *k_var as f64,
            _ => 0.0,
        };
        if k > 0.0 {
            b.report(&format!("{scale} {label} refresh step"), refresh_secs, "s");
            b.report(
                &format!("{scale} {label} amortized (K={k})"),
                (refresh_secs + (k - 1.0) * steady) / k,
                "s/step",
            );
        }
    }
    b.set_labels(&[]);
}

fn main() {
    let mut b = Bencher::new();
    // Paper Table 3 ranks. 60M runs at FULL scale by default; the larger
    // scales are opt-in (BENCH_SCALES=60m,130m) — a 130M TSR step is
    // ~100 GFLOPs of projections and this host may be a single core.
    let scales = std::env::var("BENCH_SCALES").unwrap_or_else(|_| "60m".into());
    for s in scales.split(',') {
        match s.trim() {
            "60m" => bench_scale(&mut b, "60m", 128, 256, 64),
            "130m" => bench_scale(&mut b, "130m", 256, 384, 96),
            "350m" => bench_scale(&mut b, "350m", 256, 384, 128),
            other => eprintln!("skip unknown scale {other}"),
        }
    }
    // CI bench-smoke artifact (no-op unless BENCH_JSON_DIR is set).
    b.write_json("optimizer_step");
    println!("\n(1B: run `tsr table3 --timing` — full-scale steps need >16 GB of grads)");
}
