//! Regenerate every paper *figure*'s data series (Figs. 1, 3, 4, 5) plus
//! the Theorem 1 validation sweep. JSON series land in results/.
//!
//! Run: `cargo bench --bench paper_figures`
//! (FIG_STEPS=400 for higher-fidelity curves; default keeps bench quick.)

use tsr::exp::{figures, theory};

fn main() {
    let steps = std::env::var("FIG_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(48);
    // Sized for the CI host (single core); `tsr fig1 --workers 8
    // --steps 400` regenerates publication-fidelity series.
    let workers = 2;

    figures::fig1(steps, workers);
    figures::fig3(steps, workers);
    figures::fig4(steps, workers);
    figures::fig5(steps, workers);
    theory::theory_sweep(&[50, 100, 200, 400], 2, 25);
}
