//! Regenerate every paper *table* (Tables 1, 2, 3, 4, 6).
//!
//! Byte/memory columns are exact counting identities; Table 3's loss
//! column uses short proxy runs and its update-time column measures one
//! full-scale optimizer step on this host (60M/130M; larger scales are
//! reported by the analytic profile only under `cargo bench` to keep
//! memory in bounds — run `tsr table3` for the full version).
//!
//! Run: `cargo bench --bench paper_tables`

use tsr::exp::tables;

fn main() {
    // Table 1 at the paper's illustrative shape.
    tables::table1(4096, 4096, 128);

    // Table 2 for the 60M config at the paper's ranks.
    let spec = tsr::model::ModelSpec::llama_60m();
    tables::table2(&spec, 256, 64);

    // Table 3: bytes/peak/memory for all four scales. Short proxy-loss
    // runs; timing off here (see bench `optimizer_step` for timings).
    tables::table3(40, false);

    // Table 4: GLUE byte accounting + synthetic-task metric parity.
    tables::table4(80);

    // Table 6: extra TSR configurations.
    tables::table6();
}
