//! Discrete-event engine throughput: how fast the step-time simulator
//! itself runs. One "op" simulates a full refresh period (100 steps) of
//! a method's payload schedule on a 4×8 cluster — the unit of work the
//! `tsr simtime` experiment performs per (method, topology) cell.
//!
//! Run: `cargo bench --bench sim_step`

use tsr::comm::Topology;
use tsr::exp::simtime::method_roster;
use tsr::model::ModelSpec;
use tsr::optim::AdamHyper;
use tsr::sim::{simulate_method, simulate_step, SimCfg};
use tsr::util::bench::Bencher;

fn main() {
    let mut b = Bencher::new();
    let spec = ModelSpec::llama_60m();
    let blocks = spec.blocks();
    let topo = Topology::multi_node(4, 8);
    let cfg = SimCfg::default();

    for m in method_roster("60m") {
        // Construct once (single replica — schedules are shape-only);
        // the bench isolates the engine, not optimizer construction.
        let opt = m.build(&blocks, AdamHyper::default(), 1);
        b.bench(&format!("simulate_method 100 steps {}", m.label()), || {
            let tl = simulate_method(opt.as_ref(), &blocks, &topo, &cfg, 100);
            assert!(tl.avg_step_secs > 0.0);
        });
    }

    // Single-step cost across bucket sizes (bucketing granularity sweep).
    let opt = method_roster("60m")[0].build(&blocks, AdamHyper::default(), 1);
    let plan = opt.sync_plan(1);
    for kb in [0usize, 1024, 25 * 1024] {
        let cfg = SimCfg {
            bucket_bytes: kb * 1024,
            ..Default::default()
        };
        b.bench(&format!("simulate_step adamw bucket={kb}KiB"), || {
            let tl = simulate_step(&blocks, &plan, &topo, &cfg);
            assert!(tl.step_secs > 0.0);
        });
    }

    // CI bench-smoke artifact (no-op unless BENCH_JSON_DIR is set).
    b.write_json("sim_step");
}
