//! Bit-exact JSON codecs for checkpoint payloads.
//!
//! The bitwise-resume contract (DESIGN.md §9) forbids any value from
//! drifting through serialization. JSON numbers are f64 and our writer
//! collapses `-0.0` to `0`, so floats are NOT stored as JSON numbers:
//! * f32 tensors → a hex string of their little-endian bit patterns
//!   (8 hex chars per element; exact for every bit pattern including
//!   -0.0, subnormals, infinities, and NaN payloads),
//! * f64 accumulators → a 16-hex-char string of `to_bits()`,
//! * u64 counters / RNG words → 16-hex-char strings (f64 can only
//!   represent integers exactly up to 2⁵³).
//! Small integers (shapes, byte counts < 2⁵³) stay plain JSON numbers.

use crate::linalg::Matrix;
use crate::util::json::Json;

const HEX: &[u8; 16] = b"0123456789abcdef";

fn push_byte_hex(out: &mut String, b: u8) {
    out.push(HEX[(b >> 4) as usize] as char);
    out.push(HEX[(b & 0xF) as usize] as char);
}

/// Little-endian bit-pattern hex of an f32 slice (8 chars/element).
pub fn f32s_to_hex(data: &[f32]) -> String {
    let mut out = String::with_capacity(data.len() * 8);
    for v in data {
        for b in v.to_le_bytes() {
            push_byte_hex(&mut out, b);
        }
    }
    out
}

fn hex_val(c: u8) -> Result<u8, String> {
    match c {
        b'0'..=b'9' => Ok(c - b'0'),
        b'a'..=b'f' => Ok(c - b'a' + 10),
        b'A'..=b'F' => Ok(c - b'A' + 10),
        _ => Err(format!("invalid hex digit {:?}", c as char)),
    }
}

fn bytes_from_hex(s: &str) -> Result<Vec<u8>, String> {
    let b = s.as_bytes();
    if b.len() % 2 != 0 {
        return Err(format!("odd hex length {}", b.len()));
    }
    let mut out = Vec::with_capacity(b.len() / 2);
    for pair in b.chunks_exact(2) {
        out.push((hex_val(pair[0])? << 4) | hex_val(pair[1])?);
    }
    Ok(out)
}

/// Inverse of [`f32s_to_hex`].
pub fn f32s_from_hex(s: &str) -> Result<Vec<f32>, String> {
    let bytes = bytes_from_hex(s)?;
    if bytes.len() % 4 != 0 {
        return Err(format!("hex length {} is not a whole f32 count", s.len()));
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

pub fn u64_to_json(x: u64) -> Json {
    Json::str(format!("{x:016x}"))
}

/// Decode a u64 bit word. The writer always emits exactly 16 hex
/// digits, so any other length is a truncated/corrupted field — reject
/// it rather than decode a silently wrong value.
pub fn u64_from_json(j: &Json, what: &str) -> Result<u64, String> {
    let s = j.as_str().ok_or_else(|| format!("{what}: expected hex string"))?;
    if s.len() != 16 {
        return Err(format!("{what}: expected 16 hex digits, got {:?}", s));
    }
    u64::from_str_radix(s, 16).map_err(|e| format!("{what}: bad hex {s:?}: {e}"))
}

/// Fetch `key` from an object, erroring when the key is ABSENT — this
/// keeps a present-but-null optional field (e.g. `init_step`)
/// distinguishable from a field a corrupted manifest dropped
/// (`Json::get` alone returns `Null` for both).
pub fn require<'a>(j: &'a Json, key: &str, what: &str) -> Result<&'a Json, String> {
    j.as_obj()
        .and_then(|o| o.get(key))
        .ok_or_else(|| format!("{what}: missing field {key:?}"))
}

/// `Option<u64>` as hex-or-null (refresh `init_step` bookkeeping).
pub fn opt_u64_to_json(x: Option<u64>) -> Json {
    match x {
        Some(v) => u64_to_json(v),
        None => Json::Null,
    }
}

pub fn opt_u64_from_json(j: &Json, what: &str) -> Result<Option<u64>, String> {
    match j {
        Json::Null => Ok(None),
        other => u64_from_json(other, what).map(Some),
    }
}

/// f64 as its exact bit pattern (accumulators like `predicted_step_secs`
/// must resume bit-identically).
pub fn f64_to_json(x: f64) -> Json {
    u64_to_json(x.to_bits())
}

pub fn f64_from_json(j: &Json, what: &str) -> Result<f64, String> {
    u64_from_json(j, what).map(f64::from_bits)
}

/// f32 scalar via its bit pattern (writer emits exactly 8 hex digits).
pub fn f32_to_json(x: f32) -> Json {
    Json::str(format!("{:08x}", x.to_bits()))
}

pub fn f32_from_json(j: &Json, what: &str) -> Result<f32, String> {
    let s = j.as_str().ok_or_else(|| format!("{what}: expected hex string"))?;
    if s.len() != 8 {
        return Err(format!("{what}: expected 8 hex digits, got {:?}", s));
    }
    u32::from_str_radix(s, 16)
        .map(f32::from_bits)
        .map_err(|e| format!("{what}: bad hex {s:?}: {e}"))
}

/// `{rng_s, rng_spare}` — a [`crate::util::rng::Xoshiro256`] stream
/// position: the four state words as u64 bit patterns plus the cached
/// Box–Muller spare. One shared codec for every gradient source's
/// checkpoint payload (QuadraticSim's noise RNG, the LM batcher's
/// per-worker streams), so the bit-sensitive encoding cannot fork.
pub fn rng_to_json(s: &[u64; 4], spare: Option<f64>) -> Json {
    Json::obj(vec![
        ("rng_s", Json::arr(s.iter().map(|&w| u64_to_json(w)).collect())),
        (
            "rng_spare",
            match spare {
                Some(g) => f64_to_json(g),
                None => Json::Null,
            },
        ),
    ])
}

/// Inverse of [`rng_to_json`]; feeds `Xoshiro256::from_snapshot`.
pub fn rng_from_json(j: &Json, what: &str) -> Result<([u64; 4], Option<f64>), String> {
    let words = j.get("rng_s").as_arr().ok_or_else(|| format!("{what}: missing rng_s"))?;
    if words.len() != 4 {
        return Err(format!("{what}: rng_s has {} words, expected 4", words.len()));
    }
    let mut s = [0u64; 4];
    for (i, w) in words.iter().enumerate() {
        s[i] = u64_from_json(w, &format!("{what}.rng_s[{i}]"))?;
    }
    let spare = match j.get("rng_spare") {
        Json::Null => None,
        other => Some(f64_from_json(other, &format!("{what}.rng_spare"))?),
    };
    Ok((s, spare))
}

/// `{rows, cols, f32le}` — shape plus the bit-exact payload.
pub fn matrix_to_json(m: &Matrix) -> Json {
    Json::obj(vec![
        ("rows", Json::num(m.rows as f64)),
        ("cols", Json::num(m.cols as f64)),
        ("f32le", Json::str(f32s_to_hex(&m.data))),
    ])
}

pub fn matrix_from_json(j: &Json, what: &str) -> Result<Matrix, String> {
    let rows = j.get("rows").as_usize().ok_or_else(|| format!("{what}: missing rows"))?;
    let cols = j.get("cols").as_usize().ok_or_else(|| format!("{what}: missing cols"))?;
    let data = f32s_from_hex(
        j.get("f32le").as_str().ok_or_else(|| format!("{what}: missing f32le"))?,
    )
    .map_err(|e| format!("{what}: {e}"))?;
    if data.len() != rows * cols {
        return Err(format!(
            "{what}: payload has {} elements for a {rows}x{cols} matrix",
            data.len()
        ));
    }
    Ok(Matrix::from_vec(rows, cols, data))
}

/// [`matrix_from_json`] that also enforces the shape the loading
/// optimizer allocated — the structural guard every `load_state` uses.
pub fn matrix_from_json_expect(
    j: &Json,
    rows: usize,
    cols: usize,
    what: &str,
) -> Result<Matrix, String> {
    let m = matrix_from_json(j, what)?;
    if (m.rows, m.cols) != (rows, cols) {
        return Err(format!(
            "{what}: checkpoint is {}x{} but the run expects {rows}x{cols}",
            m.rows, m.cols
        ));
    }
    Ok(m)
}

pub fn matrices_to_json(ms: &[Matrix]) -> Json {
    Json::arr(ms.iter().map(matrix_to_json).collect())
}

pub fn matrices_from_json(j: &Json, what: &str) -> Result<Vec<Matrix>, String> {
    j.as_arr()
        .ok_or_else(|| format!("{what}: expected array"))?
        .iter()
        .enumerate()
        .map(|(i, m)| matrix_from_json(m, &format!("{what}[{i}]")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_snapshot_roundtrips_bitwise_through_text() {
        for spare in [None, Some(-0.0f64), Some(1.0 / 3.0)] {
            let s = [1u64, u64::MAX, 0x0123_4567_89AB_CDEF, 0];
            let text = rng_to_json(&s, spare).to_string_pretty();
            let back = Json::parse(&text).unwrap();
            let (s2, spare2) = rng_from_json(&back, "t").unwrap();
            assert_eq!(s, s2);
            assert_eq!(spare.map(f64::to_bits), spare2.map(f64::to_bits));
        }
        // Truncated state word list is rejected.
        let mut j = rng_to_json(&[1, 2, 3, 4], None);
        j.set("rng_s", Json::arr(vec![u64_to_json(1)]));
        assert!(rng_from_json(&j, "t").is_err());
    }

    #[test]
    fn f32_hex_roundtrips_every_special_bit_pattern() {
        let vals = vec![
            0.0f32,
            -0.0, // the case plain JSON numbers lose
            1.0,
            -1.5e-8,
            f32::MIN_POSITIVE / 8.0, // subnormal
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::NAN,
            f32::from_bits(0x7fc0_dead), // NaN payload
        ];
        let back = f32s_from_hex(&f32s_to_hex(&vals)).unwrap();
        assert_eq!(vals.len(), back.len());
        for (a, b) in vals.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn u64_and_f64_roundtrip_extremes() {
        for x in [0u64, 1, u64::MAX, 1 << 63, 0x0123_4567_89ab_cdef] {
            assert_eq!(u64_from_json(&u64_to_json(x), "x").unwrap(), x);
        }
        for x in [0.0f64, -0.0, 1.0 / 3.0, f64::MAX, f64::NAN] {
            let back = f64_from_json(&f64_to_json(x), "x").unwrap();
            assert_eq!(x.to_bits(), back.to_bits());
        }
    }

    #[test]
    fn scalar_decoders_reject_truncated_fields() {
        // The writers emit fixed 16/8-digit words; anything else is a
        // corrupted manifest and must not decode to a wrong value.
        assert!(u64_from_json(&Json::str("3f80"), "t").is_err());
        assert!(u64_from_json(&Json::str("00000000000000000a"), "t").is_err());
        assert!(f32_from_json(&Json::str("3f80"), "x").is_err());
        assert!(u64_from_json(&Json::num(5.0), "t").is_err());
    }

    #[test]
    fn require_distinguishes_absent_from_null() {
        let j = Json::obj(vec![("present_null", Json::Null)]);
        assert!(require(&j, "present_null", "j").is_ok());
        assert_eq!(require(&j, "present_null", "j").unwrap(), &Json::Null);
        assert!(require(&j, "absent", "j").is_err());
        assert!(require(&Json::Null, "any", "j").is_err());
    }

    #[test]
    fn matrix_roundtrip_bitwise_through_text() {
        let mut rng = crate::util::rng::Xoshiro256::new(5);
        let m = Matrix::gaussian(7, 13, 1.0, &mut rng);
        // Through the full text layer, as a checkpoint file would go.
        let text = matrix_to_json(&m).to_string_pretty();
        let back = matrix_from_json(&Json::parse(&text).unwrap(), "m").unwrap();
        assert_eq!((back.rows, back.cols), (7, 13));
        for (a, b) in m.data.iter().zip(&back.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert!(matrix_from_json_expect(&matrix_to_json(&m), 7, 12, "m").is_err());
    }

    #[test]
    fn malformed_hex_is_rejected() {
        assert!(f32s_from_hex("abc").is_err()); // odd length
        assert!(f32s_from_hex("zz00zz00").is_err()); // non-hex
        assert!(f32s_from_hex("aabb").is_err()); // not a whole f32
    }
}
