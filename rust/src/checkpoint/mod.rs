//! Checkpoint / resume subsystem (DESIGN.md §9).
//!
//! A checkpoint is one versioned JSON manifest capturing *everything*
//! step-dependent in a run:
//! * the replicated parameters,
//! * the optimizer's full state ([`crate::optim::DistOptimizer::save_state`]:
//!   step counter, dense/core Adam moments, bases U/V, error-feedback
//!   buffers, refresh bookkeeping),
//! * the gradient source's RNG stream position,
//! * the run-so-far metrics (losses, predicted-time accumulators) and
//!   every closed [`crate::comm::CommLedger`] step record,
//! * a free-form run-config echo the CLI uses to rebuild the setup.
//!
//! **Determinism contract.** A run interrupted at any step and resumed
//! from its checkpoint — same world size, either execution backend —
//! produces the byte-identical deterministic metrics JSON (weights
//! fingerprint and every ledger column included) as the uninterrupted
//! run. Enforced by `rust/tests/checkpoint.rs` and CI's determinism
//! gate. All floats are stored as bit patterns ([`codec`]), never as
//! JSON numbers.
//!
//! **Elastic restarts.** Resuming with a different worker count is
//! supported (not bitwise — the noise stream fans out differently):
//! replicated state reloads as-is, and per-worker error-feedback
//! buffers are regathered to their canonical across-worker mean on
//! save and re-sharded over the new worker count on load
//! ([`errors_to_json`] / [`errors_from_json`]), ragged
//! `numel % workers != 0` included. Per-worker *replicated* state
//! (local-update methods' parameter replicas and moments) instead
//! broadcasts its canonical mean to every worker on an elastic load
//! ([`replicas_to_json`] / [`replicas_from_json`]).

pub mod codec;

use crate::comm::CommLedger;
use crate::linalg::Matrix;
use crate::metrics::RunMetrics;
use crate::optim::DistOptimizer;
use crate::train::GradSource;
use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// Manifest format version; bump on any incompatible layout change.
pub const CHECKPOINT_VERSION: u64 = 1;

/// One saved training state. See the module docs for the contract.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    /// Optimizer steps completed; the resumed run starts at this step.
    pub step: u64,
    /// World size the checkpoint was taken at.
    pub workers: usize,
    /// `DistOptimizer::name()` — structural guard against resuming with
    /// a different method.
    pub method: String,
    pub params: Vec<Matrix>,
    pub opt_state: Json,
    /// Gradient-source state (`Json::Null` for stateless sources).
    pub source_state: Json,
    pub metrics: Json,
    pub ledger: Json,
    /// Run-config echo (CLI arguments); the resume path rebuilds the
    /// setup from this rather than trusting re-typed flags.
    pub config: Json,
}

impl Checkpoint {
    /// Snapshot a live run. Call after `CommLedger::end_step` so the
    /// ledger has no half-accumulated step.
    #[allow(clippy::too_many_arguments)]
    pub fn capture(
        step: u64,
        workers: usize,
        params: &[Matrix],
        opt: &dyn DistOptimizer,
        source: &dyn GradSource,
        metrics: &RunMetrics,
        ledger: &CommLedger,
        config: Json,
    ) -> Self {
        Self {
            step,
            workers,
            method: opt.name().to_string(),
            params: params.to_vec(),
            opt_state: opt.save_state(),
            source_state: source.save_state(),
            metrics: metrics.state_to_json(),
            ledger: ledger.to_json(),
            config,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("version", Json::num(CHECKPOINT_VERSION as f64)),
            ("step", codec::u64_to_json(self.step)),
            ("workers", Json::num(self.workers as f64)),
            ("method", Json::str(self.method.clone())),
            ("params", codec::matrices_to_json(&self.params)),
            ("opt_state", self.opt_state.clone()),
            ("source_state", self.source_state.clone()),
            ("metrics", self.metrics.clone()),
            ("ledger", self.ledger.clone()),
            ("config", self.config.clone()),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self, String> {
        let version = j.get("version").as_u64().ok_or("checkpoint: missing version")?;
        if version != CHECKPOINT_VERSION {
            return Err(format!(
                "checkpoint: version {version} unsupported (this build reads {CHECKPOINT_VERSION})"
            ));
        }
        Ok(Self {
            step: codec::u64_from_json(j.get("step"), "checkpoint.step")?,
            workers: j.get("workers").as_usize().ok_or("checkpoint: missing workers")?,
            method: j
                .get("method")
                .as_str()
                .ok_or("checkpoint: missing method")?
                .to_string(),
            params: codec::matrices_from_json(j.get("params"), "checkpoint.params")?,
            opt_state: j.get("opt_state").clone(),
            source_state: j.get("source_state").clone(),
            metrics: j.get("metrics").clone(),
            ledger: j.get("ledger").clone(),
            config: j.get("config").clone(),
        })
    }

    /// Write `ckpt_step<step>.json` under `dir` (atomic tmp+rename).
    pub fn save(&self, dir: impl AsRef<Path>) -> Result<PathBuf, String> {
        let path = dir.as_ref().join(format!("ckpt_step{}.json", self.step));
        self.to_json().write_file_atomic(&path)?;
        Ok(path)
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self, String> {
        Self::from_json(&Json::read_file(path)?)
    }
}

/// Serialize per-worker error-feedback buffers: the exact per-worker
/// list (bitwise same-world-size resume) plus their canonical
/// across-worker mean (the elastic-restart payload).
pub fn errors_to_json(errors: &[Matrix]) -> Json {
    Json::obj(vec![
        ("mean", codec::matrix_to_json(&errors_mean(errors))),
        ("per_worker", codec::matrices_to_json(errors)),
    ])
}

/// Canonical mean of per-worker buffers, summed in worker order (a
/// fixed, backend-independent reduction order).
fn errors_mean(errors: &[Matrix]) -> Matrix {
    let mut mean = errors[0].clone();
    for e in &errors[1..] {
        mean.add_assign(e);
    }
    mean.scale(1.0 / errors.len() as f32);
    mean
}

/// Restore error-feedback buffers for a (possibly different) world
/// size of `workers`:
/// * saved count == `workers` → bit-exact per-worker restore;
/// * saved count != `workers` → **re-shard the canonical mean**:
///   worker `w` holds `workers · mean` on its contiguous shard (the
///   same [`crate::exec::shard_bounds`] split the collectives use —
///   ragged `numel % workers != 0` gives shards differing by one
///   element) and zeros elsewhere. The across-worker mean of the
///   restored buffers reproduces the canonical mean elementwise —
///   bitwise for power-of-two worker counts, to one f32 rounding of
///   `(W·c)/W` otherwise (elastic restarts are not bitwise anyway).
///
/// A manifest whose `per_worker` field is missing or malformed is
/// rejected — never silently mean-resharded — so a same-world-size
/// resume cannot quietly lose the bitwise contract.
pub fn errors_from_json(
    j: &Json,
    rows: usize,
    cols: usize,
    workers: usize,
    what: &str,
) -> Result<Vec<Matrix>, String> {
    let saved = j
        .get("per_worker")
        .as_arr()
        .ok_or_else(|| format!("{what}: missing per_worker list"))?;
    if saved.len() == workers {
        return saved
            .iter()
            .enumerate()
            .map(|(w, m)| {
                codec::matrix_from_json_expect(m, rows, cols, &format!("{what}.per_worker[{w}]"))
            })
            .collect();
    }
    let mean = codec::matrix_from_json_expect(j.get("mean"), rows, cols, &format!("{what}.mean"))?;
    Ok(reshard_mean(&mean, workers))
}

/// Serialize per-worker REPLICATED state (local-update optimizers'
/// parameter replicas and per-worker Adam moments — `DesLoc`, `Lordo`):
/// the exact per-worker list for bitwise same-world resume plus the
/// canonical across-worker mean for elastic restarts. Same layout as
/// [`errors_to_json`]; the two differ only in how they *restore* at a
/// changed world size.
pub fn replicas_to_json(replicas: &[Matrix]) -> Json {
    Json::obj(vec![
        ("mean", codec::matrix_to_json(&errors_mean(replicas))),
        ("per_worker", codec::matrices_to_json(replicas)),
    ])
}

/// Restore per-worker replicated state for a (possibly different) world
/// size of `workers`:
/// * saved count == `workers` → bit-exact per-worker restore;
/// * saved count != `workers` → **broadcast the canonical mean** to
///   every worker. Replicated state is a full *copy* per worker — so,
///   unlike error-feedback buffers (whose across-worker mean is the
///   invariant [`errors_from_json`] re-shards), the faithful elastic
///   restore starts every worker from the consensus point, exactly as
///   a fresh sync boundary would.
///
/// A manifest whose `per_worker` field is missing or malformed is
/// rejected — never silently mean-broadcast — so a same-world-size
/// resume cannot quietly lose the bitwise contract.
pub fn replicas_from_json(
    j: &Json,
    rows: usize,
    cols: usize,
    workers: usize,
    what: &str,
) -> Result<Vec<Matrix>, String> {
    let saved = j
        .get("per_worker")
        .as_arr()
        .ok_or_else(|| format!("{what}: missing per_worker list"))?;
    if saved.len() == workers {
        return saved
            .iter()
            .enumerate()
            .map(|(w, m)| {
                codec::matrix_from_json_expect(m, rows, cols, &format!("{what}.per_worker[{w}]"))
            })
            .collect();
    }
    let mean = codec::matrix_from_json_expect(j.get("mean"), rows, cols, &format!("{what}.mean"))?;
    Ok((0..workers).map(|_| mean.clone()).collect())
}

/// The elastic re-shard described on [`errors_from_json`].
pub fn reshard_mean(mean: &Matrix, workers: usize) -> Vec<Matrix> {
    let bounds = crate::exec::shard_bounds(mean.numel(), workers);
    (0..workers)
        .map(|w| {
            let mut m = Matrix::zeros(mean.rows, mean.cols);
            for i in bounds[w]..bounds[w + 1] {
                m.data[i] = workers as f32 * mean.data[i];
            }
            m
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn errors_roundtrip_exactly_at_same_world_size() {
        let mut rng = Xoshiro256::new(3);
        let errors: Vec<Matrix> = (0..3).map(|_| Matrix::gaussian(5, 7, 1.0, &mut rng)).collect();
        let j = errors_to_json(&errors);
        let back = errors_from_json(&j, 5, 7, 3, "e").unwrap();
        for (a, b) in errors.iter().zip(&back) {
            for (x, y) in a.data.iter().zip(&b.data) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn elastic_reshard_preserves_canonical_mean_on_ragged_numel() {
        // 5×7 = 35 elements over 2 workers: 17/18 split (ragged), and
        // (2·c)/2 is exact in f32 — the restored across-worker mean
        // must equal the canonical mean BITWISE.
        let mut rng = Xoshiro256::new(9);
        let errors: Vec<Matrix> = (0..3).map(|_| Matrix::gaussian(5, 7, 1.0, &mut rng)).collect();
        let j = errors_to_json(&errors);
        let back = errors_from_json(&j, 5, 7, 2, "e").unwrap();
        assert_eq!(back.len(), 2);
        let mean = super::errors_mean(&errors);
        for i in 0..35 {
            let holders: Vec<f32> = back.iter().map(|m| m.data[i]).filter(|v| *v != 0.0).collect();
            let restored_mean = back.iter().map(|m| m.data[i]).sum::<f32>() / 2.0;
            if mean.data[i] != 0.0 {
                assert_eq!(holders.len(), 1, "element {i} must live on exactly one shard");
            }
            assert_eq!(restored_mean.to_bits(), mean.data[i].to_bits(), "element {i}");
        }
    }

    #[test]
    fn replicas_roundtrip_exactly_at_same_world_size() {
        let mut rng = Xoshiro256::new(21);
        let reps: Vec<Matrix> = (0..3).map(|_| Matrix::gaussian(4, 6, 1.0, &mut rng)).collect();
        let j = replicas_to_json(&reps);
        let back = replicas_from_json(&j, 4, 6, 3, "r").unwrap();
        for (a, b) in reps.iter().zip(&back) {
            for (x, y) in a.data.iter().zip(&b.data) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn elastic_replicas_broadcast_the_mean_not_a_shard() {
        // 3 saved workers → 5 restored: every worker must hold the FULL
        // canonical mean (a replica is a copy, not a shard).
        let mut rng = Xoshiro256::new(22);
        let reps: Vec<Matrix> = (0..3).map(|_| Matrix::gaussian(4, 6, 1.0, &mut rng)).collect();
        let j = replicas_to_json(&reps);
        let back = replicas_from_json(&j, 4, 6, 5, "r").unwrap();
        assert_eq!(back.len(), 5);
        let mean = super::errors_mean(&reps);
        for m in &back {
            for (x, y) in m.data.iter().zip(&mean.data) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn replicas_without_per_worker_list_are_rejected() {
        let mut rng = Xoshiro256::new(23);
        let reps: Vec<Matrix> = (0..2).map(|_| Matrix::gaussian(3, 3, 1.0, &mut rng)).collect();
        let mut j = replicas_to_json(&reps);
        j.set("per_worker", Json::Null);
        assert!(replicas_from_json(&j, 3, 3, 2, "r").is_err());
    }

    #[test]
    fn manifest_rejects_unknown_version() {
        let j = Json::obj(vec![("version", Json::num(99.0))]);
        assert!(Checkpoint::from_json(&j).is_err());
    }

    #[test]
    fn errors_without_per_worker_list_are_rejected_not_resharded() {
        // A dropped/corrupted per_worker field must fail loudly — a
        // silent mean-reshard at the same world size would break the
        // bitwise-resume contract without any diagnostic.
        let mut rng = Xoshiro256::new(4);
        let errors: Vec<Matrix> = (0..2).map(|_| Matrix::gaussian(3, 3, 1.0, &mut rng)).collect();
        let mut j = errors_to_json(&errors);
        j.set("per_worker", Json::Null);
        assert!(errors_from_json(&j, 3, 3, 2, "e").is_err());
    }
}
