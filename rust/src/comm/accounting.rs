//! Byte-exact communication accounting (paper §3.2).
//!
//! For each step t and layer ℓ, the synchronized tensor set S_t^(ℓ)
//! determines the step-wise communicated bytes
//! `B_t = Σ_ℓ b_dtype · |S_t^(ℓ)|`. We track:
//! * `Bytes/Step = (1/T) Σ_t B_t`   (Table 3 column),
//! * `PeakBytes  = max_t B_t`       (refresh-step spikes),
//! * `CumulativeBytes(t)`           (Fig. 1 x-axis),
//! plus a per-category breakdown (embedding vs linear vs dense-vector)
//! for Fig. 5(a).

/// Layer category for the Fig. 5 breakdown.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LayerClass {
    Embedding,
    Linear,
    /// Biases, norms — always synchronized dense (§3.4).
    Vector,
}

impl LayerClass {
    /// Stable lowercase name used by trace records and reports.
    pub fn name(&self) -> &'static str {
        match self {
            LayerClass::Embedding => "embedding",
            LayerClass::Linear => "linear",
            LayerClass::Vector => "vector",
        }
    }
}

/// Bytes per element of the communicated dtype (paper uses bf16 ⇒ 2,
/// fp32 ⇒ 4; we default to 4 matching our f32 simulation and report
/// ratios, which are dtype-invariant).
pub const BYTES_F32: usize = 4;
pub const BYTES_BF16: usize = 2;

#[derive(Clone, Debug, Default)]
pub struct StepRecord {
    pub total: usize,
    pub embedding: usize,
    pub linear: usize,
    pub vector: usize,
    /// Aggregate wire bytes moved over intra-node (NVLink-class) links
    /// this step, summed over workers (`collective::sync_mean`).
    pub intra: usize,
    /// Aggregate wire bytes moved over inter-node links this step.
    pub inter: usize,
    /// True if any layer refreshed its subspace this step.
    pub refresh: bool,
}

/// Communication ledger for one training run.
#[derive(Clone, Debug, Default)]
pub struct CommLedger {
    steps: Vec<StepRecord>,
    current: StepRecord,
    /// Simulated wall-clock communication time (α–β model), seconds.
    pub sim_time: f64,
    /// Attached tracer (disabled by default — [`crate::obs::Tracer`] is
    /// a no-op handle until `set_tracer` installs an enabled one). Rides
    /// on the ledger because the ledger already reaches every metering
    /// point via `StepCtx`; excluded from `to_json`/`from_json`, so a
    /// resumed run re-attaches explicitly.
    tracer: crate::obs::Tracer,
}

impl CommLedger {
    pub fn new() -> Self {
        Self::default()
    }

    /// Attach a tracer; every subsequent metering call also emits trace
    /// records through it.
    pub fn set_tracer(&mut self, tracer: crate::obs::Tracer) {
        self.tracer = tracer;
    }

    /// The attached tracer (disabled unless `set_tracer` installed one).
    pub fn tracer(&self) -> &crate::obs::Tracer {
        &self.tracer
    }

    /// Record `elements` f32 scalars synchronized for a layer of `class`.
    pub fn record(&mut self, class: LayerClass, elements: usize) {
        self.record_bytes(class, elements * BYTES_F32);
    }

    pub fn record_bytes(&mut self, class: LayerClass, bytes: usize) {
        self.current.total += bytes;
        match class {
            LayerClass::Embedding => self.current.embedding += bytes,
            LayerClass::Linear => self.current.linear += bytes,
            LayerClass::Vector => self.current.vector += bytes,
        }
    }

    /// Record wire bytes per link class for one collective: the payload
    /// columns above count the synchronized object once; these columns
    /// count what actually crossed each class of link, summed over
    /// workers. For the two-level schedule they obey the exact
    /// conservation `intra + inter == 2(N−1) · payload` (see
    /// `collective::hier_volume_bytes`).
    pub fn record_link(&mut self, intra_bytes: usize, inter_bytes: usize) {
        self.current.intra += intra_bytes;
        self.current.inter += inter_bytes;
    }

    pub fn mark_refresh(&mut self) {
        self.current.refresh = true;
    }

    pub fn add_sim_time(&mut self, secs: f64) {
        self.sim_time += secs;
    }

    /// Close the current step; begins accumulating the next one. With a
    /// tracer attached, emits one `step_bytes` record carrying the exact
    /// columns being closed — which is why the trace's per-step byte
    /// timeline equals the ledger f64-exactly by construction.
    pub fn end_step(&mut self) {
        let rec = std::mem::take(&mut self.current);
        self.tracer.step_bytes(self.steps.len() as u64, &rec, self.sim_time);
        self.steps.push(rec);
    }

    pub fn num_steps(&self) -> usize {
        self.steps.len()
    }

    pub fn step(&self, t: usize) -> &StepRecord {
        &self.steps[t]
    }

    /// Average communicated bytes per step (Table 3 "BYTES/STEP").
    pub fn bytes_per_step(&self) -> f64 {
        if self.steps.is_empty() {
            return 0.0;
        }
        self.steps.iter().map(|s| s.total as f64).sum::<f64>() / self.steps.len() as f64
    }

    /// Peak communicated bytes over all steps (Table 3 "PEAK BYTES").
    pub fn peak_bytes(&self) -> usize {
        self.steps.iter().map(|s| s.total).max().unwrap_or(0)
    }

    /// Cumulative bytes after each step (Fig. 1 x-axis).
    pub fn cumulative(&self) -> Vec<u64> {
        let mut acc = 0u64;
        self.steps
            .iter()
            .map(|s| {
                acc += s.total as u64;
                acc
            })
            .collect()
    }

    /// (intra, inter) aggregate wire-byte totals over the run — the
    /// per-link-class split of the hierarchical collectives.
    pub fn link_totals(&self) -> (u64, u64) {
        let mut intra = 0u64;
        let mut inter = 0u64;
        for s in &self.steps {
            intra += s.intra as u64;
            inter += s.inter as u64;
        }
        (intra, inter)
    }

    /// (embedding, linear, vector) byte totals — Fig. 5(a).
    pub fn breakdown(&self) -> (u64, u64, u64) {
        let mut e = 0u64;
        let mut l = 0u64;
        let mut v = 0u64;
        for s in &self.steps {
            e += s.embedding as u64;
            l += s.linear as u64;
            v += s.vector as u64;
        }
        (e, l, v)
    }

    /// Checkpoint serialization: every closed step record plus the
    /// simulated-time accumulator (as its exact f64 bit pattern). The
    /// half-accumulated `current` step is NOT captured — checkpoints
    /// are taken between `end_step` calls (`checkpoint::Checkpoint`),
    /// and serializing mid-step would silently drop data from the
    /// manifest, so ANY pending accumulation (payload bytes, wire
    /// bytes, or a refresh mark) is a hard error in every build.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let c = &self.current;
        assert!(
            c.total == 0
                && c.embedding == 0
                && c.linear == 0
                && c.vector == 0
                && c.intra == 0
                && c.inter == 0
                && !c.refresh,
            "checkpointing a ledger with a half-accumulated step (call end_step first)"
        );
        let steps = self
            .steps
            .iter()
            .map(|s| {
                Json::arr(vec![
                    Json::num(s.embedding as f64),
                    Json::num(s.linear as f64),
                    Json::num(s.vector as f64),
                    Json::num(s.intra as f64),
                    Json::num(s.inter as f64),
                    Json::Bool(s.refresh),
                ])
            })
            .collect();
        Json::obj(vec![
            ("steps", Json::arr(steps)),
            ("sim_time", crate::checkpoint::codec::f64_to_json(self.sim_time)),
        ])
    }

    /// Inverse of [`Self::to_json`]. `total` is reconstructed from the
    /// per-class columns (an invariant of `record_bytes`).
    pub fn from_json(j: &crate::util::json::Json) -> Result<Self, String> {
        let mut ledger = Self::new();
        let steps = j.get("steps").as_arr().ok_or("ledger: missing steps")?;
        for (t, s) in steps.iter().enumerate() {
            let cols = s.as_arr().ok_or_else(|| format!("ledger step {t}: not an array"))?;
            if cols.len() != 6 {
                return Err(format!("ledger step {t}: expected 6 columns, got {}", cols.len()));
            }
            let get = |i: usize| -> Result<usize, String> {
                cols[i]
                    .as_usize()
                    .ok_or_else(|| format!("ledger step {t} col {i}: not a number"))
            };
            ledger.record_bytes(LayerClass::Embedding, get(0)?);
            ledger.record_bytes(LayerClass::Linear, get(1)?);
            ledger.record_bytes(LayerClass::Vector, get(2)?);
            ledger.record_link(get(3)?, get(4)?);
            if cols[5].as_bool().ok_or_else(|| format!("ledger step {t}: bad refresh flag"))? {
                ledger.mark_refresh();
            }
            ledger.end_step();
        }
        ledger.sim_time =
            crate::checkpoint::codec::f64_from_json(j.get("sim_time"), "ledger.sim_time")?;
        Ok(ledger)
    }

    /// Average bytes on refresh vs non-refresh steps (ablation data).
    pub fn refresh_split(&self) -> (f64, f64) {
        let (mut rs, mut rn, mut ns, mut nn) = (0f64, 0usize, 0f64, 0usize);
        for s in &self.steps {
            if s.refresh {
                rs += s.total as f64;
                rn += 1;
            } else {
                ns += s.total as f64;
                nn += 1;
            }
        }
        (
            if rn > 0 { rs / rn as f64 } else { 0.0 },
            if nn > 0 { ns / nn as f64 } else { 0.0 },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_step_accounting() {
        let mut l = CommLedger::new();
        l.record(LayerClass::Linear, 100); // 400 B
        l.record(LayerClass::Embedding, 50); // 200 B
        l.end_step();
        l.record(LayerClass::Linear, 300); // 1200 B
        l.mark_refresh();
        l.end_step();
        assert_eq!(l.num_steps(), 2);
        assert_eq!(l.bytes_per_step(), (600.0 + 1200.0) / 2.0);
        assert_eq!(l.peak_bytes(), 1200);
        assert_eq!(l.cumulative(), vec![600, 1800]);
        let (e, lin, v) = l.breakdown();
        assert_eq!((e, lin, v), (200, 1600, 0));
        let (r, n) = l.refresh_split();
        assert_eq!((r, n), (1200.0, 600.0));
    }

    #[test]
    fn link_columns_accumulate_separately_from_payload() {
        let mut l = CommLedger::new();
        l.record(LayerClass::Linear, 100); // 400 B payload
        l.record_link(300, 200);
        l.record_link(30, 20);
        l.end_step();
        l.record(LayerClass::Vector, 10);
        l.end_step();
        assert_eq!(l.step(0).total, 400);
        assert_eq!(l.step(0).intra, 330);
        assert_eq!(l.step(0).inter, 220);
        assert_eq!((l.step(1).intra, l.step(1).inter), (0, 0));
        assert_eq!(l.link_totals(), (330, 220));
    }

    #[test]
    fn json_roundtrip_preserves_every_column() {
        let mut l = CommLedger::new();
        l.record(LayerClass::Linear, 100);
        l.record_link(300, 200);
        l.mark_refresh();
        l.end_step();
        l.record(LayerClass::Embedding, 7);
        l.record(LayerClass::Vector, 3);
        l.end_step();
        l.add_sim_time(1.0 / 3.0); // not exactly representable in decimal
        let text = l.to_json().to_string_pretty();
        let back =
            CommLedger::from_json(&crate::util::json::Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.num_steps(), 2);
        for t in 0..2 {
            let (a, b) = (l.step(t), back.step(t));
            assert_eq!(
                (a.total, a.embedding, a.linear, a.vector, a.intra, a.inter, a.refresh),
                (b.total, b.embedding, b.linear, b.vector, b.intra, b.inter, b.refresh),
                "step {t}"
            );
        }
        assert_eq!(l.sim_time.to_bits(), back.sim_time.to_bits());
        assert_eq!(l.cumulative(), back.cumulative());
    }

    #[test]
    fn empty_ledger_is_zero() {
        let l = CommLedger::new();
        assert_eq!(l.bytes_per_step(), 0.0);
        assert_eq!(l.peak_bytes(), 0);
        assert!(l.cumulative().is_empty());
    }
}
