//! Collective operations over the simulated worker group.
//!
//! Workers are in-process (one parameter replica each); collectives move
//! real data between their buffers so the numerics are identical to a
//! true multi-process run. Two implementations exist:
//!
//! * [`ring_allreduce_mean`] — flat ring reduce-scatter + all-gather over
//!   all workers, the classic single-level algorithm;
//! * [`hier_allreduce_mean`] — the two-level hierarchical schedule used
//!   on NVLink-island clusters: intra-node ring reduce-scatter, then one
//!   inter-node ring per reduced chunk among its per-node owners (for
//!   one GPU per node these owners are exactly the node leaders), then
//!   an intra-node all-gather that broadcasts the global chunks back.
//!
//! Both are actual data-moving implementations (chunking, ordering, and
//! determinism are exercised and testable); a direct f64 mean serves as
//! the numerical oracle. [`sync_mean`] is the topology-aware front door
//! used by every optimizer: it picks the hierarchical schedule when the
//! worker count matches the topology shape, meters the per-link wire
//! volume into the [`CommLedger`]'s intra/inter columns, and meters the
//! synchronized-object payload per layer class exactly as before.

use crate::comm::{CommLedger, ElemFmt, LayerClass, Topology, BYTES_F32};
use crate::exec::ExecBackend;
use crate::linalg::Matrix;

/// Aggregate wire bytes moved on each link class by one hierarchical
/// all-reduce (summed over all workers).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HierVolume {
    pub intra_bytes: usize,
    pub inter_bytes: usize,
}

impl HierVolume {
    pub fn total(&self) -> usize {
        self.intra_bytes + self.inter_bytes
    }
}

/// All-reduce (average) a set of equally-shaped per-worker matrices
/// in-place via ring reduce-scatter + all-gather.
///
/// Returns the **per-worker** (busiest participant) bytes transmitted —
/// see [`ring_volume_bytes`]. Note the unit difference from
/// [`hier_allreduce_mean`], which returns **aggregate** wire bytes
/// summed over all workers (what the ledger's intra/inter columns
/// meter); do not mix the two.
pub fn ring_allreduce_mean(workers: &mut [Matrix]) -> usize {
    ring_allreduce_mean_fmt(workers, ElemFmt::F32)
}

/// [`ring_allreduce_mean`] in a typed element format: every reduce hop
/// re-rounds its sum onto the format's grid (so the values crossing the
/// "wire" are always representable), the gather hops are lossless, and
/// the final 1/n mean scale is the dequantize step. For
/// [`ElemFmt::F32`] this is the byte-identical historical path.
pub fn ring_allreduce_mean_fmt(workers: &mut [Matrix], fmt: ElemFmt) -> usize {
    let n = workers.len();
    assert!(n > 0);
    let numel = workers[0].numel();
    for w in workers.iter() {
        assert_eq!(w.numel(), numel, "ragged all-reduce");
    }
    if n == 1 {
        return 0;
    }
    let group: Vec<usize> = (0..n).collect();
    ring_reduce_scatter(workers, &group, 0, numel, fmt);
    ring_all_gather(workers, &group, 0, numel, fmt);
    scale_to_mean(workers, n as f32);
    ring_volume_bytes(numel, n)
}

/// Two-level hierarchical all-reduce (average) in-place.
///
/// `workers` is laid out node-major: worker `w` lives on node
/// `w / gpus_per_node` with local index `w % gpus_per_node`. Three
/// phases, each a real ring over the relevant group:
///
/// 1. **intra reduce-scatter** — within every node, local worker `i`
///    ends holding the node-sum of chunk `(i+1) % g`;
/// 2. **inter ring all-reduce** — for each chunk, the per-node owners of
///    that chunk run a ring all-reduce across nodes (the "leader ring";
///    with one chunk per node these are literally the node leaders);
/// 3. **intra all-gather** — the globally reduced chunks circulate back
///    inside each node, the broadcast leg of the schedule.
///
/// Returns the aggregate wire bytes per link class. Summed over workers
/// these obey the exact per-level decomposition (ragged chunks
/// included): intra = `2·nodes·(g−1)·numel·4`, inter =
/// `2·(nodes−1)·numel·4` — i.e. `2(w−1)/w` of the payload per
/// participant at each level — and intra + inter equals the flat ring's
/// aggregate `2·(N−1)·numel·4`: the hierarchy re-routes bytes from the
/// slow link to the fast one without moving more of them.
pub fn hier_allreduce_mean(
    workers: &mut [Matrix],
    nodes: usize,
    gpus_per_node: usize,
) -> HierVolume {
    hier_allreduce_mean_fmt(workers, nodes, gpus_per_node, ElemFmt::F32)
}

/// [`hier_allreduce_mean`] in a typed element format — the sequential
/// reference for the narrow-format reduction contract (DESIGN.md §14):
/// reduce hops re-round after their addition, gather hops are lossless
/// copies of already-representable values, and the final mean scale
/// dequantizes. The threaded and process backends replay the identical
/// schedule with the identical rounding points, so narrow-format runs
/// stay bitwise backend-invariant.
pub fn hier_allreduce_mean_fmt(
    workers: &mut [Matrix],
    nodes: usize,
    gpus_per_node: usize,
    fmt: ElemFmt,
) -> HierVolume {
    let n = workers.len();
    assert!(n > 0);
    assert_eq!(n, nodes * gpus_per_node, "topology shape mismatch");
    let numel = workers[0].numel();
    for w in workers.iter() {
        assert_eq!(w.numel(), numel, "ragged all-reduce");
    }
    if n == 1 {
        return HierVolume::default();
    }
    let g = gpus_per_node;
    // Degenerate shapes collapse to a single flat ring on one link class.
    if nodes == 1 || g == 1 {
        let group: Vec<usize> = (0..n).collect();
        let mut wire = ring_reduce_scatter(workers, &group, 0, numel, fmt);
        wire += ring_all_gather(workers, &group, 0, numel, fmt);
        scale_to_mean(workers, n as f32);
        return if nodes == 1 {
            HierVolume {
                intra_bytes: wire,
                inter_bytes: 0,
            }
        } else {
            HierVolume {
                intra_bytes: 0,
                inter_bytes: wire,
            }
        };
    }

    let starts: Vec<usize> = (0..=g).map(|c| c * numel / g).collect();
    let mut intra = 0usize;
    let mut inter = 0usize;

    // Phase 1: intra-node ring reduce-scatter.
    for node in 0..nodes {
        let group: Vec<usize> = (0..g).map(|j| node * g + j).collect();
        intra += ring_reduce_scatter(workers, &group, 0, numel, fmt);
    }
    // Phase 2: one cross-node ring per chunk, run by the local workers
    // that own it after phase 1 (local index i owns chunk (i+1) % g).
    for chunk in 0..g {
        let owner = (chunk + g - 1) % g;
        let group: Vec<usize> = (0..nodes).map(|node| node * g + owner).collect();
        inter += ring_reduce_scatter(workers, &group, starts[chunk], starts[chunk + 1], fmt);
        inter += ring_all_gather(workers, &group, starts[chunk], starts[chunk + 1], fmt);
    }
    // Phase 3: intra-node all-gather (broadcast of the global chunks).
    for node in 0..nodes {
        let group: Vec<usize> = (0..g).map(|j| node * g + j).collect();
        intra += ring_all_gather(workers, &group, 0, numel, fmt);
    }
    scale_to_mean(workers, n as f32);
    HierVolume {
        intra_bytes: intra,
        inter_bytes: inter,
    }
}

/// Per-level wire split for a payload of `bytes` moved by the two-level
/// schedule (collapsing to one flat ring when either level is trivial).
/// The single source of the `2(w−1)/w` decomposition — shared by the
/// element-count closed form ([`hier_volume_bytes`]), the virtual-sync
/// metering ([`record_virtual_sync`]), and [`sync_mean`]'s flat
/// fallback — so the conservation identity intra + inter = 2(N−1)·bytes
/// cannot drift between them.
pub fn hier_wire_split(bytes: usize, nodes: usize, gpus_per_node: usize) -> HierVolume {
    let n = nodes * gpus_per_node;
    if n <= 1 {
        return HierVolume::default();
    }
    if nodes == 1 {
        return HierVolume {
            intra_bytes: 2 * (n - 1) * bytes,
            inter_bytes: 0,
        };
    }
    if gpus_per_node == 1 {
        return HierVolume {
            intra_bytes: 0,
            inter_bytes: 2 * (n - 1) * bytes,
        };
    }
    HierVolume {
        intra_bytes: 2 * nodes * (gpus_per_node - 1) * bytes,
        inter_bytes: 2 * (nodes - 1) * bytes,
    }
}

/// Closed-form aggregate wire bytes of [`hier_allreduce_mean`] for a
/// payload of `numel` f32 elements — the per-level decomposition the
/// tests assert against. Exact for every `numel` (chunk raggedness
/// cancels in the aggregate).
pub fn hier_volume_bytes(numel: usize, nodes: usize, gpus_per_node: usize) -> HierVolume {
    hier_wire_split(numel * BYTES_F32, nodes, gpus_per_node)
}

/// Topology-aware all-reduce (mean) with full metering: the front door
/// every optimizer synchronizes through.
///
/// * moves the data with [`hier_allreduce_mean`] when the worker count
///   matches the topology shape (flat ring otherwise) — on the
///   [`ExecBackend::Threaded`] backend the same schedule runs as a
///   rendezvous ring over one OS thread per worker
///   (`exec::threaded::allreduce_mean`), and on
///   [`ExecBackend::Process`] as a socket ring over one OS process per
///   worker (`exec::process::allreduce_mean`), bitwise-identically,
/// * meters the aggregate wire volume per link class into the ledger's
///   intra/inter columns (threaded/process: *measured* from the chunks
///   that crossed thread/socket boundaries),
/// * meters the synchronized-object payload under `class` (unchanged
///   semantics — the analytic byte profiles stay exact),
/// * adds the serial α–β time oracle ([`Topology::allreduce_time`]) to
///   `ledger.sim_time`; the bucketed/overlapped estimate lives in
///   `sim::engine`.
///
/// Returns the payload bytes metered.
pub fn sync_mean(
    workers: &mut [Matrix],
    class: LayerClass,
    ledger: &mut CommLedger,
    topo: &Topology,
    exec: &ExecBackend,
) -> usize {
    sync_mean_fmt(workers, class, ElemFmt::F32, ledger, topo, exec)
}

/// [`sync_mean`] in a typed element format (DESIGN.md §14).
///
/// The quantize→reduce→dequantize order is fixed here, identically on
/// every backend:
///
/// 1. **quantize** — each worker's contribution is projected onto the
///    format's grid on entry (idempotent when the optimizer already
///    quantized through its error-feedback residuals, which is where the
///    residual update belongs);
/// 2. **reduce** — the ring schedule re-rounds each receiving chunk
///    after its addition, so every value that crosses a thread or socket
///    boundary is representable and serializes losslessly at
///    `fmt.width()` bytes/element;
/// 3. **dequantize** — the final 1/n mean scale runs in f32.
///
/// The metered payload is `numel × fmt.width()` and the wire columns are
/// the same `2(w−1)/w` split of it, so a bf16 core run's ledger is
/// exactly half its f32 twin's core payload — and on the process backend
/// the frames crossing the sockets really are that narrow.
pub fn sync_mean_fmt(
    workers: &mut [Matrix],
    class: LayerClass,
    fmt: ElemFmt,
    ledger: &mut CommLedger,
    topo: &Topology,
    exec: &ExecBackend,
) -> usize {
    let n = workers.len();
    assert!(n > 0);
    let numel = workers[0].numel();
    let payload = numel * fmt.width();
    for w in workers.iter_mut() {
        fmt.round_slice(&mut w.data);
    }
    let mut vol = HierVolume::default();
    if n > 1 {
        if n == topo.workers() {
            vol = match exec {
                ExecBackend::Threaded { .. } => crate::exec::threaded::allreduce_mean_fmt(
                    workers,
                    topo.nodes,
                    topo.gpus_per_node,
                    fmt,
                ),
                ExecBackend::Process { .. } => crate::exec::process::allreduce_mean_fmt(
                    workers,
                    topo.nodes,
                    topo.gpus_per_node,
                    fmt,
                ),
                ExecBackend::Sequential => {
                    hier_allreduce_mean_fmt(workers, topo.nodes, topo.gpus_per_node, fmt)
                }
            };
        } else {
            // Worker count does not tile the topology: fall back to a
            // flat ring, attributed to the slowest link class it crosses.
            // (Aggregate volume via the shared closed form —
            // ring_allreduce_mean's return is per-worker, not aggregate,
            // and must not be metered here. The threaded and process
            // flat rings' measured totals equal the closed form exactly,
            // ragged payloads included, so all backends meter
            // identically.)
            match exec {
                ExecBackend::Threaded { .. } => {
                    let measured = crate::exec::threaded::allreduce_mean_fmt(workers, 1, n, fmt);
                    debug_assert_eq!(measured.total(), 2 * (n - 1) * payload);
                }
                ExecBackend::Process { .. } => {
                    let measured = crate::exec::process::allreduce_mean_fmt(workers, 1, n, fmt);
                    debug_assert_eq!(measured.total(), 2 * (n - 1) * payload);
                }
                ExecBackend::Sequential => {
                    ring_allreduce_mean_fmt(workers, fmt);
                }
            }
            vol = if topo.nodes > 1 {
                hier_wire_split(payload, n, 1)
            } else {
                hier_wire_split(payload, 1, n)
            };
        }
        ledger.record_link(vol.intra_bytes, vol.inter_bytes);
    }
    ledger.record_bytes(class, payload);
    let sim_dt = topo.allreduce_time(payload);
    ledger.add_sim_time(sim_dt);
    // Trace the leg AFTER all three meterings so the record carries the
    // cumulative sim_t including this leg. Emitted here — the one point
    // every backend's collective funnels through — so a deterministic
    // trace cannot differ across backends.
    ledger.tracer().collective(
        class,
        payload,
        fmt.name(),
        vol.intra_bytes,
        vol.inter_bytes,
        sim_dt,
        ledger.sim_time,
    );
    payload
}

/// Meter a *virtual* collective moving `bytes` of a bit-packed payload
/// under `class` — payload column, wire split, serial time oracle, and
/// the trace record, all in one place.
///
/// SignAdam and TopKAdam compress, exchange, and decompress in-process
/// (no `Matrix` collective runs for the compressed object), but the
/// ledger's serial time oracle already charges `allreduce_time(bytes)`
/// for it — so the intra/inter wire columns must charge the matching
/// two-level volume, or the three accountings drift apart. Same
/// conservation as the real schedule: intra + inter = 2(N−1)·bytes.
/// The trace record labels its format `"packed"` (the payload is a
/// sign/top-k bitstream, not an [`ElemFmt`] grid).
pub fn record_virtual_sync(
    workers: usize,
    class: LayerClass,
    bytes: usize,
    ledger: &mut CommLedger,
    topo: &Topology,
) {
    let mut vol = HierVolume::default();
    if workers > 1 {
        vol = if workers == topo.workers() {
            hier_wire_split(bytes, topo.nodes, topo.gpus_per_node)
        } else if topo.nodes > 1 {
            hier_wire_split(bytes, workers, 1)
        } else {
            hier_wire_split(bytes, 1, workers)
        };
        ledger.record_link(vol.intra_bytes, vol.inter_bytes);
    }
    ledger.record_bytes(class, bytes);
    let sim_dt = topo.allreduce_time(bytes);
    ledger.add_sim_time(sim_dt);
    ledger.tracer().collective(
        class,
        bytes,
        "packed",
        vol.intra_bytes,
        vol.inter_bytes,
        sim_dt,
        ledger.sim_time,
    );
}

/// Oracle: direct mean, broadcast to all workers. Same result as the
/// ring implementations up to f32 reduction-order rounding.
pub fn direct_allreduce_mean(workers: &mut [Matrix]) {
    let n = workers.len();
    if n <= 1 {
        return;
    }
    let numel = workers[0].numel();
    let mut acc = vec![0.0f64; numel];
    for w in workers.iter() {
        for (a, v) in acc.iter_mut().zip(&w.data) {
            *a += *v as f64;
        }
    }
    let inv = 1.0 / n as f64;
    for w in workers.iter_mut() {
        for (v, a) in w.data.iter_mut().zip(&acc) {
            *v = (a * inv) as f32;
        }
    }
}

/// Per-worker bytes moved by a ring all-reduce of `numel` f32 elements,
/// computed from the actual chunk boundaries (`starts[c] = c·numel/n`):
/// over the 2(n−1) steps a worker sends every chunk except two, so the
/// busiest worker moves `2·numel − c_a − c_b` elements with `c_a, c_b`
/// its two skipped chunks. For `numel % n == 0` this is exactly
/// `2(n−1)/n · numel · 4`; for ragged payloads the truncating closed
/// form under-counts, so we take the max over workers (the participant
/// that paces the ring).
pub fn ring_volume_bytes(numel: usize, n: usize) -> usize {
    if n <= 1 {
        return 0;
    }
    let starts: Vec<usize> = (0..=n).map(|c| c * numel / n).collect();
    let chunk = |c: usize| starts[c + 1] - starts[c];
    (0..n)
        .map(|i| 2 * numel - chunk((i + 1) % n) - chunk((i + 2) % n))
        .max()
        .unwrap_or(0)
        * BYTES_F32
}

// ---------------------------------------------------------------------
// Ring primitives shared by the flat and hierarchical schedules. Both
// operate on the element range [lo, hi) split into `group.len()` chunks
// at boundaries `lo + c·len/m`, and return the aggregate bytes sent by
// the whole group.
// ---------------------------------------------------------------------

/// Ring reduce-scatter (sum) over `group`: after `m−1` steps the worker
/// at group position `i` holds the full group-sum of chunk `(i+1) % m`.
///
/// Narrow formats re-round the receiving chunk after each addition —
/// the sent values are always representable, so the process backend can
/// serialize them at `fmt.width()` bytes/element losslessly. Bytes are
/// counted at that width.
fn ring_reduce_scatter(
    workers: &mut [Matrix],
    group: &[usize],
    lo: usize,
    hi: usize,
    fmt: ElemFmt,
) -> usize {
    let m = group.len();
    if m <= 1 {
        return 0;
    }
    let len = hi - lo;
    let starts: Vec<usize> = (0..=m).map(|c| lo + c * len / m).collect();
    let mut sent = 0usize;
    for step in 0..m - 1 {
        for i in 0..m {
            // Position i sends chunk (i - step) mod m to position i+1.
            let c = (i + m - step) % m;
            let (clo, chi) = (starts[c], starts[c + 1]);
            let dst = (i + 1) % m;
            let (src_chunk, dst_chunk) = two_slices(workers, group[i], group[dst], clo, chi);
            for (d, s) in dst_chunk.iter_mut().zip(src_chunk.iter()) {
                *d += *s;
            }
            fmt.round_slice(dst_chunk);
            sent += chi - clo;
        }
    }
    sent * fmt.width()
}

/// Ring all-gather over `group`, assuming the ownership layout produced
/// by [`ring_reduce_scatter`]: circulates the reduced chunks until every
/// group member holds all of [lo, hi). The circulated values are already
/// representable in `fmt`, so the copies are lossless at any width.
fn ring_all_gather(
    workers: &mut [Matrix],
    group: &[usize],
    lo: usize,
    hi: usize,
    fmt: ElemFmt,
) -> usize {
    let m = group.len();
    if m <= 1 {
        return 0;
    }
    let len = hi - lo;
    let starts: Vec<usize> = (0..=m).map(|c| lo + c * len / m).collect();
    let mut sent = 0usize;
    for step in 0..m - 1 {
        for i in 0..m {
            let c = (i + 1 + m - step) % m;
            let (clo, chi) = (starts[c], starts[c + 1]);
            let dst = (i + 1) % m;
            let (src_chunk, dst_chunk) = two_slices(workers, group[i], group[dst], clo, chi);
            dst_chunk.copy_from_slice(src_chunk);
            sent += chi - clo;
        }
    }
    sent * fmt.width()
}

fn scale_to_mean(workers: &mut [Matrix], n: f32) {
    let inv = 1.0 / n;
    for w in workers.iter_mut() {
        for v in &mut w.data {
            *v *= inv;
        }
    }
}

/// Borrow chunk [lo,hi) of workers[src] (shared) and workers[dst] (mut)
/// simultaneously via `split_at_mut` — no per-chunk allocation.
fn two_slices(
    workers: &mut [Matrix],
    src: usize,
    dst: usize,
    lo: usize,
    hi: usize,
) -> (&[f32], &mut [f32]) {
    debug_assert_ne!(src, dst);
    if src < dst {
        let (left, right) = workers.split_at_mut(dst);
        (&left[src].data[lo..hi], &mut right[0].data[lo..hi])
    } else {
        let (left, right) = workers.split_at_mut(src);
        (&right[0].data[lo..hi], &mut left[dst].data[lo..hi])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn ring_matches_direct_mean() {
        prop::check("ring == mean", 24, |rng| {
            let n = prop::dim(rng, 1, 9);
            let r = prop::dim(rng, 1, 13);
            let c = prop::dim(rng, 1, 13);
            let mut ws: Vec<Matrix> = (0..n).map(|_| Matrix::gaussian(r, c, 1.0, rng)).collect();
            let mut oracle = ws.clone();
            ring_allreduce_mean(&mut ws);
            direct_allreduce_mean(&mut oracle);
            for (a, b) in ws.iter().zip(&oracle) {
                assert!(a.dist(b) < 1e-4 * (r * c) as f32, "n={n} {r}x{c}");
            }
        });
    }

    #[test]
    fn all_workers_agree_after_allreduce() {
        let mut rng = Xoshiro256::new(42);
        let mut ws: Vec<Matrix> = (0..5).map(|_| Matrix::gaussian(17, 9, 1.0, &mut rng)).collect();
        ring_allreduce_mean(&mut ws);
        for w in &ws[1..] {
            assert!(w.dist(&ws[0]) < 1e-5);
        }
    }

    #[test]
    fn volume_formula() {
        // Divisible: 2(N-1)/N × numel × 4.
        assert_eq!(ring_volume_bytes(100, 4), 2 * 3 * 100 / 4 * 4);
        assert_eq!(ring_volume_bytes(100, 1), 0);
    }

    #[test]
    fn ragged_volume_counts_actual_chunks() {
        // numel=10, n=3: chunks are 3,3,4 — the busiest worker skips the
        // two 3-element chunks and moves 2·10−3−3 = 14 elements. The old
        // truncating formula said ⌊2·2·10/3⌋ = 13.
        assert_eq!(ring_volume_bytes(10, 3), 14 * 4);
        assert!(ring_volume_bytes(10, 3) > 2 * 2 * 10 / 3 * 4);
    }

    #[test]
    fn preserves_mean_exactly_for_constants() {
        let mut ws: Vec<Matrix> = (0..4)
            .map(|i| Matrix::from_fn(3, 3, |_, _| i as f32))
            .collect();
        ring_allreduce_mean(&mut ws);
        for w in &ws {
            for &v in &w.data {
                assert!((v - 1.5).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn hier_matches_direct_mean() {
        prop::check("hier == mean", 20, |rng| {
            let nodes = prop::dim(rng, 1, 4);
            let g = prop::dim(rng, 1, 4);
            let r = prop::dim(rng, 1, 11);
            let c = prop::dim(rng, 1, 11);
            let mut ws: Vec<Matrix> = (0..nodes * g)
                .map(|_| Matrix::gaussian(r, c, 1.0, rng))
                .collect();
            let mut oracle = ws.clone();
            hier_allreduce_mean(&mut ws, nodes, g);
            direct_allreduce_mean(&mut oracle);
            for (a, b) in ws.iter().zip(&oracle) {
                assert!(a.dist(b) < 1e-4 * (r * c) as f32, "{nodes}x{g} {r}x{c}");
            }
        });
    }

    #[test]
    fn hier_volume_matches_closed_form() {
        // Ragged numel on purpose: the aggregate closed form is exact.
        let numel = 37;
        let mut rng = Xoshiro256::new(3);
        for (nodes, g) in [(2usize, 3usize), (3, 2), (4, 4), (1, 5), (5, 1)] {
            let mut ws: Vec<Matrix> = (0..nodes * g)
                .map(|_| Matrix::gaussian(1, numel, 1.0, &mut rng))
                .collect();
            let vol = hier_allreduce_mean(&mut ws, nodes, g);
            assert_eq!(vol, hier_volume_bytes(numel, nodes, g), "{nodes}x{g}");
            // Conservation: the hierarchy moves exactly the flat ring's
            // aggregate 2(N−1)·numel bytes, re-routed across link classes.
            let n = nodes * g;
            assert_eq!(vol.total(), 2 * (n - 1) * numel * BYTES_F32, "{nodes}x{g}");
        }
    }

    #[test]
    fn sync_mean_meters_payload_and_wire() {
        let topo = Topology::multi_node(2, 2);
        let mut ledger = CommLedger::new();
        let mut rng = Xoshiro256::new(9);
        let mut ws: Vec<Matrix> = (0..4).map(|_| Matrix::gaussian(5, 8, 1.0, &mut rng)).collect();
        let payload = sync_mean(
            &mut ws,
            LayerClass::Linear,
            &mut ledger,
            &topo,
            &ExecBackend::Sequential,
        );
        ledger.end_step();
        assert_eq!(payload, 40 * 4);
        assert_eq!(ledger.step(0).total, 40 * 4);
        let expect = hier_volume_bytes(40, 2, 2);
        assert_eq!(ledger.step(0).intra, expect.intra_bytes);
        assert_eq!(ledger.step(0).inter, expect.inter_bytes);
        assert!(ledger.sim_time > 0.0);
    }

    #[test]
    fn sync_mean_falls_back_to_flat_ring_on_shape_mismatch() {
        // 3 workers under a 2×2 topology: flat ring, attributed inter.
        let topo = Topology::multi_node(2, 2);
        let mut ledger = CommLedger::new();
        let mut rng = Xoshiro256::new(10);
        let mut ws: Vec<Matrix> = (0..3).map(|_| Matrix::gaussian(4, 4, 1.0, &mut rng)).collect();
        let mut oracle = ws.clone();
        sync_mean(
            &mut ws,
            LayerClass::Vector,
            &mut ledger,
            &topo,
            &ExecBackend::Sequential,
        );
        direct_allreduce_mean(&mut oracle);
        ledger.end_step();
        assert_eq!(ledger.step(0).intra, 0);
        assert_eq!(ledger.step(0).inter, 2 * 2 * 16 * 4);
        for (a, b) in ws.iter().zip(&oracle) {
            assert!(a.dist(b) < 1e-4);
        }
    }

    #[test]
    fn sync_mean_fmt_meters_width_true_payload_and_stays_backend_invariant() {
        // bf16 halves the metered payload and wire columns exactly; the
        // reduced values are bitwise-identical across backends because
        // every backend rounds at the same ring hops. i8 quarters it.
        for (fmt, width) in [(ElemFmt::Bf16, 2usize), (ElemFmt::I8, 1)] {
            let topo = Topology::multi_node(2, 2);
            let mut rng = Xoshiro256::new(23);
            let ws0: Vec<Matrix> = (0..4)
                .map(|_| Matrix::gaussian(5, 8, 0.5, &mut rng))
                .collect();
            let mut runs = Vec::new();
            for exec in [ExecBackend::Sequential, ExecBackend::threaded()] {
                let mut ws = ws0.clone();
                let mut ledger = CommLedger::new();
                let payload =
                    sync_mean_fmt(&mut ws, LayerClass::Linear, fmt, &mut ledger, &topo, &exec);
                ledger.end_step();
                assert_eq!(payload, 40 * width, "{}", fmt.name());
                assert_eq!(ledger.step(0).total, 40 * width);
                let expect = hier_wire_split(40 * width, 2, 2);
                assert_eq!(ledger.step(0).intra, expect.intra_bytes);
                assert_eq!(ledger.step(0).inter, expect.inter_bytes);
                let bits: Vec<Vec<u32>> = ws
                    .iter()
                    .map(|w| w.data.iter().map(|v| v.to_bits()).collect())
                    .collect();
                runs.push(bits);
            }
            assert_eq!(runs[0], runs[1], "{} backend drift", fmt.name());
            // All workers agree on the reduced value.
            let first = runs[0][0].clone();
            for w in &runs[0][1..] {
                assert_eq!(*w, first, "{} workers disagree", fmt.name());
            }
        }
    }

    #[test]
    fn sync_mean_f32_fmt_is_byte_identical_to_plain_sync_mean() {
        // The refactor must not perturb the full-precision path: same
        // buffers, same ledger columns.
        let topo = Topology::multi_node(2, 2);
        let mut rng = Xoshiro256::new(29);
        let ws0: Vec<Matrix> = (0..4).map(|_| Matrix::gaussian(3, 9, 1.0, &mut rng)).collect();
        let (mut wa, mut wb) = (ws0.clone(), ws0.clone());
        let (mut la, mut lb) = (CommLedger::new(), CommLedger::new());
        sync_mean(&mut wa, LayerClass::Linear, &mut la, &topo, &ExecBackend::Sequential);
        sync_mean_fmt(
            &mut wb,
            LayerClass::Linear,
            ElemFmt::F32,
            &mut lb,
            &topo,
            &ExecBackend::Sequential,
        );
        la.end_step();
        lb.end_step();
        assert_eq!(la.step(0), lb.step(0));
        for (a, b) in wa.iter().zip(&wb) {
            for (x, y) in a.data.iter().zip(&b.data) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn sync_mean_ledger_columns_are_backend_invariant() {
        // Both the matched-shape hierarchical path and the flat-ring
        // fallback must meter identical intra/inter columns on either
        // backend, and produce bitwise-identical buffers.
        for workers in [4usize, 3] {
            let topo = Topology::multi_node(2, 2);
            let mut rng = Xoshiro256::new(17);
            let ws0: Vec<Matrix> = (0..workers)
                .map(|_| Matrix::gaussian(3, 7, 1.0, &mut rng))
                .collect();
            let mut runs = Vec::new();
            for exec in [ExecBackend::Sequential, ExecBackend::threaded()] {
                let mut ws = ws0.clone();
                let mut ledger = CommLedger::new();
                sync_mean(&mut ws, LayerClass::Linear, &mut ledger, &topo, &exec);
                ledger.end_step();
                let bits: Vec<Vec<u32>> = ws
                    .iter()
                    .map(|w| w.data.iter().map(|v| v.to_bits()).collect())
                    .collect();
                runs.push((bits, ledger.step(0).intra, ledger.step(0).inter));
            }
            assert_eq!(runs[0], runs[1], "workers={workers}");
        }
    }
}
