//! Collective operations over the simulated worker group.
//!
//! Workers are in-process (one parameter replica each); collectives move
//! real data between their buffers so the numerics are identical to a
//! true multi-process run. The ring all-reduce is implemented as an
//! actual reduce-scatter + all-gather over chunks (not a shortcut mean)
//! so that algorithmic properties — chunking, ordering, determinism —
//! are exercised and testable; a direct mean implementation serves as
//! the test oracle.

use crate::linalg::Matrix;

/// All-reduce (average) a set of equally-shaped per-worker matrices
/// in-place via ring reduce-scatter + all-gather.
///
/// Returns the per-worker payload bytes this collective transmitted
/// (the standard ring volume: 2·(N−1)/N · |x| · 4 bytes).
pub fn ring_allreduce_mean(workers: &mut [Matrix]) -> usize {
    let n = workers.len();
    assert!(n > 0);
    let numel = workers[0].numel();
    for w in workers.iter() {
        assert_eq!(w.numel(), numel, "ragged all-reduce");
    }
    if n == 1 {
        return 0;
    }

    // Chunk boundaries: chunk c covers [starts[c], starts[c+1]).
    let starts: Vec<usize> = (0..=n).map(|c| c * numel / n).collect();

    // Reduce-scatter: after n-1 steps worker i holds the full sum of
    // chunk (i+1) mod n.
    for step in 0..n - 1 {
        for i in 0..n {
            // Worker i sends chunk (i - step) mod n to worker (i+1) mod n.
            let c = (i + n - step) % n;
            let (lo, hi) = (starts[c], starts[c + 1]);
            let dst = (i + 1) % n;
            // split_at_mut dance to borrow two workers at once.
            let (src_chunk, dst_chunk) = two_slices(workers, i, dst, lo, hi);
            for (d, s) in dst_chunk.iter_mut().zip(src_chunk.iter()) {
                *d += *s;
            }
        }
    }
    // All-gather: circulate the reduced chunks.
    for step in 0..n - 1 {
        for i in 0..n {
            let c = (i + 1 + n - step) % n;
            let (lo, hi) = (starts[c], starts[c + 1]);
            let dst = (i + 1) % n;
            let (src_chunk, dst_chunk) = two_slices(workers, i, dst, lo, hi);
            dst_chunk.copy_from_slice(&src_chunk);
        }
    }
    // Scale sums to means.
    let inv = 1.0 / n as f32;
    for w in workers.iter_mut() {
        for v in &mut w.data {
            *v *= inv;
        }
    }
    ring_volume_bytes(numel, n)
}

/// Oracle: direct mean, broadcast to all workers. Same result as the
/// ring implementation up to f32 reduction-order rounding.
pub fn direct_allreduce_mean(workers: &mut [Matrix]) {
    let n = workers.len();
    if n <= 1 {
        return;
    }
    let numel = workers[0].numel();
    let mut acc = vec![0.0f64; numel];
    for w in workers.iter() {
        for (a, v) in acc.iter_mut().zip(&w.data) {
            *a += *v as f64;
        }
    }
    let inv = 1.0 / n as f64;
    for w in workers.iter_mut() {
        for (v, a) in w.data.iter_mut().zip(&acc) {
            *v = (a * inv) as f32;
        }
    }
}

/// Per-worker bytes moved by a ring all-reduce of `numel` f32 elements.
pub fn ring_volume_bytes(numel: usize, n: usize) -> usize {
    if n <= 1 {
        return 0;
    }
    (2 * (n - 1) * numel / n) * std::mem::size_of::<f32>()
}

/// Borrow chunk [lo,hi) of workers[src] (shared) and workers[dst] (mut).
fn two_slices(
    workers: &mut [Matrix],
    src: usize,
    dst: usize,
    lo: usize,
    hi: usize,
) -> (Vec<f32>, &mut [f32]) {
    // Copy src chunk out (small chunk; models the "send buffer").
    let src_copy = workers[src].data[lo..hi].to_vec();
    (src_copy, &mut workers[dst].data[lo..hi])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn ring_matches_direct_mean() {
        prop::check("ring == mean", 24, |rng| {
            let n = prop::dim(rng, 1, 9);
            let r = prop::dim(rng, 1, 13);
            let c = prop::dim(rng, 1, 13);
            let mut ws: Vec<Matrix> = (0..n).map(|_| Matrix::gaussian(r, c, 1.0, rng)).collect();
            let mut oracle = ws.clone();
            ring_allreduce_mean(&mut ws);
            direct_allreduce_mean(&mut oracle);
            for (a, b) in ws.iter().zip(&oracle) {
                assert!(a.dist(b) < 1e-4 * (r * c) as f32, "n={n} {r}x{c}");
            }
        });
    }

    #[test]
    fn all_workers_agree_after_allreduce() {
        let mut rng = Xoshiro256::new(42);
        let mut ws: Vec<Matrix> = (0..5).map(|_| Matrix::gaussian(17, 9, 1.0, &mut rng)).collect();
        ring_allreduce_mean(&mut ws);
        for w in &ws[1..] {
            assert!(w.dist(&ws[0]) < 1e-5);
        }
    }

    #[test]
    fn volume_formula() {
        // 2(N-1)/N × numel × 4.
        assert_eq!(ring_volume_bytes(100, 4), 2 * 3 * 100 / 4 * 4);
        assert_eq!(ring_volume_bytes(100, 1), 0);
    }

    #[test]
    fn preserves_mean_exactly_for_constants() {
        let mut ws: Vec<Matrix> = (0..4)
            .map(|i| Matrix::from_fn(3, 3, |_, _| i as f32))
            .collect();
        ring_allreduce_mean(&mut ws);
        for w in &ws {
            for &v in &w.data {
                assert!((v - 1.5).abs() < 1e-6);
            }
        }
    }
}
