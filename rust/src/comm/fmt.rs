//! Typed payload element formats (DESIGN.md §14).
//!
//! Every byte the ledger meters is `numel × width` of some element
//! format. Historically that format was implicitly f32 (×4 everywhere);
//! this module makes it a first-class type so quantized core payloads
//! (bf16/int8 with error feedback, per 0/1-Adam — PAPERS.md) can be
//! priced exactly by the same machinery.
//!
//! Encode/decode are **deterministic bit-pattern transforms** — no
//! table lookups, no rounding-mode dependence on the host:
//!
//! * [`ElemFmt::F32`] — identity; 4-byte little-endian bit patterns.
//! * [`ElemFmt::Bf16`] — the top 16 bits of the f32 pattern, rounded to
//!   nearest-even; NaNs keep their sign and a nonzero mantissa (never
//!   silently become infinities). Decode shifts back: every bf16 value
//!   is exactly representable as f32, so decode∘encode is the
//!   *representable projection* (idempotent) and encode∘decode is the
//!   identity on bf16 values.
//! * [`ElemFmt::I8`] — symmetric fixed point `q = clamp(round(32·x),
//!   −127, 127)`, i.e. step 1/32 over ±127/32. Inside the range the
//!   quantization error is ≤ 1/64 per element; outside it saturates
//!   (the error-feedback residual carries what saturation drops).
//!
//! The reduction contract for narrow formats lives in
//! [`crate::comm::collective::sync_mean_fmt`]: contributions are
//! quantized *before* the collective (error feedback at the optimizer),
//! every ring hop re-rounds after its addition so the wire only ever
//! carries representable values, and the final 1/n mean scale is the
//! dequantize step, in f32. All three execution backends implement the
//! identical order, so narrow-format runs stay bitwise backend-invariant.

/// The I8 fixed-point scale: values are stored as multiples of 1/32.
pub const I8_SCALE: f32 = 32.0;

/// Element format of a synchronized payload.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ElemFmt {
    /// Full-precision f32 — the historical default; encode is identity.
    #[default]
    F32,
    /// bfloat16: top 16 bits of the f32 pattern, round-to-nearest-even.
    Bf16,
    /// Symmetric fixed-point int8 (step 1/32, saturating at ±127/32).
    I8,
}

impl ElemFmt {
    /// Wire bytes per element.
    pub fn width(&self) -> usize {
        match self {
            ElemFmt::F32 => 4,
            ElemFmt::Bf16 => 2,
            ElemFmt::I8 => 1,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ElemFmt::F32 => "f32",
            ElemFmt::Bf16 => "bf16",
            ElemFmt::I8 => "i8",
        }
    }

    /// Parse a CLI/config format name. Unknown names are a loud error
    /// listing the valid set (same contract as `ExecBackend::parse`).
    pub fn parse(name: &str) -> Result<Self, String> {
        match name.trim() {
            "f32" | "fp32" => Ok(ElemFmt::F32),
            "bf16" | "bfloat16" => Ok(ElemFmt::Bf16),
            "i8" | "int8" => Ok(ElemFmt::I8),
            other => Err(format!(
                "unknown element format `{other}` (valid: f32 | bf16 | i8)"
            )),
        }
    }

    /// Protocol tag for the process-backend collective spec frame.
    pub fn wire_tag(&self) -> u8 {
        match self {
            ElemFmt::F32 => 0,
            ElemFmt::Bf16 => 1,
            ElemFmt::I8 => 2,
        }
    }

    /// Inverse of [`Self::wire_tag`] — a corrupt tag is a loud protocol
    /// error, never a silent f32 fallback.
    pub fn from_wire_tag(tag: u8) -> Result<Self, String> {
        match tag {
            0 => Ok(ElemFmt::F32),
            1 => Ok(ElemFmt::Bf16),
            2 => Ok(ElemFmt::I8),
            other => Err(format!("bad element-format wire tag {other}")),
        }
    }

    /// The representable projection `decode(encode(x))` — idempotent,
    /// and the identity for [`ElemFmt::F32`].
    #[inline]
    pub fn round(&self, x: f32) -> f32 {
        match self {
            ElemFmt::F32 => x,
            ElemFmt::Bf16 => bf16_to_f32(f32_to_bf16(x)),
            ElemFmt::I8 => i8_to_f32(f32_to_i8(x)),
        }
    }

    /// Project a whole slice onto the representable grid (no-op for f32,
    /// so the full-precision path stays byte-identical to pre-refactor).
    pub fn round_slice(&self, xs: &mut [f32]) {
        if *self == ElemFmt::F32 {
            return;
        }
        for x in xs.iter_mut() {
            *x = self.round(*x);
        }
    }

    /// Serialize `xs` (which must already be representable in `self` —
    /// the collective contract guarantees it) as `numel × width` wire
    /// bytes, appended to `out`.
    pub fn write_elems(&self, out: &mut Vec<u8>, xs: &[f32]) {
        match self {
            ElemFmt::F32 => {
                out.reserve(xs.len() * 4);
                for x in xs {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            ElemFmt::Bf16 => {
                out.reserve(xs.len() * 2);
                for x in xs {
                    out.extend_from_slice(&f32_to_bf16(*x).to_le_bytes());
                }
            }
            ElemFmt::I8 => {
                out.reserve(xs.len());
                for x in xs {
                    out.push(f32_to_i8(*x) as u8);
                }
            }
        }
    }

    /// Decode exactly `out.len()` elements from `bytes` (length must be
    /// `out.len() × width` — anything else is a corrupt frame).
    pub fn read_elems(&self, bytes: &[u8], out: &mut [f32]) -> Result<(), String> {
        if bytes.len() != out.len() * self.width() {
            return Err(format!(
                "payload is {} bytes for {} {} elements (want {})",
                bytes.len(),
                out.len(),
                self.name(),
                out.len() * self.width()
            ));
        }
        match self {
            ElemFmt::F32 => {
                for (o, c) in out.iter_mut().zip(bytes.chunks_exact(4)) {
                    *o = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
                }
            }
            ElemFmt::Bf16 => {
                for (o, c) in out.iter_mut().zip(bytes.chunks_exact(2)) {
                    *o = bf16_to_f32(u16::from_le_bytes([c[0], c[1]]));
                }
            }
            ElemFmt::I8 => {
                for (o, b) in out.iter_mut().zip(bytes.iter()) {
                    *o = i8_to_f32(*b as i8);
                }
            }
        }
        Ok(())
    }
}

/// f32 → bf16 bit pattern, round-to-nearest-even. NaNs are truncated
/// with their mantissa forced nonzero (a NaN must never round or
/// truncate into an infinity); for bf16-representable values (low 16
/// bits zero) this is exactly the identity on the high half.
#[inline]
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        let h = (bits >> 16) as u16;
        return if h & 0x007F == 0 { h | 0x0040 } else { h };
    }
    // Round to nearest, ties to even on bit 16.
    let rounded = (bits as u64 + 0x7FFF + ((bits >> 16) & 1) as u64) >> 16;
    rounded as u16
}

/// bf16 bit pattern → f32 (exact: shift back into the high half).
#[inline]
pub fn bf16_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// f32 → fixed-point int8: `clamp(round(32·x), −127, 127)`. `round`
/// here is half-away-from-zero (`f32::round`), symmetric in sign; −128
/// is never produced so negation round-trips. NaN maps to 0 (the only
/// sane saturation for a sum that went undefined).
#[inline]
pub fn f32_to_i8(x: f32) -> i8 {
    if x.is_nan() {
        return 0;
    }
    (x * I8_SCALE).round().clamp(-127.0, 127.0) as i8
}

/// Fixed-point int8 → f32 (exact: small integers divided by 32).
#[inline]
pub fn i8_to_f32(q: i8) -> f32 {
    q as f32 / I8_SCALE
}

/// Error-feedback quantization of one worker contribution, in place:
/// `x ← round(x + e)`, `e ← (x + e) − round(x + e)` (0/1-Adam's
/// compensated compressor). For [`ElemFmt::F32`] this is the identity
/// and `err` stays untouched — callers skip allocating residuals there.
pub fn quantize_ef(fmt: ElemFmt, xs: &mut [f32], err: &mut [f32]) {
    if fmt == ElemFmt::F32 {
        return;
    }
    debug_assert_eq!(xs.len(), err.len());
    for (x, e) in xs.iter_mut().zip(err.iter_mut()) {
        let want = *x + *e;
        let q = fmt.round(want);
        *e = want - q;
        *x = q;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn widths_names_tags_roundtrip() {
        for fmt in [ElemFmt::F32, ElemFmt::Bf16, ElemFmt::I8] {
            assert_eq!(ElemFmt::parse(fmt.name()), Ok(fmt));
            assert_eq!(ElemFmt::from_wire_tag(fmt.wire_tag()), Ok(fmt));
        }
        assert_eq!(ElemFmt::F32.width(), 4);
        assert_eq!(ElemFmt::Bf16.width(), 2);
        assert_eq!(ElemFmt::I8.width(), 1);
        assert_eq!(ElemFmt::default(), ElemFmt::F32);
        assert!(ElemFmt::from_wire_tag(9).is_err());
    }

    #[test]
    fn parse_rejects_unknown_names_loudly() {
        for bogus in ["f16", "fp8", "", "bf-16"] {
            let err = ElemFmt::parse(bogus).unwrap_err();
            assert!(err.contains("f32 | bf16 | i8"), "`{bogus}` -> {err}");
        }
        assert_eq!(ElemFmt::parse(" int8 "), Ok(ElemFmt::I8));
        assert_eq!(ElemFmt::parse("bfloat16"), Ok(ElemFmt::Bf16));
    }

    #[test]
    fn bf16_preserves_sign_nan_and_subnormal_patterns() {
        // Every bf16-representable value (low 16 bits zero) must survive
        // encode∘decode bit-for-bit: signed zeros, subnormals, infinities,
        // and NaN payloads included.
        let specials: Vec<u32> = vec![
            0x0000_0000, // +0
            0x8000_0000, // −0
            0x3F80_0000, // 1.0
            0xBF80_0000, // −1.0
            0x0001_0000, // bf16 subnormal (f32 subnormal too)
            0x8001_0000, // negative subnormal
            0x7F80_0000, // +inf
            0xFF80_0000, // −inf
            0x7FC0_0000, // quiet NaN
            0xFFC1_0000, // NaN with sign + payload
        ];
        for bits in specials {
            let x = f32::from_bits(bits);
            let h = f32_to_bf16(x);
            assert_eq!(h, (bits >> 16) as u16, "encode {bits:#010x}");
            assert_eq!(bf16_to_f32(h).to_bits(), bits, "decode {bits:#010x}");
        }
        prop::check("bf16 representable roundtrip", 64, |rng| {
            // Random bf16 patterns (skip the NaN-payload-zero ambiguity:
            // any pattern is fine because decode is a pure shift).
            let h = (rng.next_u64() & 0xFFFF) as u16;
            let x = bf16_to_f32(h);
            if x.is_nan() {
                let back = f32_to_bf16(x);
                assert!(bf16_to_f32(back).is_nan());
                assert_eq!(back & 0x8000, h & 0x8000, "NaN keeps its sign");
            } else {
                assert_eq!(f32_to_bf16(x), h, "pattern {h:#06x}");
            }
        });
    }

    #[test]
    fn bf16_rounds_to_nearest_even() {
        // 1.0 + ulp/2 exactly: ties to even (stays 1.0).
        let tie = f32::from_bits(0x3F80_8000);
        assert_eq!(f32_to_bf16(tie), 0x3F80);
        // Just above the tie rounds up.
        let above = f32::from_bits(0x3F80_8001);
        assert_eq!(f32_to_bf16(above), 0x3F81);
        // Odd low bit ties away (to the even neighbor above).
        let tie_odd = f32::from_bits(0x3F81_8000);
        assert_eq!(f32_to_bf16(tie_odd), 0x3F82);
        // Huge finite rounds up to infinity (standard carry behavior)…
        assert_eq!(bf16_to_f32(f32_to_bf16(f32::MAX)), f32::INFINITY);
        // …but a NaN never becomes one.
        assert!(bf16_to_f32(f32_to_bf16(f32::from_bits(0x7F80_0001))).is_nan());
    }

    #[test]
    fn i8_error_bound_and_saturation() {
        prop::check("i8 quantizer error ≤ 1/64 in range", 128, |rng| {
            let x = (rng.next_f32() - 0.5) * 2.0 * (127.0 / I8_SCALE);
            let err = (x - ElemFmt::I8.round(x)).abs();
            assert!(err <= 0.5 / I8_SCALE + 1e-7, "x={x} err={err}");
        });
        assert_eq!(f32_to_i8(100.0), 127);
        assert_eq!(f32_to_i8(-100.0), -127);
        assert_eq!(f32_to_i8(f32::NAN), 0);
        // Negation symmetry: −128 never appears.
        for q in -127i8..=127 {
            assert_eq!(f32_to_i8(-i8_to_f32(q)), -q);
        }
    }

    #[test]
    fn ef_residual_telescopes_over_a_window() {
        // Feeding the SAME value x for T steps through the compensated
        // quantizer, the emitted sum telescopes: Σ q_t = T·x − e_T, so
        // the average emitted value is within |e_T|/T of x — the error
        // does not accumulate (0/1-Adam Lemma 1's shape).
        for fmt in [ElemFmt::Bf16, ElemFmt::I8] {
            prop::check(&format!("{} EF telescopes", fmt.name()), 32, |rng| {
                let x = (rng.next_f32() - 0.5) * 3.0;
                let mut e = 0.0f32;
                let mut emitted = 0.0f64;
                let steps = 64;
                for _ in 0..steps {
                    let mut xs = [x];
                    let mut es = [e];
                    quantize_ef(fmt, &mut xs, &mut es);
                    e = es[0];
                    emitted += xs[0] as f64;
                }
                let avg = emitted / steps as f64;
                let bound = match fmt {
                    // One residual's worth of error spread over the window.
                    ElemFmt::I8 => (0.5 / I8_SCALE) as f64 / steps as f64 + 1e-6,
                    _ => (x.abs() as f64 / 128.0) / steps as f64 + 1e-6,
                };
                assert!(
                    (avg - x as f64).abs() <= bound,
                    "{} x={x} avg={avg} bound={bound}",
                    fmt.name()
                );
            });
        }
    }

    #[test]
    fn quantize_ef_is_identity_for_f32() {
        let mut xs = [1.0f32, -0.25, 3.0e-8];
        let mut es = [0.5f32, 0.5, 0.5];
        let orig = xs;
        quantize_ef(ElemFmt::F32, &mut xs, &mut es);
        assert_eq!(xs, orig);
        assert_eq!(es, [0.5, 0.5, 0.5]);
    }

    #[test]
    fn wire_codec_roundtrips_representable_values() {
        let mut rng = crate::util::rng::Xoshiro256::new(31);
        for fmt in [ElemFmt::F32, ElemFmt::Bf16, ElemFmt::I8] {
            let vals: Vec<f32> = (0..37)
                .map(|_| fmt.round((rng.next_f32() - 0.5) * 4.0))
                .collect();
            let mut wire = Vec::new();
            fmt.write_elems(&mut wire, &vals);
            assert_eq!(wire.len(), vals.len() * fmt.width());
            let mut back = vec![0.0f32; vals.len()];
            fmt.read_elems(&wire, &mut back).unwrap();
            for (a, b) in vals.iter().zip(&back) {
                assert_eq!(a.to_bits(), b.to_bits(), "{}", fmt.name());
            }
            // Length mismatch is a loud error.
            assert!(fmt.read_elems(&wire[1..], &mut back).is_err());
        }
    }
}
