//! Communication substrate: simulated hierarchical interconnect,
//! collective operations over the in-process worker group, and the
//! byte-exact ledger behind every Bytes/Step and PeakBytes number in the
//! reproduced tables.

pub mod accounting;
pub mod collective;
pub mod topology;

pub use accounting::{CommLedger, LayerClass, BYTES_BF16, BYTES_F32};
pub use collective::{direct_allreduce_mean, ring_allreduce_mean, ring_volume_bytes};
pub use topology::Topology;
