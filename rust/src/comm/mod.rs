//! Communication substrate: simulated hierarchical interconnect,
//! collective operations over the in-process worker group, and the
//! byte-exact ledger behind every Bytes/Step and PeakBytes number in the
//! reproduced tables.

pub mod accounting;
pub mod collective;
pub mod fmt;
pub mod topology;

pub use accounting::{CommLedger, LayerClass, StepRecord, BYTES_BF16, BYTES_F32};
pub use collective::{
    direct_allreduce_mean, hier_allreduce_mean, hier_allreduce_mean_fmt, hier_volume_bytes,
    hier_wire_split, record_virtual_sync, ring_allreduce_mean, ring_allreduce_mean_fmt,
    ring_volume_bytes, sync_mean, sync_mean_fmt, HierVolume,
};
pub use fmt::ElemFmt;
pub use topology::Topology;
