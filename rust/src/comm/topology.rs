//! Cluster interconnect model.
//!
//! The paper's motivation is the bandwidth disparity between on-node
//! interconnects (NVLink) and cross-node links (PCIe/IB): once gradient
//! synchronization traverses the slow boundary, payload bytes dominate
//! step time. We model a two-level hierarchy with an α–β (latency +
//! inverse-bandwidth) cost per link and derive ring all-reduce costs.

/// Two-level cluster: `nodes` machines × `gpus_per_node` accelerators.
#[derive(Clone, Debug)]
pub struct Topology {
    pub nodes: usize,
    pub gpus_per_node: usize,
    /// Intra-node (NVLink-class) bandwidth, bytes/s per link.
    pub intra_bw: f64,
    /// Inter-node (PCIe/IB-class) bandwidth, bytes/s per link.
    pub inter_bw: f64,
    /// Per-message latencies (the α term), seconds.
    pub intra_lat: f64,
    pub inter_lat: f64,
}

impl Topology {
    pub fn workers(&self) -> usize {
        self.nodes * self.gpus_per_node
    }

    /// Single-node NVLink box (8×A100-like): 300 GB/s NVLink.
    pub fn single_node(gpus: usize) -> Self {
        Self {
            nodes: 1,
            gpus_per_node: gpus,
            intra_bw: 300e9,
            inter_bw: 300e9,
            intra_lat: 3e-6,
            inter_lat: 3e-6,
        }
    }

    /// Multi-node cluster with PCIe-class cross-node links (the paper's
    /// "NVLink vs PCIe" disparity): 300 GB/s inside, 16 GB/s across.
    pub fn multi_node(nodes: usize, gpus_per_node: usize) -> Self {
        Self {
            nodes,
            gpus_per_node,
            intra_bw: 300e9,
            inter_bw: 16e9,
            intra_lat: 3e-6,
            inter_lat: 25e-6,
        }
    }

    /// Commodity Ethernet cluster (the regime where TSR's win is largest).
    pub fn ethernet(nodes: usize, gpus_per_node: usize) -> Self {
        Self {
            nodes,
            gpus_per_node,
            intra_bw: 300e9,
            inter_bw: 1.25e9, // 10 GbE
            intra_lat: 3e-6,
            inter_lat: 50e-6,
        }
    }

    /// Simulated wall-clock time for a ring all-reduce of `bytes` payload
    /// over all workers. Standard model: 2(N−1)/N · bytes / BW_bottleneck
    /// + 2(N−1) · α_bottleneck. With a two-level hierarchy the bottleneck
    /// is the slow link iff the ring crosses nodes.
    ///
    /// This closed form is the documented *oracle* for the discrete-event
    /// engine in `sim::engine`: for the degenerate configuration — flat
    /// ring (single level), a single bucket carrying the whole step
    /// payload, no compute/comm overlap — the engine reproduces it with
    /// exact f64 equality (`tests/sim_engine.rs`). The engine exists for
    /// everything this formula collapses: per-level α–β channels,
    /// bucketed sync, and overlap with backward compute.
    pub fn allreduce_time(&self, bytes: usize) -> f64 {
        let n = self.workers();
        if n <= 1 {
            return 0.0;
        }
        let crosses_nodes = self.nodes > 1;
        let (bw, lat) = if crosses_nodes {
            (self.inter_bw, self.inter_lat)
        } else {
            (self.intra_bw, self.intra_lat)
        };
        let steps = 2 * (n - 1);
        let volume = 2.0 * (n as f64 - 1.0) / n as f64 * bytes as f64;
        volume / bw + steps as f64 * lat
    }

    /// Channel-perturbed copy: per-link bandwidths *divided* and
    /// latencies *multiplied* by the given factors (all ≥ 1 for the
    /// adversarial jitter model in `sim::adversity`). Factors of
    /// exactly `1.0` are bit-preserving — `x / 1.0` and `x * 1.0` are
    /// IEEE identities — which is what keeps the clean path of the
    /// adversity-aware engine byte-identical to the plain one.
    pub fn perturb_channels(
        &self,
        intra_bw_div: f64,
        inter_bw_div: f64,
        intra_lat_mult: f64,
        inter_lat_mult: f64,
    ) -> Self {
        Self {
            nodes: self.nodes,
            gpus_per_node: self.gpus_per_node,
            intra_bw: self.intra_bw / intra_bw_div,
            inter_bw: self.inter_bw / inter_bw_div,
            intra_lat: self.intra_lat * intra_lat_mult,
            inter_lat: self.inter_lat * inter_lat_mult,
        }
    }

    /// Broadcast time (tree): ceil(log2 N) hops of the full payload.
    pub fn broadcast_time(&self, bytes: usize) -> f64 {
        let n = self.workers();
        if n <= 1 {
            return 0.0;
        }
        let crosses_nodes = self.nodes > 1;
        let (bw, lat) = if crosses_nodes {
            (self.inter_bw, self.inter_lat)
        } else {
            (self.intra_bw, self.intra_lat)
        };
        let hops = (n as f64).log2().ceil();
        hops * (bytes as f64 / bw + lat)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_worker_is_free() {
        let t = Topology::single_node(1);
        assert_eq!(t.allreduce_time(1 << 30), 0.0);
    }

    #[test]
    fn larger_payload_takes_longer() {
        let t = Topology::multi_node(4, 8);
        assert!(t.allreduce_time(1 << 30) > t.allreduce_time(1 << 20));
    }

    #[test]
    fn cross_node_slower_than_intra() {
        let single = Topology::single_node(8);
        let multi = Topology::multi_node(2, 4);
        // Same worker count, same payload: crossing nodes must be slower.
        assert_eq!(single.workers(), multi.workers());
        assert!(multi.allreduce_time(1 << 28) > single.allreduce_time(1 << 28));
    }

    #[test]
    fn small_messages_latency_bound() {
        // The r×r core regime: for tiny payloads the α term dominates, so
        // halving bytes barely changes the time. This is exactly why the
        // paper reports bytes, not time, as the primary metric.
        let t = Topology::multi_node(4, 8);
        let t_small = t.allreduce_time(4 * 256 * 256); // r=256 core
        let t_half = t.allreduce_time(2 * 256 * 256);
        assert!(t_small < 1.3 * t_half);
    }
}
