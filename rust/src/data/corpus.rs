//! Synthetic pre-training corpus (C4 substitute — see DESIGN.md §6).
//!
//! A deterministic token stream with realistic statistics for a *learning
//! signal*: Zipf-distributed unigrams blended with an order-1 Markov
//! component (so there is mutual information between adjacent tokens for
//! the model to learn, and the loss curve has the usual fast-then-slow
//! shape). Fully reproducible from a seed; no files needed.

use crate::util::rng::Xoshiro256;

pub struct SyntheticCorpus {
    pub vocab: usize,
    /// Cumulative Zipf weights for the unigram component.
    unigram_cum: Vec<f64>,
    /// Each token's "successor offset" pattern — defines a sparse
    /// deterministic bigram structure the model can learn.
    succ_a: Vec<u32>,
    succ_b: Vec<u32>,
    /// Probability of following the Markov component vs the unigram.
    markov_p: f64,
}

impl SyntheticCorpus {
    pub fn new(vocab: usize, seed: u64) -> Self {
        assert!(vocab >= 4);
        let mut rng = Xoshiro256::new(seed);
        // Zipf(1.0) unigram.
        let mut cum = Vec::with_capacity(vocab);
        let mut acc = 0.0f64;
        for i in 0..vocab {
            acc += 1.0 / (i as f64 + 1.0);
            cum.push(acc);
        }
        // Two candidate successors per token (learnable bigram signal).
        let succ_a = (0..vocab).map(|_| rng.next_below(vocab as u64) as u32).collect();
        let succ_b = (0..vocab).map(|_| rng.next_below(vocab as u64) as u32).collect();
        Self {
            vocab,
            unigram_cum: cum,
            succ_a,
            succ_b,
            markov_p: 0.6,
        }
    }

    /// Sample `len` tokens continuing from `prev` using `rng`.
    pub fn sample_into(&self, rng: &mut Xoshiro256, prev: &mut u32, out: &mut [u32]) {
        for slot in out.iter_mut() {
            let next = if rng.next_f64() < self.markov_p {
                // Markov component: one of the two learned successors.
                if rng.next_f64() < 0.7 {
                    self.succ_a[*prev as usize]
                } else {
                    self.succ_b[*prev as usize]
                }
            } else {
                self.unigram_cum
                    .partition_point(|&c| c < rng.next_f64() * self.unigram_cum[self.vocab - 1])
                    .min(self.vocab - 1) as u32
            };
            *slot = next;
            *prev = next;
        }
    }

    /// Monte-Carlo plug-in estimate (nats) of the stream's marginal
    /// unigram entropy — the loss floor of any *context-free* predictor:
    /// a model that ignores history can at best emit the marginal
    /// distribution, scoring cross-entropy H(marginal). A trained LM
    /// beating this floor is direct evidence it exploits the Markov
    /// component (used by `tests/lm_train.rs` and `exp::lm_curves`).
    pub fn unigram_entropy(&self, samples: usize, seed: u64) -> f64 {
        assert!(samples > 0);
        let mut rng = Xoshiro256::new(seed);
        let mut prev = 1u32;
        let mut buf = vec![0u32; samples];
        self.sample_into(&mut rng, &mut prev, &mut buf);
        let mut counts = vec![0u64; self.vocab];
        for &t in &buf {
            counts[t as usize] += 1;
        }
        let n = samples as f64;
        counts
            .iter()
            .filter(|&&c| c > 0)
            .map(|&c| {
                let p = c as f64 / n;
                -p * p.ln()
            })
            .sum()
    }
}

/// Sharded batch iterator: worker `w` of `n` draws from an independent,
/// deterministic stream — the data-parallel sharding of §3.1.
pub struct Batcher {
    corpus: SyntheticCorpus,
    pub batch: usize,
    pub seq: usize,
    streams: Vec<(Xoshiro256, u32)>,
}

impl Batcher {
    pub fn new(corpus: SyntheticCorpus, workers: usize, batch: usize, seq: usize, seed: u64) -> Self {
        let streams = (0..workers)
            .map(|w| (Xoshiro256::for_stream(seed, w as u64), 1u32))
            .collect();
        Self {
            corpus,
            batch,
            seq,
            streams,
        }
    }

    pub fn workers(&self) -> usize {
        self.streams.len()
    }

    /// Checkpoint view of every worker stream's position: the xoshiro
    /// state words, the Box–Muller spare, and the Markov `prev` token.
    /// Restoring via [`Self::restore_streams`] continues each stream at
    /// exactly the same position, so a resumed run draws the identical
    /// tail of token blocks (the `--source lm` bitwise-resume leg of
    /// DESIGN.md §9).
    pub fn snapshot_streams(&self) -> Vec<([u64; 4], Option<f64>, u32)> {
        self.streams
            .iter()
            .map(|(rng, prev)| {
                let (s, spare) = rng.snapshot();
                (s, spare, *prev)
            })
            .collect()
    }

    /// Restore positions saved by [`Self::snapshot_streams`]. The count
    /// must match this batcher's worker count: per-worker token streams
    /// have no meaningful re-shard, so an elastic world-size change is
    /// rejected rather than silently skewing the data order.
    pub fn restore_streams(
        &mut self,
        states: &[([u64; 4], Option<f64>, u32)],
    ) -> Result<(), String> {
        if states.len() != self.streams.len() {
            return Err(format!(
                "batcher: checkpoint has {} streams but this run has {} workers",
                states.len(),
                self.streams.len()
            ));
        }
        for ((rng, prev), (s, spare, p)) in self.streams.iter_mut().zip(states) {
            *rng = Xoshiro256::from_snapshot(*s, *spare);
            *prev = *p;
        }
        Ok(())
    }

    /// Next `[batch, seq+1]` token block for worker `w` (inputs = [..seq],
    /// targets = [1..]). Returned flat, row-major.
    pub fn next_block(&mut self, w: usize) -> Vec<u32> {
        let (rng, prev) = &mut self.streams[w];
        let mut out = vec![0u32; self.batch * (self.seq + 1)];
        for b in 0..self.batch {
            self.corpus
                .sample_into(rng, prev, &mut out[b * (self.seq + 1)..(b + 1) * (self.seq + 1)]);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_constructions() {
        let mut b1 = Batcher::new(SyntheticCorpus::new(100, 7), 2, 4, 16, 9);
        let mut b2 = Batcher::new(SyntheticCorpus::new(100, 7), 2, 4, 16, 9);
        assert_eq!(b1.next_block(0), b2.next_block(0));
        assert_eq!(b1.next_block(1), b2.next_block(1));
    }

    #[test]
    fn workers_get_different_shards() {
        let mut b = Batcher::new(SyntheticCorpus::new(100, 7), 2, 2, 32, 9);
        assert_ne!(b.next_block(0), b.next_block(1));
    }

    #[test]
    fn tokens_in_range() {
        let mut b = Batcher::new(SyntheticCorpus::new(50, 3), 1, 8, 64, 1);
        for _ in 0..10 {
            for &t in &b.next_block(0) {
                assert!((t as usize) < 50);
            }
        }
    }

    #[test]
    fn stream_snapshot_restore_continues_blocks_exactly() {
        let mut b1 = Batcher::new(SyntheticCorpus::new(80, 4), 3, 2, 8, 17);
        // Advance unevenly so the streams are mid-flight.
        for _ in 0..3 {
            b1.next_block(0);
        }
        b1.next_block(1);
        let snap = b1.snapshot_streams();
        let expect: Vec<Vec<u32>> = (0..3).map(|w| b1.next_block(w)).collect();
        let mut b2 = Batcher::new(SyntheticCorpus::new(80, 4), 3, 2, 8, 17);
        b2.restore_streams(&snap).unwrap();
        for (w, e) in expect.iter().enumerate() {
            assert_eq!(&b2.next_block(w), e, "worker {w}");
        }
        // Worker-count mismatch is rejected, not silently resharded.
        let mut b4 = Batcher::new(SyntheticCorpus::new(80, 4), 4, 2, 8, 17);
        assert!(b4.restore_streams(&snap).is_err());
    }

    #[test]
    fn unigram_entropy_is_a_real_floor() {
        let corpus = SyntheticCorpus::new(64, 5);
        let h = corpus.unigram_entropy(200_000, 9);
        // Between the fully-deterministic and uniform extremes, and
        // stable across sample seeds to a few percent.
        assert!(h > 1.0 && h < (64f64).ln(), "entropy {h}");
        let h2 = corpus.unigram_entropy(200_000, 10);
        assert!((h - h2).abs() < 0.05, "{h} vs {h2}");
    }

    #[test]
    fn bigram_structure_is_learnable() {
        // The same prefix token should be followed by its successor tokens
        // far more often than chance.
        let corpus = SyntheticCorpus::new(64, 5);
        let mut rng = Xoshiro256::new(11);
        let mut prev = 1u32;
        let mut buf = vec![0u32; 200_000];
        corpus.sample_into(&mut rng, &mut prev, &mut buf);
        let mut follows = 0usize;
        let mut total = 0usize;
        for w in buf.windows(2) {
            let (a, b) = (w[0] as usize, w[1]);
            if b == corpus.succ_a[a] || b == corpus.succ_b[a] {
                follows += 1;
            }
            total += 1;
        }
        let frac = follows as f64 / total as f64;
        assert!(frac > 0.4, "bigram fraction {frac} too low to learn from");
    }
}
