//! Synthetic data substrate (offline C4/GLUE substitutes).

pub mod corpus;

pub use corpus::{Batcher, SyntheticCorpus};
