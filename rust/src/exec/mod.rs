//! Execution backends: how the simulated data-parallel worker group
//! actually runs on this host (DESIGN.md §8, §12).
//!
//! Three backends implement the same step semantics:
//!
//! * [`ExecBackend::Sequential`] — the original in-place loop: one OS
//!   thread iterates workers and moves collective chunks between their
//!   buffers directly. Cheap, allocation-free, and the reference
//!   implementation for every numeric contract in the test suite.
//! * [`ExecBackend::Threaded`] — one OS thread per simulated worker.
//!   Each thread owns its worker's gradient shard; collectives are a
//!   real rendezvous ring over shared-memory chunks with a barrier per
//!   ring step ([`threaded`]), so the `CommLedger`'s intra/inter wire
//!   columns are metered from bytes that genuinely crossed a thread
//!   boundary. The backend also shards the dense-Adam moment update and
//!   fans the per-worker rSVD sketch / projection work out over threads,
//!   which is what makes it faster wall-clock on multi-core hosts.
//! * [`ExecBackend::Process`] — one real OS **process** per simulated
//!   worker ([`process`], DESIGN.md §12). Collectives run as rendezvous
//!   rings over localhost TCP sockets with a length-prefixed checksummed
//!   frame codec (`net/`), so the wire columns meter bytes that were
//!   literally serialized onto a socket and read back off it. Per-worker
//!   fan-out compute (sketches, projections, elementwise shards) stays
//!   in the coordinator process — only the collective path crosses the
//!   process boundary.
//!
//! **Determinism contract.** For any method, topology, and seed, all
//! backends produce bitwise-identical weights and identical ledger byte
//! columns. The threaded and process rings replay the sequential
//! schedule exactly — the chunk a worker reduces at ring step `s` is
//! fixed by `(position, s)`, each element receives its additions in the
//! same order (threaded: barrier per step; process: message arrival
//! order on per-pair TCP streams), and f32 payloads cross the wire as
//! little-endian bit patterns — so no reordering or re-encoding can
//! creep into the f32 sums. Elementwise shards (dense Adam) and
//! per-worker fan-outs (sketches, core projections) are trivially
//! order-free. `tests/exec_parity.rs` enforces this for all nine
//! optimizers; CI diffs full `tsr train` runs byte-for-byte across all
//! three backends.

pub mod process;
pub mod threaded;

/// Which execution engine drives collectives and hot-path loops.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum ExecBackend {
    /// Single-threaded in-place reference loop.
    #[default]
    Sequential,
    /// One OS thread per simulated worker for collectives; up to
    /// `threads` OS threads for elementwise / per-worker fan-out work.
    Threaded { threads: usize },
    /// One OS process per simulated worker for collectives, rings over
    /// localhost TCP. `workers` is the world size to pre-spawn (0 =
    /// spawn lazily at the first collective); groups are pooled per
    /// world size either way.
    Process { workers: usize },
}

impl ExecBackend {
    /// Threaded backend sized to this host's available parallelism.
    pub fn threaded() -> Self {
        Self::Threaded {
            threads: crate::util::pool::default_threads(),
        }
    }

    /// Process backend with lazy group spawning.
    pub fn process() -> Self {
        Self::Process { workers: 0 }
    }

    /// Parse a CLI/env backend name. Unknown names are a loud error
    /// listing the valid set — a typo must never fall back silently.
    pub fn parse(name: &str) -> Result<Self, String> {
        match name.trim() {
            "sequential" | "seq" => Ok(Self::Sequential),
            "threaded" | "thread" => Ok(Self::threaded()),
            "process" | "proc" => Ok(Self::process()),
            other => Err(format!(
                "unknown execution backend `{other}` (valid: sequential | threaded | process)"
            )),
        }
    }

    /// Backend selected by the `TSR_BACKEND` environment variable
    /// (default `sequential`); a set-but-invalid value panics with the
    /// valid list rather than silently running the wrong backend. CI
    /// runs the whole test suite once per backend to exercise each path
    /// everywhere a `Trainer` or experiment driver is constructed.
    pub fn from_env() -> Self {
        match std::env::var("TSR_BACKEND") {
            Ok(v) => Self::parse(&v).unwrap_or_else(|e| panic!("TSR_BACKEND: {e}")),
            Err(_) => Self::Sequential,
        }
    }

    /// Size the backend to a known world size: the process backend
    /// records it so the trainer can pre-spawn the worker group before
    /// step 0. No-op for the in-process backends.
    pub fn sized_for(self, workers: usize) -> Self {
        match self {
            Self::Process { .. } => Self::Process { workers },
            other => other,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Sequential => "sequential",
            Self::Threaded { .. } => "threaded",
            Self::Process { .. } => "process",
        }
    }

    pub fn is_threaded(&self) -> bool {
        matches!(self, Self::Threaded { .. })
    }

    pub fn is_process(&self) -> bool {
        matches!(self, Self::Process { .. })
    }

    /// Worker-thread budget for elementwise shards and fan-outs (1 for
    /// the sequential backend, and for the process backend — its
    /// children only serve collectives; fan-out compute stays in the
    /// coordinator).
    pub fn threads(&self) -> usize {
        match self {
            Self::Sequential | Self::Process { .. } => 1,
            Self::Threaded { threads } => (*threads).max(1),
        }
    }

    /// Map `f` over `0..n` (one simulated worker each), collecting
    /// results in index order. Threaded: real OS threads via the scoped
    /// pool. The results are bitwise backend-independent because each
    /// index's computation touches only its own inputs.
    pub fn map_workers<T: Send>(&self, n: usize, f: impl Fn(usize) -> T + Sync) -> Vec<T> {
        match self {
            Self::Sequential | Self::Process { .. } => (0..n).map(f).collect(),
            Self::Threaded { threads } => crate::util::pool::parallel_map(n, (*threads).max(1), f),
        }
    }
}

/// Contiguous shard boundaries `c·len/shards` for `c = 0..=shards` —
/// the same splitting rule the ring collectives use for chunks, so
/// shard sizes differ by at most one element for ragged `len`.
pub fn shard_bounds(len: usize, shards: usize) -> Vec<usize> {
    let s = shards.max(1);
    (0..=s).map(|c| c * len / s).collect()
}

/// Chunk boundaries `lo + c·(hi−lo)/m` for `c = 0..=m` — the single
/// splitting rule every ring collective uses, shared by the threaded
/// and process backends so their schedules cannot drift from the
/// sequential primitives in `comm::collective` (the parity suite pins
/// all three to each other).
pub(crate) fn chunk_starts(lo: usize, hi: usize, m: usize) -> Vec<usize> {
    let len = hi - lo;
    (0..=m).map(|c| lo + c * len / m).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_name_roundtrip() {
        assert_eq!(ExecBackend::parse("sequential"), Ok(ExecBackend::Sequential));
        assert!(ExecBackend::parse("threaded").unwrap().is_threaded());
        assert!(ExecBackend::parse("process").unwrap().is_process());
        assert_eq!(ExecBackend::Sequential.name(), "sequential");
        assert_eq!(ExecBackend::threaded().name(), "threaded");
        assert_eq!(ExecBackend::process().name(), "process");
        assert_eq!(ExecBackend::Sequential.threads(), 1);
        assert_eq!(ExecBackend::process().threads(), 1);
        assert!(ExecBackend::threaded().threads() >= 1);
    }

    #[test]
    fn parse_rejects_unknown_names_loudly() {
        // A typo must produce an error naming the valid set, not a
        // silent fallback (the old behavior for TSR_BACKEND).
        for bogus in ["gpu", "Threaded", "processs", "", "  "] {
            let err = ExecBackend::parse(bogus).unwrap_err();
            assert!(
                err.contains("sequential | threaded | process"),
                "`{bogus}` -> {err}"
            );
            assert!(err.contains("unknown execution backend"), "`{bogus}` -> {err}");
        }
        // Trimmed aliases still parse.
        assert_eq!(ExecBackend::parse(" seq "), Ok(ExecBackend::Sequential));
        assert!(ExecBackend::parse("proc").unwrap().is_process());
    }

    #[test]
    fn sized_for_touches_only_the_process_backend() {
        assert_eq!(
            ExecBackend::process().sized_for(8),
            ExecBackend::Process { workers: 8 }
        );
        assert_eq!(ExecBackend::Sequential.sized_for(8), ExecBackend::Sequential);
        let t = ExecBackend::threaded();
        assert_eq!(t.sized_for(8), t);
    }

    #[test]
    fn map_workers_matches_serial_map() {
        let serial = ExecBackend::Sequential.map_workers(13, |i| i * i);
        let par = ExecBackend::Threaded { threads: 4 }.map_workers(13, |i| i * i);
        let proc = ExecBackend::process().map_workers(13, |i| i * i);
        assert_eq!(serial, par);
        assert_eq!(serial, proc);
    }

    #[test]
    fn shard_bounds_cover_range_exactly() {
        for (len, s) in [(10usize, 3usize), (0, 4), (7, 7), (100, 1), (5, 9)] {
            let b = shard_bounds(len, s);
            assert_eq!(b[0], 0);
            assert_eq!(*b.last().unwrap(), len);
            for w in b.windows(2) {
                assert!(w[0] <= w[1]);
            }
        }
    }

    #[test]
    fn chunk_starts_match_shard_bounds_at_zero_offset() {
        for (len, m) in [(37usize, 5usize), (4, 7), (0, 3), (12, 4)] {
            assert_eq!(chunk_starts(0, len, m), shard_bounds(len, m));
            let shifted = chunk_starts(10, 10 + len, m);
            assert!(shifted.iter().zip(shard_bounds(len, m)).all(|(a, b)| *a == 10 + b));
        }
    }
}
