//! Execution backends: how the simulated data-parallel worker group
//! actually runs on this host (DESIGN.md §8).
//!
//! Two backends implement the same step semantics:
//!
//! * [`ExecBackend::Sequential`] — the original in-place loop: one OS
//!   thread iterates workers and moves collective chunks between their
//!   buffers directly. Cheap, allocation-free, and the reference
//!   implementation for every numeric contract in the test suite.
//! * [`ExecBackend::Threaded`] — one OS thread per simulated worker.
//!   Each thread owns its worker's gradient shard; collectives are a
//!   real rendezvous ring over shared-memory chunks with a barrier per
//!   ring step ([`threaded`]), so the `CommLedger`'s intra/inter wire
//!   columns are metered from bytes that genuinely crossed a thread
//!   boundary. The backend also shards the dense-Adam moment update and
//!   fans the per-worker rSVD sketch / projection work out over threads,
//!   which is what makes it faster wall-clock on multi-core hosts.
//!
//! **Determinism contract.** For any method, topology, and seed, both
//! backends produce bitwise-identical weights and identical ledger byte
//! columns. The threaded rings replay the sequential schedule exactly —
//! the chunk a worker reduces at ring step `s` is fixed by `(position,
//! s)`, each element receives its additions in the same order, and a
//! barrier separates steps — so no atomics-order nondeterminism can
//! creep into the f32 sums. Elementwise shards (dense Adam) and
//! per-worker fan-outs (sketches, core projections) are trivially
//! order-free. `tests/exec_parity.rs` enforces this for all seven
//! optimizers; CI diffs two full `tsr train` runs byte-for-byte.

pub mod threaded;

/// Which execution engine drives collectives and hot-path loops.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum ExecBackend {
    /// Single-threaded in-place reference loop.
    #[default]
    Sequential,
    /// One OS thread per simulated worker for collectives; up to
    /// `threads` OS threads for elementwise / per-worker fan-out work.
    Threaded { threads: usize },
}

impl ExecBackend {
    /// Threaded backend sized to this host's available parallelism.
    pub fn threaded() -> Self {
        Self::Threaded {
            threads: crate::util::pool::default_threads(),
        }
    }

    /// Parse a CLI/env backend name (`sequential` | `threaded`).
    pub fn parse(name: &str) -> Option<Self> {
        match name.trim() {
            "sequential" | "seq" => Some(Self::Sequential),
            "threaded" | "thread" => Some(Self::threaded()),
            _ => None,
        }
    }

    /// Backend selected by the `TSR_BACKEND` environment variable
    /// (default `sequential`). CI runs the whole test suite once with
    /// `TSR_BACKEND=threaded` to exercise the threaded paths everywhere
    /// a `Trainer` or experiment driver is constructed.
    pub fn from_env() -> Self {
        std::env::var("TSR_BACKEND")
            .ok()
            .and_then(|v| Self::parse(&v))
            .unwrap_or(Self::Sequential)
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Sequential => "sequential",
            Self::Threaded { .. } => "threaded",
        }
    }

    pub fn is_threaded(&self) -> bool {
        matches!(self, Self::Threaded { .. })
    }

    /// Worker-thread budget for elementwise shards and fan-outs (1 for
    /// the sequential backend).
    pub fn threads(&self) -> usize {
        match self {
            Self::Sequential => 1,
            Self::Threaded { threads } => (*threads).max(1),
        }
    }

    /// Map `f` over `0..n` (one simulated worker each), collecting
    /// results in index order. Threaded: real OS threads via the scoped
    /// pool. The results are bitwise backend-independent because each
    /// index's computation touches only its own inputs.
    pub fn map_workers<T: Send>(&self, n: usize, f: impl Fn(usize) -> T + Sync) -> Vec<T> {
        match self {
            Self::Sequential => (0..n).map(f).collect(),
            Self::Threaded { threads } => crate::util::pool::parallel_map(n, (*threads).max(1), f),
        }
    }
}

/// Contiguous shard boundaries `c·len/shards` for `c = 0..=shards` —
/// the same splitting rule the ring collectives use for chunks, so
/// shard sizes differ by at most one element for ragged `len`.
pub fn shard_bounds(len: usize, shards: usize) -> Vec<usize> {
    let s = shards.max(1);
    (0..=s).map(|c| c * len / s).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_name_roundtrip() {
        assert_eq!(ExecBackend::parse("sequential"), Some(ExecBackend::Sequential));
        assert!(ExecBackend::parse("threaded").unwrap().is_threaded());
        assert_eq!(ExecBackend::parse("gpu"), None);
        assert_eq!(ExecBackend::Sequential.name(), "sequential");
        assert_eq!(ExecBackend::threaded().name(), "threaded");
        assert_eq!(ExecBackend::Sequential.threads(), 1);
        assert!(ExecBackend::threaded().threads() >= 1);
    }

    #[test]
    fn map_workers_matches_serial_map() {
        let serial = ExecBackend::Sequential.map_workers(13, |i| i * i);
        let par = ExecBackend::Threaded { threads: 4 }.map_workers(13, |i| i * i);
        assert_eq!(serial, par);
    }

    #[test]
    fn shard_bounds_cover_range_exactly() {
        for (len, s) in [(10usize, 3usize), (0, 4), (7, 7), (100, 1), (5, 9)] {
            let b = shard_bounds(len, s);
            assert_eq!(b[0], 0);
            assert_eq!(*b.last().unwrap(), len);
            for w in b.windows(2) {
                assert!(w[0] <= w[1]);
            }
        }
    }
}
