//! Process execution backend: one real OS process per simulated worker,
//! ring collectives over localhost TCP (DESIGN.md §12).
//!
//! The coordinator (this module, running in the main `tsr` process)
//! keeps ownership of the per-worker buffers — exactly like the other
//! backends — and, per collective, scatters each worker's buffer to its
//! child process, lets the children run the socket-ring all-reduce
//! among themselves ([`worker`]), and gathers the reduced buffers back.
//! Only the worker↔worker `Data` frames count as wire bytes: the
//! coordinator scatter/gather is an artifact of keeping the buffers
//! host-side, not part of the simulated collective.
//!
//! **Lifecycle.** Worker groups are pooled by world size and spawned
//! lazily on first use (or eagerly via [`ensure_group`]): the current
//! binary is re-executed with the hidden `tsr _worker` subcommand, the
//! children rendezvous through the coordinator's listener into a full
//! TCP mesh, and the group then serves collectives until the process
//! exits (children watch the control socket and exit on EOF, so a dead
//! coordinator never leaves orphans). A group whose collective fails is
//! killed, reaped, and evicted — the next collective at that world size
//! spawns a fresh group.
//!
//! **Determinism.** The children replay the exact sequential chunk
//! schedule (see [`worker`]); payloads cross the wire as little-endian
//! bit patterns at the element format's width (f32 words, bf16
//! halfwords, or int8 bytes — DESIGN.md §14), re-rounded at the same
//! schedule points as the sequential backend so the narrow encoding is
//! lossless for the values it carries; the coordinator writes requests
//! and reads results in rank order. Weights and every ledger column
//! are bitwise-identical to the `Sequential` backend — `tests/
//! exec_parity.rs` pins this for all nine optimizers.
//!
//! **Metering.** Each worker counts the payload bytes it sent and
//! received per link class during the rings; the coordinator asserts
//! the sent and received totals match (every byte metered was actually
//! written to a socket and read back off it) and returns the measured
//! volume, which is what `sync_mean` records in the ledger.

pub mod worker;

use crate::comm::collective::HierVolume;
use crate::comm::ElemFmt;
use crate::linalg::Matrix;
use crate::net::{
    accept_deadline, bind_localhost, read_frame_expect, write_frame, Builder, FrameKind, NetError,
    Reader, WIRE_VERSION,
};
use std::collections::HashMap;
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// One spawned worker group: `world` children plus one control stream
/// per rank. All collectives on a group are serialized by its mutex.
struct ProcessGroup {
    world: usize,
    children: Vec<Child>,
    ctrl: Vec<TcpStream>,
    /// Collectives issued so far; echoed in every request/response pair
    /// so a desynchronized stream is caught immediately.
    seq: u64,
}

fn pool() -> &'static Mutex<HashMap<usize, Arc<Mutex<ProcessGroup>>>> {
    static POOL: OnceLock<Mutex<HashMap<usize, Arc<Mutex<ProcessGroup>>>>> = OnceLock::new();
    POOL.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Test-only fault injection: the next collective on a group of the
/// given world size tells this rank's worker to exit mid-collective
/// (the robustness tests use it to exercise child-death detection
/// without OS-level races). Keyed by world size so concurrently running
/// tests on other group sizes cannot absorb the fault.
static CHAOS_KILL: Mutex<Option<(usize, usize)>> = Mutex::new(None);

/// Arm fault injection: kill `rank`'s worker during the next collective
/// that runs on a `world`-sized group (test-only).
pub fn inject_fault_next_collective(world: usize, rank: usize) {
    *lock(&CHAOS_KILL) = Some((world, rank));
}

static WORKER_BIN: OnceLock<PathBuf> = OnceLock::new();

/// Override the binary re-executed as `tsr _worker`. Integration tests
/// call this with `env!("CARGO_BIN_EXE_tsr")`, whose libtest harness
/// binary could not serve as a worker itself. First call wins.
pub fn set_worker_binary(path: PathBuf) {
    let _ = WORKER_BIN.set(path);
}

/// Resolve the worker binary: explicit override, then `TSR_WORKER_BIN`,
/// then the current executable when it is the `tsr` binary itself, then
/// the sibling `tsr` next to a cargo test binary's `deps/` directory.
fn worker_binary() -> Result<PathBuf, String> {
    if let Some(p) = WORKER_BIN.get() {
        return Ok(p.clone());
    }
    if let Ok(p) = std::env::var("TSR_WORKER_BIN") {
        return Ok(PathBuf::from(p));
    }
    let exe = std::env::current_exe().map_err(|e| format!("current_exe: {e}"))?;
    let stem = exe.file_stem().and_then(|s| s.to_str()).unwrap_or("");
    if stem == "tsr" {
        return Ok(exe);
    }
    // Test binaries live in target/<profile>/deps/<name>-<hash>; the
    // uplifted tsr binary sits one directory up.
    if let Some(parent) = exe.parent() {
        if parent.file_name().and_then(|s| s.to_str()) == Some("deps") {
            if let Some(target_dir) = parent.parent() {
                for name in ["tsr", "tsr.exe"] {
                    let candidate = target_dir.join(name);
                    if candidate.is_file() {
                        return Ok(candidate);
                    }
                }
            }
        }
    }
    Err(format!(
        "cannot resolve the worker binary from {} — set TSR_WORKER_BIN or call \
         exec::process::set_worker_binary (tests: env!(\"CARGO_BIN_EXE_tsr\"))",
        exe.display()
    ))
}

fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    // A collective that panicked poisons its mutex; the group it was
    // using has already been destroyed and evicted, so recovery is safe.
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// Pre-spawn (or reuse) the worker group for `world` workers — the
/// trainer calls this up front so the spawn cost lands before step 0,
/// and a broken environment fails loudly at startup instead of at the
/// first collective. Panics on spawn failure.
pub fn ensure_group(world: usize) {
    if world > 1 {
        drop(group_for(world));
    }
}

fn group_for(world: usize) -> Arc<Mutex<ProcessGroup>> {
    let mut map = lock(pool());
    if let Some(g) = map.get(&world) {
        return Arc::clone(g);
    }
    let g = spawn_group(world)
        .unwrap_or_else(|e| panic!("process backend: failed to spawn {world}-worker group: {e}"));
    let arc = Arc::new(Mutex::new(g));
    map.insert(world, Arc::clone(&arc));
    arc
}

/// Tear down every pooled group: send `Shutdown`, reap the children
/// (killing any that ignore it past the deadline), and clear the pool.
/// Idle children also exit on their own when this process dies (control
/// socket EOF), so calling this is hygiene, not a correctness need.
pub fn shutdown_all() {
    let groups: Vec<_> = lock(pool()).drain().collect();
    for (_, g) in groups {
        let mut g = lock(&g);
        for rank in 0..g.world {
            let _ = write_frame(&mut g.ctrl[rank], FrameKind::Shutdown, &[], "shutdown");
        }
        let deadline = std::time::Instant::now() + crate::net::io_deadline();
        for ch in &mut g.children {
            loop {
                match ch.try_wait() {
                    Ok(Some(_)) => break,
                    Ok(None) if std::time::Instant::now() >= deadline => {
                        let _ = ch.kill();
                        let _ = ch.wait();
                        break;
                    }
                    Ok(None) => std::thread::sleep(std::time::Duration::from_millis(2)),
                    Err(_) => break,
                }
            }
        }
    }
}

/// Two-level hierarchical all-reduce (average) over real worker
/// processes — same contract as `exec::threaded::allreduce_mean`:
/// node-major layout, degenerate shapes collapse to a flat ring, and
/// the returned volume is the aggregate payload bytes that crossed the
/// worker sockets per link class. Panics (loudly, with a distinct
/// diagnosis) on child death, frame corruption, or a blown deadline —
/// after killing and reaping the whole group, so no zombies remain and
/// the next collective starts from a fresh spawn.
pub fn allreduce_mean(workers: &mut [Matrix], nodes: usize, gpus_per_node: usize) -> HierVolume {
    allreduce_mean_fmt(workers, nodes, gpus_per_node, ElemFmt::F32)
}

/// Format-aware variant: ring chunks cross the sockets encoded at
/// `fmt.width()` bytes per element (the children re-round each
/// reduce-scatter partial sum at the same schedule points as the
/// sequential backend, so narrow frames are lossless for the values
/// they carry and the result stays bitwise backend-invariant). The
/// returned volume counts the narrow bytes actually sent.
pub fn allreduce_mean_fmt(
    workers: &mut [Matrix],
    nodes: usize,
    gpus_per_node: usize,
    fmt: ElemFmt,
) -> HierVolume {
    let n = workers.len();
    assert!(n > 0);
    assert_eq!(n, nodes * gpus_per_node, "topology shape mismatch");
    let numel = workers[0].numel();
    for w in workers.iter() {
        assert_eq!(w.numel(), numel, "ragged all-reduce");
    }
    if n == 1 {
        return HierVolume::default();
    }
    let group = group_for(n);
    let mut g = lock(&group);
    match collective(&mut g, workers, nodes, gpus_per_node, fmt) {
        Ok(vol) => vol,
        Err(msg) => {
            destroy(&mut g);
            lock(pool()).remove(&n);
            panic!("process backend: {msg}");
        }
    }
}

fn destroy(g: &mut ProcessGroup) {
    for ch in &mut g.children {
        let _ = ch.kill();
    }
    for ch in &mut g.children {
        let _ = ch.wait(); // reap — no zombie children survive a failure
    }
    g.ctrl.clear();
    // Wall tier only: the next collective at this world size respawns,
    // which shows up as a fresh `proc_spawn`.
    crate::obs::global().wall_event(
        "proc_destroy",
        vec![("world", crate::util::json::Json::num(g.world as f64))],
    );
}

/// Run one collective on a live group: scatter, let the rings run,
/// gather, cross-check the wire accounting.
fn collective(
    g: &mut ProcessGroup,
    workers: &mut [Matrix],
    nodes: usize,
    gpus_per_node: usize,
    fmt: ElemFmt,
) -> Result<HierVolume, String> {
    g.seq += 1;
    let seq = g.seq;
    let numel = workers[0].numel();
    // Wall-tier per-worker wire counters are requested only when the
    // global tracer is in wall mode — deterministic traces never touch
    // this path (DESIGN.md §16).
    let tracer = crate::obs::global();
    let want_trace = tracer.wall();
    let chaos = {
        let mut slot = lock(&CHAOS_KILL);
        match *slot {
            Some((world, rank)) if world == g.world => {
                *slot = None;
                Some(rank)
            }
            _ => None,
        }
    };

    for rank in 0..g.world {
        let inject = u8::from(chaos == Some(rank));
        let payload = Builder::new()
            .u64(seq)
            .u32(nodes as u32)
            .u32(gpus_per_node as u32)
            .u64(numel as u64)
            .u8(inject)
            .u8(fmt.wire_tag())
            .u8(u8::from(want_trace))
            .f32s(&workers[rank].data)
            .build();
        let what = format!("coordinator -> worker {rank}");
        write_frame(&mut g.ctrl[rank], FrameKind::Collective, &payload, &what)
            .map_err(|e| classify(&mut g.children, rank, e))?;
    }

    let (mut sent_intra, mut sent_inter, mut recv_intra, mut recv_inter) = (0u64, 0u64, 0u64, 0u64);
    for rank in 0..g.world {
        let what = format!("coordinator <- worker {rank}");
        let payload = read_frame_expect(&mut g.ctrl[rank], FrameKind::Result, &what)
            .map_err(|e| classify(&mut g.children, rank, e))?;
        let mut r = Reader::new(&payload, &what);
        let decode = (|| -> Result<(), NetError> {
            let got_seq = r.u64("seq")?;
            if got_seq != seq {
                return Err(NetError::Malformed {
                    what: what.clone(),
                    detail: format!("result for collective {got_seq}, expected {seq}"),
                });
            }
            sent_intra += r.u64("sent_intra")?;
            sent_inter += r.u64("sent_inter")?;
            recv_intra += r.u64("recv_intra")?;
            recv_inter += r.u64("recv_inter")?;
            Ok(())
        })();
        decode.map_err(|e| classify(&mut g.children, rank, e))?;
        let mut rest = r;
        rest.f32s_into(&mut workers[rank].data, "payload")
            .and_then(|()| rest.finish())
            .map_err(|e| classify(&mut g.children, rank, e))?;
    }

    if want_trace {
        // Gather each worker's Trace frame in rank order so the merged
        // wall records are rank-ordered too.
        for rank in 0..g.world {
            let what = format!("coordinator trace <- worker {rank}");
            let payload = read_frame_expect(&mut g.ctrl[rank], FrameKind::Trace, &what)
                .map_err(|e| classify(&mut g.children, rank, e))?;
            let mut r = Reader::new(&payload, &what);
            let decode = (|| -> Result<(u64, u64, u64, u64), NetError> {
                let got_seq = r.u64("seq")?;
                if got_seq != seq {
                    return Err(NetError::Malformed {
                        what: what.clone(),
                        detail: format!("trace for collective {got_seq}, expected {seq}"),
                    });
                }
                let fs = r.u64("frames_sent")?;
                let bs = r.u64("bytes_sent")?;
                let fr = r.u64("frames_recv")?;
                let br = r.u64("bytes_recv")?;
                r.finish()?;
                Ok((fs, bs, fr, br))
            })();
            let (fs, bs, fr, br) = decode.map_err(|e| classify(&mut g.children, rank, e))?;
            use crate::util::json::Json;
            tracer.wall_event(
                "worker_frames",
                vec![
                    ("rank", Json::num(rank as f64)),
                    ("seq", Json::num(seq as f64)),
                    ("frames_sent", Json::num(fs as f64)),
                    ("bytes_sent", Json::num(bs as f64)),
                    ("frames_recv", Json::num(fr as f64)),
                    ("bytes_recv", Json::num(br as f64)),
                ],
            );
        }
    }

    // The wire accounting closes: every payload byte the ledger will
    // see was written to a socket by one worker AND read back off it by
    // another. A mismatch means a frame was lost or double-counted.
    if sent_intra != recv_intra || sent_inter != recv_inter {
        return Err(format!(
            "wire accounting mismatch: sent {sent_intra}+{sent_inter} bytes \
             (intra+inter) but received {recv_intra}+{recv_inter}"
        ));
    }
    Ok(HierVolume {
        intra_bytes: recv_intra as usize,
        inter_bytes: recv_inter as usize,
    })
}

/// Turn a link failure on `rank` into a distinct, actionable diagnosis:
/// child death (any dead child is named with its exit status), frame
/// corruption, blown deadline, or other I/O — the §12 error taxonomy.
fn classify(children: &mut [Child], rank: usize, e: NetError) -> String {
    let dead: Vec<String> = children
        .iter_mut()
        .enumerate()
        .filter_map(|(r, ch)| match ch.try_wait() {
            Ok(Some(status)) => Some(format!("worker {r} ({status})")),
            _ => None,
        })
        .collect();
    if !dead.is_empty() {
        return format!(
            "{} died mid-collective; the worker group was torn down and the next \
             collective will spawn a fresh one [link error: {e}]",
            dead.join(", ")
        );
    }
    if e.is_disconnect() {
        return format!(
            "worker {rank} died mid-collective (connection closed); the worker group \
             was torn down [link error: {e}]"
        );
    }
    if e.is_timeout() {
        return format!(
            "worker {rank} stalled past the TSR_NET_TIMEOUT_MS deadline mid-collective: {e}"
        );
    }
    match e {
        NetError::BadKind { .. }
        | NetError::BadLength { .. }
        | NetError::BadChecksum { .. }
        | NetError::Malformed { .. }
        | NetError::UnexpectedKind { .. } => {
            format!("corrupt frame from worker {rank}: {e}")
        }
        other => format!("worker {rank} link failed: {other}"),
    }
}

// ---------------------------------------------------------------------
// Spawn + rendezvous
// ---------------------------------------------------------------------

fn next_token() -> u64 {
    static COUNTER: AtomicU64 = AtomicU64::new(1);
    ((std::process::id() as u64) << 32) | COUNTER.fetch_add(1, Ordering::Relaxed)
}

fn spawn_group(world: usize) -> Result<ProcessGroup, String> {
    let bin = worker_binary()?;
    let listener = bind_localhost("coordinator").map_err(|e| e.to_string())?;
    let addr = listener
        .local_addr()
        .map_err(|e| format!("coordinator listener addr: {e}"))?;
    let token = next_token();

    let mut children = Vec::with_capacity(world);
    for rank in 0..world {
        let spawned = Command::new(&bin)
            .arg("_worker")
            .args(["--rank", &rank.to_string()])
            .args(["--world", &world.to_string()])
            .args(["--connect", &addr.to_string()])
            .args(["--token", &token.to_string()])
            .stdin(Stdio::null())
            // stdout stays quiet (the coordinator's own stdout may be a
            // metrics pipe); worker panics land on our stderr.
            .stdout(Stdio::null())
            .stderr(Stdio::inherit())
            .spawn();
        match spawned {
            Ok(ch) => children.push(ch),
            Err(e) => {
                kill_all(&mut children);
                return Err(format!("spawn `{} _worker` (rank {rank}): {e}", bin.display()));
            }
        }
    }

    match rendezvous(&listener, world, token) {
        Ok(ctrl) => {
            crate::obs::global().wall_event(
                "proc_spawn",
                vec![("world", crate::util::json::Json::num(world as f64))],
            );
            Ok(ProcessGroup {
                world,
                children,
                ctrl,
                seq: 0,
            })
        }
        Err(e) => {
            kill_all(&mut children);
            Err(format!("rendezvous failed: {e}"))
        }
    }
}

fn kill_all(children: &mut [Child]) {
    for ch in children.iter_mut() {
        let _ = ch.kill();
    }
    for ch in children.iter_mut() {
        let _ = ch.wait();
    }
}

/// Collect every worker's `Hello`, broadcast the peer port table, and
/// wait for all `Ready`s (sent only after a worker's full mesh is up).
fn rendezvous(
    listener: &TcpListener,
    world: usize,
    token: u64,
) -> Result<Vec<TcpStream>, NetError> {
    let mut ctrl: Vec<Option<TcpStream>> = (0..world).map(|_| None).collect();
    let mut ports = vec![0u16; world];
    for _ in 0..world {
        let what = "coordinator hello";
        let mut s = accept_deadline(listener, what)?;
        let payload = read_frame_expect(&mut s, FrameKind::Hello, what)?;
        let mut r = Reader::new(&payload, what);
        let version = r.u32("version")?;
        let got_token = r.u64("token")?;
        let rank = r.u32("rank")? as usize;
        let got_world = r.u32("world")? as usize;
        let port = r.u16("peer_port")?;
        r.finish()?;
        if version != WIRE_VERSION || got_token != token || got_world != world {
            return Err(NetError::Malformed {
                what: what.into(),
                detail: format!(
                    "hello mismatch (version {version}/{WIRE_VERSION}, token ok: {}, \
                     world {got_world}/{world}) — stale worker or foreign connection",
                    got_token == token
                ),
            });
        }
        if rank >= world || ctrl[rank].is_some() {
            return Err(NetError::Malformed {
                what: what.into(),
                detail: format!("duplicate or out-of-range hello for rank {rank}"),
            });
        }
        ports[rank] = port;
        ctrl[rank] = Some(s);
    }
    let mut streams: Vec<TcpStream> = ctrl.into_iter().map(|s| s.unwrap()).collect();

    let mut peers = Builder::new().u32(world as u32);
    for &p in &ports {
        peers = peers.u16(p);
    }
    let peers = peers.build();
    for (rank, s) in streams.iter_mut().enumerate() {
        let what = format!("coordinator peers -> worker {rank}");
        write_frame(s, FrameKind::Peers, &peers, &what)?;
    }
    for (rank, s) in streams.iter_mut().enumerate() {
        let what = format!("coordinator ready <- worker {rank}");
        let payload = read_frame_expect(s, FrameKind::Ready, &what)?;
        let mut r = Reader::new(&payload, &what);
        let got = r.u32("rank")? as usize;
        r.finish()?;
        if got != rank {
            return Err(NetError::Malformed {
                what,
                detail: format!("ready from rank {got} on rank {rank}'s control stream"),
            });
        }
    }
    Ok(streams)
}
