//! Child side of the process backend: the hidden `tsr _worker`
//! subcommand (DESIGN.md §12).
//!
//! A worker is one OS process per simulated data-parallel worker. Its
//! life: connect back to the coordinator, rendezvous into a full TCP
//! mesh with its peers, then serve collectives until the coordinator
//! says `Shutdown` — or until the control socket reports EOF, which
//! means the coordinator process died and the worker must exit rather
//! than linger as an orphan.
//!
//! The ring all-reduce here is the **push form** of the exact schedule
//! `comm::collective` runs sequentially and `exec::threaded` runs over
//! shared memory: at reduce-scatter step `s`, group position `i` sends
//! chunk `(i − s) mod m` to its successor and accumulates the chunk
//! `(pred − s) mod m` it receives from its predecessor, elementwise in
//! index order; the all-gather leg circulates chunks `(i + 1 − s) mod
//! m`. Identical chunk boundaries ([`crate::exec::chunk_starts`]),
//! identical per-element addition order, identical final `1/n` scale —
//! so the result is bitwise-identical to the sequential backend, the
//! same argument that carries the threaded backend's parity contract.
//!
//! Deadlock freedom: every peer link gets a dedicated writer thread fed
//! by an unbounded channel, so the main thread's sends never block on a
//! full kernel buffer while its peer is itself blocked sending — the
//! classic ring deadlock. Receives stay on the main thread with the
//! socket's read deadline, so a dead or wedged peer surfaces as a
//! distinct error within `TSR_NET_TIMEOUT_MS` instead of a hang.

use crate::comm::ElemFmt;
use crate::exec::chunk_starts;
use crate::net::{
    accept_deadline, bind_localhost, connect_peer, read_frame, read_frame_expect, write_frame,
    Builder, Frame, FrameKind, NetError, Reader, WIRE_VERSION,
};
use crate::util::cli::Args;
use std::net::{SocketAddr, TcpStream};
use std::sync::mpsc;

/// Exit code a worker uses when the coordinator's fault-injection flag
/// tells it to die mid-collective (test-only; DESIGN.md §12).
pub const FAULT_EXIT_CODE: i32 = 113;

/// One mesh link to a peer worker: reads happen on the main thread via
/// `rx` (the socket's read deadline applies); writes are queued to a
/// dedicated writer thread via `tx` so sends never block the ring.
struct Link {
    rx: TcpStream,
    tx: mpsc::Sender<Vec<u8>>,
    /// Cumulative wire totals (frames and encoded bytes, headers
    /// included) for the observability wall tier — shipped back in a
    /// `Trace` frame only when the coordinator asks (DESIGN.md §16).
    stats: std::cell::Cell<LinkStats>,
}

/// Per-link wire totals; `Cell`-wrapped because sends/receives happen on
/// the single main thread.
#[derive(Clone, Copy, Default)]
struct LinkStats {
    frames_sent: u64,
    bytes_sent: u64,
    frames_recv: u64,
    bytes_recv: u64,
}

impl Link {
    fn new(stream: TcpStream, what: &str) -> Result<Self, NetError> {
        let mut wr = stream
            .try_clone()
            .map_err(|e| NetError::from_io(what, e))?;
        let (tx, rx_q) = mpsc::channel::<Vec<u8>>();
        std::thread::spawn(move || {
            // A failed write means the peer is gone; the main thread
            // will hit its own loud read error on the next ring step,
            // so the writer just drains and exits.
            while let Ok(bytes) = rx_q.recv() {
                use std::io::Write as _;
                if wr.write_all(&bytes).is_err() {
                    break;
                }
            }
        });
        Ok(Self {
            rx: stream,
            tx,
            stats: std::cell::Cell::new(LinkStats::default()),
        })
    }

    /// Ship one ring chunk at the element format's wire width: every
    /// value here is already fmt-representable (the schedule re-rounds
    /// after each accumulation), so the narrow encoding is lossless.
    fn send_chunk(&self, chunk: &[f32], fmt: ElemFmt, what: &str) -> Result<(), NetError> {
        let mut payload = Vec::with_capacity(chunk.len() * fmt.width());
        fmt.write_elems(&mut payload, chunk);
        let frame = crate::net::encode_frame(FrameKind::Data, &payload);
        let mut st = self.stats.get();
        st.frames_sent += 1;
        st.bytes_sent += frame.len() as u64;
        self.stats.set(st);
        self.tx
            .send(frame)
            .map_err(|_| NetError::Disconnected {
                what: what.to_string(),
                detail: "peer writer thread exited".into(),
            })
    }

    fn recv_chunk(&mut self, out: &mut [f32], fmt: ElemFmt, what: &str) -> Result<(), NetError> {
        let payload = read_frame_expect(&mut self.rx, FrameKind::Data, what)?;
        let mut st = self.stats.get();
        st.frames_recv += 1;
        st.bytes_recv += (payload.len() + crate::net::HEADER_BYTES) as u64;
        self.stats.set(st);
        if payload.len() != out.len() * fmt.width() {
            return Err(NetError::Malformed {
                what: what.to_string(),
                detail: format!(
                    "ring chunk carries {} bytes, schedule expects {}",
                    payload.len(),
                    out.len() * fmt.width()
                ),
            });
        }
        fmt.read_elems(&payload, out).map_err(|detail| NetError::Malformed {
            what: what.to_string(),
            detail,
        })
    }
}

/// Wire-byte counters one worker reports back per collective, payload
/// bytes only (frame headers excluded — the ledger meters the simulated
/// collective's data movement, exactly like the other backends).
#[derive(Default)]
struct Counters {
    sent_intra: u64,
    sent_inter: u64,
    recv_intra: u64,
    recv_inter: u64,
}

/// Entry point for `tsr _worker` — never returns.
pub fn worker_main(args: &Args) -> ! {
    let need = |key: &str| -> String {
        args.get(key).map(str::to_string).unwrap_or_else(|| {
            crate::tsr_error!("tsr _worker: missing required --{key} (internal subcommand)");
            std::process::exit(2);
        })
    };
    let rank: usize = need("rank").parse().unwrap_or_else(|_| {
        crate::tsr_error!("tsr _worker: --rank must be an integer");
        std::process::exit(2);
    });
    let world: usize = need("world").parse().unwrap_or_else(|_| {
        crate::tsr_error!("tsr _worker: --world must be an integer");
        std::process::exit(2);
    });
    let addr: SocketAddr = need("connect").parse().unwrap_or_else(|_| {
        crate::tsr_error!("tsr _worker: --connect must be a socket address");
        std::process::exit(2);
    });
    let token: u64 = need("token").parse().unwrap_or_else(|_| {
        crate::tsr_error!("tsr _worker: --token must be an integer");
        std::process::exit(2);
    });
    match run(rank, world, addr, token) {
        Ok(()) => std::process::exit(0),
        Err(e) => {
            crate::tsr_error!("tsr _worker rank {rank}/{world}: {e}");
            std::process::exit(1);
        }
    }
}

fn run(rank: usize, world: usize, addr: SocketAddr, token: u64) -> Result<(), NetError> {
    let what = format!("worker {rank} control");
    let mut ctrl = connect_peer(addr, &what)?;

    // Rendezvous: open a peer listener, tell the coordinator its port,
    // learn everyone else's, and form the full mesh — lower ranks are
    // dialed, higher ranks dial us and identify themselves by PeerHello.
    let listener = bind_localhost(&what)?;
    let my_port = listener
        .local_addr()
        .map_err(|e| NetError::from_io(&what, e))?
        .port();
    let hello = Builder::new()
        .u32(WIRE_VERSION)
        .u64(token)
        .u32(rank as u32)
        .u32(world as u32)
        .u16(my_port)
        .build();
    write_frame(&mut ctrl, FrameKind::Hello, &hello, &what)?;

    let peers_payload = read_frame_expect(&mut ctrl, FrameKind::Peers, &what)?;
    let mut r = Reader::new(&peers_payload, &what);
    let peer_world = r.u32("world")? as usize;
    if peer_world != world {
        return Err(NetError::Malformed {
            what: what.clone(),
            detail: format!("coordinator says world={peer_world}, spawned with --world {world}"),
        });
    }
    let mut ports = vec![0u16; world];
    for p in ports.iter_mut() {
        *p = r.u16("peer_port")?;
    }
    r.finish()?;

    let mut links: Vec<Option<Link>> = (0..world).map(|_| None).collect();
    for (peer, &port) in ports.iter().enumerate().take(rank) {
        let link_what = format!("worker {rank} -> peer {peer}");
        let mut s = connect_peer(SocketAddr::from(([127, 0, 0, 1], port)), &link_what)?;
        let ph = Builder::new().u64(token).u32(rank as u32).build();
        write_frame(&mut s, FrameKind::PeerHello, &ph, &link_what)?;
        links[peer] = Some(Link::new(s, &link_what)?);
    }
    for _ in rank + 1..world {
        let accept_what = format!("worker {rank} peer accept");
        let mut s = accept_deadline(&listener, &accept_what)?;
        let ph = read_frame_expect(&mut s, FrameKind::PeerHello, &accept_what)?;
        let mut r = Reader::new(&ph, &accept_what);
        let peer_token = r.u64("token")?;
        let peer = r.u32("rank")? as usize;
        r.finish()?;
        if peer_token != token || peer <= rank || peer >= world || links[peer].is_some() {
            return Err(NetError::Malformed {
                what: accept_what,
                detail: format!("bogus peer hello (rank {peer}, token match: {})", peer_token == token),
            });
        }
        links[peer] = Some(Link::new(s, &format!("worker {rank} <- peer {peer}"))?);
    }
    drop(listener);

    let ready = Builder::new().u32(rank as u32).build();
    write_frame(&mut ctrl, FrameKind::Ready, &ready, "worker ready")?;

    // Serve collectives until Shutdown (or coordinator death = EOF).
    let mut buf: Vec<f32> = Vec::new();
    let mut scratch: Vec<f32> = Vec::new();
    loop {
        let frame = match wait_frame(&mut ctrl, rank)? {
            None => return Ok(()), // coordinator gone: exit quietly
            Some(f) => f,
        };
        match frame.kind {
            FrameKind::Shutdown => return Ok(()),
            FrameKind::Collective => {
                serve_collective(rank, world, &frame, &mut ctrl, &mut links, &mut buf, &mut scratch)?
            }
            other => {
                return Err(NetError::UnexpectedKind {
                    what: format!("worker {rank} control"),
                    expect: FrameKind::Collective,
                    got: other,
                })
            }
        }
    }
}

/// Idle-wait for the next control frame without tripping the read
/// deadline: `peek` consumes nothing, so looping on its timeout cannot
/// desynchronize the frame stream the way a timed-out partial
/// `read_exact` would. EOF here means the coordinator died — the worker
/// exits cleanly instead of becoming an orphan.
fn wait_frame(ctrl: &mut TcpStream, rank: usize) -> Result<Option<Frame>, NetError> {
    let what = format!("worker {rank} control");
    let mut probe = [0u8; 1];
    loop {
        match ctrl.peek(&mut probe) {
            Ok(0) => return Ok(None),
            Ok(_) => return read_frame(ctrl, &what).map(Some),
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                continue
            }
            Err(e) => {
                let ne = NetError::from_io(&what, e);
                if ne.is_disconnect() {
                    return Ok(None);
                }
                return Err(ne);
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn serve_collective(
    rank: usize,
    world: usize,
    frame: &Frame,
    ctrl: &mut TcpStream,
    links: &mut [Option<Link>],
    buf: &mut Vec<f32>,
    scratch: &mut Vec<f32>,
) -> Result<(), NetError> {
    let what = format!("worker {rank} collective");
    let mut r = Reader::new(&frame.payload, &what);
    let seq = r.u64("seq")?;
    let nodes = r.u32("nodes")? as usize;
    let g = r.u32("gpus_per_node")? as usize;
    let numel = r.u64("numel")? as usize;
    let inject_fault = r.u8("inject_fault")?;
    let fmt = ElemFmt::from_wire_tag(r.u8("elem_fmt")?).map_err(|detail| NetError::Malformed {
        what: what.clone(),
        detail,
    })?;
    let want_trace = r.u8("trace")? != 0;
    if nodes * g != world {
        return Err(NetError::Malformed {
            what: what.clone(),
            detail: format!("collective shape {nodes}x{g} does not tile world {world}"),
        });
    }
    buf.resize(numel, 0.0);
    scratch.resize(numel, 0.0);
    r.f32s_into(buf, "payload")?;
    r.finish()?;

    if inject_fault != 0 {
        // Test-only chaos: die exactly mid-collective, after accepting
        // the request — peers are now blocked on our chunks, which is
        // the failure the coordinator must detect and classify.
        crate::tsr_error!("tsr _worker rank {rank}: fault injection — exiting mid-collective");
        std::process::exit(FAULT_EXIT_CODE);
    }

    let before: LinkStats = if want_trace {
        link_totals(links)
    } else {
        LinkStats::default()
    };
    let c = allreduce(rank, nodes, g, fmt, buf, scratch, links)?;

    let result = Builder::new()
        .u64(seq)
        .u64(c.sent_intra)
        .u64(c.sent_inter)
        .u64(c.recv_intra)
        .u64(c.recv_inter)
        .f32s(buf)
        .build();
    write_frame(ctrl, FrameKind::Result, &result, &what)?;

    if want_trace {
        // Wall-tier wire totals for this collective: Data frame counts
        // and encoded bytes (headers included — unlike the Result
        // counters, which meter payload only).
        let after = link_totals(links);
        let trace = Builder::new()
            .u64(seq)
            .u64(after.frames_sent - before.frames_sent)
            .u64(after.bytes_sent - before.bytes_sent)
            .u64(after.frames_recv - before.frames_recv)
            .u64(after.bytes_recv - before.bytes_recv)
            .build();
        write_frame(ctrl, FrameKind::Trace, &trace, &what)?;
    }
    Ok(())
}

/// Sum the per-link wire totals across the mesh.
fn link_totals(links: &[Option<Link>]) -> LinkStats {
    let mut t = LinkStats::default();
    for l in links.iter().flatten() {
        let s = l.stats.get();
        t.frames_sent += s.frames_sent;
        t.bytes_sent += s.bytes_sent;
        t.frames_recv += s.frames_recv;
        t.bytes_recv += s.bytes_recv;
    }
    t
}

/// The two-level hierarchical all-reduce (average), socket-ring push
/// form — phase-for-phase the schedule of `exec::threaded::
/// worker_thread`, with message arrival standing in for the barriers
/// (a chunk can only be received after its sender finished producing
/// it, which is exactly the ordering the barriers enforced).
fn allreduce(
    rank: usize,
    nodes: usize,
    g: usize,
    fmt: ElemFmt,
    buf: &mut [f32],
    scratch: &mut [f32],
    links: &mut [Option<Link>],
) -> Result<Counters, NetError> {
    let n = nodes * g;
    let numel = buf.len();
    let mut c = Counters::default();
    if n > 1 {
        if nodes == 1 || g == 1 {
            // Flat ring over everyone on the single link class.
            let group: Vec<usize> = (0..n).collect();
            let (s1, r1) = ring_reduce_scatter(rank, &group, 0, numel, fmt, buf, scratch, links)?;
            let (s2, r2) = ring_all_gather(rank, &group, 0, numel, fmt, buf, scratch, links)?;
            if nodes == 1 {
                c.sent_intra += (s1 + s2) as u64;
                c.recv_intra += (r1 + r2) as u64;
            } else {
                c.sent_inter += (s1 + s2) as u64;
                c.recv_inter += (r1 + r2) as u64;
            }
        } else {
            let node = rank / g;
            let local = rank % g;
            let intra_group: Vec<usize> = (0..g).map(|j| node * g + j).collect();
            // Phase 1: intra-node ring reduce-scatter.
            let (s, r) =
                ring_reduce_scatter(local, &intra_group, 0, numel, fmt, buf, scratch, links)?;
            c.sent_intra += s as u64;
            c.recv_intra += r as u64;
            // Phase 2: local index i owns chunk (i+1) % g after phase 1;
            // run one cross-node ring over that chunk.
            let chunk = (local + 1) % g;
            let starts = chunk_starts(0, numel, g);
            let inter_group: Vec<usize> = (0..nodes).map(|nd| nd * g + local).collect();
            let (clo, chi) = (starts[chunk], starts[chunk + 1]);
            let (s, r) =
                ring_reduce_scatter(node, &inter_group, clo, chi, fmt, buf, scratch, links)?;
            c.sent_inter += s as u64;
            c.recv_inter += r as u64;
            let (s, r) = ring_all_gather(node, &inter_group, clo, chi, fmt, buf, scratch, links)?;
            c.sent_inter += s as u64;
            c.recv_inter += r as u64;
            // Phase 3: intra-node all-gather broadcasts the global chunks.
            let (s, r) = ring_all_gather(local, &intra_group, 0, numel, fmt, buf, scratch, links)?;
            c.sent_intra += s as u64;
            c.recv_intra += r as u64;
        }
    }
    // Same final scale as the sequential/threaded backends: each worker
    // multiplies its own buffer by the f32 1/n once, after all rings.
    let inv = 1.0 / n as f32;
    for v in buf.iter_mut() {
        *v *= inv;
    }
    Ok(c)
}

/// Ring reduce-scatter (sum) over `group` from group position `pos`,
/// push form. Returns `(sent, received)` payload bytes at the element
/// format's wire width. Each received chunk is accumulated then
/// re-rounded to `fmt` — the same schedule point as the sequential
/// backend — so every value a later hop ships is fmt-representable.
/// Zero-length ragged chunks are skipped symmetrically (no frame).
fn ring_reduce_scatter(
    pos: usize,
    group: &[usize],
    lo: usize,
    hi: usize,
    fmt: ElemFmt,
    buf: &mut [f32],
    scratch: &mut [f32],
    links: &mut [Option<Link>],
) -> Result<(usize, usize), NetError> {
    let m = group.len();
    if m <= 1 {
        return Ok((0, 0));
    }
    let starts = chunk_starts(lo, hi, m);
    let succ = group[(pos + 1) % m];
    let pred_pos = (pos + m - 1) % m;
    let pred = group[pred_pos];
    let (mut sent, mut recvd) = (0usize, 0usize);
    for step in 0..m - 1 {
        // Send chunk (pos − step) mod m to the successor…
        let cs = (pos + m - step) % m;
        let (slo, shi) = (starts[cs], starts[cs + 1]);
        if shi > slo {
            link(links, succ)?.send_chunk(&buf[slo..shi], fmt, "ring rs send")?;
            sent += (shi - slo) * fmt.width();
        }
        // …and accumulate chunk (pred − step) mod m from the
        // predecessor, elementwise in index order (the sequential
        // backend's exact addition order for this element).
        let cr = (pred_pos + m - step) % m;
        let (rlo, rhi) = (starts[cr], starts[cr + 1]);
        if rhi > rlo {
            let tmp = &mut scratch[..rhi - rlo];
            link(links, pred)?.recv_chunk(tmp, fmt, "ring rs recv")?;
            for (d, s) in buf[rlo..rhi].iter_mut().zip(tmp.iter()) {
                *d += *s;
            }
            fmt.round_slice(&mut buf[rlo..rhi]);
            recvd += (rhi - rlo) * fmt.width();
        }
    }
    Ok((sent, recvd))
}

/// Ring all-gather over `group`, push form, assuming the ownership
/// layout [`ring_reduce_scatter`] produces. Chunks here are already
/// fmt-representable, so circulation is a lossless copy. Returns
/// `(sent, received)` payload bytes at the wire width.
fn ring_all_gather(
    pos: usize,
    group: &[usize],
    lo: usize,
    hi: usize,
    fmt: ElemFmt,
    buf: &mut [f32],
    scratch: &mut [f32],
    links: &mut [Option<Link>],
) -> Result<(usize, usize), NetError> {
    let m = group.len();
    if m <= 1 {
        return Ok((0, 0));
    }
    let starts = chunk_starts(lo, hi, m);
    let succ = group[(pos + 1) % m];
    let pred_pos = (pos + m - 1) % m;
    let pred = group[pred_pos];
    let (mut sent, mut recvd) = (0usize, 0usize);
    for step in 0..m - 1 {
        let cs = (pos + 1 + m - step) % m;
        let (slo, shi) = (starts[cs], starts[cs + 1]);
        if shi > slo {
            link(links, succ)?.send_chunk(&buf[slo..shi], fmt, "ring ag send")?;
            sent += (shi - slo) * fmt.width();
        }
        let cr = (pred_pos + 1 + m - step) % m;
        let (rlo, rhi) = (starts[cr], starts[cr + 1]);
        if rhi > rlo {
            let tmp = &mut scratch[..rhi - rlo];
            link(links, pred)?.recv_chunk(tmp, fmt, "ring ag recv")?;
            buf[rlo..rhi].copy_from_slice(tmp);
            recvd += (rhi - rlo) * fmt.width();
        }
    }
    Ok((sent, recvd))
}

fn link(links: &mut [Option<Link>], peer: usize) -> Result<&mut Link, NetError> {
    links[peer].as_mut().ok_or_else(|| NetError::Malformed {
        what: "ring".into(),
        detail: format!("no mesh link to peer {peer}"),
    })
}
