//! Rendezvous ring collectives over real OS threads.
//!
//! One thread per simulated worker; each thread owns its worker's
//! buffer. A ring step is a *pull*: every thread reads the chunk its
//! ring predecessor is sending and reduces (or copies) it into its own
//! buffer, then all threads meet at a [`Barrier`] before the next step.
//! The chunk schedule is exactly the sequential one in
//! `comm::collective` — position `i` sends chunk `(i − s) mod m` at
//! reduce-scatter step `s` and chunk `(i + 1 − s) mod m` at all-gather
//! step `s` — so every buffer element receives the same additions in
//! the same order as the sequential backend and the result is bitwise
//! identical (see the determinism contract in [`super`]).
//!
//! Safety model: threads address each other's buffers through raw
//! pointers, but within any barrier-delimited step each buffer is
//! written only by its owner (the chunk it receives) and read only at a
//! *different* chunk (the one it sends) — ranges are disjoint, and the
//! barrier's happens-before edge publishes each step's writes to the
//! next step's readers. No locks, no atomics on the data path.
//!
//! Wire metering is *measured*, not computed: each thread counts the
//! bytes it actually pulled across the thread boundary, and the summed
//! counters are what `sync_mean` records in the ledger's intra/inter
//! columns. `hier_volume_matches_sequential_closed_form` (below) and
//! `tests/exec_parity.rs` pin these measurements to the analytic
//! `2(w−1)/w` decomposition.

use crate::comm::collective::HierVolume;
use crate::comm::ElemFmt;
use crate::linalg::Matrix;
use std::sync::Barrier;

/// Per-worker base pointers into the (equally shaped) worker buffers.
struct SharedBufs {
    ptrs: Vec<*mut f32>,
    numel: usize,
}

// SAFETY: the raw pointers are only dereferenced under the disjointness
// discipline described in the module docs; the barrier provides the
// required happens-before edges between steps.
unsafe impl Sync for SharedBufs {}

use crate::exec::chunk_starts;

/// Two-level hierarchical all-reduce (average) run by one OS thread per
/// worker. Same layout contract as `collective::hier_allreduce_mean`:
/// worker `w` lives on node `w / gpus_per_node`. Degenerate shapes
/// (`nodes == 1` or `gpus_per_node == 1`) collapse to a flat ring on
/// the corresponding link class, exactly like the sequential schedule.
///
/// Returns the aggregate wire bytes per link class, measured from the
/// chunks each thread pulled from its ring predecessor.
pub fn allreduce_mean(workers: &mut [Matrix], nodes: usize, gpus_per_node: usize) -> HierVolume {
    allreduce_mean_fmt(workers, nodes, gpus_per_node, ElemFmt::F32)
}

/// [`allreduce_mean`] in a typed element format: the rendezvous rings
/// re-round each pulled-and-reduced chunk exactly where the sequential
/// reference does (DESIGN.md §14), and the measured wire counters are
/// `fmt.width()` bytes/element — the thread-boundary analogue of the
/// process backend's narrow socket frames.
pub fn allreduce_mean_fmt(
    workers: &mut [Matrix],
    nodes: usize,
    gpus_per_node: usize,
    fmt: ElemFmt,
) -> HierVolume {
    let n = workers.len();
    assert!(n > 0);
    assert_eq!(n, nodes * gpus_per_node, "topology shape mismatch");
    let numel = workers[0].numel();
    for w in workers.iter() {
        assert_eq!(w.numel(), numel, "ragged all-reduce");
    }
    if n == 1 {
        return HierVolume::default();
    }
    let bufs = SharedBufs {
        ptrs: workers.iter_mut().map(|m| m.data.as_mut_ptr()).collect(),
        numel,
    };
    let barrier = Barrier::new(n);
    let mut volumes: Vec<(usize, usize)> = Vec::with_capacity(n);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n)
            .map(|me| {
                let bufs = &bufs;
                let barrier = &barrier;
                scope.spawn(move || worker_thread(me, bufs, barrier, nodes, gpus_per_node, fmt))
            })
            .collect();
        volumes = handles
            .into_iter()
            .map(|h| h.join().expect("collective worker thread panicked"))
            .collect();
    });
    let (intra, inter) = volumes
        .iter()
        .fold((0, 0), |(a, b), (x, y)| (a + x, b + y));
    // Wall tier only (dropped unless `--trace-wall`): per-collective
    // thread fan-out, the threaded analogue of the process backend's
    // `worker_frames` records.
    crate::obs::global().wall_event(
        "thread_collective",
        vec![
            ("threads", crate::util::json::Json::num(n as f64)),
            ("numel", crate::util::json::Json::num(numel as f64)),
        ],
    );
    HierVolume {
        intra_bytes: intra,
        inter_bytes: inter,
    }
}

/// One worker's life: the phase schedule of the hierarchical (or
/// degenerate flat) all-reduce, then scale its own buffer to the mean.
/// Every thread executes the same number of barrier waits in the same
/// order — the phase step counts depend only on (nodes, g).
fn worker_thread(
    me: usize,
    bufs: &SharedBufs,
    barrier: &Barrier,
    nodes: usize,
    g: usize,
    fmt: ElemFmt,
) -> (usize, usize) {
    let n = nodes * g;
    let numel = bufs.numel;
    let mut intra = 0usize;
    let mut inter = 0usize;

    if nodes == 1 || g == 1 {
        // Flat ring over everyone, attributed to the single link class.
        let group: Vec<usize> = (0..n).collect();
        let wire = ring_reduce_scatter(me, &group, 0, numel, bufs, barrier, fmt)
            + ring_all_gather(me, &group, 0, numel, bufs, barrier, fmt);
        if nodes == 1 {
            intra = wire;
        } else {
            inter = wire;
        }
    } else {
        let node = me / g;
        let local = me % g;
        let intra_group: Vec<usize> = (0..g).map(|j| node * g + j).collect();
        // Phase 1: intra-node ring reduce-scatter (all nodes' rings run
        // concurrently on disjoint buffers).
        intra += ring_reduce_scatter(local, &intra_group, 0, numel, bufs, barrier, fmt);
        // Phase 2: after phase 1 local index i owns chunk (i+1) % g, so
        // each thread runs exactly one cross-node ring over its chunk.
        let chunk = (local + 1) % g;
        let starts = chunk_starts(0, numel, g);
        let inter_group: Vec<usize> = (0..nodes).map(|nd| nd * g + local).collect();
        let (clo, chi) = (starts[chunk], starts[chunk + 1]);
        inter += ring_reduce_scatter(node, &inter_group, clo, chi, bufs, barrier, fmt);
        inter += ring_all_gather(node, &inter_group, clo, chi, bufs, barrier, fmt);
        // Phase 3: intra-node all-gather broadcasts the global chunks.
        intra += ring_all_gather(local, &intra_group, 0, numel, bufs, barrier, fmt);
    }

    // All pulls done everywhere; now each thread owns its buffer alone.
    barrier.wait();
    // SAFETY: after the final barrier no other thread touches buffer
    // `me` again; `me` is this thread's exclusive index.
    let own = unsafe { std::slice::from_raw_parts_mut(bufs.ptrs[me], numel) };
    let inv = 1.0 / n as f32;
    for v in own {
        *v *= inv;
    }
    (intra, inter)
}

/// Ring reduce-scatter (sum) over `group`, pull form, from the
/// perspective of the thread at group position `pos`. Element range
/// [lo, hi) splits into `m` chunks at `lo + c·len/m` — identical
/// boundaries to the sequential primitive. Returns bytes pulled.
fn ring_reduce_scatter(
    pos: usize,
    group: &[usize],
    lo: usize,
    hi: usize,
    bufs: &SharedBufs,
    barrier: &Barrier,
    fmt: ElemFmt,
) -> usize {
    let m = group.len();
    if m <= 1 {
        return 0;
    }
    let starts = chunk_starts(lo, hi, m);
    let pred = (pos + m - 1) % m;
    let mut pulled = 0usize;
    for step in 0..m - 1 {
        // Sequential schedule: position `pred` sends chunk (pred − step)
        // mod m to `pos` — we pull it and reduce in place.
        let c = (pred + m - step) % m;
        let (clo, chi) = (starts[c], starts[c + 1]);
        // SAFETY: during this step, buffer group[pred] is written only
        // by its owner at chunk (pred − 1 − step) mod m ≠ c, and buffer
        // group[pos] is read only by its successor at chunk
        // (pos − step) mod m ≠ c; both ranges are disjoint from [clo,
        // chi). The barrier below sequences steps.
        unsafe {
            let src = std::slice::from_raw_parts(bufs.ptrs[group[pred]].add(clo), chi - clo);
            let dst = std::slice::from_raw_parts_mut(bufs.ptrs[group[pos]].add(clo), chi - clo);
            for (d, s) in dst.iter_mut().zip(src.iter()) {
                *d += *s;
            }
            // Narrow formats re-round after the addition — the same hop
            // point where the sequential reference rounds, so sums stay
            // bitwise backend-invariant.
            fmt.round_slice(dst);
        }
        pulled += chi - clo;
        barrier.wait();
    }
    pulled * fmt.width()
}

/// Ring all-gather over `group`, pull form, assuming the ownership
/// layout `ring_reduce_scatter` produces. Returns bytes pulled.
fn ring_all_gather(
    pos: usize,
    group: &[usize],
    lo: usize,
    hi: usize,
    bufs: &SharedBufs,
    barrier: &Barrier,
    fmt: ElemFmt,
) -> usize {
    let m = group.len();
    if m <= 1 {
        return 0;
    }
    let starts = chunk_starts(lo, hi, m);
    let pred = (pos + m - 1) % m;
    let mut pulled = 0usize;
    for step in 0..m - 1 {
        let c = (pred + 1 + m - step) % m;
        let (clo, chi) = (starts[c], starts[c + 1]);
        // SAFETY: same disjointness argument as the reduce-scatter —
        // owner writes chunk (pred − step) mod m ≠ c this step.
        unsafe {
            let src = std::slice::from_raw_parts(bufs.ptrs[group[pred]].add(clo), chi - clo);
            let dst = std::slice::from_raw_parts_mut(bufs.ptrs[group[pos]].add(clo), chi - clo);
            dst.copy_from_slice(src);
        }
        pulled += chi - clo;
        barrier.wait();
    }
    pulled * fmt.width()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::collective::{hier_allreduce_mean, hier_volume_bytes, ring_allreduce_mean};
    use crate::util::prop;
    use crate::util::rng::Xoshiro256;

    fn bits(ws: &[Matrix]) -> Vec<Vec<u32>> {
        ws.iter()
            .map(|w| w.data.iter().map(|v| v.to_bits()).collect())
            .collect()
    }

    #[test]
    fn flat_ring_is_bitwise_identical_to_sequential() {
        prop::check("threaded flat == sequential", 20, |rng| {
            let n = prop::dim(rng, 2, 9);
            let r = prop::dim(rng, 1, 13);
            let c = prop::dim(rng, 1, 13);
            let mut ws: Vec<Matrix> = (0..n).map(|_| Matrix::gaussian(r, c, 1.0, rng)).collect();
            let mut seq = ws.clone();
            let vol = allreduce_mean(&mut ws, 1, n);
            ring_allreduce_mean(&mut seq);
            assert_eq!(bits(&ws), bits(&seq), "n={n} {r}x{c}");
            assert_eq!(vol, hier_volume_bytes(r * c, 1, n));
        });
    }

    #[test]
    fn hier_is_bitwise_identical_to_sequential() {
        prop::check("threaded hier == sequential", 16, |rng| {
            let nodes = prop::dim(rng, 1, 4);
            let g = prop::dim(rng, 1, 4);
            if nodes * g < 2 {
                return;
            }
            let r = prop::dim(rng, 1, 11);
            let c = prop::dim(rng, 1, 11);
            let mut ws: Vec<Matrix> = (0..nodes * g)
                .map(|_| Matrix::gaussian(r, c, 1.0, rng))
                .collect();
            let mut seq = ws.clone();
            let vol = allreduce_mean(&mut ws, nodes, g);
            let seq_vol = hier_allreduce_mean(&mut seq, nodes, g);
            assert_eq!(bits(&ws), bits(&seq), "{nodes}x{g} {r}x{c}");
            assert_eq!(vol, seq_vol, "{nodes}x{g}");
        });
    }

    #[test]
    fn hier_volume_matches_sequential_closed_form() {
        // Ragged numel on purpose: measured pulls must still sum to the
        // exact aggregate decomposition.
        let numel = 37;
        let mut rng = Xoshiro256::new(8);
        for (nodes, g) in [(2usize, 3usize), (3, 2), (4, 4), (1, 5), (5, 1)] {
            let mut ws: Vec<Matrix> = (0..nodes * g)
                .map(|_| Matrix::gaussian(1, numel, 1.0, &mut rng))
                .collect();
            let vol = allreduce_mean(&mut ws, nodes, g);
            assert_eq!(vol, hier_volume_bytes(numel, nodes, g), "{nodes}x{g}");
        }
    }

    #[test]
    fn ragged_chunks_single_element_and_tiny_payloads() {
        // numel < workers: some ring chunks are empty — the schedule
        // must still terminate and agree with the sequential backend.
        for numel in [1usize, 2, 3] {
            let mut rng = Xoshiro256::new(numel as u64);
            let mut ws: Vec<Matrix> = (0..4)
                .map(|_| Matrix::gaussian(1, numel, 1.0, &mut rng))
                .collect();
            let mut seq = ws.clone();
            let vol = allreduce_mean(&mut ws, 2, 2);
            let seq_vol = hier_allreduce_mean(&mut seq, 2, 2);
            assert_eq!(bits(&ws), bits(&seq), "numel={numel}");
            assert_eq!(vol, seq_vol, "numel={numel}");
        }
    }

    #[test]
    fn narrow_formats_are_bitwise_identical_to_sequential() {
        use crate::comm::collective::hier_allreduce_mean_fmt;
        for fmt in [ElemFmt::Bf16, ElemFmt::I8] {
            prop::check(&format!("threaded {} == sequential", fmt.name()), 12, |rng| {
                let nodes = prop::dim(rng, 1, 3);
                let g = prop::dim(rng, 1, 3);
                if nodes * g < 2 {
                    return;
                }
                let r = prop::dim(rng, 1, 9);
                let c = prop::dim(rng, 1, 9);
                let mut ws: Vec<Matrix> = (0..nodes * g)
                    .map(|_| {
                        let mut m = Matrix::gaussian(r, c, 0.5, rng);
                        fmt.round_slice(&mut m.data);
                        m
                    })
                    .collect();
                let mut seq = ws.clone();
                let vol = allreduce_mean_fmt(&mut ws, nodes, g, fmt);
                let seq_vol = hier_allreduce_mean_fmt(&mut seq, nodes, g, fmt);
                assert_eq!(bits(&ws), bits(&seq), "{nodes}x{g} {r}x{c} {}", fmt.name());
                assert_eq!(vol, seq_vol, "{nodes}x{g} {}", fmt.name());
                // Width-true measured wire volume.
                let f32_vol = hier_volume_bytes(r * c, nodes, g);
                assert_eq!(vol.total() * 4, f32_vol.total() * fmt.width(), "{nodes}x{g}");
            });
        }
    }

    #[test]
    fn single_worker_is_a_no_op() {
        let mut ws = vec![Matrix::from_vec(1, 3, vec![1.0, 2.0, 3.0])];
        let vol = allreduce_mean(&mut ws, 1, 1);
        assert_eq!(vol, HierVolume::default());
        assert_eq!(ws[0].data, vec![1.0, 2.0, 3.0]);
    }
}
