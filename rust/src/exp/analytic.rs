//! Closed-form communication profiles (paper §3.2 applied to each method).
//!
//! Bytes/Step and PeakBytes are counting identities over the model's
//! block shapes — independent of data, hardware, and training dynamics —
//! so we reproduce Tables 1 and 3's byte columns *exactly* from these
//! formulas, and cross-check the simulated optimizers against them in
//! integration tests.
//!
//! Exactness contract: every profile's `bytes_per_step` is computed as an
//! *integer* byte total over one full refresh period divided once by the
//! period length — the identical f64 operation `CommLedger::bytes_per_step`
//! performs over a run of exactly one period. Integration tests therefore
//! assert bit-for-bit equality between metered and analytic bytes, for
//! every method (`simulated_bytes_match_analytic_profiles`).

use crate::comm::{ElemFmt, LayerClass, BYTES_F32};
use crate::model::{BlockSpec, ModelSpec};
use crate::optim::sign_adam::sign_payload_bytes;
use crate::optim::topk_adam::{topk_elems, topk_payload_bytes};

fn gcd(a: u64, b: u64) -> u64 {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

/// Least common multiple of two refresh intervals (the ledger-matching
/// averaging period for methods with two schedules).
pub fn lcm(a: u64, b: u64) -> u64 {
    a / gcd(a, b) * b
}

#[derive(Clone, Debug)]
pub struct CommProfile {
    pub bytes_per_step: f64,
    pub peak_bytes: f64,
    /// (embedding, linear, vector) steady-state element split per step.
    pub split: (f64, f64, f64),
}

/// Dense AdamW: every parameter, every step.
pub fn adamw_profile(spec: &ModelSpec) -> CommProfile {
    let mut split = (0f64, 0f64, 0f64);
    for b in spec.blocks() {
        add_split(&mut split, b.class, b.numel() as f64);
    }
    let total = (split.0 + split.1 + split.2) * BYTES_F32 as f64;
    CommProfile {
        bytes_per_step: total,
        peak_bytes: total,
        split,
    }
}

/// GaLore-style one-sided: linear blocks sync the r×(long dim) projected
/// gradient; refresh (every K) adds the FULL dense gradient of each
/// linear block. Embeddings and vectors stay dense.
pub fn onesided_profile(spec: &ModelSpec, rank: usize, k_refresh: usize) -> CommProfile {
    onesided_profile_fmt(spec, rank, k_refresh, ElemFmt::F32)
}

/// Format-aware one-sided profile (DESIGN.md §14): the steady projected
/// factor is priced at `core_fmt.width()` bytes/element; the dense
/// refresh gradient and the always-dense blocks stay f32. The split
/// reports f32-equivalent element counts (bytes / 4), consistent with
/// the sign/topk profiles.
pub fn onesided_profile_fmt(
    spec: &ModelSpec,
    rank: usize,
    k_refresh: usize,
    core_fmt: ElemFmt,
) -> CommProfile {
    let mut split = (0f64, 0f64, 0f64);
    let mut steady_bytes = 0u64;
    let mut refresh_extra = 0u64;
    for b in spec.blocks() {
        let bytes = match b.class {
            LayerClass::Linear => {
                let r = rank.min(b.rows).min(b.cols);
                let long = b.rows.max(b.cols);
                refresh_extra += b.numel() as u64;
                (r * long * core_fmt.width()) as u64
            }
            _ => (b.numel() * BYTES_F32) as u64,
        };
        add_split(&mut split, b.class, bytes as f64 / BYTES_F32 as f64);
        steady_bytes += bytes;
    }
    let k = k_refresh.max(1) as u64;
    let bpe = BYTES_F32 as u64;
    CommProfile {
        bytes_per_step: ((steady_bytes * k + refresh_extra * bpe) as f64) / k as f64,
        peak_bytes: (steady_bytes + refresh_extra * bpe) as f64,
        split,
    }
}

/// TSR parameters (mirrors `optim::TsrConfig` for the analytic path).
#[derive(Clone, Copy, Debug)]
pub struct TsrParams {
    pub rank: usize,
    pub k_refresh: usize,
    pub rank_emb: usize,
    pub k_refresh_emb: usize,
    pub oversample: usize,
}

/// TSR-Adam: matrix blocks sync the r×r core; refresh (every K / K_emb)
/// adds the sketches Q̄ (m×k) + B̄ (k×n). Vectors stay dense. Averaging
/// period = lcm(K, K_emb), the exact cycle the ledger sees.
pub fn tsr_profile(spec: &ModelSpec, p: TsrParams) -> CommProfile {
    tsr_profile_fmt(spec, p, ElemFmt::F32)
}

/// Format-aware TSR profile (DESIGN.md §14): the steady r×r cores are
/// priced at `core_fmt.width()` bytes/element; refresh sketches and
/// dense vectors stay f32, exactly as `TsrAdam` quantizes. The period
/// total stays an integer byte count divided once, preserving the
/// bit-for-bit metered == analytic contract.
pub fn tsr_profile_fmt(spec: &ModelSpec, p: TsrParams, core_fmt: ElemFmt) -> CommProfile {
    let mut split = (0f64, 0f64, 0f64);
    let mut steady_bytes = 0u64;
    let mut period_extra = 0u64;
    let mut peak_extra = 0u64;
    let kl = p.k_refresh.max(1) as u64;
    let ke = p.k_refresh_emb.max(1) as u64;
    let period = lcm(kl, ke);
    for b in spec.blocks() {
        let bytes = match b.class {
            LayerClass::Vector => (b.numel() * BYTES_F32) as u64,
            class => {
                let (r, kk) = if class == LayerClass::Embedding {
                    (p.rank_emb, ke)
                } else {
                    (p.rank, kl)
                };
                let r = r.min(b.rows).min(b.cols);
                let sk = (r + p.oversample).min(b.rows).min(b.cols);
                let sketches = (b.rows * sk + sk * b.cols) as u64;
                period_extra += sketches * (period / kk);
                peak_extra += sketches;
                (r * r * core_fmt.width()) as u64
            }
        };
        add_split(&mut split, b.class, bytes as f64 / BYTES_F32 as f64);
        steady_bytes += bytes;
    }
    let bpe = BYTES_F32 as u64;
    CommProfile {
        bytes_per_step: ((steady_bytes * period + period_extra * bpe) as f64) / period as f64,
        // Worst step: all blocks refresh together (step 0 / lcm of K's).
        peak_bytes: (steady_bytes + peak_extra * bpe) as f64,
        split,
    }
}

/// SignAdam: matrix blocks sync a packed sign bitmap + scale per step
/// (1 bit/element); every `k_var` steps a dense all-reduce re-estimates
/// the frozen variance. Vectors stay dense.
pub fn sign_profile(spec: &ModelSpec, k_var: usize) -> CommProfile {
    let mut split = (0f64, 0f64, 0f64);
    let mut steady_bytes = 0u64;
    let mut extra_bytes = 0u64;
    for b in spec.blocks() {
        let bytes = match b.class {
            LayerClass::Vector => (b.numel() * BYTES_F32) as u64,
            _ => {
                extra_bytes += (b.numel() * BYTES_F32) as u64;
                sign_payload_bytes(b.numel()) as u64
            }
        };
        // Split reports f32-equivalent element counts for the Fig. 5
        // breakdown (bytes / 4), consistent across methods.
        add_split(&mut split, b.class, bytes as f64 / BYTES_F32 as f64);
        steady_bytes += bytes;
    }
    let k = k_var.max(1) as u64;
    CommProfile {
        bytes_per_step: (steady_bytes * k + extra_bytes) as f64 / k as f64,
        peak_bytes: (steady_bytes + extra_bytes) as f64,
        split,
    }
}

/// TopKAdam: matrix blocks sync k = ceil(ρ·numel) (index, value) pairs
/// per step; no refresh events, so Peak == Bytes/Step. Vectors dense.
pub fn topk_profile(spec: &ModelSpec, keep_frac: f64) -> CommProfile {
    let mut split = (0f64, 0f64, 0f64);
    let mut steady_bytes = 0u64;
    for b in spec.blocks() {
        let bytes = match b.class {
            LayerClass::Vector => (b.numel() * BYTES_F32) as u64,
            _ => topk_payload_bytes(topk_elems(b.numel(), keep_frac)) as u64,
        };
        add_split(&mut split, b.class, bytes as f64 / BYTES_F32 as f64);
        steady_bytes += bytes;
    }
    CommProfile {
        bytes_per_step: steady_bytes as f64,
        peak_bytes: steady_bytes as f64,
        split,
    }
}

/// DES-LOC: every block (vectors included) holds per-worker replicas
/// and moments; a step communicates `numel` elements per optimizer
/// state whose period divides `t` — params every `k_p`, first moment
/// every `k_m`, second moment every `k_v` — and **exactly zero bytes**
/// on local steps. Averaging period = lcm(k_p, k_m, k_v), the exact
/// cycle the ledger sees; peak is step 0, where all three states sync.
pub fn desloc_profile(spec: &ModelSpec, k_p: u64, k_m: u64, k_v: u64) -> CommProfile {
    let (kp, km, kv) = (k_p.max(1), k_m.max(1), k_v.max(1));
    let period = lcm(kp, lcm(km, kv));
    let syncs_per_period = period / kp + period / km + period / kv;
    let mut split = (0f64, 0f64, 0f64);
    let mut period_total = 0u64;
    let mut peak = 0u64;
    for b in spec.blocks() {
        let numel = b.numel() as u64;
        period_total += numel * syncs_per_period;
        peak += numel * 3;
        add_split(
            &mut split,
            b.class,
            (numel * syncs_per_period) as f64 / period as f64,
        );
    }
    let bpe = BYTES_F32 as u64;
    CommProfile {
        bytes_per_step: (period_total * bpe) as f64 / period as f64,
        peak_bytes: (peak * bpe) as f64,
        split,
    }
}

/// LoRDO: `h`−1 of every `h` steps are purely local (**exactly zero
/// bytes**); the round boundary pays the warm-started rank-r delta
/// factors P (m×r̂) + Q' (n×r̂) per matrix block and a dense replica
/// mean per vector block. Peak == the sync step; period = h.
pub fn lordo_profile(spec: &ModelSpec, rank: usize, h: u64) -> CommProfile {
    lordo_profile_fmt(spec, rank, h, ElemFmt::F32)
}

/// Format-aware LoRDO profile (DESIGN.md §14): the round's delta
/// factors P + Q' are priced at `core_fmt.width()` bytes/element; the
/// dense vector replica means stay f32.
pub fn lordo_profile_fmt(spec: &ModelSpec, rank: usize, h: u64, core_fmt: ElemFmt) -> CommProfile {
    let h = h.max(1);
    let mut split = (0f64, 0f64, 0f64);
    let mut sync_bytes = 0u64;
    for b in spec.blocks() {
        let bytes = match b.class {
            LayerClass::Vector => (b.numel() * BYTES_F32) as u64,
            _ => {
                let r = rank.min(b.rows).min(b.cols);
                ((b.rows * r + b.cols * r) * core_fmt.width()) as u64
            }
        };
        add_split(&mut split, b.class, bytes as f64 / (BYTES_F32 as u64 * h) as f64);
        sync_bytes += bytes;
    }
    CommProfile {
        bytes_per_step: sync_bytes as f64 / h as f64,
        peak_bytes: sync_bytes as f64,
        split,
    }
}

/// Table 1: synchronized-object sizes for one m×n gradient.
pub fn table1_row(m: usize, n: usize, r: usize) -> [(String, usize); 4] {
    [
        ("AdamW (dense G)".into(), m * n),
        ("LoRA (G_A, G_B)".into(), r * m + r * n),
        ("One-sided (UᵀG)".into(), r * n.max(m)),
        ("TSR (UᵀGV)".into(), r * r),
    ]
}

fn add_split(split: &mut (f64, f64, f64), class: LayerClass, elems: f64) {
    match class {
        LayerClass::Embedding => split.0 += elems,
        LayerClass::Linear => split.1 += elems,
        LayerClass::Vector => split.2 += elems,
    }
}

/// Dense byte share of embeddings vs linears for Fig. 5(a).
pub fn embedding_share(spec: &ModelSpec) -> f64 {
    let p = adamw_profile(spec);
    p.split.0 / (p.split.0 + p.split.1 + p.split.2)
}

/// Cross-check helper used by tests: a block-level element count for one
/// step of TSR (steady state).
pub fn tsr_steady_elements(blocks: &[BlockSpec], rank: usize, rank_emb: usize) -> usize {
    blocks
        .iter()
        .map(|b| match b.class {
            LayerClass::Vector => b.numel(),
            LayerClass::Embedding => {
                let r = rank_emb.min(b.rows).min(b.cols);
                r * r
            }
            LayerClass::Linear => {
                let r = rank.min(b.rows).min(b.cols);
                r * r
            }
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    const G: f64 = 1024.0 * 1024.0 * 1024.0;

    #[test]
    fn adamw_matches_table3_bytes_per_step() {
        for (spec, expect) in [
            (ModelSpec::llama_60m(), 0.17),
            (ModelSpec::llama_130m(), 0.44),
            (ModelSpec::llama_350m(), 1.34),
            (ModelSpec::llama_1b(), 5.09),
        ] {
            let p = adamw_profile(&spec);
            let g = p.bytes_per_step / G;
            // Consistently ~8% under the paper across all four scales —
            // the Table 5 shapes leave a small unspecified remainder
            // (paper's exact norm/rotary bookkeeping); the scaling match
            // is what matters.
            assert!(
                (g - expect).abs() / expect < 0.12,
                "{}: {g:.3} vs {expect}",
                spec.name
            );
            assert_eq!(p.bytes_per_step, p.peak_bytes);
        }
    }

    #[test]
    fn tsr_matches_table3_peak_bytes() {
        // Table 3 TSR rows: 60M r=256(64) K=100 → peak 0.10G;
        // 130M r=384(96) → 0.31G; 350M r=384(128) → 0.79G; 1B 512(256) → 2.05G.
        for (spec, r, re, expect) in [
            (ModelSpec::llama_60m(), 256, 64, 0.10),
            (ModelSpec::llama_130m(), 384, 96, 0.31),
            (ModelSpec::llama_350m(), 384, 128, 0.79),
            (ModelSpec::llama_1b(), 512, 256, 2.05),
        ] {
            let p = tsr_profile(
                &spec,
                TsrParams {
                    rank: r,
                    k_refresh: 100,
                    rank_emb: re,
                    k_refresh_emb: 100,
                    oversample: 8,
                },
            );
            let g = p.peak_bytes / G;
            assert!(
                (g - expect).abs() / expect < 0.25,
                "{}: peak {g:.3}G vs paper {expect}G",
                spec.name
            );
        }
    }

    #[test]
    fn tsr_bytes_per_step_an_order_below_dense() {
        // Table 3's headline: ~13× average reduction across scales.
        let mut ratios = Vec::new();
        for (spec, r, re) in [
            (ModelSpec::llama_60m(), 256, 64),
            (ModelSpec::llama_130m(), 384, 96),
            (ModelSpec::llama_350m(), 384, 128),
            (ModelSpec::llama_1b(), 512, 256),
        ] {
            let dense = adamw_profile(&spec).bytes_per_step;
            let tsr = tsr_profile(
                &spec,
                TsrParams {
                    rank: r,
                    k_refresh: 100,
                    rank_emb: re,
                    k_refresh_emb: 100,
                    oversample: 8,
                },
            )
            .bytes_per_step;
            ratios.push(dense / tsr);
        }
        let mean = ratios.iter().sum::<f64>() / ratios.len() as f64;
        assert!(
            mean > 8.0 && mean < 40.0,
            "mean reduction {mean:.1}× (paper: 13×; ratios {ratios:?})"
        );
    }

    #[test]
    fn onesided_between_dense_and_tsr() {
        let spec = ModelSpec::llama_60m();
        let dense = adamw_profile(&spec).bytes_per_step;
        let one = onesided_profile(&spec, 128, 200).bytes_per_step;
        let tsr = tsr_profile(
            &spec,
            TsrParams {
                rank: 256,
                k_refresh: 100,
                rank_emb: 64,
                k_refresh_emb: 100,
                oversample: 8,
            },
        )
        .bytes_per_step;
        assert!(tsr < one && one < dense, "{tsr} < {one} < {dense}");
    }

    #[test]
    fn table1_scaling_orders() {
        let rows = table1_row(4096, 4096, 128);
        assert!(rows[3].1 < rows[1].1 && rows[1].1 < rows[0].1);
        assert!(rows[3].1 < rows[2].1 && rows[2].1 < rows[0].1);
        assert_eq!(rows[3].1, 128 * 128);
    }

    #[test]
    fn lcm_basics() {
        assert_eq!(lcm(4, 8), 8);
        assert_eq!(lcm(100, 100), 100);
        assert_eq!(lcm(6, 10), 30);
        assert_eq!(lcm(1, 7), 7);
    }

    #[test]
    fn sign_profile_is_about_32x_below_dense() {
        // 1 bit vs 32 bits per element, plus the amortized dense variance
        // refresh and the always-dense vectors.
        let spec = ModelSpec::llama_60m();
        let dense = adamw_profile(&spec).bytes_per_step;
        let sign = sign_profile(&spec, 1000);
        assert!(sign.bytes_per_step < dense / 20.0, "{}", sign.bytes_per_step);
        assert!(sign.bytes_per_step > dense / 40.0, "{}", sign.bytes_per_step);
        // Peak = a full dense step on top of the compressed payload.
        assert!(sign.peak_bytes > dense);
        // Shorter variance interval → more amortized dense traffic.
        let sign_freq = sign_profile(&spec, 10);
        assert!(sign_freq.bytes_per_step > sign.bytes_per_step);
    }

    #[test]
    fn topk_profile_scales_with_density_and_is_flat() {
        let spec = ModelSpec::llama_60m();
        let dense = adamw_profile(&spec).bytes_per_step;
        let p1 = topk_profile(&spec, 0.01);
        let p5 = topk_profile(&spec, 0.05);
        assert_eq!(p1.bytes_per_step, p1.peak_bytes);
        assert!(p1.bytes_per_step < p5.bytes_per_step);
        // 1% density at 8 B/entry ≈ 2% of dense f32 traffic + vectors.
        assert!(p1.bytes_per_step < 0.04 * dense, "{}", p1.bytes_per_step);
        assert!(p1.bytes_per_step > 0.015 * dense, "{}", p1.bytes_per_step);
    }

    #[test]
    fn tsr_profile_mixed_refresh_intervals_average_over_lcm() {
        // K=4, K_emb=8: per lcm-period (8 steps) the linear sketches are
        // paid twice, the embedding sketches once.
        let spec = ModelSpec::proxy(100, 16, 32, 2, 1);
        let p = |k, ke| {
            tsr_profile(
                &spec,
                TsrParams {
                    rank: 4,
                    k_refresh: k,
                    rank_emb: 4,
                    k_refresh_emb: ke,
                    oversample: 2,
                },
            )
        };
        let mixed = p(4, 8);
        let uniform_fast = p(4, 4);
        let uniform_slow = p(8, 8);
        assert!(mixed.bytes_per_step < uniform_fast.bytes_per_step);
        assert!(mixed.bytes_per_step > uniform_slow.bytes_per_step);
        assert_eq!(mixed.peak_bytes, uniform_fast.peak_bytes);
    }

    #[test]
    fn desloc_profile_amortizes_over_the_three_periods() {
        let spec = ModelSpec::proxy(100, 16, 32, 2, 1);
        let dense = adamw_profile(&spec).bytes_per_step;
        // k_p=k_m=k_v=1 degenerates to syncing all three states densely
        // every step: exactly 3× the dense-params profile.
        let every_step = desloc_profile(&spec, 1, 1, 1);
        assert_eq!(every_step.bytes_per_step, 3.0 * dense);
        assert_eq!(every_step.peak_bytes, 3.0 * dense);
        // Desynced periods 2/4/8: per 8-step period params sync 4×,
        // m 2×, v 1× → 7 dense payloads / 8 steps.
        let p = desloc_profile(&spec, 2, 4, 8);
        assert_eq!(p.bytes_per_step, dense * 7.0 / 8.0);
        assert_eq!(p.peak_bytes, 3.0 * dense);
        // Longer periods strictly cheaper per step, same peak.
        let slow = desloc_profile(&spec, 8, 16, 32);
        assert!(slow.bytes_per_step < p.bytes_per_step);
        assert_eq!(slow.peak_bytes, p.peak_bytes);
    }

    #[test]
    fn lordo_profile_amortizes_the_round_payload_over_h() {
        let spec = ModelSpec::proxy(100, 16, 32, 2, 1);
        let p4 = lordo_profile(&spec, 4, 8);
        let p4_slow = lordo_profile(&spec, 4, 16);
        // Same sync payload, amortized over twice the local steps.
        assert_eq!(p4.peak_bytes, p4_slow.peak_bytes);
        assert_eq!(p4.bytes_per_step, 2.0 * p4_slow.bytes_per_step);
        // Large H drives bytes/step far below dense.
        let dense = adamw_profile(&spec).bytes_per_step;
        assert!(p4_slow.bytes_per_step < 0.1 * dense, "{}", p4_slow.bytes_per_step);
        // Higher rank → more bytes per round.
        assert!(lordo_profile(&spec, 8, 8).peak_bytes > p4.peak_bytes);
    }

    /// DESIGN.md §14: narrowing the core format shaves exactly
    /// (4 − width) bytes per steady low-rank element off every profile,
    /// leaving the f32 sketch/refresh/vector terms untouched. k = 1 and
    /// h = 1 make the period division exact, so `==` on f64 is sound.
    #[test]
    fn narrow_core_formats_shave_exact_steady_bytes() {
        let spec = ModelSpec::proxy(101, 16, 32, 2, 1);
        let core_elems: u64 = spec
            .blocks()
            .iter()
            .filter(|b| b.class != LayerClass::Vector)
            .map(|b| {
                let r = 4usize.min(b.rows).min(b.cols);
                (r * r) as u64
            })
            .sum();
        let p = TsrParams {
            rank: 4,
            k_refresh: 1,
            rank_emb: 4,
            k_refresh_emb: 1,
            oversample: 2,
        };
        let base = tsr_profile(&spec, p);
        assert_eq!(
            base.bytes_per_step,
            tsr_profile_fmt(&spec, p, ElemFmt::F32).bytes_per_step,
            "f32 delegate must be byte-identical"
        );
        for fmt in [ElemFmt::Bf16, ElemFmt::I8] {
            let saved = (core_elems * (BYTES_F32 - fmt.width()) as u64) as f64;
            let narrow = tsr_profile_fmt(&spec, p, fmt);
            assert_eq!(narrow.bytes_per_step, base.bytes_per_step - saved);
            assert_eq!(narrow.peak_bytes, base.peak_bytes - saved);
        }

        // One-sided: steady r×long factor narrows, dense refresh + the
        // always-dense embedding/vector blocks do not.
        let factor_elems: u64 = spec
            .blocks()
            .iter()
            .filter(|b| b.class == LayerClass::Linear)
            .map(|b| (4usize.min(b.rows).min(b.cols) * b.rows.max(b.cols)) as u64)
            .sum();
        let base = onesided_profile(&spec, 4, 1);
        let narrow = onesided_profile_fmt(&spec, 4, 1, ElemFmt::Bf16);
        let saved = (factor_elems * (BYTES_F32 - 2) as u64) as f64;
        assert_eq!(narrow.bytes_per_step, base.bytes_per_step - saved);
        assert_eq!(narrow.peak_bytes, base.peak_bytes - saved);

        // LoRDO: P + Q' narrow, vector replica means do not.
        let pq_elems: u64 = spec
            .blocks()
            .iter()
            .filter(|b| b.class != LayerClass::Vector)
            .map(|b| {
                let r = 4usize.min(b.rows).min(b.cols);
                ((b.rows + b.cols) * r) as u64
            })
            .sum();
        let base = lordo_profile(&spec, 4, 1);
        let narrow = lordo_profile_fmt(&spec, 4, 1, ElemFmt::I8);
        let saved = (pq_elems * (BYTES_F32 - 1) as u64) as f64;
        assert_eq!(narrow.bytes_per_step, base.bytes_per_step - saved);
        assert_eq!(narrow.peak_bytes, base.peak_bytes - saved);
    }

    #[test]
    fn embedding_share_decreases_with_scale() {
        // Fig. 5(a): embeddings dominate at small scale, shrink relatively
        // as the linear stack grows.
        let s60 = embedding_share(&ModelSpec::llama_60m());
        let s1b = embedding_share(&ModelSpec::llama_1b());
        assert!(s60 > 0.25, "60m embedding share {s60}");
        assert!(s1b < s60, "1b {s1b} < 60m {s60}");
    }
}
