//! Regenerators for the paper's figures (data series as CSV/JSON; the
//! paper's plots are these series drawn with matplotlib).

use super::analytic::{adamw_profile, embedding_share};
use super::runs::{proxy_onesided_rank, proxy_spec, proxy_tsr_cfg, run_proxy, MethodCfg, RunOutput};
use crate::metrics::results_path;
use crate::model::ModelSpec;
use crate::optim::onesided::OneSidedRefresh;
use crate::optim::RefreshKind;
use crate::util::json::Json;

fn curve_json(out: &RunOutput) -> Json {
    Json::obj(vec![
        ("label", Json::str(out.label.clone())),
        ("final_loss", Json::num(out.metrics.final_loss() as f64)),
        ("bytes_per_step", Json::num(out.ledger.bytes_per_step())),
        ("peak_bytes", Json::num(out.ledger.peak_bytes() as f64)),
        (
            "loss",
            Json::Arr(out.metrics.loss.iter().map(|&l| Json::num(l as f64)).collect()),
        ),
        (
            "cum_bytes",
            Json::Arr(
                out.metrics
                    .cum_bytes
                    .iter()
                    .map(|&b| Json::num(b as f64))
                    .collect(),
            ),
        ),
    ])
}

fn save(name: &str, j: &Json) {
    let p = results_path(name).unwrap_or_else(|e| panic!("{e}"));
    std::fs::write(&p, j.to_string_pretty())
        .unwrap_or_else(|e| panic!("write {}: {e}", p.display()));
    println!("  -> wrote {}", p.display());
}

/// Fig. 1: bytes-to-loss curves (loss vs cumulative communicated bytes)
/// for three representative scales × {AdamW, GaLore, TSR}.
pub fn fig1(steps: usize, workers: usize) -> Json {
    println!("\nFig 1 — bytes-to-loss curves (proxy scales)");
    let mut panels = Vec::new();
    for scale in ["60m", "130m", "350m"] {
        let spec = proxy_spec(scale);
        let methods = [
            MethodCfg::Adam,
            MethodCfg::OneSided {
                rank: proxy_onesided_rank(scale),
                k: 200,
                refresh: OneSidedRefresh::RandomizedSvd,
            },
            MethodCfg::Tsr(proxy_tsr_cfg(scale)),
        ];
        let mut curves = Vec::new();
        for m in &methods {
            let out = run_proxy(&spec, m, steps, workers, 0.02, 0.02, 0xF16_1);
            println!(
                "  {scale:<5} {:<16} final loss {:>8.4}  cum bytes {}",
                out.label,
                out.metrics.final_loss(),
                crate::util::bench::fmt_bytes(
                    *out.metrics.cum_bytes.last().unwrap_or(&0) as f64
                )
            );
            curves.push(curve_json(&out));
        }
        panels.push(Json::obj(vec![
            ("scale", Json::str(scale)),
            ("curves", Json::Arr(curves)),
        ]));
    }
    let j = Json::obj(vec![("panels", Json::Arr(panels))]);
    save("fig1_bytes_to_loss.json", &j);
    j
}

/// Fig. 3: the three ablations on the 60M proxy.
pub fn fig3(steps: usize, workers: usize) -> Json {
    println!("\nFig 3 — ablations (60m proxy)");
    let spec = proxy_spec("60m");
    let base = proxy_tsr_cfg("60m");

    // (a) one-sided vs two-sided at matched rank.
    let mut a_curves = Vec::new();
    for m in [
        MethodCfg::OneSided {
            rank: base.rank,
            k: base.refresh_every,
            refresh: OneSidedRefresh::RandomizedSvd,
        },
        MethodCfg::Tsr(base.clone()),
    ] {
        let out = run_proxy(&spec, &m, steps, workers, 0.02, 0.02, 0xAB1);
        println!(
            "  (a) {:<18} final {:>8.4}  bytes/step {}",
            out.label,
            out.metrics.final_loss(),
            crate::util::bench::fmt_bytes(out.ledger.bytes_per_step())
        );
        a_curves.push(curve_json(&out));
    }

    // (b) randomized vs exact-dense refresh.
    let mut b_curves = Vec::new();
    for kind in [RefreshKind::Randomized, RefreshKind::ExactDense] {
        let mut cfg = base.clone();
        cfg.refresh_kind = kind;
        cfg.refresh_every = 25;
        cfg.refresh_emb = 25;
        let out = run_proxy(&spec, &MethodCfg::Tsr(cfg), steps, workers, 0.02, 0.02, 0xAB2);
        let label = match kind {
            RefreshKind::Randomized => "rsvd-refresh",
            RefreshKind::ExactDense => "exact-svd-refresh",
        };
        println!(
            "  (b) {:<18} final {:>8.4}  bytes/step {}  peak {}",
            label,
            out.metrics.final_loss(),
            crate::util::bench::fmt_bytes(out.ledger.bytes_per_step()),
            crate::util::bench::fmt_bytes(out.ledger.peak_bytes() as f64)
        );
        let mut j = curve_json(&out);
        if let Json::Obj(o) = &mut j {
            o.insert("label".into(), Json::str(label));
        }
        b_curves.push(j);
    }

    // (c) refresh interval K sweep.
    let mut c_curves = Vec::new();
    for k in [20usize, 50, 100, 200] {
        let mut cfg = base.clone();
        cfg.refresh_every = k;
        cfg.refresh_emb = k;
        let out = run_proxy(&spec, &MethodCfg::Tsr(cfg), steps, workers, 0.02, 0.02, 0xAB3);
        println!(
            "  (c) K={k:<4} final {:>8.4}  bytes/step {}",
            out.metrics.final_loss(),
            crate::util::bench::fmt_bytes(out.ledger.bytes_per_step())
        );
        let mut j = curve_json(&out);
        if let Json::Obj(o) = &mut j {
            o.insert("label".into(), Json::str(format!("K={k}")));
        }
        c_curves.push(j);
    }

    let j = Json::obj(vec![
        ("a_one_vs_two_sided", Json::Arr(a_curves)),
        ("b_svd_vs_rsvd", Json::Arr(b_curves)),
        ("c_refresh_interval", Json::Arr(c_curves)),
    ]);
    save("fig3_ablations.json", &j);
    j
}

/// Fig. 4: loss–communication Pareto frontier across scales, including
/// the compressed-communication baselines (sign + top-k) and the
/// local-update family (DES-LOC, LoRDO) so the frontier spans every
/// compression family in the repo.
pub fn fig4(steps: usize, workers: usize) -> Json {
    println!("\nFig 4 — Pareto frontier (final loss vs bytes/step, proxy scales)");
    let mut points = Vec::new();
    for scale in ["60m", "130m", "350m", "1b"] {
        let spec = proxy_spec(scale);
        let methods = [
            MethodCfg::Adam,
            MethodCfg::OneSided {
                rank: proxy_onesided_rank(scale),
                k: 200,
                refresh: OneSidedRefresh::RandomizedSvd,
            },
            MethodCfg::Tsr(proxy_tsr_cfg(scale)),
            MethodCfg::PowerSgd { rank: 8 },
            MethodCfg::Sign { k_var: 100 },
            MethodCfg::TopK { keep_frac: 0.01 },
            MethodCfg::DesLoc { k_p: 8, k_m: 32, k_v: 128 },
            MethodCfg::Lordo { rank: 8, h: 8 },
        ];
        for m in &methods {
            let out = run_proxy(&spec, m, steps, workers, 0.02, 0.02, 0xFA4);
            println!(
                "  {scale:<5} {:<18} loss {:>8.4}  bytes/step {}",
                out.label,
                out.metrics.final_loss(),
                crate::util::bench::fmt_bytes(out.ledger.bytes_per_step())
            );
            points.push(Json::obj(vec![
                ("scale", Json::str(scale)),
                ("method", Json::str(out.label.clone())),
                ("final_loss", Json::num(out.metrics.final_loss() as f64)),
                ("bytes_per_step", Json::num(out.ledger.bytes_per_step())),
            ]));
        }
    }
    let j = Json::obj(vec![("points", Json::Arr(points))]);
    save("fig4_pareto.json", &j);
    j
}

/// Fig. 5: (a) embedding vs linear share of dense traffic per scale;
/// (b) TSR with vs without embedding compression (loss–bytes curves).
pub fn fig5(steps: usize, workers: usize) -> Json {
    println!("\nFig 5(a) — dense gradient traffic share (exact, paper scales)");
    let mut shares = Vec::new();
    for scale in ["60m", "130m", "350m", "1b"] {
        let spec = ModelSpec::by_name(scale).unwrap();
        let share = embedding_share(&spec);
        let prof = adamw_profile(&spec);
        println!(
            "  {scale:<5} embedding {:>5.1}%  linear {:>5.1}%",
            100.0 * share,
            100.0 * prof.split.1 / (prof.split.0 + prof.split.1 + prof.split.2)
        );
        shares.push(Json::obj(vec![
            ("scale", Json::str(scale)),
            ("embedding_share", Json::num(share)),
        ]));
    }

    println!("Fig 5(b) — embedding compression on vs off (60m proxy)");
    let spec = proxy_spec("60m");
    let base = proxy_tsr_cfg("60m");
    let mut curves = Vec::new();
    // TSR with embedding compression (the paper's full method).
    let out_on = run_proxy(&spec, &MethodCfg::Tsr(base.clone()), steps, workers, 0.02, 0.02, 0xF5);
    // TSR with embeddings left dense: emulate by a huge r_emb clamped to
    // full rank and no embedding refresh cost → embedding syncs dense-rank
    // core = full matrix. We model "dense embedding" exactly by rank_emb =
    // min dim (core = d×d = full column space at hidden size).
    let mut dense_emb = base.clone();
    dense_emb.rank_emb = usize::MAX / 2;
    dense_emb.refresh_emb = usize::MAX / 2;
    let out_off = run_proxy(&spec, &MethodCfg::Tsr(dense_emb), steps, workers, 0.02, 0.02, 0xF5);
    for (label, out) in [("tsr-emb-compressed", &out_on), ("tsr-emb-dense", &out_off)] {
        println!(
            "  {:<20} final {:>8.4}  bytes/step {}",
            label,
            out.metrics.final_loss(),
            crate::util::bench::fmt_bytes(out.ledger.bytes_per_step())
        );
        let mut j = curve_json(out);
        if let Json::Obj(o) = &mut j {
            o.insert("label".into(), Json::str(label));
        }
        curves.push(j);
    }
    let j = Json::obj(vec![
        ("a_shares", Json::Arr(shares)),
        ("b_curves", Json::Arr(curves)),
    ]);
    save("fig5_embedding.json", &j);
    j
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_shares_structure() {
        // Analytic part only (no training): embedding share must be
        // largest at 60m and strictly decreasing with scale.
        let mut last = 1.0f64;
        for scale in ["60m", "130m", "350m", "1b"] {
            let s = embedding_share(&ModelSpec::by_name(scale).unwrap());
            assert!(s < last, "{scale}: {s} !< {last}");
            last = s;
        }
    }
}
