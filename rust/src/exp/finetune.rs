//! Table 5: the adaptation regime — communication of a low-rank
//! fine-tune vs a dense AdamW fine-tune from the *same* pretrained
//! embedding (the paper's 25× GLUE-era claim, reproduced in shape on
//! the native stack; DESIGN.md §6, §14).
//!
//! Pipeline under measurement: a short dense LM pretrain produces a
//! token-embedding table; both fine-tunes transfer it bit-for-bit
//! (`ClassifyTask::init_params_pretrained`) and train the same
//! classification task with matched seeds. Rows differ only in the
//! optimizer: dense AdamW, TSR f32, and the adaptation-regime
//! configuration `tsr finetune` defaults to — lower rank, shorter
//! refresh, bf16 cores with error feedback.
//!
//! **Comparable-loss contract:** the compressed rows must land within
//! [`LOSS_TOL`]× of AdamW's final loss; the headline column is the
//! bytes/step reduction at that quality, which must be ≥ 10× for the
//! bf16 row (`table5_shows_10x_comm_reduction_at_comparable_loss`).

use crate::comm::{ElemFmt, Topology};
use crate::exp::MethodCfg;
use crate::linalg::Matrix;
use crate::model::ModelSpec;
use crate::optim::{AdamHyper, LrSchedule, TsrConfig};
use crate::train::finetune::ClassifyTask;
use crate::train::lm_source::LmSource;
use crate::train::{GradSource, Trainer};
use crate::util::json::Json;

/// Comparable-loss tolerance: a compressed fine-tune row is accepted
/// when its final loss is ≤ `LOSS_TOL` × the dense AdamW final loss.
/// Matches the spirit of the paper's "within noise of dense" GLUE
/// deltas; generous enough to be seed-stable, tight enough that a
/// diverging optimizer fails the table.
pub const LOSS_TOL: f32 = 1.15;

/// Fine-tune shape shared by the table and the `tsr finetune` CLI
/// defaults: rank 8 with embedding rank 8, refresh every 25 steps,
/// bf16 cores.
pub fn finetune_tsr_cfg(rank: usize, k: usize, core_fmt: ElemFmt) -> TsrConfig {
    TsrConfig {
        rank,
        rank_emb: rank,
        refresh_every: k,
        refresh_emb: k,
        oversample: 4,
        core_fmt,
        ..Default::default()
    }
}

/// Short dense pretrain of the native LM; returns the trained
/// token-embedding table (the block named `embed_tokens`). This is the
/// in-process equivalent of `tsr train --source lm --save-every N`
/// followed by `tsr finetune --from <ckpt>` reading the manifest.
pub fn pretrain_embedding(
    spec: &ModelSpec,
    steps: usize,
    workers: usize,
    seed: u64,
) -> Matrix {
    let mut source = LmSource::new(spec, workers, 4, 16, seed);
    let blocks = source.blocks().to_vec();
    let mut opt = MethodCfg::Adam.build(
        &blocks,
        AdamHyper {
            lr: 0.01,
            weight_decay: 0.0,
            scale: 1.0,
            ..Default::default()
        },
        workers,
    );
    let mut params = source.init_params(seed ^ 0xF00D);
    let trainer = Trainer::new(Topology::single_node(workers), LrSchedule::constant());
    trainer.run(&mut source, opt.as_mut(), &mut params, steps);
    // By name, not by class: the untied LM head is also Embedding-class
    // (`blocks_untied_lm`), and only `embed_tokens` transfers.
    let idx = blocks
        .iter()
        .position(|b| b.name == "embed_tokens")
        .expect("LM spec has no embed_tokens block");
    params.swap_remove(idx)
}

struct Row {
    label: String,
    bytes_per_step: f64,
    cum_bytes: u64,
    final_loss: f32,
    accuracy: f32,
}

fn run_finetune_row(
    label: &str,
    method: &MethodCfg,
    core_fmt: ElemFmt,
    emb: &Matrix,
    steps: usize,
    workers: usize,
    seed: u64,
) -> Row {
    let (vocab, dim) = (emb.rows, emb.cols);
    let mut task = ClassifyTask::new(vocab, dim, 32, 4, 16, workers, 16, seed);
    let blocks = task.blocks().to_vec();
    let hyper = AdamHyper {
        lr: 0.02,
        weight_decay: 0.0,
        scale: 1.0,
        ..Default::default()
    };
    let mut opt = method.build_with_fmt(&blocks, hyper, workers, core_fmt);
    let mut params = task.init_params_pretrained(seed ^ 0xF00D, emb);
    let trainer = Trainer::new(Topology::single_node(workers), LrSchedule::constant());
    let (metrics, ledger) = trainer.run(&mut task, opt.as_mut(), &mut params, steps);
    Row {
        label: label.to_string(),
        bytes_per_step: ledger.bytes_per_step(),
        cum_bytes: ledger.cumulative().last().copied().unwrap_or(0),
        final_loss: metrics.final_loss(),
        accuracy: task.accuracy(&params),
    }
}

/// Table 5: adaptation-regime bytes vs dense AdamW at comparable loss.
pub fn table5(pretrain_steps: usize, steps: usize, workers: usize, seed: u64) -> Json {
    let spec = ModelSpec::proxy(64, 32, 64, 2, 2);
    let emb = pretrain_embedding(&spec, pretrain_steps, workers, seed);

    let tsr_f32 = MethodCfg::Tsr(finetune_tsr_cfg(8, 25, ElemFmt::F32));
    let tsr_bf16 = MethodCfg::Tsr(finetune_tsr_cfg(8, 25, ElemFmt::Bf16));
    let rows = vec![
        run_finetune_row("adamw", &MethodCfg::Adam, ElemFmt::F32, &emb, steps, workers, seed),
        run_finetune_row("tsr-f32", &tsr_f32, ElemFmt::F32, &emb, steps, workers, seed),
        run_finetune_row("tsr-bf16", &tsr_bf16, ElemFmt::Bf16, &emb, steps, workers, seed),
    ];
    let dense = rows[0].bytes_per_step;

    println!(
        "\nTable 5 — fine-tune from a pretrained embedding ({} pretrain + {} finetune steps)",
        pretrain_steps, steps
    );
    println!(
        "{:<10} {:>12} {:>8} {:>11} {:>9}  (comparable-loss tol {LOSS_TOL}x)",
        "METHOD", "BYTES/STEP", "xAdam", "FINAL LOSS", "ACC"
    );
    for r in &rows {
        println!(
            "{:<10} {:>12.1} {:>7.1}x {:>11.4} {:>9.3}",
            r.label,
            r.bytes_per_step,
            dense / r.bytes_per_step,
            r.final_loss,
            r.accuracy
        );
    }

    let out = rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("method", Json::str(r.label.clone())),
                ("bytes_per_step", Json::num(r.bytes_per_step)),
                ("cum_bytes", Json::num(r.cum_bytes as f64)),
                ("reduction_x", Json::num(dense / r.bytes_per_step)),
                ("final_loss", Json::num(r.final_loss as f64)),
                ("accuracy", Json::num(r.accuracy as f64)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("pretrain_steps", Json::num(pretrain_steps as f64)),
        ("finetune_steps", Json::num(steps as f64)),
        ("loss_tol", Json::num(LOSS_TOL as f64)),
        ("rows", Json::Arr(out)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The PR's headline acceptance: ≥ 10× comm reduction for the bf16
    /// adaptation configuration vs dense AdamW at comparable loss
    /// (within [`LOSS_TOL`]×), and the bf16 row strictly cheaper than
    /// the f32 TSR row (the format is doing real work on the wire).
    #[test]
    fn table5_shows_10x_comm_reduction_at_comparable_loss() {
        let j = table5(30, 150, 2, 42);
        let rows = j.get("rows").as_arr().unwrap();
        let by = |label: &str| {
            rows.iter()
                .find(|r| r.get_str("method", "") == label)
                .unwrap_or_else(|| panic!("missing row {label}"))
        };
        let (adam, f32_row, bf16) = (by("adamw"), by("tsr-f32"), by("tsr-bf16"));
        let adam_loss = adam.get_f64("final_loss", f64::NAN) as f32;
        let bf16_loss = bf16.get_f64("final_loss", f64::NAN) as f32;
        assert!(
            bf16_loss <= LOSS_TOL * adam_loss,
            "bf16 loss {bf16_loss} vs adamw {adam_loss} (tol {LOSS_TOL}x)"
        );
        let reduction = bf16.get_f64("reduction_x", 0.0);
        assert!(reduction >= 10.0, "only {reduction:.1}x below dense");
        assert!(
            bf16.get_f64("bytes_per_step", 0.0) < f32_row.get_f64("bytes_per_step", 0.0),
            "bf16 must be strictly cheaper than f32 TSR"
        );
        // Quality signal, not just loss: the transferred embedding plus
        // compressed sync still learns the task well above chance (1/4).
        assert!(bf16.get_f64("accuracy", 0.0) > 0.5);
    }

    /// The embedding transfer is real: pretraining moves the table, and
    /// the pretrained fine-tune starts from exactly that matrix.
    #[test]
    fn pretrained_embedding_differs_from_init() {
        let spec = ModelSpec::proxy(64, 32, 64, 2, 2);
        let emb = pretrain_embedding(&spec, 5, 2, 7);
        assert_eq!((emb.rows, emb.cols), (64, 32));
        let src = LmSource::new(&spec, 2, 4, 16, 7);
        let init = src.init_params(7 ^ 0xF00D);
        let idx = src
            .blocks()
            .iter()
            .position(|b| b.name == "embed_tokens")
            .unwrap();
        assert_ne!(emb.data, init[idx].data, "pretrain left the embedding untouched");
    }
}
