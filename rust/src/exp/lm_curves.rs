//! Quality-vs-bytes on the native transformer LM (`tsr lm-curves`,
//! DESIGN.md §10) — the repo's first experiment whose loss axis comes
//! from a *real* model rather than the quadratic proxy.
//!
//! Following the evaluation settings of GaLore and PowerSGD (PAPERS.md):
//! compression methods must be compared on end-task loss, not gradient
//! norms. Every method trains the same LM from the same initialization
//! on the same per-worker token streams (matched seeds); the output
//! reports each method's final loss, its relative gap to dense AdamW,
//! and its ledger bytes — loss you keep vs bytes you stop sending. The
//! corpus's unigram entropy is included as the context-free loss floor:
//! a method below it is demonstrably learning from context.

use crate::comm::Topology;
use crate::data::SyntheticCorpus;
use crate::exec::ExecBackend;
use crate::exp::runs::MethodCfg;
use crate::model::ModelSpec;
use crate::optim::onesided::OneSidedRefresh;
use crate::optim::{AdamHyper, LrSchedule, TsrConfig};
use crate::train::lm_source::LmSource;
use crate::train::{GradSource, Trainer};
use crate::util::json::Json;

/// Run shape for the quality-vs-bytes sweep. The default is the
/// 64-vocab / 2-layer acceptance configuration (ISSUE 5), sized so the
/// full 7-method sweep is CPU-feasible.
#[derive(Clone, Debug)]
pub struct LmCurvesCfg {
    pub steps: usize,
    pub workers: usize,
    pub seed: u64,
    pub vocab: usize,
    pub hidden: usize,
    pub inter: usize,
    pub heads: usize,
    pub layers: usize,
    pub batch: usize,
    pub seq: usize,
    pub lr: f32,
}

impl Default for LmCurvesCfg {
    fn default() -> Self {
        Self {
            steps: 300,
            workers: 4,
            seed: 0x5EED,
            vocab: 64,
            hidden: 32,
            inter: 64,
            heads: 2,
            layers: 2,
            batch: 8,
            seq: 16,
            lr: 0.01,
        }
    }
}

/// The canonical TSR configuration for the native LM — the single
/// source of truth shared by the `lm-curves` roster, the acceptance
/// test (`tests/lm_train.rs`), and the `lm_step` bench, so the
/// configuration the table reports is exactly the one that is asserted
/// and timed.
///
/// Rank 3h/4 with K = 25: real transformer gradients at this tiny
/// scale are NOT as low-rank as the quadratic proxy's (the mini-batch
/// noise floor is broad), so rank h/2 leaves a ~10% loss gap while
/// 3h/4 sits within ~2% of dense AdamW — still at well under half the
/// bytes (oversampled sketches cap at min(m, n)).
pub fn lm_tsr_cfg(hidden: usize) -> TsrConfig {
    let rank = (3 * hidden / 4).max(4);
    TsrConfig {
        rank,
        rank_emb: rank,
        refresh_every: 25,
        refresh_emb: 25,
        oversample: 8,
        ..Default::default()
    }
}

/// The method roster: dense AdamW, TSR-Adam with the embedding
/// extension enabled ([`lm_tsr_cfg`]), GaLore-style one-sided, the
/// Sign/TopK compressed baselines, and the local-update family
/// (DES-LOC, LoRDO) — every family the paper's headline claim is
/// measured against, at ranks scaled to the LM's hidden size.
pub fn lm_methods(hidden: usize) -> Vec<MethodCfg> {
    let rank = (3 * hidden / 4).max(4);
    vec![
        MethodCfg::Adam,
        MethodCfg::Tsr(lm_tsr_cfg(hidden)),
        MethodCfg::OneSided {
            rank,
            k: 25,
            refresh: OneSidedRefresh::RandomizedSvd,
        },
        MethodCfg::Sign { k_var: 25 },
        MethodCfg::TopK { keep_frac: 0.05 },
        MethodCfg::DesLoc {
            k_p: 8,
            k_m: 32,
            k_v: 128,
        },
        MethodCfg::Lordo { rank, h: 8 },
    ]
}

/// One training run of `method` on the LM described by `cfg`, with
/// seeds matched across methods (same corpus, same streams, same init).
pub fn run_lm_method(
    cfg: &LmCurvesCfg,
    method: &MethodCfg,
    exec: &ExecBackend,
) -> crate::exp::runs::RunOutput {
    let spec = ModelSpec::proxy(cfg.vocab, cfg.hidden, cfg.inter, cfg.heads, cfg.layers);
    let mut source = LmSource::new(&spec, cfg.workers, cfg.batch, cfg.seq, cfg.seed);
    let blocks = source.blocks().to_vec();
    let hyper = AdamHyper {
        lr: cfg.lr,
        weight_decay: 0.0,
        scale: 1.0,
        ..Default::default()
    };
    let mut opt = method.build(&blocks, hyper, cfg.workers);
    let mut params = source.init_params(cfg.seed ^ 0xF00D);
    let topo = Topology::multi_node(2, cfg.workers.div_ceil(2));
    let trainer = Trainer::new(topo, LrSchedule::paper(cfg.steps)).with_backend(*exec);
    let (mut metrics, ledger) = trainer.run(&mut source, opt.as_mut(), &mut params, cfg.steps);
    metrics.name = method.label();
    crate::exp::runs::RunOutput {
        label: method.label(),
        metrics,
        ledger,
        state_elements: opt.state_elements(),
    }
}

/// The full sweep: one row per method. Prints the quality-vs-bytes
/// table and returns it as JSON (written to `results/lm_curves.json`
/// by the CLI).
pub fn lm_curves(cfg: &LmCurvesCfg, exec: &ExecBackend) -> Json {
    let floor = SyntheticCorpus::new(cfg.vocab, cfg.seed).unigram_entropy(200_000, 0xF1_00D);
    println!(
        "\nLM quality-vs-bytes — vocab {}, hidden {}, {} layers, {} workers, {} steps",
        cfg.vocab, cfg.hidden, cfg.layers, cfg.workers, cfg.steps
    );
    println!("unigram-entropy floor (context-free predictor): {floor:.4} nats");
    println!(
        "{:<22} {:>10} {:>12} {:>12} {:>12}",
        "method", "final", "vs adamw", "bytes/step", "cum bytes"
    );
    let mut rows = Vec::new();
    let mut adam_final: Option<f64> = None;
    for method in lm_methods(cfg.hidden) {
        let out = run_lm_method(cfg, &method, exec);
        let final_loss = out.metrics.final_loss() as f64;
        // Gap baseline matched by LABEL, not roster position, so a
        // reordered method list cannot silently rebase the column.
        if out.label == "adamw" {
            adam_final = Some(final_loss);
        }
        let base = adam_final.expect("lm_methods must run adamw before any gap is computed");
        let gap = (final_loss - base) / base;
        let cum = *out.metrics.cum_bytes.last().unwrap_or(&0);
        println!(
            "{:<22} {:>10.4} {:>11.2}% {:>12} {:>12}",
            out.label,
            final_loss,
            100.0 * gap,
            crate::util::bench::fmt_bytes(out.ledger.bytes_per_step()),
            crate::util::bench::fmt_bytes(cum as f64),
        );
        rows.push(Json::obj(vec![
            ("label", Json::str(out.label.clone())),
            ("final_loss", Json::num(final_loss)),
            ("loss_gap_vs_adamw", Json::num(gap)),
            ("beats_unigram_floor", Json::Bool(final_loss < floor)),
            ("bytes_per_step", Json::num(out.ledger.bytes_per_step())),
            ("peak_bytes", Json::num(out.ledger.peak_bytes() as f64)),
            ("cum_bytes", Json::num(cum as f64)),
            ("state_elements", Json::num(out.state_elements as f64)),
            (
                "loss",
                Json::Arr(out.metrics.loss.iter().map(|&l| Json::num(l as f64)).collect()),
            ),
        ]));
    }
    Json::obj(vec![
        ("experiment", Json::str("lm_curves")),
        ("vocab", Json::num(cfg.vocab as f64)),
        ("hidden", Json::num(cfg.hidden as f64)),
        ("layers", Json::num(cfg.layers as f64)),
        ("steps", Json::num(cfg.steps as f64)),
        ("workers", Json::num(cfg.workers as f64)),
        ("seed", crate::checkpoint::codec::u64_to_json(cfg.seed)),
        ("unigram_entropy_floor", Json::num(floor)),
        ("rows", Json::Arr(rows)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_emits_one_row_per_method_with_matched_seeds() {
        // Shortened sweep: the structure (row count, floor field, gap
        // sign conventions) is what this test pins; the 300-step quality
        // acceptance lives in tests/lm_train.rs.
        let cfg = LmCurvesCfg {
            steps: 6,
            workers: 2,
            batch: 2,
            seq: 8,
            ..Default::default()
        };
        let j = lm_curves(&cfg, &ExecBackend::Sequential);
        let rows = j.get("rows").as_arr().unwrap();
        assert_eq!(rows.len(), lm_methods(cfg.hidden).len());
        assert_eq!(rows[0].get_str("label", "?"), "adamw");
        assert_eq!(rows[0].get_f64("loss_gap_vs_adamw", 1.0), 0.0);
        assert!(j.get_f64("unigram_entropy_floor", 0.0) > 1.0);
        // TSR moves fewer bytes per step than dense AdamW even in a
        // short run that pays a refresh at step 0.
        let adam_bytes = rows[0].get_f64("bytes_per_step", 0.0);
        let tsr_bytes = rows[1].get_f64("bytes_per_step", f64::MAX);
        assert!(tsr_bytes < adam_bytes, "{tsr_bytes} vs {adam_bytes}");
    }
}
