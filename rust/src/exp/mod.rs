//! Experiment drivers: one entry point per paper table/figure
//! (DESIGN.md §3 maps ids → modules → CLI subcommands).

pub mod analytic;
pub mod figures;
pub mod finetune;
pub mod lm_curves;
pub mod runs;
pub mod simtime;
pub mod soak;
pub mod tables;
pub mod theory;

pub use analytic::{
    adamw_profile, desloc_profile, lordo_profile, lordo_profile_fmt, onesided_profile,
    onesided_profile_fmt, sign_profile, topk_profile, tsr_profile, tsr_profile_fmt, CommProfile,
    TsrParams,
};
pub use runs::{run_proxy, run_proxy_exec, MethodCfg, RunOutput};
