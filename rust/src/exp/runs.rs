//! Shared run helpers for the experiment drivers: method configs, proxy
//! scales (CPU-feasible stand-ins for 60M…1B — DESIGN.md §6), and the
//! run loop gluing QuadraticSim + optimizer + ledger.

use crate::comm::{CommLedger, ElemFmt, Topology};
use crate::metrics::RunMetrics;
use crate::model::{BlockSpec, ModelSpec};
use crate::optim::{
    AdamHyper, DenseAdamW, DesLoc, DistOptimizer, Lordo, LrSchedule, OneSidedAdam, PowerSgd,
    SignAdam, TopKAdam, TsrAdam, TsrConfig, TsrSgd,
};
use crate::optim::onesided::OneSidedRefresh;
use crate::train::gradsim::QuadraticSim;
use crate::train::{GradSource, Trainer};

/// A method under test, with everything needed to instantiate it.
#[derive(Clone, Debug)]
pub enum MethodCfg {
    Adam,
    OneSided {
        rank: usize,
        k: usize,
        refresh: OneSidedRefresh,
    },
    Tsr(TsrConfig),
    /// Algorithm 2: core-momentum SGD with the same two-sided refresh
    /// (lr taken from the Adam hyper-parameters, β = 0.9).
    TsrSgd(TsrConfig),
    PowerSgd {
        rank: usize,
    },
    /// 1-bit sign compression, dense variance refresh every `k_var`.
    Sign {
        k_var: usize,
    },
    /// Top-k sparse sync keeping `keep_frac` of each matrix block.
    TopK {
        keep_frac: f64,
    },
    /// DES-LOC: local AdamW steps with per-state sync periods — params
    /// every `k_p`, first moment every `k_m`, second moment every `k_v`.
    DesLoc {
        k_p: u64,
        k_m: u64,
        k_v: u64,
    },
    /// LoRDO: `h` local AdamW steps, then one warm-started rank-`rank`
    /// low-rank synchronization of the parameter deltas.
    Lordo {
        rank: usize,
        h: u64,
    },
}

impl MethodCfg {
    pub fn label(&self) -> String {
        match self {
            MethodCfg::Adam => "adamw".into(),
            MethodCfg::OneSided { rank, .. } => format!("onesided-r{rank}"),
            MethodCfg::Tsr(c) => format!("tsr-r{}({})-k{}", c.rank, c.rank_emb, c.refresh_every),
            MethodCfg::TsrSgd(c) => format!("tsr-sgd-r{}-k{}", c.rank, c.refresh_every),
            MethodCfg::PowerSgd { rank } => format!("powersgd-r{rank}"),
            MethodCfg::Sign { k_var } => format!("signadam-k{k_var}"),
            MethodCfg::TopK { keep_frac } => format!("topk-d{keep_frac:.3}"),
            MethodCfg::DesLoc { k_p, k_m, k_v } => format!("desloc-p{k_p}m{k_m}v{k_v}"),
            MethodCfg::Lordo { rank, h } => format!("lordo-r{rank}-h{h}"),
        }
    }

    /// The default-knob config for a CLI method name — the single
    /// method-name parser every front end dispatches through (mirrors
    /// [`crate::exec::ExecBackend::parse`]'s strictness: unknown names
    /// are rejected loudly with the full valid list, never defaulted).
    /// Knob flags (`--rank`, `--k`, `--k-p`, …) are applied on top by
    /// the caller.
    pub fn parse(name: &str) -> Result<MethodCfg, String> {
        match name.trim() {
            "adamw" => Ok(MethodCfg::Adam),
            "galore" | "onesided" => Ok(MethodCfg::OneSided {
                rank: 8,
                k: 50,
                refresh: OneSidedRefresh::RandomizedSvd,
            }),
            "tsr" => Ok(MethodCfg::Tsr(Self::default_tsr_cfg())),
            "tsr-sgd" | "tsrsgd" => Ok(MethodCfg::TsrSgd(Self::default_tsr_cfg())),
            "powersgd" => Ok(MethodCfg::PowerSgd { rank: 8 }),
            "signadam" => Ok(MethodCfg::Sign { k_var: 100 }),
            "topk" => Ok(MethodCfg::TopK { keep_frac: 0.01 }),
            "desloc" | "des-loc" => Ok(MethodCfg::DesLoc {
                k_p: 8,
                k_m: 32,
                k_v: 128,
            }),
            "lordo" => Ok(MethodCfg::Lordo { rank: 8, h: 8 }),
            other => Err(format!(
                "unknown method `{other}` (valid: adamw | galore | tsr | tsr-sgd | \
                 powersgd | signadam | topk | desloc | lordo)"
            )),
        }
    }

    fn default_tsr_cfg() -> TsrConfig {
        TsrConfig {
            rank: 8,
            rank_emb: 4,
            refresh_every: 50,
            refresh_emb: 50,
            oversample: 8,
            ..Default::default()
        }
    }

    pub fn build(
        &self,
        blocks: &[BlockSpec],
        hyper: AdamHyper,
        workers: usize,
    ) -> Box<dyn DistOptimizer> {
        match self {
            MethodCfg::Adam => Box::new(DenseAdamW::new(blocks, hyper)),
            MethodCfg::OneSided { rank, k, refresh } => {
                Box::new(OneSidedAdam::new(blocks, hyper, *rank, *k, *refresh))
            }
            MethodCfg::Tsr(cfg) => Box::new(TsrAdam::new(blocks, hyper, cfg.clone())),
            MethodCfg::TsrSgd(cfg) => Box::new(TsrSgd::new(blocks, hyper.lr, 0.9, cfg.clone())),
            MethodCfg::PowerSgd { rank } => {
                Box::new(PowerSgd::new(blocks, workers, hyper.lr, 0.9, *rank))
            }
            MethodCfg::Sign { k_var } => {
                Box::new(SignAdam::new(blocks, hyper, *k_var, workers))
            }
            MethodCfg::TopK { keep_frac } => {
                Box::new(TopKAdam::new(blocks, workers, hyper, *keep_frac))
            }
            MethodCfg::DesLoc { k_p, k_m, k_v } => {
                Box::new(DesLoc::new(blocks, hyper, workers, *k_p, *k_m, *k_v))
            }
            MethodCfg::Lordo { rank, h } => {
                Box::new(Lordo::new(blocks, hyper, workers, *rank, *h))
            }
        }
    }

    /// [`build`] with a payload element format (DESIGN.md §14): a
    /// non-f32 `core_fmt` narrows the steady low-rank payload of the
    /// methods that support it — TSR-Adam's r×r cores, the one-sided
    /// projected factor, LoRDO's delta factors — with per-worker error
    /// feedback. Other methods (and TSR-SGD, which has no EF path)
    /// ignore the format and sync f32, so their byte ledgers are
    /// untouched; at `F32` this is exactly `build`.
    pub fn build_with_fmt(
        &self,
        blocks: &[BlockSpec],
        hyper: AdamHyper,
        workers: usize,
        core_fmt: ElemFmt,
    ) -> Box<dyn DistOptimizer> {
        match self {
            MethodCfg::Tsr(cfg) if core_fmt != ElemFmt::F32 => {
                let mut cfg = cfg.clone();
                cfg.core_fmt = core_fmt;
                Box::new(TsrAdam::new(blocks, hyper, cfg))
            }
            MethodCfg::OneSided { rank, k, refresh } if core_fmt != ElemFmt::F32 => Box::new(
                OneSidedAdam::new(blocks, hyper, *rank, *k, *refresh).with_core_fmt(core_fmt),
            ),
            MethodCfg::Lordo { rank, h } if core_fmt != ElemFmt::F32 => {
                Box::new(Lordo::new(blocks, hyper, workers, *rank, *h).with_core_fmt(core_fmt))
            }
            _ => self.build(blocks, hyper, workers),
        }
    }
}

/// CPU-feasible proxy of a paper scale: hidden/4, vocab 2000, fewer
/// layers; rank configs scale down by the same factor so the rank/hidden
/// ratios match the paper's.
pub fn proxy_spec(scale: &str) -> ModelSpec {
    match scale {
        "60m" => ModelSpec::proxy(2000, 128, 344, 4, 4),
        "130m" => ModelSpec::proxy(2000, 192, 512, 6, 6),
        "350m" => ModelSpec::proxy(2000, 256, 684, 8, 6),
        "1b" => ModelSpec::proxy(2000, 384, 1024, 8, 6),
        other => panic!("unknown proxy scale {other}"),
    }
}

/// Paper rank configs mapped to proxy scale (divide by 4, like hidden).
pub fn proxy_tsr_cfg(scale: &str) -> TsrConfig {
    let (rank, rank_emb) = match scale {
        "60m" => (64, 16),
        "130m" => (96, 24),
        "350m" => (96, 32),
        "1b" => (128, 64),
        _ => (64, 16),
    };
    TsrConfig {
        rank,
        rank_emb,
        refresh_every: 100,
        refresh_emb: 100,
        oversample: 8,
        power_q: 1,
        ..Default::default()
    }
}

pub fn proxy_onesided_rank(scale: &str) -> usize {
    match scale {
        "60m" => 32,
        "130m" => 64,
        "350m" | "1b" => 64,
        _ => 32,
    }
}

pub struct RunOutput {
    pub label: String,
    pub metrics: RunMetrics,
    pub ledger: CommLedger,
    pub state_elements: usize,
}

/// Train `method` on the quadratic proxy for `steps` steps, on the
/// backend selected by `TSR_BACKEND` (so the whole experiment harness —
/// tables, figures, benches — flips to the threaded backend from the
/// environment). Backends are bitwise-identical, so every result is
/// reproducible either way.
pub fn run_proxy(
    spec: &ModelSpec,
    method: &MethodCfg,
    steps: usize,
    workers: usize,
    noise: f32,
    lr: f32,
    seed: u64,
) -> RunOutput {
    run_proxy_exec(
        spec,
        method,
        steps,
        workers,
        noise,
        lr,
        seed,
        crate::exec::ExecBackend::from_env(),
    )
}

/// [`run_proxy`] with an explicit execution backend — what the CLI's
/// `--backend` flag and the cross-backend parity suite drive.
pub fn run_proxy_exec(
    spec: &ModelSpec,
    method: &MethodCfg,
    steps: usize,
    workers: usize,
    noise: f32,
    lr: f32,
    seed: u64,
    exec: crate::exec::ExecBackend,
) -> RunOutput {
    // Intrinsic dimension ≥ the ranks under test: when r exceeds the
    // gradient's true rank, the surplus core coordinates carry pure
    // mini-batch noise and Adam's normalization amplifies them to full
    // step size (observed divergence; the paper's transformer gradients
    // never have rank below the configured r at these scales).
    let mut sim = QuadraticSim::new(spec, workers, (spec.hidden / 2).max(8), noise, seed);
    let blocks = sim.blocks().to_vec();
    let hyper = AdamHyper {
        lr,
        weight_decay: 0.0,
        scale: 1.0,
        ..Default::default()
    };
    let mut opt = method.build(&blocks, hyper, workers);
    let mut params = sim.init_params(seed ^ 0xF00D);
    let topo = Topology::multi_node(2, workers.div_ceil(2));
    let trainer = Trainer::new(topo, LrSchedule::paper(steps)).with_backend(exec);
    let (mut metrics, ledger) = trainer.run(&mut sim, opt.as_mut(), &mut params, steps);
    metrics.name = method.label();
    RunOutput {
        label: method.label(),
        metrics,
        ledger,
        state_elements: opt.state_elements(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_methods_train_on_proxy() {
        let spec = ModelSpec::proxy(200, 32, 64, 2, 2);
        let methods = [
            MethodCfg::Adam,
            MethodCfg::OneSided {
                rank: 8,
                k: 20,
                refresh: OneSidedRefresh::ExactSvd,
            },
            MethodCfg::Tsr(TsrConfig {
                rank: 8,
                rank_emb: 8,
                refresh_every: 20,
                refresh_emb: 20,
                oversample: 4,
                ..Default::default()
            }),
            MethodCfg::PowerSgd { rank: 8 },
            MethodCfg::Sign { k_var: 20 },
            MethodCfg::TopK { keep_frac: 0.05 },
            MethodCfg::DesLoc {
                k_p: 2,
                k_m: 4,
                k_v: 8,
            },
            MethodCfg::Lordo { rank: 8, h: 4 },
        ];
        for m in &methods {
            let out = run_proxy(&spec, m, 40, 2, 0.01, 0.05, 7);
            let first = out.metrics.loss[0];
            let last = out.metrics.final_loss();
            assert!(
                last < first,
                "{} did not descend: {first} -> {last}",
                out.label
            );
            assert!(out.state_elements > 0);
            assert_eq!(out.ledger.num_steps(), 40);
        }
    }

    #[test]
    fn tsr_uses_fewest_bytes() {
        let spec = ModelSpec::proxy(200, 32, 64, 2, 2);
        let adam = run_proxy(&spec, &MethodCfg::Adam, 10, 2, 0.0, 0.05, 1);
        let tsr = run_proxy(
            &spec,
            &MethodCfg::Tsr(TsrConfig {
                rank: 8,
                rank_emb: 8,
                refresh_every: 100,
                refresh_emb: 100,
                oversample: 4,
                ..Default::default()
            }),
            10,
            2,
            0.0,
            0.05,
            1,
        );
        assert!(tsr.ledger.bytes_per_step() < 0.35 * adam.ledger.bytes_per_step());
    }

    #[test]
    fn parse_accepts_all_nine_methods() {
        for (name, label_prefix) in [
            ("adamw", "adamw"),
            ("galore", "onesided-"),
            ("onesided", "onesided-"),
            ("tsr", "tsr-r"),
            ("tsr-sgd", "tsr-sgd-"),
            ("powersgd", "powersgd-"),
            ("signadam", "signadam-"),
            ("topk", "topk-"),
            ("desloc", "desloc-"),
            ("des-loc", "desloc-"),
            ("lordo", "lordo-"),
        ] {
            let m = MethodCfg::parse(name).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(
                m.label().starts_with(label_prefix),
                "{name} -> {}",
                m.label()
            );
        }
        // Whitespace is tolerated, same as ExecBackend::parse.
        assert!(MethodCfg::parse(" tsr ").is_ok());
    }

    #[test]
    fn parse_rejects_unknown_names_listing_all_nine() {
        let err = MethodCfg::parse("adamx").unwrap_err();
        for name in [
            "adamw", "galore", "tsr", "tsr-sgd", "powersgd", "signadam", "topk", "desloc",
            "lordo",
        ] {
            assert!(err.contains(name), "error `{err}` must list `{name}`");
        }
        assert!(err.contains("adamx"), "error must echo the bad name");
        assert!(MethodCfg::parse("").is_err());
    }

    /// DESIGN.md §14: the fmt-aware builder narrows exactly the three
    /// supported methods' steady plans and leaves everything else —
    /// including the F32 path — byte-identical to `build`.
    #[test]
    fn build_with_fmt_narrows_only_supported_methods() {
        let spec = ModelSpec::proxy(100, 16, 32, 2, 1);
        let blocks = spec.blocks();
        let hyper = AdamHyper::default();
        let methods = [
            MethodCfg::Adam,
            MethodCfg::OneSided {
                rank: 4,
                k: 50,
                refresh: OneSidedRefresh::ExactSvd,
            },
            MethodCfg::Tsr(TsrConfig {
                rank: 4,
                rank_emb: 4,
                refresh_every: 50,
                refresh_emb: 50,
                oversample: 2,
                ..Default::default()
            }),
            MethodCfg::Lordo { rank: 4, h: 1 },
            MethodCfg::Sign { k_var: 50 },
        ];
        for m in &methods {
            let base = m.build(&blocks, hyper, 2).sync_plan(1).total_bytes();
            let same = m
                .build_with_fmt(&blocks, hyper, 2, ElemFmt::F32)
                .sync_plan(1)
                .total_bytes();
            assert_eq!(base, same, "{}: F32 must delegate to build", m.label());
            let narrow = m
                .build_with_fmt(&blocks, hyper, 2, ElemFmt::Bf16)
                .sync_plan(1)
                .total_bytes();
            let supports = matches!(
                m,
                MethodCfg::Tsr(_) | MethodCfg::OneSided { .. } | MethodCfg::Lordo { .. }
            );
            if supports {
                assert!(narrow < base, "{}: bf16 must shrink the plan", m.label());
            } else {
                assert_eq!(narrow, base, "{}: must ignore the format", m.label());
            }
        }
    }

    #[test]
    fn parsed_methods_build_and_train() {
        // Every parseable name yields a config that instantiates and
        // takes a step at default knobs (small world, few steps).
        let spec = ModelSpec::proxy(100, 16, 32, 2, 1);
        for name in ["adamw", "desloc", "lordo"] {
            let m = MethodCfg::parse(name).unwrap();
            let out = run_proxy(&spec, &m, 3, 2, 0.0, 0.05, 5);
            assert_eq!(out.ledger.num_steps(), 3, "{name}");
        }
    }
}
