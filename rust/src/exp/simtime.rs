//! `tsr simtime` — the "Fig 6"-style step-time breakdown.
//!
//! Runs the discrete-event engine (`sim::engine`) over every method's
//! payload schedule on each cluster topology and reports, per method:
//! predicted step time, exposed (non-overlapped) communication, overlap
//! fraction, and the refresh-spike peak step. This is the wall-clock
//! story behind the byte tables: compressed methods win or lose on
//! *exposed* communication, and as the inter-node bandwidth rises the
//! regime turns latency-bound and TSR's advantage over dense AdamW
//! shrinks (paper §5 discussion).
//!
//! Loss is irrelevant here, so the real Table 5 shapes are used (the
//! schedules are counting identities); optimizers are constructed one at
//! a time with a single worker replica and dropped after their schedule
//! is consumed, keeping peak memory to one method's state. That state is
//! still model-scale (`--scale 1b` peaks at ~3× the 1.2B-param f32
//! footprint while TopKAdam's plans are extracted) — the price of
//! keeping `sync_plan` the single source of payload truth on the
//! optimizer itself rather than a parallel shape-only reimplementation
//! that could drift from `step()`.

use crate::comm::Topology;
use crate::exp::MethodCfg;
use crate::model::{BlockSpec, ModelSpec};
use crate::optim::onesided::OneSidedRefresh;
use crate::optim::{AdamHyper, SyncPlan, TsrConfig};
use crate::sim::{simulate_plans_adv, Adversity, MethodTimeline, SimCfg};
use crate::util::bench::{fmt_bytes, fmt_time};
use crate::util::json::Json;

/// The nine methods under test at paper ranks for `scale`.
pub fn method_roster(scale: &str) -> Vec<MethodCfg> {
    let (rank, rank_emb) = match scale {
        "60m" => (256, 64),
        "130m" => (384, 96),
        "350m" => (384, 128),
        "1b" => (512, 256),
        _ => (256, 64),
    };
    let onesided_rank = match scale {
        "60m" => 128,
        "1b" => 512,
        _ => 256,
    };
    let tsr = TsrConfig {
        rank,
        rank_emb,
        refresh_every: 100,
        refresh_emb: 100,
        oversample: 8,
        ..Default::default()
    };
    vec![
        MethodCfg::Adam,
        MethodCfg::OneSided {
            rank: onesided_rank,
            k: 200,
            refresh: OneSidedRefresh::RandomizedSvd,
        },
        MethodCfg::Tsr(tsr.clone()),
        MethodCfg::TsrSgd(tsr),
        MethodCfg::PowerSgd { rank: onesided_rank },
        MethodCfg::Sign { k_var: 1000 },
        MethodCfg::TopK { keep_frac: 0.01 },
        // Local-update family: mostly-zero-byte schedules with periodic
        // dense (DES-LOC) or low-rank (LoRDO) sync spikes — the engine
        // sees genuine zero-payload steps between them.
        MethodCfg::DesLoc {
            k_p: 16,
            k_m: 64,
            k_v: 256,
        },
        MethodCfg::Lordo {
            rank: onesided_rank,
            h: 30,
        },
    ]
}

/// Extract a method's payload schedule for `steps` steps. The optimizer
/// (whose moments/error buffers are model-scale) is built with a single
/// replica and dropped before returning — the plans are shape-only and
/// can be reused across every topology in the sweep.
pub fn method_plans(blocks: &[BlockSpec], method: &MethodCfg, steps: usize) -> Vec<SyncPlan> {
    let opt = method.build(blocks, AdamHyper::default(), 1);
    (0..steps.max(1)).map(|t| opt.sync_plan(t as u64)).collect()
}

/// One method's timeline row (shared with `exp::soak`).
pub fn timeline_json(label: &str, tl: &MethodTimeline) -> Json {
    Json::obj(vec![
        ("method", Json::str(label)),
        ("step_secs", Json::num(tl.avg_step_secs)),
        ("compute_secs", Json::num(tl.avg_compute_secs)),
        ("comm_busy_secs", Json::num(tl.avg_comm_busy_secs)),
        ("exposed_comm_secs", Json::num(tl.avg_exposed_secs)),
        ("peak_step_secs", Json::num(tl.peak_step_secs)),
        ("overlap_frac", Json::num(tl.overlap_frac)),
        ("payload_bytes_per_step", Json::num(tl.avg_payload_bytes)),
        ("straggler_idle_secs", Json::num(tl.avg_straggler_idle_secs)),
    ])
}

/// The full experiment: all nine methods × the three cluster shapes,
/// under an [`Adversity`] model (`Adversity::clean` for the nominal
/// figure — bitwise-identical to the pre-adversity output). The
/// per-method (plan extraction + three-topology simulation) cells are
/// independent, so the threaded backend fans them out over OS threads;
/// results are collected in roster order either way.
pub fn simtime(
    scale: &str,
    nodes: usize,
    gpus: usize,
    steps: usize,
    cfg: &SimCfg,
    exec: &crate::exec::ExecBackend,
    adv: &Adversity,
) -> Json {
    let spec = ModelSpec::by_name(scale).expect("unknown scale (60m|130m|350m|1b|roberta)");
    let topos = [
        ("single_node", Topology::single_node(nodes * gpus)),
        ("multi_node", Topology::multi_node(nodes, gpus)),
        ("ethernet", Topology::ethernet(nodes, gpus)),
    ];
    println!(
        "\nFig 6 — predicted step-time breakdown ({}, {} workers, {} steps, bucket {}, {})",
        spec.name,
        nodes * gpus,
        steps,
        fmt_bytes(cfg.bucket_bytes as f64),
        if cfg.overlap { "overlap" } else { "no overlap" },
    );
    if !adv.is_clean() {
        let jitter = match &adv.jitter {
            Some(j) => format!("amp {} seed {}", j.amp, j.seed),
            None => "off".into(),
        };
        println!(
            "  adversity: straggler pace {:.2}x, jitter {jitter}",
            adv.straggler.pace()
        );
    }
    // One optimizer build per method (state is model-scale); the
    // extracted schedules are reused across all three topologies.
    let blocks = spec.blocks();
    let roster = method_roster(scale);
    let per_method: Vec<(String, Vec<MethodTimeline>)> = exec.map_workers(roster.len(), |mi| {
        let m = &roster[mi];
        let plans = method_plans(&blocks, m, steps);
        let tls = topos
            .iter()
            .map(|(_, topo)| simulate_plans_adv(&plans, &blocks, topo, cfg, adv))
            .collect();
        (m.label(), tls)
    });
    let mut panels = Vec::new();
    for (ti, (tname, topo)) in topos.iter().enumerate() {
        println!(
            "\n  [{tname}] intra {} B/s, inter {} B/s",
            topo.intra_bw, topo.inter_bw
        );
        println!(
            "  {:<18} {:>12} {:>12} {:>12} {:>9} {:>12}",
            "method", "step", "exposed", "peak step", "overlap", "bytes/step"
        );
        let mut rows = Vec::new();
        for (label, tls) in &per_method {
            let tl = &tls[ti];
            println!(
                "  {:<18} {:>12} {:>12} {:>12} {:>8.1}% {:>12}",
                label,
                fmt_time(tl.avg_step_secs),
                fmt_time(tl.avg_exposed_secs),
                fmt_time(tl.peak_step_secs),
                100.0 * tl.overlap_frac,
                fmt_bytes(tl.avg_payload_bytes),
            );
            rows.push(timeline_json(label, tl));
        }
        panels.push(Json::obj(vec![
            ("topology", Json::str(*tname)),
            ("inter_bw", Json::num(topo.inter_bw)),
            ("methods", Json::Arr(rows)),
        ]));
    }
    Json::obj(vec![
        ("scale", Json::str(scale)),
        ("workers", Json::num((nodes * gpus) as f64)),
        ("steps", Json::num(steps as f64)),
        ("bucket_bytes", Json::num(cfg.bucket_bytes as f64)),
        ("overlap", Json::Bool(cfg.overlap)),
        ("panels", Json::Arr(panels)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::simulate_plans;

    #[test]
    fn roster_has_nine_methods() {
        assert_eq!(method_roster("60m").len(), 9);
    }

    // The §5 regime assertion (TSR's exposed-comm advantage over dense
    // AdamW shrinks as inter_bw rises) lives in `tests/sim_engine.rs::
    // tsr_exposed_advantage_shrinks_with_inter_bandwidth` on a cheap
    // proxy spec — not duplicated here at model scale.

    #[test]
    fn plans_extracted_once_drive_all_topologies() {
        let spec = ModelSpec::proxy(200, 16, 32, 2, 2);
        let blocks = spec.blocks();
        let cfg = SimCfg::default();
        for m in method_roster("60m") {
            let plans = method_plans(&blocks, &m, 6);
            assert_eq!(plans.len(), 6);
            for topo in [Topology::single_node(8), Topology::ethernet(2, 4)] {
                let tl = simulate_plans(&plans, &blocks, &topo, &cfg);
                assert!(tl.avg_step_secs > 0.0, "{}", m.label());
                assert!(tl.avg_payload_bytes > 0.0, "{}", m.label());
            }
        }
    }
}
