//! `tsr soak` — the resilience sweep (DESIGN.md §11).
//!
//! Sweeps worker counts × cluster shapes × adversity scenarios for six
//! headline methods (dense AdamW, one-sided low-rank, TSR, TopK, plus
//! the local-update DES-LOC and LoRDO):
//!
//! * **clean / straggler / jitter** — timing cells from the
//!   discrete-event engine under the seeded `sim::adversity` models:
//!   predicted step time, exposed communication, peak bytes, and idle
//!   straggler capacity;
//! * **kill_resume** — one failure-injection [`Drill`] per cell: the
//!   run is killed at `kill_at` through the checkpoint subsystem and
//!   resumed twice — same world size (asserted **bitwise** against the
//!   uninterrupted run) and at [`elastic_partner`] workers (asserted
//!   within the loss-trajectory tolerance);
//! * **trace** — one traced TSR drill cell (DESIGN.md §16): the
//!   deterministic trace is asserted byte-identical across a repeat of
//!   the cell, and the kill+resume run's trace tail is asserted to
//!   splice exactly onto the uninterrupted run's.
//!
//! Everything is seeded; the emitted JSON is byte-identical across
//! repeat runs and across execution backends (CI's `soak-smoke` leg
//! diffs both). The sweep also *asserts* the paper-facing sanity
//! property: a straggler costs dense AdamW strictly more predicted
//! step time than TSR on the multi-node and Ethernet shapes (the
//! exposed-comm advantage survives adversity).
//!
//! Timing cells run on the CPU-feasible proxy shapes (`runs::
//! proxy_spec` — hidden/4 with ranks scaled to match the paper's
//! rank/hidden ratios); drills run on the tiny quadratic source.

use crate::checkpoint::codec;
use crate::comm::Topology;
use crate::exec::ExecBackend;
use crate::exp::runs::{proxy_onesided_rank, proxy_spec, proxy_tsr_cfg};
use crate::exp::simtime::{method_plans, timeline_json};
use crate::exp::MethodCfg;
use crate::optim::onesided::OneSidedRefresh;
use crate::optim::{SyncPlan, TsrConfig};
use crate::resilience::{elastic_partner, Drill, DrillCfg};
use crate::sim::{
    simulate_plans_adv, Adversity, JitterModel, MethodTimeline, SimCfg, StragglerModel,
};
use crate::util::bench::fmt_time;
use crate::util::json::Json;

/// Sweep configuration (defaults match the CLI's).
#[derive(Clone, Debug)]
pub struct SoakCfg {
    /// Proxy scale for the timing cells (60m|130m|350m|1b).
    pub scale: String,
    pub workers_list: Vec<usize>,
    /// Total steps of each drill's reference run.
    pub steps: usize,
    /// Kill step for the drills (mid-refresh-period by default).
    pub kill_at: usize,
    /// Schedule horizon for the timing cells (covers refresh spikes).
    pub plan_steps: usize,
    pub seed: u64,
    /// Compute multiplier of the single straggler in the straggler
    /// scenario.
    pub straggler_mult: f64,
    /// Link-jitter amplitude in the jitter scenario.
    pub jitter_amp: f64,
    /// Worker counts above this skip the (training-loop) drills; the
    /// skip is logged, never silent.
    pub drill_cap: usize,
    /// Relative loss-trajectory tolerance for elastic resumes.
    pub elastic_tol: f64,
    pub sim: SimCfg,
}

impl Default for SoakCfg {
    fn default() -> Self {
        Self {
            scale: "60m".into(),
            workers_list: vec![4, 8],
            steps: 16,
            kill_at: 7,
            plan_steps: 30,
            seed: 42,
            straggler_mult: 2.0,
            jitter_amp: 0.5,
            drill_cap: 16,
            elastic_tol: 0.5,
            sim: SimCfg::default(),
        }
    }
}

const SCENARIOS: [&str; 3] = ["clean", "straggler", "jitter"];
const TOPO_KINDS: [&str; 3] = ["single_node", "multi_node", "ethernet"];

/// The three cluster shapes at a given worker count (same node/GPU
/// split rule as `tsr train`: w/8 nodes of 8 when that divides evenly,
/// else two nodes).
fn topo_for(kind: &str, workers: usize) -> Topology {
    let (nodes, gpus) = if workers >= 16 && workers % 8 == 0 {
        (workers / 8, 8)
    } else {
        (2, workers.div_ceil(2))
    };
    match kind {
        "single_node" => Topology::single_node(workers),
        "multi_node" => Topology::multi_node(nodes, gpus),
        "ethernet" => Topology::ethernet(nodes, gpus),
        other => panic!("unknown topology kind {other}"),
    }
}

/// Timing roster: AdamW, one-sided, TSR, TopK, DES-LOC, LoRDO at proxy
/// ranks. Index order is load-bearing — the straggler self-check reads
/// AdamW at 0 and TSR at 2, so new methods append at the end.
fn timing_methods(scale: &str) -> Vec<MethodCfg> {
    vec![
        MethodCfg::Adam,
        MethodCfg::OneSided {
            rank: proxy_onesided_rank(scale),
            k: 200,
            refresh: OneSidedRefresh::RandomizedSvd,
        },
        MethodCfg::Tsr(proxy_tsr_cfg(scale)),
        MethodCfg::TopK { keep_frac: 0.01 },
        MethodCfg::DesLoc {
            k_p: 8,
            k_m: 32,
            k_v: 128,
        },
        MethodCfg::Lordo {
            rank: proxy_onesided_rank(scale),
            h: 8,
        },
    ]
}

/// Drill roster: the same families at drill-sized ranks, refresh
/// period `k` (the default `kill_at = 7` lands mid-period for k = 5,
/// and mid-local-phase for the DES-LOC/LoRDO cadences below).
fn drill_methods(k: usize) -> Vec<MethodCfg> {
    let tsr = TsrConfig {
        rank: 8,
        rank_emb: 4,
        refresh_every: k,
        refresh_emb: k,
        oversample: 3,
        ..Default::default()
    };
    vec![
        MethodCfg::Adam,
        MethodCfg::OneSided {
            rank: 6,
            k,
            refresh: OneSidedRefresh::ExactSvd,
        },
        MethodCfg::Tsr(tsr),
        MethodCfg::TopK { keep_frac: 0.05 },
        MethodCfg::DesLoc { k_p: 2, k_m: 4, k_v: 8 },
        MethodCfg::Lordo { rank: 6, h: 3 },
    ]
}

/// One traced drill cell (DESIGN.md §16): a tiny TSR run with a
/// deterministic tracer attached, proven byte-identical across a repeat
/// of the whole cell, plus a same-world kill+resume whose trace tail
/// must splice onto the full run's. Panics on any violation; returns
/// the deterministic trace summary for the soak JSON (diffed by CI
/// across repeats and backends like every other soak row).
fn trace_cell(cfg: &SoakCfg, exec: ExecBackend) -> Json {
    let workers = 2usize;
    let method = MethodCfg::Tsr(TsrConfig {
        rank: 8,
        rank_emb: 4,
        refresh_every: 5,
        refresh_emb: 5,
        oversample: 3,
        ..Default::default()
    });
    let make = || {
        let mut dc = DrillCfg::quick(method.clone(), workers, cfg.steps, cfg.kill_at);
        dc.seed = cfg.seed;
        dc.exec = exec;
        dc.trace = true;
        dc
    };
    let drill = Drill::prepare(make());
    let report = drill.resume(workers);
    report.assert_contract(cfg.elastic_tol);
    assert_eq!(
        report.trace_tail_match,
        Some(true),
        "trace cell: resumed trace tail diverged from the full run's"
    );

    let jsonl = |recs: &[Json]| -> String { recs.iter().map(|r| r.to_string() + "\n").collect() };
    let full = drill.full_trace().expect("traced drill has a trace");
    let again = Drill::prepare(make());
    assert_eq!(
        jsonl(full),
        jsonl(again.full_trace().expect("traced drill has a trace")),
        "trace cell: repeat run's trace not byte-identical"
    );
    println!(
        "  trace cell: {} records — repeat byte-identical, resume tail spliced",
        full.len()
    );

    Json::obj(vec![
        ("method", Json::str(method.label())),
        ("workers", Json::num(workers as f64)),
        ("records", Json::num(full.len() as f64)),
        ("repeat_identical", Json::Bool(true)),
        ("resume_tail_match", Json::Bool(true)),
        ("summary", crate::obs::analyze::summarize(full)),
    ])
}

fn adversity_for(scenario: &str, workers: usize, cfg: &SoakCfg) -> Adversity {
    match scenario {
        "clean" => Adversity::clean(workers),
        "straggler" => Adversity {
            straggler: StragglerModel::single(workers, cfg.straggler_mult),
            jitter: None,
        },
        "jitter" => Adversity {
            straggler: StragglerModel::none(workers),
            jitter: Some(JitterModel {
                seed: cfg.seed,
                amp: cfg.jitter_amp,
            }),
        },
        other => panic!("unknown scenario {other}"),
    }
}

/// Run the full sweep; returns the deterministic JSON table. Panics if
/// any drill breaks its verification tier or the straggler ordering
/// self-check fails — a soak that "succeeds" has proven its claims.
pub fn soak(cfg: &SoakCfg, exec: ExecBackend) -> Json {
    let spec = proxy_spec(&cfg.scale);
    let blocks = spec.blocks();
    let methods = timing_methods(&cfg.scale);
    println!(
        "\nsoak — resilience sweep ({} proxy, workers {:?}, horizon {}, drills {} steps kill@{}, seed {})",
        spec.name, cfg.workers_list, cfg.plan_steps, cfg.steps, cfg.kill_at, cfg.seed
    );

    // Schedules are shape-only: extract once per method, reuse across
    // every (workers × topology × scenario) cell.
    let plans: Vec<(String, Vec<SyncPlan>, usize)> = exec.map_workers(methods.len(), |mi| {
        let m = &methods[mi];
        let p = method_plans(&blocks, m, cfg.plan_steps);
        let peak = p.iter().map(|pl| pl.total_bytes()).max().unwrap_or(0);
        (m.label(), p, peak)
    });

    // ---- timing cells: clean / straggler / jitter ----
    let mut cells: Vec<(usize, &str, &str, usize, MethodTimeline)> = Vec::new();
    for &w in &cfg.workers_list {
        for kind in TOPO_KINDS {
            let topo = topo_for(kind, w);
            for scenario in SCENARIOS {
                let adv = adversity_for(scenario, topo.workers(), cfg);
                for (mi, (_, p, _)) in plans.iter().enumerate() {
                    let tl = simulate_plans_adv(p, &blocks, &topo, &cfg.sim, &adv);
                    cells.push((w, kind, scenario, mi, tl));
                }
            }
        }
    }
    let step_of = |w: usize, kind: &str, scenario: &str, mi: usize| -> f64 {
        cells
            .iter()
            .find(|c| c.0 == w && c.1 == kind && c.2 == scenario && c.3 == mi)
            .expect("cell exists")
            .4
            .avg_step_secs
    };

    // Self-check (acceptance criterion): on the shapes where cross-node
    // bytes matter, a straggler must cost dense AdamW strictly more
    // step time than TSR — the exposed-comm advantage survives.
    for &w in &cfg.workers_list {
        for kind in ["multi_node", "ethernet"] {
            let d_adam = step_of(w, kind, "straggler", 0) - step_of(w, kind, "clean", 0);
            let d_tsr = step_of(w, kind, "straggler", 2) - step_of(w, kind, "clean", 2);
            assert!(
                d_adam > d_tsr && d_tsr >= 0.0,
                "straggler hurt AdamW no more than TSR ({kind}, {w} workers): \
                 Δadamw {d_adam} vs Δtsr {d_tsr}"
            );
            println!(
                "  [{kind:<11} w={w:<3}] straggler Δstep  adamw {}  tsr {}",
                fmt_time(d_adam),
                fmt_time(d_tsr)
            );
        }
    }

    let cell_rows: Vec<Json> = cells
        .iter()
        .map(|(w, kind, scenario, mi, tl)| {
            let mut row = timeline_json(&plans[*mi].0, tl);
            row.set("workers", Json::num(*w as f64));
            row.set("topology", Json::str(*kind));
            row.set("scenario", Json::str(*scenario));
            row.set("peak_bytes", Json::num(plans[*mi].2 as f64));
            row
        })
        .collect();

    // ---- kill + resume drills ----
    let mut drill_specs: Vec<(usize, &str, MethodCfg)> = Vec::new();
    for &w in &cfg.workers_list {
        if w > cfg.drill_cap {
            println!(
                "  soak: skipping kill+resume drills at {w} workers (> drill cap {})",
                cfg.drill_cap
            );
            continue;
        }
        for kind in TOPO_KINDS {
            for m in drill_methods(5) {
                drill_specs.push((w, kind, m));
            }
        }
    }
    let drill_rows: Vec<Vec<Json>> = exec.map_workers(drill_specs.len(), |i| {
        let (w, kind, m) = &drill_specs[i];
        let mut dc = DrillCfg::quick(m.clone(), *w, cfg.steps, cfg.kill_at);
        dc.seed = cfg.seed;
        dc.topo = topo_for(kind, *w);
        dc.exec = exec;
        let drill = Drill::prepare(dc);
        let same = drill.resume(*w);
        same.assert_contract(cfg.elastic_tol);
        let elastic = drill.resume(elastic_partner(*w));
        elastic.assert_contract(cfg.elastic_tol);
        [same, elastic]
            .iter()
            .map(|r| {
                let mut row = r.to_json();
                row.set("workers", Json::num(*w as f64));
                row.set("topology", Json::str(*kind));
                row.set("scenario", Json::str("kill_resume"));
                row
            })
            .collect()
    });
    let drills: Vec<Json> = drill_rows.into_iter().flatten().collect();
    println!(
        "  drills: {} kill+resume cells ({} rows) — bitwise + elastic contracts held",
        drill_specs.len(),
        drills.len()
    );

    // ---- traced drill cell (trace determinism + resume splice) ----
    let trace = trace_cell(cfg, exec);

    Json::obj(vec![
        ("scale", Json::str(cfg.scale.clone())),
        ("spec", Json::str(spec.name.clone())),
        (
            "workers",
            Json::Arr(cfg.workers_list.iter().map(|&w| Json::num(w as f64)).collect()),
        ),
        ("plan_steps", Json::num(cfg.plan_steps as f64)),
        ("drill_steps", Json::num(cfg.steps as f64)),
        ("kill_at", Json::num(cfg.kill_at as f64)),
        ("seed", codec::u64_to_json(cfg.seed)),
        ("straggler_mult", Json::num(cfg.straggler_mult)),
        ("jitter_amp", Json::num(cfg.jitter_amp)),
        ("elastic_tol", Json::num(cfg.elastic_tol)),
        ("bucket_bytes", Json::num(cfg.sim.bucket_bytes as f64)),
        ("cells", Json::Arr(cell_rows)),
        ("drills", Json::Arr(drills)),
        ("trace_cell", trace),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topo_for_covers_all_kinds_at_any_worker_count() {
        for w in [2usize, 4, 7, 16, 24] {
            assert_eq!(topo_for("single_node", w).workers(), w);
            assert!(topo_for("multi_node", w).nodes > 1);
            assert!(topo_for("ethernet", w).inter_bw < 16e9 + 1.0);
        }
        assert_eq!(topo_for("multi_node", 16).nodes, 2);
        assert_eq!(topo_for("multi_node", 24).nodes, 3);
    }

    #[test]
    fn rosters_are_six_methods_with_fixed_indices() {
        let t = timing_methods("60m");
        assert_eq!(t.len(), 6);
        assert!(matches!(t[0], MethodCfg::Adam));
        assert!(matches!(t[2], MethodCfg::Tsr(_)));
        assert!(matches!(t[4], MethodCfg::DesLoc { .. }));
        assert!(matches!(t[5], MethodCfg::Lordo { .. }));
        let d = drill_methods(5);
        assert_eq!(d.len(), 6);
        assert!(matches!(d[0], MethodCfg::Adam));
        assert!(matches!(d[5], MethodCfg::Lordo { .. }));
    }
}
