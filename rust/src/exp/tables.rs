//! Regenerators for the paper's tables (see DESIGN.md §3 for the index).
//!
//! Byte and memory columns come from the *exact* analytic profiles;
//! loss columns come from CPU-feasible proxy training runs (documented
//! substitution); update-time columns are measured on this host.

use super::analytic::{
    adamw_profile, desloc_profile, lordo_profile, onesided_profile, sign_profile, table1_row,
    topk_profile, tsr_profile, TsrParams,
};
use super::runs::{proxy_onesided_rank, proxy_spec, proxy_tsr_cfg, run_proxy, MethodCfg};
use crate::model::{memory_bytes, memory_bytes_error_feedback, Method, ModelSpec};
use crate::optim::onesided::OneSidedRefresh;
use crate::optim::{AdamHyper, DistOptimizer, StepCtx, TsrConfig};
use crate::util::bench::fmt_bytes;
use crate::util::json::Json;
use std::time::Instant;

/// Table 1: communication objects + scaling for one m×n matrix gradient.
pub fn table1(m: usize, n: usize, r: usize) -> Json {
    println!("\nTable 1 — synchronized object for G ∈ R^{m}×{n}, rank r={r}");
    println!("{:<22} {:>14} {:>12}", "METHOD", "ELEMENTS", "SCALING");
    let scalings = ["O(mn)", "O(r(m+n))", "O(rn)", "O(r^2)"];
    let mut rows = Vec::new();
    for (row, scale) in table1_row(m, n, r).iter().zip(scalings) {
        println!("{:<22} {:>14} {:>12}", row.0, row.1, scale);
        rows.push(Json::obj(vec![
            ("method", Json::str(row.0.clone())),
            ("elements", Json::num(row.1 as f64)),
            ("scaling", Json::str(scale)),
        ]));
    }
    Json::obj(vec![
        ("m", Json::num(m as f64)),
        ("n", Json::num(n as f64)),
        ("r", Json::num(r as f64)),
        ("rows", Json::Arr(rows)),
    ])
}

/// Table 2: weights + optimizer-state parameter counts per method.
pub fn table2(spec: &ModelSpec, r: usize, r_emb: usize) -> Json {
    println!(
        "\nTable 2 — parameter/state counts for {} (r={r}, r_emb={r_emb})",
        spec.name
    );
    println!(
        "{:<12} {:>16} {:>18} {:>12}",
        "METHOD", "WEIGHTS", "OPT STATE", "STATE/ADAM"
    );
    let mut rows = Vec::new();
    let adam_state = crate::model::model_footprint(spec, Method::Adam, r, r_emb).1;
    for (m, name) in [
        (Method::Adam, "ADAM"),
        (Method::Lora, "LORA"),
        (Method::OneSided, "ONE-SIDED"),
        (Method::Tsr, "TSR"),
    ] {
        let (w, s) = crate::model::model_footprint(spec, m, r, r_emb);
        println!(
            "{:<12} {:>16} {:>18} {:>11.3}x",
            name,
            w,
            s,
            s as f64 / adam_state as f64
        );
        rows.push(Json::obj(vec![
            ("method", Json::str(name)),
            ("weights", Json::num(w as f64)),
            ("state", Json::num(s as f64)),
        ]));
    }
    Json::obj(vec![("model", Json::str(spec.name.clone())), ("rows", Json::Arr(rows))])
}

/// Paper Table 3 configurations (scale, adam-, galore-, tsr-specific).
pub struct Table3Cfg {
    pub scale: &'static str,
    pub galore_rank: usize,
    pub galore_k: usize,
    pub tsr_rank: usize,
    pub tsr_rank_emb: usize,
    pub tsr_k: usize,
    /// Paper-reported values for side-by-side printing.
    pub paper: [(&'static str, f64, f64); 3], // (method, bytes/step G, peak G)
}

pub fn table3_configs() -> Vec<Table3Cfg> {
    vec![
        Table3Cfg {
            scale: "60m",
            galore_rank: 128,
            galore_k: 200,
            tsr_rank: 256,
            tsr_rank_emb: 64,
            tsr_k: 100,
            paper: [
                ("adamw", 0.17, 0.17),
                ("galore", 0.10, 0.14),
                ("tsr", 0.020, 0.10),
            ],
        },
        Table3Cfg {
            scale: "130m",
            galore_rank: 256,
            galore_k: 200,
            tsr_rank: 384,
            tsr_rank_emb: 96,
            tsr_k: 100,
            paper: [
                ("adamw", 0.44, 0.44),
                ("galore", 0.21, 0.36),
                ("tsr", 0.058, 0.31),
            ],
        },
        Table3Cfg {
            scale: "350m",
            galore_rank: 256,
            galore_k: 200,
            tsr_rank: 384,
            tsr_rank_emb: 128,
            tsr_k: 100,
            paper: [
                ("adamw", 1.34, 1.34),
                ("galore", 0.44, 0.98),
                ("tsr", 0.11, 0.79),
            ],
        },
        Table3Cfg {
            scale: "1b",
            galore_rank: 512,
            galore_k: 200,
            tsr_rank: 512,
            tsr_rank_emb: 256,
            tsr_k: 100,
            paper: [
                ("adamw", 5.09, 5.09),
                ("galore", 1.48, 3.63),
                ("tsr", 0.21, 2.05),
            ],
        },
    ]
}

/// Measure one optimizer step's wall time at FULL paper scale (this
/// host): gradients are synthesized once, then the step is timed.
fn measure_update_time(spec: &ModelSpec, method: &MethodCfg, workers: usize) -> f64 {
    use crate::comm::{CommLedger, Topology};
    use crate::train::gradsim::QuadraticSim;
    use crate::train::GradSource;
    let mut sim = QuadraticSim::new(spec, workers, 8, 0.0, 0xBEEF);
    let blocks = sim.blocks().to_vec();
    let mut params = sim.init_params(3);
    let mut grads = crate::optim::alloc_worker_grads(&blocks, workers);
    sim.compute(&params, 0, &mut grads);
    let mut opt = method.build(&blocks, AdamHyper::default(), workers);
    let topo = Topology::multi_node(2, workers.div_ceil(2));
    let mut ledger = CommLedger::new();
    // Warm (includes the init refresh), then time the steady-state step.
    let exec = crate::exec::ExecBackend::from_env();
    let mut run_once = |params: &mut Vec<crate::linalg::Matrix>,
                        grads: &mut Vec<Vec<crate::linalg::Matrix>>,
                        ledger: &mut CommLedger| {
        let mut ctx = StepCtx {
            params,
            grads,
            ledger,
            topo: &topo,
            lr_mult: 1.0,
            exec: &exec,
        };
        opt.step(&mut ctx);
        ledger.end_step();
    };
    run_once(&mut params, &mut grads, &mut ledger);
    let t0 = Instant::now();
    run_once(&mut params, &mut grads, &mut ledger);
    t0.elapsed().as_secs_f64()
}

/// Compressed-communication baseline settings used in the extended
/// Table 3 rows (paper-less: the source paper does not report these
/// families, so the columns show our exact byte profiles side by side).
pub const TABLE3_SIGN_KVAR: usize = 1000;
pub const TABLE3_TOPK_FRAC: f64 = 0.005;
/// Local-update baseline settings for the extended Table 3 rows:
/// DES-LOC per-state periods (params/m/v) and LoRDO's local horizon.
pub const TABLE3_DESLOC_KP: u64 = 16;
pub const TABLE3_DESLOC_KM: u64 = 64;
pub const TABLE3_DESLOC_KV: u64 = 256;
pub const TABLE3_LORDO_H: u64 = 30;

/// Table 3: byte/memory columns exact; loss from proxy training; update
/// time measured on this host. `loss_steps = 0` skips the training runs
/// (bytes/memory only — used by fast benches). Beyond the paper's three
/// methods, two compressed-communication baselines are included:
/// SignAdam (1-bit + error feedback) and TopKAdam (extreme sparsity).
pub fn table3(loss_steps: usize, measure_time: bool) -> Json {
    const G: f64 = 1024.0 * 1024.0 * 1024.0;
    println!("\nTable 3 — main results (bytes/memory exact; loss on proxy scale)");
    println!(
        "{:<6} {:<9} {:>10} {:>5} {:>11} {:>11} {:>9} {:>9} {:>10} {:>10}",
        "SCALE", "METHOD", "RANK", "K", "BYTES/STEP", "(paper)", "PEAK", "(paper)", "MEMORY", "UPD TIME"
    );
    // One entry per table row: every per-method artifact (byte profile,
    // memory, the full-scale config for update timing, the proxy-scale
    // config for the loss column) lives in a single name-keyed record so
    // columns cannot be attributed to the wrong method by index drift.
    struct Row {
        name: &'static str,
        prof: super::analytic::CommProfile,
        mem: u64,
        rank: String,
        k: usize,
        full: MethodCfg,
        proxy: MethodCfg,
    }

    let mut rows = Vec::new();
    for cfg in table3_configs() {
        let spec = ModelSpec::by_name(cfg.scale).unwrap();
        let table_rows = vec![
            Row {
                name: "adamw",
                prof: adamw_profile(&spec),
                mem: memory_bytes(&spec, Method::Adam, 0, 0),
                rank: "-".to_string(),
                k: 0,
                full: MethodCfg::Adam,
                proxy: MethodCfg::Adam,
            },
            Row {
                name: "galore",
                prof: onesided_profile(&spec, cfg.galore_rank, cfg.galore_k),
                mem: memory_bytes(&spec, Method::OneSided, cfg.galore_rank, cfg.galore_rank),
                rank: format!("{}", cfg.galore_rank),
                k: cfg.galore_k,
                full: MethodCfg::OneSided {
                    rank: cfg.galore_rank,
                    k: cfg.galore_k,
                    refresh: OneSidedRefresh::RandomizedSvd,
                },
                proxy: MethodCfg::OneSided {
                    rank: proxy_onesided_rank(cfg.scale),
                    k: cfg.galore_k,
                    refresh: OneSidedRefresh::RandomizedSvd,
                },
            },
            Row {
                name: "tsr",
                prof: tsr_profile(
                    &spec,
                    TsrParams {
                        rank: cfg.tsr_rank,
                        k_refresh: cfg.tsr_k,
                        rank_emb: cfg.tsr_rank_emb,
                        k_refresh_emb: cfg.tsr_k,
                        oversample: 8,
                    },
                ),
                mem: memory_bytes(&spec, Method::Tsr, cfg.tsr_rank, cfg.tsr_rank_emb),
                rank: format!("{}({})", cfg.tsr_rank, cfg.tsr_rank_emb),
                k: cfg.tsr_k,
                full: MethodCfg::Tsr(TsrConfig {
                    rank: cfg.tsr_rank,
                    rank_emb: cfg.tsr_rank_emb,
                    refresh_every: cfg.tsr_k,
                    refresh_emb: cfg.tsr_k,
                    oversample: 8,
                    ..Default::default()
                }),
                proxy: MethodCfg::Tsr(proxy_tsr_cfg(cfg.scale)),
            },
            // The compressed baselines carry dense Adam moments plus one
            // per-device error-feedback residual per matrix block; their
            // schedule/density is identical at full and proxy scale.
            Row {
                name: "signadam",
                prof: sign_profile(&spec, TABLE3_SIGN_KVAR),
                mem: memory_bytes_error_feedback(&spec),
                rank: "-".to_string(),
                k: TABLE3_SIGN_KVAR,
                full: MethodCfg::Sign {
                    k_var: TABLE3_SIGN_KVAR,
                },
                proxy: MethodCfg::Sign {
                    k_var: TABLE3_SIGN_KVAR,
                },
            },
            Row {
                name: "topk",
                prof: topk_profile(&spec, TABLE3_TOPK_FRAC),
                mem: memory_bytes_error_feedback(&spec),
                rank: format!("{:.1}%", TABLE3_TOPK_FRAC * 100.0),
                k: 0,
                full: MethodCfg::TopK {
                    keep_frac: TABLE3_TOPK_FRAC,
                },
                proxy: MethodCfg::TopK {
                    keep_frac: TABLE3_TOPK_FRAC,
                },
            },
            // Local-update baselines: per-device state is a full dense
            // Adam triple (replica + m + v; LoRDO adds only the n×r warm
            // factor), so the memory column is the dense-Adam figure.
            Row {
                name: "desloc",
                prof: desloc_profile(&spec, TABLE3_DESLOC_KP, TABLE3_DESLOC_KM, TABLE3_DESLOC_KV),
                mem: memory_bytes(&spec, Method::Adam, 0, 0),
                rank: "-".to_string(),
                k: TABLE3_DESLOC_KP as usize,
                full: MethodCfg::DesLoc {
                    k_p: TABLE3_DESLOC_KP,
                    k_m: TABLE3_DESLOC_KM,
                    k_v: TABLE3_DESLOC_KV,
                },
                proxy: MethodCfg::DesLoc {
                    k_p: TABLE3_DESLOC_KP,
                    k_m: TABLE3_DESLOC_KM,
                    k_v: TABLE3_DESLOC_KV,
                },
            },
            Row {
                name: "lordo",
                prof: lordo_profile(&spec, cfg.galore_rank, TABLE3_LORDO_H),
                mem: memory_bytes(&spec, Method::Adam, 0, 0),
                rank: format!("{}", cfg.galore_rank),
                k: TABLE3_LORDO_H as usize,
                full: MethodCfg::Lordo {
                    rank: cfg.galore_rank,
                    h: TABLE3_LORDO_H,
                },
                proxy: MethodCfg::Lordo {
                    rank: proxy_onesided_rank(cfg.scale),
                    h: TABLE3_LORDO_H,
                },
            },
        ];
        // Every paper reference entry must align with a table row — a
        // name typo would otherwise silently drop a paper column.
        for (pname, _, _) in &cfg.paper {
            assert!(
                table_rows.iter().any(|r| r.name == *pname),
                "paper entry {pname} has no matching table row"
            );
        }

        // Optional proxy-loss runs (proxy config taken from the same row).
        let losses: Vec<f64> = if loss_steps > 0 {
            let pspec = proxy_spec(cfg.scale);
            table_rows
                .iter()
                .map(|r| {
                    run_proxy(&pspec, &r.proxy, loss_steps, 4, 0.02, 0.02, 42)
                        .metrics
                        .final_loss() as f64
                })
                .collect()
        } else {
            vec![f64::NAN; table_rows.len()]
        };

        for (i, row) in table_rows.iter().enumerate() {
            let upd = if measure_time {
                measure_update_time(&spec, &row.full, 2)
            } else {
                f64::NAN
            };
            // Paper reference values exist only for the three methods the
            // paper reports; the compressed baselines print "-".
            let paper = cfg.paper.iter().find(|p| p.0 == row.name);
            let (pbytes_s, ppeak_s) = match paper {
                Some((_, pb, pp)) => (format!("{pb}G"), format!("{pp}G")),
                None => ("-".to_string(), "-".to_string()),
            };
            println!(
                "{:<6} {:<9} {:>10} {:>5} {:>11} {:>11} {:>9} {:>9} {:>10} {:>9.2}s",
                cfg.scale,
                row.name,
                row.rank,
                if row.k == 0 { "-".into() } else { row.k.to_string() },
                fmt_bytes(row.prof.bytes_per_step),
                pbytes_s,
                fmt_bytes(row.prof.peak_bytes),
                ppeak_s,
                fmt_bytes(row.mem as f64),
                upd,
            );
            rows.push(Json::obj(vec![
                ("scale", Json::str(cfg.scale)),
                ("method", Json::str(row.name)),
                ("bytes_per_step", Json::num(row.prof.bytes_per_step)),
                (
                    "paper_bytes_per_step",
                    match paper {
                        Some((_, pb, _)) => Json::num(pb * G),
                        None => Json::Null,
                    },
                ),
                ("peak_bytes", Json::num(row.prof.peak_bytes)),
                (
                    "paper_peak_bytes",
                    match paper {
                        Some((_, _, pp)) => Json::num(pp * G),
                        None => Json::Null,
                    },
                ),
                ("memory_bytes", Json::num(row.mem as f64)),
                ("proxy_final_loss", Json::num(losses[i])),
                ("update_time_s", Json::num(upd)),
            ]));
        }
    }
    Json::obj(vec![("rows", Json::Arr(rows))])
}

/// Table 4: GLUE fine-tuning — Bytes/Step exact on RoBERTa-base shapes;
/// task metrics from the synthetic classification substitute.
pub fn table4(train_steps: usize) -> Json {
    const M: f64 = 1024.0 * 1024.0;
    let spec = ModelSpec::roberta_base();
    // Paper setup: GaLore rank 4 (matches its 158M bytes/step), TSR r=4
    // two-sided with embedding compression (r_emb=8).
    let adam = adamw_profile(&spec);
    let galore = onesided_profile(&spec, 4, 500);
    let tsr = tsr_profile(
        &spec,
        TsrParams {
            rank: 4,
            k_refresh: 500,
            rank_emb: 8,
            k_refresh_emb: 500,
            oversample: 4,
        },
    );
    println!("\nTable 4 — GLUE fine-tuning bytes (RoBERTa-base shapes, exact)");
    println!(
        "{:<8} {:>12} {:>10}  (paper: Adam 494M, GaLore 158M, TSR 20M)",
        "METHOD", "BYTES/STEP", "xAdam"
    );
    for (name, p) in [("adam", &adam), ("galore", &galore), ("tsr", &tsr)] {
        println!(
            "{:<8} {:>11.1}M {:>9.1}x",
            name,
            p.bytes_per_step / M,
            adam.bytes_per_step / p.bytes_per_step
        );
    }

    // Synthetic task suite: 8 tasks ≈ 8 GLUE datasets; metric = accuracy.
    let mut task_rows = Vec::new();
    if train_steps > 0 {
        use crate::comm::Topology;
        use crate::optim::LrSchedule;
        use crate::train::finetune::ClassifyTask;
        use crate::train::{GradSource, Trainer};
        println!("\n  synthetic-task accuracy (structural stand-in for GLUE metrics):");
        println!("  {:<8} {}", "METHOD", "task accuracies / mean");
        for (mi, mname) in ["adam", "galore", "tsr"].iter().enumerate() {
            let mut accs = Vec::new();
            for task_id in 0..8u64 {
                let mut task = ClassifyTask::new(256, 24, 32, 3, 16, 2, 16, 100 + task_id);
                let blocks = task.blocks().to_vec();
                let hyper = AdamHyper {
                    lr: 0.02,
                    ..Default::default()
                };
                let mut opt: Box<dyn DistOptimizer> = match mi {
                    0 => MethodCfg::Adam.build(&blocks, hyper, 2),
                    1 => MethodCfg::OneSided {
                        rank: 8,
                        k: 50,
                        refresh: OneSidedRefresh::RandomizedSvd,
                    }
                    .build(&blocks, hyper, 2),
                    _ => MethodCfg::Tsr(TsrConfig {
                        rank: 8,
                        rank_emb: 8,
                        refresh_every: 50,
                        refresh_emb: 50,
                        oversample: 4,
                        ..Default::default()
                    })
                    .build(&blocks, hyper, 2),
                };
                let mut params = task.init_params(task_id);
                let trainer = Trainer::new(Topology::single_node(2), LrSchedule::constant());
                trainer.run(&mut task, opt.as_mut(), &mut params, train_steps);
                accs.push(task.accuracy(&params));
            }
            let mean = accs.iter().sum::<f32>() / accs.len() as f32;
            let accs_s: Vec<String> = accs.iter().map(|a| format!("{:.2}", a)).collect();
            println!("  {:<8} [{}] / {:.3}", mname, accs_s.join(" "), mean);
            task_rows.push(Json::obj(vec![
                ("method", Json::str(*mname)),
                (
                    "accuracies",
                    Json::Arr(accs.iter().map(|&a| Json::num(a as f64)).collect()),
                ),
                ("mean", Json::num(mean as f64)),
            ]));
        }
    }
    Json::obj(vec![
        ("adam_bytes", Json::num(adam.bytes_per_step)),
        ("galore_bytes", Json::num(galore.bytes_per_step)),
        ("tsr_bytes", Json::num(tsr.bytes_per_step)),
        ("tasks", Json::Arr(task_rows)),
    ])
}

/// Table 6: additional TSR configurations.
pub fn table6() -> Json {
    println!("\nTable 6 — additional TSR configurations (bytes exact)");
    println!(
        "{:<8} {:>10} {:>5} {:>11} {:>10} {:>9} {:>9}",
        "SCALE", "RANK", "K", "BYTES/STEP", "(paper)", "PEAK", "(paper)"
    );
    let configs = [
        ("60m", 128usize, 64usize, 200usize, 0.008, 0.05),
        ("130m", 256, 96, 50, 0.032, 0.20),
        ("350m", 256, 128, 50, 0.062, 0.52),
    ];
    let mut rows = Vec::new();
    for (scale, r, re, k, pb, pp) in configs {
        let spec = ModelSpec::by_name(scale).unwrap();
        let p = tsr_profile(
            &spec,
            TsrParams {
                rank: r,
                k_refresh: k,
                rank_emb: re,
                k_refresh_emb: k,
                oversample: 8,
            },
        );
        println!(
            "{:<8} {:>6}({:>2}) {:>5} {:>11} {:>9}G {:>9} {:>8}G",
            scale,
            r,
            re,
            k,
            fmt_bytes(p.bytes_per_step),
            pb,
            fmt_bytes(p.peak_bytes),
            pp
        );
        rows.push(Json::obj(vec![
            ("scale", Json::str(scale)),
            ("rank", Json::num(r as f64)),
            ("rank_emb", Json::num(re as f64)),
            ("k", Json::num(k as f64)),
            ("bytes_per_step", Json::num(p.bytes_per_step)),
            ("peak_bytes", Json::num(p.peak_bytes)),
        ]));
    }
    Json::obj(vec![("rows", Json::Arr(rows))])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_json_has_four_rows() {
        let j = table1(1024, 1024, 64);
        assert_eq!(j.get("rows").as_arr().unwrap().len(), 4);
    }

    #[test]
    fn table3_bytes_only_runs_fast() {
        let j = table3(0, false);
        let rows = j.get("rows").as_arr().unwrap();
        assert_eq!(rows.len(), 28); // 4 scales × 7 methods
        // Per scale: [adamw, galore, tsr, signadam, topk, desloc, lordo].
        for chunk in rows.chunks(7) {
            let adam = chunk[0].get("bytes_per_step").as_f64().unwrap();
            let tsr = chunk[2].get("bytes_per_step").as_f64().unwrap();
            let sign = chunk[3].get("bytes_per_step").as_f64().unwrap();
            let topk = chunk[4].get("bytes_per_step").as_f64().unwrap();
            let desloc = chunk[5].get("bytes_per_step").as_f64().unwrap();
            let lordo = chunk[6].get("bytes_per_step").as_f64().unwrap();
            // TSR must beat AdamW by >5×; both compressed baselines must
            // land between TSR-class compression and dense.
            assert!(adam / tsr > 5.0);
            assert!(sign < 0.1 * adam, "sign {sign} vs adam {adam}");
            assert!(topk < 0.1 * adam, "topk {topk} vs adam {adam}");
            // Local-update rows: amortized traffic well below dense, but
            // a dense-payload PEAK (the step where everything syncs).
            assert!(desloc < 0.1 * adam, "desloc {desloc} vs adam {adam}");
            assert!(lordo < 0.1 * adam, "lordo {lordo} vs adam {adam}");
            let adam_peak = chunk[0].get("peak_bytes").as_f64().unwrap();
            let desloc_peak = chunk[5].get("peak_bytes").as_f64().unwrap();
            assert!(desloc_peak >= adam_peak, "desloc peak syncs all three states");
            // The paper-less baselines have no paper reference columns.
            assert_eq!(chunk[3].get("paper_bytes_per_step"), &Json::Null);
            assert_eq!(chunk[5].get("paper_bytes_per_step"), &Json::Null);
            assert_eq!(chunk[6].get("paper_bytes_per_step"), &Json::Null);
        }
    }

    #[test]
    fn table4_bytes_ratios_match_paper_order() {
        let j = table4(0);
        let adam = j.get("adam_bytes").as_f64().unwrap();
        let galore = j.get("galore_bytes").as_f64().unwrap();
        let tsr = j.get("tsr_bytes").as_f64().unwrap();
        // Paper: 494M / 158M / 20M → ratios ~3.1× and ~25×.
        assert!((adam / (494.0 * 1024.0 * 1024.0) - 1.0).abs() < 0.06, "adam {adam}");
        assert!(adam / galore > 2.0 && adam / galore < 5.0);
        assert!(adam / tsr > 10.0, "adam/tsr {}", adam / tsr);
    }

    #[test]
    fn table6_rows_monotone_in_rank() {
        let j = table6();
        let rows = j.get("rows").as_arr().unwrap();
        assert_eq!(rows.len(), 3);
        for r in rows {
            assert!(r.get("bytes_per_step").as_f64().unwrap() > 0.0);
        }
    }
}
