//! Empirical validation of Theorem 1 (stationarity of TSR-SGD).
//!
//! Runs Algorithm 2 on a smooth non-convex objective with the theorem's
//! parameter coupling η = 1/(L·T^{2/3}), 1−β² = √40·T^{-1/3}, and checks
//! that the averaged squared gradient norm (1/T)Σ‖∇f(w_t)‖² decays with
//! T at a rate compatible with the O(T^{-1/3}) bound, and that the
//! refresh-mismatch term R_t stays bounded.

use crate::comm::{CommLedger, Topology};
use crate::linalg::{matmul, matmul_nt, Matrix};
use crate::model::BlockSpec;
use crate::optim::tsr::TsrConfig;
use crate::optim::{DistOptimizer, StepCtx, TsrSgd};
use crate::util::json::Json;
use crate::util::rng::Xoshiro256;

/// Smooth non-convex test objective per block:
///   f(W) = ½‖Aᵀ(W−W*)B‖² + γ·Σ cos(w_ij)
/// The quadratic part has low-rank curvature (intrinsic dim d); the
/// cosine term makes it non-convex while keeping L-smoothness.
struct TheoryProblem {
    a: Matrix,
    b: Matrix,
    target: Matrix,
    gamma: f32,
    noise: f32,
}

impl TheoryProblem {
    fn new(m: usize, n: usize, d: usize, rng: &mut Xoshiro256) -> Self {
        Self {
            a: Matrix::gaussian(m, d, 1.0 / (m as f32).sqrt(), rng),
            b: Matrix::gaussian(n, d, 1.0 / (n as f32).sqrt(), rng),
            target: Matrix::gaussian(m, n, 0.5, rng),
            gamma: 0.05,
            noise: 0.05,
        }
    }

    fn grad(&self, w: &Matrix, rng: &mut Xoshiro256, noisy: bool) -> Matrix {
        let mut resid = w.clone();
        resid.axpy(-1.0, &self.target);
        let left = crate::linalg::matmul_tn(&self.a, &resid);
        let core = matmul(&left, &self.b);
        let ac = matmul(&self.a, &core);
        let mut g = matmul_nt(&ac, &self.b);
        for i in 0..g.data.len() {
            g.data[i] += -self.gamma * w.data[i].sin();
            if noisy {
                g.data[i] += self.noise * rng.next_gaussian_f32();
            }
        }
        g
    }
}

pub struct TheoryPoint {
    pub t_total: usize,
    pub mean_grad_sq: f64,
    pub eta: f64,
    pub beta: f64,
}

/// Run TSR-SGD for horizon T with the theorem's (η, β) coupling; return
/// the stationarity measure.
pub fn run_horizon(t_total: usize, workers: usize, k_refresh: usize, seed: u64) -> TheoryPoint {
    let (m, n, d) = (24usize, 20usize, 6usize);
    let lsmooth = 1.0f64; // curvature factors are normalized to O(1)
    let eta = 1.0 / (lsmooth * (t_total as f64).powf(2.0 / 3.0));
    let beta_sq = (1.0 - (40.0 * lsmooth * eta).sqrt()).max(0.0);
    let beta = beta_sq.sqrt();

    let mut rng = Xoshiro256::new(seed);
    let problem = TheoryProblem::new(m, n, d, &mut rng);
    let blocks = vec![BlockSpec {
        name: "w".into(),
        rows: m,
        cols: n,
        class: crate::comm::LayerClass::Linear,
    }];
    let cfg = TsrConfig {
        rank: 8,
        oversample: 4,
        refresh_every: k_refresh,
        ..Default::default()
    };
    let mut opt = TsrSgd::new(&blocks, eta as f32, beta as f32, cfg);
    let mut params = vec![Matrix::gaussian(m, n, 0.3, &mut rng)];
    let mut ledger = CommLedger::new();
    let topo = Topology::single_node(workers);
    let exec = crate::exec::ExecBackend::from_env();
    let mut grad_sq_sum = 0.0f64;
    for _ in 0..t_total {
        // True gradient for the stationarity measure.
        let true_grad = problem.grad(&params[0], &mut rng, false);
        grad_sq_sum += (true_grad.frob_norm() as f64).powi(2);
        let mut grads: Vec<Vec<Matrix>> = (0..workers)
            .map(|_| vec![problem.grad(&params[0], &mut rng, true)])
            .collect();
        opt.step(&mut StepCtx {
            params: &mut params,
            grads: &mut grads,
            ledger: &mut ledger,
            topo: &topo,
            lr_mult: 1.0,
            exec: &exec,
        });
        ledger.end_step();
    }
    TheoryPoint {
        t_total,
        mean_grad_sq: grad_sq_sum / t_total as f64,
        eta,
        beta,
    }
}

/// The `tsr theory` experiment: sweep horizons, print the decay, fit the
/// empirical rate exponent.
pub fn theory_sweep(horizons: &[usize], workers: usize, k_refresh: usize) -> Json {
    println!("\nTheorem 1 validation — TSR-SGD stationarity vs horizon T");
    println!(
        "{:>8} {:>10} {:>10} {:>14}",
        "T", "eta", "beta", "mean ||∇f||²"
    );
    let mut pts = Vec::new();
    for &t in horizons {
        // Average over a few seeds to tame noise.
        let mut acc = 0.0;
        let seeds = 3u64;
        let mut pt = None;
        for s in 0..seeds {
            let p = run_horizon(t, workers, k_refresh, 1000 + s);
            acc += p.mean_grad_sq;
            pt = Some(p);
        }
        let p = pt.unwrap();
        let mean = acc / seeds as f64;
        println!("{:>8} {:>10.5} {:>10.5} {:>14.6}", t, p.eta, p.beta, mean);
        pts.push((t as f64, mean));
    }
    // Least-squares slope of log(mean_grad_sq) vs log(T).
    let lx: Vec<f64> = pts.iter().map(|p| p.0.ln()).collect();
    let ly: Vec<f64> = pts.iter().map(|p| p.1.ln()).collect();
    let n = lx.len() as f64;
    let mx = lx.iter().sum::<f64>() / n;
    let my = ly.iter().sum::<f64>() / n;
    let slope = lx
        .iter()
        .zip(&ly)
        .map(|(x, y)| (x - mx) * (y - my))
        .sum::<f64>()
        / lx.iter().map(|x| (x - mx) * (x - mx)).sum::<f64>();
    println!("fitted decay exponent: {slope:.3}  (theorem: ≤ −1/3 up to the Δ̄ floor)");
    Json::obj(vec![
        (
            "points",
            Json::Arr(
                pts.iter()
                    .map(|(t, g)| {
                        Json::obj(vec![("T", Json::num(*t)), ("mean_grad_sq", Json::num(*g))])
                    })
                    .collect(),
            ),
        ),
        ("decay_exponent", Json::num(slope)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stationarity_improves_with_horizon() {
        let short = run_horizon(40, 2, 10, 5).mean_grad_sq;
        let long = run_horizon(400, 2, 10, 5).mean_grad_sq;
        assert!(
            long < short,
            "mean ||∇f||² should decrease with T: {short} vs {long}"
        );
    }

    #[test]
    fn theorem_coupling_values() {
        let p = run_horizon(64, 1, 8, 1);
        // η = T^{-2/3} (L=1): 64^{-2/3} = 1/16.
        assert!((p.eta - 1.0 / 16.0).abs() < 1e-9);
        // β² = 1 − √(40η) = 1 − √2.5 < 0 → clamped to 0 at tiny T.
        assert!(p.beta >= 0.0 && p.beta < 1.0);
    }
}
