//! # TSR-Adam: Two-Sided Low-Rank Communication for Distributed Adam
//!
//! Reproduction of *"From O(mn) to O(r²): Two-Sided Low-Rank Communication
//! for Adam in Distributed Training with Memory Efficiency"* (CS.LG 2026).
//!
//! Three-layer architecture:
//! * **L3 (this crate)** — the distributed data-parallel coordinator:
//!   simulated worker group, hierarchical interconnect with byte-exact
//!   communication accounting, the TSR-Adam / TSR-SGD optimizers and all
//!   compared baselines, and the training loop.
//! * **L2 (`python/compile/model.py`)** — JAX transformer fwd+bwd, AOT-
//!   lowered to HLO text artifacts executed via PJRT from Rust.
//! * **L1 (`python/compile/kernels/`)** — Pallas kernels for the compute
//!   hot-spots (tiled matmul, two-sided core projection, lift), verified
//!   against pure-jnp oracles.
//!
//! See `DESIGN.md` for the full system inventory and experiment index.

pub mod checkpoint;
pub mod comm;
pub mod data;
pub mod exec;
pub mod exp;
pub mod linalg;
pub mod metrics;
pub mod model;
pub mod net;
pub mod nn;
pub mod obs;
pub mod optim;
pub mod resilience;
pub mod runtime;
pub mod sim;
pub mod train;
pub mod util;
