//! Blocked, parallel matrix multiplication kernels.
//!
//! TSR's L3 hot path is dominated by the two-sided projection
//! `C = Uᵀ G V` (two tall-skinny multiplies) and the lift `U D Vᵀ`.
//! These kernels use i-k-j loop order over row-major storage (streaming
//! access on both operands), 8-wide manual unrolling to let LLVM
//! auto-vectorize, and row-block parallelism via the scoped pool.

use super::matrix::Matrix;
use crate::util::pool;

/// Threshold (in f32 multiply-adds) above which we parallelize.
const PAR_FLOPS: usize = 1 << 22;

/// Threshold (in f32 multiply-adds, m·n·k) above which [`mm_nt`]
/// materializes Bᵀ once and runs the streaming NN kernel instead of
/// the row-strided dot-product form. The copy is O(nk) against O(mnk)
/// compute, so it amortizes on large products (~2.7× on the TSR lift
/// path) but dominates on small ones — see DESIGN.md §15 for the
/// measurements behind the boundary.
const NT_TRANSPOSE_COPY_FLOPS: usize = 1 << 20;

/// General transpose-aware product: `op(A) · op(B)` where `op(X)` is
/// `Xᵀ` when the matching flag is set. This is the single entry point
/// behind which the orientation-specific kernels live — callers name
/// the orientation at the call site instead of picking among three
/// differently-named functions:
///
/// - `(false, false)` → the blocked streaming NN kernel,
/// - `(true,  false)` → the TN kernel (`AᵀB` without materializing Aᵀ),
/// - `(false, true)`  → the NT kernel (dot-product or transpose-copy),
/// - `(true,  true)`  → `AᵀBᵀ = (B·A)ᵀ`, one NN product + one transpose
///   (no dedicated kernel: the shape never appears on a hot path).
pub fn gemm(a: &Matrix, ta: bool, b: &Matrix, tb: bool) -> Matrix {
    match (ta, tb) {
        (false, false) => mm_nn(a, b),
        (true, false) => mm_tn(a, b),
        (false, true) => mm_nt(a, b),
        (true, true) => mm_nn(b, a).transpose(),
    }
}

/// C = A · B  (m×k · k×n). Thin wrapper over [`gemm`].
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    gemm(a, false, b, false)
}

fn mm_nn(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows, "matmul dim mismatch: {}x{} · {}x{}", a.rows, a.cols, b.rows, b.cols);
    let mut c = Matrix::zeros(a.rows, b.cols);
    matmul_into(a, b, &mut c);
    c
}

/// C = A · B into a pre-allocated output (zeroed here) — lets the step
/// loop reuse buffers without reallocating.
pub fn matmul_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.cols, b.rows);
    assert_eq!((c.rows, c.cols), (a.rows, b.cols));
    c.data.fill(0.0);
    let n = b.cols;
    let k = a.cols;
    let flops = a.rows * n * k;
    let threads = if flops >= PAR_FLOPS {
        pool::default_threads()
    } else {
        1
    };
    // Partition rows of A/C into contiguous blocks, one task per block.
    let block = a.rows.div_ceil(threads.max(1) * 4).max(1);
    let nblocks = a.rows.div_ceil(block);
    let a_data = &a.data;
    let b_data = &b.data;
    let c_ptr = SendMutSlice(c.data.as_mut_ptr(), c.data.len());
    let cp = &c_ptr;
    // k-blocking keeps a B panel (KB × n) resident in L2 across all rows
    // of the task's block — without it the kernel is memory-bound
    // streaming the whole B per A row (DESIGN.md §15: 1.4 GB → ~10 MB of
    // traffic on the 512×1376×512 MLP shape).
    const KB: usize = 128;
    pool::parallel_for(nblocks, threads, move |bi| {
        let i0 = bi * block;
        let i1 = (i0 + block).min(a.rows);
        // SAFETY: row blocks [i0, i1) are disjoint across tasks.
        let c_all = unsafe { std::slice::from_raw_parts_mut(cp.0, cp.1) };
        for k0 in (0..k).step_by(KB) {
            let k1 = (k0 + KB).min(k);
            for i in i0..i1 {
                let a_row = &a_data[i * k..(i + 1) * k];
                let c_row = &mut c_all[i * n..(i + 1) * n];
                // 2-way kk unroll halves the C-row read/write traffic
                // (the axpy kernel is store-bound once B is L2-resident).
                let mut kk = k0;
                while kk + 1 < k1 {
                    let a0 = a_row[kk];
                    let a1 = a_row[kk + 1];
                    let b0 = &b_data[kk * n..(kk + 1) * n];
                    let b1 = &b_data[(kk + 1) * n..(kk + 2) * n];
                    if a0 != 0.0 || a1 != 0.0 {
                        axpy2_row(c_row, a0, b0, a1, b1);
                    }
                    kk += 2;
                }
                if kk < k1 {
                    let aik = a_row[kk];
                    if aik != 0.0 {
                        axpy_row(c_row, aik, &b_data[kk * n..(kk + 1) * n]);
                    }
                }
            }
        }
    });
}

/// C = Aᵀ · B  (A is k×m, B is k×n → C is m×n). Thin wrapper over
/// [`gemm`] with `ta = true`.
pub fn matmul_tn(a: &Matrix, b: &Matrix) -> Matrix {
    gemm(a, true, b, false)
}

/// The `UᵀG` kernel: Aᵀ·B without materializing Aᵀ.
fn mm_tn(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.rows, b.rows, "matmul_tn dim mismatch");
    let m = a.cols;
    let n = b.cols;
    let mut c = Matrix::zeros(m, n);
    let flops = m * n * a.rows;
    let threads = if flops >= PAR_FLOPS {
        pool::default_threads()
    } else {
        1
    };
    // Each task owns a block of C rows (= columns of A). For cache
    // efficiency we stream A and B row-by-row and accumulate rank-1
    // updates into the task's C block: c[i, :] += a[kk, i] * b[kk, :].
    let block = m.div_ceil(threads.max(1) * 4).max(1);
    let nblocks = m.div_ceil(block);
    let c_ptr = SendMutSlice(c.data.as_mut_ptr(), c.data.len());
    let cp = &c_ptr;
    pool::parallel_for(nblocks, threads, move |bi| {
        let i0 = bi * block;
        let i1 = (i0 + block).min(m);
        let c_all = unsafe { std::slice::from_raw_parts_mut(cp.0, cp.1) };
        for kk in 0..a.rows {
            let a_row = a.row(kk);
            let b_row = b.row(kk);
            for i in i0..i1 {
                let aki = a_row[i];
                if aki == 0.0 {
                    continue;
                }
                let c_row = &mut c_all[i * n..(i + 1) * n];
                axpy_row(c_row, aki, b_row);
            }
        }
    });
    c
}

/// C = A · Bᵀ  (m×k · n×k → m×n). Thin wrapper over [`gemm`] with
/// `tb = true`.
pub fn matmul_nt(a: &Matrix, b: &Matrix) -> Matrix {
    gemm(a, false, b, true)
}

/// The A·Bᵀ kernel.
///
/// Perf note (DESIGN.md §15): the dot-product form below runs at
/// ~5.8 GF/s vs ~15 GF/s for the streaming `matmul` on this host (the
/// row-strided B access defeats the vectorizer's reuse). Above
/// [`NT_TRANSPOSE_COPY_FLOPS`] we therefore materialize Bᵀ once (O(nk)
/// copy) and run the fast kernel — 2.7× on the TSR lift path.
fn mm_nt(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.cols, "matmul_nt dim mismatch");
    if a.rows * b.rows * a.cols >= NT_TRANSPOSE_COPY_FLOPS {
        return mm_nn(a, &b.transpose());
    }
    let m = a.rows;
    let n = b.rows;
    let k = a.cols;
    let mut c = Matrix::zeros(m, n);
    let flops = m * n * k;
    let threads = if flops >= PAR_FLOPS {
        pool::default_threads()
    } else {
        1
    };
    let block = m.div_ceil(threads.max(1) * 4).max(1);
    let nblocks = m.div_ceil(block);
    let c_ptr = SendMutSlice(c.data.as_mut_ptr(), c.data.len());
    let cp = &c_ptr;
    pool::parallel_for(nblocks, threads, move |bi| {
        let i0 = bi * block;
        let i1 = (i0 + block).min(m);
        let c_all = unsafe { std::slice::from_raw_parts_mut(cp.0, cp.1) };
        for i in i0..i1 {
            let a_row = a.row(i);
            let c_row = &mut c_all[i * n..(i + 1) * n];
            for j in 0..n {
                c_row[j] = dot(a_row, b.row(j));
            }
        }
    });
    c
}

/// Contraction order for [`core_project`] on an m×n gradient: `true`
/// picks GV-first, `false` UᵀG-first. Both orders pay the same m·n·r
/// for the first multiply; the second multiply costs m·r² after GV
/// (intermediate G·V is m×r) vs n·r² after UᵀG (intermediate Uᵀ·G is
/// r×n), so GV-first is the flop- and memory-argmin exactly when
/// m ≤ n. Exposed so tests can assert the dispatch matches the
/// flop-count argmin on both branches (it was inverted once).
pub fn core_project_gv_first(m: usize, n: usize) -> bool {
    m <= n
}

/// The TSR core projection `C = Uᵀ G V` (r×r), fused to avoid
/// materializing the larger intermediate: [`core_project_gv_first`]
/// picks the cheaper of `Uᵀ·(G·V)` and `(Uᵀ·G)·V` from the shapes.
pub fn core_project(u: &Matrix, g: &Matrix, v: &Matrix) -> Matrix {
    // cost(GV first) = m·n·r + m·r·r ; cost(UᵀG first) = m·n·r + r·n·r
    let m = g.rows;
    let n = g.cols;
    assert_eq!(u.rows, m, "U rows must match G rows");
    assert_eq!(v.rows, n, "V rows must match G cols");
    if core_project_gv_first(m, n) {
        // GV (m×r) is the smaller intermediate and m·r² ≤ n·r².
        let t = matmul(g, v); // m×r
        matmul_tn(u, &t) // r×r
    } else {
        let t = matmul_tn(u, g); // r×n
        matmul(&t, v) // r×r
    }
}

/// The TSR lift `ΔW = U · D · Vᵀ` (m×n); D is r×r.
pub fn lift(u: &Matrix, d: &Matrix, v: &Matrix) -> Matrix {
    let ud = matmul(u, d); // m×r
    matmul_nt(&ud, v) // m×n
}

#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // 8 accumulators → LLVM vectorizes to fma lanes.
    let mut acc = [0.0f32; 8];
    let chunks = a.len() / 8;
    for c in 0..chunks {
        let i = c * 8;
        for l in 0..8 {
            acc[l] += a[i + l] * b[i + l];
        }
    }
    let mut s = acc.iter().sum::<f32>();
    for i in chunks * 8..a.len() {
        s += a[i] * b[i];
    }
    s
}

#[inline]
fn axpy_row(c: &mut [f32], alpha: f32, b: &[f32]) {
    debug_assert_eq!(c.len(), b.len());
    for (cv, bv) in c.iter_mut().zip(b) {
        *cv += alpha * bv;
    }
}

#[inline]
fn axpy2_row(c: &mut [f32], a0: f32, b0: &[f32], a1: f32, b1: &[f32]) {
    debug_assert_eq!(c.len(), b0.len());
    debug_assert_eq!(c.len(), b1.len());
    for i in 0..c.len() {
        c[i] += a0 * b0[i] + a1 * b1[i];
    }
}

struct SendMutSlice(*mut f32, usize);
unsafe impl Send for SendMutSlice {}
unsafe impl Sync for SendMutSlice {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0f64;
                for k in 0..a.cols {
                    s += a.at(i, k) as f64 * b.at(k, j) as f64;
                }
                *c.at_mut(i, j) = s as f32;
            }
        }
        c
    }

    #[test]
    fn matches_naive() {
        let mut rng = Xoshiro256::new(1);
        for &(m, k, n) in &[(1, 1, 1), (5, 7, 3), (64, 32, 48), (129, 65, 33)] {
            let a = Matrix::gaussian(m, k, 1.0, &mut rng);
            let b = Matrix::gaussian(k, n, 1.0, &mut rng);
            let c = matmul(&a, &b);
            assert!(c.dist(&naive(&a, &b)) < 1e-3 * (m * n) as f32);
        }
    }

    #[test]
    fn tn_and_nt_match_explicit_transpose() {
        let mut rng = Xoshiro256::new(2);
        let a = Matrix::gaussian(40, 23, 1.0, &mut rng);
        let b = Matrix::gaussian(40, 31, 1.0, &mut rng);
        assert!(matmul_tn(&a, &b).dist(&matmul(&a.transpose(), &b)) < 1e-3);
        let b2 = Matrix::gaussian(17, 23, 1.0, &mut rng);
        assert!(matmul_nt(&a, &b2).dist(&matmul(&a, &b2.transpose())) < 1e-3);
    }

    #[test]
    fn gemm_orientations_are_bitwise_the_named_entry_points() {
        // The named wrappers ARE gemm calls, so equality is structural —
        // this test pins the wrapper→flag wiring (a swapped flag would
        // still typecheck and, on square-ish inputs, nearly pass a
        // tolerance check).
        let mut rng = Xoshiro256::new(6);
        let a = Matrix::gaussian(19, 24, 1.0, &mut rng);
        let b = Matrix::gaussian(24, 13, 1.0, &mut rng);
        assert_eq!(gemm(&a, false, &b, false).data, matmul(&a, &b).data);
        let at = Matrix::gaussian(24, 19, 1.0, &mut rng);
        assert_eq!(gemm(&at, true, &b, false).data, matmul_tn(&at, &b).data);
        let bt = Matrix::gaussian(13, 24, 1.0, &mut rng);
        assert_eq!(gemm(&a, false, &bt, true).data, matmul_nt(&a, &bt).data);
    }

    #[test]
    fn gemm_double_transpose_matches_composition() {
        let mut rng = Xoshiro256::new(7);
        let a = Matrix::gaussian(24, 19, 1.0, &mut rng); // op(A) is 19×24
        let b = Matrix::gaussian(13, 24, 1.0, &mut rng); // op(B) is 24×13
        let c = gemm(&a, true, &b, true);
        assert_eq!((c.rows, c.cols), (19, 13));
        // AᵀBᵀ = (B·A)ᵀ, and that is literally how it is computed.
        assert_eq!(c.data, matmul(&b, &a).transpose().data);
        // Cross-check against the explicit-transpose route numerically.
        assert!(c.dist(&matmul(&a.transpose(), &b.transpose())) < 1e-3);
    }

    #[test]
    fn nt_crossover_is_correct_on_both_sides_of_the_boundary() {
        // 128·128·64 = 2²⁰ lands exactly ON the threshold (transpose-
        // copy path); dropping k to 63 falls just below (direct
        // dot-product path). Both must agree with the explicit-
        // transpose product.
        assert!(128 * 128 * 64 >= NT_TRANSPOSE_COPY_FLOPS);
        assert!(128 * 128 * 63 < NT_TRANSPOSE_COPY_FLOPS);
        let mut rng = Xoshiro256::new(8);
        for &k in &[63usize, 64] {
            let a = Matrix::gaussian(128, k, 1.0, &mut rng);
            let b = Matrix::gaussian(128, k, 1.0, &mut rng);
            let c = matmul_nt(&a, &b);
            let expect = matmul(&a, &b.transpose());
            assert!(c.dist(&expect) < 1e-2, "k={k}");
            if 128 * 128 * k >= NT_TRANSPOSE_COPY_FLOPS {
                // At/above the boundary the NT entry point IS the
                // transpose-copy composition, so equality is bitwise —
                // pinning that the fast path actually engaged.
                assert_eq!(c.data, expect.data, "k={k} took the slow path");
            }
        }
    }

    #[test]
    fn core_project_both_orders_agree() {
        let mut rng = Xoshiro256::new(3);
        // m > n branch
        let g1 = Matrix::gaussian(60, 20, 1.0, &mut rng);
        let u1 = Matrix::gaussian(60, 8, 1.0, &mut rng);
        let v1 = Matrix::gaussian(20, 8, 1.0, &mut rng);
        let c1 = core_project(&u1, &g1, &v1);
        let expect1 = matmul(&matmul_tn(&u1, &g1), &v1);
        assert!(c1.dist(&expect1) < 1e-3);
        // m < n branch
        let g2 = Matrix::gaussian(20, 60, 1.0, &mut rng);
        let u2 = Matrix::gaussian(20, 8, 1.0, &mut rng);
        let v2 = Matrix::gaussian(60, 8, 1.0, &mut rng);
        let c2 = core_project(&u2, &g2, &v2);
        let expect2 = matmul(&matmul_tn(&u2, &g2), &v2);
        assert!(c2.dist(&expect2) < 1e-3);
    }

    #[test]
    fn core_project_order_matches_flop_argmin() {
        // The dispatch must pick the order whose total multiply-add
        // count is minimal, on BOTH branches (regression: the branch
        // was inverted against its own cost comment).
        for &(m, n) in &[
            (20usize, 60usize), // wide: GV-first (m·r² < n·r²)
            (60, 20),           // tall: UᵀG-first
            (32, 32),           // square: tie — either order is argmin
            (1, 100),
            (100, 1),
        ] {
            for &r in &[1usize, 4, 16] {
                let gv_cost = m * n * r + m * r * r;
                let utg_cost = m * n * r + r * n * r;
                let chosen_cost = if core_project_gv_first(m, n) {
                    gv_cost
                } else {
                    utg_cost
                };
                assert_eq!(
                    chosen_cost,
                    gv_cost.min(utg_cost),
                    "core_project picked the costlier order for m={m} n={n} r={r}"
                );
            }
        }
    }

    #[test]
    fn lift_matches_composition() {
        let mut rng = Xoshiro256::new(4);
        let u = Matrix::gaussian(30, 6, 1.0, &mut rng);
        let d = Matrix::gaussian(6, 6, 1.0, &mut rng);
        let v = Matrix::gaussian(25, 6, 1.0, &mut rng);
        let w = lift(&u, &d, &v);
        let expect = matmul(&matmul(&u, &d), &v.transpose());
        assert!(w.dist(&expect) < 1e-3);
    }

    #[test]
    fn large_parallel_path() {
        let mut rng = Xoshiro256::new(5);
        let a = Matrix::gaussian(300, 300, 1.0, &mut rng);
        let b = Matrix::gaussian(300, 300, 1.0, &mut rng);
        let c = matmul(&a, &b);
        // Spot-check a few entries against naive dot products.
        for &(i, j) in &[(0, 0), (150, 299), (299, 7)] {
            let mut s = 0.0f64;
            for k in 0..300 {
                s += a.at(i, k) as f64 * b.at(k, j) as f64;
            }
            assert!((c.at(i, j) as f64 - s).abs() < 1e-2, "({i},{j})");
        }
    }
}
