//! Dense row-major f32 matrix.
//!
//! The minimal matrix type the whole optimizer stack is built on. Heavy
//! multiplies live in [`crate::linalg::matmul`]; this file holds layout,
//! element-wise ops, and small utilities.

use crate::util::rng::Xoshiro256;

#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "data length mismatch");
        Self { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, f: impl Fn(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Self { rows, cols, data }
    }

    pub fn identity(n: usize) -> Self {
        Self::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    /// i.i.d. N(0, sigma²) entries.
    pub fn gaussian(rows: usize, cols: usize, sigma: f32, rng: &mut Xoshiro256) -> Self {
        let mut m = Self::zeros(rows, cols);
        rng.fill_gaussian(&mut m.data);
        if sigma != 1.0 {
            for v in &mut m.data {
                *v *= sigma;
            }
        }
        m
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn numel(&self) -> usize {
        self.rows * self.cols
    }

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness on large matrices.
        const B: usize = 32;
        for ib in (0..self.rows).step_by(B) {
            for jb in (0..self.cols).step_by(B) {
                for i in ib..(ib + B).min(self.rows) {
                    for j in jb..(jb + B).min(self.cols) {
                        t.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        t
    }

    /// First `k` columns as a new matrix (used for Ũ[:, :r] truncation).
    pub fn take_cols(&self, k: usize) -> Matrix {
        assert!(k <= self.cols);
        let mut out = Matrix::zeros(self.rows, k);
        for i in 0..self.rows {
            out.row_mut(i).copy_from_slice(&self.row(i)[..k]);
        }
        out
    }

    // ---------- element-wise / BLAS-1 ----------

    pub fn add_assign(&mut self, other: &Matrix) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// self += alpha * other  (axpy)
    pub fn axpy(&mut self, alpha: f32, other: &Matrix) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    pub fn scale(&mut self, alpha: f32) {
        for a in &mut self.data {
            *a *= alpha;
        }
    }

    pub fn hadamard(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a * b)
            .collect();
        Matrix::from_vec(self.rows, self.cols, data)
    }

    pub fn frob_norm(&self) -> f32 {
        self.data.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>().sqrt() as f32
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, v| m.max(v.abs()))
    }

    /// ‖self - other‖_F
    pub fn dist(&self, other: &Matrix) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| {
                let d = (*a - *b) as f64;
                d * d
            })
            .sum::<f64>()
            .sqrt() as f32
    }

    pub fn fill(&mut self, v: f32) {
        self.data.fill(v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_and_access() {
        let m = Matrix::from_fn(3, 4, |i, j| (i * 10 + j) as f32);
        assert_eq!(m.at(2, 3), 23.0);
        assert_eq!(m.row(1), &[10.0, 11.0, 12.0, 13.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let m = Matrix::from_fn(37, 53, |i, j| (i * 53 + j) as f32);
        let t = m.transpose();
        assert_eq!(t.rows, 53);
        assert_eq!(t.at(5, 7), m.at(7, 5));
        assert_eq!(t.transpose(), m);
    }

    #[test]
    fn take_cols_truncates() {
        let m = Matrix::from_fn(4, 6, |i, j| (i + j) as f32);
        let k = m.take_cols(2);
        assert_eq!(k.cols, 2);
        assert_eq!(k.at(3, 1), m.at(3, 1));
    }

    #[test]
    fn axpy_and_norms() {
        let mut a = Matrix::from_vec(1, 3, vec![1.0, 2.0, 2.0]);
        let b = Matrix::from_vec(1, 3, vec![1.0, 1.0, 1.0]);
        a.axpy(2.0, &b);
        assert_eq!(a.data, vec![3.0, 4.0, 4.0]);
        assert!((Matrix::from_vec(1, 2, vec![3.0, 4.0]).frob_norm() - 5.0).abs() < 1e-6);
    }

    #[test]
    fn gaussian_is_deterministic() {
        let mut r1 = Xoshiro256::new(5);
        let mut r2 = Xoshiro256::new(5);
        let a = Matrix::gaussian(8, 8, 1.0, &mut r1);
        let b = Matrix::gaussian(8, 8, 1.0, &mut r2);
        assert_eq!(a, b);
    }
}
