//! Dense linear algebra substrate (f32, row-major).
//!
//! Built from scratch for this repo (no BLAS/LAPACK in the offline crate
//! universe). Everything the TSR optimizer family needs:
//! matrices, blocked parallel matmul, thin Householder QR ("orth"),
//! small-matrix SVD (Jacobi + Gram variants), and randomized SVD.

pub mod matmul;
pub mod matrix;
pub mod qr;
pub mod rsvd;
pub mod svd;

pub use matmul::{
    core_project, core_project_gv_first, gemm, lift, matmul, matmul_into, matmul_nt, matmul_tn,
};
pub use matrix::Matrix;
pub use qr::{orth, ortho_defect, qr_thin};
pub use rsvd::{rsvd, svd_truncated, Rsvd};
pub use svd::{eig_symmetric, svd_gram, svd_jacobi};
