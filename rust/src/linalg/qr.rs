//! Thin QR factorization via Householder reflections.
//!
//! `orth(Y)` in Algorithm 1 — every sketch `Y = GΩ` is orthonormalized
//! with a thin QR. We use blocked-free Householder (numerically stable,
//! unlike Gram–Schmidt on ill-conditioned sketches) and form the thin Q
//! explicitly by applying the reflectors to the first k identity columns.

use super::matrix::Matrix;

/// Thin QR: A (m×k, m ≥ k) → (Q (m×k) with orthonormal columns, R (k×k)
/// upper triangular) such that A = Q·R.
pub fn qr_thin(a: &Matrix) -> (Matrix, Matrix) {
    let m = a.rows;
    let k = a.cols;
    assert!(m >= k, "qr_thin requires m >= k (got {m}x{k})");
    // Work in f64 internally for stability of the reflector cascade.
    let mut w: Vec<f64> = a.data.iter().map(|&v| v as f64).collect();
    // Householder vectors stored in the lower triangle of w; betas here.
    let mut betas = vec![0.0f64; k];

    for j in 0..k {
        // Compute reflector for column j, rows j..m.
        let mut norm2 = 0.0f64;
        for i in j..m {
            let x = w[i * k + j];
            norm2 += x * x;
        }
        let norm = norm2.sqrt();
        if norm == 0.0 {
            betas[j] = 0.0;
            continue;
        }
        let x0 = w[j * k + j];
        let alpha = if x0 >= 0.0 { -norm } else { norm };
        // v = x - alpha*e1, normalized so v[0] = 1.
        let v0 = x0 - alpha;
        betas[j] = if v0 == 0.0 { 0.0 } else { -v0 / alpha }; // = 2/(vᵀv) * v0² form
        // Store normalized v below the diagonal.
        for i in (j + 1)..m {
            w[i * k + j] /= v0;
        }
        w[j * k + j] = alpha; // R diagonal

        // Apply reflector to the trailing columns: A := (I - beta v vᵀ) A
        for c in (j + 1)..k {
            let mut s = w[j * k + c]; // v[0] = 1 implicit
            for i in (j + 1)..m {
                s += w[i * k + j] * w[i * k + c];
            }
            s *= betas[j];
            w[j * k + c] -= s;
            for i in (j + 1)..m {
                w[i * k + c] -= s * w[i * k + j];
            }
        }
    }

    // Extract R.
    let mut r = Matrix::zeros(k, k);
    for i in 0..k {
        for j in i..k {
            *r.at_mut(i, j) = w[i * k + j] as f32;
        }
    }

    // Form thin Q by applying reflectors (in reverse) to identity columns.
    let mut q = vec![0.0f64; m * k];
    for j in 0..k {
        q[j * k + j] = 1.0;
    }
    for j in (0..k).rev() {
        if betas[j] == 0.0 {
            continue;
        }
        for c in 0..k {
            let mut s = q[j * k + c];
            for i in (j + 1)..m {
                s += w[i * k + j] * q[i * k + c];
            }
            s *= betas[j];
            q[j * k + c] -= s;
            for i in (j + 1)..m {
                q[i * k + c] -= s * w[i * k + j];
            }
        }
    }

    let qm = Matrix::from_vec(m, k, q.iter().map(|&v| v as f32).collect());
    (qm, r)
}

/// `orth(Y)`: orthonormal basis for the column span of Y (Algorithm 1).
pub fn orth(y: &Matrix) -> Matrix {
    qr_thin(y).0
}

/// ‖QᵀQ − I‖_max — orthonormality defect, used by tests and invariants.
pub fn ortho_defect(q: &Matrix) -> f32 {
    let g = super::matmul::matmul_tn(q, q);
    let mut worst = 0.0f32;
    for i in 0..g.rows {
        for j in 0..g.cols {
            let target = if i == j { 1.0 } else { 0.0 };
            worst = worst.max((g.at(i, j) - target).abs());
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::matmul;
    use crate::util::prop;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn reconstructs_a() {
        let mut rng = Xoshiro256::new(1);
        for &(m, k) in &[(5, 5), (20, 7), (100, 32), (64, 1)] {
            let a = Matrix::gaussian(m, k, 1.0, &mut rng);
            let (q, r) = qr_thin(&a);
            let qr = matmul(&q, &r);
            assert!(qr.dist(&a) < 1e-3 * (m as f32), "{m}x{k}");
            assert!(ortho_defect(&q) < 1e-4, "{m}x{k} defect {}", ortho_defect(&q));
        }
    }

    #[test]
    fn r_is_upper_triangular() {
        let mut rng = Xoshiro256::new(2);
        let a = Matrix::gaussian(30, 10, 1.0, &mut rng);
        let (_, r) = qr_thin(&a);
        for i in 0..10 {
            for j in 0..i {
                assert_eq!(r.at(i, j), 0.0);
            }
        }
    }

    #[test]
    fn handles_rank_deficient() {
        // Two identical columns: QR must not produce NaNs.
        let mut rng = Xoshiro256::new(3);
        let col = Matrix::gaussian(12, 1, 1.0, &mut rng);
        let a = Matrix::from_fn(12, 2, |i, _| col.at(i, 0));
        let (q, _) = qr_thin(&a);
        assert!(q.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn prop_orthonormal_columns() {
        prop::check("qr orthonormal", 32, |rng| {
            let k = prop::dim(rng, 1, 12);
            let m = k + prop::dim(rng, 0, 40);
            let a = Matrix::gaussian(m, k, 1.0, rng);
            let q = orth(&a);
            assert!(
                ortho_defect(&q) < 1e-4,
                "defect {} for {}x{}",
                ortho_defect(&q),
                m,
                k
            );
        });
    }

    #[test]
    fn prop_span_preserved() {
        // Q Qᵀ A = A when A has full column rank (projection onto span(A)).
        prop::check("qr span", 16, |rng| {
            let k = prop::dim(rng, 1, 8);
            let m = k + prop::dim(rng, 4, 24);
            let a = Matrix::gaussian(m, k, 1.0, rng);
            let q = orth(&a);
            let proj = matmul(&q, &super::super::matmul::matmul_tn(&q, &a));
            assert!(proj.dist(&a) < 1e-3 * m as f32);
        });
    }
}
