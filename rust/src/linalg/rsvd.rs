//! Randomized SVD (Halko–Martinsson–Tropp) — the centralized counterpart
//! of the paper's sketch-based subspace refresh (§3.5).
//!
//! The *distributed* refresh (per-worker sketches + all-reduce of Q and
//! B) lives in `crate::optim::tsr`; this module provides the single-node
//! building block used by baselines (GaLore-rSVD ablation, Fig. 3b) and
//! as a test oracle for the distributed path with N=1.

use super::matmul::{matmul, matmul_tn};
use super::matrix::Matrix;
use super::qr::orth;
use super::svd::svd_gram;
use crate::util::rng::Xoshiro256;

/// Output of a randomized SVD: `A ≈ U diag(sigma) Vᵀ` with rank-r factors.
pub struct Rsvd {
    pub u: Matrix,
    pub sigma: Vec<f32>,
    pub v: Matrix,
}

/// Randomized range finder + small SVD.
///
/// * `r` — target rank, `p` — oversampling (k = r + p sketch columns),
/// * `q` — power-iteration steps (Algorithm 1 shows q = 1),
/// * `rng` — source of the Gaussian test matrix Ω.
pub fn rsvd(a: &Matrix, r: usize, p: usize, q: usize, rng: &mut Xoshiro256) -> Rsvd {
    let k = (r + p).min(a.rows).min(a.cols);
    let omega = Matrix::gaussian(a.cols, k, 1.0, rng);
    let mut qm = orth(&matmul(a, &omega)); // m×k
    for _ in 0..q {
        // Alternate Aᵀ/A multiplications with re-orthonormalization —
        // the exact scheme in Algorithm 1's refresh block.
        let y_row = matmul_tn(a, &qm); // n×k
        let q_row = orth(&y_row);
        let y = matmul(a, &q_row); // m×k
        qm = orth(&y);
    }
    let b = matmul_tn(&qm, a); // k×n
    let (ub, sigma, vb) = svd_gram(&b);
    let r_eff = r.min(k);
    Rsvd {
        u: matmul(&qm, &ub.take_cols(r_eff)),
        sigma: sigma[..r_eff].to_vec(),
        v: vb.take_cols(r_eff),
    }
}

/// Exact truncated SVD via one-sided Jacobi — the "Normal SVD" baseline
/// of Fig. 3(b). O(min²·max); fine at ablation scales.
pub fn svd_truncated(a: &Matrix, r: usize) -> Rsvd {
    let (u, sigma, v) = super::svd::svd_jacobi(a);
    let r_eff = r.min(sigma.len());
    Rsvd {
        u: u.take_cols(r_eff),
        sigma: sigma[..r_eff].to_vec(),
        v: v.take_cols(r_eff),
    }
}

impl Rsvd {
    /// U diag(σ) Vᵀ
    pub fn reconstruct(&self) -> Matrix {
        let mut us = self.u.clone();
        for j in 0..us.cols {
            for i in 0..us.rows {
                *us.at_mut(i, j) *= self.sigma[j];
            }
        }
        super::matmul::matmul_nt(&us, &self.v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::qr::ortho_defect;
    use crate::util::prop;

    fn lowrank_plus_noise(
        m: usize,
        n: usize,
        r: usize,
        noise: f32,
        rng: &mut Xoshiro256,
    ) -> Matrix {
        let a = Matrix::gaussian(m, r, 1.0, rng);
        let b = Matrix::gaussian(r, n, 1.0, rng);
        let mut x = matmul(&a, &b);
        let e = Matrix::gaussian(m, n, noise, rng);
        x.add_assign(&e);
        x
    }

    #[test]
    fn recovers_lowrank_matrix() {
        let mut rng = Xoshiro256::new(7);
        let a = lowrank_plus_noise(60, 45, 6, 0.0, &mut rng);
        let out = rsvd(&a, 6, 4, 1, &mut rng);
        assert!(out.reconstruct().dist(&a) < 1e-2 * a.frob_norm());
        assert!(ortho_defect(&out.u) < 1e-3);
        assert!(ortho_defect(&out.v) < 1e-3);
    }

    #[test]
    fn power_iteration_helps_slow_spectrum() {
        let mut rng = Xoshiro256::new(8);
        let a = lowrank_plus_noise(80, 60, 8, 0.15, &mut rng);
        let mut r0 = Xoshiro256::new(99);
        let mut r1 = Xoshiro256::new(99);
        let e0 = rsvd(&a, 8, 2, 0, &mut r0).reconstruct().dist(&a);
        let e1 = rsvd(&a, 8, 2, 2, &mut r1).reconstruct().dist(&a);
        assert!(e1 <= e0 * 1.05, "q=2 ({e1}) should not be worse than q=0 ({e0})");
    }

    #[test]
    fn close_to_exact_truncation() {
        let mut rng = Xoshiro256::new(9);
        let a = lowrank_plus_noise(50, 40, 5, 0.05, &mut rng);
        let exact = svd_truncated(&a, 5).reconstruct();
        let approx = rsvd(&a, 5, 5, 1, &mut rng).reconstruct();
        let e_exact = exact.dist(&a) as f64;
        let e_approx = approx.dist(&a) as f64;
        assert!(
            e_approx <= 1.25 * e_exact + 1e-6,
            "rsvd error {e_approx} vs exact {e_exact}"
        );
    }

    #[test]
    fn prop_rank_clamping() {
        prop::check("rsvd rank clamp", 12, |rng| {
            let m = prop::dim(rng, 3, 20);
            let n = prop::dim(rng, 3, 20);
            let a = Matrix::gaussian(m, n, 1.0, rng);
            let r = prop::dim(rng, 1, 30); // may exceed min(m,n)
            let out = rsvd(&a, r, 3, 1, rng);
            assert!(out.u.cols <= m.min(n).min(r));
            assert_eq!(out.u.cols, out.v.cols);
            assert_eq!(out.sigma.len(), out.u.cols);
        });
    }
}
