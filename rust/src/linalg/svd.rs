//! Small-matrix SVD.
//!
//! Algorithm 1 needs the SVD of the reduced matrix `B̄ ∈ R^{k×n}` with
//! k = r + p small. Two implementations:
//!
//! * [`svd_jacobi`] — one-sided Jacobi on the (max-dim × min-dim)
//!   orientation: slow but very accurate; the correctness oracle and the
//!   path used for modest sizes.
//! * [`svd_gram`] — Gram-matrix eigendecomposition (B·Bᵀ, k×k) followed by
//!   `V = Bᵀ U Σ⁻¹`: one big matmul + an O(k³) Jacobi eig. This is the
//!   fast path for refresh at large n (condition number is squared, which
//!   is acceptable for subspace *refresh* — we only need the span).
//!
//! Both return `(U, sigma, V)` with `A ≈ U·diag(sigma)·Vᵀ`, singular
//! values in descending order.

use super::matmul::{matmul, matmul_nt, matmul_tn};
use super::matrix::Matrix;

/// Cyclic Jacobi eigendecomposition of a symmetric matrix S (k×k).
/// Returns (eigenvalues desc, eigenvectors as columns).
pub fn eig_symmetric(s: &Matrix) -> (Vec<f32>, Matrix) {
    assert_eq!(s.rows, s.cols);
    let k = s.rows;
    let mut a: Vec<f64> = s.data.iter().map(|&v| v as f64).collect();
    let mut v = vec![0.0f64; k * k];
    for i in 0..k {
        v[i * k + i] = 1.0;
    }
    let max_sweeps = 30;
    for _ in 0..max_sweeps {
        let mut off = 0.0f64;
        for i in 0..k {
            for j in (i + 1)..k {
                off += a[i * k + j] * a[i * k + j];
            }
        }
        if off.sqrt() < 1e-12 * (k as f64) {
            break;
        }
        for p in 0..k {
            for q in (p + 1)..k {
                let apq = a[p * k + q];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = a[p * k + p];
                let aqq = a[q * k + q];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let sn = t * c;
                // Rotate rows/cols p and q of A.
                for i in 0..k {
                    let aip = a[i * k + p];
                    let aiq = a[i * k + q];
                    a[i * k + p] = c * aip - sn * aiq;
                    a[i * k + q] = sn * aip + c * aiq;
                }
                for j in 0..k {
                    let apj = a[p * k + j];
                    let aqj = a[q * k + j];
                    a[p * k + j] = c * apj - sn * aqj;
                    a[q * k + j] = sn * apj + c * aqj;
                }
                // Accumulate eigenvectors.
                for i in 0..k {
                    let vip = v[i * k + p];
                    let viq = v[i * k + q];
                    v[i * k + p] = c * vip - sn * viq;
                    v[i * k + q] = sn * vip + c * viq;
                }
            }
        }
    }
    // Extract eigenvalues, sort descending, permute eigenvectors.
    let mut pairs: Vec<(f64, usize)> = (0..k).map(|i| (a[i * k + i], i)).collect();
    pairs.sort_by(|x, y| y.0.partial_cmp(&x.0).unwrap());
    let evals: Vec<f32> = pairs.iter().map(|p| p.0 as f32).collect();
    let mut evecs = Matrix::zeros(k, k);
    for (new_j, (_, old_j)) in pairs.iter().enumerate() {
        for i in 0..k {
            *evecs.at_mut(i, new_j) = v[i * k + old_j] as f32;
        }
    }
    (evals, evecs)
}

/// One-sided Jacobi SVD. Accurate; O(min² · max) per sweep.
pub fn svd_jacobi(a: &Matrix) -> (Matrix, Vec<f32>, Matrix) {
    if a.rows >= a.cols {
        svd_jacobi_tall(a)
    } else {
        // A = U Σ Vᵀ  ⇔  Aᵀ = V Σ Uᵀ
        let (v, s, u) = svd_jacobi_tall(&a.transpose());
        (u, s, v)
    }
}

/// One-sided Jacobi for m ≥ n: orthogonalize the n columns of A.
fn svd_jacobi_tall(a: &Matrix) -> (Matrix, Vec<f32>, Matrix) {
    let m = a.rows;
    let n = a.cols;
    // Column-major working copy in f64.
    let mut w = vec![0.0f64; m * n];
    for i in 0..m {
        for j in 0..n {
            w[j * m + i] = a.at(i, j) as f64;
        }
    }
    let mut v = vec![0.0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }
    let eps = 1e-12;
    for _sweep in 0..40 {
        let mut converged = true;
        for p in 0..n {
            for q in (p + 1)..n {
                let (cp, cq) = (p * m, q * m);
                let mut app = 0.0;
                let mut aqq = 0.0;
                let mut apq = 0.0;
                for i in 0..m {
                    let x = w[cp + i];
                    let y = w[cq + i];
                    app += x * x;
                    aqq += y * y;
                    apq += x * y;
                }
                if apq.abs() <= eps * (app * aqq).sqrt() || apq == 0.0 {
                    continue;
                }
                converged = false;
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let x = w[cp + i];
                    let y = w[cq + i];
                    w[cp + i] = c * x - s * y;
                    w[cq + i] = s * x + c * y;
                }
                for i in 0..n {
                    let x = v[p * n + i];
                    let y = v[q * n + i];
                    v[p * n + i] = c * x - s * y;
                    v[q * n + i] = s * x + c * y;
                }
            }
        }
        if converged {
            break;
        }
    }
    // Singular values = column norms; U = normalized columns.
    let mut sig: Vec<(f64, usize)> = (0..n)
        .map(|j| {
            let norm = (0..m).map(|i| w[j * m + i] * w[j * m + i]).sum::<f64>().sqrt();
            (norm, j)
        })
        .collect();
    sig.sort_by(|x, y| y.0.partial_cmp(&x.0).unwrap());
    let mut u = Matrix::zeros(m, n);
    let mut vt_cols = Matrix::zeros(n, n);
    let mut sigma = Vec::with_capacity(n);
    for (new_j, (s, old_j)) in sig.iter().enumerate() {
        sigma.push(*s as f32);
        let inv = if *s > 1e-300 { 1.0 / s } else { 0.0 };
        for i in 0..m {
            *u.at_mut(i, new_j) = (w[old_j * m + i] * inv) as f32;
        }
        for i in 0..n {
            *vt_cols.at_mut(i, new_j) = v[old_j * n + i] as f32;
        }
    }
    (u, sigma, vt_cols)
}

/// Gram-matrix SVD for wide B (k×n, k ≤ n): eig(B·Bᵀ) → U, σ; V = BᵀUΣ⁻¹.
pub fn svd_gram(b: &Matrix) -> (Matrix, Vec<f32>, Matrix) {
    assert!(
        b.rows <= b.cols,
        "svd_gram expects wide input (k<=n), got {}x{}",
        b.rows,
        b.cols
    );
    let gram = matmul_nt(b, b); // k×k
    let (evals, u) = eig_symmetric(&gram);
    let sigma: Vec<f32> = evals.iter().map(|&l| l.max(0.0).sqrt()).collect();
    // V = Bᵀ U Σ⁻¹ (columns with tiny σ are zeroed; callers truncate).
    let bt_u = matmul_tn(b, &u); // n×k
    let mut v = bt_u;
    for j in 0..v.cols {
        let inv = if sigma[j] > 1e-12 { 1.0 / sigma[j] } else { 0.0 };
        for i in 0..v.rows {
            *v.at_mut(i, j) *= inv;
        }
    }
    (u, sigma, v)
}

/// Reconstruct U·diag(s)·Vᵀ, truncated to rank r (testing helper).
pub fn reconstruct(u: &Matrix, s: &[f32], v: &Matrix, r: usize) -> Matrix {
    let ur = u.take_cols(r);
    let vr = v.take_cols(r);
    let mut usr = ur.clone();
    for j in 0..r {
        for i in 0..usr.rows {
            *usr.at_mut(i, j) *= s[j];
        }
    }
    matmul(&usr, &vr.transpose())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::qr::ortho_defect;
    use crate::util::prop;
    use crate::util::rng::Xoshiro256;

    fn random_lowrank(m: usize, n: usize, r: usize, rng: &mut Xoshiro256) -> Matrix {
        let a = Matrix::gaussian(m, r, 1.0, rng);
        let b = Matrix::gaussian(r, n, 1.0, rng);
        matmul(&a, &b)
    }

    #[test]
    fn jacobi_reconstructs() {
        let mut rng = Xoshiro256::new(1);
        for &(m, n) in &[(8, 8), (20, 6), (6, 20), (33, 17)] {
            let a = Matrix::gaussian(m, n, 1.0, &mut rng);
            let (u, s, v) = svd_jacobi(&a);
            let k = m.min(n);
            let rec = reconstruct(&u, &s, &v, k);
            assert!(rec.dist(&a) < 1e-3 * (m * n) as f32, "{m}x{n}: {}", rec.dist(&a));
            assert!(ortho_defect(&u.take_cols(k)) < 1e-4);
            assert!(ortho_defect(&v.take_cols(k)) < 1e-4);
        }
    }

    #[test]
    fn singular_values_descending_nonneg() {
        let mut rng = Xoshiro256::new(2);
        let a = Matrix::gaussian(15, 25, 1.0, &mut rng);
        let (_, s, _) = svd_jacobi(&a);
        for w in s.windows(2) {
            assert!(w[0] >= w[1] - 1e-6);
        }
        assert!(s.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn gram_matches_jacobi_on_spectrum() {
        let mut rng = Xoshiro256::new(3);
        let b = Matrix::gaussian(10, 40, 1.0, &mut rng);
        let (_, s1, _) = svd_jacobi(&b);
        let (u2, s2, v2) = svd_gram(&b);
        for i in 0..10 {
            assert!((s1[i] - s2[i]).abs() < 1e-2 * s1[0], "σ{i}: {} vs {}", s1[i], s2[i]);
        }
        let rec = reconstruct(&u2, &s2, &v2, 10);
        assert!(rec.dist(&b) < 1e-2 * b.frob_norm());
    }

    #[test]
    fn exact_lowrank_recovery() {
        let mut rng = Xoshiro256::new(4);
        let a = random_lowrank(30, 22, 5, &mut rng);
        let (u, s, v) = svd_jacobi(&a);
        // Rank-5 truncation is (numerically) exact.
        let rec = reconstruct(&u, &s, &v, 5);
        assert!(rec.dist(&a) < 1e-2 * a.frob_norm());
        // σ₆.. ≈ 0
        assert!(s[5] < 1e-3 * s[0]);
    }

    #[test]
    fn eig_symmetric_diagonalizes() {
        let mut rng = Xoshiro256::new(5);
        let x = Matrix::gaussian(9, 9, 1.0, &mut rng);
        let s = matmul_nt(&x, &x); // SPD
        let (evals, q) = eig_symmetric(&s);
        // S·q_j = λ_j q_j
        let sq = matmul(&s, &q);
        for j in 0..9 {
            for i in 0..9 {
                assert!((sq.at(i, j) - evals[j] * q.at(i, j)).abs() < 1e-2 * evals[0].abs());
            }
        }
        assert!(ortho_defect(&q) < 1e-4);
    }

    #[test]
    fn prop_gram_best_rank_r_error() {
        // Eckart–Young sanity: rank-r truncation error equals tail spectrum.
        prop::check("eckart-young", 8, |rng| {
            let k = prop::dim(rng, 4, 8);
            let n = k + prop::dim(rng, 8, 30);
            let b = Matrix::gaussian(k, n, 1.0, rng);
            let (u, s, v) = svd_gram(&b);
            let r = k / 2;
            let rec = reconstruct(&u, &s, &v, r);
            let err2 = rec.dist(&b).powi(2);
            let tail: f32 = s[r..].iter().map(|x| x * x).sum();
            assert!(
                (err2 - tail).abs() < 0.05 * (tail + 1e-6),
                "err² {err2} vs tail {tail}"
            );
        });
    }
}
