//! `tsr` — CLI for the TSR-Adam reproduction.
//!
//! Subcommands (see DESIGN.md §3 for the experiment index):
//!   table1|...|table6                    regenerate paper tables (table5 =
//!                                        pretrain→finetune adaptation regime)
//!   fig1|fig3|fig4|fig5                  regenerate paper figure data
//!   simtime                              Fig 6: step-time breakdown (sim/)
//!   soak                                 resilience sweep: straggler/jitter/kill+resume
//!   theory                               Theorem 1 validation sweep
//!   lm-curves                            quality-vs-bytes on the native LM (nn/)
//!   train                                end-to-end training run (pjrt|quad|lm)
//!   finetune                             classification fine-tune from a
//!                                        pretrained LM checkpoint (--from)
//!   info                                 platform / artifact status

use tsr::exp::{figures, tables, theory};
use tsr::metrics::results_path;
use tsr::util::cli::Args;

fn main() {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        // Hidden: child side of the process execution backend — this
        // binary re-executed as one simulated worker (DESIGN.md §12).
        // Dispatched first so a worker never touches artifacts/results.
        Some("_worker") => tsr::exec::process::worker::worker_main(&args),
        Some("table1") => {
            let m = args.get_usize("m", 4096);
            let n = args.get_usize("n", 4096);
            let r = args.get_usize("rank", 128);
            write_results("table1.json", &tables::table1(m, n, r));
        }
        Some("table2") => {
            let scale = args.get_or("scale", "60m");
            let spec = tsr::model::ModelSpec::by_name(scale).expect("unknown scale");
            let r = args.get_usize("rank", 256);
            let re = args.get_usize("rank-emb", 64);
            write_results("table2.json", &tables::table2(&spec, r, re));
        }
        Some("table3") => {
            let steps = args.get_usize("loss-steps", 200);
            // Full-scale step timing is opt-in: a 1B-scale TSR step is
            // ~1 TFLOP of projections (minutes on a single core).
            let timing = args.flag("timing");
            write_results("table3.json", &tables::table3(steps, timing));
        }
        Some("table4") => {
            let steps = args.get_usize("steps", 150);
            write_results("table4.json", &tables::table4(steps));
        }
        Some("table5") => {
            write_results(
                "table5.json",
                &tsr::exp::finetune::table5(
                    args.get_usize("pretrain-steps", 30),
                    args.get_usize("steps", 150),
                    args.get_usize("workers", 2),
                    args.get_u64("seed", 42),
                ),
            );
        }
        Some("table6") => {
            write_results("table6.json", &tables::table6());
        }
        Some("fig1") => {
            figures::fig1(args.get_usize("steps", 300), args.get_usize("workers", 4));
        }
        Some("fig3") => {
            figures::fig3(args.get_usize("steps", 300), args.get_usize("workers", 4));
        }
        Some("fig4") => {
            figures::fig4(args.get_usize("steps", 250), args.get_usize("workers", 4));
        }
        Some("fig5") => {
            figures::fig5(args.get_usize("steps", 300), args.get_usize("workers", 4));
        }
        Some("simtime") => {
            let cfg = tsr::sim::SimCfg {
                bucket_bytes: args.get_usize("bucket-kb", 25 * 1024) * 1024,
                flops: args.get_f64("flops", 312e12),
                tokens_per_step: args.get_usize("tokens", 8192),
                overlap: !args.flag("no-overlap"),
                hierarchical: !args.flag("flat"),
            };
            let nodes = args.get_usize("nodes", 4);
            let gpus = args.get_usize("gpus", 8);
            let adv = tsr::sim::Adversity::from_knobs(
                nodes * gpus,
                args.get_f64("straggler", 1.0),
                args.get_f64("jitter", 0.0),
                args.get_u64("seed", 42),
            );
            let j = tsr::exp::simtime::simtime(
                args.get_or("scale", "60m"),
                nodes,
                gpus,
                args.get_usize("steps", 100),
                &cfg,
                &backend_from_args(&args),
                &adv,
            );
            write_results("fig6_simtime.json", &j);
        }
        Some("soak") => {
            let cfg = tsr::exp::soak::SoakCfg {
                scale: args.get_or("scale", "60m").to_string(),
                workers_list: args
                    .get_or("workers-list", "4,8")
                    .split(',')
                    .filter_map(|s| s.trim().parse().ok())
                    .collect(),
                steps: args.get_usize("steps", 16),
                kill_at: args.get_usize("kill-at", 7),
                plan_steps: args.get_usize("plan-steps", 30),
                seed: args.get_u64("seed", 42),
                straggler_mult: args.get_f64("straggler", 2.0),
                jitter_amp: args.get_f64("jitter", 0.5),
                drill_cap: args.get_usize("drill-cap", 16),
                elastic_tol: args.get_f64("elastic-tol", 0.5),
                sim: tsr::sim::SimCfg {
                    bucket_bytes: args.get_usize("bucket-kb", 25 * 1024) * 1024,
                    ..Default::default()
                },
            };
            assert!(
                !cfg.workers_list.is_empty(),
                "--workers-list must name at least one worker count"
            );
            let j = tsr::exp::soak::soak(&cfg, backend_from_args(&args));
            write_results("soak.json", &j);
        }
        Some("lm-curves") => {
            let cfg = tsr::exp::lm_curves::LmCurvesCfg {
                steps: args.get_usize("steps", 300),
                workers: args.get_usize("workers", 4),
                seed: args.get_u64("seed", 0x5EED),
                ..Default::default()
            };
            let j = tsr::exp::lm_curves::lm_curves(&cfg, &backend_from_args(&args));
            write_results("lm_curves.json", &j);
        }
        Some("theory") => {
            let horizons: Vec<usize> = args
                .get_or("horizons", "50,100,200,400,800")
                .split(',')
                .filter_map(|s| s.parse().ok())
                .collect();
            let j = theory::theory_sweep(&horizons, args.get_usize("workers", 2), args.get_usize("k", 25));
            write_results("theory.json", &j);
        }
        Some("train") => run_train(&args),
        Some("finetune") => run_finetune(&args),
        Some("trace") => run_trace(&args),
        Some("info") => info(),
        other => {
            if let Some(cmd) = other {
                tsr::tsr_error!("unknown subcommand: {cmd}\n");
            }
            eprintln!(
                "usage: tsr <subcommand> [--options]\n\
                 \n  tables:   table1 table2 table3 [--loss-steps N] table4 \
                 table5 [--pretrain-steps N --steps N --workers W --seed S] table6\
                 \n  figures:  fig1 fig3 fig4 fig5 [--steps N --workers W]\
                 \n  simtime:  simtime [--scale 60m --nodes 4 --gpus 8 --steps N \
                 --bucket-kb K --tokens T --flops F --no-overlap --flat \
                 --straggler MULT --jitter AMP --seed S]\
                 \n  soak:     soak [--scale 60m --workers-list 4,8 --steps 16 --kill-at 7 \
                 --plan-steps 30 --seed 42 --straggler 2.0 --jitter 0.5 --drill-cap 16 \
                 --elastic-tol 0.5 --bucket-kb K --backend B] — resilience sweep: \
                 clean/straggler/jitter timing cells plus kill+resume drills \
                 (bitwise same-world, tolerance elastic; DESIGN.md §11)\
                 \n  theory:   theory [--horizons 50,100,...]\
                 \n  lm:       lm-curves [--steps N --workers W --seed S] — loss-vs-bytes \
                 table on the native transformer LM (AdamW vs TSR vs baselines, \
                 matched seeds; DESIGN.md §10)\
                 \n  train:    train --manifest artifacts/tiny_manifest.json \
                 [--method adamw|galore|tsr|tsr-sgd|powersgd|signadam|topk|desloc|lordo] \
                 [--steps N] [--workers W] [--k-var N] [--keep-frac F] \
                 [--k-p N --k-m N --k-v N] [--h N]\
                 \n            --workers N       simulated data-parallel workers (default 4)\
                 \n            --backend B       execution backend: sequential | threaded \
                 | process (default $TSR_BACKEND or sequential; all three are \
                 bitwise-identical — threaded runs one OS thread per worker, \
                 process one OS process per worker over localhost sockets, see \
                 DESIGN.md §8, §12)\
                 \n            --source S        gradient source: quad | lm | pjrt \
                 (default pjrt). quad = synthetic low-rank quadratic; lm = native \
                 pure-Rust transformer LM on the synthetic corpus ([--vocab V \
                 --hidden H --inter F --heads A --layers L --batch B --seq T], \
                 DESIGN.md §10). Both are artifact-free and emit deterministic \
                 metrics JSON for CI's cross-backend gate\
                 \n            --core-fmt F      payload element format for the steady \
                 low-rank sync: f32 | bf16 | i8 (default f32; tsr/galore/lordo \
                 only — narrows the synced cores/factors with per-worker error \
                 feedback, DESIGN.md §14)\
                 \n            --save-every N    write a checkpoint manifest every N steps \
                 (quad/lm sources; --save-dir DIR, default checkpoints/)\
                 \n            --resume PATH     continue a checkpointed run: byte-identical \
                 to the uninterrupted run at the same world size; elastic \
                 --workers supported for quad only (DESIGN.md §9)\
                 \n            --trace PATH      write a deterministic trace artifact \
                 (JSONL: spans, per-link collective legs, per-step byte records; \
                 byte-identical across repeats AND backends — DESIGN.md §16). \
                 --trace-wall adds wall-clock + backend wall-tier records \
                 (not byte-stable)\
                 \n  finetune: finetune --from CKPT — classification fine-tune from a \
                 `train --source lm` checkpoint: transfers the pretrained \
                 token embedding, trains the task head with the adaptation-\
                 regime defaults (--method tsr --rank 8 --k 25 --core-fmt bf16; \
                 --method adamw for the dense baseline). Also honors \
                 [--hidden H --classes C --seq T --batch B --workers W --lr F \
                 --seed S --steps N --save-every N --save-dir D --backend B] \
                 and --resume PATH to continue a fine-tune checkpoint \
                 byte-for-byte (DESIGN.md §6, §14); --trace PATH as in train\
                 \n  trace:    trace <trace.jsonl> [more.jsonl ...] [--chrome out.json] — \
                 analyze trace artifacts: per-phase breakdown, per-link byte \
                 timeline with refresh spikes, peak step; extra traces get a \
                 cross-method comparison; --chrome exports Chrome trace format \
                 for Perfetto (DESIGN.md §16)\
                 \n  info"
            );
            std::process::exit(if other.is_some() { 2 } else { 0 });
        }
    }
}

fn write_results(name: &str, j: &tsr::util::json::Json) {
    let p = results_path(name).unwrap_or_else(|e| panic!("{e}"));
    std::fs::write(&p, j.to_string_pretty())
        .unwrap_or_else(|e| panic!("write {}: {e}", p.display()));
    println!("\n-> wrote {}", p.display());
}

/// Resolve `--trace PATH [--trace-wall]` into a tracer handle (disabled
/// when `--trace` is absent) plus the artifact path. An enabled tracer
/// is also installed as the process-global slot so the execution
/// backends can emit their wall-tier records (DESIGN.md §16).
fn tracer_from_args(args: &Args) -> (tsr::obs::Tracer, Option<String>) {
    match args.get("trace") {
        None => {
            if args.flag("trace-wall") {
                tsr::tsr_error!("error: --trace-wall requires --trace <path>");
                std::process::exit(2);
            }
            (tsr::obs::Tracer::default(), None)
        }
        Some(path) => {
            let t = if args.flag("trace-wall") {
                tsr::obs::Tracer::new_wall()
            } else {
                tsr::obs::Tracer::new()
            };
            tsr::obs::set_global(t.clone());
            (t, Some(path.to_string()))
        }
    }
}

/// `tsr trace <trace.jsonl> [more.jsonl ...] [--chrome out.json]` —
/// analyze deterministic trace artifacts: per-phase breakdown, per-link
/// byte timeline with refresh spikes, peak step; two or more traces get
/// a cross-method comparison table. `--chrome PATH` additionally
/// exports the first trace in Chrome trace format (load it in Perfetto
/// or chrome://tracing).
fn run_trace(args: &Args) {
    use tsr::obs::analyze;
    if args.positional.is_empty() {
        tsr::tsr_error!(
            "error: tsr trace needs at least one trace artifact\n\
             usage: tsr trace <trace.jsonl> [more.jsonl ...] [--chrome out.json]"
        );
        std::process::exit(2);
    }
    let mut traces = Vec::new();
    for path in &args.positional {
        let text = std::fs::read_to_string(path)
            .unwrap_or_else(|e| panic!("read trace {path}: {e}"));
        let records = analyze::parse_jsonl(&text).unwrap_or_else(|e| panic!("parse {path}: {e}"));
        if traces.is_empty() {
            print!("{}", analyze::render_report(&records));
            if let Some(out) = args.get("chrome") {
                let chrome = analyze::chrome_trace(&records);
                tsr::util::json::write_text_atomic(out, &chrome.to_string())
                    .unwrap_or_else(|e| panic!("{e}"));
                println!("-> wrote chrome trace {out} (open in Perfetto / chrome://tracing)");
            }
        }
        traces.push((path.to_string(), records));
    }
    for (path, records) in traces.iter().skip(1) {
        println!("\ncompare {} vs {path}:", traces[0].0);
        print!("{}", analyze::compare(&traces[0].1, records));
    }
}

/// `--backend sequential|threaded|process`, falling back to
/// `$TSR_BACKEND`. Unknown names exit loudly with the valid list —
/// same strictness as `--source`.
fn backend_from_args(args: &Args) -> tsr::exec::ExecBackend {
    match args.get("backend") {
        Some(name) => tsr::exec::ExecBackend::parse(name).unwrap_or_else(|e| {
            tsr::tsr_error!("error: --backend: {e}");
            std::process::exit(2);
        }),
        None => tsr::exec::ExecBackend::from_env(),
    }
}

/// Resolve the method-selection flags (rank defaults derive from the
/// model's hidden dimension) into the config-echo keys that
/// [`method_cfg_from_config`] reads — the single method dispatch shared
/// by the quad and PJRT train paths, and by fresh runs and resumes.
fn method_config_json(args: &Args, hidden: usize) -> tsr::util::json::Json {
    use tsr::util::json::Json;
    // Validate the format name eagerly so a typo exits loudly at launch,
    // not after the first checkpoint is written.
    let core_fmt = args.get_or("core-fmt", "f32");
    if let Err(e) = tsr::comm::ElemFmt::parse(core_fmt) {
        tsr::tsr_error!("error: --core-fmt: {e}");
        std::process::exit(2);
    }
    Json::obj(vec![
        ("method", Json::str(args.get_or("method", "tsr"))),
        ("core_fmt", Json::str(core_fmt)),
        ("rank", Json::num(args.get_usize("rank", (hidden / 4).max(4)) as f64)),
        ("rank_emb", Json::num(args.get_usize("rank-emb", (hidden / 8).max(4)) as f64)),
        ("k", Json::num(args.get_usize("k", 50) as f64)),
        ("k_var", Json::num(args.get_usize("k-var", 100) as f64)),
        ("keep_frac", Json::num(args.get_f64("keep-frac", 0.01))),
        ("k_p", Json::num(args.get_usize("k-p", 8) as f64)),
        ("k_m", Json::num(args.get_usize("k-m", 32) as f64)),
        ("k_v", Json::num(args.get_usize("k-v", 128) as f64)),
        ("h", Json::num(args.get_usize("h", 8) as f64)),
    ])
}

fn info() {
    match tsr::runtime::Engine::cpu() {
        Ok(e) => println!("PJRT platform: {}", e.platform()),
        Err(e) => println!("PJRT unavailable: {e}"),
    }
    for name in ["tiny_manifest.json", "e2e_manifest.json"] {
        let p = std::path::Path::new("artifacts").join(name);
        println!(
            "artifact {}: {}",
            p.display(),
            if p.exists() { "present" } else { "missing (run `make artifacts`)" }
        );
    }
}

/// `tsr train` front door: dispatch on gradient source. A `--resume`
/// run takes its source kind from the manifest's config echo, so the
/// flag may be omitted there. Unknown sources fail loudly with the
/// valid list — a typo must never fall through to a default path.
fn run_train(args: &Args) {
    if args.get("resume").is_some() {
        return run_train_synth(args);
    }
    match args.get_or("source", "pjrt") {
        "quad" | "lm" => run_train_synth(args),
        "pjrt" => run_train_pjrt(args),
        other => {
            tsr::tsr_error!(
                "error: unknown --source `{other}`\n\
                 valid sources: quad | lm | pjrt\n\
                 \x20 quad  synthetic low-rank quadratic objective (artifact-free, deterministic)\n\
                 \x20 lm    native pure-Rust transformer LM on the synthetic corpus\n\
                 \x20       (artifact-free, deterministic — DESIGN.md §10)\n\
                 \x20 pjrt  AOT-compiled JAX artifact via PJRT (needs `make artifacts`)"
            );
            std::process::exit(2);
        }
    }
}

/// Resolve a `--source quad|lm` run configuration — every default
/// applied — into the JSON echo stored in checkpoint manifests. Both
/// the fresh path and the resume path construct their setup from this
/// one document, so a resumed run cannot drift from re-typed flags.
fn synth_run_config(args: &Args) -> tsr::util::json::Json {
    use tsr::util::json::Json;
    let source = args.get_or("source", "quad");
    let mut cfg;
    if source == "lm" {
        let hidden = args.get_usize("hidden", 32);
        cfg = method_config_json(args, hidden);
        cfg.set("vocab", Json::num(args.get_usize("vocab", 64) as f64));
        cfg.set("hidden", Json::num(hidden as f64));
        cfg.set("inter", Json::num(args.get_usize("inter", hidden * 2) as f64));
        cfg.set("heads", Json::num(args.get_usize("heads", 2) as f64));
        cfg.set("layers", Json::num(args.get_usize("layers", 2) as f64));
        cfg.set("batch", Json::num(args.get_usize("batch", 4) as f64));
        cfg.set("seq", Json::num(args.get_usize("seq", 16) as f64));
        cfg.set("lr", Json::num(args.get_f64("lr", 0.01)));
    } else {
        let scale = args.get_or("scale", "tiny");
        let hidden = if scale == "tiny" {
            32
        } else {
            tsr::exp::runs::proxy_spec(scale).hidden
        };
        cfg = method_config_json(args, hidden);
        cfg.set("scale", Json::str(scale));
        cfg.set("noise", Json::num(args.get_f64("noise", 0.01)));
        cfg.set("lr", Json::num(args.get_f64("lr", 0.05)));
    }
    cfg.set("source", Json::str(source));
    cfg.set("steps", Json::num(args.get_usize("steps", 40) as f64));
    cfg.set("workers", Json::num(args.get_usize("workers", 4) as f64));
    cfg.set(
        "seed",
        tsr::checkpoint::codec::u64_to_json(args.get_u64("seed", 42)),
    );
    cfg.set("topo", Json::str(args.get_or("topo", "multi_node")));
    cfg
}

/// Build the optimizer selection from the resolved config echo
/// ([`method_config_json`]); fresh runs, resumes, and the PJRT path
/// all dispatch through here. The name goes through the one shared
/// parser (`MethodCfg::parse` — unknown names exit loudly with all
/// nine valid methods); the echoed knobs are applied on top of its
/// defaults per variant.
/// The payload element format echoed in a run config (absent key — e.g.
/// a pre-format checkpoint — means f32, DESIGN.md §14).
fn core_fmt_from_config(cfg: &tsr::util::json::Json) -> tsr::comm::ElemFmt {
    tsr::comm::ElemFmt::parse(cfg.get_str("core_fmt", "f32")).unwrap_or_else(|e| {
        tsr::tsr_error!("error: config core_fmt: {e}");
        std::process::exit(2);
    })
}

fn method_cfg_from_config(cfg: &tsr::util::json::Json) -> tsr::exp::MethodCfg {
    use tsr::exp::MethodCfg;

    let name = cfg.get_str("method", "tsr");
    let mut m = MethodCfg::parse(name).unwrap_or_else(|e| {
        tsr::tsr_error!("error: --method: {e}");
        std::process::exit(2);
    });
    let rank = cfg.get_usize("rank", 8);
    let rank_emb = cfg.get_usize("rank_emb", 4);
    let k = cfg.get_usize("k", 50);
    match &mut m {
        MethodCfg::Adam => {}
        MethodCfg::OneSided { rank: r, k: kk, .. } => {
            *r = rank;
            *kk = k;
        }
        MethodCfg::Tsr(c) | MethodCfg::TsrSgd(c) => {
            c.rank = rank;
            c.rank_emb = rank_emb;
            c.refresh_every = k;
            c.refresh_emb = k;
        }
        MethodCfg::PowerSgd { rank: r } => *r = rank,
        MethodCfg::Sign { k_var } => *k_var = cfg.get_usize("k_var", 100),
        MethodCfg::TopK { keep_frac } => *keep_frac = cfg.get_f64("keep_frac", 0.01),
        MethodCfg::DesLoc { k_p, k_m, k_v } => {
            *k_p = cfg.get_usize("k_p", 8) as u64;
            *k_m = cfg.get_usize("k_m", 32) as u64;
            *k_v = cfg.get_usize("k_v", 128) as u64;
        }
        MethodCfg::Lordo { rank: r, h } => {
            *r = rank;
            *h = cfg.get_usize("h", 8) as u64;
        }
    }
    m
}

/// Synthetic deterministic training (`--source quad | lm`) — no PJRT
/// artifacts needed. `quad` feeds the low-rank quadratic objective,
/// `lm` the native pure-Rust transformer LM on the synthetic corpus
/// (DESIGN.md §10). Both emit the *deterministic* metrics JSON (no
/// wall-clock fields, plus a final-weight fingerprint), which CI's
/// determinism gate runs twice per backend and diffs byte-for-byte.
/// `--save-every N` writes checkpoint manifests; `--resume PATH`
/// continues one — interrupted + resumed is byte-identical to
/// uninterrupted (DESIGN.md §9).
fn run_train_synth(args: &Args) {
    use tsr::checkpoint::Checkpoint;
    use tsr::comm::{CommLedger, Topology};
    use tsr::exp::runs::proxy_spec;
    use tsr::metrics::RunMetrics;
    use tsr::optim::{AdamHyper, LrSchedule};
    use tsr::train::gradsim::QuadraticSim;
    use tsr::train::lm_source::LmSource;
    use tsr::train::{CkptCfg, GradSource, Trainer};

    let backend = backend_from_args(args);
    let resume = args.get("resume").map(|p| {
        let ck = Checkpoint::load(p).unwrap_or_else(|e| panic!("--resume: {e}"));
        let src = ck.config.get_str("source", "?").to_string();
        assert!(
            src == "quad" || src == "lm",
            "--resume: checkpoint source `{src}` is not a synthetic source (quad|lm)"
        );
        if let Some(flag) = args.get("source") {
            assert_eq!(
                flag, src,
                "--resume: --source {flag} contradicts the checkpoint's source `{src}`"
            );
        }
        ck
    });
    // One resolved config drives both paths; a resume trusts the
    // manifest's echo, not re-typed method flags. Flag the ones it
    // discards so a contradictory command line doesn't mislead.
    let config = match &resume {
        Some(ck) => {
            const CONFIG_ONLY: &[&str] = &[
                "lr", "noise", "seed", "method", "k", "k-var", "keep-frac", "rank", "rank-emb",
                "k-p", "k-m", "k-v", "h", "core-fmt", "scale", "topo", "vocab", "hidden", "inter",
                "heads", "layers", "batch", "seq",
            ];
            for flag in CONFIG_ONLY {
                if args.get(flag).is_some() {
                    tsr::tsr_warn!(
                        "warning: --{flag} is fixed by the checkpoint's config and was ignored \
                         (--resume honors only --steps/--workers/--backend/--out/--save-*)"
                    );
                }
            }
            ck.config.clone()
        }
        None => synth_run_config(args),
    };
    let kind = config.get_str("source", "quad").to_string();
    let start_step = resume.as_ref().map(|ck| ck.step as usize).unwrap_or(0);
    let steps = args.get_usize("steps", config.get_usize("steps", 40));
    assert!(
        steps > start_step,
        "--steps {steps} must exceed the checkpoint's completed step {start_step}"
    );
    // Elastic: --workers may differ from the checkpoint's world size
    // (quad only — lm data streams are per-worker and cannot re-shard).
    let workers = args.get_usize("workers", config.get_usize("workers", 4));
    let lr = config.get_f64("lr", if kind == "lm" { 0.01 } else { 0.05 }) as f32;
    let seed = tsr::checkpoint::codec::u64_from_json(config.get("seed"), "config.seed")
        .expect("config.seed");
    let topo = match config.get_str("topo", "multi_node") {
        "single_node" => Topology::single_node(workers),
        "multi_node" => Topology::multi_node(2, workers.div_ceil(2)),
        "ethernet" => Topology::ethernet(2, workers.div_ceil(2)),
        other => panic!("unknown --topo {other} (single_node|multi_node|ethernet)"),
    };

    let (mut source, run_desc): (Box<dyn GradSource>, String) = if kind == "lm" {
        if let Some(ck) = &resume {
            assert_eq!(
                workers, ck.workers,
                "--resume: elastic --workers is not supported for --source lm \
                 (per-worker token streams cannot be re-sharded)"
            );
        }
        let spec = tsr::model::ModelSpec::proxy(
            config.get_usize("vocab", 64),
            config.get_usize("hidden", 32),
            config.get_usize("inter", 64),
            config.get_usize("heads", 2),
            config.get_usize("layers", 2),
        );
        let src = LmSource::new(
            &spec,
            workers,
            config.get_usize("batch", 4),
            config.get_usize("seq", 16),
            seed,
        );
        let desc = format!("lm:{}", spec.name);
        (Box::new(src), desc)
    } else {
        let noise = config.get_f64("noise", 0.01) as f32;
        let scale = config.get_str("scale", "tiny").to_string();
        let spec = if scale == "tiny" {
            tsr::model::ModelSpec::proxy(200, 32, 64, 2, 2)
        } else {
            proxy_spec(&scale)
        };
        let sim = QuadraticSim::new(&spec, workers, (spec.hidden / 2).max(8), noise, seed);
        let desc = format!("quad:{}", spec.name);
        (Box::new(sim), desc)
    };
    let blocks = source.blocks().to_vec();
    let mcfg = method_cfg_from_config(&config);
    let hyper = AdamHyper {
        lr,
        weight_decay: 0.0,
        scale: 1.0,
        ..Default::default()
    };
    let mut opt = mcfg.build_with_fmt(&blocks, hyper, workers, core_fmt_from_config(&config));

    let (mut params, metrics0, mut ledger0) = match &resume {
        Some(ck) => {
            assert_eq!(opt.name(), ck.method, "--resume: optimizer method mismatch");
            if workers != ck.workers {
                println!(
                    "elastic resume: {} -> {} workers (error-feedback state re-sharded; \
                     not bitwise vs the original world size)",
                    ck.workers, workers
                );
            }
            opt.load_state(&ck.opt_state, workers)
                .expect("--resume: restore optimizer state");
            source
                .load_state(&ck.source_state)
                .expect("--resume: restore source state");
            (
                ck.params.clone(),
                RunMetrics::state_from_json(&ck.metrics).expect("--resume: restore metrics"),
                CommLedger::from_json(&ck.ledger).expect("--resume: restore ledger"),
            )
        }
        None => (
            source.init_params(seed ^ 0xF00D),
            RunMetrics::new(opt.name()),
            CommLedger::new(),
        ),
    };
    // The ledger (fresh or checkpoint-restored) re-attaches the tracer
    // explicitly — trace state is never serialized into manifests.
    let (tracer, trace_out) = tracer_from_args(args);
    tracer.meta(opt.name(), workers);
    if start_step > 0 {
        tracer.resume(start_step as u64, workers);
    }
    ledger0.set_tracer(tracer.clone());

    let mut trainer =
        Trainer::new(topo, LrSchedule::paper(steps)).with_backend(backend.sized_for(workers));
    let save_every = args.get_usize("save-every", 0);
    if save_every > 0 {
        // New manifests echo the RESOLVED run shape: a resume that
        // overrode --steps/--workers writes checkpoints describing the
        // run it is actually executing, so a resume-of-resume picks
        // them up without re-typed flags.
        let mut save_config = config.clone();
        save_config.set("steps", tsr::util::json::Json::num(steps as f64));
        save_config.set("workers", tsr::util::json::Json::num(workers as f64));
        trainer.ckpt = Some(CkptCfg {
            every: save_every,
            dir: args.get_or("save-dir", "checkpoints").into(),
            config: save_config,
        });
    }
    let (mut metrics, ledger) = trainer.run_from(
        source.as_mut(),
        opt.as_mut(),
        &mut params,
        start_step,
        steps,
        metrics0,
        ledger0,
    );
    metrics.name = mcfg.label();

    println!(
        "== {} on {run_desc} ({} workers, {} backend{}) ==",
        mcfg.label(),
        workers,
        backend.name(),
        if start_step > 0 {
            format!(", resumed at step {start_step}")
        } else {
            String::new()
        }
    );
    println!("final loss      : {:.4}", metrics.final_loss());
    println!(
        "bytes/step      : {}",
        tsr::util::bench::fmt_bytes(ledger.bytes_per_step())
    );
    println!(
        "weights fp      : {:016x}",
        tsr::metrics::params_fingerprint(&params)
    );

    let default_out = if kind == "lm" {
        "results/train_lm.json"
    } else {
        "results/train_quad.json"
    };
    let out = args.get_or("out", default_out);
    if let Some(dir) = std::path::Path::new(out).parent() {
        let _ = std::fs::create_dir_all(dir);
    }
    std::fs::write(
        out,
        metrics
            .to_json_deterministic(&ledger, &params)
            .to_string_pretty(),
    )
    .expect("write run json");
    println!("-> wrote {out}");
    if let Some(tp) = &trace_out {
        tracer.write_jsonl(tp).unwrap_or_else(|e| panic!("{e}"));
        println!("-> wrote trace {tp}");
    }
}

/// Resolve the `tsr finetune` run shape into the config echo stored in
/// its checkpoint manifests. Defaults are the adaptation regime
/// (DESIGN.md §6, §14): TSR rank 8, refresh every 25, bf16 cores —
/// the configuration Table 5 prices against dense AdamW.
fn finetune_run_config(args: &Args, vocab: usize, dim: usize) -> tsr::util::json::Json {
    use tsr::util::json::Json;
    let core_fmt = args.get_or("core-fmt", "bf16");
    if let Err(e) = tsr::comm::ElemFmt::parse(core_fmt) {
        tsr::tsr_error!("error: --core-fmt: {e}");
        std::process::exit(2);
    }
    Json::obj(vec![
        ("source", Json::str("classify")),
        ("method", Json::str(args.get_or("method", "tsr"))),
        ("core_fmt", Json::str(core_fmt)),
        ("rank", Json::num(args.get_usize("rank", 8) as f64)),
        ("rank_emb", Json::num(args.get_usize("rank-emb", 8) as f64)),
        ("k", Json::num(args.get_usize("k", 25) as f64)),
        ("vocab", Json::num(vocab as f64)),
        ("dim", Json::num(dim as f64)),
        ("hidden", Json::num(args.get_usize("hidden", 32) as f64)),
        ("classes", Json::num(args.get_usize("classes", 4) as f64)),
        ("seq", Json::num(args.get_usize("seq", 16) as f64)),
        ("batch", Json::num(args.get_usize("batch", 16) as f64)),
        ("workers", Json::num(args.get_usize("workers", 2) as f64)),
        ("steps", Json::num(args.get_usize("steps", 150) as f64)),
        ("lr", Json::num(args.get_f64("lr", 0.02))),
        (
            "seed",
            tsr::checkpoint::codec::u64_to_json(args.get_u64("seed", 42)),
        ),
    ])
}

/// `tsr finetune` — the second leg of the pretrain → finetune pipeline
/// (DESIGN.md §6): load a `train --source lm` checkpoint, transfer its
/// token-embedding table bit-for-bit into a [`ClassifyTask`]
/// (`tsr::train::finetune`), and train the task with the adaptation-
/// regime optimizer. `--resume` continues a fine-tune checkpoint
/// byte-for-byte at the same world size, exactly like `train --resume`.
fn run_finetune(args: &Args) {
    use tsr::checkpoint::Checkpoint;
    use tsr::comm::{CommLedger, Topology};
    use tsr::metrics::RunMetrics;
    use tsr::optim::{AdamHyper, LrSchedule};
    use tsr::train::finetune::ClassifyTask;
    use tsr::train::{CkptCfg, GradSource, Trainer};

    let backend = backend_from_args(args);
    let resume = args.get("resume").map(|p| {
        let ck = Checkpoint::load(p).unwrap_or_else(|e| panic!("--resume: {e}"));
        let src = ck.config.get_str("source", "?").to_string();
        assert_eq!(
            src, "classify",
            "--resume: checkpoint source `{src}` is not a finetune run (classify); \
             pretrain checkpoints go through --from"
        );
        ck
    });
    // One resolved config drives both paths, same contract as `train`:
    // a resume trusts the manifest's echo, not re-typed flags.
    let config = match &resume {
        Some(ck) => {
            const CONFIG_ONLY: &[&str] = &[
                "method", "rank", "rank-emb", "k", "core-fmt", "hidden", "classes", "seq",
                "batch", "workers", "lr", "seed", "from",
            ];
            for flag in CONFIG_ONLY {
                if args.get(flag).is_some() {
                    tsr::tsr_warn!(
                        "warning: --{flag} is fixed by the checkpoint's config and was ignored \
                         (--resume honors only --steps/--backend/--out/--save-*)"
                    );
                }
            }
            ck.config.clone()
        }
        None => {
            let from = args.get("from").unwrap_or_else(|| {
                tsr::tsr_error!(
                    "error: finetune needs --from <pretrain checkpoint> \
                     (a `train --source lm --save-every N` manifest) or --resume <finetune checkpoint>"
                );
                std::process::exit(2);
            });
            let ck = Checkpoint::load(from).unwrap_or_else(|e| panic!("--from: {e}"));
            let src = ck.config.get_str("source", "?");
            assert_eq!(
                src, "lm",
                "--from: checkpoint source `{src}` has no token embedding to transfer \
                 (need a `train --source lm` checkpoint)"
            );
            // Locate the embedding param by the LM trainer's block order
            // (`blocks_untied_lm` — the untied head is Embedding-class
            // too, so match `embed_tokens` by name), the same spec
            // reconstruction `train --resume` performs.
            let spec = tsr::model::ModelSpec::proxy(
                ck.config.get_usize("vocab", 64),
                ck.config.get_usize("hidden", 32),
                ck.config.get_usize("inter", 64),
                ck.config.get_usize("heads", 2),
                ck.config.get_usize("layers", 2),
            );
            let idx = spec
                .blocks_untied_lm()
                .iter()
                .position(|b| b.name == "embed_tokens")
                .expect("--from: LM spec has no embed_tokens block");
            let emb = &ck.params[idx];
            println!(
                "transfer: {} ({}x{} token embedding from `{}`, step {})",
                spec.name, emb.rows, emb.cols, from, ck.step
            );
            let mut cfg = finetune_run_config(args, emb.rows, emb.cols);
            cfg.set("from", tsr::util::json::Json::str(from));
            // The embedding rides along only until init below; stash it
            // where the fresh-run arm can reach it.
            cfg.set("_emb", tsr::checkpoint::codec::matrix_to_json(emb));
            cfg
        }
    };
    let start_step = resume.as_ref().map(|ck| ck.step as usize).unwrap_or(0);
    let steps = args.get_usize("steps", config.get_usize("steps", 150));
    assert!(
        steps > start_step,
        "--steps {steps} must exceed the checkpoint's completed step {start_step}"
    );
    // World size is config-fixed: the task's sample stream is a single
    // RNG shared across workers, so it cannot re-shard elastically.
    let workers = config.get_usize("workers", 2);
    let lr = config.get_f64("lr", 0.02) as f32;
    let seed = tsr::checkpoint::codec::u64_from_json(config.get("seed"), "config.seed")
        .expect("config.seed");
    let mut task = ClassifyTask::new(
        config.get_usize("vocab", 64),
        config.get_usize("dim", 32),
        config.get_usize("hidden", 32),
        config.get_usize("classes", 4),
        config.get_usize("seq", 16),
        workers,
        config.get_usize("batch", 16),
        seed,
    );
    let blocks = task.blocks().to_vec();
    let mcfg = method_cfg_from_config(&config);
    let hyper = AdamHyper {
        lr,
        weight_decay: 0.0,
        scale: 1.0,
        ..Default::default()
    };
    let mut opt = mcfg.build_with_fmt(&blocks, hyper, workers, core_fmt_from_config(&config));

    let (mut params, metrics0, mut ledger0) = match &resume {
        Some(ck) => {
            assert_eq!(opt.name(), ck.method, "--resume: optimizer method mismatch");
            assert_eq!(
                workers, ck.workers,
                "--resume: finetune world size is fixed by the checkpoint"
            );
            opt.load_state(&ck.opt_state, workers)
                .expect("--resume: restore optimizer state");
            task.load_state(&ck.source_state)
                .expect("--resume: restore task state");
            (
                ck.params.clone(),
                RunMetrics::state_from_json(&ck.metrics).expect("--resume: restore metrics"),
                CommLedger::from_json(&ck.ledger).expect("--resume: restore ledger"),
            )
        }
        None => {
            let emb = tsr::checkpoint::codec::matrix_from_json(config.get("_emb"), "embedding")
                .expect("transfer embedding");
            (
                task.init_params_pretrained(seed ^ 0xF00D, &emb),
                RunMetrics::new(opt.name()),
                CommLedger::new(),
            )
        }
    };
    let (tracer, trace_out) = tracer_from_args(args);
    tracer.meta(opt.name(), workers);
    if start_step > 0 {
        tracer.resume(start_step as u64, workers);
    }
    ledger0.set_tracer(tracer.clone());

    let mut trainer = Trainer::new(Topology::single_node(workers), LrSchedule::constant())
        .with_backend(backend.sized_for(workers));
    let save_every = args.get_usize("save-every", 0);
    if save_every > 0 {
        // Manifests echo the resolved run shape minus the transfer-time
        // embedding (it lives in `params` from here on).
        let mut save_config = config.clone();
        save_config.set("steps", tsr::util::json::Json::num(steps as f64));
        save_config.set("_emb", tsr::util::json::Json::Null);
        trainer.ckpt = Some(CkptCfg {
            every: save_every,
            dir: args.get_or("save-dir", "checkpoints").into(),
            config: save_config,
        });
    }
    let (mut metrics, ledger) = trainer.run_from(
        &mut task,
        opt.as_mut(),
        &mut params,
        start_step,
        steps,
        metrics0,
        ledger0,
    );
    metrics.name = mcfg.label();

    println!(
        "== finetune {} on classify:{}x{} ({} workers, {} backend{}) ==",
        mcfg.label(),
        task.vocab,
        task.dim,
        workers,
        backend.name(),
        if start_step > 0 {
            format!(", resumed at step {start_step}")
        } else {
            String::new()
        }
    );
    println!("final loss      : {:.4}", metrics.final_loss());
    println!("accuracy        : {:.3}", task.accuracy(&params));
    println!(
        "bytes/step      : {}",
        tsr::util::bench::fmt_bytes(ledger.bytes_per_step())
    );
    println!(
        "weights fp      : {:016x}",
        tsr::metrics::params_fingerprint(&params)
    );

    let out = args.get_or("out", "results/finetune.json");
    if let Some(dir) = std::path::Path::new(out).parent() {
        let _ = std::fs::create_dir_all(dir);
    }
    std::fs::write(
        out,
        metrics
            .to_json_deterministic(&ledger, &params)
            .to_string_pretty(),
    )
    .expect("write run json");
    println!("-> wrote {out}");
    if let Some(tp) = &trace_out {
        tracer.write_jsonl(tp).unwrap_or_else(|e| panic!("{e}"));
        println!("-> wrote trace {tp}");
    }
}

/// End-to-end PJRT training: the real L1+L2+L3 composition.
fn run_train_pjrt(args: &Args) {
    use tsr::comm::Topology;
    use tsr::data::{Batcher, SyntheticCorpus};
    use tsr::optim::{AdamHyper, LrSchedule};
    use tsr::train::pjrt_source::PjrtSource;
    use tsr::train::{GradSource, Trainer};

    let manifest_path = args.get_or("manifest", "artifacts/tiny_manifest.json");
    let steps = args.get_usize("steps", 200);
    let workers = args.get_usize("workers", 4);
    let lr = args.get_f64("lr", 0.01) as f32;

    let manifest = tsr::runtime::Manifest::load(manifest_path).expect("load manifest");
    let engine = tsr::runtime::Engine::cpu().expect("pjrt cpu client");
    println!(
        "loaded {} (vocab {}, hidden {}, layers {}, batch {}, seq {}) on {}",
        manifest.name,
        manifest.vocab,
        manifest.hidden,
        manifest.layers,
        manifest.batch,
        manifest.seq,
        engine.platform()
    );
    let model = engine.load_model(manifest.clone()).expect("compile artifact");
    let corpus = SyntheticCorpus::new(manifest.vocab, 0xC0);
    let batcher = Batcher::new(corpus, workers, manifest.batch, manifest.seq, 0xDA7A);
    let mut source = PjrtSource::new(model, batcher);
    let blocks = source.blocks().to_vec();

    let method_config = method_config_json(args, manifest.hidden);
    let mcfg = method_cfg_from_config(&method_config);
    let hyper = AdamHyper {
        lr,
        weight_decay: 0.0,
        scale: 1.0,
        ..Default::default()
    };
    let mut opt = mcfg.build_with_fmt(&blocks, hyper, workers, core_fmt_from_config(&method_config));
    let mut params = source.init_params(args.get_u64("seed", 42));
    let mut trainer = Trainer::new(
        Topology::multi_node(2, workers.div_ceil(2)),
        LrSchedule::paper(steps),
    )
    .with_backend(backend_from_args(args).sized_for(workers));
    trainer.verbose = true;
    trainer.log_every = args.get_usize("log-every", 10);
    trainer.sim = Some(tsr::sim::SimCfg {
        tokens_per_step: manifest.batch * manifest.seq,
        ..Default::default()
    });
    let (tracer, trace_out) = tracer_from_args(args);
    tracer.meta(opt.name(), workers);
    let mut ledger0 = tsr::comm::CommLedger::new();
    ledger0.set_tracer(tracer.clone());
    let t0 = std::time::Instant::now();
    let (metrics, ledger) = trainer.run_from(
        &mut source,
        opt.as_mut(),
        &mut params,
        0,
        steps,
        tsr::metrics::RunMetrics::new(opt.name()),
        ledger0,
    );
    let wall = t0.elapsed().as_secs_f64();

    println!("\n== {} on {} ==", mcfg.label(), manifest.name);
    println!("backend         : {} ({} workers)", trainer.exec.name(), workers);
    println!("final loss      : {:.4}", metrics.final_loss());
    println!(
        "bytes/step      : {}",
        tsr::util::bench::fmt_bytes(ledger.bytes_per_step())
    );
    println!(
        "peak bytes      : {}",
        tsr::util::bench::fmt_bytes(ledger.peak_bytes() as f64)
    );
    println!(
        "cumulative bytes: {}",
        tsr::util::bench::fmt_bytes(*metrics.cum_bytes.last().unwrap_or(&0) as f64)
    );
    println!("optimizer state : {} elements", opt.state_elements());
    let (intra, inter) = ledger.link_totals();
    println!(
        "wire bytes      : {} intra-node + {} inter-node",
        tsr::util::bench::fmt_bytes(intra as f64),
        tsr::util::bench::fmt_bytes(inter as f64)
    );
    println!("sim comm time   : {:.3}s (serial α–β oracle)", ledger.sim_time);
    println!(
        "predicted step  : {:.2}ms avg, {:.2}ms exposed comm (event engine)",
        1e3 * metrics.predicted_step_secs / steps as f64,
        1e3 * metrics.exposed_comm_secs / steps as f64
    );
    println!("wall time       : {wall:.1}s  ({:.3}s/step)", wall / steps as f64);

    let out = args.get_or("out", "results/train_run.json");
    let _ = std::fs::create_dir_all("results");
    std::fs::write(out, metrics.to_json().to_string_pretty()).expect("write run json");
    println!("-> wrote {out}");
    if let Some(tp) = &trace_out {
        tracer.write_jsonl(tp).unwrap_or_else(|e| panic!("{e}"));
        println!("-> wrote trace {tp}");
    }
}
