//! `tsr` — CLI for the TSR-Adam reproduction.
//!
//! Subcommands (see DESIGN.md §3 for the experiment index):
//!   table1|table2|table3|table4|table6   regenerate paper tables
//!   fig1|fig3|fig4|fig5                  regenerate paper figure data
//!   simtime                              Fig 6: step-time breakdown (sim/)
//!   theory                               Theorem 1 validation sweep
//!   train                                PJRT end-to-end training run
//!   info                                 platform / artifact status

use tsr::exp::{figures, tables, theory};
use tsr::metrics::results_path;
use tsr::util::cli::Args;

fn main() {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("table1") => {
            let m = args.get_usize("m", 4096);
            let n = args.get_usize("n", 4096);
            let r = args.get_usize("rank", 128);
            write_results("table1.json", &tables::table1(m, n, r));
        }
        Some("table2") => {
            let scale = args.get_or("scale", "60m");
            let spec = tsr::model::ModelSpec::by_name(scale).expect("unknown scale");
            let r = args.get_usize("rank", 256);
            let re = args.get_usize("rank-emb", 64);
            write_results("table2.json", &tables::table2(&spec, r, re));
        }
        Some("table3") => {
            let steps = args.get_usize("loss-steps", 200);
            // Full-scale step timing is opt-in: a 1B-scale TSR step is
            // ~1 TFLOP of projections (minutes on a single core).
            let timing = args.flag("timing");
            write_results("table3.json", &tables::table3(steps, timing));
        }
        Some("table4") => {
            let steps = args.get_usize("steps", 150);
            write_results("table4.json", &tables::table4(steps));
        }
        Some("table6") => {
            write_results("table6.json", &tables::table6());
        }
        Some("fig1") => {
            figures::fig1(args.get_usize("steps", 300), args.get_usize("workers", 4));
        }
        Some("fig3") => {
            figures::fig3(args.get_usize("steps", 300), args.get_usize("workers", 4));
        }
        Some("fig4") => {
            figures::fig4(args.get_usize("steps", 250), args.get_usize("workers", 4));
        }
        Some("fig5") => {
            figures::fig5(args.get_usize("steps", 300), args.get_usize("workers", 4));
        }
        Some("simtime") => {
            let cfg = tsr::sim::SimCfg {
                bucket_bytes: args.get_usize("bucket-kb", 25 * 1024) * 1024,
                flops: args.get_f64("flops", 312e12),
                tokens_per_step: args.get_usize("tokens", 8192),
                overlap: !args.flag("no-overlap"),
                hierarchical: !args.flag("flat"),
            };
            let j = tsr::exp::simtime::simtime(
                args.get_or("scale", "60m"),
                args.get_usize("nodes", 4),
                args.get_usize("gpus", 8),
                args.get_usize("steps", 100),
                &cfg,
                &backend_from_args(args),
            );
            write_results("fig6_simtime.json", &j);
        }
        Some("theory") => {
            let horizons: Vec<usize> = args
                .get_or("horizons", "50,100,200,400,800")
                .split(',')
                .filter_map(|s| s.parse().ok())
                .collect();
            let j = theory::theory_sweep(&horizons, args.get_usize("workers", 2), args.get_usize("k", 25));
            write_results("theory.json", &j);
        }
        Some("train") => run_train(&args),
        Some("info") => info(),
        other => {
            if let Some(cmd) = other {
                eprintln!("unknown subcommand: {cmd}\n");
            }
            eprintln!(
                "usage: tsr <subcommand> [--options]\n\
                 \n  tables:   table1 table2 table3 [--loss-steps N] table4 table6\
                 \n  figures:  fig1 fig3 fig4 fig5 [--steps N --workers W]\
                 \n  simtime:  simtime [--scale 60m --nodes 4 --gpus 8 --steps N \
                 --bucket-kb K --tokens T --flops F --no-overlap --flat]\
                 \n  theory:   theory [--horizons 50,100,...]\
                 \n  train:    train --manifest artifacts/tiny_manifest.json \
                 [--method tsr|adamw|galore|signadam|topk] [--steps N] [--workers W] \
                 [--k-var N] [--keep-frac F]\
                 \n            --workers N       simulated data-parallel workers (default 4)\
                 \n            --backend B       execution backend: sequential | threaded \
                 (default $TSR_BACKEND or sequential; both are bitwise-identical — \
                 threaded runs one OS thread per worker, see DESIGN.md §8)\
                 \n            --source quad     synthetic low-rank quadratic instead of a \
                 PJRT manifest (no artifacts needed; deterministic metrics JSON \
                 for CI's cross-backend gate)\
                 \n  info"
            );
            std::process::exit(if other.is_some() { 2 } else { 0 });
        }
    }
}

fn write_results(name: &str, j: &tsr::util::json::Json) {
    let p = results_path(name);
    std::fs::write(&p, j.to_string_pretty()).expect("write results");
    println!("\n-> wrote {}", p.display());
}

/// `--backend sequential|threaded`, falling back to `$TSR_BACKEND`.
fn backend_from_args(args: &Args) -> tsr::exec::ExecBackend {
    match args.get("backend") {
        Some(name) => tsr::exec::ExecBackend::parse(name)
            .unwrap_or_else(|| panic!("unknown backend {name} (sequential|threaded)")),
        None => tsr::exec::ExecBackend::from_env(),
    }
}

/// Method config shared by both train sources; rank defaults derive
/// from the model's hidden dimension.
fn method_cfg_from_args(args: &Args, hidden: usize) -> tsr::exp::MethodCfg {
    use tsr::exp::MethodCfg;
    use tsr::optim::onesided::OneSidedRefresh;
    use tsr::optim::TsrConfig;

    let rank = args.get_usize("rank", (hidden / 4).max(4));
    let rank_emb = args.get_usize("rank-emb", (hidden / 8).max(4));
    let k = args.get_usize("k", 50);
    match args.get_or("method", "tsr") {
        "adamw" => MethodCfg::Adam,
        "galore" => MethodCfg::OneSided {
            rank,
            k,
            refresh: OneSidedRefresh::RandomizedSvd,
        },
        "tsr" => MethodCfg::Tsr(TsrConfig {
            rank,
            rank_emb,
            refresh_every: k,
            refresh_emb: k,
            oversample: 8,
            ..Default::default()
        }),
        "signadam" => MethodCfg::Sign {
            k_var: args.get_usize("k-var", 100),
        },
        "topk" => MethodCfg::TopK {
            keep_frac: args.get_f64("keep-frac", 0.01),
        },
        other => panic!("unknown method {other}"),
    }
}

fn info() {
    match tsr::runtime::Engine::cpu() {
        Ok(e) => println!("PJRT platform: {}", e.platform()),
        Err(e) => println!("PJRT unavailable: {e}"),
    }
    for name in ["tiny_manifest.json", "e2e_manifest.json"] {
        let p = std::path::Path::new("artifacts").join(name);
        println!(
            "artifact {}: {}",
            p.display(),
            if p.exists() { "present" } else { "missing (run `make artifacts`)" }
        );
    }
}

/// `tsr train` front door: dispatch on gradient source.
fn run_train(args: &Args) {
    match args.get_or("source", "pjrt") {
        "quad" => run_train_quad(args),
        "pjrt" => run_train_pjrt(args),
        other => panic!("unknown --source {other} (pjrt|quad)"),
    }
}

/// Synthetic low-rank quadratic training — no PJRT artifacts needed.
/// Emits the *deterministic* metrics JSON (no wall-clock fields, plus a
/// final-weight fingerprint), which CI's determinism gate runs twice
/// per backend and diffs byte-for-byte.
fn run_train_quad(args: &Args) {
    use tsr::comm::Topology;
    use tsr::exp::runs::proxy_spec;
    use tsr::optim::{AdamHyper, LrSchedule};
    use tsr::train::gradsim::QuadraticSim;
    use tsr::train::{GradSource, Trainer};

    let steps = args.get_usize("steps", 40);
    let workers = args.get_usize("workers", 4);
    let lr = args.get_f64("lr", 0.05) as f32;
    let noise = args.get_f64("noise", 0.01) as f32;
    let seed = args.get_u64("seed", 42);
    let backend = backend_from_args(args);
    let scale = args.get_or("scale", "tiny");
    let spec = if scale == "tiny" {
        tsr::model::ModelSpec::proxy(200, 32, 64, 2, 2)
    } else {
        proxy_spec(scale)
    };
    let topo = match args.get_or("topo", "multi_node") {
        "single_node" => Topology::single_node(workers),
        "multi_node" => Topology::multi_node(2, workers.div_ceil(2)),
        "ethernet" => Topology::ethernet(2, workers.div_ceil(2)),
        other => panic!("unknown --topo {other} (single_node|multi_node|ethernet)"),
    };

    let mut sim = QuadraticSim::new(&spec, workers, (spec.hidden / 2).max(8), noise, seed);
    let blocks = sim.blocks().to_vec();
    let mcfg = method_cfg_from_args(args, spec.hidden);
    let hyper = AdamHyper {
        lr,
        weight_decay: 0.0,
        scale: 1.0,
        ..Default::default()
    };
    let mut opt = mcfg.build(&blocks, hyper, workers);
    let mut params = sim.init_params(seed ^ 0xF00D);
    let trainer = Trainer::new(topo, LrSchedule::paper(steps)).with_backend(backend);
    let (mut metrics, ledger) = trainer.run(&mut sim, opt.as_mut(), &mut params, steps);
    metrics.name = mcfg.label();

    println!(
        "== {} on quad:{} ({} workers, {} backend) ==",
        mcfg.label(),
        spec.name,
        workers,
        backend.name()
    );
    println!("final loss      : {:.4}", metrics.final_loss());
    println!(
        "bytes/step      : {}",
        tsr::util::bench::fmt_bytes(ledger.bytes_per_step())
    );
    println!(
        "weights fp      : {:016x}",
        tsr::metrics::params_fingerprint(&params)
    );

    let out = args.get_or("out", "results/train_quad.json");
    if let Some(dir) = std::path::Path::new(out).parent() {
        let _ = std::fs::create_dir_all(dir);
    }
    std::fs::write(
        out,
        metrics
            .to_json_deterministic(&ledger, &params)
            .to_string_pretty(),
    )
    .expect("write run json");
    println!("-> wrote {out}");
}

/// End-to-end PJRT training: the real L1+L2+L3 composition.
fn run_train_pjrt(args: &Args) {
    use tsr::comm::Topology;
    use tsr::data::{Batcher, SyntheticCorpus};
    use tsr::optim::{AdamHyper, LrSchedule};
    use tsr::train::pjrt_source::PjrtSource;
    use tsr::train::{GradSource, Trainer};

    let manifest_path = args.get_or("manifest", "artifacts/tiny_manifest.json");
    let steps = args.get_usize("steps", 200);
    let workers = args.get_usize("workers", 4);
    let lr = args.get_f64("lr", 0.01) as f32;

    let manifest = tsr::runtime::Manifest::load(manifest_path).expect("load manifest");
    let engine = tsr::runtime::Engine::cpu().expect("pjrt cpu client");
    println!(
        "loaded {} (vocab {}, hidden {}, layers {}, batch {}, seq {}) on {}",
        manifest.name,
        manifest.vocab,
        manifest.hidden,
        manifest.layers,
        manifest.batch,
        manifest.seq,
        engine.platform()
    );
    let model = engine.load_model(manifest.clone()).expect("compile artifact");
    let corpus = SyntheticCorpus::new(manifest.vocab, 0xC0);
    let batcher = Batcher::new(corpus, workers, manifest.batch, manifest.seq, 0xDA7A);
    let mut source = PjrtSource::new(model, batcher);
    let blocks = source.blocks().to_vec();

    let mcfg = method_cfg_from_args(args, manifest.hidden);
    let hyper = AdamHyper {
        lr,
        weight_decay: 0.0,
        scale: 1.0,
        ..Default::default()
    };
    let mut opt = mcfg.build(&blocks, hyper, workers);
    let mut params = source.init_params(args.get_u64("seed", 42));
    let mut trainer = Trainer::new(
        Topology::multi_node(2, workers.div_ceil(2)),
        LrSchedule::paper(steps),
    )
    .with_backend(backend_from_args(args));
    trainer.verbose = true;
    trainer.log_every = args.get_usize("log-every", 10);
    trainer.sim = Some(tsr::sim::SimCfg {
        tokens_per_step: manifest.batch * manifest.seq,
        ..Default::default()
    });
    let t0 = std::time::Instant::now();
    let (metrics, ledger) = trainer.run(&mut source, opt.as_mut(), &mut params, steps);
    let wall = t0.elapsed().as_secs_f64();

    println!("\n== {} on {} ==", mcfg.label(), manifest.name);
    println!("backend         : {} ({} workers)", trainer.exec.name(), workers);
    println!("final loss      : {:.4}", metrics.final_loss());
    println!(
        "bytes/step      : {}",
        tsr::util::bench::fmt_bytes(ledger.bytes_per_step())
    );
    println!(
        "peak bytes      : {}",
        tsr::util::bench::fmt_bytes(ledger.peak_bytes() as f64)
    );
    println!(
        "cumulative bytes: {}",
        tsr::util::bench::fmt_bytes(*metrics.cum_bytes.last().unwrap_or(&0) as f64)
    );
    println!("optimizer state : {} elements", opt.state_elements());
    let (intra, inter) = ledger.link_totals();
    println!(
        "wire bytes      : {} intra-node + {} inter-node",
        tsr::util::bench::fmt_bytes(intra as f64),
        tsr::util::bench::fmt_bytes(inter as f64)
    );
    println!("sim comm time   : {:.3}s (serial α–β oracle)", ledger.sim_time);
    println!(
        "predicted step  : {:.2}ms avg, {:.2}ms exposed comm (event engine)",
        1e3 * metrics.predicted_step_secs / steps as f64,
        1e3 * metrics.exposed_comm_secs / steps as f64
    );
    println!("wall time       : {wall:.1}s  ({:.3}s/step)", wall / steps as f64);

    let out = args.get_or("out", "results/train_run.json");
    let _ = std::fs::create_dir_all("results");
    std::fs::write(out, metrics.to_json().to_string_pretty()).expect("write run json");
    println!("-> wrote {out}");
}
