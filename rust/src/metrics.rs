//! Run metrics: loss curves, byte curves, CSV/JSON emission for the
//! table/figure regeneration harness.

use crate::util::json::Json;
use std::io::Write;
use std::path::Path;

#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    pub name: String,
    pub loss: Vec<f32>,
    /// Cumulative communicated bytes after each step.
    pub cum_bytes: Vec<u64>,
    /// Wall-clock seconds per optimizer step (measured, this host).
    pub step_secs: Vec<f64>,
    /// Simulated communication seconds (serial α–β model — the ledger's
    /// closed-form oracle, no bucketing or overlap).
    pub sim_comm_secs: f64,
    /// Total predicted step seconds from the discrete-event engine
    /// (bucketed, hierarchical, overlapped) when `Trainer::sim` is set.
    pub predicted_step_secs: f64,
    /// Total exposed (non-overlapped) communication seconds predicted by
    /// the engine.
    pub exposed_comm_secs: f64,
}

impl RunMetrics {
    pub fn new(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            ..Default::default()
        }
    }

    pub fn final_loss(&self) -> f32 {
        // Mean of the last 5% of steps — smooths stochastic batch noise.
        if self.loss.is_empty() {
            return f32::NAN;
        }
        let k = (self.loss.len() / 20).max(1);
        let tail = &self.loss[self.loss.len() - k..];
        tail.iter().sum::<f32>() / k as f32
    }

    pub fn mean_step_secs(&self) -> f64 {
        if self.step_secs.is_empty() {
            return 0.0;
        }
        self.step_secs.iter().sum::<f64>() / self.step_secs.len() as f64
    }

    /// Write a CSV with step, loss, cumulative bytes.
    pub fn write_csv(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "step,loss,cum_bytes")?;
        for i in 0..self.loss.len() {
            writeln!(
                f,
                "{},{},{}",
                i,
                self.loss[i],
                self.cum_bytes.get(i).copied().unwrap_or(0)
            )?;
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("final_loss", Json::num(self.final_loss() as f64)),
            (
                "loss",
                Json::Arr(self.loss.iter().map(|&l| Json::num(l as f64)).collect()),
            ),
            (
                "cum_bytes",
                Json::Arr(self.cum_bytes.iter().map(|&b| Json::num(b as f64)).collect()),
            ),
            ("mean_step_secs", Json::num(self.mean_step_secs())),
            ("sim_comm_secs", Json::num(self.sim_comm_secs)),
            ("predicted_step_secs", Json::num(self.predicted_step_secs)),
            ("exposed_comm_secs", Json::num(self.exposed_comm_secs)),
        ])
    }
}

/// Ensure `results/` exists and return the path for `name`.
pub fn results_path(name: &str) -> std::path::PathBuf {
    let dir = std::path::PathBuf::from("results");
    let _ = std::fs::create_dir_all(&dir);
    dir.join(name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn final_loss_is_tail_mean() {
        let mut m = RunMetrics::new("x");
        m.loss = (0..100).map(|i| 100.0 - i as f32).collect();
        // last 5 values: 5..1 → mean 3
        assert!((m.final_loss() - 3.0).abs() < 1e-5);
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut m = RunMetrics::new("y");
        m.loss = vec![3.0, 2.0];
        m.cum_bytes = vec![10, 20];
        let p = std::env::temp_dir().join("tsr_metrics_test.csv");
        m.write_csv(&p).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert!(s.contains("step,loss,cum_bytes"));
        assert_eq!(s.lines().count(), 3);
    }

    #[test]
    fn json_contains_fields() {
        let mut m = RunMetrics::new("z");
        m.loss = vec![1.0];
        let j = m.to_json();
        assert_eq!(j.get("name").as_str(), Some("z"));
        assert!(j.get("final_loss").as_f64().is_some());
    }
}
