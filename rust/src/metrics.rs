//! Run metrics: loss curves, byte curves, CSV/JSON emission for the
//! table/figure regeneration harness.

use crate::comm::CommLedger;
use crate::linalg::Matrix;
use crate::util::json::Json;
use std::path::Path;

/// FNV-1a over the little-endian bit patterns of every parameter — a
/// cheap bitwise-equality witness. Two runs produce the same
/// fingerprint iff every weight bit matches; CI's determinism gate
/// diffs it (inside [`RunMetrics::to_json_deterministic`]) across
/// repeated runs and across execution backends.
pub fn params_fingerprint(params: &[Matrix]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for p in params {
        for v in &p.data {
            for b in v.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x0100_0000_01b3);
            }
        }
    }
    h
}

#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    pub name: String,
    pub loss: Vec<f32>,
    /// Cumulative communicated bytes after each step.
    pub cum_bytes: Vec<u64>,
    /// Wall-clock seconds per optimizer step (measured, this host).
    pub step_secs: Vec<f64>,
    /// Simulated communication seconds (serial α–β model — the ledger's
    /// closed-form oracle, no bucketing or overlap).
    pub sim_comm_secs: f64,
    /// Total predicted step seconds from the discrete-event engine
    /// (bucketed, hierarchical, overlapped) when `Trainer::sim` is set.
    pub predicted_step_secs: f64,
    /// Total exposed (non-overlapped) communication seconds predicted by
    /// the engine.
    pub exposed_comm_secs: f64,
}

impl RunMetrics {
    pub fn new(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            ..Default::default()
        }
    }

    pub fn final_loss(&self) -> f32 {
        // Mean of the last 5% of steps — smooths stochastic batch noise.
        if self.loss.is_empty() {
            return f32::NAN;
        }
        let k = (self.loss.len() / 20).max(1);
        let tail = &self.loss[self.loss.len() - k..];
        tail.iter().sum::<f32>() / k as f32
    }

    pub fn mean_step_secs(&self) -> f64 {
        if self.step_secs.is_empty() {
            return 0.0;
        }
        self.step_secs.iter().sum::<f64>() / self.step_secs.len() as f64
    }

    /// Write a CSV with step, loss, cumulative bytes. Atomic (tmp +
    /// rename, parent directory created) via the same helper the
    /// checkpoint manifests use; every failure names the path — the old
    /// version assumed the directory existed and surfaced a bare
    /// `NotFound` when it didn't.
    pub fn write_csv(&self, path: impl AsRef<Path>) -> Result<(), String> {
        let mut text = String::from("step,loss,cum_bytes\n");
        for i in 0..self.loss.len() {
            text.push_str(&format!(
                "{},{},{}\n",
                i,
                self.loss[i],
                self.cum_bytes.get(i).copied().unwrap_or(0)
            ));
        }
        crate::util::json::write_text_atomic(path, &text)
    }

    /// Backend-determinism witness: every field here is a deterministic
    /// function of (method, topology, seed) — losses, byte curves,
    /// ledger columns, simulated times, and the final-weight
    /// fingerprint, but **no wall-clock measurements**. CI runs `tsr
    /// train --source quad` twice per backend and diffs this output
    /// byte-for-byte; any nondeterminism (or cross-backend divergence)
    /// fails the gate.
    pub fn to_json_deterministic(&self, ledger: &CommLedger, params: &[Matrix]) -> Json {
        let (intra, inter) = ledger.link_totals();
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("final_loss", Json::num(self.final_loss() as f64)),
            (
                "loss",
                Json::Arr(self.loss.iter().map(|&l| Json::num(l as f64)).collect()),
            ),
            (
                "cum_bytes",
                Json::Arr(self.cum_bytes.iter().map(|&b| Json::num(b as f64)).collect()),
            ),
            ("bytes_per_step", Json::num(ledger.bytes_per_step())),
            ("peak_bytes", Json::num(ledger.peak_bytes() as f64)),
            ("wire_intra_bytes", Json::num(intra as f64)),
            ("wire_inter_bytes", Json::num(inter as f64)),
            ("sim_comm_secs", Json::num(self.sim_comm_secs)),
            ("predicted_step_secs", Json::num(self.predicted_step_secs)),
            ("exposed_comm_secs", Json::num(self.exposed_comm_secs)),
            (
                "params_fingerprint",
                Json::str(format!("{:016x}", params_fingerprint(params))),
            ),
        ])
    }

    /// Checkpoint serialization of the run-so-far metrics: the loss
    /// trajectory (bit-exact f32 hex) and the engine-prediction f64
    /// accumulators (bit patterns). `cum_bytes` and `sim_comm_secs`
    /// are NOT stored — both are recomputed from the resumed ledger at
    /// run end — and `step_secs` is wall clock, which a resumed run
    /// legitimately re-measures (it never enters the deterministic
    /// JSON).
    pub fn state_to_json(&self) -> Json {
        use crate::checkpoint::codec;
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("loss_f32le", Json::str(codec::f32s_to_hex(&self.loss))),
            ("predicted_step_secs", codec::f64_to_json(self.predicted_step_secs)),
            ("exposed_comm_secs", codec::f64_to_json(self.exposed_comm_secs)),
        ])
    }

    /// Inverse of [`Self::state_to_json`].
    pub fn state_from_json(j: &Json) -> Result<Self, String> {
        use crate::checkpoint::codec;
        let mut m = RunMetrics::new(j.get("name").as_str().ok_or("metrics: missing name")?);
        m.loss = codec::f32s_from_hex(
            j.get("loss_f32le").as_str().ok_or("metrics: missing loss_f32le")?,
        )
        .map_err(|e| format!("metrics.loss: {e}"))?;
        m.predicted_step_secs =
            codec::f64_from_json(j.get("predicted_step_secs"), "metrics.predicted_step_secs")?;
        m.exposed_comm_secs =
            codec::f64_from_json(j.get("exposed_comm_secs"), "metrics.exposed_comm_secs")?;
        Ok(m)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("final_loss", Json::num(self.final_loss() as f64)),
            (
                "loss",
                Json::Arr(self.loss.iter().map(|&l| Json::num(l as f64)).collect()),
            ),
            (
                "cum_bytes",
                Json::Arr(self.cum_bytes.iter().map(|&b| Json::num(b as f64)).collect()),
            ),
            ("mean_step_secs", Json::num(self.mean_step_secs())),
            ("sim_comm_secs", Json::num(self.sim_comm_secs)),
            ("predicted_step_secs", Json::num(self.predicted_step_secs)),
            ("exposed_comm_secs", Json::num(self.exposed_comm_secs)),
        ])
    }
}

/// Ensure `results/` exists and return the path for `name`. A failed
/// mkdir (permissions, a `results` FILE squatting on the name) used to
/// be silently swallowed here and resurface as a confusing `NotFound`
/// at write time; now it is a loud error naming the directory.
pub fn results_path(name: &str) -> Result<std::path::PathBuf, String> {
    let dir = std::path::PathBuf::from("results");
    std::fs::create_dir_all(&dir)
        .map_err(|e| format!("results dir {}: cannot create: {e}", dir.display()))?;
    Ok(dir.join(name))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn final_loss_is_tail_mean() {
        let mut m = RunMetrics::new("x");
        m.loss = (0..100).map(|i| 100.0 - i as f32).collect();
        // last 5 values: 5..1 → mean 3
        assert!((m.final_loss() - 3.0).abs() < 1e-5);
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut m = RunMetrics::new("y");
        m.loss = vec![3.0, 2.0];
        m.cum_bytes = vec![10, 20];
        let p = std::env::temp_dir().join("tsr_metrics_test.csv");
        m.write_csv(&p).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert!(s.contains("step,loss,cum_bytes"));
        assert_eq!(s.lines().count(), 3);
    }

    #[test]
    fn csv_creates_missing_parent_directories() {
        // The satellite fix: writing into a results dir that does not
        // exist yet must create it rather than failing NotFound.
        let mut m = RunMetrics::new("nested");
        m.loss = vec![1.0];
        m.cum_bytes = vec![4];
        let dir = std::env::temp_dir().join("tsr_metrics_nested_test");
        let _ = std::fs::remove_dir_all(&dir);
        let p = dir.join("deep").join("run.csv");
        m.write_csv(&p).unwrap();
        assert!(p.exists());
        assert!(!p.with_extension("tmp").exists(), "tmp file left behind");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn csv_failure_names_the_path() {
        // Parent "directory" is a FILE: creation must fail loudly with
        // the offending path in the message, not a bare io error.
        let dir = std::env::temp_dir().join("tsr_metrics_squat_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let squatter = dir.join("results");
        std::fs::write(&squatter, "not a directory").unwrap();
        let m = RunMetrics::new("err");
        let err = m.write_csv(squatter.join("run.csv")).unwrap_err();
        assert!(
            err.contains("results"),
            "error must name the path it failed on: {err}"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fingerprint_is_bit_sensitive() {
        let a = vec![Matrix::from_vec(1, 2, vec![1.0, 2.0])];
        let mut b = vec![Matrix::from_vec(1, 2, vec![1.0, 2.0])];
        assert_eq!(params_fingerprint(&a), params_fingerprint(&b));
        // Flip the lowest mantissa bit of one element only.
        b[0].data[1] = f32::from_bits(b[0].data[1].to_bits() ^ 1);
        assert_ne!(params_fingerprint(&a), params_fingerprint(&b));
    }

    #[test]
    fn deterministic_json_has_no_wall_clock_fields() {
        let mut m = RunMetrics::new("det");
        m.loss = vec![1.0, 0.5];
        m.cum_bytes = vec![8, 16];
        m.step_secs = vec![0.123, 0.456]; // wall clock — must NOT leak
        let mut ledger = CommLedger::new();
        ledger.record(crate::comm::LayerClass::Linear, 2);
        ledger.end_step();
        let params = vec![Matrix::from_vec(1, 2, vec![0.25, -1.5])];
        let s = m.to_json_deterministic(&ledger, &params).to_string_pretty();
        assert!(s.contains("params_fingerprint"));
        assert!(s.contains("wire_intra_bytes"));
        assert!(!s.contains("step_secs\": [") && !s.contains("mean_step_secs"));
    }

    #[test]
    fn checkpoint_state_roundtrips_bitwise() {
        let mut m = RunMetrics::new("resume-me");
        m.loss = vec![1.5, -0.0, f32::from_bits(0x3f80_0001)];
        m.predicted_step_secs = 1.0 / 7.0;
        m.exposed_comm_secs = 2.0 / 3.0;
        m.step_secs = vec![0.5]; // wall clock — intentionally dropped
        let text = m.state_to_json().to_string_pretty();
        let back = RunMetrics::state_from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.name, "resume-me");
        assert_eq!(back.loss.len(), 3);
        for (a, b) in m.loss.iter().zip(&back.loss) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(back.predicted_step_secs.to_bits(), m.predicted_step_secs.to_bits());
        assert_eq!(back.exposed_comm_secs.to_bits(), m.exposed_comm_secs.to_bits());
        assert!(back.step_secs.is_empty());
    }

    #[test]
    fn json_contains_fields() {
        let mut m = RunMetrics::new("z");
        m.loss = vec![1.0];
        let j = m.to_json();
        assert_eq!(j.get("name").as_str(), Some("z"));
        assert!(j.get("final_loss").as_f64().is_some());
    }
}
