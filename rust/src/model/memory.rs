//! Optimizer-state memory accounting (paper Table 2).
//!
//! For a matrix block W ∈ R^{m×n} with rank r (embedding rank r_e):
//!
//! | method    | weights            | optimizer state                  |
//! |-----------|--------------------|----------------------------------|
//! | Adam      | mn                 | 2mn                              |
//! | LoRA      | mn + rm + rn       | 2mr + 2nr                        |
//! | One-sided | mn                 | mr + 2nr   (project short side)  |
//! | TSR       | mn                 | mr + nr + 2r²                    |
//! | TSR (emb) | V·m                | V·r_e + r_e·m + 2r_e²            |

use super::registry::{BlockSpec, ModelSpec};
use crate::comm::LayerClass;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    Adam,
    Lora,
    OneSided,
    Tsr,
}

/// Optimizer-state elements for one matrix block under `method`.
/// `r` applies to Linear blocks; `r_emb` to Embedding blocks (dense
/// methods ignore both). Vector blocks always carry dense Adam state.
pub fn state_elements(block: &BlockSpec, method: Method, r: usize, r_emb: usize) -> usize {
    let (m, n) = (block.rows, block.cols);
    if block.class == LayerClass::Vector {
        return 2 * m * n;
    }
    // Table 2: only TSR treats embeddings low-rank; Adam/LoRA/One-sided
    // keep dense Adam state on the embedding matrix.
    if block.class == LayerClass::Embedding && method != Method::Tsr {
        return 2 * m * n;
    }
    let rank = match block.class {
        LayerClass::Embedding => r_emb,
        _ => r,
    };
    let rank = rank.min(m).min(n);
    match method {
        Method::Adam => 2 * m * n,
        // LoRA trains adapters A (m×r), B (r×n): Adam state on both.
        Method::Lora => 2 * rank * m + 2 * rank * n,
        // One-sided projects the shorter dimension (GaLore): basis on the
        // short side + moments on the projected gradient.
        Method::OneSided => {
            let (short, long) = if m <= n { (m, n) } else { (n, m) };
            short * rank + 2 * long * rank
        }
        // Two bases + two r×r core moments.
        Method::Tsr => m * rank + n * rank + 2 * rank * rank,
    }
}

/// Trainable-weight elements for one block (LoRA adds adapter factors).
pub fn weight_elements(block: &BlockSpec, method: Method, r: usize, r_emb: usize) -> usize {
    let (m, n) = (block.rows, block.cols);
    if block.class == LayerClass::Vector || method != Method::Lora {
        return m * n;
    }
    let rank = match block.class {
        LayerClass::Embedding => r_emb,
        _ => r,
    }
    .min(m)
    .min(n);
    m * n + rank * m + rank * n
}

/// Total (weights, optimizer-state) elements for a model under a method.
pub fn model_footprint(spec: &ModelSpec, method: Method, r: usize, r_emb: usize) -> (usize, usize) {
    let mut w = 0usize;
    let mut s = 0usize;
    for b in spec.blocks() {
        w += weight_elements(&b, method, r, r_emb);
        s += state_elements(&b, method, r, r_emb);
    }
    (w, s)
}

/// Table 3 "MEMORY" column: weights + optimizer state at bf16 (2 B/elem).
///
/// Calibration note: with bf16 storage this reproduces the paper's Table 3
/// *ratios* (TSR/Adam ≈ 0.61, GaLore/Adam ≈ 0.75 at 60M) and tracks the
/// absolute numbers within ~20% — the residual is the paper's unspecified
/// bookkeeping of gradient/activation buffers.
pub fn memory_bytes(spec: &ModelSpec, method: Method, r: usize, r_emb: usize) -> u64 {
    let (w, s) = model_footprint(spec, method, r, r_emb);
    ((w + s) * 2) as u64
}

/// Memory for the error-feedback compression baselines (SignAdam /
/// TopKAdam): dense Adam moments on every block plus one per-device
/// residual matrix for each compressed (matrix) block.
pub fn memory_bytes_error_feedback(spec: &ModelSpec) -> u64 {
    let (w, s) = model_footprint(spec, Method::Adam, 0, 0);
    let residual: usize = spec
        .blocks()
        .iter()
        .filter(|b| b.class != LayerClass::Vector)
        .map(|b| b.numel())
        .sum();
    ((w + s + residual) * 2) as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gib(b: u64) -> f64 {
        b as f64 / (1024.0 * 1024.0 * 1024.0)
    }

    #[test]
    fn table2_formulas_hold_per_block() {
        let lin = BlockSpec {
            name: "w".into(),
            rows: 1024,
            cols: 4096,
            class: LayerClass::Linear,
        };
        assert_eq!(state_elements(&lin, Method::Adam, 64, 0), 2 * 1024 * 4096);
        assert_eq!(
            state_elements(&lin, Method::Tsr, 64, 0),
            1024 * 64 + 4096 * 64 + 2 * 64 * 64
        );
        assert_eq!(
            state_elements(&lin, Method::OneSided, 64, 0),
            1024 * 64 + 2 * 4096 * 64
        );
        assert_eq!(
            state_elements(&lin, Method::Lora, 64, 0),
            2 * 64 * 1024 + 2 * 64 * 4096
        );
        let emb = BlockSpec {
            name: "e".into(),
            rows: 32000,
            cols: 512,
            class: LayerClass::Embedding,
        };
        assert_eq!(
            state_elements(&emb, Method::Tsr, 256, 64),
            32000 * 64 + 512 * 64 + 2 * 64 * 64
        );
    }

    #[test]
    fn tsr_memory_below_adam_and_galore() {
        let spec = ModelSpec::llama_60m();
        let adam = memory_bytes(&spec, Method::Adam, 0, 0);
        let galore = memory_bytes(&spec, Method::OneSided, 128, 128);
        let tsr = memory_bytes(&spec, Method::Tsr, 256, 64);
        assert!(tsr < galore, "tsr {} vs galore {}", gib(tsr), gib(galore));
        assert!(galore < adam);
    }

    #[test]
    fn memory_matches_table3_ordering_and_magnitude() {
        // Table 3 (60M): AdamW 0.28G, GaLore(128) 0.21G, TSR 256(64) 0.17G.
        let spec = ModelSpec::llama_60m();
        let adam = gib(memory_bytes(&spec, Method::Adam, 0, 0));
        let galore = gib(memory_bytes(&spec, Method::OneSided, 128, 128));
        let tsr = gib(memory_bytes(&spec, Method::Tsr, 256, 64));
        // Absolutes within ~35% (paper's buffer bookkeeping unspecified);
        // crucially the *ratios* must match: TSR/Adam ≈ 0.61, GaLore/Adam ≈ 0.75.
        assert!((adam - 0.28).abs() / 0.28 < 0.35, "adam {adam}");
        assert!((galore - 0.21).abs() / 0.21 < 0.35, "galore {galore}");
        assert!((tsr - 0.17).abs() / 0.17 < 0.35, "tsr {tsr}");
        assert!(((tsr / adam) - 0.61).abs() < 0.15, "tsr/adam {}", tsr / adam);
        assert!(((galore / adam) - 0.75).abs() < 0.15, "galore/adam {}", galore / adam);
    }

    #[test]
    fn error_feedback_memory_is_adam_plus_residual() {
        let spec = ModelSpec::llama_60m();
        let adam = memory_bytes(&spec, Method::Adam, 0, 0);
        let ef = memory_bytes_error_feedback(&spec);
        // The per-device residual adds one bf16 copy of the matrix blocks.
        assert_eq!(ef, adam + spec.matrix_param_count() as u64 * 2);
    }

    #[test]
    fn rank_clamped_to_dims() {
        let tiny = BlockSpec {
            name: "t".into(),
            rows: 4,
            cols: 8,
            class: LayerClass::Linear,
        };
        // r > min(m,n) must clamp, not blow up.
        let s = state_elements(&tiny, Method::Tsr, 999, 0);
        assert_eq!(s, 4 * 4 + 8 * 4 + 2 * 16);
    }
}
