//! Model shape registry and memory accounting.

pub mod memory;
pub mod registry;

pub use memory::{memory_bytes, model_footprint, state_elements, Method};
pub use registry::{BlockSpec, ModelSpec};
