//! Model shape registry and memory accounting.

pub mod memory;
pub mod registry;

pub use memory::{
    memory_bytes, memory_bytes_error_feedback, model_footprint, state_elements, Method,
};
pub use registry::{BlockSpec, ModelSpec};
