//! Model shape registry.
//!
//! The byte/memory tables of the paper are counting identities over the
//! shapes of matrix parameter blocks. This module encodes the LLaMA
//! configurations of Table 5 (60M/130M/350M/1B), RoBERTa-base (GLUE
//! fine-tuning, Table 4), and arbitrary proxy scales used for the real
//! CPU training runs.

use crate::comm::LayerClass;

/// One matrix-shaped parameter block W^(ℓ) ∈ R^{rows×cols} (§3.1), or a
/// vector block (biases / norms) that is always synchronized dense.
#[derive(Clone, Debug)]
pub struct BlockSpec {
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    pub class: LayerClass,
}

impl BlockSpec {
    pub fn numel(&self) -> usize {
        self.rows * self.cols
    }

    fn mat(name: String, rows: usize, cols: usize, class: LayerClass) -> Self {
        Self {
            name,
            rows,
            cols,
            class,
        }
    }

    fn vec(name: String, n: usize) -> Self {
        Self {
            name,
            rows: 1,
            cols: n,
            class: LayerClass::Vector,
        }
    }
}

/// Transformer configuration (LLaMA-style unless `roberta` is set).
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: String,
    pub vocab: usize,
    pub hidden: usize,
    pub intermediate: usize,
    pub heads: usize,
    pub layers: usize,
    /// Training steps used in the paper for this scale (Table 5).
    pub paper_steps: usize,
    /// RoBERTa-style (GELU MLP, learned positions, tied QKV shapes).
    pub roberta: bool,
}

impl ModelSpec {
    // ---- Table 5 configurations ----

    pub fn llama_60m() -> Self {
        Self {
            name: "llama-60m".into(),
            vocab: 32000,
            hidden: 512,
            intermediate: 1376,
            heads: 8,
            layers: 8,
            paper_steps: 20_000,
            roberta: false,
        }
    }

    pub fn llama_130m() -> Self {
        Self {
            name: "llama-130m".into(),
            vocab: 32000,
            hidden: 768,
            intermediate: 2048,
            heads: 12,
            layers: 12,
            paper_steps: 20_000,
            roberta: false,
        }
    }

    pub fn llama_350m() -> Self {
        Self {
            name: "llama-350m".into(),
            vocab: 32000,
            hidden: 1024,
            intermediate: 2736,
            heads: 16,
            layers: 24,
            paper_steps: 90_000,
            roberta: false,
        }
    }

    /// Table 5 lists hidden "52048" for 1B — an obvious typo for 2048
    /// (32 heads × 64 head-dim; ~1.2B params with the listed inter/layers).
    pub fn llama_1b() -> Self {
        Self {
            name: "llama-1b".into(),
            vocab: 32000,
            hidden: 2048,
            intermediate: 5461,
            heads: 32,
            layers: 24,
            paper_steps: 90_000,
            roberta: false,
        }
    }

    /// RoBERTa-base shapes for the GLUE fine-tuning byte accounting.
    pub fn roberta_base() -> Self {
        Self {
            name: "roberta-base".into(),
            vocab: 50265,
            hidden: 768,
            intermediate: 3072,
            heads: 12,
            layers: 12,
            paper_steps: 0,
            roberta: true,
        }
    }

    /// CPU-feasible proxy scale for real end-to-end training runs.
    pub fn proxy(vocab: usize, hidden: usize, intermediate: usize, heads: usize, layers: usize) -> Self {
        Self {
            name: format!("proxy-h{hidden}-l{layers}-v{vocab}"),
            vocab,
            hidden,
            intermediate,
            heads,
            layers,
            paper_steps: 0,
            roberta: false,
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "60m" | "llama-60m" => Some(Self::llama_60m()),
            "130m" | "llama-130m" => Some(Self::llama_130m()),
            "350m" | "llama-350m" => Some(Self::llama_350m()),
            "1b" | "llama-1b" => Some(Self::llama_1b()),
            "roberta" | "roberta-base" => Some(Self::roberta_base()),
            _ => None,
        }
    }

    /// All parameter blocks of the model, matrix blocks first.
    ///
    /// LLaMA block layout per layer: attention q/k/v/o (h×h), SwiGLU
    /// gate/up (h×i) and down (i×h); RMSNorm vectors. Embedding and LM
    /// head are vocab-dimension blocks (class `Embedding` — the paper's
    /// §3.6 treats them with their own (r_emb, K_emb)).
    pub fn blocks(&self) -> Vec<BlockSpec> {
        use LayerClass::*;
        let h = self.hidden;
        let f = self.intermediate;
        let mut out = Vec::new();
        out.push(BlockSpec::mat("embed_tokens".into(), self.vocab, h, Embedding));
        if self.roberta {
            out.push(BlockSpec::mat("embed_positions".into(), 514, h, Linear));
        }
        for l in 0..self.layers {
            for proj in ["q_proj", "k_proj", "v_proj", "o_proj"] {
                out.push(BlockSpec::mat(format!("layers.{l}.attn.{proj}"), h, h, Linear));
            }
            if self.roberta {
                // GELU MLP: fc1 (h×f), fc2 (f×h)
                out.push(BlockSpec::mat(format!("layers.{l}.mlp.fc1"), h, f, Linear));
                out.push(BlockSpec::mat(format!("layers.{l}.mlp.fc2"), f, h, Linear));
            } else {
                out.push(BlockSpec::mat(format!("layers.{l}.mlp.gate"), h, f, Linear));
                out.push(BlockSpec::mat(format!("layers.{l}.mlp.up"), h, f, Linear));
                out.push(BlockSpec::mat(format!("layers.{l}.mlp.down"), f, h, Linear));
            }
            out.push(BlockSpec::vec(format!("layers.{l}.attn_norm"), h));
            out.push(BlockSpec::vec(format!("layers.{l}.mlp_norm"), h));
        }
        out.push(BlockSpec::vec("final_norm".into(), h));
        // LLaMA configs use *tied* embeddings (embed_tokens doubles as the
        // LM head): this is the only reading under which the paper's dense
        // AdamW Bytes/Step column (0.17/0.44/1.34/5.09 G) reproduces
        // exactly from the Table 5 shapes.
        if self.roberta {
            // Classification head for GLUE.
            out.push(BlockSpec::mat("classifier.dense".into(), h, h, Linear));
            out.push(BlockSpec::mat("classifier.out".into(), h, 2, Linear));
        }
        out
    }

    /// Parameter blocks for the native-nn LM trainer (`nn/`,
    /// DESIGN.md §10): the layout of [`Self::blocks`] plus an **untied**
    /// `lm_head` block (vocab×h, class `Embedding` — a vocab-dimension
    /// block with its own (r_emb, K_emb) under §3.6).
    ///
    /// The byte tables read Table 5 with *tied* embeddings (the only
    /// reading that reproduces the paper's dense Bytes/Step column), but
    /// a tied trainer would add the head's dense softmax gradient onto
    /// `embed_tokens` and destroy the row-sparsity the embedding
    /// extension exists for. The nn trainer therefore unties: the input
    /// embedding keeps genuinely token-sparse gradients while the head
    /// carries the dense vocab-dimension gradient separately.
    pub fn blocks_untied_lm(&self) -> Vec<BlockSpec> {
        assert!(!self.roberta, "the nn LM trainer uses the LLaMA-style layout");
        let mut out = self.blocks();
        out.push(BlockSpec::mat(
            "lm_head".into(),
            self.vocab,
            self.hidden,
            LayerClass::Embedding,
        ));
        out
    }

    pub fn param_count(&self) -> usize {
        self.blocks().iter().map(|b| b.numel()).sum()
    }

    /// Matrix-block parameter count (the communication-relevant subset).
    pub fn matrix_param_count(&self) -> usize {
        self.blocks()
            .iter()
            .filter(|b| b.class != LayerClass::Vector)
            .map(|b| b.numel())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scales_roughly_match_names() {
        // Parameter totals land near the *synced* totals implied by the
        // paper's dense Bytes/Step (tied embeddings): 0.17G/4 ≈ 43M, etc.
        let n60 = ModelSpec::llama_60m().param_count() as f64;
        assert!((38e6..50e6).contains(&n60), "60m -> {n60}");
        let n130 = ModelSpec::llama_130m().param_count() as f64;
        assert!((100e6..130e6).contains(&n130), "130m -> {n130}");
        let n350 = ModelSpec::llama_350m().param_count() as f64;
        assert!((300e6..400e6).contains(&n350), "350m -> {n350}");
        let n1b = ModelSpec::llama_1b().param_count() as f64;
        assert!((1.2e9..1.45e9).contains(&n1b), "1b -> {n1b}");
    }

    #[test]
    fn block_classes() {
        let spec = ModelSpec::llama_60m();
        let blocks = spec.blocks();
        let emb: Vec<_> = blocks.iter().filter(|b| b.class == LayerClass::Embedding).collect();
        assert_eq!(emb.len(), 1); // tied embed_tokens (doubles as LM head)
        assert!(blocks.iter().any(|b| b.class == LayerClass::Vector));
        // 7 matrix blocks per layer for LLaMA.
        let linear = blocks.iter().filter(|b| b.class == LayerClass::Linear).count();
        assert_eq!(linear, 7 * spec.layers);
    }

    #[test]
    fn untied_lm_layout_adds_exactly_one_head_block() {
        let spec = ModelSpec::proxy(64, 32, 64, 2, 2);
        let tied = spec.blocks();
        let untied = spec.blocks_untied_lm();
        assert_eq!(untied.len(), tied.len() + 1);
        let head = untied.last().unwrap();
        assert_eq!(head.name, "lm_head");
        assert_eq!((head.rows, head.cols), (64, 32));
        assert_eq!(head.class, LayerClass::Embedding);
        // Two vocab-dimension blocks now carry the §3.6 (r_emb, K_emb).
        let emb = untied.iter().filter(|b| b.class == LayerClass::Embedding).count();
        assert_eq!(emb, 2);
    }

    #[test]
    fn by_name_roundtrip() {
        for n in ["60m", "130m", "350m", "1b", "roberta"] {
            assert!(ModelSpec::by_name(n).is_some(), "{n}");
        }
        assert!(ModelSpec::by_name("9000t").is_none());
    }

    #[test]
    fn dense_bytes_per_step_matches_table3() {
        // Table 3: AdamW Bytes/Step — 60M: 0.17G, 130M: 0.44G, 350M: 1.34G,
        // 1B: 5.09G (f32 objects). Our shape registry must reproduce these
        // within a few percent (paper counts all-synced params).
        for (spec, expect_g) in [
            (ModelSpec::llama_60m(), 0.17),
            (ModelSpec::llama_130m(), 0.44),
            (ModelSpec::llama_350m(), 1.34),
            (ModelSpec::llama_1b(), 5.09),
        ] {
            let bytes = spec.param_count() as f64 * 4.0;
            let g = bytes / (1024.0 * 1024.0 * 1024.0);
            let rel = (g - expect_g).abs() / expect_g;
            assert!(rel < 0.12, "{}: {g:.3}G vs paper {expect_g}G", spec.name);
        }
    }
}
