//! Length-prefixed frame codec for the process-backend wire protocol
//! (DESIGN.md §12).
//!
//! Every message on every socket — coordinator↔worker control links and
//! worker↔worker ring links alike — is one frame:
//!
//! ```text
//! [ payload_len: u32 LE ][ kind: u8 ][ crc: u32 LE ][ payload bytes ]
//! ```
//!
//! `crc` is FNV-1a over the payload, so a torn or bit-flipped frame is
//! detected as corruption rather than silently decoded into garbage
//! f32s. Corruption, truncation, timeout, and disconnection each map to
//! a **distinct** [`NetError`] variant with its own message — the error
//! taxonomy the coordinator uses to tell "worker died" from "worker sent
//! garbage" from "worker hung".
//!
//! All multi-byte integers and all f32 payloads are little-endian bit
//! patterns (`to_le_bytes`/`from_le_bytes`), so a buffer survives the
//! wire round trip **bitwise** — the process backend's determinism
//! contract rests on this plus the ring schedule itself.

use std::io::{Read, Write};

/// Wire protocol version, exchanged in every `Hello`; a coordinator and
/// worker from different builds refuse each other loudly. v2 added the
/// element-format tag to `Collective` frames and narrow (bf16/int8)
/// `Data` ring chunks. v3 added the trace-request flag on `Collective`
/// and the worker→coordinator `Trace` counter frame (DESIGN.md §16).
pub const WIRE_VERSION: u32 = 3;

/// Hard upper bound on a frame payload (1 GiB). A length prefix above
/// this is corruption by definition — no collective in this repo ships
/// a larger object — and is rejected before any allocation.
pub const MAX_FRAME_PAYLOAD: usize = 1 << 30;

/// Frame header size: payload_len (4) + kind (1) + crc (4).
pub const HEADER_BYTES: usize = 9;

/// Every message type in the protocol (DESIGN.md §12 lifecycle).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum FrameKind {
    /// worker → coordinator: `{version, token, rank, peer_port}`.
    Hello = 1,
    /// coordinator → worker: everyone's peer-listener ports.
    Peers = 2,
    /// worker → worker on a fresh mesh link: `{token, rank}`.
    PeerHello = 3,
    /// worker → coordinator: mesh formed, ready for collectives.
    Ready = 4,
    /// coordinator → worker: one collective request + this worker's
    /// buffer: `{op, nodes, gpus_per_node, numel, f32 payload}`.
    Collective = 5,
    /// worker → worker: one ring chunk (raw f32 payload).
    Data = 6,
    /// worker → coordinator: wire-byte counters + the reduced buffer.
    Result = 7,
    /// coordinator → worker: exit cleanly.
    Shutdown = 8,
    /// worker → coordinator: per-kind frame/byte counters for the
    /// observability wall tier, sent only when the coordinator's
    /// `Collective` carried the trace flag (DESIGN.md §16).
    Trace = 9,
}

impl FrameKind {
    pub fn from_u8(b: u8) -> Option<Self> {
        Some(match b {
            1 => Self::Hello,
            2 => Self::Peers,
            3 => Self::PeerHello,
            4 => Self::Ready,
            5 => Self::Collective,
            6 => Self::Data,
            7 => Self::Result,
            8 => Self::Shutdown,
            9 => Self::Trace,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Hello => "hello",
            Self::Peers => "peers",
            Self::PeerHello => "peer-hello",
            Self::Ready => "ready",
            Self::Collective => "collective",
            Self::Data => "data",
            Self::Result => "result",
            Self::Shutdown => "shutdown",
            Self::Trace => "trace",
        }
    }
}

/// The wire-layer error taxonomy. Variants are deliberately distinct so
/// callers (and humans reading a panic) can tell a dead peer from a
/// hung peer from a corrupt stream — the §12 robustness contract.
#[derive(Debug)]
pub enum NetError {
    /// The peer closed (or reset) the connection.
    Disconnected { what: String, detail: String },
    /// A blocking read/write exceeded its deadline.
    Timeout { what: String },
    /// Frame corruption: unknown kind byte.
    BadKind { what: String, kind: u8 },
    /// Frame corruption: length prefix beyond [`MAX_FRAME_PAYLOAD`].
    BadLength { what: String, len: u64 },
    /// Frame corruption: payload checksum mismatch.
    BadChecksum { what: String, expect: u32, got: u32 },
    /// A structurally valid frame whose payload does not decode (short
    /// fields, trailing bytes, impossible values).
    Malformed { what: String, detail: String },
    /// A valid frame of the wrong kind for this point in the protocol.
    UnexpectedKind {
        what: String,
        expect: FrameKind,
        got: FrameKind,
    },
    /// Any other I/O failure.
    Io { what: String, err: std::io::Error },
}

impl NetError {
    /// True when the peer is gone (process death shows up as this).
    pub fn is_disconnect(&self) -> bool {
        matches!(self, Self::Disconnected { .. })
    }

    pub fn is_timeout(&self) -> bool {
        matches!(self, Self::Timeout { .. })
    }

    /// Classify an `std::io::Error` from a read/write on `what`.
    pub fn from_io(what: &str, err: std::io::Error) -> Self {
        use std::io::ErrorKind as K;
        match err.kind() {
            K::UnexpectedEof | K::ConnectionReset | K::ConnectionAborted | K::BrokenPipe => {
                Self::Disconnected {
                    what: what.to_string(),
                    detail: err.to_string(),
                }
            }
            K::WouldBlock | K::TimedOut => Self::Timeout {
                what: what.to_string(),
            },
            _ => Self::Io {
                what: what.to_string(),
                err,
            },
        }
    }
}

impl std::fmt::Display for NetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Disconnected { what, detail } => {
                write!(f, "{what}: peer disconnected ({detail})")
            }
            Self::Timeout { what } => write!(f, "{what}: deadline exceeded"),
            Self::BadKind { what, kind } => {
                write!(f, "{what}: corrupt frame (unknown kind byte 0x{kind:02x})")
            }
            Self::BadLength { what, len } => write!(
                f,
                "{what}: corrupt frame (length prefix {len} exceeds {MAX_FRAME_PAYLOAD})"
            ),
            Self::BadChecksum { what, expect, got } => write!(
                f,
                "{what}: corrupt frame (checksum {got:08x}, header says {expect:08x})"
            ),
            Self::Malformed { what, detail } => write!(f, "{what}: malformed payload ({detail})"),
            Self::UnexpectedKind { what, expect, got } => write!(
                f,
                "{what}: protocol violation (expected {} frame, got {})",
                expect.name(),
                got.name()
            ),
            Self::Io { what, err } => write!(f, "{what}: io error ({err})"),
        }
    }
}

/// FNV-1a over `bytes` — the per-frame payload checksum.
pub fn fnv1a32(bytes: &[u8]) -> u32 {
    let mut h: u32 = 0x811c_9dc5;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

/// One decoded frame.
#[derive(Debug)]
pub struct Frame {
    pub kind: FrameKind,
    pub payload: Vec<u8>,
}

/// Encode a frame into its full wire byte sequence (header + payload) —
/// the unit the worker's writer threads queue and `write_all`.
pub fn encode_frame(kind: FrameKind, payload: &[u8]) -> Vec<u8> {
    assert!(payload.len() <= MAX_FRAME_PAYLOAD, "frame payload too large");
    let mut out = Vec::with_capacity(HEADER_BYTES + payload.len());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.push(kind as u8);
    out.extend_from_slice(&fnv1a32(payload).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

/// Write one frame (blocking, honoring the stream's write timeout).
pub fn write_frame(w: &mut impl Write, kind: FrameKind, payload: &[u8], what: &str) -> Result<(), NetError> {
    w.write_all(&encode_frame(kind, payload))
        .map_err(|e| NetError::from_io(what, e))
}

/// Read one frame (blocking, honoring the stream's read timeout),
/// validating kind, length, and checksum.
pub fn read_frame(r: &mut impl Read, what: &str) -> Result<Frame, NetError> {
    let mut header = [0u8; HEADER_BYTES];
    r.read_exact(&mut header).map_err(|e| NetError::from_io(what, e))?;
    let len = u32::from_le_bytes(header[0..4].try_into().unwrap()) as usize;
    if len > MAX_FRAME_PAYLOAD {
        return Err(NetError::BadLength {
            what: what.to_string(),
            len: len as u64,
        });
    }
    let kind = FrameKind::from_u8(header[4]).ok_or_else(|| NetError::BadKind {
        what: what.to_string(),
        kind: header[4],
    })?;
    let expect_crc = u32::from_le_bytes(header[5..9].try_into().unwrap());
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload).map_err(|e| NetError::from_io(what, e))?;
    let got_crc = fnv1a32(&payload);
    if got_crc != expect_crc {
        return Err(NetError::BadChecksum {
            what: what.to_string(),
            expect: expect_crc,
            got: got_crc,
        });
    }
    Ok(Frame { kind, payload })
}

/// Read one frame and insist on its kind.
pub fn read_frame_expect(
    r: &mut impl Read,
    expect: FrameKind,
    what: &str,
) -> Result<Vec<u8>, NetError> {
    let fr = read_frame(r, what)?;
    if fr.kind != expect {
        return Err(NetError::UnexpectedKind {
            what: what.to_string(),
            expect,
            got: fr.kind,
        });
    }
    Ok(fr.payload)
}

// ---------------------------------------------------------------------
// Payload encode/decode helpers. All little-endian; f32s as bit
// patterns (bitwise round trip).
// ---------------------------------------------------------------------

/// Payload builder.
#[derive(Default)]
pub struct Builder(Vec<u8>);

impl Builder {
    pub fn new() -> Self {
        Self::default()
    }
    pub fn u8(mut self, v: u8) -> Self {
        self.0.push(v);
        self
    }
    pub fn u16(mut self, v: u16) -> Self {
        self.0.extend_from_slice(&v.to_le_bytes());
        self
    }
    pub fn u32(mut self, v: u32) -> Self {
        self.0.extend_from_slice(&v.to_le_bytes());
        self
    }
    pub fn u64(mut self, v: u64) -> Self {
        self.0.extend_from_slice(&v.to_le_bytes());
        self
    }
    pub fn f32s(mut self, vs: &[f32]) -> Self {
        self.0.reserve(vs.len() * 4);
        for v in vs {
            self.0.extend_from_slice(&v.to_le_bytes());
        }
        self
    }
    pub fn build(self) -> Vec<u8> {
        self.0
    }
}

/// Payload reader over a decoded frame; every `take_*` underflow and any
/// trailing garbage at `finish()` is a [`NetError::Malformed`].
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
    what: &'a str,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8], what: &'a str) -> Self {
        Self { buf, pos: 0, what }
    }

    fn take(&mut self, n: usize, field: &str) -> Result<&'a [u8], NetError> {
        if self.pos + n > self.buf.len() {
            return Err(NetError::Malformed {
                what: self.what.to_string(),
                detail: format!(
                    "field `{field}` needs {n} bytes at offset {}, payload has {}",
                    self.pos,
                    self.buf.len()
                ),
            });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self, field: &str) -> Result<u8, NetError> {
        Ok(self.take(1, field)?[0])
    }
    pub fn u16(&mut self, field: &str) -> Result<u16, NetError> {
        Ok(u16::from_le_bytes(self.take(2, field)?.try_into().unwrap()))
    }
    pub fn u32(&mut self, field: &str) -> Result<u32, NetError> {
        Ok(u32::from_le_bytes(self.take(4, field)?.try_into().unwrap()))
    }
    pub fn u64(&mut self, field: &str) -> Result<u64, NetError> {
        Ok(u64::from_le_bytes(self.take(8, field)?.try_into().unwrap()))
    }

    /// Decode exactly `out.len()` f32 bit patterns into `out`.
    pub fn f32s_into(&mut self, out: &mut [f32], field: &str) -> Result<(), NetError> {
        let raw = self.take(out.len() * 4, field)?;
        for (i, v) in out.iter_mut().enumerate() {
            *v = f32::from_le_bytes(raw[4 * i..4 * i + 4].try_into().unwrap());
        }
        Ok(())
    }

    /// The payload must be fully consumed — trailing bytes are
    /// corruption, not slack.
    pub fn finish(self) -> Result<(), NetError> {
        if self.pos != self.buf.len() {
            return Err(NetError::Malformed {
                what: self.what.to_string(),
                detail: format!(
                    "{} trailing bytes after the last field",
                    self.buf.len() - self.pos
                ),
            });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn frame_roundtrip_is_bitwise() {
        let vals = [1.5f32, -0.0, f32::NAN, 3.4e-39 /* subnormal */, 7.25];
        let payload = Builder::new().u32(9).u64(u64::MAX).f32s(&vals).build();
        let wire = encode_frame(FrameKind::Collective, &payload);
        let fr = read_frame(&mut Cursor::new(&wire), "t").unwrap();
        assert_eq!(fr.kind, FrameKind::Collective);
        let mut r = Reader::new(&fr.payload, "t");
        assert_eq!(r.u32("a").unwrap(), 9);
        assert_eq!(r.u64("b").unwrap(), u64::MAX);
        let mut back = [0f32; 5];
        r.f32s_into(&mut back, "c").unwrap();
        r.finish().unwrap();
        for (a, b) in vals.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn corruption_kinds_produce_distinct_errors() {
        let wire = encode_frame(FrameKind::Data, &[1, 2, 3, 4]);

        // (a) Unknown kind byte.
        let mut bad = wire.clone();
        bad[4] = 0xEE;
        let e_kind = read_frame(&mut Cursor::new(&bad), "t").unwrap_err().to_string();
        assert!(e_kind.contains("unknown kind byte 0xee"), "{e_kind}");

        // (b) Absurd length prefix.
        let mut bad = wire.clone();
        bad[0..4].copy_from_slice(&u32::MAX.to_le_bytes());
        let e_len = read_frame(&mut Cursor::new(&bad), "t").unwrap_err().to_string();
        assert!(e_len.contains("length prefix"), "{e_len}");

        // (c) Flipped payload bit -> checksum mismatch.
        let mut bad = wire.clone();
        *bad.last_mut().unwrap() ^= 0x40;
        let e_crc = read_frame(&mut Cursor::new(&bad), "t").unwrap_err().to_string();
        assert!(e_crc.contains("checksum"), "{e_crc}");

        // (d) Truncated stream -> disconnect, not a decode error.
        let err = read_frame(&mut Cursor::new(&wire[..wire.len() - 1]), "t").unwrap_err();
        assert!(err.is_disconnect(), "{err}");

        // All four diagnoses are pairwise distinct.
        let msgs = [e_kind, e_len, e_crc, err.to_string()];
        for i in 0..msgs.len() {
            for j in i + 1..msgs.len() {
                assert_ne!(msgs[i], msgs[j]);
            }
        }
    }

    #[test]
    fn wrong_kind_at_protocol_point_is_its_own_error() {
        let wire = encode_frame(FrameKind::Data, &[]);
        let err = read_frame_expect(&mut Cursor::new(&wire), FrameKind::Result, "t").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("expected result frame, got data"), "{msg}");
    }

    #[test]
    fn reader_rejects_short_fields_and_trailing_bytes() {
        let payload = Builder::new().u32(5).build();
        let mut r = Reader::new(&payload, "t");
        assert!(r.u64("too-big").is_err());

        let payload = Builder::new().u32(5).u8(1).build();
        let mut r = Reader::new(&payload, "t");
        r.u32("a").unwrap();
        assert!(r.finish().is_err());
    }
}
