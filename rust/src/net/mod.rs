//! Minimal localhost wire layer for the process execution backend
//! (DESIGN.md §12).
//!
//! Everything here is deliberately small: loopback TCP only, one frame
//! codec ([`frame`]), and a handful of connection helpers that encode
//! the robustness contract — **every blocking operation has a
//! deadline**, bind retries with backoff, and failures classify into
//! the distinct [`frame::NetError`] taxonomy instead of a generic io
//! error string.

pub mod frame;

pub use frame::{
    encode_frame, read_frame, read_frame_expect, write_frame, Builder, Frame, FrameKind, NetError,
    Reader, HEADER_BYTES, MAX_FRAME_PAYLOAD, WIRE_VERSION,
};

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::time::{Duration, Instant};

/// Deadline applied to every blocking socket operation (reads, writes,
/// accepts, connects). Overridable via `TSR_NET_TIMEOUT_MS` so tests
/// can shrink it; the default is generous because CI machines stall.
pub fn io_deadline() -> Duration {
    let ms = std::env::var("TSR_NET_TIMEOUT_MS")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(20_000);
    Duration::from_millis(ms.max(1))
}

/// Bind a loopback listener on an ephemeral port, retrying with backoff.
///
/// Port 0 makes the kernel pick a free port, so collisions are rare —
/// but address-space exhaustion and transient EADDRINUSE under heavy
/// parallel test load do happen, hence the retry loop.
pub fn bind_localhost(what: &str) -> Result<TcpListener, NetError> {
    let deadline = Instant::now() + io_deadline();
    let mut backoff = Duration::from_millis(10);
    loop {
        match TcpListener::bind(("127.0.0.1", 0)) {
            Ok(l) => return Ok(l),
            Err(e) => {
                if Instant::now() + backoff >= deadline {
                    return Err(NetError::Io {
                        what: format!("{what}: bind 127.0.0.1:0"),
                        err: e,
                    });
                }
                std::thread::sleep(backoff);
                backoff = (backoff * 2).min(Duration::from_millis(500));
            }
        }
    }
}

/// Accept one connection with a deadline (a plain `accept()` blocks
/// forever if the expected peer died before connecting).
pub fn accept_deadline(listener: &TcpListener, what: &str) -> Result<TcpStream, NetError> {
    listener
        .set_nonblocking(true)
        .map_err(|e| NetError::from_io(what, e))?;
    let deadline = Instant::now() + io_deadline();
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                stream
                    .set_nonblocking(false)
                    .map_err(|e| NetError::from_io(what, e))?;
                configure_stream(&stream, what)?;
                return Ok(stream);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    return Err(NetError::Timeout {
                        what: format!("{what}: accept"),
                    });
                }
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(e) => return Err(NetError::from_io(what, e)),
        }
    }
}

/// Connect to a loopback peer, retrying until the deadline (the peer's
/// listener may not be up yet during rendezvous).
pub fn connect_peer(addr: SocketAddr, what: &str) -> Result<TcpStream, NetError> {
    let deadline = Instant::now() + io_deadline();
    let mut backoff = Duration::from_millis(5);
    loop {
        match TcpStream::connect_timeout(&addr, Duration::from_millis(500)) {
            Ok(stream) => {
                configure_stream(&stream, what)?;
                return Ok(stream);
            }
            Err(e) => {
                if Instant::now() + backoff >= deadline {
                    return Err(NetError::Io {
                        what: format!("{what}: connect {addr}"),
                        err: e,
                    });
                }
                std::thread::sleep(backoff);
                backoff = (backoff * 2).min(Duration::from_millis(200));
            }
        }
    }
}

/// Apply the standard socket configuration: no Nagle batching (ring
/// chunks are latency-bound) and read/write timeouts so no frame
/// exchange can hang past the deadline.
pub fn configure_stream(stream: &TcpStream, what: &str) -> Result<(), NetError> {
    stream
        .set_nodelay(true)
        .map_err(|e| NetError::from_io(what, e))?;
    stream
        .set_read_timeout(Some(io_deadline()))
        .map_err(|e| NetError::from_io(what, e))?;
    stream
        .set_write_timeout(Some(io_deadline()))
        .map_err(|e| NetError::from_io(what, e))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;

    #[test]
    fn frames_cross_a_real_socket_bitwise() {
        let listener = bind_localhost("test").unwrap();
        let addr = listener.local_addr().unwrap();
        let vals: Vec<f32> = (0..1000).map(|i| (i as f32).sin()).collect();
        let payload = Builder::new().u32(7).f32s(&vals).build();
        let sent = payload.clone();
        let child = std::thread::spawn(move || {
            let mut s = connect_peer(addr, "test-client").unwrap();
            write_frame(&mut s, FrameKind::Data, &sent, "test-client").unwrap();
        });
        let mut conn = accept_deadline(&listener, "test-server").unwrap();
        let got = read_frame_expect(&mut conn, FrameKind::Data, "test-server").unwrap();
        child.join().unwrap();
        assert_eq!(got, payload);
    }

    #[test]
    fn accept_times_out_when_no_peer_connects() {
        let listener = bind_localhost("test").unwrap();
        // Shrink the deadline locally: accept_deadline reads io_deadline()
        // once, so drive the wait with a tiny env override via a direct
        // nonblocking loop instead — here we just assert the mechanism by
        // using a listener nobody connects to and a short manual deadline.
        listener.set_nonblocking(true).unwrap();
        let start = std::time::Instant::now();
        let deadline = start + Duration::from_millis(50);
        let mut timed_out = false;
        loop {
            match listener.accept() {
                Ok(_) => break,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if std::time::Instant::now() >= deadline {
                        timed_out = true;
                        break;
                    }
                    std::thread::sleep(Duration::from_millis(2));
                }
                Err(_) => break,
            }
        }
        assert!(timed_out);
    }

    #[test]
    fn read_deadline_fires_as_timeout_error() {
        let listener = bind_localhost("test").unwrap();
        let addr = listener.local_addr().unwrap();
        let child = std::thread::spawn(move || {
            // Connect, send half a header, then stall (but keep the
            // socket open so the reader sees a timeout, not an EOF).
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(&[1, 2, 3]).unwrap();
            std::thread::sleep(Duration::from_millis(300));
        });
        let conn = accept_deadline(&listener, "test-server").unwrap();
        conn.set_read_timeout(Some(Duration::from_millis(50))).unwrap();
        let mut conn = conn;
        let err = read_frame(&mut conn, "test-server").unwrap_err();
        assert!(err.is_timeout(), "expected timeout, got: {err}");
        child.join().unwrap();
    }
}
