//! Per-layer forward/backward primitives for the native transformer
//! (DESIGN.md §10). Each function is pure in its tensor arguments so the
//! gradcheck suite can probe it in isolation; backward functions
//! *accumulate* into their output buffers (`+=`), matching how the
//! transformer sums gradient contributions across branches.

use crate::linalg::{gemm, Matrix};

/// RMSNorm variance floor.
pub const RMSNORM_EPS: f32 = 1e-6;

/// RMSNorm over each row of `x` (N×h) with weight `w` (1×h):
/// `y_ij = w_j · x_ij / rms(x_i)`, `rms(x_i) = sqrt(mean_j x_ij² + ε)`.
pub fn rmsnorm(x: &Matrix, w: &Matrix) -> Matrix {
    assert_eq!(w.rows, 1, "rmsnorm weight must be a row vector");
    assert_eq!(x.cols, w.cols, "rmsnorm width mismatch");
    let h = x.cols;
    let mut y = Matrix::zeros(x.rows, h);
    for i in 0..x.rows {
        let xr = x.row(i);
        let r = inv_rms(xr);
        let yr = y.row_mut(i);
        for j in 0..h {
            yr[j] = w.data[j] * xr[j] * r;
        }
    }
    y
}

/// Backward of [`rmsnorm`]. With `s = mean_j x_j²`, `r = 1/sqrt(s+ε)`:
/// `∂y_j/∂x_i = w_j·r·δ_ij − (r³/h)·w_j·x_j·x_i`, so
/// `dx_i += r·w_i·dy_i − (r³/h)·x_i·Σ_j dy_j·w_j·x_j` and
/// `dw_j += Σ_rows dy_j·x_j·r`. Accumulates into `dx` and `dw`.
pub fn rmsnorm_bwd(x: &Matrix, w: &Matrix, dy: &Matrix, dx: &mut Matrix, dw: &mut Matrix) {
    assert_eq!((x.rows, x.cols), (dy.rows, dy.cols));
    assert_eq!((x.rows, x.cols), (dx.rows, dx.cols));
    assert_eq!((dw.rows, dw.cols), (w.rows, w.cols));
    let h = x.cols;
    for i in 0..x.rows {
        let xr = x.row(i);
        let dyr = dy.row(i);
        let r = inv_rms(xr);
        let mut dot = 0.0f32;
        for j in 0..h {
            dot += dyr[j] * w.data[j] * xr[j];
        }
        let c = r * r * r * dot / h as f32;
        let dxr = dx.row_mut(i);
        for j in 0..h {
            dxr[j] += r * w.data[j] * dyr[j] - c * xr[j];
            dw.data[j] += dyr[j] * xr[j] * r;
        }
    }
}

#[inline]
fn inv_rms(row: &[f32]) -> f32 {
    let mut ss = 0.0f32;
    for &v in row {
        ss += v * v;
    }
    1.0 / (ss / row.len() as f32 + RMSNORM_EPS).sqrt()
}

#[inline]
fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// SiLU (swish): `x·σ(x)` — the SwiGLU gate nonlinearity.
#[inline]
pub fn silu(x: f32) -> f32 {
    x * sigmoid(x)
}

/// d silu / dx = `σ(x)·(1 + x·(1−σ(x)))`.
#[inline]
pub fn silu_grad(x: f32) -> f32 {
    let s = sigmoid(x);
    s * (1.0 + x * (1.0 - s))
}

/// Single-head causal attention over one sequence: `q,k,v` are S×d,
/// scores are `q·kᵀ/√d` masked to `j ≤ i`, rows softmaxed. Returns
/// `(ctx = P·v, P)`; `P` (S×S) is strictly lower-triangular-plus-
/// diagonal (zeros above the diagonal) and is the cache backward needs.
pub fn causal_attention(q: &Matrix, k: &Matrix, v: &Matrix) -> (Matrix, Matrix) {
    let s = q.rows;
    let d = q.cols;
    assert_eq!((k.rows, k.cols), (s, d));
    assert_eq!((v.rows, v.cols), (s, d));
    let scale = 1.0 / (d as f32).sqrt();
    let mut probs = Matrix::zeros(s, s);
    let mut row = vec![0.0f32; s];
    for i in 0..s {
        let mut maxv = f32::NEG_INFINITY;
        for j in 0..=i {
            let mut dot = 0.0f32;
            let qr = q.row(i);
            let kr = k.row(j);
            for t in 0..d {
                dot += qr[t] * kr[t];
            }
            row[j] = dot * scale;
            maxv = maxv.max(row[j]);
        }
        let mut z = 0.0f32;
        for j in 0..=i {
            row[j] = (row[j] - maxv).exp();
            z += row[j];
        }
        let inv = 1.0 / z;
        let pr = probs.row_mut(i);
        for j in 0..=i {
            pr[j] = row[j] * inv;
        }
    }
    let ctx = gemm(&probs, false, v, false);
    (ctx, probs)
}

/// Backward of [`causal_attention`] given the cached probabilities:
/// `dv = Pᵀ·dctx`, `dP = dctx·vᵀ`,
/// `dS_ij = P_ij·(dP_ij − Σ_t P_it·dP_it)` (softmax Jacobian, causal
/// support only), `dq = dS·k/√d`, `dk = dSᵀ·q/√d`.
pub fn causal_attention_bwd(
    q: &Matrix,
    k: &Matrix,
    v: &Matrix,
    probs: &Matrix,
    dctx: &Matrix,
) -> (Matrix, Matrix, Matrix) {
    let s = q.rows;
    let d = q.cols;
    let scale = 1.0 / (d as f32).sqrt();
    // dv = Pᵀ·dctx: the TN kernel skips P's zero upper triangle on its
    // own (per-element zero check), so the dense call does no masked work.
    let dv = gemm(probs, true, dctx, false);
    // dP row i is only read at j ≤ i — compute the causal triangle only.
    let mut dp = Matrix::zeros(s, s);
    for i in 0..s {
        let dcr = dctx.row(i);
        let dpr = dp.row_mut(i);
        for j in 0..=i {
            let vr = v.row(j);
            let mut dot = 0.0f32;
            for t in 0..d {
                dot += dcr[t] * vr[t];
            }
            dpr[j] = dot;
        }
    }
    let mut ds = Matrix::zeros(s, s);
    for i in 0..s {
        let pr = probs.row(i);
        let dpr = dp.row(i);
        let mut rowsum = 0.0f32;
        for j in 0..=i {
            rowsum += pr[j] * dpr[j];
        }
        let dsr = ds.row_mut(i);
        for j in 0..=i {
            dsr[j] = pr[j] * (dpr[j] - rowsum);
        }
    }
    let mut dq = gemm(&ds, false, k, false);
    dq.scale(scale);
    let mut dk = gemm(&ds, true, q, false);
    dk.scale(scale);
    (dq, dk, dv)
}

/// Softmax cross-entropy over each row of `logits` (N×V) against
/// `targets` (len N). Returns the **summed** loss in f64 (the caller
/// divides by N) and the unscaled gradient `p − onehot(target)` — the
/// caller folds in the 1/N mean factor. Per row, loss is computed as
/// `logsumexp(logits) − logits[target]` with the usual max shift.
pub fn softmax_xent(logits: &Matrix, targets: &[u32]) -> (f64, Matrix) {
    let n = logits.rows;
    let v = logits.cols;
    assert_eq!(targets.len(), n, "one target per logits row");
    let mut d = Matrix::zeros(n, v);
    let mut total = 0.0f64;
    for i in 0..n {
        let lr = logits.row(i);
        let t = targets[i] as usize;
        debug_assert!(t < v);
        let mut maxv = f32::NEG_INFINITY;
        for &l in lr {
            maxv = maxv.max(l);
        }
        let mut z = 0.0f32;
        let dr = d.row_mut(i);
        for j in 0..v {
            dr[j] = (lr[j] - maxv).exp();
            z += dr[j];
        }
        let inv = 1.0 / z;
        for item in dr.iter_mut() {
            *item *= inv;
        }
        dr[t] -= 1.0;
        total += (z as f64).ln() + maxv as f64 - lr[t] as f64;
    }
    (total, d)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn rmsnorm_rows_have_unit_rms_when_weight_is_one() {
        let mut rng = Xoshiro256::new(1);
        let x = Matrix::gaussian(4, 9, 2.0, &mut rng);
        let mut w = Matrix::zeros(1, 9);
        w.fill(1.0);
        let y = rmsnorm(&x, &w);
        for i in 0..4 {
            let ms: f32 = y.row(i).iter().map(|v| v * v).sum::<f32>() / 9.0;
            assert!((ms - 1.0).abs() < 1e-3, "row {i}: mean square {ms}");
        }
    }

    #[test]
    fn attention_probs_are_causal_and_normalized() {
        let mut rng = Xoshiro256::new(2);
        let q = Matrix::gaussian(6, 4, 1.0, &mut rng);
        let k = Matrix::gaussian(6, 4, 1.0, &mut rng);
        let v = Matrix::gaussian(6, 4, 1.0, &mut rng);
        let (ctx, p) = causal_attention(&q, &k, &v);
        assert_eq!((ctx.rows, ctx.cols), (6, 4));
        for i in 0..6 {
            for j in (i + 1)..6 {
                assert_eq!(p.at(i, j), 0.0, "({i},{j}) must be masked");
            }
            let row_sum: f32 = p.row(i).iter().sum();
            assert!((row_sum - 1.0).abs() < 1e-5, "row {i} sums to {row_sum}");
        }
        // Position 0 can only attend to itself: ctx row 0 == v row 0.
        for t in 0..4 {
            assert!((ctx.at(0, t) - v.at(0, t)).abs() < 1e-6);
        }
    }

    #[test]
    fn softmax_xent_matches_uniform_logits() {
        // All-zero logits over V classes: loss = ln V per row, gradient
        // rows are 1/V everywhere except target − 1.
        let logits = Matrix::zeros(3, 8);
        let (total, d) = softmax_xent(&logits, &[0, 3, 7]);
        assert!((total / 3.0 - (8f64).ln()).abs() < 1e-6);
        assert!((d.at(0, 1) - 1.0 / 8.0).abs() < 1e-6);
        assert!((d.at(1, 3) - (1.0 / 8.0 - 1.0)).abs() < 1e-6);
    }

    #[test]
    fn silu_grad_matches_finite_difference() {
        for &x in &[-3.0f32, -0.7, 0.0, 0.4, 2.5] {
            let eps = 1e-3;
            let fd = (silu(x + eps) - silu(x - eps)) / (2.0 * eps);
            assert!((fd - silu_grad(x)).abs() < 1e-3, "x={x}");
        }
    }
}
