//! Native pure-Rust transformer LM with hand-written backprop
//! (DESIGN.md §10).
//!
//! Until this module existed, every runnable loss curve in the repo came
//! from the synthetic quadratic objective — the PJRT path is a vendored
//! stub, so no optimizer had ever seen a *real* transformer gradient,
//! and the TSR embedding extension (`rank_emb`/`refresh_emb`, §3.6) had
//! never been exercised by genuinely token-sparse gradients. This module
//! closes that gap: a small decoder-only transformer over the existing
//! [`crate::linalg::Matrix`] type with manual forward + backward,
//! trained on the [`crate::data::SyntheticCorpus`] through
//! [`crate::train::lm_source::LmSource`].
//!
//! Layer inventory (shapes follow the Table-5 registry exactly, plus an
//! untied LM head — [`crate::model::ModelSpec::blocks_untied_lm`]):
//!
//! * token embedding (V×h, class `Embedding`) — backward emits a
//!   **row-sparse** gradient: only batch-touched rows are nonzero;
//! * per layer: RMSNorm → multi-head causal attention (RoPE-free,
//!   q/k/v/o all h×h) → residual → RMSNorm → SwiGLU MLP (gate/up h×f,
//!   down f×h) → residual;
//! * final RMSNorm, untied LM head (V×h, class `Embedding`) with
//!   softmax cross-entropy.
//!
//! Every backward is hand-derived ([`layers`] holds the per-layer
//! primitives); `tests/nn_gradcheck.rs` verifies each against central
//! finite differences and checks bitwise determinism across repeated
//! runs and both execution backends. Determinism comes for free from
//! fixed reduction orders: the matmul kernels partition output rows
//! (each row's k-loop runs in one fixed order regardless of thread
//! count), and every softmax / norm / loss accumulation here is a plain
//! in-order loop.

pub mod layers;
pub mod transformer;

pub use layers::{causal_attention, causal_attention_bwd, rmsnorm, rmsnorm_bwd, softmax_xent};
pub use transformer::TransformerLm;
