//! Decoder-only transformer LM over [`Matrix`] with manual backprop
//! (DESIGN.md §10).
//!
//! Pre-norm residual architecture on the registry's LLaMA-style block
//! layout with an **untied** LM head
//! ([`ModelSpec::blocks_untied_lm`]):
//!
//! ```text
//! x⁰ = E[tokens]                                  (row gather, V×h embed)
//! for each layer: h¹ = x + Attn(RMSNorm₁(x))      (causal, multi-head)
//!                 x  = h¹ + SwiGLU(RMSNorm₂(h¹))
//! logits = RMSNormF(x) · Hᵀ                       (H: V×h untied head)
//! loss   = mean softmax-CE over all B·S positions
//! ```
//!
//! The embedding gradient is the defining output: `dE[t] += Σ_{p: input
//! token at p is t} dx⁰[p]` — **only batch-touched rows are nonzero**,
//! which is what finally exercises the paper's §3.6 embedding extension
//! with real token sparsity. The untied head receives the dense softmax
//! gradient `dH = dlogitsᵀ · xnf`; tying it to `E` would destroy the
//! row-sparsity, which is why the nn trainer unties.

use super::layers::{
    causal_attention, causal_attention_bwd, rmsnorm, rmsnorm_bwd, silu, silu_grad, softmax_xent,
};
use crate::linalg::{gemm, Matrix};
use crate::model::{BlockSpec, ModelSpec};
use crate::train::pjrt_source::init_block;
use crate::util::rng::Xoshiro256;

/// Per-layer block indices into the parameter list (resolved by name so
/// a registry reordering fails loudly at construction, not silently).
struct LayerIdx {
    q: usize,
    k: usize,
    v: usize,
    o: usize,
    gate: usize,
    up: usize,
    down: usize,
    attn_norm: usize,
    mlp_norm: usize,
}

/// Forward cache for one layer — everything backward re-reads.
struct LayerCache {
    x_in: Matrix,
    xn1: Matrix,
    q: Matrix,
    k: Matrix,
    v: Matrix,
    /// Attention probabilities per (batch, head): index `b·heads + j`.
    probs: Vec<Matrix>,
    /// Concatenated head outputs, pre-o-projection.
    ctx: Matrix,
    h1: Matrix,
    xn2: Matrix,
    g_pre: Matrix,
    u_pre: Matrix,
    act: Matrix,
}

struct Cache {
    inputs: Vec<u32>,
    layers: Vec<LayerCache>,
    x_last: Matrix,
    xnf: Matrix,
    /// `(softmax − onehot)/N` — loss gradient wrt logits, mean-scaled.
    dlogits: Matrix,
}

pub struct TransformerLm {
    pub vocab: usize,
    pub hidden: usize,
    pub inter: usize,
    pub heads: usize,
    pub head_dim: usize,
    blocks: Vec<BlockSpec>,
    embed: usize,
    final_norm: usize,
    head: usize,
    layers: Vec<LayerIdx>,
}

impl TransformerLm {
    pub fn new(spec: &ModelSpec) -> Self {
        assert!(!spec.roberta, "nn trainer implements the LLaMA-style layout only");
        assert_eq!(
            spec.hidden % spec.heads,
            0,
            "hidden {} must divide into {} heads",
            spec.hidden,
            spec.heads
        );
        let blocks = spec.blocks_untied_lm();
        let find = |name: &str| {
            blocks
                .iter()
                .position(|b| b.name == name)
                .unwrap_or_else(|| panic!("registry layout is missing block `{name}`"))
        };
        let layers = (0..spec.layers)
            .map(|l| LayerIdx {
                q: find(&format!("layers.{l}.attn.q_proj")),
                k: find(&format!("layers.{l}.attn.k_proj")),
                v: find(&format!("layers.{l}.attn.v_proj")),
                o: find(&format!("layers.{l}.attn.o_proj")),
                gate: find(&format!("layers.{l}.mlp.gate")),
                up: find(&format!("layers.{l}.mlp.up")),
                down: find(&format!("layers.{l}.mlp.down")),
                attn_norm: find(&format!("layers.{l}.attn_norm")),
                mlp_norm: find(&format!("layers.{l}.mlp_norm")),
            })
            .collect();
        Self {
            vocab: spec.vocab,
            hidden: spec.hidden,
            inter: spec.intermediate,
            heads: spec.heads,
            head_dim: spec.hidden / spec.heads,
            embed: find("embed_tokens"),
            final_norm: find("final_norm"),
            head: find("lm_head"),
            layers,
            blocks,
        }
    }

    pub fn blocks(&self) -> &[BlockSpec] {
        &self.blocks
    }

    /// Standard transformer init over the block layout (norms → 1,
    /// embedding/head → N(0, 0.02), linear → N(0, 1/√fan_in)).
    pub fn init_params(&self, seed: u64) -> Vec<Matrix> {
        let mut rng = Xoshiro256::new(seed);
        self.blocks.iter().map(|b| init_block(b, &mut rng)).collect()
    }

    /// Split a flat `[batch, seq+1]` token block (the [`crate::data::
    /// Batcher`] layout) into next-token (input, target) pairs.
    fn split_tokens(&self, tokens: &[u32], batch: usize) -> (Vec<u32>, Vec<u32>, usize) {
        assert!(batch > 0 && tokens.len() % batch == 0, "token block shape mismatch");
        let bs1 = tokens.len() / batch;
        assert!(bs1 >= 2, "need at least one (input, target) pair per sequence");
        let seq = bs1 - 1;
        let mut inputs = Vec::with_capacity(batch * seq);
        let mut targets = Vec::with_capacity(batch * seq);
        for b in 0..batch {
            let row = &tokens[b * bs1..(b + 1) * bs1];
            inputs.extend_from_slice(&row[..seq]);
            targets.extend_from_slice(&row[1..]);
        }
        (inputs, targets, seq)
    }

    /// Mean next-token cross-entropy (nats) — forward only. f64 so the
    /// gradcheck's finite differences are not limited by the scalar.
    pub fn loss(&self, params: &[Matrix], tokens: &[u32], batch: usize) -> f64 {
        self.forward(params, tokens, batch).0
    }

    fn forward(&self, params: &[Matrix], tokens: &[u32], batch: usize) -> (f64, Cache) {
        let (inputs, targets, seq) = self.split_tokens(tokens, batch);
        let n = inputs.len();
        let h = self.hidden;
        let hd = self.head_dim;

        let mut x = Matrix::zeros(n, h);
        for (p, &t) in inputs.iter().enumerate() {
            debug_assert!((t as usize) < self.vocab);
            x.row_mut(p).copy_from_slice(params[self.embed].row(t as usize));
        }

        let mut layer_caches = Vec::with_capacity(self.layers.len());
        for li in &self.layers {
            let x_in = x;
            let xn1 = rmsnorm(&x_in, &params[li.attn_norm]);
            let q = gemm(&xn1, false, &params[li.q], false);
            let k = gemm(&xn1, false, &params[li.k], false);
            let v = gemm(&xn1, false, &params[li.v], false);
            let mut ctx = Matrix::zeros(n, h);
            let mut probs = Vec::with_capacity(batch * self.heads);
            for b in 0..batch {
                for j in 0..self.heads {
                    let qs = gather_head(&q, b, seq, j, hd);
                    let ks = gather_head(&k, b, seq, j, hd);
                    let vs = gather_head(&v, b, seq, j, hd);
                    let (c, p) = causal_attention(&qs, &ks, &vs);
                    scatter_head(&mut ctx, &c, b, seq, j, hd);
                    probs.push(p);
                }
            }
            let attn_out = gemm(&ctx, false, &params[li.o], false);
            let mut h1 = x_in.clone();
            h1.add_assign(&attn_out);
            let xn2 = rmsnorm(&h1, &params[li.mlp_norm]);
            let g_pre = gemm(&xn2, false, &params[li.gate], false);
            let u_pre = gemm(&xn2, false, &params[li.up], false);
            let mut act = Matrix::zeros(n, self.inter);
            for i in 0..act.data.len() {
                act.data[i] = silu(g_pre.data[i]) * u_pre.data[i];
            }
            let mlp_out = gemm(&act, false, &params[li.down], false);
            let mut x_out = h1.clone();
            x_out.add_assign(&mlp_out);
            layer_caches.push(LayerCache {
                x_in,
                xn1,
                q,
                k,
                v,
                probs,
                ctx,
                h1,
                xn2,
                g_pre,
                u_pre,
                act,
            });
            x = x_out;
        }

        let x_last = x;
        let xnf = rmsnorm(&x_last, &params[self.final_norm]);
        let logits = gemm(&xnf, false, &params[self.head], true);
        let (loss_sum, mut dlogits) = softmax_xent(&logits, &targets);
        dlogits.scale(1.0 / n as f32);
        (
            loss_sum / n as f64,
            Cache {
                inputs,
                layers: layer_caches,
                x_last,
                xnf,
                dlogits,
            },
        )
    }

    /// One fwd+bwd pass over a flat `[batch, seq+1]` token block,
    /// writing per-block gradients into `grads` (zeroed here; ordered
    /// like [`Self::blocks`]). Returns the mean token loss.
    pub fn step_into(
        &self,
        params: &[Matrix],
        tokens: &[u32],
        batch: usize,
        grads: &mut [Matrix],
    ) -> f32 {
        assert_eq!(grads.len(), self.blocks.len(), "one gradient buffer per block");
        for g in grads.iter_mut() {
            g.fill(0.0);
        }
        let (loss, cache) = self.forward(params, tokens, batch);
        let n = cache.inputs.len();
        let seq = n / batch;
        let hd = self.head_dim;

        // Untied head + final norm.
        grads[self.head].add_assign(&gemm(&cache.dlogits, true, &cache.xnf, false));
        let dxnf = gemm(&cache.dlogits, false, &params[self.head], false);
        let mut dx = Matrix::zeros(n, self.hidden);
        rmsnorm_bwd(
            &cache.x_last,
            &params[self.final_norm],
            &dxnf,
            &mut dx,
            &mut grads[self.final_norm],
        );

        for (li, lc) in self.layers.iter().zip(&cache.layers).rev() {
            // MLP branch of x_out = h1 + down(silu(gate(xn2)) ⊙ up(xn2)).
            let da = gemm(&dx, false, &params[li.down], true);
            grads[li.down].add_assign(&gemm(&lc.act, true, &dx, false));
            let mut dg = Matrix::zeros(n, self.inter);
            let mut du = Matrix::zeros(n, self.inter);
            for i in 0..dg.data.len() {
                let gp = lc.g_pre.data[i];
                dg.data[i] = da.data[i] * lc.u_pre.data[i] * silu_grad(gp);
                du.data[i] = da.data[i] * silu(gp);
            }
            grads[li.gate].add_assign(&gemm(&lc.xn2, true, &dg, false));
            grads[li.up].add_assign(&gemm(&lc.xn2, true, &du, false));
            let mut dxn2 = gemm(&dg, false, &params[li.gate], true);
            dxn2.add_assign(&gemm(&du, false, &params[li.up], true));
            // Residual: dh1 = dx (pass-through) + norm₂ backprop.
            let mut dh1 = dx;
            rmsnorm_bwd(&lc.h1, &params[li.mlp_norm], &dxn2, &mut dh1, &mut grads[li.mlp_norm]);

            // Attention branch of h1 = x_in + o(attn(xn1)).
            grads[li.o].add_assign(&gemm(&lc.ctx, true, &dh1, false));
            let dctx = gemm(&dh1, false, &params[li.o], true);
            let mut dq_all = Matrix::zeros(n, self.hidden);
            let mut dk_all = Matrix::zeros(n, self.hidden);
            let mut dv_all = Matrix::zeros(n, self.hidden);
            for b in 0..batch {
                for j in 0..self.heads {
                    let qs = gather_head(&lc.q, b, seq, j, hd);
                    let ks = gather_head(&lc.k, b, seq, j, hd);
                    let vs = gather_head(&lc.v, b, seq, j, hd);
                    let dctx_s = gather_head(&dctx, b, seq, j, hd);
                    let p = &lc.probs[b * self.heads + j];
                    let (dqs, dks, dvs) = causal_attention_bwd(&qs, &ks, &vs, p, &dctx_s);
                    scatter_head(&mut dq_all, &dqs, b, seq, j, hd);
                    scatter_head(&mut dk_all, &dks, b, seq, j, hd);
                    scatter_head(&mut dv_all, &dvs, b, seq, j, hd);
                }
            }
            grads[li.q].add_assign(&gemm(&lc.xn1, true, &dq_all, false));
            grads[li.k].add_assign(&gemm(&lc.xn1, true, &dk_all, false));
            grads[li.v].add_assign(&gemm(&lc.xn1, true, &dv_all, false));
            let mut dxn1 = gemm(&dq_all, false, &params[li.q], true);
            dxn1.add_assign(&gemm(&dk_all, false, &params[li.k], true));
            dxn1.add_assign(&gemm(&dv_all, false, &params[li.v], true));
            let mut dx_in = dh1;
            let dw_n1 = &mut grads[li.attn_norm];
            rmsnorm_bwd(&lc.x_in, &params[li.attn_norm], &dxn1, &mut dx_in, dw_n1);
            dx = dx_in;
        }

        // Row-sparse embedding gradient: only batch-touched rows receive
        // mass (position order — a fixed f32 accumulation order).
        let ge = &mut grads[self.embed];
        for (p, &t) in cache.inputs.iter().enumerate() {
            let src = dx.row(p);
            let dst = ge.row_mut(t as usize);
            for (d, s) in dst.iter_mut().zip(src) {
                *d += s;
            }
        }
        loss as f32
    }
}

/// Copy one attention head's S×hd slice out of the packed N×h matrix.
fn gather_head(x: &Matrix, b: usize, seq: usize, j: usize, hd: usize) -> Matrix {
    let mut out = Matrix::zeros(seq, hd);
    for t in 0..seq {
        out.row_mut(t)
            .copy_from_slice(&x.row(b * seq + t)[j * hd..(j + 1) * hd]);
    }
    out
}

/// Write one head's S×hd slice back into the packed N×h matrix. Each
/// (b, j) pair owns a disjoint row/column range, so plain overwrite.
fn scatter_head(dst: &mut Matrix, src: &Matrix, b: usize, seq: usize, j: usize, hd: usize) {
    for t in 0..seq {
        dst.row_mut(b * seq + t)[j * hd..(j + 1) * hd].copy_from_slice(src.row(t));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> (TransformerLm, Vec<Matrix>, Vec<u32>) {
        let spec = ModelSpec::proxy(12, 8, 12, 2, 2);
        let lm = TransformerLm::new(&spec);
        let params = lm.init_params(3);
        let mut rng = Xoshiro256::new(7);
        let tokens: Vec<u32> = (0..2 * 6).map(|_| rng.next_below(12) as u32).collect();
        (lm, params, tokens)
    }

    #[test]
    fn layout_resolves_and_head_is_untied() {
        let (lm, params, _) = tiny();
        assert_ne!(lm.embed, lm.head);
        assert_eq!(params[lm.embed].rows, 12);
        assert_eq!(params[lm.head].rows, 12);
        assert_eq!(params[lm.head].cols, 8);
        assert_eq!(lm.layers.len(), 2);
    }

    #[test]
    fn initial_loss_is_near_ln_vocab() {
        // With 0.02-scale embeddings/head, logits start near zero and
        // the softmax is near-uniform: loss ≈ ln V.
        let (lm, params, tokens) = tiny();
        let loss = lm.loss(&params, &tokens, 2);
        let lnv = (12f64).ln();
        assert!(
            (loss - lnv).abs() < 0.3 * lnv,
            "initial loss {loss} vs ln(12) = {lnv}"
        );
    }

    #[test]
    fn step_into_returns_forward_loss_and_finite_grads() {
        let (lm, params, tokens) = tiny();
        let mut grads: Vec<Matrix> = lm
            .blocks()
            .iter()
            .map(|b| Matrix::zeros(b.rows, b.cols))
            .collect();
        let loss = lm.step_into(&params, &tokens, 2, &mut grads);
        assert!((loss as f64 - lm.loss(&params, &tokens, 2)).abs() < 1e-6);
        for (g, b) in grads.iter().zip(lm.blocks()) {
            assert!(g.data.iter().all(|v| v.is_finite()), "{}", b.name);
            assert!(g.frob_norm() > 0.0, "{} gradient is identically zero", b.name);
        }
    }
}
