//! Trace artifact analysis — the library behind `tsr trace`.
//!
//! Consumes the JSONL artifact written by [`super::Tracer::write_jsonl`]
//! and produces:
//! * a deterministic machine-readable summary ([`summarize`]) whose byte
//!   totals equal the `CommLedger` columns f64-exactly (they are sums of
//!   the `step_bytes` records the ledger itself emitted),
//! * a human report ([`render_report`]): per-phase breakdown,
//!   per-link-class byte timeline with refresh spikes marked, and the
//!   peak-bytes step,
//! * a cross-method comparison ([`compare`]),
//! * a Chrome-trace-format export ([`chrome_trace`]) loadable in
//!   Perfetto / `chrome://tracing`.

use crate::util::json::Json;
use std::collections::BTreeMap;

/// Parse a JSONL trace: one JSON record per non-empty line.
pub fn parse_jsonl(text: &str) -> Result<Vec<Json>, String> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        out.push(Json::parse(line).map_err(|e| format!("trace line {}: {e}", i + 1))?);
    }
    Ok(out)
}

/// Records at or after `boundary` step, with the unstamped header kinds
/// (`meta`, `resume`) dropped — the deterministic splice cut for
/// comparing a resumed run's trace against the uninterrupted run's tail
/// (see the resume-boundary contract in the module docs / DESIGN.md
/// §16). Returns the records re-serialized as compact lines so callers
/// can assert byte-for-byte equality.
pub fn tail_after(records: &[Json], boundary: u64) -> Vec<String> {
    records
        .iter()
        .filter(|r| !matches!(r.get("k").as_str(), Some("meta") | Some("resume")))
        .filter(|r| r.get("step").as_u64().unwrap_or(0) >= boundary)
        .map(|r| r.to_string())
        .collect()
}

/// Deterministic summary of one trace. Sorted-key JSON; every number is
/// an exact sum/copy of record fields (no averaging surprises).
pub fn summarize(records: &[Json]) -> Json {
    let mut method = String::new();
    let mut workers = 0usize;
    let mut wall = false;
    let mut steps = 0u64;
    let (mut total, mut emb, mut lin, mut vec_b) = (0f64, 0f64, 0f64, 0f64);
    let (mut intra, mut inter) = (0f64, 0f64);
    let mut sim_secs = 0f64;
    let mut peak_bytes = 0f64;
    let mut peak_step = 0u64;
    let mut refresh_steps: Vec<Json> = Vec::new();
    // phase -> (count, wall_us total)
    let mut phases: BTreeMap<String, (u64, f64)> = BTreeMap::new();
    // class -> (count, bytes, sim_dt total)
    let mut collectives: BTreeMap<String, (u64, f64, f64)> = BTreeMap::new();
    let mut resumes = 0u64;
    let mut events: BTreeMap<String, u64> = BTreeMap::new();

    for r in records {
        match r.get("k").as_str() {
            Some("meta") => {
                method = r.get_str("method", "").to_string();
                workers = r.get_usize("workers", 0);
                wall = r.get_bool("wall", false);
            }
            Some("resume") => resumes += 1,
            Some("step_bytes") => {
                steps += 1;
                let step = r.get("step").as_u64().unwrap_or(0);
                let t = r.get_f64("total", 0.0);
                total += t;
                emb += r.get_f64("embedding", 0.0);
                lin += r.get_f64("linear", 0.0);
                vec_b += r.get_f64("vector", 0.0);
                intra += r.get_f64("intra", 0.0);
                inter += r.get_f64("inter", 0.0);
                sim_secs = r.get_f64("sim_t", sim_secs);
                if t > peak_bytes {
                    peak_bytes = t;
                    peak_step = step;
                }
                if r.get_bool("refresh", false) {
                    refresh_steps.push(Json::num(step as f64));
                }
            }
            Some("span") => {
                let e = phases.entry(r.get_str("phase", "?").to_string()).or_insert((0, 0.0));
                e.0 += 1;
                e.1 += r.get_f64("wall_us", 0.0);
            }
            Some("collective") => {
                let e = collectives
                    .entry(r.get_str("class", "?").to_string())
                    .or_insert((0, 0.0, 0.0));
                e.0 += 1;
                e.1 += r.get_f64("bytes", 0.0);
                e.2 += r.get_f64("sim_dt", 0.0);
            }
            Some("event") | Some("wall_event") => {
                *events.entry(r.get_str("name", "?").to_string()).or_insert(0) += 1;
            }
            _ => {}
        }
    }

    let phases_j = Json::Obj(
        phases
            .into_iter()
            .map(|(name, (count, wall_us))| {
                let mut o = Json::obj(vec![("count", Json::num(count as f64))]);
                if wall {
                    o.set("wall_us", Json::num(wall_us));
                }
                (name, o)
            })
            .collect(),
    );
    let collectives_j = Json::Obj(
        collectives
            .into_iter()
            .map(|(class, (count, bytes, sim_dt))| {
                (
                    class,
                    Json::obj(vec![
                        ("count", Json::num(count as f64)),
                        ("bytes", Json::num(bytes)),
                        ("sim_secs", Json::num(sim_dt)),
                    ]),
                )
            })
            .collect(),
    );
    let events_j =
        Json::Obj(events.into_iter().map(|(n, c)| (n, Json::num(c as f64))).collect());

    Json::obj(vec![
        ("method", Json::str(method)),
        ("workers", Json::num(workers as f64)),
        ("wall", Json::Bool(wall)),
        ("steps", Json::num(steps as f64)),
        (
            "bytes",
            Json::obj(vec![
                ("total", Json::num(total)),
                ("embedding", Json::num(emb)),
                ("linear", Json::num(lin)),
                ("vector", Json::num(vec_b)),
                ("intra", Json::num(intra)),
                ("inter", Json::num(inter)),
            ]),
        ),
        (
            "peak",
            Json::obj(vec![
                ("step", Json::num(peak_step as f64)),
                ("bytes", Json::num(peak_bytes)),
            ]),
        ),
        ("refresh_steps", Json::Arr(refresh_steps)),
        ("sim_secs", Json::num(sim_secs)),
        ("phases", phases_j),
        ("collectives", collectives_j),
        ("events", events_j),
        ("resumes", Json::num(resumes as f64)),
    ])
}

fn fmt_bytes(b: f64) -> String {
    crate::util::bench::fmt_bytes(b)
}

/// Human report: per-phase table, per-link-class totals, and a byte
/// timeline with refresh spikes marked. Long runs elide steady steps —
/// refresh spikes, the peak step, and the edges always print.
pub fn render_report(records: &[Json]) -> String {
    let s = summarize(records);
    let mut out = String::new();
    let wall = s.get_bool("wall", false);
    out.push_str(&format!(
        "trace: method={} workers={} steps={} ({} records{})\n",
        s.get_str("method", "?"),
        s.get_usize("workers", 0),
        s.get_usize("steps", 0),
        records.len(),
        if wall { ", wall-clock" } else { ", deterministic" },
    ));
    if s.get_usize("resumes", 0) > 0 {
        out.push_str(&format!("  resume boundaries: {}\n", s.get_usize("resumes", 0)));
    }

    out.push_str("\nper-phase breakdown:\n");
    if let Some(phases) = s.get("phases").as_obj() {
        for (phase, v) in phases {
            match v.get("wall_us").as_f64() {
                Some(us) => out.push_str(&format!(
                    "  {phase:<24} x{:<6} {:>12.3} ms wall\n",
                    v.get_usize("count", 0),
                    us / 1e3,
                )),
                None => {
                    out.push_str(&format!("  {phase:<24} x{}\n", v.get_usize("count", 0)))
                }
            }
        }
        if phases.is_empty() {
            out.push_str("  (no spans recorded)\n");
        }
    }

    out.push_str("\nper-link-class collectives:\n");
    if let Some(cols) = s.get("collectives").as_obj() {
        for (class, v) in cols {
            out.push_str(&format!(
                "  {class:<12} x{:<6} {:>12}  {:>10.6} s sim\n",
                v.get_usize("count", 0),
                fmt_bytes(v.get_f64("bytes", 0.0)),
                v.get_f64("sim_secs", 0.0),
            ));
        }
    }
    let b = s.get("bytes");
    out.push_str(&format!(
        "  wire split: intra {} / inter {}\n",
        fmt_bytes(b.get_f64("intra", 0.0)),
        fmt_bytes(b.get_f64("inter", 0.0)),
    ));
    out.push_str(&format!(
        "  payload:    emb {} / linear {} / vector {}  (total {})\n",
        fmt_bytes(b.get_f64("embedding", 0.0)),
        fmt_bytes(b.get_f64("linear", 0.0)),
        fmt_bytes(b.get_f64("vector", 0.0)),
        fmt_bytes(b.get_f64("total", 0.0)),
    ));

    // Byte timeline from the raw step_bytes records.
    let step_recs: Vec<&Json> = records
        .iter()
        .filter(|r| r.get("k").as_str() == Some("step_bytes"))
        .collect();
    let peak_step = s.get("peak").get_usize("step", 0);
    out.push_str("\nbyte timeline (step: total [emb/linear/vector], * = refresh spike):\n");
    let n = step_recs.len();
    let mut elided = 0usize;
    for (i, r) in step_recs.iter().enumerate() {
        let step = r.get_usize("step", 0);
        let refresh = r.get_bool("refresh", false);
        let notable = refresh || step == peak_step || i < 3 || i + 3 >= n;
        if n > 48 && !notable {
            elided += 1;
            continue;
        }
        if elided > 0 {
            out.push_str(&format!("  ... {elided} steady steps elided ...\n"));
            elided = 0;
        }
        out.push_str(&format!(
            "  {:>6}: {:>12} [{} / {} / {}]{}{}\n",
            step,
            fmt_bytes(r.get_f64("total", 0.0)),
            fmt_bytes(r.get_f64("embedding", 0.0)),
            fmt_bytes(r.get_f64("linear", 0.0)),
            fmt_bytes(r.get_f64("vector", 0.0)),
            if refresh { "  *refresh*" } else { "" },
            if step == peak_step { "  <-- peak" } else { "" },
        ));
    }
    if elided > 0 {
        out.push_str(&format!("  ... {elided} steady steps elided ...\n"));
    }
    out.push_str(&format!(
        "\npeak: step {} at {}; sim comm time {:.6} s\n",
        peak_step,
        fmt_bytes(s.get("peak").get_f64("bytes", 0.0)),
        s.get_f64("sim_secs", 0.0),
    ));
    out
}

/// Cross-method comparison of two traces: side-by-side totals plus
/// byte ratios (the Fig-6-style "where do the bytes go" question asked
/// of two real runs).
pub fn compare(a: &[Json], b: &[Json]) -> String {
    let (sa, sb) = (summarize(a), summarize(b));
    let mut out = String::new();
    out.push_str(&format!(
        "{:<22} {:>16} {:>16} {:>8}\n",
        "",
        sa.get_str("method", "a"),
        sb.get_str("method", "b"),
        "ratio"
    ));
    let rows: [(&str, fn(&Json) -> f64); 6] = [
        ("steps", |s| s.get_f64("steps", 0.0)),
        ("total bytes", |s| s.get("bytes").get_f64("total", 0.0)),
        ("embedding bytes", |s| s.get("bytes").get_f64("embedding", 0.0)),
        ("linear bytes", |s| s.get("bytes").get_f64("linear", 0.0)),
        ("peak step bytes", |s| s.get("peak").get_f64("bytes", 0.0)),
        ("sim comm secs", |s| s.get_f64("sim_secs", 0.0)),
    ];
    for (label, get) in rows {
        let (va, vb) = (get(&sa), get(&sb));
        let ratio = if va > 0.0 { vb / va } else { f64::NAN };
        out.push_str(&format!("{label:<22} {va:>16.6} {vb:>16.6} {ratio:>8.3}\n"));
    }
    out
}

/// Chrome-trace-format (`trace_events`) export, loadable in Perfetto.
///
/// Track layout:
/// * tid 0 — per-step byte counters (`step_bytes` as `C` events on the
///   sim-time axis; refresh steps emit an extra instant marker),
/// * tid 1 — collective legs as complete (`X`) slices on the sim-time
///   axis (`ts = sim_t − sim_dt`),
/// * tid 2 — wall-clock spans (`X`, only present in wall traces),
/// * instants for `event` / `resume` / `wall_event` records.
///
/// Timestamps are microseconds as the format requires; deterministic
/// traces use the α–β `sim_time` axis, wall records their `wall_*`
/// fields.
pub fn chrome_trace(records: &[Json]) -> Json {
    let mut events: Vec<Json> = Vec::new();
    let ev = |ph: &str, name: &str, ts: f64, tid: u64, extra: Vec<(&str, Json)>| {
        let mut o = Json::obj(vec![
            ("ph", Json::str(ph)),
            ("name", Json::str(name)),
            ("ts", Json::num(ts)),
            ("pid", Json::num(0.0)),
            ("tid", Json::num(tid as f64)),
        ]);
        for (k, v) in extra {
            o.set(k, v);
        }
        o
    };
    for r in records {
        let step = r.get_f64("step", 0.0);
        match r.get("k").as_str() {
            Some("step_bytes") => {
                let ts = r.get_f64("sim_t", 0.0) * 1e6;
                events.push(ev(
                    "C",
                    "bytes/class",
                    ts,
                    0,
                    vec![(
                        "args",
                        Json::obj(vec![
                            ("embedding", Json::num(r.get_f64("embedding", 0.0))),
                            ("linear", Json::num(r.get_f64("linear", 0.0))),
                            ("vector", Json::num(r.get_f64("vector", 0.0))),
                        ]),
                    )],
                ));
                if r.get_bool("refresh", false) {
                    events.push(ev(
                        "i",
                        "refresh",
                        ts,
                        0,
                        vec![
                            ("s", Json::str("g")),
                            ("args", Json::obj(vec![("step", Json::num(step))])),
                        ],
                    ));
                }
            }
            Some("collective") => {
                let dt = r.get_f64("sim_dt", 0.0) * 1e6;
                let ts = r.get_f64("sim_t", 0.0) * 1e6 - dt;
                events.push(ev(
                    "X",
                    r.get_str("class", "collective"),
                    ts,
                    1,
                    vec![
                        ("dur", Json::num(dt)),
                        (
                            "args",
                            Json::obj(vec![
                                ("bytes", Json::num(r.get_f64("bytes", 0.0))),
                                ("fmt", Json::str(r.get_str("fmt", "f32"))),
                                ("step", Json::num(step)),
                            ]),
                        ),
                    ],
                ));
            }
            Some("span") => {
                if let Some(ts) = r.get("wall_ts").as_f64() {
                    events.push(ev(
                        "X",
                        r.get_str("phase", "span"),
                        ts,
                        2,
                        vec![
                            ("dur", Json::num(r.get_f64("wall_us", 0.0))),
                            ("args", Json::obj(vec![("step", Json::num(step))])),
                        ],
                    ));
                }
            }
            Some("event") | Some("resume") => {
                events.push(ev(
                    "i",
                    r.get_str("name", r.get_str("k", "event")),
                    step * 1e6,
                    0,
                    vec![("s", Json::str("g"))],
                ));
            }
            Some("wall_event") => {
                events.push(ev(
                    "i",
                    r.get_str("name", "wall_event"),
                    r.get_f64("wall_us", 0.0),
                    3,
                    vec![("s", Json::str("p"))],
                ));
            }
            _ => {}
        }
    }
    Json::obj(vec![("traceEvents", Json::Arr(events))])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::accounting::StepRecord;
    use crate::comm::LayerClass;
    use crate::obs::Tracer;

    fn sample_trace() -> Vec<Json> {
        let t = Tracer::new();
        t.meta("tsr", 4);
        for step in 0..3u64 {
            t.set_step(step);
            {
                crate::span!(t, "grad_compute");
            }
            t.collective(LayerClass::Linear, 4096, "f32", 6144, 2048, 1e-3, (step + 1) as f64 * 1e-3);
            let rec = StepRecord {
                total: if step == 1 { 9000 } else { 4096 },
                embedding: 0,
                linear: if step == 1 { 9000 } else { 4096 },
                vector: 0,
                intra: 6144,
                inter: 2048,
                refresh: step == 1,
            };
            t.step_bytes(step, &rec, (step + 1) as f64 * 1e-3);
        }
        t.records()
    }

    #[test]
    fn summary_totals_are_exact_sums() {
        let s = summarize(&sample_trace());
        assert_eq!(s.get("bytes").get_f64("total", 0.0), 4096.0 + 9000.0 + 4096.0);
        assert_eq!(s.get("bytes").get_f64("intra", 0.0), 3.0 * 6144.0);
        assert_eq!(s.get("peak").get_usize("step", 99), 1);
        assert_eq!(s.get("peak").get_f64("bytes", 0.0), 9000.0);
        let refresh = s.get("refresh_steps").as_arr().unwrap();
        assert_eq!(refresh.len(), 1);
        assert_eq!(refresh[0].as_u64(), Some(1));
        assert_eq!(s.get_usize("steps", 0), 3);
        assert_eq!(s.get_str("method", ""), "tsr");
    }

    #[test]
    fn report_marks_refresh_and_peak() {
        let report = render_report(&sample_trace());
        assert!(report.contains("*refresh*"), "{report}");
        assert!(report.contains("<-- peak"), "{report}");
        assert!(report.contains("grad_compute"), "{report}");
    }

    #[test]
    fn jsonl_roundtrip_preserves_summary() {
        let recs = sample_trace();
        let text: String = recs.iter().map(|r| r.to_string() + "\n").collect();
        let back = parse_jsonl(&text).unwrap();
        assert_eq!(summarize(&recs).to_string(), summarize(&back).to_string());
    }

    #[test]
    fn tail_after_drops_headers_and_earlier_steps() {
        let t = Tracer::new();
        t.meta("tsr", 2);
        t.resume(1, 2);
        t.set_step(0);
        t.event("a", vec![]);
        t.set_step(1);
        t.event("b", vec![]);
        let tail = tail_after(&t.records(), 1);
        assert_eq!(tail.len(), 1);
        assert!(tail[0].contains("\"b\""), "{tail:?}");
    }

    #[test]
    fn chrome_export_has_counter_and_slice_events() {
        let j = chrome_trace(&sample_trace());
        let evs = j.get("traceEvents").as_arr().unwrap();
        assert!(evs.iter().any(|e| e.get("ph").as_str() == Some("C")));
        assert!(evs.iter().any(|e| e.get("ph").as_str() == Some("X")));
        assert!(evs.iter().any(|e| e.get("name").as_str() == Some("refresh")));
    }

    #[test]
    fn compare_reports_ratios() {
        let recs = sample_trace();
        let out = compare(&recs, &recs);
        assert!(out.contains("total bytes"), "{out}");
        assert!(out.contains("1.000"), "{out}");
    }

    #[test]
    fn bad_jsonl_line_is_a_loud_error() {
        let err = parse_jsonl("{\"k\":\"meta\"}\nnot json\n").unwrap_err();
        assert!(err.contains("line 2"), "{err}");
    }
}
