//! Leveled stderr logging facade (DESIGN.md §16).
//!
//! Replaces the scattered `eprintln!` call sites: every diagnostic goes
//! through [`write`] (via the `tsr_error!` / `tsr_warn!` / `tsr_info!` /
//! `tsr_debug!` macros) and is filtered by the `TSR_LOG` environment
//! variable (`error | warn | info | debug`, default `warn`).
//!
//! The facade prints the formatted message **verbatim** — no level
//! prefix, no timestamp — so test-visible error strings are unchanged
//! from their `eprintln!` days. Product output (tables, summaries,
//! results paths) stays on `println!`; this is for diagnostics only.
//! Error-level messages always print at the default level.

use std::sync::OnceLock;

/// Severity, ordered most- to least-severe.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    pub fn name(&self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }

    /// Parse a `TSR_LOG` value. Unknown names are a loud error listing
    /// the valid set — same idiom as `ExecBackend::parse`.
    pub fn parse(name: &str) -> Result<Self, String> {
        match name.trim() {
            "error" => Ok(Level::Error),
            "warn" | "warning" => Ok(Level::Warn),
            "info" => Ok(Level::Info),
            "debug" => Ok(Level::Debug),
            other => Err(format!(
                "unknown log level `{other}` (valid: error | warn | info | debug)"
            )),
        }
    }
}

static MAX_LEVEL: OnceLock<Level> = OnceLock::new();

/// The active threshold: `TSR_LOG` if set (a set-but-invalid value
/// panics with the valid list rather than silently filtering wrong),
/// else [`Level::Warn`]. Resolved once per process.
pub fn max_level() -> Level {
    *MAX_LEVEL.get_or_init(|| match std::env::var("TSR_LOG") {
        Ok(v) => Level::parse(&v).unwrap_or_else(|e| panic!("TSR_LOG: {e}")),
        Err(_) => Level::Warn,
    })
}

/// Whether a message at `level` would print.
pub fn enabled(level: Level) -> bool {
    level <= max_level()
}

/// Print `args` to stderr iff `level` clears the threshold. Use the
/// `tsr_*!` macros rather than calling this directly.
pub fn write(level: Level, args: std::fmt::Arguments) {
    if enabled(level) {
        eprintln!("{args}");
    }
}

/// Unrecoverable-path diagnostics; always printed (error ≤ warn).
#[macro_export]
macro_rules! tsr_error {
    ($($a:tt)*) => {
        $crate::obs::log::write($crate::obs::log::Level::Error, format_args!($($a)*))
    };
}

/// Suspicious-but-continuing diagnostics; printed at the default level.
#[macro_export]
macro_rules! tsr_warn {
    ($($a:tt)*) => {
        $crate::obs::log::write($crate::obs::log::Level::Warn, format_args!($($a)*))
    };
}

/// Config echoes and progress notes; hidden unless `TSR_LOG=info`.
#[macro_export]
macro_rules! tsr_info {
    ($($a:tt)*) => {
        $crate::obs::log::write($crate::obs::log::Level::Info, format_args!($($a)*))
    };
}

/// High-volume internals; hidden unless `TSR_LOG=debug`.
#[macro_export]
macro_rules! tsr_debug {
    ($($a:tt)*) => {
        $crate::obs::log::write($crate::obs::log::Level::Debug, format_args!($($a)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order_most_severe_first() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
    }

    #[test]
    fn parse_roundtrips_and_rejects_loudly() {
        for l in [Level::Error, Level::Warn, Level::Info, Level::Debug] {
            assert_eq!(Level::parse(l.name()), Ok(l));
        }
        assert_eq!(Level::parse(" warning "), Ok(Level::Warn));
        for bogus in ["verbose", "ERROR", "", "trace"] {
            let err = Level::parse(bogus).unwrap_err();
            assert!(err.contains("error | warn | info | debug"), "`{bogus}` -> {err}");
        }
    }

    #[test]
    fn default_threshold_passes_errors_and_warnings() {
        // The suite runs without TSR_LOG set (or with a valid value);
        // error must always clear whatever threshold is active.
        assert!(enabled(Level::Error));
        // Macros compile and format lazily.
        crate::tsr_debug!("invisible by default: {}", 42);
    }
}
