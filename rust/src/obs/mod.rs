//! Observability: deterministic tracing + leveled logging (DESIGN.md §16).
//!
//! The tracer records **step-indexed, sim-time-stamped** events from the
//! training pipeline into an in-memory buffer and writes them as a
//! sorted-key JSONL artifact (one record per line, `util::json` compact
//! serialization, atomic tmp+rename).
//!
//! **Determinism contract.** A trace recorded *without* wall-clock mode
//! contains only backend-invariant fields (step index, a per-step record
//! sequence `j`, byte counts, the ledger's α–β `sim_time`), emitted only
//! from coordinator-side code that is identical across the sequential /
//! threaded / process backends. Such a trace is byte-identical across
//! repeats of the same seeded run AND across execution backends — CI
//! diffs it the same way it diffs the metrics JSON. Wall-clock timing is
//! strictly opt-in ([`Tracer::new_wall`], `tsr train --trace-wall`) and
//! quarantined into `wall_*` fields; enabling it also unlocks
//! backend-specific records (process handshake / frame counters /
//! respawns), which ride on the wall tier precisely because a wall trace
//! makes no byte-identity promise.
//!
//! **Disabled tracer.** The default [`Tracer`] is disabled: every
//! emission site is a single `Option` check, no allocation, no lock —
//! and it is *bit-preserving*: a run with a disabled tracer attached
//! produces the byte-identical deterministic metrics JSON as a run
//! without one (asserted in `rust/tests/trace.rs`).
//!
//! **Resume boundary.** A resumed run re-attaches a fresh tracer and
//! emits a `resume` record before its first step. Because the per-step
//! sequence `j` resets at every step boundary, the resumed trace's step
//! records are byte-identical to the same steps of the uninterrupted
//! run's trace (drop `meta`/`resume` lines and compare step ≥ boundary —
//! [`analyze::tail_after`] implements exactly that cut; asserted by the
//! resilience drills and the soak trace cell).

pub mod analyze;
pub mod log;

use crate::comm::accounting::StepRecord;
use crate::comm::LayerClass;
use crate::util::json::Json;
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Trace format version, written into the `meta` record.
pub const TRACE_VERSION: u64 = 1;

struct State {
    /// Current step index (set by the training loop).
    step: u64,
    /// Per-step record sequence; resets to 0 at every `set_step`, so a
    /// record is addressed by the deterministic pair `(step, j)` and a
    /// resumed run's step records line up with the full run's.
    j: u64,
    records: Vec<Json>,
}

struct Inner {
    wall: bool,
    epoch: Instant,
    state: Mutex<State>,
}

/// Cheap-to-clone tracer handle. `Tracer::default()` is disabled;
/// cloning shares the underlying buffer.
#[derive(Clone, Default)]
pub struct Tracer(Option<Arc<Inner>>);

impl std::fmt::Debug for Tracer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.0 {
            None => write!(f, "Tracer(disabled)"),
            Some(i) => write!(f, "Tracer(enabled, wall={})", i.wall),
        }
    }
}

impl Tracer {
    /// Enabled tracer recording only deterministic fields.
    pub fn new() -> Self {
        Self::with_wall(false)
    }

    /// Enabled tracer that ALSO stamps `wall_*` fields and accepts
    /// backend-specific wall-tier records. Not byte-stable — see the
    /// module docs.
    pub fn new_wall() -> Self {
        Self::with_wall(true)
    }

    fn with_wall(wall: bool) -> Self {
        Tracer(Some(Arc::new(Inner {
            wall,
            epoch: Instant::now(),
            state: Mutex::new(State {
                step: 0,
                j: 0,
                records: Vec::new(),
            }),
        })))
    }

    pub fn enabled(&self) -> bool {
        self.0.is_some()
    }

    pub fn wall(&self) -> bool {
        self.0.as_ref().is_some_and(|i| i.wall)
    }

    fn lock(inner: &Inner) -> std::sync::MutexGuard<'_, State> {
        inner.state.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Enter step `t`: subsequent records carry `step: t` and the
    /// per-step sequence restarts at 0.
    pub fn set_step(&self, t: u64) {
        if let Some(inner) = &self.0 {
            let mut st = Self::lock(inner);
            st.step = t;
            st.j = 0;
        }
    }

    /// Push one record with the deterministic `(step, j)` stamp plus
    /// `fields`. The single append point for every stamped record kind.
    fn emit(&self, k: &str, fields: Vec<(&str, Json)>) {
        let Some(inner) = &self.0 else { return };
        let mut st = Self::lock(inner);
        let mut o = Json::obj(fields);
        o.set("k", Json::str(k));
        o.set("step", Json::num(st.step as f64));
        o.set("j", Json::num(st.j as f64));
        st.j += 1;
        st.records.push(o);
    }

    /// First line of the artifact: run identity. Deliberately excludes
    /// the execution backend — a deterministic trace must not differ
    /// across backends, including its header.
    pub fn meta(&self, method: &str, workers: usize) {
        let Some(inner) = &self.0 else { return };
        let mut o = Json::obj(vec![
            ("k", Json::str("meta")),
            ("method", Json::str(method)),
            ("trace_version", Json::num(TRACE_VERSION as f64)),
            ("workers", Json::num(workers as f64)),
        ]);
        if inner.wall {
            o.set("wall", Json::Bool(true));
        }
        Self::lock(inner).records.push(o);
    }

    /// Resume-boundary record: the run restarts at `start_step` from a
    /// checkpoint. Unstamped (no `j`) so [`analyze::tail_after`] can
    /// splice resumed traces against uninterrupted ones.
    pub fn resume(&self, start_step: u64, workers: usize) {
        let Some(inner) = &self.0 else { return };
        Self::lock(inner).records.push(Json::obj(vec![
            ("k", Json::str("resume")),
            ("start_step", Json::num(start_step as f64)),
            ("workers", Json::num(workers as f64)),
        ]));
    }

    /// Span guard for a pipeline phase: one `span` record is emitted
    /// when the guard drops (wall mode adds `wall_ts`/`wall_us`).
    pub fn span(&self, phase: &'static str) -> SpanGuard {
        SpanGuard {
            tracer: self.clone(),
            phase,
            t0: if self.wall() { Some(Instant::now()) } else { None },
        }
    }

    /// Named point event with extra deterministic fields.
    pub fn event(&self, name: &str, fields: Vec<(&str, Json)>) {
        if self.0.is_none() {
            return;
        }
        let mut fields = fields;
        fields.push(("name", Json::str(name)));
        self.emit("event", fields);
    }

    /// Named numeric sample (deterministic values only).
    pub fn counter(&self, name: &str, value: f64) {
        if self.0.is_none() {
            return;
        }
        self.emit(
            "counter",
            vec![("name", Json::str(name)), ("value", Json::num(value))],
        );
    }

    /// One collective leg as metered by `comm::collective::sync_mean`:
    /// payload bytes by layer class and element format (`"packed"` for
    /// the bit-packed virtual collectives of sign/top-k), the per-link
    /// intra/inter wire split, and the α–β model's `sim_dt` for the leg
    /// plus the cumulative `sim_t` after it.
    #[allow(clippy::too_many_arguments)]
    pub fn collective(
        &self,
        class: LayerClass,
        bytes: usize,
        fmt: &str,
        intra: usize,
        inter: usize,
        sim_dt: f64,
        sim_t: f64,
    ) {
        if self.0.is_none() {
            return;
        }
        self.emit(
            "collective",
            vec![
                ("class", Json::str(class.name())),
                ("bytes", Json::num(bytes as f64)),
                ("fmt", Json::str(fmt)),
                ("intra", Json::num(intra as f64)),
                ("inter", Json::num(inter as f64)),
                ("sim_dt", Json::num(sim_dt)),
                ("sim_t", Json::num(sim_t)),
            ],
        );
    }

    /// Per-step byte totals, emitted by `CommLedger::end_step` from the
    /// exact `StepRecord` it closes — so the trace's byte timeline
    /// equals the ledger columns f64-exactly by construction.
    pub fn step_bytes(&self, step: u64, rec: &StepRecord, sim_t: f64) {
        let Some(inner) = &self.0 else { return };
        let mut st = Self::lock(inner);
        let mut o = Json::obj(vec![
            ("k", Json::str("step_bytes")),
            ("total", Json::num(rec.total as f64)),
            ("embedding", Json::num(rec.embedding as f64)),
            ("linear", Json::num(rec.linear as f64)),
            ("vector", Json::num(rec.vector as f64)),
            ("intra", Json::num(rec.intra as f64)),
            ("inter", Json::num(rec.inter as f64)),
            ("refresh", Json::Bool(rec.refresh)),
            ("sim_t", Json::num(sim_t)),
        ]);
        // Step index comes from the ledger (its closed-step count), not
        // the tracer cursor, so ledger-only callers stay correct.
        o.set("step", Json::num(step as f64));
        o.set("j", Json::num(st.j as f64));
        st.j += 1;
        st.records.push(o);
    }

    /// Wall-tier record: backend-specific, wall-stamped, dropped unless
    /// wall mode is on. The only record kind the process/threaded
    /// backends emit (via the global tracer).
    pub fn wall_event(&self, name: &str, fields: Vec<(&str, Json)>) {
        let Some(inner) = &self.0 else { return };
        if !inner.wall {
            return;
        }
        let wall_us = inner.epoch.elapsed().as_micros() as f64;
        let mut fields = fields;
        fields.push(("name", Json::str(name)));
        fields.push(("wall_us", Json::num(wall_us)));
        self.emit("wall_event", fields);
    }

    /// Snapshot of the records so far (cloned; the tracer keeps going).
    pub fn records(&self) -> Vec<Json> {
        match &self.0 {
            None => Vec::new(),
            Some(inner) => Self::lock(inner).records.clone(),
        }
    }

    /// Serialize to JSONL: one compact sorted-key record per line.
    pub fn to_jsonl(&self) -> String {
        let mut s = String::new();
        for r in self.records() {
            s.push_str(&r.to_string());
            s.push('\n');
        }
        s
    }

    /// Write the JSONL artifact atomically (tmp+rename, parent dirs
    /// created) — same helper the checkpoint manifests use.
    pub fn write_jsonl(&self, path: impl AsRef<std::path::Path>) -> Result<(), String> {
        crate::util::json::write_text_atomic(path, &self.to_jsonl())
    }
}

/// RAII phase span; emits its `span` record on drop.
pub struct SpanGuard {
    tracer: Tracer,
    phase: &'static str,
    t0: Option<Instant>,
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some(inner) = &self.tracer.0 else { return };
        let mut fields = vec![("phase", Json::str(self.phase))];
        if let Some(t0) = self.t0 {
            let ts = t0.duration_since(inner.epoch).as_micros() as f64;
            fields.push(("wall_ts", Json::num(ts)));
            fields.push(("wall_us", Json::num(t0.elapsed().as_micros() as f64)));
        }
        self.tracer.emit("span", fields);
    }
}

/// Open a phase span that closes at the end of the enclosing scope:
/// `span!(tracer, "project")`.
#[macro_export]
macro_rules! span {
    ($tracer:expr, $phase:expr) => {
        let _span_guard = $tracer.span($phase);
    };
}

/// Process-global tracer slot. Only the execution backends use it, and
/// only for wall-tier records ([`Tracer::wall_event`]) — deterministic
/// records always travel through the ledger-attached handle, so the
/// global can never perturb the byte-identity contract.
static GLOBAL: OnceLock<Mutex<Tracer>> = OnceLock::new();

fn global_slot() -> &'static Mutex<Tracer> {
    GLOBAL.get_or_init(|| Mutex::new(Tracer::default()))
}

/// Install (or replace) the global tracer for backend wall events.
pub fn set_global(t: Tracer) {
    *global_slot().lock().unwrap_or_else(|p| p.into_inner()) = t;
}

/// Current global tracer (disabled if never set).
pub fn global() -> Tracer {
    global_slot().lock().unwrap_or_else(|p| p.into_inner()).clone()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_tracer_records_nothing() {
        let t = Tracer::default();
        assert!(!t.enabled());
        t.meta("tsr", 4);
        t.set_step(3);
        t.event("x", vec![]);
        t.counter("c", 1.0);
        {
            span!(t, "phase");
        }
        assert!(t.records().is_empty());
        assert_eq!(t.to_jsonl(), "");
    }

    #[test]
    fn per_step_sequence_resets_and_stamps() {
        let t = Tracer::new();
        t.meta("tsr", 2);
        t.set_step(0);
        t.event("a", vec![]);
        t.event("b", vec![]);
        t.set_step(1);
        t.event("c", vec![]);
        let r = t.records();
        assert_eq!(r.len(), 4);
        assert_eq!(r[1].get("j").as_u64(), Some(0));
        assert_eq!(r[2].get("j").as_u64(), Some(1));
        assert_eq!(r[3].get("j").as_u64(), Some(0));
        assert_eq!(r[3].get("step").as_u64(), Some(1));
    }

    #[test]
    fn deterministic_records_carry_no_wall_fields() {
        let t = Tracer::new();
        t.set_step(0);
        {
            span!(t, "phase");
        }
        t.wall_event("backend_thing", vec![]); // dropped: wall mode off
        let lines = t.to_jsonl();
        assert!(!lines.contains("wall"), "wall leak: {lines}");
        assert_eq!(t.records().len(), 1);
    }

    #[test]
    fn wall_mode_quarantines_into_wall_fields() {
        let t = Tracer::new_wall();
        t.set_step(0);
        {
            span!(t, "phase");
        }
        t.wall_event("spawn", vec![("rank", Json::num(1.0))]);
        let r = t.records();
        assert_eq!(r.len(), 2);
        assert!(r[0].get("wall_us").as_f64().is_some());
        assert!(r[0].get("wall_ts").as_f64().is_some());
        assert_eq!(r[1].get("name").as_str(), Some("spawn"));
        assert!(r[1].get("wall_us").as_f64().is_some());
    }

    #[test]
    fn clones_share_the_buffer() {
        let t = Tracer::new();
        let u = t.clone();
        t.set_step(0);
        u.event("from-clone", vec![]);
        assert_eq!(t.records().len(), 1);
    }

    #[test]
    fn jsonl_lines_parse_back() {
        let t = Tracer::new();
        t.meta("adamw", 4);
        t.set_step(2);
        t.counter("loss", 0.5);
        for line in t.to_jsonl().lines() {
            Json::parse(line).unwrap();
        }
    }
}
