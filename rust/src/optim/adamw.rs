//! Dense AdamW under full gradient synchronization (paper §3.1) — the
//! O(mn) baseline of Tables 1 & 3.

use super::{AdamHyper, DenseAdamState, DistOptimizer, StepCtx, SyncItem, SyncPlan};
use crate::comm::{collective, LayerClass};
use crate::model::BlockSpec;

pub struct DenseAdamW {
    hyper: AdamHyper,
    classes: Vec<LayerClass>,
    state: Vec<DenseAdamState>,
    t: u64,
}

impl DenseAdamW {
    pub fn new(blocks: &[BlockSpec], hyper: AdamHyper) -> Self {
        Self {
            hyper,
            classes: blocks.iter().map(|b| b.class).collect(),
            state: blocks
                .iter()
                .map(|b| DenseAdamState::new(b.rows, b.cols))
                .collect(),
            t: 0,
        }
    }
}

impl DistOptimizer for DenseAdamW {
    fn name(&self) -> &'static str {
        "adamw"
    }

    fn step(&mut self, ctx: &mut StepCtx) {
        self.t += 1;
        let tracer = ctx.tracer();
        crate::span!(tracer, "dense_step");
        let nblocks = ctx.params.len();
        for b in 0..nblocks {
            // All-reduce the dense gradient: S_t = { Ḡ } (mn elements).
            let mut per_worker: Vec<_> = ctx.grads.iter_mut().map(|g| g[b].clone()).collect();
            collective::sync_mean(&mut per_worker, self.classes[b], ctx.ledger, ctx.topo, ctx.exec);
            let gbar = &per_worker[0];

            // The dense-Adam hot path: sharded over worker threads on
            // the threaded backend (bitwise-identical either way).
            self.state[b].update_exec(
                &mut ctx.params[b],
                gbar,
                &self.hyper,
                ctx.lr_mult,
                self.t,
                ctx.exec,
            );
        }
    }

    fn sync_plan(&self, _t: u64) -> SyncPlan {
        // Every parameter, every step.
        SyncPlan {
            items: self
                .state
                .iter()
                .enumerate()
                .map(|(b, st)| SyncItem {
                    block: b,
                    class: self.classes[b],
                    bytes: st.m.numel() * crate::comm::BYTES_F32,
                    fmt: crate::comm::ElemFmt::F32,
                    refresh: false,
                })
                .collect(),
        }
    }

    fn state_elements(&self) -> usize {
        self.state.iter().map(|s| s.elements()).sum()
    }

    fn save_state(&self) -> crate::util::json::Json {
        use crate::checkpoint::codec;
        use crate::util::json::Json;
        Json::obj(vec![
            ("t", codec::u64_to_json(self.t)),
            (
                "blocks",
                Json::arr(self.state.iter().map(|s| s.state_to_json()).collect()),
            ),
        ])
    }

    fn load_state(
        &mut self,
        state: &crate::util::json::Json,
        _workers: usize,
    ) -> Result<(), String> {
        use crate::checkpoint::codec;
        let blocks = state.get("blocks").as_arr().ok_or("adamw: missing blocks")?;
        if blocks.len() != self.state.len() {
            return Err(format!(
                "adamw: checkpoint has {} blocks, run has {}",
                blocks.len(),
                self.state.len()
            ));
        }
        for (b, j) in blocks.iter().enumerate() {
            self.state[b].state_from_json(j, &format!("adamw.blocks[{b}]"))?;
        }
        self.t = codec::u64_from_json(state.get("t"), "adamw.t")?;
        Ok(())
    }

    fn seek(&mut self, t: u64) {
        self.t = t;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{CommLedger, Topology};
    use crate::linalg::Matrix;
    use crate::model::ModelSpec;
    use crate::optim::alloc_worker_grads;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn bytes_per_step_equals_param_count() {
        let spec = ModelSpec::proxy(64, 16, 32, 2, 2);
        let blocks = spec.blocks();
        let mut params: Vec<Matrix> = blocks.iter().map(|b| Matrix::zeros(b.rows, b.cols)).collect();
        let mut grads = alloc_worker_grads(&blocks, 3);
        let mut rng = Xoshiro256::new(0);
        for w in grads.iter_mut() {
            for g in w.iter_mut() {
                *g = Matrix::gaussian(g.rows, g.cols, 1.0, &mut rng);
            }
        }
        let mut opt = DenseAdamW::new(&blocks, AdamHyper::default());
        let mut ledger = CommLedger::new();
        let topo = Topology::multi_node(1, 3);
        let mut ctx = StepCtx {
            params: &mut params,
            grads: &mut grads,
            ledger: &mut ledger,
            topo: &topo,
            lr_mult: 1.0,
            exec: &crate::exec::ExecBackend::Sequential,
        };
        opt.step(&mut ctx);
        ledger.end_step();
        assert_eq!(
            ledger.bytes_per_step() as usize,
            spec.param_count() * 4,
            "dense sync = every parameter, every step"
        );
        assert_eq!(opt.state_elements(), 2 * spec.param_count());
    }

    #[test]
    fn identical_grads_all_workers_equals_single_worker_adam() {
        let blocks = ModelSpec::proxy(32, 8, 16, 2, 1).blocks();
        let mut params: Vec<Matrix> =
            blocks.iter().map(|b| Matrix::from_fn(b.rows, b.cols, |i, j| ((i + j) % 3) as f32)).collect();
        let mut rng = Xoshiro256::new(1);
        let shared: Vec<Matrix> = blocks
            .iter()
            .map(|b| Matrix::gaussian(b.rows, b.cols, 1.0, &mut rng))
            .collect();
        let mut grads: Vec<Vec<Matrix>> = (0..4).map(|_| shared.clone()).collect();
        let mut opt = DenseAdamW::new(&blocks, AdamHyper::default());
        let mut ledger = CommLedger::new();
        let topo = Topology::single_node(4);
        let mut reference = params.clone();
        let mut ref_state: Vec<DenseAdamState> = blocks
            .iter()
            .map(|b| DenseAdamState::new(b.rows, b.cols))
            .collect();
        opt.step(&mut StepCtx {
            params: &mut params,
            grads: &mut grads,
            ledger: &mut ledger,
            topo: &topo,
            lr_mult: 1.0,
            exec: &crate::exec::ExecBackend::Sequential,
        });
        for (b, st) in ref_state.iter_mut().enumerate() {
            st.update(&mut reference[b], &shared[b], &AdamHyper::default(), 1.0, 1);
        }
        for (p, r) in params.iter().zip(&reference) {
            assert!(p.dist(r) < 1e-5);
        }
    }
}
