//! DES-LOC (Iacob et al., 2025, PAPERS.md) — desynchronized sync
//! periods *per optimizer state*: parameters all-reduce every `K_p`
//! steps, Adam's first moment every `K_m`, the second moment every
//! `K_v` (typically K_p ≤ K_m ≤ K_v, since m decorrelates faster than
//! v). Between syncs every worker takes purely LOCAL AdamW steps on
//! its own parameter replica and moments — such steps communicate
//! **exactly zero bytes**, which is the contract the generalized
//! `sync_plan(t)` carries: per-block items with `bytes: 0` on local
//! steps, and per-state payload multiples on partial-sync steps.
//!
//! The shared [`super::sync_due`] predicate drives both `step()` and
//! `sync_plan()`, so plan==ledger stays byte-exact from any `seek`
//! (the same discipline `refresh_due` enforces for the refresh
//! schedules — DESIGN.md §13).
//!
//! Shapes here: `ctx.params` holds the *synchronized* parameters the
//! harness evaluates gradients/loss at; they advance only on K_p
//! boundaries (to the across-worker mean of the local replicas).
//! Every block — vectors included — keeps per-worker replicas, so
//! local steps are zero-byte for the whole model, not just matrices.

use super::{sync_due, AdamHyper, DenseAdamState, DistOptimizer, StepCtx, SyncItem, SyncPlan};
use crate::comm::{collective, LayerClass, BYTES_F32};
use crate::linalg::Matrix;
use crate::model::BlockSpec;

struct DlBlock {
    /// Per-worker parameter replicas (the local-update state).
    replicas: Vec<Matrix>,
    /// Per-worker Adam moments, same world-size layout.
    adam: Vec<DenseAdamState>,
}

pub struct DesLoc {
    /// Parameter sync period.
    pub k_p: u64,
    /// First-moment sync period.
    pub k_m: u64,
    /// Second-moment sync period.
    pub k_v: u64,
    hyper: AdamHyper,
    classes: Vec<LayerClass>,
    blocks: Vec<DlBlock>,
    /// Replicas start as copies of `ctx.params` on the first step (the
    /// optimizer never sees parameters at construction time). Persisted
    /// so a resumed run never re-seeds mid-flight.
    init: bool,
    t: u64,
}

impl DesLoc {
    pub fn new(
        blocks: &[BlockSpec],
        hyper: AdamHyper,
        workers: usize,
        k_p: u64,
        k_m: u64,
        k_v: u64,
    ) -> Self {
        let states = blocks
            .iter()
            .map(|b| DlBlock {
                replicas: (0..workers).map(|_| Matrix::zeros(b.rows, b.cols)).collect(),
                adam: (0..workers).map(|_| DenseAdamState::new(b.rows, b.cols)).collect(),
            })
            .collect();
        Self {
            k_p,
            k_m,
            k_v,
            hyper,
            classes: blocks.iter().map(|b| b.class).collect(),
            blocks: states,
            init: false,
            t: 0,
        }
    }
}

impl DistOptimizer for DesLoc {
    fn name(&self) -> &'static str {
        "des-loc"
    }

    fn step(&mut self, ctx: &mut StepCtx) {
        let t = self.t;
        self.t += 1;
        let t1 = self.t;
        if !self.init {
            for (b, blk) in self.blocks.iter_mut().enumerate() {
                for r in blk.replicas.iter_mut() {
                    *r = ctx.params[b].clone();
                }
            }
            self.init = true;
        }
        let (p_due, m_due, v_due) = (
            sync_due(self.k_p, t),
            sync_due(self.k_m, t),
            sync_due(self.k_v, t),
        );
        if p_due || m_due || v_due {
            ctx.tracer().event(
                "state_sync",
                vec![
                    ("p", crate::util::json::Json::Bool(p_due)),
                    ("m", crate::util::json::Json::Bool(m_due)),
                    ("v", crate::util::json::Json::Bool(v_due)),
                ],
            );
        } else {
            ctx.tracer().event("local_step", vec![]);
        }
        for b in 0..ctx.params.len() {
            let blk = &mut self.blocks[b];
            // Local AdamW step: each worker updates its OWN replica with
            // its OWN gradient and moments. No communication.
            for (w, g) in ctx.grads.iter().enumerate() {
                blk.adam[w].update_exec(
                    &mut blk.replicas[w],
                    &g[b],
                    &self.hyper,
                    ctx.lr_mult,
                    t1,
                    ctx.exec,
                );
            }
            let class = self.classes[b];
            if p_due {
                collective::sync_mean(&mut blk.replicas, class, ctx.ledger, ctx.topo, ctx.exec);
                ctx.params[b] = blk.replicas[0].clone();
            }
            if m_due {
                let mut ms: Vec<Matrix> = blk.adam.iter().map(|a| a.m.clone()).collect();
                collective::sync_mean(&mut ms, class, ctx.ledger, ctx.topo, ctx.exec);
                for (a, m) in blk.adam.iter_mut().zip(ms) {
                    a.m = m;
                }
            }
            if v_due {
                let mut vs: Vec<Matrix> = blk.adam.iter().map(|a| a.v.clone()).collect();
                collective::sync_mean(&mut vs, class, ctx.ledger, ctx.topo, ctx.exec);
                for (a, v) in blk.adam.iter_mut().zip(vs) {
                    a.v = v;
                }
            }
        }
    }

    fn sync_plan(&self, t: u64) -> SyncPlan {
        // Same predicate as step(): bytes = numel × (number of optimizer
        // states due at t) per block — exactly zero on local steps.
        let states_due = [self.k_p, self.k_m, self.k_v]
            .iter()
            .filter(|k| sync_due(**k, t))
            .count();
        let items = self
            .blocks
            .iter()
            .enumerate()
            .map(|(b, blk)| SyncItem {
                block: b,
                class: self.classes[b],
                bytes: blk.replicas[0].numel() * BYTES_F32 * states_due,
                fmt: crate::comm::ElemFmt::F32,
                refresh: false,
            })
            .collect();
        SyncPlan { items }
    }

    fn state_elements(&self) -> usize {
        // Per worker: replica + m + v.
        self.blocks
            .iter()
            .map(|blk| 3 * blk.replicas.len() * blk.replicas[0].numel())
            .sum()
    }

    fn save_state(&self) -> crate::util::json::Json {
        use crate::checkpoint::{codec, replicas_to_json};
        use crate::util::json::Json;
        let blocks = self
            .blocks
            .iter()
            .map(|blk| {
                let ms: Vec<Matrix> = blk.adam.iter().map(|a| a.m.clone()).collect();
                let vs: Vec<Matrix> = blk.adam.iter().map(|a| a.v.clone()).collect();
                Json::obj(vec![
                    ("params", replicas_to_json(&blk.replicas)),
                    ("m", replicas_to_json(&ms)),
                    ("v", replicas_to_json(&vs)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("t", codec::u64_to_json(self.t)),
            ("init", codec::u64_to_json(self.init as u64)),
            ("blocks", Json::arr(blocks)),
        ])
    }

    fn load_state(
        &mut self,
        state: &crate::util::json::Json,
        workers: usize,
    ) -> Result<(), String> {
        use crate::checkpoint::{codec, replicas_from_json};
        let blocks = state.get("blocks").as_arr().ok_or("des-loc: missing blocks")?;
        if blocks.len() != self.blocks.len() {
            return Err(format!(
                "des-loc: checkpoint has {} blocks, run has {}",
                blocks.len(),
                self.blocks.len()
            ));
        }
        for (i, j) in blocks.iter().enumerate() {
            let what = format!("des-loc.blocks[{i}]");
            let blk = &mut self.blocks[i];
            let (rows, cols) = (blk.replicas[0].rows, blk.replicas[0].cols);
            blk.replicas =
                replicas_from_json(j.get("params"), rows, cols, workers, &format!("{what}.params"))?;
            let ms = replicas_from_json(j.get("m"), rows, cols, workers, &format!("{what}.m"))?;
            let vs = replicas_from_json(j.get("v"), rows, cols, workers, &format!("{what}.v"))?;
            blk.adam = ms
                .into_iter()
                .zip(vs)
                .map(|(m, v)| {
                    let mut a = DenseAdamState::new(rows, cols);
                    a.m = m;
                    a.v = v;
                    a
                })
                .collect();
        }
        self.init = codec::u64_from_json(state.get("init"), "des-loc.init")? != 0;
        self.t = codec::u64_from_json(state.get("t"), "des-loc.t")?;
        Ok(())
    }

    fn seek(&mut self, t: u64) {
        self.t = t;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{CommLedger, Topology};
    use crate::exec::ExecBackend;
    use crate::util::rng::Xoshiro256;

    fn run_steps(k_p: u64, k_m: u64, k_v: u64, steps: u64) -> (CommLedger, DesLoc, Vec<Matrix>) {
        let blocks = vec![
            BlockSpec {
                name: "w".into(),
                rows: 6,
                cols: 5,
                class: LayerClass::Linear,
            },
            BlockSpec {
                name: "b".into(),
                rows: 1,
                cols: 7,
                class: LayerClass::Vector,
            },
        ];
        let mut opt = DesLoc::new(&blocks, AdamHyper::default(), 2, k_p, k_m, k_v);
        let mut params = vec![Matrix::zeros(6, 5), Matrix::zeros(1, 7)];
        let mut ledger = CommLedger::new();
        let topo = Topology::single_node(2);
        let mut rng = Xoshiro256::new(11);
        for _ in 0..steps {
            let mut grads: Vec<Vec<Matrix>> = (0..2)
                .map(|_| {
                    vec![
                        Matrix::gaussian(6, 5, 1.0, &mut rng),
                        Matrix::gaussian(1, 7, 1.0, &mut rng),
                    ]
                })
                .collect();
            opt.step(&mut StepCtx {
                params: &mut params,
                grads: &mut grads,
                ledger: &mut ledger,
                topo: &topo,
                lr_mult: 1.0,
                exec: &ExecBackend::Sequential,
            });
            ledger.end_step();
        }
        (ledger, opt, params)
    }

    #[test]
    fn local_steps_are_exactly_zero_bytes_and_plan_matches_ledger() {
        let (ledger, opt, _) = run_steps(2, 4, 8, 8);
        let numel = 6 * 5 + 7;
        for t in 0..8u64 {
            let plan = opt.sync_plan(t);
            assert_eq!(plan.total_bytes(), ledger.step(t as usize).total, "step {t}");
            let states_due = [2u64, 4, 8].iter().filter(|k| t % **k == 0).count();
            assert_eq!(plan.total_bytes(), numel * BYTES_F32 * states_due, "step {t}");
        }
        // Odd steps are local: exact zero.
        assert_eq!(ledger.step(1).total, 0);
        assert_eq!(ledger.step(3).total, 0);
        // Step 0 syncs all three states; step 4 params+m; step 2 params only.
        assert_eq!(ledger.step(0).total, numel * BYTES_F32 * 3);
        assert_eq!(ledger.step(4).total, numel * BYTES_F32 * 2);
        assert_eq!(ledger.step(2).total, numel * BYTES_F32);
    }

    #[test]
    fn params_advance_only_on_param_sync_steps() {
        let blocks = vec![BlockSpec {
            name: "w".into(),
            rows: 4,
            cols: 4,
            class: LayerClass::Linear,
        }];
        let mut opt = DesLoc::new(&blocks, AdamHyper::default(), 2, 3, 3, 3);
        let mut params = vec![Matrix::zeros(4, 4)];
        let mut ledger = CommLedger::new();
        let topo = Topology::single_node(2);
        let mut rng = Xoshiro256::new(5);
        let mut snapshots = Vec::new();
        for _ in 0..7 {
            let mut grads: Vec<Vec<Matrix>> = (0..2)
                .map(|_| vec![Matrix::gaussian(4, 4, 1.0, &mut rng)])
                .collect();
            opt.step(&mut StepCtx {
                params: &mut params,
                grads: &mut grads,
                ledger: &mut ledger,
                topo: &topo,
                lr_mult: 1.0,
                exec: &ExecBackend::Sequential,
            });
            ledger.end_step();
            snapshots.push(params[0].clone());
        }
        // Steps 0, 3, 6 sync params; 1, 2, 4, 5 leave them untouched.
        for (t, changed) in [(1, false), (2, false), (3, true), (4, false), (5, false), (6, true)] {
            let same = snapshots[t].data == snapshots[t - 1].data;
            assert_eq!(same, !changed, "step {t}");
        }
    }

    #[test]
    fn checkpoint_roundtrip_preserves_phase_and_replicas() {
        let (_, opt, _) = run_steps(2, 4, 8, 5);
        let state = opt.save_state();
        let blocks = vec![
            BlockSpec {
                name: "w".into(),
                rows: 6,
                cols: 5,
                class: LayerClass::Linear,
            },
            BlockSpec {
                name: "b".into(),
                rows: 1,
                cols: 7,
                class: LayerClass::Vector,
            },
        ];
        let mut fresh = DesLoc::new(&blocks, AdamHyper::default(), 2, 2, 4, 8);
        fresh.load_state(&state, 2).unwrap();
        assert!(fresh.init);
        for (a, b) in opt.blocks.iter().zip(&fresh.blocks) {
            for (x, y) in a.replicas.iter().zip(&b.replicas) {
                assert_eq!(x.data, y.data);
            }
            for (x, y) in a.adam.iter().zip(&b.adam) {
                assert_eq!(x.m.data, y.m.data);
                assert_eq!(x.v.data, y.v.data);
            }
        }
        // Mid-local-phase counter survives: next plans line up.
        for t in 5..13 {
            assert_eq!(opt.sync_plan(t).total_bytes(), fresh.sync_plan(t).total_bytes());
        }
    }
}
