//! LoRDO (Jovanović et al., PAPERS.md) — distributed low-rank
//! optimization with INFREQUENT communication: every worker takes `H`
//! purely local AdamW steps on its own parameter replica, then the
//! round closes with one low-rank synchronization of the parameter
//! *delta* Δᵢ = xᵢ − x (local replica minus the shared anchor), using
//! the same warm-started single power iteration as PowerSGD — but on
//! deltas once per H steps instead of gradients every step:
//!
//! * Pᵢ = Δᵢ Q   (m×r), all-reduced and orthonormalized to P̂,
//! * Q'ᵢ = Δᵢᵀ P̂ (n×r), all-reduced to Q̄ (the next round's warm start),
//! * x ← x + P̂ Q̄ᵀ, and every replica restarts from the new anchor.
//!
//! Vector blocks sync their replicas densely at the same cadence; Adam
//! moments stay local forever (never communicated). The H−1 steps in
//! between are **exactly zero bytes** — the generalized `sync_plan(t)`
//! contract (DESIGN.md §13): per-block items with `bytes: 0`, driven by
//! the same [`super::sync_due`] predicate as `step()` so plan==ledger
//! stays byte-exact from any `seek`. Comm per round is O(r(m+n)),
//! amortized O(r(m+n)/H) per step — below every per-step compressor
//! here once H is large.

use super::{sync_due, AdamHyper, DenseAdamState, DistOptimizer, StepCtx, SyncItem, SyncPlan};
use crate::comm::{collective, fmt as elem, ElemFmt, LayerClass, BYTES_F32};
use crate::linalg::{gemm, orth, Matrix};
use crate::model::BlockSpec;
use crate::util::rng::Xoshiro256;

struct LoCommon {
    /// Per-worker parameter replicas (the local-update state).
    replicas: Vec<Matrix>,
    /// Per-worker Adam moments — local forever, never synchronized.
    adam: Vec<DenseAdamState>,
}

struct LoBlock {
    rank: usize,
    /// Warm-started right factor Q (n×r), carried across rounds.
    q: Matrix,
    /// Per-worker error-feedback residuals for narrow `core_fmt`s, one
    /// pair per worker — the P (m×r) and Q' (n×r) factor syncs quantize
    /// independently (empty for f32; DESIGN.md §14).
    errors_p: Vec<Matrix>,
    errors_q: Vec<Matrix>,
    st: LoCommon,
}

enum BlockState {
    /// Vectors: dense replica mean every H steps.
    Dense(LoCommon),
    /// Matrices: low-rank delta sync every H steps.
    LowRank(LoBlock),
}

pub struct Lordo {
    /// Target rank of the delta factorization (clamped per block).
    pub rank: usize,
    /// Local steps per round; the sync fires when `t % h == 0`.
    pub h: u64,
    hyper: AdamHyper,
    classes: Vec<LayerClass>,
    blocks: Vec<BlockState>,
    /// Element format of the low-rank delta-factor syncs (P and Q');
    /// vector replica means stay f32.
    core_fmt: ElemFmt,
    /// Replicas start as copies of `ctx.params` on the first step;
    /// persisted so a resumed run never re-seeds mid-flight.
    init: bool,
    t: u64,
}

impl Lordo {
    pub fn new(blocks: &[BlockSpec], hyper: AdamHyper, workers: usize, rank: usize, h: u64) -> Self {
        let mut rng = Xoshiro256::new(0x10D0);
        let common = |b: &BlockSpec| LoCommon {
            replicas: (0..workers).map(|_| Matrix::zeros(b.rows, b.cols)).collect(),
            adam: (0..workers).map(|_| DenseAdamState::new(b.rows, b.cols)).collect(),
        };
        let states = blocks
            .iter()
            .map(|b| {
                if b.class == LayerClass::Vector {
                    BlockState::Dense(common(b))
                } else {
                    let r = rank.min(b.rows).min(b.cols);
                    BlockState::LowRank(LoBlock {
                        rank: r,
                        q: orth(&Matrix::gaussian(b.cols, r, 1.0, &mut rng)),
                        errors_p: Vec::new(),
                        errors_q: Vec::new(),
                        st: common(b),
                    })
                }
            })
            .collect();
        Self {
            rank,
            h,
            hyper,
            classes: blocks.iter().map(|b| b.class).collect(),
            blocks: states,
            core_fmt: ElemFmt::F32,
            init: false,
            t: 0,
        }
    }

    /// Quantize the round-boundary delta-factor syncs to `fmt` with
    /// per-worker error feedback (builder; f32 by default).
    pub fn with_core_fmt(mut self, fmt: ElemFmt) -> Self {
        self.core_fmt = fmt;
        self
    }
}

impl DistOptimizer for Lordo {
    fn name(&self) -> &'static str {
        "lordo"
    }

    fn step(&mut self, ctx: &mut StepCtx) {
        let t = self.t;
        self.t += 1;
        let t1 = self.t;
        if !self.init {
            for (b, blk) in self.blocks.iter_mut().enumerate() {
                let st = match blk {
                    BlockState::Dense(st) => st,
                    BlockState::LowRank(lb) => &mut lb.st,
                };
                for r in st.replicas.iter_mut() {
                    *r = ctx.params[b].clone();
                }
            }
            self.init = true;
        }
        let due = sync_due(self.h, t);
        let tracer = ctx.tracer();
        if due {
            tracer.event(
                "delta_sync",
                vec![("h", crate::util::json::Json::num(self.h as f64))],
            );
        } else {
            tracer.event("local_step", vec![]);
        }
        for b in 0..ctx.params.len() {
            let class = self.classes[b];
            let st = match &mut self.blocks[b] {
                BlockState::Dense(st) => st,
                BlockState::LowRank(lb) => &mut lb.st,
            };
            // Local AdamW step: each worker's own replica, gradient,
            // and moments. No communication.
            for (w, g) in ctx.grads.iter().enumerate() {
                st.adam[w].update_exec(
                    &mut st.replicas[w],
                    &g[b],
                    &self.hyper,
                    ctx.lr_mult,
                    t1,
                    ctx.exec,
                );
            }
            if !due {
                continue;
            }
            match &mut self.blocks[b] {
                BlockState::Dense(st) => {
                    collective::sync_mean(&mut st.replicas, class, ctx.ledger, ctx.topo, ctx.exec);
                    ctx.params[b] = st.replicas[0].clone();
                }
                BlockState::LowRank(blk) => {
                    crate::span!(tracer, "factorize");
                    // Δ_i = local replica − shared anchor.
                    let deltas: Vec<Matrix> = blk
                        .st
                        .replicas
                        .iter()
                        .map(|r| {
                            let mut d = r.clone();
                            d.axpy(-1.0, &ctx.params[b]);
                            d
                        })
                        .collect();
                    let fmt = self.core_fmt;
                    // P_i = Δ_i Q (fanned out per worker); EF-quantize
                    // when narrow; all-reduce; orth.
                    let mut ps: Vec<Matrix> = ctx
                        .exec
                        .map_workers(deltas.len(), |i| gemm(&deltas[i], false, &blk.q, false));
                    if fmt != ElemFmt::F32 {
                        let (pr, pc) = (ps[0].rows, ps[0].cols);
                        if blk.errors_p.is_empty() {
                            blk.errors_p =
                                (0..ps.len()).map(|_| Matrix::zeros(pr, pc)).collect();
                        }
                        debug_assert_eq!(blk.errors_p.len(), ps.len(), "EF world mismatch");
                        for (p, e) in ps.iter_mut().zip(blk.errors_p.iter_mut()) {
                            elem::quantize_ef(fmt, &mut p.data, &mut e.data);
                        }
                    }
                    collective::sync_mean_fmt(&mut ps, class, fmt, ctx.ledger, ctx.topo, ctx.exec);
                    let phat = orth(&ps[0]);
                    // Q'_i = Δ_iᵀ P̂ ; all-reduce → next round's warm start.
                    let mut qs: Vec<Matrix> = ctx
                        .exec
                        .map_workers(deltas.len(), |i| gemm(&deltas[i], true, &phat, false));
                    if fmt != ElemFmt::F32 {
                        let (qr, qc) = (qs[0].rows, qs[0].cols);
                        if blk.errors_q.is_empty() {
                            blk.errors_q =
                                (0..qs.len()).map(|_| Matrix::zeros(qr, qc)).collect();
                        }
                        debug_assert_eq!(blk.errors_q.len(), qs.len(), "EF world mismatch");
                        for (q, e) in qs.iter_mut().zip(blk.errors_q.iter_mut()) {
                            elem::quantize_ef(fmt, &mut q.data, &mut e.data);
                        }
                    }
                    collective::sync_mean_fmt(&mut qs, class, fmt, ctx.ledger, ctx.topo, ctx.exec);
                    blk.q = qs.swap_remove(0);
                    // Anchor absorbs the rank-r averaged delta; every
                    // replica restarts the next round from it.
                    let update = gemm(&phat, false, &blk.q, true);
                    ctx.params[b].add_assign(&update);
                    for r in blk.st.replicas.iter_mut() {
                        *r = ctx.params[b].clone();
                    }
                }
            }
        }
    }

    fn sync_plan(&self, t: u64) -> SyncPlan {
        // Same predicate as step(): H−1 of every H steps are exact-zero;
        // the round boundary pays P (m×r) + Q' (n×r) per matrix block
        // and a dense replica mean per vector block.
        let due = sync_due(self.h, t);
        let items = self
            .blocks
            .iter()
            .enumerate()
            .map(|(b, s)| {
                // Matrix factors at the core format's width; dense
                // vector replica means at f32.
                let (bytes, fmt) = if !due {
                    (0, self.core_fmt)
                } else {
                    match s {
                        BlockState::Dense(st) => {
                            (st.replicas[0].numel() * BYTES_F32, ElemFmt::F32)
                        }
                        BlockState::LowRank(blk) => {
                            let elems =
                                blk.st.replicas[0].rows * blk.rank + blk.q.rows * blk.rank;
                            (elems * self.core_fmt.width(), self.core_fmt)
                        }
                    }
                };
                SyncItem {
                    block: b,
                    class: self.classes[b],
                    bytes,
                    fmt,
                    refresh: false,
                }
            })
            .collect();
        SyncPlan { items }
    }

    fn state_elements(&self) -> usize {
        self.blocks
            .iter()
            .map(|s| match s {
                BlockState::Dense(st) => 3 * st.replicas.len() * st.replicas[0].numel(),
                BlockState::LowRank(blk) => {
                    blk.q.numel()
                        + 3 * blk.st.replicas.len() * blk.st.replicas[0].numel()
                        + blk.errors_p.iter().map(|e| e.numel()).sum::<usize>()
                        + blk.errors_q.iter().map(|e| e.numel()).sum::<usize>()
                }
            })
            .sum()
    }

    fn save_state(&self) -> crate::util::json::Json {
        use crate::checkpoint::{codec, replicas_to_json};
        use crate::util::json::Json;
        let common = |st: &LoCommon| {
            let ms: Vec<Matrix> = st.adam.iter().map(|a| a.m.clone()).collect();
            let vs: Vec<Matrix> = st.adam.iter().map(|a| a.v.clone()).collect();
            vec![
                ("params", replicas_to_json(&st.replicas)),
                ("m", replicas_to_json(&ms)),
                ("v", replicas_to_json(&vs)),
            ]
        };
        let blocks = self
            .blocks
            .iter()
            .map(|s| match s {
                BlockState::Dense(st) => {
                    let mut fields = vec![("kind", Json::str("dense"))];
                    fields.extend(common(st));
                    Json::obj(fields)
                }
                BlockState::LowRank(blk) => {
                    let mut fields = vec![
                        ("kind", Json::str("lowrank")),
                        ("q", codec::matrix_to_json(&blk.q)),
                    ];
                    if !blk.errors_p.is_empty() {
                        fields.push(("ef_p", crate::checkpoint::errors_to_json(&blk.errors_p)));
                    }
                    if !blk.errors_q.is_empty() {
                        fields.push(("ef_q", crate::checkpoint::errors_to_json(&blk.errors_q)));
                    }
                    fields.extend(common(&blk.st));
                    Json::obj(fields)
                }
            })
            .collect();
        Json::obj(vec![
            ("t", codec::u64_to_json(self.t)),
            ("init", codec::u64_to_json(self.init as u64)),
            ("blocks", Json::arr(blocks)),
        ])
    }

    fn load_state(
        &mut self,
        state: &crate::util::json::Json,
        workers: usize,
    ) -> Result<(), String> {
        use crate::checkpoint::{codec, replicas_from_json};
        let blocks = state.get("blocks").as_arr().ok_or("lordo: missing blocks")?;
        if blocks.len() != self.blocks.len() {
            return Err(format!(
                "lordo: checkpoint has {} blocks, run has {}",
                blocks.len(),
                self.blocks.len()
            ));
        }
        let load_common =
            |st: &mut LoCommon, j: &crate::util::json::Json, what: &str| -> Result<(), String> {
                let (rows, cols) = (st.replicas[0].rows, st.replicas[0].cols);
                st.replicas =
                    replicas_from_json(j.get("params"), rows, cols, workers, &format!("{what}.params"))?;
                let ms = replicas_from_json(j.get("m"), rows, cols, workers, &format!("{what}.m"))?;
                let vs = replicas_from_json(j.get("v"), rows, cols, workers, &format!("{what}.v"))?;
                st.adam = ms
                    .into_iter()
                    .zip(vs)
                    .map(|(m, v)| {
                        let mut a = DenseAdamState::new(rows, cols);
                        a.m = m;
                        a.v = v;
                        a
                    })
                    .collect();
                Ok(())
            };
        for (i, j) in blocks.iter().enumerate() {
            let what = format!("lordo.blocks[{i}]");
            match (&mut self.blocks[i], j.get("kind").as_str()) {
                (BlockState::Dense(st), Some("dense")) => load_common(st, j, &what)?,
                (BlockState::LowRank(blk), Some("lowrank")) => {
                    blk.q = codec::matrix_from_json_expect(j.get("q"), blk.q.rows, blk.q.cols, &what)?;
                    let null = crate::util::json::Json::Null;
                    blk.errors_p = if j.get("ef_p") == &null {
                        Vec::new()
                    } else {
                        crate::checkpoint::errors_from_json(
                            j.get("ef_p"),
                            blk.st.replicas[0].rows,
                            blk.q.cols,
                            workers,
                            &format!("{what}.ef_p"),
                        )?
                    };
                    blk.errors_q = if j.get("ef_q") == &null {
                        Vec::new()
                    } else {
                        crate::checkpoint::errors_from_json(
                            j.get("ef_q"),
                            blk.q.rows,
                            blk.q.cols,
                            workers,
                            &format!("{what}.ef_q"),
                        )?
                    };
                    load_common(&mut blk.st, j, &what)?;
                }
                (_, kind) => {
                    return Err(format!("{what}: block kind mismatch (checkpoint: {kind:?})"));
                }
            }
        }
        self.init = codec::u64_from_json(state.get("init"), "lordo.init")? != 0;
        self.t = codec::u64_from_json(state.get("t"), "lordo.t")?;
        Ok(())
    }

    fn seek(&mut self, t: u64) {
        self.t = t;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{CommLedger, Topology};
    use crate::exec::ExecBackend;

    fn blocks() -> Vec<BlockSpec> {
        vec![
            BlockSpec {
                name: "w".into(),
                rows: 10,
                cols: 8,
                class: LayerClass::Linear,
            },
            BlockSpec {
                name: "b".into(),
                rows: 1,
                cols: 6,
                class: LayerClass::Vector,
            },
        ]
    }

    fn drive(opt: &mut Lordo, steps: u64, seed: u64) -> (CommLedger, Vec<Matrix>) {
        let mut params = vec![Matrix::zeros(10, 8), Matrix::zeros(1, 6)];
        let mut ledger = CommLedger::new();
        let topo = Topology::single_node(2);
        let mut rng = Xoshiro256::new(seed);
        for _ in 0..steps {
            let mut grads: Vec<Vec<Matrix>> = (0..2)
                .map(|_| {
                    vec![
                        Matrix::gaussian(10, 8, 1.0, &mut rng),
                        Matrix::gaussian(1, 6, 1.0, &mut rng),
                    ]
                })
                .collect();
            opt.step(&mut StepCtx {
                params: &mut params,
                grads: &mut grads,
                ledger: &mut ledger,
                topo: &topo,
                lr_mult: 1.0,
                exec: &ExecBackend::Sequential,
            });
            ledger.end_step();
        }
        (ledger, params)
    }

    #[test]
    fn h_minus_one_of_every_h_steps_are_zero_bytes() {
        let mut opt = Lordo::new(&blocks(), AdamHyper::default(), 2, 4, 3);
        let (ledger, _) = drive(&mut opt, 7, 3);
        // Rank clamps to 4; sync pays (10·4 + 8·4) for the matrix plus
        // 6 dense vector elements.
        let sync_bytes = (10 * 4 + 8 * 4 + 6) * BYTES_F32;
        for t in 0..7u64 {
            let expect = if t % 3 == 0 { sync_bytes } else { 0 };
            assert_eq!(ledger.step(t as usize).total, expect, "step {t}");
            assert_eq!(opt.sync_plan(t).total_bytes(), expect, "plan step {t}");
            assert_eq!(opt.sync_plan(t).items.len(), 2);
        }
    }

    #[test]
    fn anchor_moves_toward_local_progress_each_round() {
        // Constant RANK-1 gradient g = u·vᵀ: Adam's steady direction is
        // sign(g) = sign(u)·sign(v)ᵀ — still rank 1 — so the per-round
        // delta fits entirely inside the rank-4 factorization and the
        // anchor should absorb essentially all synced local progress.
        let specs = vec![BlockSpec {
            name: "w".into(),
            rows: 12,
            cols: 9,
            class: LayerClass::Linear,
        }];
        let mut rng = Xoshiro256::new(7);
        let u = Matrix::gaussian(12, 1, 1.0, &mut rng);
        let v = Matrix::gaussian(9, 1, 1.0, &mut rng);
        let mut g = Matrix::zeros(12, 9);
        for i in 0..12 {
            for j in 0..9 {
                g.data[i * 9 + j] = u.data[i] * v.data[j];
            }
        }
        let mut opt = Lordo::new(&specs, AdamHyper::default(), 1, 4, 3);
        let mut params = vec![Matrix::zeros(12, 9)];
        let mut ledger = CommLedger::new();
        let topo = Topology::single_node(1);
        for _ in 0..12 {
            let mut grads = vec![vec![g.clone()]];
            opt.step(&mut StepCtx {
                params: &mut params,
                grads: &mut grads,
                ledger: &mut ledger,
                topo: &topo,
                lr_mult: 1.0,
                exec: &ExecBackend::Sequential,
            });
            ledger.end_step();
        }
        // Sign descent at lr 1e-2: the syncs at t=0,3,6,9 absorb 10 of
        // the 12 local steps' movement, ≈ −lr·10·sign(g); require most
        // of that magnitude, tightly aligned.
        let mut ideal = Matrix::zeros(12, 9);
        for (i, x) in g.data.iter().enumerate() {
            ideal.data[i] = -0.01 * 10.0 * x.signum();
        }
        let cos = {
            let num: f32 = params[0].data.iter().zip(&ideal.data).map(|(a, b)| a * b).sum();
            num / (params[0].frob_norm() * ideal.frob_norm())
        };
        assert!(cos > 0.95, "cosine {cos}");
        assert!(params[0].frob_norm() > 0.7 * ideal.frob_norm());
    }

    #[test]
    fn checkpoint_roundtrip_mid_round_is_exact() {
        let mut opt = Lordo::new(&blocks(), AdamHyper::default(), 2, 4, 3);
        // 5 steps: cut lands mid-round (two local steps past the t=3 sync).
        let (_, params_a) = drive(&mut opt, 5, 9);
        let state = opt.save_state();
        let mut fresh = Lordo::new(&blocks(), AdamHyper::default(), 2, 4, 3);
        fresh.load_state(&state, 2).unwrap();
        assert!(fresh.init);
        // Continuing both for 4 more steps stays bitwise identical.
        let (_, pa) = drive_from(&mut opt, params_a.clone(), 4, 77);
        let (_, pb) = drive_from(&mut fresh, params_a, 4, 77);
        for (a, b) in pa.iter().zip(&pb) {
            assert_eq!(a.data, b.data);
        }
    }

    /// DESIGN.md §14: with bf16 delta factors, a sync round pays
    /// 2 bytes per P/Q' element (vector replicas stay f32), the analytic
    /// plan equals the metered ledger every step, and a mid-round
    /// checkpoint — EF residuals included — resumes bitwise.
    #[test]
    fn bf16_delta_factors_halve_round_bytes_and_resume_bitwise() {
        let mk = || {
            Lordo::new(&blocks(), AdamHyper::default(), 2, 4, 3).with_core_fmt(ElemFmt::Bf16)
        };
        let mut opt = mk();
        let (ledger, _) = drive(&mut opt, 7, 3);
        // Rank clamps to 4: P is 10×4, Q' is 8×4 at 2 bytes each; the
        // 6-element vector block still syncs dense f32.
        let sync_bytes = (10 * 4 + 8 * 4) * ElemFmt::Bf16.width() + 6 * BYTES_F32;
        for t in 0..7u64 {
            let expect = if t % 3 == 0 { sync_bytes } else { 0 };
            assert_eq!(ledger.step(t as usize).total, expect, "step {t}");
            assert_eq!(opt.sync_plan(t).total_bytes(), expect, "plan step {t}");
        }

        // Mid-round cut: 5 steps past two syncs, EF residuals live.
        let mut opt = mk();
        let (_, params_a) = drive(&mut opt, 5, 9);
        let has_live_ef = match &opt.blocks[0] {
            BlockState::LowRank(blk) => {
                !blk.errors_p.is_empty()
                    && blk
                        .errors_p
                        .iter()
                        .chain(blk.errors_q.iter())
                        .any(|e| e.data.iter().any(|&x| x != 0.0))
            }
            BlockState::Dense(_) => false,
        };
        assert!(has_live_ef, "quantized syncs left no residual: vacuous test");
        let state = opt.save_state();
        let mut fresh = mk();
        fresh.load_state(&state, 2).unwrap();
        let (_, pa) = drive_from(&mut opt, params_a.clone(), 4, 77);
        let (_, pb) = drive_from(&mut fresh, params_a, 4, 77);
        for (a, b) in pa.iter().zip(&pb) {
            assert_eq!(a.data, b.data);
        }
    }

    fn drive_from(
        opt: &mut Lordo,
        mut params: Vec<Matrix>,
        steps: u64,
        seed: u64,
    ) -> (CommLedger, Vec<Matrix>) {
        let mut ledger = CommLedger::new();
        let topo = Topology::single_node(2);
        let mut rng = Xoshiro256::new(seed);
        for _ in 0..steps {
            let mut grads: Vec<Vec<Matrix>> = (0..2)
                .map(|_| {
                    vec![
                        Matrix::gaussian(10, 8, 1.0, &mut rng),
                        Matrix::gaussian(1, 6, 1.0, &mut rng),
                    ]
                })
                .collect();
            opt.step(&mut StepCtx {
                params: &mut params,
                grads: &mut grads,
                ledger: &mut ledger,
                topo: &topo,
                lr_mult: 1.0,
                exec: &ExecBackend::Sequential,
            });
            ledger.end_step();
        }
        (ledger, params)
    }

    use crate::util::rng::Xoshiro256;
}
