//! Distributed optimizers.
//!
//! Every method the paper trains or compares against:
//! * [`adamw::DenseAdamW`] — dense all-reduce baseline (§3.1),
//! * [`onesided::OneSidedAdam`] — GaLore-style one-sided projection
//!   (related work; Fig. 3a / Table 3 "GALORE" rows),
//! * [`tsr::TsrAdam`] — the paper's contribution (Algorithm 1),
//! * [`tsr_sgd::TsrSgd`] — the analyzed momentum variant (Algorithm 2),
//! * [`powersgd::PowerSgd`] — structured-compression baseline
//!   (Vogels et al., related work §A),
//! * [`sign_adam::SignAdam`] — 1-bit sign compression with error feedback
//!   and 0/1-Adam-style variance freezing (Lu et al., 2022),
//! * [`topk_adam::TopKAdam`] — per-block top-k sparse synchronization
//!   with error feedback (SCAPE-style extreme sparsity),
//! * [`des_loc::DesLoc`] — desynchronized per-state sync periods with
//!   purely local steps in between (Iacob et al., 2025),
//! * [`lordo::Lordo`] — H local steps, then one low-rank delta sync
//!   (Jovanović et al.).
//!
//! All optimizers operate on a replicated parameter set plus per-worker
//! gradients, synchronize through the simulated collectives, and meter
//! every communicated tensor through the [`CommLedger`]. The last two
//! are *local-update* methods: most of their steps communicate exactly
//! zero bytes, which `sync_plan(t)` expresses as per-block items with
//! `bytes: 0` (DESIGN.md §13).

pub mod adamw;
pub mod des_loc;
pub mod lordo;
pub mod onesided;
pub mod powersgd;
pub mod schedule;
pub mod sign_adam;
pub mod topk_adam;
pub mod tsr;
pub mod tsr_sgd;

use crate::comm::{CommLedger, ElemFmt, LayerClass, Topology};
use crate::exec::ExecBackend;
use crate::linalg::Matrix;
use crate::model::BlockSpec;
use crate::util::json::Json;

pub use adamw::DenseAdamW;
pub use des_loc::DesLoc;
pub use lordo::Lordo;
pub use onesided::OneSidedAdam;
pub use powersgd::PowerSgd;
pub use schedule::LrSchedule;
pub use sign_adam::SignAdam;
pub use topk_adam::TopKAdam;
pub use tsr::{RefreshKind, TsrAdam, TsrConfig};
pub use tsr_sgd::TsrSgd;

/// AdamW hyper-parameters shared by all Adam-family methods.
#[derive(Clone, Copy, Debug)]
pub struct AdamHyper {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    /// GaLore-style update scale factor α (paper: 0.5 for 60M, 0.75 else).
    pub scale: f32,
}

impl Default for AdamHyper {
    fn default() -> Self {
        Self {
            lr: 1e-2,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.0,
            scale: 1.0,
        }
    }
}

/// Everything an optimizer sees at one step.
pub struct StepCtx<'a> {
    /// Replicated parameters, one matrix per block.
    pub params: &'a mut [Matrix],
    /// Per-worker local gradients: `grads[worker][block]`.
    pub grads: &'a mut [Vec<Matrix>],
    pub ledger: &'a mut CommLedger,
    pub topo: &'a Topology,
    /// Learning-rate multiplier from the schedule (warmup/cosine).
    pub lr_mult: f32,
    /// Execution backend driving collectives and hot-path parallelism
    /// (DESIGN.md §8). Both backends are bitwise-identical.
    pub exec: &'a ExecBackend,
}

impl StepCtx<'_> {
    /// The trace handle riding on the ledger (disabled unless the run
    /// attached one, DESIGN.md §16). Cloned so optimizers can hold it
    /// across mutable ledger use; clones share the record buffer.
    pub fn tracer(&self) -> crate::obs::Tracer {
        self.ledger.tracer().clone()
    }
}

/// One block's contribution to step-`t` gradient synchronization.
#[derive(Clone, Debug)]
pub struct SyncItem {
    /// Block index in forward (model) order.
    pub block: usize,
    pub class: LayerClass,
    /// Payload bytes the method synchronizes for this block at step t —
    /// already format-true (`numel × fmt.width()` for the steady
    /// payload; refresh extras are priced at their own widths).
    pub bytes: usize,
    /// Element format of the block's *steady* payload (DESIGN.md §14).
    /// Refresh-step items still describe their sketch extras in f32;
    /// `bytes` is authoritative, `fmt` annotates the steady encoding.
    pub fmt: ElemFmt,
    /// True when this step carries the block's refresh extra (sketches,
    /// dense SVD gradient, variance re-estimate, …).
    pub refresh: bool,
}

/// A method's payload schedule for one step: what `step()` will meter,
/// predicted without running it. The discrete-event engine (`sim/`)
/// buckets and times these payloads; `tests/sim_engine.rs` asserts the
/// schedule matches the metered ledger byte-for-byte for every method.
#[derive(Clone, Debug, Default)]
pub struct SyncPlan {
    pub items: Vec<SyncItem>,
}

impl SyncPlan {
    pub fn total_bytes(&self) -> usize {
        self.items.iter().map(|i| i.bytes).sum()
    }

    pub fn has_refresh(&self) -> bool {
        self.items.iter().any(|i| i.refresh)
    }
}

/// THE refresh predicate, shared by `step()` and `sync_plan()` of every
/// refresh-based method so the executed schedule and the predicted
/// schedule cannot diverge. (They did, once: `sync_plan` checked only
/// the cadence while `step()` also refreshed uninitialized bases, so
/// predicted bytes went wrong whenever the first executed step wasn't a
/// refresh boundary — exactly what a resume or a mid-period prediction
/// creates.)
///
/// A block refreshes at step `t` iff:
/// * the cadence hits (`t % every == 0`), or
/// * `t` is the step that first built the block's state
///   (`init_step == Some(t)`), or
/// * the state does not exist yet and `t` is the next step this
///   optimizer will execute (`next_step`) — the mid-period-start case.
pub fn refresh_due(init_step: Option<u64>, next_step: u64, every: u64, t: u64) -> bool {
    t % every.max(1) == 0
        || init_step == Some(t)
        || (init_step.is_none() && t == next_step)
}

/// THE sync-cadence predicate for local-update methods ([`DesLoc`],
/// [`Lordo`]), shared by `step()` and `sync_plan()` for the same reason
/// [`refresh_due`] is shared by the refresh-based methods: one
/// predicate, two call sites, zero room for the executed and predicted
/// schedules to diverge. Pure in `t` (no initialization bookkeeping —
/// local-update state needs no mid-period first-step special case, the
/// cadence itself fires at `t == 0`), so any `seek` lands on the exact
/// same schedule the uninterrupted run followed.
pub fn sync_due(every: u64, t: u64) -> bool {
    t % every.max(1) == 0
}

pub trait DistOptimizer {
    fn name(&self) -> &'static str;

    /// Apply one optimizer step. Must:
    /// 1. synchronize whatever S_t the method defines (metering bytes),
    /// 2. update any internal state (moments, bases),
    /// 3. write the new parameters into `ctx.params`.
    fn step(&mut self, ctx: &mut StepCtx);

    /// Per-block payload schedule for (0-indexed) step `t` of a run that
    /// starts from this optimizer's initial state. Deterministic in `t`:
    /// refresh cadences are fixed by configuration, so the schedule can
    /// be queried without executing steps — this is what the
    /// discrete-event step-time simulator consumes.
    fn sync_plan(&self, t: u64) -> SyncPlan;

    /// Total optimizer-state elements currently held (memory accounting).
    fn state_elements(&self) -> usize;

    /// Serialize the full step-dependent state — step counter, moments,
    /// bases, error-feedback buffers, refresh bookkeeping — into a JSON
    /// tree of bit-exact payloads (`checkpoint::codec`). Together with
    /// the parameters, the source RNG position, and the ledger this is
    /// sufficient to resume a run bitwise-identically (DESIGN.md §9).
    fn save_state(&self) -> Json;

    /// Restore state produced by [`Self::save_state`] into a freshly
    /// constructed optimizer of the same configuration. `workers` is
    /// the resuming world size: per-worker error-feedback buffers
    /// restore bit-exactly when it matches the saved world size and
    /// are re-sharded from their canonical mean otherwise (elastic
    /// restart, `checkpoint::errors_from_json`). Errors on structural
    /// mismatch (method, block count, shapes).
    fn load_state(&mut self, state: &Json, workers: usize) -> Result<(), String>;

    /// Position the step counter at `t` without executing steps: the
    /// next `step()` call runs as step `t` (bias correction, refresh
    /// cadence, and `sync_plan` all see the mid-period start). Used by
    /// weights-only resumes; `load_state` restores the counter itself.
    fn seek(&mut self, t: u64);
}

/// Dense per-block Adam moments — used directly by [`DenseAdamW`] and by
/// every low-rank method for its Vector-class (bias/norm) blocks.
#[derive(Clone, Debug)]
pub struct DenseAdamState {
    pub m: Matrix,
    pub v: Matrix,
}

impl DenseAdamState {
    pub fn new(rows: usize, cols: usize) -> Self {
        Self {
            m: Matrix::zeros(rows, cols),
            v: Matrix::zeros(rows, cols),
        }
    }

    pub fn elements(&self) -> usize {
        self.m.numel() + self.v.numel()
    }

    /// Checkpoint payload: both moment matrices, bit-exact.
    pub fn state_to_json(&self) -> Json {
        use crate::checkpoint::codec;
        Json::obj(vec![
            ("m", codec::matrix_to_json(&self.m)),
            ("v", codec::matrix_to_json(&self.v)),
        ])
    }

    /// Restore moments saved by [`Self::state_to_json`], enforcing the
    /// block shape this optimizer allocated.
    pub fn state_from_json(&mut self, j: &Json, what: &str) -> Result<(), String> {
        use crate::checkpoint::codec;
        let (rows, cols) = (self.m.rows, self.m.cols);
        self.m = codec::matrix_from_json_expect(j.get("m"), rows, cols, &format!("{what}.m"))?;
        self.v = codec::matrix_from_json_expect(j.get("v"), rows, cols, &format!("{what}.v"))?;
        Ok(())
    }

    /// Standard AdamW update on `w` given the aggregated gradient `g`.
    /// `t` is 1-indexed for bias correction. Equivalent to
    /// [`Self::update_exec`] on the sequential backend.
    pub fn update(&mut self, w: &mut Matrix, g: &Matrix, h: &AdamHyper, lr_mult: f32, t: u64) {
        self.update_exec(w, g, h, lr_mult, t, &ExecBackend::Sequential);
    }

    /// AdamW update, sharded over `exec.threads()` OS threads on the
    /// threaded backend. The update is elementwise, so shard boundaries
    /// cannot change any result bit — the dense-Adam hot path simply
    /// runs on all cores instead of one.
    pub fn update_exec(
        &mut self,
        w: &mut Matrix,
        g: &Matrix,
        h: &AdamHyper,
        lr_mult: f32,
        t: u64,
        exec: &ExecBackend,
    ) {
        let len = w.data.len();
        let bc1 = 1.0 - h.beta1.powi(t as i32);
        let bc2 = 1.0 - h.beta2.powi(t as i32);
        let lr = h.lr * lr_mult;
        // Below ~64 KiB of elements the spawn cost dominates any win.
        const MIN_PAR_ELEMS: usize = 16 * 1024;
        let shards = if len < MIN_PAR_ELEMS { 1 } else { exec.threads() };
        if shards <= 1 {
            adam_update_slice(
                &mut self.m.data,
                &mut self.v.data,
                &mut w.data,
                &g.data,
                h,
                lr,
                bc1,
                bc2,
            );
            return;
        }
        let bounds = crate::exec::shard_bounds(len, shards);
        std::thread::scope(|scope| {
            let mut m_rest: &mut [f32] = &mut self.m.data;
            let mut v_rest: &mut [f32] = &mut self.v.data;
            let mut w_rest: &mut [f32] = &mut w.data;
            let mut g_rest: &[f32] = &g.data;
            for c in 0..shards {
                let cut = bounds[c + 1] - bounds[c];
                let (ms, mr) = std::mem::take(&mut m_rest).split_at_mut(cut);
                let (vs, vr) = std::mem::take(&mut v_rest).split_at_mut(cut);
                let (ws, wr) = std::mem::take(&mut w_rest).split_at_mut(cut);
                let (gs, gr) = g_rest.split_at(cut);
                m_rest = mr;
                v_rest = vr;
                w_rest = wr;
                g_rest = gr;
                scope.spawn(move || adam_update_slice(ms, vs, ws, gs, h, lr, bc1, bc2));
            }
        });
    }
}

/// The elementwise AdamW kernel both backends share: identical math on
/// any contiguous shard of (m, v, w, g).
fn adam_update_slice(
    m: &mut [f32],
    v: &mut [f32],
    w: &mut [f32],
    g: &[f32],
    h: &AdamHyper,
    lr: f32,
    bc1: f32,
    bc2: f32,
) {
    let b1 = h.beta1;
    let b2 = h.beta2;
    for i in 0..w.len() {
        let gi = g[i];
        m[i] = b1 * m[i] + (1.0 - b1) * gi;
        v[i] = b2 * v[i] + (1.0 - b2) * gi * gi;
        let mhat = m[i] / bc1;
        let vhat = v[i] / bc2;
        let upd = mhat / (vhat.sqrt() + h.eps);
        w[i] -= lr * (h.scale * upd + h.weight_decay * w[i]);
    }
}

/// Build per-block gradient buffers shaped like the model, one per worker.
pub fn alloc_worker_grads(blocks: &[BlockSpec], workers: usize) -> Vec<Vec<Matrix>> {
    (0..workers)
        .map(|_| {
            blocks
                .iter()
                .map(|b| Matrix::zeros(b.rows, b.cols))
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_adam_moves_against_gradient() {
        let mut st = DenseAdamState::new(1, 3);
        let mut w = Matrix::from_vec(1, 3, vec![1.0, -1.0, 0.5]);
        let g = Matrix::from_vec(1, 3, vec![1.0, -1.0, 0.0]);
        let h = AdamHyper {
            lr: 0.1,
            ..Default::default()
        };
        let w0 = w.clone();
        st.update(&mut w, &g, &h, 1.0, 1);
        assert!(w.data[0] < w0.data[0]);
        assert!(w.data[1] > w0.data[1]);
        assert!((w.data[2] - w0.data[2]).abs() < 1e-6);
    }

    #[test]
    fn weight_decay_shrinks_params_without_gradient() {
        let mut st = DenseAdamState::new(1, 1);
        let mut w = Matrix::from_vec(1, 1, vec![2.0]);
        let g = Matrix::zeros(1, 1);
        let h = AdamHyper {
            lr: 0.1,
            weight_decay: 0.1,
            ..Default::default()
        };
        st.update(&mut w, &g, &h, 1.0, 1);
        assert!(w.data[0] < 2.0 && w.data[0] > 1.9);
    }

    #[test]
    fn sharded_update_is_bitwise_identical_to_serial() {
        use crate::util::rng::Xoshiro256;
        // Large enough to cross the parallel threshold.
        let n = 40_000;
        let mut rng = Xoshiro256::new(12);
        let g = Matrix::gaussian(1, n, 1.0, &mut rng);
        let w0 = Matrix::gaussian(1, n, 1.0, &mut rng);
        let h = AdamHyper {
            lr: 0.01,
            weight_decay: 0.02,
            ..Default::default()
        };
        let mut st_a = DenseAdamState::new(1, n);
        let mut st_b = st_a.clone();
        let mut w_a = w0.clone();
        let mut w_b = w0;
        for t in 1..=3u64 {
            st_a.update_exec(&mut w_a, &g, &h, 0.7, t, &ExecBackend::Sequential);
            st_b.update_exec(&mut w_b, &g, &h, 0.7, t, &ExecBackend::Threaded { threads: 5 });
        }
        for i in 0..n {
            assert_eq!(w_a.data[i].to_bits(), w_b.data[i].to_bits(), "w[{i}]");
            assert_eq!(st_a.m.data[i].to_bits(), st_b.m.data[i].to_bits(), "m[{i}]");
            assert_eq!(st_a.v.data[i].to_bits(), st_b.v.data[i].to_bits(), "v[{i}]");
        }
    }

    #[test]
    fn refresh_due_models_initialization_and_cadence() {
        // Fresh state starting at step 0: cadence only (0 hits it).
        assert!(refresh_due(None, 0, 5, 0));
        assert!(!refresh_due(None, 0, 5, 1));
        assert!(refresh_due(None, 0, 5, 5));
        // Fresh state starting MID-PERIOD (the resume / mid-period
        // prediction case): the first executed step refreshes even off
        // the cadence — this is the predicate sync_plan used to get
        // wrong.
        assert!(refresh_due(None, 7, 5, 7));
        assert!(!refresh_due(None, 7, 5, 8));
        assert!(refresh_due(None, 7, 5, 10));
        // Initialized at a non-boundary step: that step reports its
        // refresh post-hoc; afterwards, cadence only.
        assert!(refresh_due(Some(7), 9, 5, 7));
        assert!(!refresh_due(Some(7), 9, 5, 9));
        assert!(refresh_due(Some(7), 9, 5, 10));
        // Degenerate every=0 must not divide by zero.
        assert!(refresh_due(None, 0, 0, 3));
    }

    #[test]
    fn sync_due_is_pure_cadence_from_any_seek() {
        // Fires at t=0 (every run's first step syncs) and on multiples.
        assert!(sync_due(4, 0));
        assert!(!sync_due(4, 1));
        assert!(!sync_due(4, 3));
        assert!(sync_due(4, 4));
        assert!(sync_due(4, 8));
        // every=1 → every step communicates (dense-cadence degenerate).
        assert!(sync_due(1, 5));
        // every=0 must not divide by zero.
        assert!(sync_due(0, 3));
        // Purity in t: seeking to any step gives the same answer the
        // uninterrupted schedule had — no init_step/next_step state.
        for t in 0..20 {
            assert_eq!(sync_due(6, t), t % 6 == 0);
        }
    }

    #[test]
    fn bias_correction_first_step_magnitude() {
        // First Adam step magnitude ≈ lr for a unit gradient.
        let mut st = DenseAdamState::new(1, 1);
        let mut w = Matrix::from_vec(1, 1, vec![0.0]);
        let g = Matrix::from_vec(1, 1, vec![1.0]);
        let h = AdamHyper {
            lr: 0.01,
            ..Default::default()
        };
        st.update(&mut w, &g, &h, 1.0, 1);
        assert!((w.data[0] + 0.01).abs() < 1e-4, "{}", w.data[0]);
    }
}
