//! One-sided low-rank Adam (GaLore-style) — the O(rn) baseline.
//!
//! Projects each matrix gradient onto a single learned basis on its
//! *shorter* dimension: for m ≤ n, C_i = Uᵀ G_i ∈ R^{r×n} (else G_i V).
//! Synchronizes the projected gradient (O(rn) — still scaling with a
//! matrix dimension, Table 1 row 3), keeps Adam moments in the projected
//! space, and refreshes U by SVD of the *densely synchronized* average
//! gradient every K steps — the refresh-peak behaviour the paper
//! contrasts against (Fig. 2b). Embeddings stay dense, as in GaLore.

use super::{refresh_due, AdamHyper, DenseAdamState, DistOptimizer, StepCtx, SyncItem, SyncPlan};
use crate::comm::{collective, fmt as elem, ElemFmt, LayerClass};
use crate::linalg::{gemm, rsvd, svd_truncated, Matrix};
use crate::model::BlockSpec;
use crate::util::rng::Xoshiro256;

/// Refresh flavour for the ablation in Fig. 3(b): exact SVD on the dense
/// gradient vs randomized SVD on the dense gradient (GaLore-2-style).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OneSidedRefresh {
    ExactSvd,
    RandomizedSvd,
}

enum BlockState {
    Dense(DenseAdamState),
    Projected(ProjBlock),
}

struct ProjBlock {
    rank: usize,
    refresh_every: usize,
    /// True if we project the row space (m ≤ n): C = Uᵀ G; else C = G V.
    left: bool,
    basis: Matrix,
    m: Matrix,
    v: Matrix,
    /// Per-worker error-feedback residuals for narrow `core_fmt`s on
    /// the steady projected payload (empty for f32; DESIGN.md §14).
    errors: Vec<Matrix>,
    /// Step that first built the basis ([`refresh_due`] bookkeeping).
    init_step: Option<u64>,
}

pub struct OneSidedAdam {
    hyper: AdamHyper,
    refresh: OneSidedRefresh,
    classes: Vec<LayerClass>,
    blocks: Vec<BlockState>,
    /// Element format of the steady projected-factor sync; the dense
    /// refresh gradient stays f32 (it feeds the SVD that sets basis
    /// quality — same rationale as TSR's f32 sketches).
    core_fmt: ElemFmt,
    seed: u64,
    t: u64,
}

impl OneSidedAdam {
    pub fn new(
        blocks: &[BlockSpec],
        hyper: AdamHyper,
        rank: usize,
        refresh_every: usize,
        refresh: OneSidedRefresh,
    ) -> Self {
        let states = blocks
            .iter()
            .map(|b| {
                // GaLore: embeddings and vectors stay dense.
                if b.class != LayerClass::Linear {
                    BlockState::Dense(DenseAdamState::new(b.rows, b.cols))
                } else {
                    let left = b.rows <= b.cols;
                    let r = rank.min(b.rows).min(b.cols);
                    let (pr, pc) = if left { (r, b.cols) } else { (b.rows, r) };
                    BlockState::Projected(ProjBlock {
                        rank: r,
                        refresh_every: refresh_every.max(1),
                        left,
                        basis: Matrix::zeros(if left { b.rows } else { b.cols }, r),
                        m: Matrix::zeros(pr, pc),
                        v: Matrix::zeros(pr, pc),
                        errors: Vec::new(),
                        init_step: None,
                    })
                }
            })
            .collect();
        Self {
            hyper,
            refresh,
            classes: blocks.iter().map(|b| b.class).collect(),
            blocks: states,
            core_fmt: ElemFmt::F32,
            seed: 0x6A10_4E,
            t: 0,
        }
    }

    /// Quantize the steady projected sync to `fmt` with per-worker
    /// error feedback (builder — the constructor signature is shared by
    /// many call sites and stays f32-default).
    pub fn with_core_fmt(mut self, fmt: ElemFmt) -> Self {
        self.core_fmt = fmt;
        self
    }
}

impl DistOptimizer for OneSidedAdam {
    fn name(&self) -> &'static str {
        "onesided-adam"
    }

    fn step(&mut self, ctx: &mut StepCtx) {
        let t = self.t;
        self.t += 1;
        let t1 = self.t;
        let h = self.hyper;

        for b in 0..ctx.params.len() {
            let class = self.classes[b];
            match &mut self.blocks[b] {
                BlockState::Dense(st) => {
                    let mut per_worker: Vec<_> =
                        ctx.grads.iter().map(|g| g[b].clone()).collect();
                    collective::sync_mean(&mut per_worker, class, ctx.ledger, ctx.topo, ctx.exec);
                    st.update_exec(
                        &mut ctx.params[b],
                        &per_worker[0],
                        &h,
                        ctx.lr_mult,
                        t1,
                        ctx.exec,
                    );
                }
                BlockState::Projected(blk) => {
                    // Shared predicate with sync_plan ([`refresh_due`]).
                    if refresh_due(blk.init_step, t, blk.refresh_every as u64, t) {
                        ctx.tracer().event(
                            "refresh",
                            vec![
                                ("block", crate::util::json::Json::num(b as f64)),
                                (
                                    "kind",
                                    crate::util::json::Json::str(match self.refresh {
                                        OneSidedRefresh::ExactSvd => "exact",
                                        OneSidedRefresh::RandomizedSvd => "rsvd",
                                    }),
                                ),
                            ],
                        );
                        // GaLore refresh: dense all-reduce, then local SVD
                        // → this is what spikes PeakBytes.
                        let mut dense: Vec<Matrix> =
                            ctx.grads.iter().map(|g| g[b].clone()).collect();
                        collective::sync_mean(&mut dense, class, ctx.ledger, ctx.topo, ctx.exec);
                        ctx.ledger.mark_refresh();
                        let gbar = &dense[0];
                        let factors = match self.refresh {
                            OneSidedRefresh::ExactSvd => svd_truncated(gbar, blk.rank),
                            OneSidedRefresh::RandomizedSvd => {
                                let mut rng =
                                    Xoshiro256::for_stream(self.seed, (b as u64) << 32 | t);
                                rsvd(gbar, blk.rank, 8, 1, &mut rng)
                            }
                        };
                        blk.basis = if blk.left { factors.u } else { factors.v };
                        if blk.init_step.is_none() {
                            blk.init_step = Some(t);
                        }
                    }

                    // Project per worker (fanned out over threads), then
                    // all-reduce the O(rn) object — error-feedback
                    // quantized when the steady format is narrow.
                    let grads_ref = &*ctx.grads;
                    let mut proj: Vec<Matrix> = ctx.exec.map_workers(grads_ref.len(), |i| {
                        if blk.left {
                            gemm(&blk.basis, true, &grads_ref[i][b], false) // r×n
                        } else {
                            gemm(&grads_ref[i][b], false, &blk.basis, false) // m×r
                        }
                    });
                    let fmt = self.core_fmt;
                    if fmt != ElemFmt::F32 {
                        let (pr, pc) = (blk.m.rows, blk.m.cols);
                        if blk.errors.is_empty() {
                            blk.errors =
                                (0..proj.len()).map(|_| Matrix::zeros(pr, pc)).collect();
                        }
                        debug_assert_eq!(blk.errors.len(), proj.len(), "EF world mismatch");
                        for (p, e) in proj.iter_mut().zip(blk.errors.iter_mut()) {
                            elem::quantize_ef(fmt, &mut p.data, &mut e.data);
                        }
                    }
                    collective::sync_mean_fmt(&mut proj, class, fmt, ctx.ledger, ctx.topo, ctx.exec);
                    let cbar = &proj[0];

                    // Adam moments in projected space.
                    let b1 = h.beta1;
                    let b2 = h.beta2;
                    let bc1 = 1.0 - b1.powi(t1 as i32);
                    let bc2 = 1.0 - b2.powi(t1 as i32);
                    let mut d = Matrix::zeros(cbar.rows, cbar.cols);
                    for i in 0..cbar.data.len() {
                        let c = cbar.data[i];
                        blk.m.data[i] = b1 * blk.m.data[i] + (1.0 - b1) * c;
                        blk.v.data[i] = b2 * blk.v.data[i] + (1.0 - b2) * c * c;
                        let mhat = blk.m.data[i] / bc1;
                        let vhat = blk.v.data[i] / bc2;
                        d.data[i] = mhat / (vhat.sqrt() + h.eps);
                    }

                    // Lift back: ΔW = U D (left) or D Vᵀ (right).
                    let dw = if blk.left {
                        gemm(&blk.basis, false, &d, false)
                    } else {
                        gemm(&d, false, &blk.basis, true)
                    };
                    let lr = h.lr * ctx.lr_mult;
                    let w = &mut ctx.params[b];
                    for i in 0..w.data.len() {
                        w.data[i] -= lr * (h.scale * dw.data[i] + h.weight_decay * w.data[i]);
                    }
                }
            }
        }
    }

    fn sync_plan(&self, t: u64) -> SyncPlan {
        let items = self
            .blocks
            .iter()
            .enumerate()
            .map(|(b, s)| match s {
                BlockState::Dense(st) => SyncItem {
                    block: b,
                    class: self.classes[b],
                    bytes: st.m.numel() * crate::comm::BYTES_F32,
                    fmt: ElemFmt::F32,
                    refresh: false,
                },
                BlockState::Projected(blk) => {
                    let refresh = refresh_due(blk.init_step, self.t, blk.refresh_every as u64, t);
                    // Projected object every step (at the steady core
                    // format's width); full dense f32 gradient on
                    // refresh steps (the GaLore peak-byte event).
                    let dense = if blk.left {
                        blk.basis.rows * blk.m.cols
                    } else {
                        blk.m.rows * blk.basis.rows
                    };
                    let extra = if refresh { dense } else { 0 };
                    SyncItem {
                        block: b,
                        class: self.classes[b],
                        bytes: blk.m.numel() * self.core_fmt.width()
                            + extra * crate::comm::BYTES_F32,
                        fmt: self.core_fmt,
                        refresh,
                    }
                }
            })
            .collect();
        SyncPlan { items }
    }

    fn state_elements(&self) -> usize {
        self.blocks
            .iter()
            .map(|s| match s {
                BlockState::Dense(st) => st.elements(),
                BlockState::Projected(b) => {
                    b.basis.numel()
                        + b.m.numel()
                        + b.v.numel()
                        + b.errors.iter().map(|e| e.numel()).sum::<usize>()
                }
            })
            .sum()
    }

    fn save_state(&self) -> crate::util::json::Json {
        use crate::checkpoint::codec;
        use crate::util::json::Json;
        let blocks = self
            .blocks
            .iter()
            .map(|s| match s {
                BlockState::Dense(st) => Json::obj(vec![
                    ("kind", Json::str("dense")),
                    ("adam", st.state_to_json()),
                ]),
                BlockState::Projected(b) => {
                    let mut fields = vec![
                        ("kind", Json::str("projected")),
                        ("basis", codec::matrix_to_json(&b.basis)),
                        ("m", codec::matrix_to_json(&b.m)),
                        ("v", codec::matrix_to_json(&b.v)),
                        ("init_step", codec::opt_u64_to_json(b.init_step)),
                    ];
                    if !b.errors.is_empty() {
                        fields.push(("ef", crate::checkpoint::errors_to_json(&b.errors)));
                    }
                    Json::obj(fields)
                }
            })
            .collect();
        Json::obj(vec![
            ("t", codec::u64_to_json(self.t)),
            ("blocks", Json::arr(blocks)),
        ])
    }

    fn load_state(
        &mut self,
        state: &crate::util::json::Json,
        workers: usize,
    ) -> Result<(), String> {
        use crate::checkpoint::codec;
        let blocks = state.get("blocks").as_arr().ok_or("onesided: missing blocks")?;
        if blocks.len() != self.blocks.len() {
            return Err(format!(
                "onesided: checkpoint has {} blocks, run has {}",
                blocks.len(),
                self.blocks.len()
            ));
        }
        for (i, j) in blocks.iter().enumerate() {
            let what = format!("onesided.blocks[{i}]");
            match (&mut self.blocks[i], j.get("kind").as_str()) {
                (BlockState::Dense(st), Some("dense")) => {
                    st.state_from_json(j.get("adam"), &what)?;
                }
                (BlockState::Projected(b), Some("projected")) => {
                    b.basis = codec::matrix_from_json_expect(
                        j.get("basis"),
                        b.basis.rows,
                        b.basis.cols,
                        &what,
                    )?;
                    b.m = codec::matrix_from_json_expect(j.get("m"), b.m.rows, b.m.cols, &what)?;
                    b.v = codec::matrix_from_json_expect(j.get("v"), b.v.rows, b.v.cols, &what)?;
                    b.init_step = codec::opt_u64_from_json(
                        codec::require(j, "init_step", &what)?,
                        &format!("{what}.init_step"),
                    )?;
                    b.errors = if j.get("ef") == &crate::util::json::Json::Null {
                        Vec::new()
                    } else {
                        crate::checkpoint::errors_from_json(
                            j.get("ef"),
                            b.m.rows,
                            b.m.cols,
                            workers,
                            &format!("{what}.ef"),
                        )?
                    };
                }
                (_, kind) => {
                    return Err(format!("{what}: block kind mismatch (checkpoint: {kind:?})"));
                }
            }
        }
        self.t = codec::u64_from_json(state.get("t"), "onesided.t")?;
        Ok(())
    }

    fn seek(&mut self, t: u64) {
        self.t = t;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{CommLedger, Topology};
    use crate::model::ModelSpec;
    use crate::optim::alloc_worker_grads;

    #[test]
    fn steady_state_syncs_o_rn_not_mn() {
        let blocks = vec![BlockSpec {
            name: "w".into(),
            rows: 64,
            cols: 96,
            class: LayerClass::Linear,
        }];
        let mut params = vec![Matrix::zeros(64, 96)];
        let mut opt = OneSidedAdam::new(
            &blocks,
            AdamHyper::default(),
            8,
            100,
            OneSidedRefresh::ExactSvd,
        );
        let mut ledger = CommLedger::new();
        let topo = Topology::single_node(2);
        let mut rng = Xoshiro256::new(3);
        for _ in 0..3 {
            let mut grads: Vec<Vec<Matrix>> = (0..2)
                .map(|_| vec![Matrix::gaussian(64, 96, 1.0, &mut rng)])
                .collect();
            opt.step(&mut StepCtx {
                params: &mut params,
                grads: &mut grads,
                ledger: &mut ledger,
                topo: &topo,
                lr_mult: 1.0,
                exec: &crate::exec::ExecBackend::Sequential,
            });
            ledger.end_step();
        }
        // step 0: dense refresh (mn) + projected (rn) — project left (m<n).
        assert_eq!(ledger.step(0).total, (64 * 96 + 8 * 96) * 4);
        // steps 1–2: projected only.
        assert_eq!(ledger.step(1).total, 8 * 96 * 4);
        assert_eq!(ledger.step(2).total, 8 * 96 * 4);
        // Table 2 one-sided state: mr + 2nr with m the short side.
        assert_eq!(opt.state_elements(), 64 * 8 + 2 * 96 * 8);
    }

    /// bf16 steady projection: metered bytes halve exactly, the dense
    /// refresh gradient stays full f32, and `sync_plan` prices both.
    #[test]
    fn bf16_steady_projection_halves_metered_bytes() {
        let blocks = vec![BlockSpec {
            name: "w".into(),
            rows: 64,
            cols: 96,
            class: LayerClass::Linear,
        }];
        let mut params = vec![Matrix::zeros(64, 96)];
        let mut opt = OneSidedAdam::new(
            &blocks,
            AdamHyper::default(),
            8,
            100,
            OneSidedRefresh::ExactSvd,
        )
        .with_core_fmt(ElemFmt::Bf16);
        let mut ledger = CommLedger::new();
        let topo = Topology::single_node(2);
        let mut rng = Xoshiro256::new(3);
        for t in 0..3u64 {
            let planned = opt.sync_plan(t).total_bytes();
            let mut grads: Vec<Vec<Matrix>> = (0..2)
                .map(|_| vec![Matrix::gaussian(64, 96, 1.0, &mut rng)])
                .collect();
            opt.step(&mut StepCtx {
                params: &mut params,
                grads: &mut grads,
                ledger: &mut ledger,
                topo: &topo,
                lr_mult: 1.0,
                exec: &crate::exec::ExecBackend::Sequential,
            });
            ledger.end_step();
            assert_eq!(ledger.step(t as usize).total, planned, "plan vs meter");
        }
        // step 0: dense f32 refresh (mn·4) + bf16 projected (rn·2).
        assert_eq!(ledger.step(0).total, 64 * 96 * 4 + 8 * 96 * 2);
        // steps 1–2: the bf16 projected object only — exactly half f32.
        assert_eq!(ledger.step(1).total, 8 * 96 * 2);
        assert_eq!(ledger.step(2).total, 8 * 96 * 2);
        // EF residuals join the state accounting: 2 workers × r×n.
        assert_eq!(
            opt.state_elements(),
            64 * 8 + 2 * 96 * 8 + 2 * 8 * 96,
            "EF buffers counted"
        );
    }

    #[test]
    fn embeddings_stay_dense() {
        let spec = ModelSpec::proxy(40, 8, 16, 2, 1);
        let blocks = spec.blocks();
        let mut params: Vec<Matrix> =
            blocks.iter().map(|b| Matrix::zeros(b.rows, b.cols)).collect();
        let mut opt = OneSidedAdam::new(
            &blocks,
            AdamHyper::default(),
            4,
            1000,
            OneSidedRefresh::ExactSvd,
        );
        let mut ledger = CommLedger::new();
        let topo = Topology::single_node(2);
        let mut rng = Xoshiro256::new(4);
        let mut grads = alloc_worker_grads(&blocks, 2);
        for w in grads.iter_mut() {
            for g in w.iter_mut() {
                *g = Matrix::gaussian(g.rows, g.cols, 1.0, &mut rng);
            }
        }
        opt.step(&mut StepCtx {
            params: &mut params,
            grads: &mut grads,
            ledger: &mut ledger,
            topo: &topo,
            lr_mult: 1.0,
            exec: &crate::exec::ExecBackend::Sequential,
        });
        ledger.end_step();
        // Embedding bytes = full dense embedding block every step.
        let emb_elems: usize = blocks
            .iter()
            .filter(|b| b.class == LayerClass::Embedding)
            .map(|b| b.numel())
            .sum();
        assert_eq!(ledger.step(0).embedding, emb_elems * 4);
    }

    #[test]
    fn right_projection_for_tall_blocks() {
        // rows > cols → project the column space: C = G V (m×r).
        let blocks = vec![BlockSpec {
            name: "w".into(),
            rows: 120,
            cols: 30,
            class: LayerClass::Linear,
        }];
        let mut params = vec![Matrix::zeros(120, 30)];
        let mut opt = OneSidedAdam::new(
            &blocks,
            AdamHyper::default(),
            5,
            100,
            OneSidedRefresh::RandomizedSvd,
        );
        let mut ledger = CommLedger::new();
        let topo = Topology::single_node(2);
        let mut rng = Xoshiro256::new(6);
        for _ in 0..2 {
            let mut grads: Vec<Vec<Matrix>> = (0..2)
                .map(|_| vec![Matrix::gaussian(120, 30, 1.0, &mut rng)])
                .collect();
            opt.step(&mut StepCtx {
                params: &mut params,
                grads: &mut grads,
                ledger: &mut ledger,
                topo: &topo,
                lr_mult: 1.0,
                exec: &crate::exec::ExecBackend::Sequential,
            });
            ledger.end_step();
        }
        assert_eq!(ledger.step(1).total, 120 * 5 * 4);
        assert_eq!(opt.state_elements(), 30 * 5 + 2 * 120 * 5);
    }

    use crate::comm::LayerClass;
    use crate::linalg::Matrix;
    use crate::model::BlockSpec;
    use crate::util::rng::Xoshiro256;
}
