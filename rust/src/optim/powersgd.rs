//! PowerSGD (Vogels et al., 2019) — the classical low-rank
//! gradient-compression baseline from the paper's related work (§A).
//!
//! Rank-r compression with a single warm-started power iteration and
//! error feedback: per step synchronize P = (G+E)Q (m×r) and
//! Q' = (G+E)ᵀP̂ (n×r); comm O(r(m+n)) — Table 1's LoRA-like scaling row.

use super::{AdamHyper, DenseAdamState, DistOptimizer, StepCtx, SyncItem, SyncPlan};
use crate::comm::{collective, LayerClass};
use crate::linalg::{gemm, orth, Matrix};
use crate::model::BlockSpec;
use crate::util::rng::Xoshiro256;

enum BlockState {
    Dense(DenseAdamState),
    Compressed(PsBlock),
}

struct PsBlock {
    #[allow(dead_code)]
    rank: usize,
    /// Warm-started right factor Q (n×r).
    q: Matrix,
    /// Per-worker error-feedback buffers (m×n each).
    errors: Vec<Matrix>,
    /// SGD momentum on the decompressed gradient.
    momentum: Matrix,
}

pub struct PowerSgd {
    pub lr: f32,
    pub beta: f32,
    classes: Vec<LayerClass>,
    blocks: Vec<BlockState>,
    hyper: AdamHyper,
    t: u64,
}

impl PowerSgd {
    pub fn new(blocks: &[BlockSpec], workers: usize, lr: f32, beta: f32, rank: usize) -> Self {
        let mut rng = Xoshiro256::new(0x505E_A5);
        let states = blocks
            .iter()
            .map(|b| {
                if b.class == LayerClass::Vector {
                    BlockState::Dense(DenseAdamState::new(b.rows, b.cols))
                } else {
                    let r = rank.min(b.rows).min(b.cols);
                    BlockState::Compressed(PsBlock {
                        rank: r,
                        q: orth(&Matrix::gaussian(b.cols, r, 1.0, &mut rng)),
                        errors: (0..workers).map(|_| Matrix::zeros(b.rows, b.cols)).collect(),
                        momentum: Matrix::zeros(b.rows, b.cols),
                    })
                }
            })
            .collect();
        Self {
            lr,
            beta,
            classes: blocks.iter().map(|b| b.class).collect(),
            blocks: states,
            hyper: AdamHyper {
                lr,
                ..Default::default()
            },
            t: 0,
        }
    }
}

impl DistOptimizer for PowerSgd {
    fn name(&self) -> &'static str {
        "powersgd"
    }

    fn step(&mut self, ctx: &mut StepCtx) {
        self.t += 1;
        let t1 = self.t;
        let lr = self.lr * ctx.lr_mult;
        let tracer = ctx.tracer();
        crate::span!(tracer, "compress_step");

        for b in 0..ctx.params.len() {
            let class = self.classes[b];
            match &mut self.blocks[b] {
                BlockState::Dense(st) => {
                    let mut per_worker: Vec<_> =
                        ctx.grads.iter().map(|g| g[b].clone()).collect();
                    collective::sync_mean(&mut per_worker, class, ctx.ledger, ctx.topo, ctx.exec);
                    st.update_exec(
                        &mut ctx.params[b],
                        &per_worker[0],
                        &self.hyper,
                        ctx.lr_mult,
                        t1,
                        ctx.exec,
                    );
                }
                BlockState::Compressed(blk) => {
                    // Error-compensated gradient per worker.
                    let comp: Vec<Matrix> = ctx
                        .grads
                        .iter()
                        .zip(blk.errors.iter())
                        .map(|(g, e)| {
                            let mut x = g[b].clone();
                            x.add_assign(e);
                            x
                        })
                        .collect();
                    // P_i = X_i Q (per-worker, fanned out); all-reduce;
                    // orthonormalize.
                    let mut ps: Vec<Matrix> =
                        ctx.exec.map_workers(comp.len(), |i| gemm(&comp[i], false, &blk.q, false));
                    collective::sync_mean(&mut ps, class, ctx.ledger, ctx.topo, ctx.exec);
                    let phat = orth(&ps[0]);
                    // Q'_i = X_iᵀ P̂ ; all-reduce.
                    let mut qs: Vec<Matrix> =
                        ctx.exec.map_workers(comp.len(), |i| gemm(&comp[i], true, &phat, false));
                    collective::sync_mean(&mut qs, class, ctx.ledger, ctx.topo, ctx.exec);
                    blk.q = qs.swap_remove(0);

                    // Decompressed averaged gradient Ĝ = P̂ Qᵀ.
                    let ghat = gemm(&phat, false, &blk.q, true);
                    // Error feedback: e_i ← X_i − Ĝ.
                    for (e, x) in blk.errors.iter_mut().zip(comp.into_iter()) {
                        *e = x;
                        e.axpy(-1.0, &ghat);
                    }
                    // Momentum SGD on the decompressed gradient.
                    let beta = self.beta;
                    for i in 0..ghat.data.len() {
                        blk.momentum.data[i] =
                            beta * blk.momentum.data[i] + ghat.data[i];
                        ctx.params[b].data[i] -= lr * blk.momentum.data[i];
                    }
                }
            }
        }
    }

    fn sync_plan(&self, _t: u64) -> SyncPlan {
        // Flat O(r(m+n)) traffic: P (m×r) + Q' (n×r) every step.
        let items = self
            .blocks
            .iter()
            .enumerate()
            .map(|(b, s)| {
                let elems = match s {
                    BlockState::Dense(st) => st.m.numel(),
                    BlockState::Compressed(blk) => {
                        let r = blk.q.cols;
                        blk.momentum.rows * r + blk.q.rows * r
                    }
                };
                SyncItem {
                    block: b,
                    class: self.classes[b],
                    bytes: elems * crate::comm::BYTES_F32,
                    fmt: crate::comm::ElemFmt::F32,
                    refresh: false,
                }
            })
            .collect();
        SyncPlan { items }
    }

    fn state_elements(&self) -> usize {
        self.blocks
            .iter()
            .map(|s| match s {
                BlockState::Dense(st) => st.elements(),
                BlockState::Compressed(b) => {
                    b.q.numel()
                        + b.momentum.numel()
                        + b.errors.iter().map(|e| e.numel()).sum::<usize>()
                }
            })
            .sum()
    }

    fn save_state(&self) -> crate::util::json::Json {
        use crate::checkpoint::codec;
        use crate::util::json::Json;
        let blocks = self
            .blocks
            .iter()
            .map(|s| match s {
                BlockState::Dense(st) => Json::obj(vec![
                    ("kind", Json::str("dense")),
                    ("adam", st.state_to_json()),
                ]),
                BlockState::Compressed(b) => Json::obj(vec![
                    ("kind", Json::str("compressed")),
                    ("q", codec::matrix_to_json(&b.q)),
                    ("momentum", codec::matrix_to_json(&b.momentum)),
                    ("errors", crate::checkpoint::errors_to_json(&b.errors)),
                ]),
            })
            .collect();
        Json::obj(vec![
            ("t", codec::u64_to_json(self.t)),
            ("blocks", Json::arr(blocks)),
        ])
    }

    fn load_state(
        &mut self,
        state: &crate::util::json::Json,
        workers: usize,
    ) -> Result<(), String> {
        use crate::checkpoint::codec;
        let blocks = state.get("blocks").as_arr().ok_or("powersgd: missing blocks")?;
        if blocks.len() != self.blocks.len() {
            return Err(format!(
                "powersgd: checkpoint has {} blocks, run has {}",
                blocks.len(),
                self.blocks.len()
            ));
        }
        for (i, j) in blocks.iter().enumerate() {
            let what = format!("powersgd.blocks[{i}]");
            match (&mut self.blocks[i], j.get("kind").as_str()) {
                (BlockState::Dense(st), Some("dense")) => {
                    st.state_from_json(j.get("adam"), &what)?;
                }
                (BlockState::Compressed(b), Some("compressed")) => {
                    b.q = codec::matrix_from_json_expect(j.get("q"), b.q.rows, b.q.cols, &what)?;
                    let (rows, cols) = (b.momentum.rows, b.momentum.cols);
                    b.momentum =
                        codec::matrix_from_json_expect(j.get("momentum"), rows, cols, &what)?;
                    b.errors = crate::checkpoint::errors_from_json(
                        j.get("errors"),
                        rows,
                        cols,
                        workers,
                        &format!("{what}.errors"),
                    )?;
                }
                (_, kind) => {
                    return Err(format!("{what}: block kind mismatch (checkpoint: {kind:?})"));
                }
            }
        }
        self.t = codec::u64_from_json(state.get("t"), "powersgd.t")?;
        Ok(())
    }

    fn seek(&mut self, t: u64) {
        self.t = t;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{CommLedger, Topology};

    #[test]
    fn comm_is_r_times_m_plus_n() {
        let blocks = vec![BlockSpec {
            name: "w".into(),
            rows: 50,
            cols: 70,
            class: LayerClass::Linear,
        }];
        let mut params = vec![Matrix::zeros(50, 70)];
        let mut opt = PowerSgd::new(&blocks, 2, 0.1, 0.9, 4);
        let mut ledger = CommLedger::new();
        let topo = Topology::single_node(2);
        let mut rng = Xoshiro256::new(1);
        let mut grads: Vec<Vec<Matrix>> = (0..2)
            .map(|_| vec![Matrix::gaussian(50, 70, 1.0, &mut rng)])
            .collect();
        opt.step(&mut StepCtx {
            params: &mut params,
            grads: &mut grads,
            ledger: &mut ledger,
            topo: &topo,
            lr_mult: 1.0,
            exec: &crate::exec::ExecBackend::Sequential,
        });
        ledger.end_step();
        assert_eq!(ledger.step(0).total, (50 * 4 + 70 * 4) * 4);
    }

    #[test]
    fn error_feedback_recovers_full_gradient_over_time() {
        // With a CONSTANT gradient, PowerSGD + error feedback approaches
        // transmitting the full gradient information: the accumulated
        // update direction converges to Ḡ.
        let blocks = vec![BlockSpec {
            name: "w".into(),
            rows: 12,
            cols: 10,
            class: LayerClass::Linear,
        }];
        let mut rng = Xoshiro256::new(2);
        let g = Matrix::gaussian(12, 10, 1.0, &mut rng);
        let mut params = vec![Matrix::zeros(12, 10)];
        let mut opt = PowerSgd::new(&blocks, 1, 0.1, 0.0, 2);
        let mut ledger = CommLedger::new();
        let topo = Topology::single_node(1);
        for _ in 0..50 {
            let mut grads = vec![vec![g.clone()]];
            opt.step(&mut StepCtx {
                params: &mut params,
                grads: &mut grads,
                ledger: &mut ledger,
                topo: &topo,
                lr_mult: 1.0,
                exec: &crate::exec::ExecBackend::Sequential,
            });
            ledger.end_step();
        }
        // After 50 steps at lr 0.1, params ≈ −0.1·50·g if transmission were
        // lossless; require ≥80% of that magnitude in the right direction.
        let mut ideal = g.clone();
        ideal.scale(-0.1 * 50.0);
        let cos = {
            let num: f32 = params[0]
                .data
                .iter()
                .zip(&ideal.data)
                .map(|(a, b)| a * b)
                .sum();
            num / (params[0].frob_norm() * ideal.frob_norm())
        };
        assert!(cos > 0.95, "cosine {cos}");
        assert!(params[0].frob_norm() > 0.8 * ideal.frob_norm());
    }

    use crate::comm::LayerClass;
    use crate::linalg::Matrix;
    use crate::model::BlockSpec;
    use crate::util::rng::Xoshiro256;
}
