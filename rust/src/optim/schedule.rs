//! Learning-rate schedule: linear warmup (first 10% of steps) + cosine
//! decay to 10% of the base LR — the paper's pre-training schedule (§C.1).

#[derive(Clone, Copy, Debug)]
pub struct LrSchedule {
    pub total_steps: usize,
    pub warmup_steps: usize,
    /// Final LR as a fraction of the base LR.
    pub min_ratio: f32,
}

impl LrSchedule {
    /// The paper's schedule for `total` steps.
    pub fn paper(total: usize) -> Self {
        Self {
            total_steps: total.max(1),
            warmup_steps: (total / 10).max(1),
            min_ratio: 0.1,
        }
    }

    pub fn constant() -> Self {
        Self {
            total_steps: 1,
            warmup_steps: 0,
            min_ratio: 1.0,
        }
    }

    /// Multiplier at step `t` (0-indexed).
    pub fn multiplier(&self, t: usize) -> f32 {
        if self.warmup_steps > 0 && t < self.warmup_steps {
            return (t + 1) as f32 / self.warmup_steps as f32;
        }
        if self.total_steps <= self.warmup_steps {
            return 1.0;
        }
        let progress =
            (t - self.warmup_steps) as f32 / (self.total_steps - self.warmup_steps) as f32;
        let progress = progress.clamp(0.0, 1.0);
        let cos = 0.5 * (1.0 + (std::f32::consts::PI * progress).cos());
        self.min_ratio + (1.0 - self.min_ratio) * cos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_rises_then_cosine_decays() {
        let s = LrSchedule::paper(1000);
        assert!(s.multiplier(0) < s.multiplier(50));
        assert!((s.multiplier(99) - 1.0).abs() < 0.02);
        assert!(s.multiplier(500) < 1.0);
        assert!((s.multiplier(999) - 0.1).abs() < 0.01);
    }

    #[test]
    fn constant_is_one() {
        let s = LrSchedule::constant();
        for t in [0, 10, 1000] {
            assert_eq!(s.multiplier(t), 1.0);
        }
    }

    #[test]
    fn multiplier_bounded() {
        let s = LrSchedule::paper(77);
        for t in 0..200 {
            let m = s.multiplier(t);
            assert!((0.0..=1.0).contains(&m), "t={t} m={m}");
        }
    }
}
