//! SignAdam — 1-bit sign compression with error feedback and 0/1-Adam
//! style variance freezing (Lu et al., 2022; PAPERS.md related work).
//!
//! The extreme-quantization baseline family the paper compares against:
//! for each matrix block the per-step synchronized object is the sign
//! bitmap of the error-compensated gradient (1 bit/element) plus one f32
//! scale — Table 1 scaling O(mn/32). Adam's second moment cannot be
//! maintained from sign-only traffic, so it is *frozen*: every `k_var`
//! steps a full dense gradient all-reduce re-estimates v (the refresh
//! peak of this family), and in between the update runs Adam with the
//! frozen v and a momentum built from the compressed gradients. Vector
//! blocks (biases/norms) stay dense, as in every method here (§3.4).
//!
//! Byte accounting is exact and mirrors `exp::analytic::sign_profile`:
//! both sides meter [`sign_payload_bytes`] per matrix block per step and
//! the full dense block every `k_var` steps.

use super::{refresh_due, AdamHyper, DenseAdamState, DistOptimizer, StepCtx, SyncItem, SyncPlan};
use crate::comm::{collective, LayerClass};
use crate::linalg::Matrix;
use crate::model::BlockSpec;

/// Wire bytes of the compressed object for one m×n block: a 1-bit sign
/// per element (packed) plus one f32 magnitude scale.
pub fn sign_payload_bytes(numel: usize) -> usize {
    numel.div_ceil(8) + crate::comm::BYTES_F32
}

enum BlockState {
    Dense(DenseAdamState),
    Sign(SignBlock),
}

struct SignBlock {
    /// Momentum on the decompressed mean gradient.
    m: Matrix,
    /// Frozen second moment, re-estimated every `k_var` steps.
    v: Matrix,
    /// Per-worker error-feedback residuals.
    errors: Vec<Matrix>,
    /// Number of v updates so far (1-indexed bias correction for v).
    tv: u64,
    /// Step of the first dense variance estimate ([`refresh_due`]) —
    /// v must exist before the first compressed update, even when the
    /// run starts mid-period (resume).
    init_step: Option<u64>,
}

pub struct SignAdam {
    hyper: AdamHyper,
    /// Dense variance-refresh interval (the method's only dense traffic).
    pub k_var: usize,
    classes: Vec<LayerClass>,
    blocks: Vec<BlockState>,
    t: u64,
}

impl SignAdam {
    pub fn new(blocks: &[BlockSpec], hyper: AdamHyper, k_var: usize, workers: usize) -> Self {
        let states = blocks
            .iter()
            .map(|b| {
                if b.class == LayerClass::Vector {
                    BlockState::Dense(DenseAdamState::new(b.rows, b.cols))
                } else {
                    BlockState::Sign(SignBlock {
                        m: Matrix::zeros(b.rows, b.cols),
                        v: Matrix::zeros(b.rows, b.cols),
                        errors: (0..workers).map(|_| Matrix::zeros(b.rows, b.cols)).collect(),
                        tv: 0,
                        init_step: None,
                    })
                }
            })
            .collect();
        Self {
            hyper,
            k_var: k_var.max(1),
            classes: blocks.iter().map(|b| b.class).collect(),
            blocks: states,
            t: 0,
        }
    }
}

impl DistOptimizer for SignAdam {
    fn name(&self) -> &'static str {
        "sign-adam"
    }

    fn step(&mut self, ctx: &mut StepCtx) {
        let t = self.t;
        self.t += 1;
        let t1 = self.t;
        let h = self.hyper;
        let workers = ctx.grads.len();

        for b in 0..ctx.params.len() {
            let class = self.classes[b];
            match &mut self.blocks[b] {
                BlockState::Dense(st) => {
                    let mut per_worker: Vec<_> =
                        ctx.grads.iter().map(|g| g[b].clone()).collect();
                    collective::sync_mean(&mut per_worker, class, ctx.ledger, ctx.topo, ctx.exec);
                    st.update_exec(
                        &mut ctx.params[b],
                        &per_worker[0],
                        &h,
                        ctx.lr_mult,
                        t1,
                        ctx.exec,
                    );
                }
                BlockState::Sign(blk) => {
                    // Variance refresh: dense all-reduce every k_var steps
                    // (the first executed step included — v must exist
                    // before the first compressed update, also when that
                    // step isn't a cadence boundary, i.e. mid-period
                    // resume). This is the family's peak-byte event,
                    // analogous to GaLore's dense refresh; the predicate
                    // is shared with sync_plan ([`refresh_due`]).
                    if refresh_due(blk.init_step, t, self.k_var as u64, t) {
                        ctx.tracer().event(
                            "var_refresh",
                            vec![("block", crate::util::json::Json::num(b as f64))],
                        );
                        let mut dense: Vec<Matrix> =
                            ctx.grads.iter().map(|g| g[b].clone()).collect();
                        collective::sync_mean(&mut dense, class, ctx.ledger, ctx.topo, ctx.exec);
                        ctx.ledger.mark_refresh();
                        blk.tv += 1;
                        if blk.init_step.is_none() {
                            blk.init_step = Some(t);
                        }
                        let b2 = h.beta2;
                        let gbar = &dense[0];
                        for i in 0..blk.v.data.len() {
                            let g = gbar.data[i];
                            blk.v.data[i] = b2 * blk.v.data[i] + (1.0 - b2) * g * g;
                        }
                    }

                    // Compressed path: per worker, sign-quantize the
                    // error-compensated gradient x_i = g_i + e_i with a
                    // per-block mean-|x| scale (1-bit SGD compressor),
                    // aggregate the decompressed signs, update residuals.
                    let mut ghat = Matrix::zeros(blk.m.rows, blk.m.cols);
                    for (gw, e) in ctx.grads.iter().zip(blk.errors.iter_mut()) {
                        let g = &gw[b];
                        let numel = g.data.len();
                        let mut scale = 0.0f32;
                        for i in 0..numel {
                            scale += (g.data[i] + e.data[i]).abs();
                        }
                        scale /= numel as f32;
                        for i in 0..numel {
                            let x = g.data[i] + e.data[i];
                            let s = if x >= 0.0 { scale } else { -scale };
                            ghat.data[i] += s;
                            e.data[i] = x - s;
                        }
                    }
                    ghat.scale(1.0 / workers as f32);
                    let bytes = sign_payload_bytes(ghat.numel());
                    collective::record_virtual_sync(workers, class, bytes, ctx.ledger, ctx.topo);

                    // Adam update: fresh momentum, frozen variance.
                    let b1 = h.beta1;
                    let bc1 = 1.0 - b1.powi(t1 as i32);
                    let bc2 = 1.0 - h.beta2.powi(blk.tv as i32);
                    let lr = h.lr * ctx.lr_mult;
                    let w = &mut ctx.params[b];
                    for i in 0..w.data.len() {
                        blk.m.data[i] = b1 * blk.m.data[i] + (1.0 - b1) * ghat.data[i];
                        let mhat = blk.m.data[i] / bc1;
                        let vhat = blk.v.data[i] / bc2;
                        let upd = mhat / (vhat.sqrt() + h.eps);
                        w.data[i] -= lr * (h.scale * upd + h.weight_decay * w.data[i]);
                    }
                }
            }
        }
    }

    fn sync_plan(&self, t: u64) -> SyncPlan {
        let items = self
            .blocks
            .iter()
            .enumerate()
            .map(|(b, s)| match s {
                BlockState::Dense(st) => SyncItem {
                    block: b,
                    class: self.classes[b],
                    bytes: st.m.numel() * crate::comm::BYTES_F32,
                    fmt: crate::comm::ElemFmt::F32,
                    refresh: false,
                },
                BlockState::Sign(blk) => {
                    let refresh = refresh_due(blk.init_step, self.t, self.k_var as u64, t);
                    let numel = blk.m.numel();
                    let dense = if refresh {
                        numel * crate::comm::BYTES_F32
                    } else {
                        0
                    };
                    SyncItem {
                        block: b,
                        class: self.classes[b],
                        bytes: sign_payload_bytes(numel) + dense,
                        fmt: crate::comm::ElemFmt::F32,
                        refresh,
                    }
                }
            })
            .collect();
        SyncPlan { items }
    }

    fn state_elements(&self) -> usize {
        self.blocks
            .iter()
            .map(|s| match s {
                BlockState::Dense(st) => st.elements(),
                BlockState::Sign(blk) => {
                    blk.m.numel()
                        + blk.v.numel()
                        + blk.errors.iter().map(|e| e.numel()).sum::<usize>()
                }
            })
            .sum()
    }

    fn save_state(&self) -> crate::util::json::Json {
        use crate::checkpoint::codec;
        use crate::util::json::Json;
        let blocks = self
            .blocks
            .iter()
            .map(|s| match s {
                BlockState::Dense(st) => Json::obj(vec![
                    ("kind", Json::str("dense")),
                    ("adam", st.state_to_json()),
                ]),
                BlockState::Sign(blk) => Json::obj(vec![
                    ("kind", Json::str("sign")),
                    ("m", codec::matrix_to_json(&blk.m)),
                    ("v", codec::matrix_to_json(&blk.v)),
                    ("tv", codec::u64_to_json(blk.tv)),
                    ("init_step", codec::opt_u64_to_json(blk.init_step)),
                    ("errors", crate::checkpoint::errors_to_json(&blk.errors)),
                ]),
            })
            .collect();
        Json::obj(vec![
            ("t", codec::u64_to_json(self.t)),
            ("blocks", Json::arr(blocks)),
        ])
    }

    fn load_state(
        &mut self,
        state: &crate::util::json::Json,
        workers: usize,
    ) -> Result<(), String> {
        use crate::checkpoint::codec;
        let blocks = state.get("blocks").as_arr().ok_or("sign-adam: missing blocks")?;
        if blocks.len() != self.blocks.len() {
            return Err(format!(
                "sign-adam: checkpoint has {} blocks, run has {}",
                blocks.len(),
                self.blocks.len()
            ));
        }
        for (i, j) in blocks.iter().enumerate() {
            let what = format!("sign-adam.blocks[{i}]");
            match (&mut self.blocks[i], j.get("kind").as_str()) {
                (BlockState::Dense(st), Some("dense")) => {
                    st.state_from_json(j.get("adam"), &what)?;
                }
                (BlockState::Sign(blk), Some("sign")) => {
                    let (rows, cols) = (blk.m.rows, blk.m.cols);
                    blk.m = codec::matrix_from_json_expect(j.get("m"), rows, cols, &what)?;
                    blk.v = codec::matrix_from_json_expect(j.get("v"), rows, cols, &what)?;
                    blk.tv = codec::u64_from_json(j.get("tv"), &format!("{what}.tv"))?;
                    blk.init_step = codec::opt_u64_from_json(
                        codec::require(j, "init_step", &what)?,
                        &format!("{what}.init_step"),
                    )?;
                    blk.errors = crate::checkpoint::errors_from_json(
                        j.get("errors"),
                        rows,
                        cols,
                        workers,
                        &format!("{what}.errors"),
                    )?;
                }
                (_, kind) => {
                    return Err(format!("{what}: block kind mismatch (checkpoint: {kind:?})"));
                }
            }
        }
        self.t = codec::u64_from_json(state.get("t"), "sign-adam.t")?;
        Ok(())
    }

    fn seek(&mut self, t: u64) {
        self.t = t;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{CommLedger, Topology};
    use crate::util::rng::Xoshiro256;

    fn one_block(rows: usize, cols: usize) -> Vec<BlockSpec> {
        vec![BlockSpec {
            name: "w".into(),
            rows,
            cols,
            class: LayerClass::Linear,
        }]
    }

    #[test]
    fn payload_is_bitmap_plus_scale() {
        assert_eq!(sign_payload_bytes(64), 8 + 4);
        assert_eq!(sign_payload_bytes(65), 9 + 4);
        assert_eq!(sign_payload_bytes(1), 1 + 4);
    }

    #[test]
    fn steady_steps_sync_one_bit_per_element() {
        let blocks = one_block(40, 50);
        let mut params = vec![Matrix::zeros(40, 50)];
        let mut opt = SignAdam::new(&blocks, AdamHyper::default(), 100, 2);
        let mut ledger = CommLedger::new();
        let topo = Topology::single_node(2);
        let mut rng = Xoshiro256::new(1);
        for _ in 0..3 {
            let mut grads: Vec<Vec<Matrix>> = (0..2)
                .map(|_| vec![Matrix::gaussian(40, 50, 1.0, &mut rng)])
                .collect();
            opt.step(&mut StepCtx {
                params: &mut params,
                grads: &mut grads,
                ledger: &mut ledger,
                topo: &topo,
                lr_mult: 1.0,
                exec: &crate::exec::ExecBackend::Sequential,
            });
            ledger.end_step();
        }
        // Step 0: dense variance estimate + signs; steps 1-2 signs only.
        let compressed = sign_payload_bytes(40 * 50);
        assert_eq!(ledger.step(0).total, 40 * 50 * 4 + compressed);
        assert!(ledger.step(0).refresh);
        assert_eq!(ledger.step(1).total, compressed);
        assert_eq!(ledger.step(2).total, compressed);
    }

    #[test]
    fn error_feedback_recovers_constant_gradient() {
        // With a constant gradient the EF residual keeps the quantization
        // error bounded, so the accumulated update direction aligns with
        // the true gradient.
        let blocks = one_block(12, 10);
        let mut rng = Xoshiro256::new(2);
        let g = Matrix::gaussian(12, 10, 1.0, &mut rng);
        let mut params = vec![Matrix::zeros(12, 10)];
        let mut opt = SignAdam::new(
            &blocks,
            AdamHyper {
                lr: 0.01,
                ..Default::default()
            },
            10,
            1,
        );
        let mut ledger = CommLedger::new();
        let topo = Topology::single_node(1);
        for _ in 0..60 {
            let mut grads = vec![vec![g.clone()]];
            opt.step(&mut StepCtx {
                params: &mut params,
                grads: &mut grads,
                ledger: &mut ledger,
                topo: &topo,
                lr_mult: 1.0,
                exec: &crate::exec::ExecBackend::Sequential,
            });
            ledger.end_step();
        }
        let cos = {
            let num: f32 = params[0].data.iter().zip(&g.data).map(|(a, b)| a * b).sum();
            -num / (params[0].frob_norm() * g.frob_norm())
        };
        // Adam whitening sends the update toward sign(g): for gaussian g
        // the cosine between sign(g) and g concentrates near √(2/π)≈0.8.
        assert!(cos > 0.6, "update direction cosine {cos}");
    }

    #[test]
    fn descends_on_quadratic() {
        let blocks = one_block(24, 18);
        let mut rng = Xoshiro256::new(9);
        let target = Matrix::gaussian(24, 18, 1.0, &mut rng);
        let mut params = vec![Matrix::zeros(24, 18)];
        let mut opt = SignAdam::new(
            &blocks,
            AdamHyper {
                lr: 0.02,
                ..Default::default()
            },
            20,
            2,
        );
        let mut ledger = CommLedger::new();
        let topo = Topology::single_node(2);
        let loss0 = params[0].dist(&target);
        for _ in 0..200 {
            let mut grads: Vec<Vec<Matrix>> = (0..2)
                .map(|_| {
                    let mut g = params[0].clone();
                    g.axpy(-1.0, &target);
                    let noise = Matrix::gaussian(24, 18, 0.05, &mut rng);
                    g.add_assign(&noise);
                    vec![g]
                })
                .collect();
            opt.step(&mut StepCtx {
                params: &mut params,
                grads: &mut grads,
                ledger: &mut ledger,
                topo: &topo,
                lr_mult: 1.0,
                exec: &crate::exec::ExecBackend::Sequential,
            });
            ledger.end_step();
        }
        let loss1 = params[0].dist(&target);
        assert!(loss1 < 0.5 * loss0, "loss {loss0} -> {loss1}");
    }

    #[test]
    fn state_counts_moments_and_residuals() {
        let blocks = one_block(10, 8);
        let opt = SignAdam::new(&blocks, AdamHyper::default(), 50, 3);
        assert_eq!(opt.state_elements(), 80 + 80 + 3 * 80);
    }
}
