//! TopKAdam — per-block top-k sparse gradient synchronization with error
//! feedback (SCAPE-style extreme sparse communication; PAPERS.md).
//!
//! The sparsification baseline family: each worker transmits only the k
//! largest-magnitude entries of its error-compensated gradient per matrix
//! block, as (index, value) pairs — payload [`topk_payload_bytes`] =
//! 8·k bytes (u32 index + f32 value). Untransmitted mass accumulates in
//! per-worker residuals (error feedback), which is what keeps extreme
//! densities (≤1%) convergent. Adam moments stay dense on the aggregated
//! sparse gradient; Vector blocks stay dense (§3.4). Communication is
//! perfectly flat: PeakBytes == Bytes/Step, with no refresh spikes — the
//! qualitative contrast to the refresh-based low-rank families.
//!
//! Byte accounting is exact and mirrors `exp::analytic::topk_profile`:
//! both sides derive k from [`topk_elems`] on the same block shapes.

use super::{AdamHyper, DenseAdamState, DistOptimizer, StepCtx, SyncItem, SyncPlan};
use crate::comm::{collective, LayerClass};
use crate::linalg::Matrix;
use crate::model::BlockSpec;

/// Entries kept per block: ceil(keep_frac · numel), clamped to [1, numel].
pub fn topk_elems(numel: usize, keep_frac: f64) -> usize {
    ((numel as f64 * keep_frac).ceil() as usize).clamp(1, numel.max(1))
}

/// Wire bytes for k sparse entries: u32 index + f32 value each.
pub fn topk_payload_bytes(k: usize) -> usize {
    k * (4 + crate::comm::BYTES_F32)
}

enum BlockState {
    Dense(DenseAdamState),
    Sparse(TopkBlock),
}

struct TopkBlock {
    /// Entries transmitted per step for this block.
    k: usize,
    /// Dense Adam moments on the aggregated sparse gradient.
    state: DenseAdamState,
    /// Per-worker error-feedback residuals.
    errors: Vec<Matrix>,
}

pub struct TopKAdam {
    hyper: AdamHyper,
    pub keep_frac: f64,
    classes: Vec<LayerClass>,
    blocks: Vec<BlockState>,
    t: u64,
}

impl TopKAdam {
    pub fn new(blocks: &[BlockSpec], workers: usize, hyper: AdamHyper, keep_frac: f64) -> Self {
        let states = blocks
            .iter()
            .map(|b| {
                if b.class == LayerClass::Vector {
                    BlockState::Dense(DenseAdamState::new(b.rows, b.cols))
                } else {
                    BlockState::Sparse(TopkBlock {
                        k: topk_elems(b.numel(), keep_frac),
                        state: DenseAdamState::new(b.rows, b.cols),
                        errors: (0..workers).map(|_| Matrix::zeros(b.rows, b.cols)).collect(),
                    })
                }
            })
            .collect();
        Self {
            hyper,
            keep_frac,
            classes: blocks.iter().map(|b| b.class).collect(),
            blocks: states,
            t: 0,
        }
    }
}

impl DistOptimizer for TopKAdam {
    fn name(&self) -> &'static str {
        "topk-adam"
    }

    fn step(&mut self, ctx: &mut StepCtx) {
        self.t += 1;
        let t1 = self.t;
        let h = self.hyper;
        let workers = ctx.grads.len();

        for b in 0..ctx.params.len() {
            let class = self.classes[b];
            match &mut self.blocks[b] {
                BlockState::Dense(st) => {
                    let mut per_worker: Vec<_> =
                        ctx.grads.iter().map(|g| g[b].clone()).collect();
                    collective::sync_mean(&mut per_worker, class, ctx.ledger, ctx.topo, ctx.exec);
                    st.update_exec(
                        &mut ctx.params[b],
                        &per_worker[0],
                        &h,
                        ctx.lr_mult,
                        t1,
                        ctx.exec,
                    );
                }
                BlockState::Sparse(blk) => {
                    // Per worker: x = g + e, keep the k largest |x|,
                    // accumulate them into the aggregate, bank the rest.
                    let rows = blk.state.m.rows;
                    let cols = blk.state.m.cols;
                    let mut ghat = Matrix::zeros(rows, cols);
                    for (gw, e) in ctx.grads.iter().zip(blk.errors.iter_mut()) {
                        let g = &gw[b];
                        let numel = g.data.len();
                        let mut x = vec![0.0f32; numel];
                        for i in 0..numel {
                            x[i] = g.data[i] + e.data[i];
                        }
                        let mut idx: Vec<usize> = (0..numel).collect();
                        if blk.k < numel {
                            idx.select_nth_unstable_by(blk.k - 1, |&a, &c| {
                                x[c].abs().total_cmp(&x[a].abs())
                            });
                            idx.truncate(blk.k);
                        }
                        e.data.copy_from_slice(&x);
                        for &i in &idx {
                            ghat.data[i] += x[i];
                            e.data[i] = 0.0;
                        }
                    }
                    ghat.scale(1.0 / workers as f32);
                    let bytes = topk_payload_bytes(blk.k);
                    collective::record_virtual_sync(workers, class, bytes, ctx.ledger, ctx.topo);

                    // Dense Adam on the aggregated sparse gradient —
                    // sharded over threads like the AdamW hot path.
                    blk.state
                        .update_exec(&mut ctx.params[b], &ghat, &h, ctx.lr_mult, t1, ctx.exec);
                }
            }
        }
    }

    fn sync_plan(&self, _t: u64) -> SyncPlan {
        // Perfectly flat: 8·k bytes per matrix block, dense vectors.
        let items = self
            .blocks
            .iter()
            .enumerate()
            .map(|(b, s)| {
                let bytes = match s {
                    BlockState::Dense(st) => st.m.numel() * crate::comm::BYTES_F32,
                    BlockState::Sparse(blk) => topk_payload_bytes(blk.k),
                };
                SyncItem {
                    block: b,
                    class: self.classes[b],
                    bytes,
                    fmt: crate::comm::ElemFmt::F32,
                    refresh: false,
                }
            })
            .collect();
        SyncPlan { items }
    }

    fn state_elements(&self) -> usize {
        self.blocks
            .iter()
            .map(|s| match s {
                BlockState::Dense(st) => st.elements(),
                BlockState::Sparse(blk) => {
                    blk.state.elements()
                        + blk.errors.iter().map(|e| e.numel()).sum::<usize>()
                }
            })
            .sum()
    }

    fn save_state(&self) -> crate::util::json::Json {
        use crate::checkpoint::codec;
        use crate::util::json::Json;
        let blocks = self
            .blocks
            .iter()
            .map(|s| match s {
                BlockState::Dense(st) => Json::obj(vec![
                    ("kind", Json::str("dense")),
                    ("adam", st.state_to_json()),
                ]),
                BlockState::Sparse(blk) => Json::obj(vec![
                    ("kind", Json::str("sparse")),
                    ("k", Json::num(blk.k as f64)),
                    ("adam", blk.state.state_to_json()),
                    ("errors", crate::checkpoint::errors_to_json(&blk.errors)),
                ]),
            })
            .collect();
        Json::obj(vec![
            ("t", codec::u64_to_json(self.t)),
            ("blocks", Json::arr(blocks)),
        ])
    }

    fn load_state(
        &mut self,
        state: &crate::util::json::Json,
        workers: usize,
    ) -> Result<(), String> {
        use crate::checkpoint::codec;
        let blocks = state.get("blocks").as_arr().ok_or("topk-adam: missing blocks")?;
        if blocks.len() != self.blocks.len() {
            return Err(format!(
                "topk-adam: checkpoint has {} blocks, run has {}",
                blocks.len(),
                self.blocks.len()
            ));
        }
        for (i, j) in blocks.iter().enumerate() {
            let what = format!("topk-adam.blocks[{i}]");
            match (&mut self.blocks[i], j.get("kind").as_str()) {
                (BlockState::Dense(st), Some("dense")) => {
                    st.state_from_json(j.get("adam"), &what)?;
                }
                (BlockState::Sparse(blk), Some("sparse")) => {
                    // k derives from keep_frac and the block shape; a
                    // mismatch means a different sparsity config.
                    let k = j.get("k").as_usize().ok_or_else(|| format!("{what}: missing k"))?;
                    if k != blk.k {
                        return Err(format!(
                            "{what}: checkpoint keeps k={k}, run keeps k={}",
                            blk.k
                        ));
                    }
                    blk.state.state_from_json(j.get("adam"), &what)?;
                    let (rows, cols) = (blk.state.m.rows, blk.state.m.cols);
                    blk.errors = crate::checkpoint::errors_from_json(
                        j.get("errors"),
                        rows,
                        cols,
                        workers,
                        &format!("{what}.errors"),
                    )?;
                }
                (_, kind) => {
                    return Err(format!("{what}: block kind mismatch (checkpoint: {kind:?})"));
                }
            }
        }
        self.t = codec::u64_from_json(state.get("t"), "topk-adam.t")?;
        Ok(())
    }

    fn seek(&mut self, t: u64) {
        self.t = t;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{CommLedger, Topology};
    use crate::util::rng::Xoshiro256;

    fn one_block(rows: usize, cols: usize) -> Vec<BlockSpec> {
        vec![BlockSpec {
            name: "w".into(),
            rows,
            cols,
            class: LayerClass::Linear,
        }]
    }

    #[test]
    fn k_derivation_clamps() {
        assert_eq!(topk_elems(1000, 0.01), 10);
        assert_eq!(topk_elems(1000, 0.0101), 11); // ceil
        assert_eq!(topk_elems(10, 0.0001), 1); // floor of 1
        assert_eq!(topk_elems(10, 2.0), 10); // capped at numel
        assert_eq!(topk_payload_bytes(10), 80);
    }

    #[test]
    fn bytes_are_flat_at_8k_per_step() {
        let blocks = one_block(30, 40);
        let mut params = vec![Matrix::zeros(30, 40)];
        let mut opt = TopKAdam::new(&blocks, 2, AdamHyper::default(), 0.05);
        let k = topk_elems(30 * 40, 0.05);
        let mut ledger = CommLedger::new();
        let topo = Topology::single_node(2);
        let mut rng = Xoshiro256::new(3);
        for _ in 0..4 {
            let mut grads: Vec<Vec<Matrix>> = (0..2)
                .map(|_| vec![Matrix::gaussian(30, 40, 1.0, &mut rng)])
                .collect();
            opt.step(&mut StepCtx {
                params: &mut params,
                grads: &mut grads,
                ledger: &mut ledger,
                topo: &topo,
                lr_mult: 1.0,
                exec: &crate::exec::ExecBackend::Sequential,
            });
            ledger.end_step();
        }
        for t in 0..4 {
            assert_eq!(ledger.step(t).total, 8 * k);
        }
        assert_eq!(ledger.peak_bytes() as f64, ledger.bytes_per_step());
    }

    #[test]
    fn selection_transmits_largest_entries() {
        // One worker, k=2: only the two largest-|x| coordinates move the
        // aggregate; the rest land in the residual.
        let blocks = one_block(1, 5);
        let mut params = vec![Matrix::zeros(1, 5)];
        let mut opt = TopKAdam::new(&blocks, 1, AdamHyper::default(), 0.4);
        let mut ledger = CommLedger::new();
        let topo = Topology::single_node(1);
        let g = Matrix::from_vec(1, 5, vec![0.1, -3.0, 0.2, 2.0, -0.3]);
        let mut grads = vec![vec![g.clone()]];
        opt.step(&mut StepCtx {
            params: &mut params,
            grads: &mut grads,
            ledger: &mut ledger,
            topo: &topo,
            lr_mult: 1.0,
            exec: &crate::exec::ExecBackend::Sequential,
        });
        ledger.end_step();
        // Coordinates 1 and 3 were transmitted: params moved there.
        assert!(params[0].data[1] > 0.0 && params[0].data[3] < 0.0);
        // Untransmitted coordinates are untouched and banked as residual.
        for i in [0usize, 2, 4] {
            assert_eq!(params[0].data[i], 0.0);
            if let BlockState::Sparse(blk) = &opt.blocks[0] {
                assert_eq!(blk.errors[0].data[i], g.data[i]);
            }
        }
    }

    #[test]
    fn error_feedback_recovers_full_gradient_over_time() {
        let blocks = one_block(12, 10);
        let mut rng = Xoshiro256::new(4);
        let g = Matrix::gaussian(12, 10, 1.0, &mut rng);
        let mut params = vec![Matrix::zeros(12, 10)];
        let mut opt = TopKAdam::new(
            &blocks,
            1,
            AdamHyper {
                lr: 0.05,
                ..Default::default()
            },
            0.05,
        );
        let mut ledger = CommLedger::new();
        let topo = Topology::single_node(1);
        for _ in 0..200 {
            let mut grads = vec![vec![g.clone()]];
            opt.step(&mut StepCtx {
                params: &mut params,
                grads: &mut grads,
                ledger: &mut ledger,
                topo: &topo,
                lr_mult: 1.0,
                exec: &crate::exec::ExecBackend::Sequential,
            });
            ledger.end_step();
        }
        // Error feedback cycles through coordinates at frequency ∝ |g_i|:
        // within 200 steps all but the smallest-|g| tail must have been
        // transmitted at least once.
        let moved = params[0].data.iter().filter(|v| v.abs() > 1e-4).count();
        assert!(moved > 95, "only {moved}/120 coordinates updated");
        let cos = {
            let num: f32 = params[0].data.iter().zip(&g.data).map(|(a, b)| a * b).sum();
            -num / (params[0].frob_norm() * g.frob_norm())
        };
        assert!(cos > 0.4, "direction cosine {cos}");
    }

    #[test]
    fn state_counts_moments_and_residuals() {
        let blocks = one_block(10, 8);
        let opt = TopKAdam::new(&blocks, 2, AdamHyper::default(), 0.01);
        assert_eq!(opt.state_elements(), 2 * 80 + 2 * 80);
    }
}
