//! TSR-Adam — Algorithm 1 of the paper.
//!
//! Per matrix block W ∈ R^{m×n}: orthonormal bases U (m×r), V (n×r);
//! non-refresh steps synchronize only the core C̄ = AR(Uᵀ G_i V) ∈ R^{r×r}
//! and run AdamW moments in core space; refresh steps (every K) rebuild
//! (U, V) with a *distributed randomized SVD* that all-reduces only the
//! sketches Q̄ (m×k) and B̄ (k×n), never the full gradient (§3.5).
//! Embedding blocks use their own (r_emb, K_emb) (§3.6). Vector blocks
//! (biases/norms) are synchronized and updated densely (§3.4).

use super::{refresh_due, AdamHyper, DenseAdamState, DistOptimizer, StepCtx, SyncItem, SyncPlan};
use crate::comm::{collective, fmt as elem, ElemFmt, LayerClass};
use crate::linalg::{gemm, matrix::Matrix, orth, svd_gram};
use crate::linalg::matmul::{core_project, lift};
use crate::model::BlockSpec;
use crate::util::rng::Xoshiro256;

/// How a refresh rebuilds the bases — Fig. 3(b) ablation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RefreshKind {
    /// Sketch-based distributed randomized SVD (the paper's method):
    /// communicates Q̄ (m×k) + B̄ (k×n) only.
    Randomized,
    /// "Normal SVD" baseline: all-reduce the FULL dense gradient (mn) and
    /// take its exact truncated SVD — the peak-byte hazard TSR removes.
    ExactDense,
}

#[derive(Clone, Debug)]
pub struct TsrConfig {
    /// Rank for Linear blocks.
    pub rank: usize,
    /// Refresh interval K for Linear blocks.
    pub refresh_every: usize,
    /// Embedding-specific rank r_emb (§3.6).
    pub rank_emb: usize,
    /// Embedding-specific refresh interval K_emb.
    pub refresh_emb: usize,
    /// Oversampling p (k = r + p).
    pub oversample: usize,
    /// Power-iteration steps q (Algorithm 1 shows q = 1).
    pub power_q: usize,
    pub refresh_kind: RefreshKind,
    /// Re-orthonormalize Q̄ after averaging (numerical safety; the paper
    /// uses Q̄ directly — averaging nearly-aligned worker bases).
    pub reorth_qbar: bool,
    /// Element format of the steady r×r core sync (DESIGN.md §14).
    /// Narrow formats quantize each worker's projected core with a
    /// per-worker error-feedback residual (0/1-Adam style); the Adam
    /// moments, bases, and refresh sketches stay f32 — the refresh is a
    /// rare peak event and basis quality is what the method lives on.
    pub core_fmt: ElemFmt,
    /// Shared RNG seed for the sketch Ω (identical across workers).
    pub seed: u64,
}

impl Default for TsrConfig {
    fn default() -> Self {
        Self {
            rank: 64,
            refresh_every: 100,
            rank_emb: 32,
            refresh_emb: 100,
            oversample: 8,
            power_q: 1,
            refresh_kind: RefreshKind::Randomized,
            reorth_qbar: true,
            core_fmt: ElemFmt::F32,
            seed: 0x7512_AD,
        }
    }
}

enum BlockState {
    /// Dense AdamW for vector blocks.
    Dense(DenseAdamState),
    LowRank(TsrBlock),
}

struct TsrBlock {
    rank: usize,
    k: usize,
    refresh_every: usize,
    u: Matrix,
    v: Matrix,
    /// Core-space Adam moments (r×r).
    m: Matrix,
    vmom: Matrix,
    /// Per-worker error-feedback residuals for narrow `core_fmt`s
    /// (empty for f32; lazily sized to the world on first quantized
    /// sync). Serialized through `checkpoint::errors_to_json` so a
    /// mid-run kill resumes byte-for-byte.
    errors: Vec<Matrix>,
    refresh_count: u64,
    /// Step at which the bases were first built (None until then) —
    /// the `initialized` flag plus the position `sync_plan` needs to
    /// model it ([`refresh_due`]).
    init_step: Option<u64>,
}

pub struct TsrAdam {
    hyper: AdamHyper,
    cfg: TsrConfig,
    classes: Vec<LayerClass>,
    blocks: Vec<BlockState>,
    t: u64,
}

impl TsrAdam {
    pub fn new(blocks: &[BlockSpec], hyper: AdamHyper, cfg: TsrConfig) -> Self {
        let states = blocks
            .iter()
            .map(|b| {
                if b.class == LayerClass::Vector {
                    BlockState::Dense(DenseAdamState::new(b.rows, b.cols))
                } else {
                    let (r, every) = match b.class {
                        LayerClass::Embedding => (cfg.rank_emb, cfg.refresh_emb),
                        _ => (cfg.rank, cfg.refresh_every),
                    };
                    let r = r.min(b.rows).min(b.cols);
                    let k = (r + cfg.oversample).min(b.rows).min(b.cols);
                    BlockState::LowRank(TsrBlock {
                        rank: r,
                        k,
                        refresh_every: every.max(1),
                        u: Matrix::zeros(b.rows, r),
                        v: Matrix::zeros(b.cols, r),
                        m: Matrix::zeros(r, r),
                        vmom: Matrix::zeros(r, r),
                        errors: Vec::new(),
                        refresh_count: 0,
                        init_step: None,
                    })
                }
            })
            .collect();
        Self {
            hyper,
            cfg,
            classes: blocks.iter().map(|b| b.class).collect(),
            blocks: states,
            t: 0,
        }
    }

    /// Distributed randomized refresh (Algorithm 1, refresh branch).
    ///
    /// Communicates per worker: B̄ (k×n) and Q̄ (m×k). Everything else —
    /// the sketch multiply, QR, and power iterations — is worker-local.
    fn refresh_randomized(
        blk: &mut TsrBlock,
        class: LayerClass,
        block_idx: usize,
        seed: u64,
        power_q: usize,
        reorth: bool,
        grads: &[&Matrix],
        ctx_ledger: &mut crate::comm::CommLedger,
        topo: &crate::comm::Topology,
        exec: &crate::exec::ExecBackend,
    ) {
        let n = grads[0].cols;
        blk.refresh_count += 1;
        // Shared Ω from the common seed: every worker draws the same one.
        let stream = (block_idx as u64) << 32 | blk.refresh_count;
        let mut rng = Xoshiro256::for_stream(seed, stream);
        let omega = Matrix::gaussian(n, blk.k, 1.0, &mut rng);

        // Worker-local sketches + power iterations: the rSVD-refresh hot
        // path, one worker per OS thread on the threaded backend (each
        // worker's sketch reads only its own gradient — backend-exact).
        let pairs: Vec<(Matrix, Matrix)> = exec.map_workers(grads.len(), |i| {
            let g = grads[i];
            let mut q = orth(&gemm(g, false, &omega, false)); // m×k
            for _ in 0..power_q {
                let q_row = orth(&gemm(g, true, &q, false)); // n×k
                q = orth(&gemm(g, false, &q_row, false)); // m×k
            }
            // Worker-local reduced matrix B_i = Q_iᵀ G_i (k×n).
            let b = gemm(&q, true, g, false);
            (q, b)
        });
        let (mut qs, mut bs): (Vec<Matrix>, Vec<Matrix>) = pairs.into_iter().unzip();

        // All-reduce the two sketches — the ONLY refresh communication.
        collective::sync_mean(&mut bs, class, ctx_ledger, topo, exec);
        collective::sync_mean(&mut qs, class, ctx_ledger, topo, exec);
        ctx_ledger.mark_refresh();

        let mut qbar = qs.swap_remove(0);
        if reorth {
            qbar = orth(&qbar);
        }
        let bbar = &bs[0];

        // Small SVD of B̄ (k×n) and base refresh:
        //   U ← Q̄ Ũ[:, :r],  V ← Ṽ[:, :r].
        let (ut, _sigma, vt) = svd_gram(bbar);
        blk.u = gemm(&qbar, false, &ut.take_cols(blk.rank), false);
        blk.v = vt.take_cols(blk.rank);
    }

    /// Fig. 3(b) baseline refresh: dense all-reduce + exact SVD.
    fn refresh_exact_dense(
        blk: &mut TsrBlock,
        class: LayerClass,
        grads: &[&Matrix],
        ctx_ledger: &mut crate::comm::CommLedger,
        topo: &crate::comm::Topology,
        exec: &crate::exec::ExecBackend,
    ) {
        blk.refresh_count += 1;
        let mut dense: Vec<Matrix> = grads.iter().map(|g| (*g).clone()).collect();
        collective::sync_mean(&mut dense, class, ctx_ledger, topo, exec);
        ctx_ledger.mark_refresh();
        let out = crate::linalg::svd_truncated(&dense[0], blk.rank);
        blk.u = out.u;
        blk.v = out.v;
    }
}

impl DistOptimizer for TsrAdam {
    fn name(&self) -> &'static str {
        "tsr-adam"
    }

    fn step(&mut self, ctx: &mut StepCtx) {
        let t = self.t; // 0-indexed step for refresh schedule
        self.t += 1;
        let t1 = self.t; // 1-indexed for bias correction
        let h = self.hyper;
        let tracer = ctx.tracer();
        let nblocks = ctx.params.len();

        for b in 0..nblocks {
            let class = self.classes[b];
            match &mut self.blocks[b] {
                BlockState::Dense(st) => {
                    // §3.4: non-matrix parameters sync dense.
                    let mut per_worker: Vec<_> =
                        ctx.grads.iter().map(|g| g[b].clone()).collect();
                    collective::sync_mean(&mut per_worker, class, ctx.ledger, ctx.topo, ctx.exec);
                    st.update_exec(
                        &mut ctx.params[b],
                        &per_worker[0],
                        &h,
                        ctx.lr_mult,
                        t1,
                        ctx.exec,
                    );
                }
                BlockState::LowRank(blk) => {
                    let grads_b: Vec<&Matrix> = ctx.grads.iter().map(|g| &g[b]).collect();
                    // Shared predicate with sync_plan — at execution
                    // time t IS the next step, so an uninitialized
                    // block always refreshes here.
                    if refresh_due(blk.init_step, t, blk.refresh_every as u64, t) {
                        tracer.event(
                            "refresh",
                            vec![
                                ("block", crate::util::json::Json::num(b as f64)),
                                (
                                    "kind",
                                    crate::util::json::Json::str(match self.cfg.refresh_kind {
                                        RefreshKind::Randomized => "rsvd",
                                        RefreshKind::ExactDense => "exact",
                                    }),
                                ),
                            ],
                        );
                        match self.cfg.refresh_kind {
                            RefreshKind::Randomized => Self::refresh_randomized(
                                blk,
                                class,
                                b,
                                self.cfg.seed,
                                self.cfg.power_q,
                                self.cfg.reorth_qbar,
                                &grads_b,
                                ctx.ledger,
                                ctx.topo,
                                ctx.exec,
                            ),
                            RefreshKind::ExactDense => Self::refresh_exact_dense(
                                blk,
                                class,
                                &grads_b,
                                ctx.ledger,
                                ctx.topo,
                                ctx.exec,
                            ),
                        }
                        if blk.init_step.is_none() {
                            blk.init_step = Some(t);
                        }
                    }

                    // Core synchronization: C_i = Uᵀ G_i V, C̄ = AR(C_i) —
                    // per-worker projections fan out over threads. For
                    // narrow core formats each worker quantizes its
                    // error-compensated core x_i = C_i + e_i onto the
                    // format grid first (0/1-Adam-style error feedback;
                    // DESIGN.md §14), then the collective re-rounds each
                    // reduce hop so the frames stay representable.
                    let mut cores: Vec<Matrix> = {
                        crate::span!(tracer, "project");
                        ctx.exec
                            .map_workers(grads_b.len(), |i| core_project(&blk.u, grads_b[i], &blk.v))
                    };
                    let fmt = self.cfg.core_fmt;
                    if fmt != ElemFmt::F32 {
                        crate::span!(tracer, "quantize_ef");
                        let r = blk.rank;
                        if blk.errors.is_empty() {
                            blk.errors = (0..cores.len()).map(|_| Matrix::zeros(r, r)).collect();
                        }
                        debug_assert_eq!(blk.errors.len(), cores.len(), "EF world mismatch");
                        for (c, e) in cores.iter_mut().zip(blk.errors.iter_mut()) {
                            elem::quantize_ef(fmt, &mut c.data, &mut e.data);
                        }
                    }
                    collective::sync_mean_fmt(&mut cores, class, fmt, ctx.ledger, ctx.topo, ctx.exec);
                    let cbar = &cores[0];

                    // AdamW in core space (§3.4).
                    let b1 = h.beta1;
                    let b2 = h.beta2;
                    let bc1 = 1.0 - b1.powi(t1 as i32);
                    let bc2 = 1.0 - b2.powi(t1 as i32);
                    let r = blk.rank;
                    let mut d = Matrix::zeros(r, r);
                    for i in 0..r * r {
                        let c = cbar.data[i];
                        blk.m.data[i] = b1 * blk.m.data[i] + (1.0 - b1) * c;
                        blk.vmom.data[i] = b2 * blk.vmom.data[i] + (1.0 - b2) * c * c;
                        let mhat = blk.m.data[i] / bc1;
                        let vhat = blk.vmom.data[i] / bc2;
                        d.data[i] = mhat / (vhat.sqrt() + h.eps);
                    }

                    // Lift ΔW = U D Vᵀ and apply W ← W − η(α·ΔW + λW).
                    crate::span!(tracer, "lift");
                    let dw = lift(&blk.u, &d, &blk.v);
                    let lr = h.lr * ctx.lr_mult;
                    let w = &mut ctx.params[b];
                    for i in 0..w.data.len() {
                        w.data[i] -= lr * (h.scale * dw.data[i] + h.weight_decay * w.data[i]);
                    }
                }
            }
        }
    }

    fn sync_plan(&self, t: u64) -> SyncPlan {
        let items = self
            .blocks
            .iter()
            .enumerate()
            .map(|(b, s)| match s {
                BlockState::Dense(st) => SyncItem {
                    block: b,
                    class: self.classes[b],
                    bytes: st.m.numel() * crate::comm::BYTES_F32,
                    fmt: ElemFmt::F32,
                    refresh: false,
                },
                BlockState::LowRank(blk) => {
                    let refresh = refresh_due(blk.init_step, self.t, blk.refresh_every as u64, t);
                    let (m, n) = (blk.u.rows, blk.v.rows);
                    let extra = if !refresh {
                        0
                    } else {
                        match self.cfg.refresh_kind {
                            // Sketches Q̄ (m×k) + B̄ (k×n).
                            RefreshKind::Randomized => m * blk.k + blk.k * n,
                            // Full dense gradient for the exact SVD.
                            RefreshKind::ExactDense => m * n,
                        }
                    };
                    // Steady core at the core format's width; refresh
                    // sketches stay f32 (see `TsrConfig::core_fmt`).
                    let fmt = self.cfg.core_fmt;
                    SyncItem {
                        block: b,
                        class: self.classes[b],
                        bytes: blk.rank * blk.rank * fmt.width() + extra * crate::comm::BYTES_F32,
                        fmt,
                        refresh,
                    }
                }
            })
            .collect();
        SyncPlan { items }
    }

    fn state_elements(&self) -> usize {
        self.blocks
            .iter()
            .map(|s| match s {
                BlockState::Dense(st) => st.elements(),
                // U + V + two core moments (Table 2 TSR row), plus the
                // per-worker EF residuals when the core is quantized.
                BlockState::LowRank(b) => {
                    b.u.numel()
                        + b.v.numel()
                        + b.m.numel()
                        + b.vmom.numel()
                        + b.errors.iter().map(|e| e.numel()).sum::<usize>()
                }
            })
            .sum()
    }

    fn save_state(&self) -> crate::util::json::Json {
        use crate::checkpoint::codec;
        use crate::util::json::Json;
        let blocks = self
            .blocks
            .iter()
            .map(|s| match s {
                BlockState::Dense(st) => Json::obj(vec![
                    ("kind", Json::str("dense")),
                    ("adam", st.state_to_json()),
                ]),
                BlockState::LowRank(b) => {
                    let mut fields = vec![
                        ("kind", Json::str("lowrank")),
                        ("u", codec::matrix_to_json(&b.u)),
                        ("v", codec::matrix_to_json(&b.v)),
                        ("m", codec::matrix_to_json(&b.m)),
                        ("vmom", codec::matrix_to_json(&b.vmom)),
                        ("refresh_count", codec::u64_to_json(b.refresh_count)),
                        ("init_step", codec::opt_u64_to_json(b.init_step)),
                    ];
                    // EF residuals travel with the checkpoint whenever
                    // they exist, so a quantized-core kill resumes
                    // byte-for-byte (absent only before the first
                    // quantized sync, when they are still all-zero).
                    if !b.errors.is_empty() {
                        fields.push(("ef", crate::checkpoint::errors_to_json(&b.errors)));
                    }
                    Json::obj(fields)
                }
            })
            .collect();
        Json::obj(vec![
            ("t", codec::u64_to_json(self.t)),
            ("blocks", Json::arr(blocks)),
        ])
    }

    fn load_state(
        &mut self,
        state: &crate::util::json::Json,
        workers: usize,
    ) -> Result<(), String> {
        use crate::checkpoint::codec;
        let blocks = state.get("blocks").as_arr().ok_or("tsr: missing blocks")?;
        if blocks.len() != self.blocks.len() {
            return Err(format!(
                "tsr: checkpoint has {} blocks, run has {}",
                blocks.len(),
                self.blocks.len()
            ));
        }
        for (i, j) in blocks.iter().enumerate() {
            let what = format!("tsr.blocks[{i}]");
            match (&mut self.blocks[i], j.get("kind").as_str()) {
                (BlockState::Dense(st), Some("dense")) => {
                    st.state_from_json(j.get("adam"), &what)?;
                }
                (BlockState::LowRank(b), Some("lowrank")) => {
                    let (rows, cols) = (b.u.rows, b.v.rows);
                    let r = b.rank;
                    b.u = codec::matrix_from_json_expect(j.get("u"), rows, r, &what)?;
                    b.v = codec::matrix_from_json_expect(j.get("v"), cols, r, &what)?;
                    b.m = codec::matrix_from_json_expect(j.get("m"), r, r, &what)?;
                    b.vmom = codec::matrix_from_json_expect(j.get("vmom"), r, r, &what)?;
                    b.refresh_count =
                        codec::u64_from_json(j.get("refresh_count"), &format!("{what}.count"))?;
                    b.init_step = codec::opt_u64_from_json(
                        codec::require(j, "init_step", &what)?,
                        &format!("{what}.init_step"),
                    )?;
                    // Narrow-core EF residuals: strict restore when the
                    // checkpoint carries them (elastic re-shard on a
                    // world-size change); absent means the run was
                    // saved before its first quantized sync.
                    b.errors = if j.get("ef") == &crate::util::json::Json::Null {
                        Vec::new()
                    } else {
                        crate::checkpoint::errors_from_json(
                            j.get("ef"),
                            r,
                            r,
                            workers,
                            &format!("{what}.ef"),
                        )?
                    };
                }
                (_, kind) => {
                    return Err(format!("{what}: block kind mismatch (checkpoint: {kind:?})"));
                }
            }
        }
        self.t = codec::u64_from_json(state.get("t"), "tsr.t")?;
        Ok(())
    }

    fn seek(&mut self, t: u64) {
        self.t = t;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{CommLedger, Topology};
    use crate::model::ModelSpec;
    use crate::optim::alloc_worker_grads;

    fn run_steps(
        cfg: TsrConfig,
        workers: usize,
        steps: usize,
    ) -> (CommLedger, Vec<Matrix>, TsrAdam) {
        let blocks = ModelSpec::proxy(48, 16, 24, 2, 2).blocks();
        let mut params: Vec<Matrix> = blocks
            .iter()
            .map(|b| Matrix::from_fn(b.rows, b.cols, |i, j| ((i * 7 + j) % 5) as f32 * 0.1))
            .collect();
        let mut opt = TsrAdam::new(&blocks, AdamHyper::default(), cfg);
        let mut ledger = CommLedger::new();
        let topo = Topology::multi_node(2, workers.div_ceil(2));
        let mut rng = Xoshiro256::new(77);
        for _ in 0..steps {
            let mut grads = alloc_worker_grads(&blocks, workers);
            for w in grads.iter_mut() {
                for g in w.iter_mut() {
                    *g = Matrix::gaussian(g.rows, g.cols, 1.0, &mut rng);
                }
            }
            opt.step(&mut StepCtx {
                params: &mut params,
                grads: &mut grads,
                ledger: &mut ledger,
                topo: &topo,
                lr_mult: 1.0,
                exec: &crate::exec::ExecBackend::Sequential,
            });
            ledger.end_step();
        }
        (ledger, params, opt)
    }

    #[test]
    fn non_refresh_steps_sync_only_r2_per_matrix_block() {
        let cfg = TsrConfig {
            rank: 4,
            rank_emb: 4,
            refresh_every: 1000,
            refresh_emb: 1000,
            oversample: 2,
            ..Default::default()
        };
        let (ledger, _, _) = run_steps(cfg, 2, 3);
        // Step 0 refreshes (init); steps 1, 2 must be core-only.
        let blocks = ModelSpec::proxy(48, 16, 24, 2, 2).blocks();
        let matrix_blocks = blocks
            .iter()
            .filter(|b| b.class != LayerClass::Vector)
            .count();
        let vector_elems: usize = blocks
            .iter()
            .filter(|b| b.class == LayerClass::Vector)
            .map(|b| b.numel())
            .sum();
        let expect = (matrix_blocks * 16 + vector_elems) * 4;
        assert_eq!(ledger.step(1).total, expect);
        assert_eq!(ledger.step(2).total, expect);
        assert!(ledger.step(0).total > expect, "refresh step adds sketches");
        assert!(ledger.step(0).refresh);
        assert!(!ledger.step(1).refresh);
    }

    #[test]
    fn refresh_bytes_match_mk_plus_kn() {
        // Single matrix block → refresh payload is exactly (mk + kn + r²)·4
        // plus the dense vector syncs.
        let blocks = vec![BlockSpec {
            name: "w".into(),
            rows: 40,
            cols: 28,
            class: LayerClass::Linear,
        }];
        let cfg = TsrConfig {
            rank: 6,
            oversample: 2,
            refresh_every: 10,
            ..Default::default()
        };
        let mut params = vec![Matrix::zeros(40, 28)];
        let mut opt = TsrAdam::new(&blocks, AdamHyper::default(), cfg);
        let mut ledger = CommLedger::new();
        let topo = Topology::single_node(3);
        let mut rng = Xoshiro256::new(5);
        let mut grads: Vec<Vec<Matrix>> = (0..3)
            .map(|_| vec![Matrix::gaussian(40, 28, 1.0, &mut rng)])
            .collect();
        opt.step(&mut StepCtx {
            params: &mut params,
            grads: &mut grads,
            ledger: &mut ledger,
            topo: &topo,
            lr_mult: 1.0,
            exec: &crate::exec::ExecBackend::Sequential,
        });
        ledger.end_step();
        let k = 8;
        let expect = ((40 * k) + (k * 28) + 6 * 6) * 4;
        assert_eq!(ledger.step(0).total, expect);
    }

    /// Acceptance pin: with `core_fmt = bf16` the metered steady-state
    /// ledger bytes are EXACTLY half the f32 run's core payload (the
    /// dense vector syncs stay f32 in both runs, so the delta is the
    /// core payload's other half). i8 quarters it.
    #[test]
    fn narrow_core_fmt_scales_steady_state_core_bytes_exactly() {
        let base = TsrConfig {
            rank: 4,
            rank_emb: 4,
            refresh_every: 1000,
            refresh_emb: 1000,
            oversample: 2,
            ..Default::default()
        };
        let blocks = ModelSpec::proxy(48, 16, 24, 2, 2).blocks();
        let matrix_blocks = blocks
            .iter()
            .filter(|b| b.class != LayerClass::Vector)
            .count();
        let vector_bytes: usize = blocks
            .iter()
            .filter(|b| b.class == LayerClass::Vector)
            .map(|b| b.numel() * 4)
            .sum();
        let (l32, _, opt32) = run_steps(base.clone(), 2, 3);
        for (fmt, width) in [(ElemFmt::Bf16, 2usize), (ElemFmt::I8, 1usize)] {
            let mut cfg = base.clone();
            cfg.core_fmt = fmt;
            let (ln, _, opt) = run_steps(cfg, 2, 3);
            let steady = matrix_blocks * 16 * width + vector_bytes;
            assert_eq!(ln.step(1).total, steady, "{}", fmt.name());
            assert_eq!(ln.step(2).total, steady, "{}", fmt.name());
            // f32 core payload is matrix_blocks·r²·4; the narrow run
            // drops exactly the missing width fraction of it.
            assert_eq!(
                l32.step(1).total - ln.step(1).total,
                matrix_blocks * 16 * (4 - width),
                "{}",
                fmt.name()
            );
            // The plan agrees with the meter, byte-for-byte.
            assert_eq!(opt.sync_plan(1).total_bytes(), steady, "{}", fmt.name());
            // EF residuals (2 workers × r² per matrix block) are
            // counted as optimizer memory on top of the f32 twin's.
            assert_eq!(
                opt.state_elements(),
                opt32.state_elements() + matrix_blocks * 2 * 16,
                "{}",
                fmt.name()
            );
        }
    }

    /// `sync_plan` and the metered ledger agree for quantized cores on
    /// refresh steps too (sketches priced f32, core at its width).
    #[test]
    fn quantized_core_sync_plan_matches_metered_ledger() {
        for fmt in [ElemFmt::Bf16, ElemFmt::I8] {
            let cfg = TsrConfig {
                rank: 4,
                rank_emb: 4,
                refresh_every: 3,
                refresh_emb: 3,
                oversample: 2,
                core_fmt: fmt,
                ..Default::default()
            };
            let blocks = ModelSpec::proxy(48, 16, 24, 2, 2).blocks();
            let mut params: Vec<Matrix> =
                blocks.iter().map(|b| Matrix::zeros(b.rows, b.cols)).collect();
            let mut opt = TsrAdam::new(&blocks, AdamHyper::default(), cfg);
            let mut ledger = CommLedger::new();
            let topo = Topology::multi_node(2, 1);
            let mut rng = Xoshiro256::new(3);
            for t in 0..5u64 {
                let planned = opt.sync_plan(t).total_bytes();
                let mut grads = alloc_worker_grads(&blocks, 2);
                for w in grads.iter_mut() {
                    for g in w.iter_mut() {
                        *g = Matrix::gaussian(g.rows, g.cols, 1.0, &mut rng);
                    }
                }
                opt.step(&mut StepCtx {
                    params: &mut params,
                    grads: &mut grads,
                    ledger: &mut ledger,
                    topo: &topo,
                    lr_mult: 1.0,
                    exec: &crate::exec::ExecBackend::Sequential,
                });
                ledger.end_step();
                assert_eq!(
                    ledger.step(t as usize).total,
                    planned,
                    "{} step {t}",
                    fmt.name()
                );
            }
        }
    }

    /// EF residuals checkpoint and restore byte-for-byte: an
    /// interrupted bf16-core run continues bitwise-identically to the
    /// uninterrupted one, which fails if the per-worker residuals are
    /// dropped, reordered, or re-quantized on the way through JSON.
    #[test]
    fn quantized_core_resume_is_bitwise_with_ef_state() {
        let cfg = TsrConfig {
            rank: 4,
            rank_emb: 4,
            refresh_every: 3,
            refresh_emb: 3,
            oversample: 2,
            core_fmt: ElemFmt::Bf16,
            ..Default::default()
        };
        let blocks = ModelSpec::proxy(48, 16, 24, 2, 2).blocks();
        let topo = Topology::multi_node(2, 1);
        let step_once = |opt: &mut TsrAdam,
                         params: &mut Vec<Matrix>,
                         ledger: &mut CommLedger,
                         rng: &mut Xoshiro256| {
            let mut grads = alloc_worker_grads(&blocks, 2);
            for w in grads.iter_mut() {
                for g in w.iter_mut() {
                    *g = Matrix::gaussian(g.rows, g.cols, 1.0, rng);
                }
            }
            opt.step(&mut StepCtx {
                params,
                grads: &mut grads,
                ledger,
                topo: &topo,
                lr_mult: 1.0,
                exec: &crate::exec::ExecBackend::Sequential,
            });
            ledger.end_step();
        };

        // Uninterrupted: 7 steps.
        let mut params_a: Vec<Matrix> =
            blocks.iter().map(|b| Matrix::zeros(b.rows, b.cols)).collect();
        let mut opt_a = TsrAdam::new(&blocks, AdamHyper::default(), cfg.clone());
        let mut ledger_a = CommLedger::new();
        let mut rng_a = Xoshiro256::new(11);
        for _ in 0..7 {
            step_once(&mut opt_a, &mut params_a, &mut ledger_a, &mut rng_a);
        }

        // Interrupted at 4: save, rebuild fresh, load, run 3 more with
        // the same gradient stream position.
        let mut params_b: Vec<Matrix> =
            blocks.iter().map(|b| Matrix::zeros(b.rows, b.cols)).collect();
        let mut opt_b = TsrAdam::new(&blocks, AdamHyper::default(), cfg.clone());
        let mut ledger_b = CommLedger::new();
        let mut rng_b = Xoshiro256::new(11);
        for _ in 0..4 {
            step_once(&mut opt_b, &mut params_b, &mut ledger_b, &mut rng_b);
        }
        let saved = opt_b.save_state();
        // The residuals are live (non-trivial) by step 4 — otherwise
        // this test proves nothing about EF serialization.
        let has_live_ef = opt_b.blocks.iter().any(|s| match s {
            BlockState::LowRank(b) => b.errors.iter().any(|e| e.data.iter().any(|&x| x != 0.0)),
            _ => false,
        });
        assert!(has_live_ef, "EF residuals never became non-zero");
        let mut opt_c = TsrAdam::new(&blocks, AdamHyper::default(), cfg);
        opt_c.load_state(&saved, 2).unwrap();
        for _ in 0..3 {
            step_once(&mut opt_c, &mut params_b, &mut ledger_b, &mut rng_b);
        }
        for (a, b) in params_a.iter().zip(params_b.iter()) {
            let (ab, bb): (Vec<u32>, Vec<u32>) = (
                a.data.iter().map(|v| v.to_bits()).collect(),
                b.data.iter().map(|v| v.to_bits()).collect(),
            );
            assert_eq!(ab, bb, "resumed run diverged");
        }
    }

    #[test]
    fn exact_dense_refresh_has_higher_peak() {
        let base = TsrConfig {
            rank: 6,
            rank_emb: 6,
            oversample: 2,
            refresh_every: 4,
            refresh_emb: 4,
            ..Default::default()
        };
        let mut exact = base.clone();
        exact.refresh_kind = RefreshKind::ExactDense;
        let (l_rand, _, _) = run_steps(base, 2, 8);
        let (l_exact, _, _) = run_steps(exact, 2, 8);
        assert!(
            l_exact.peak_bytes() > l_rand.peak_bytes(),
            "dense-SVD refresh must dominate peak: {} vs {}",
            l_exact.peak_bytes(),
            l_rand.peak_bytes()
        );
    }

    #[test]
    fn bases_stay_orthonormal_across_refreshes() {
        let cfg = TsrConfig {
            rank: 5,
            rank_emb: 5,
            refresh_every: 2,
            refresh_emb: 2,
            oversample: 3,
            ..Default::default()
        };
        let (_, _, opt) = run_steps(cfg, 3, 7);
        for st in &opt.blocks {
            if let BlockState::LowRank(b) = st {
                assert!(
                    crate::linalg::ortho_defect(&b.u) < 1e-2,
                    "U defect {}",
                    crate::linalg::ortho_defect(&b.u)
                );
                assert!(crate::linalg::ortho_defect(&b.v) < 1e-2);
            }
        }
    }

    #[test]
    fn state_elements_match_table2() {
        let blocks = vec![BlockSpec {
            name: "w".into(),
            rows: 100,
            cols: 60,
            class: LayerClass::Linear,
        }];
        let cfg = TsrConfig {
            rank: 8,
            ..Default::default()
        };
        let opt = TsrAdam::new(&blocks, AdamHyper::default(), cfg);
        assert_eq!(opt.state_elements(), 100 * 8 + 60 * 8 + 2 * 64);
    }

    #[test]
    fn descends_on_quadratic() {
        // f(W) = ½‖W − W*‖² — TSR-Adam should reduce it substantially.
        let blocks = vec![BlockSpec {
            name: "w".into(),
            rows: 24,
            cols: 18,
            class: LayerClass::Linear,
        }];
        let mut rng = Xoshiro256::new(9);
        let target = Matrix::gaussian(24, 18, 1.0, &mut rng);
        let mut params = vec![Matrix::zeros(24, 18)];
        let cfg = TsrConfig {
            rank: 8,
            oversample: 4,
            refresh_every: 5,
            ..Default::default()
        };
        let mut opt = TsrAdam::new(
            &blocks,
            AdamHyper {
                lr: 0.05,
                ..Default::default()
            },
            cfg,
        );
        let mut ledger = CommLedger::new();
        let topo = Topology::single_node(2);
        let loss0 = params[0].dist(&target);
        for _ in 0..200 {
            let mut grads: Vec<Vec<Matrix>> = (0..2)
                .map(|_| {
                    let mut g = params[0].clone();
                    g.axpy(-1.0, &target);
                    // worker noise
                    let noise = Matrix::gaussian(24, 18, 0.05, &mut rng);
                    g.add_assign(&noise);
                    vec![g]
                })
                .collect();
            opt.step(&mut StepCtx {
                params: &mut params,
                grads: &mut grads,
                ledger: &mut ledger,
                topo: &topo,
                lr_mult: 1.0,
                exec: &crate::exec::ExecBackend::Sequential,
            });
            ledger.end_step();
        }
        let loss1 = params[0].dist(&target);
        assert!(loss1 < 0.5 * loss0, "loss {loss0} -> {loss1}");
    }

    use crate::comm::LayerClass;
    use crate::linalg::Matrix;
    use crate::model::BlockSpec;
    use crate::util::rng::Xoshiro256;
}
