//! TSR-SGD — Algorithm 2 (momentum, no weight decay).
//!
//! The variant analyzed by Theorem 1: the update is
//! `w_{t+1} = w_t − η · U m V ᵀ` with core momentum
//! `m ← β m + (1−β) C̄`. Shares the randomized two-sided refresh with
//! TSR-Adam. Used by the theory-validation experiment (`tsr theory`),
//! which empirically checks the T^{−1/3} stationarity decay.

use super::tsr::TsrConfig;
use super::{refresh_due, DistOptimizer, StepCtx, SyncItem, SyncPlan};
use crate::comm::{collective, LayerClass};
use crate::linalg::matmul::{core_project, lift};
use crate::linalg::{gemm, orth, svd_gram, Matrix};
use crate::model::BlockSpec;
use crate::util::rng::Xoshiro256;

struct SgdBlock {
    rank: usize,
    k: usize,
    refresh_every: usize,
    u: Matrix,
    v: Matrix,
    /// Core momentum (r×r).
    m: Matrix,
    refresh_count: u64,
    /// Step that first built the bases ([`refresh_due`] bookkeeping).
    init_step: Option<u64>,
}

enum BlockState {
    /// Dense momentum SGD for vector blocks.
    Dense { m: Matrix },
    LowRank(SgdBlock),
}

pub struct TsrSgd {
    pub lr: f32,
    pub beta: f32,
    cfg: TsrConfig,
    classes: Vec<LayerClass>,
    blocks: Vec<BlockState>,
    t: u64,
    /// ‖U m Vᵀ (new bases) − U m Vᵀ (old bases)‖² at the last refresh —
    /// the R_t term of Theorem 1, exposed for the theory experiment.
    pub last_refresh_mismatch: f32,
}

impl TsrSgd {
    pub fn new(blocks: &[BlockSpec], lr: f32, beta: f32, cfg: TsrConfig) -> Self {
        let states = blocks
            .iter()
            .map(|b| {
                if b.class == LayerClass::Vector {
                    BlockState::Dense {
                        m: Matrix::zeros(b.rows, b.cols),
                    }
                } else {
                    let (r, every) = match b.class {
                        LayerClass::Embedding => (cfg.rank_emb, cfg.refresh_emb),
                        _ => (cfg.rank, cfg.refresh_every),
                    };
                    let r = r.min(b.rows).min(b.cols);
                    let k = (r + cfg.oversample).min(b.rows).min(b.cols);
                    BlockState::LowRank(SgdBlock {
                        rank: r,
                        k,
                        refresh_every: every.max(1),
                        u: Matrix::zeros(b.rows, r),
                        v: Matrix::zeros(b.cols, r),
                        m: Matrix::zeros(r, r),
                        refresh_count: 0,
                        init_step: None,
                    })
                }
            })
            .collect();
        Self {
            lr,
            beta,
            cfg,
            classes: blocks.iter().map(|b| b.class).collect(),
            blocks: states,
            t: 0,
            last_refresh_mismatch: 0.0,
        }
    }
}

impl DistOptimizer for TsrSgd {
    fn name(&self) -> &'static str {
        "tsr-sgd"
    }

    fn step(&mut self, ctx: &mut StepCtx) {
        let t = self.t;
        self.t += 1;
        let lr = self.lr * ctx.lr_mult;
        let beta = self.beta;
        let tracer = ctx.tracer();

        for b in 0..ctx.params.len() {
            let class = self.classes[b];
            match &mut self.blocks[b] {
                BlockState::Dense { m } => {
                    let mut per_worker: Vec<_> =
                        ctx.grads.iter().map(|g| g[b].clone()).collect();
                    collective::sync_mean(&mut per_worker, class, ctx.ledger, ctx.topo, ctx.exec);
                    let g = &per_worker[0];
                    for i in 0..m.data.len() {
                        m.data[i] = beta * m.data[i] + (1.0 - beta) * g.data[i];
                        ctx.params[b].data[i] -= lr * m.data[i];
                    }
                }
                BlockState::LowRank(blk) => {
                    let grads_b: Vec<&Matrix> = ctx.grads.iter().map(|g| &g[b]).collect();
                    // Shared predicate with sync_plan ([`refresh_due`]).
                    if refresh_due(blk.init_step, t, blk.refresh_every as u64, t) {
                        tracer.event(
                            "refresh",
                            vec![
                                ("block", crate::util::json::Json::num(b as f64)),
                                ("kind", crate::util::json::Json::str("rsvd")),
                            ],
                        );
                        // Record the lifted momentum before the bases move
                        // (for the R_t term of Theorem 1).
                        let lifted_old = if blk.init_step.is_some() {
                            Some(lift(&blk.u, &blk.m, &blk.v))
                        } else {
                            None
                        };

                        blk.refresh_count += 1;
                        let stream = (b as u64) << 32 | blk.refresh_count;
                        let mut rng = Xoshiro256::for_stream(self.cfg.seed, stream);
                        let n = grads_b[0].cols;
                        let omega = Matrix::gaussian(n, blk.k, 1.0, &mut rng);
                        // rSVD sketches: one worker per OS thread on the
                        // threaded backend (same fan-out as TSR-Adam).
                        let power_q = self.cfg.power_q;
                        let pairs: Vec<(Matrix, Matrix)> =
                            ctx.exec.map_workers(grads_b.len(), |i| {
                                let g = grads_b[i];
                                let mut q = orth(&gemm(g, false, &omega, false));
                                for _ in 0..power_q {
                                    let q_row = orth(&gemm(g, true, &q, false));
                                    q = orth(&gemm(g, false, &q_row, false));
                                }
                                let bmat = gemm(&q, true, g, false);
                                (q, bmat)
                            });
                        let (mut qs, mut bs): (Vec<Matrix>, Vec<Matrix>) =
                            pairs.into_iter().unzip();
                        collective::sync_mean(&mut bs, class, ctx.ledger, ctx.topo, ctx.exec);
                        collective::sync_mean(&mut qs, class, ctx.ledger, ctx.topo, ctx.exec);
                        ctx.ledger.mark_refresh();
                        let mut qbar = qs.swap_remove(0);
                        if self.cfg.reorth_qbar {
                            qbar = orth(&qbar);
                        }
                        let (ut, _s, vt) = svd_gram(&bs[0]);
                        let u_new = gemm(&qbar, false, &ut.take_cols(blk.rank), false);
                        let v_new = vt.take_cols(blk.rank);

                        // Re-express the momentum in the new bases via the
                        // refresh-alignment projection (Theorem 1's
                        // assumption): m' = U'ᵀ (U m Vᵀ) V'.
                        if let Some(lifted) = lifted_old {
                            blk.m = core_project(&u_new, &lifted, &v_new);
                            let lifted_new = lift(&u_new, &blk.m, &v_new);
                            self.last_refresh_mismatch = lifted_new.dist(&lifted).powi(2);
                        }
                        blk.u = u_new;
                        blk.v = v_new;
                        if blk.init_step.is_none() {
                            blk.init_step = Some(t);
                        }
                    }

                    let mut cores: Vec<Matrix> = {
                        crate::span!(tracer, "project");
                        ctx.exec
                            .map_workers(grads_b.len(), |i| core_project(&blk.u, grads_b[i], &blk.v))
                    };
                    collective::sync_mean(&mut cores, class, ctx.ledger, ctx.topo, ctx.exec);
                    let cbar = &cores[0];

                    for i in 0..blk.m.data.len() {
                        blk.m.data[i] = beta * blk.m.data[i] + (1.0 - beta) * cbar.data[i];
                    }
                    let dw = lift(&blk.u, &blk.m, &blk.v);
                    let w = &mut ctx.params[b];
                    for i in 0..w.data.len() {
                        w.data[i] -= lr * dw.data[i];
                    }
                }
            }
        }
    }

    fn sync_plan(&self, t: u64) -> SyncPlan {
        // Same schedule as TSR-Adam's randomized path: r×r core each
        // step, sketches Q̄ + B̄ on refresh steps.
        let items = self
            .blocks
            .iter()
            .enumerate()
            .map(|(b, s)| match s {
                BlockState::Dense { m } => SyncItem {
                    block: b,
                    class: self.classes[b],
                    bytes: m.numel() * crate::comm::BYTES_F32,
                    fmt: crate::comm::ElemFmt::F32,
                    refresh: false,
                },
                BlockState::LowRank(blk) => {
                    let refresh = refresh_due(blk.init_step, self.t, blk.refresh_every as u64, t);
                    let (m, n) = (blk.u.rows, blk.v.rows);
                    let extra = if refresh { m * blk.k + blk.k * n } else { 0 };
                    SyncItem {
                        block: b,
                        class: self.classes[b],
                        bytes: (blk.rank * blk.rank + extra) * crate::comm::BYTES_F32,
                        fmt: crate::comm::ElemFmt::F32,
                        refresh,
                    }
                }
            })
            .collect();
        SyncPlan { items }
    }

    fn state_elements(&self) -> usize {
        self.blocks
            .iter()
            .map(|s| match s {
                BlockState::Dense { m } => m.numel(),
                BlockState::LowRank(b) => b.u.numel() + b.v.numel() + b.m.numel(),
            })
            .sum()
    }

    fn save_state(&self) -> crate::util::json::Json {
        use crate::checkpoint::codec;
        use crate::util::json::Json;
        let blocks = self
            .blocks
            .iter()
            .map(|s| match s {
                BlockState::Dense { m } => Json::obj(vec![
                    ("kind", Json::str("dense")),
                    ("m", codec::matrix_to_json(m)),
                ]),
                BlockState::LowRank(b) => Json::obj(vec![
                    ("kind", Json::str("lowrank")),
                    ("u", codec::matrix_to_json(&b.u)),
                    ("v", codec::matrix_to_json(&b.v)),
                    ("m", codec::matrix_to_json(&b.m)),
                    ("refresh_count", codec::u64_to_json(b.refresh_count)),
                    ("init_step", codec::opt_u64_to_json(b.init_step)),
                ]),
            })
            .collect();
        Json::obj(vec![
            ("t", codec::u64_to_json(self.t)),
            ("last_refresh_mismatch", codec::f32_to_json(self.last_refresh_mismatch)),
            ("blocks", Json::arr(blocks)),
        ])
    }

    fn load_state(
        &mut self,
        state: &crate::util::json::Json,
        _workers: usize,
    ) -> Result<(), String> {
        use crate::checkpoint::codec;
        let blocks = state.get("blocks").as_arr().ok_or("tsr-sgd: missing blocks")?;
        if blocks.len() != self.blocks.len() {
            return Err(format!(
                "tsr-sgd: checkpoint has {} blocks, run has {}",
                blocks.len(),
                self.blocks.len()
            ));
        }
        for (i, j) in blocks.iter().enumerate() {
            let what = format!("tsr-sgd.blocks[{i}]");
            match (&mut self.blocks[i], j.get("kind").as_str()) {
                (BlockState::Dense { m }, Some("dense")) => {
                    *m = codec::matrix_from_json_expect(j.get("m"), m.rows, m.cols, &what)?;
                }
                (BlockState::LowRank(b), Some("lowrank")) => {
                    let (rows, cols) = (b.u.rows, b.v.rows);
                    let r = b.rank;
                    b.u = codec::matrix_from_json_expect(j.get("u"), rows, r, &what)?;
                    b.v = codec::matrix_from_json_expect(j.get("v"), cols, r, &what)?;
                    b.m = codec::matrix_from_json_expect(j.get("m"), r, r, &what)?;
                    b.refresh_count =
                        codec::u64_from_json(j.get("refresh_count"), &format!("{what}.count"))?;
                    b.init_step = codec::opt_u64_from_json(
                        codec::require(j, "init_step", &what)?,
                        &format!("{what}.init_step"),
                    )?;
                }
                (_, kind) => {
                    return Err(format!("{what}: block kind mismatch (checkpoint: {kind:?})"));
                }
            }
        }
        self.t = codec::u64_from_json(state.get("t"), "tsr-sgd.t")?;
        self.last_refresh_mismatch = codec::f32_from_json(
            state.get("last_refresh_mismatch"),
            "tsr-sgd.last_refresh_mismatch",
        )?;
        Ok(())
    }

    fn seek(&mut self, t: u64) {
        self.t = t;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{CommLedger, Topology};

    #[test]
    fn converges_on_strongly_convex_quadratic() {
        let blocks = vec![BlockSpec {
            name: "w".into(),
            rows: 20,
            cols: 16,
            class: LayerClass::Linear,
        }];
        let mut rng = Xoshiro256::new(21);
        let target = Matrix::gaussian(20, 16, 1.0, &mut rng);
        let mut params = vec![Matrix::zeros(20, 16)];
        let cfg = TsrConfig {
            rank: 8,
            oversample: 4,
            refresh_every: 10,
            refresh_kind: crate::optim::RefreshKind::Randomized,
            ..Default::default()
        };
        let mut opt = TsrSgd::new(&blocks, 0.3, 0.9, cfg);
        let mut ledger = CommLedger::new();
        let topo = Topology::single_node(2);
        let l0 = params[0].dist(&target);
        for _ in 0..120 {
            let mut grads: Vec<Vec<Matrix>> = (0..2)
                .map(|_| {
                    let mut g = params[0].clone();
                    g.axpy(-1.0, &target);
                    vec![g]
                })
                .collect();
            opt.step(&mut StepCtx {
                params: &mut params,
                grads: &mut grads,
                ledger: &mut ledger,
                topo: &topo,
                lr_mult: 1.0,
                exec: &crate::exec::ExecBackend::Sequential,
            });
            ledger.end_step();
        }
        let l1 = params[0].dist(&target);
        assert!(l1 < 0.25 * l0, "{l0} -> {l1}");
    }

    #[test]
    fn refresh_mismatch_is_finite_and_small_for_stable_gradients() {
        let blocks = vec![BlockSpec {
            name: "w".into(),
            rows: 24,
            cols: 24,
            class: LayerClass::Linear,
        }];
        let mut rng = Xoshiro256::new(22);
        // Fixed low-rank gradient → subspace is stable → R_t ≈ 0 after
        // the first refresh re-expression.
        let a = Matrix::gaussian(24, 4, 1.0, &mut rng);
        let bmat = Matrix::gaussian(4, 24, 1.0, &mut rng);
        let gfix = gemm(&a, false, &bmat, false);
        let mut params = vec![Matrix::zeros(24, 24)];
        let cfg = TsrConfig {
            rank: 6,
            oversample: 4,
            refresh_every: 3,
            ..Default::default()
        };
        let mut opt = TsrSgd::new(&blocks, 0.01, 0.9, cfg);
        let mut ledger = CommLedger::new();
        let topo = Topology::single_node(1);
        for _ in 0..10 {
            let mut grads = vec![vec![gfix.clone()]];
            opt.step(&mut StepCtx {
                params: &mut params,
                grads: &mut grads,
                ledger: &mut ledger,
                topo: &topo,
                lr_mult: 1.0,
                exec: &crate::exec::ExecBackend::Sequential,
            });
            ledger.end_step();
        }
        assert!(opt.last_refresh_mismatch.is_finite());
        assert!(
            opt.last_refresh_mismatch < 1e-3,
            "stable subspace should give tiny R_t, got {}",
            opt.last_refresh_mismatch
        );
    }

    use crate::comm::LayerClass;
    use crate::linalg::Matrix;
    use crate::model::BlockSpec;
    use crate::util::rng::Xoshiro256;
}
