//! Failure-injection drills (DESIGN.md §11).
//!
//! A [`Drill`] kills a training run at an arbitrary step *through the
//! checkpoint subsystem* — the manifest goes through a full JSON text
//! round trip and every live object is dropped, exactly what a process
//! death plus restart does — then resumes elastically and verifies the
//! outcome against one of two tiers:
//!
//! * **bitwise** (same world size, either backend): the resumed run's
//!   deterministic metrics JSON — weights fingerprint and every ledger
//!   column included — must equal the uninterrupted run's byte for
//!   byte (the DESIGN.md §9 resume contract, now exercised by a
//!   harness instead of only by tests);
//! * **tolerance** (changed world size): bitwise equality is impossible
//!   (the noise stream fans out differently and error-feedback buffers
//!   are re-sharded from their canonical mean), so the post-resume loss
//!   trajectory on the quadratic source must track the uninterrupted
//!   run within a relative tolerance.
//!
//! `tsr soak` runs one drill per (workers × topology × method) cell;
//! `tests/resilience.rs` pins both tiers across both exec backends.

use crate::checkpoint::Checkpoint;
use crate::comm::{CommLedger, Topology};
use crate::exec::ExecBackend;
use crate::exp::MethodCfg;
use crate::linalg::Matrix;
use crate::metrics::RunMetrics;
use crate::model::ModelSpec;
use crate::obs::{analyze, Tracer};
use crate::optim::{AdamHyper, DistOptimizer, LrSchedule};
use crate::train::gradsim::QuadraticSim;
use crate::train::{GradSource, Trainer};
use crate::util::json::Json;

/// One drill's scenario: which run to kill, where, and on what cluster.
#[derive(Clone, Debug)]
pub struct DrillCfg {
    pub method: MethodCfg,
    pub spec: ModelSpec,
    /// World size of the original (killed) run.
    pub workers: usize,
    /// Total optimizer steps of the uninterrupted reference run.
    pub steps: usize,
    /// Step at which the run is killed (checkpoint + drop everything).
    pub kill_at: usize,
    pub seed: u64,
    /// Gradient-noise scale of the quadratic source.
    pub noise: f32,
    pub hyper: AdamHyper,
    pub topo: Topology,
    pub exec: ExecBackend,
    /// Attach a deterministic [`Tracer`] to the reference and resumed
    /// runs and verify the §16 resume-boundary contract: the resumed
    /// trace's tail must equal the uninterrupted trace's tail byte for
    /// byte (same world size only — elastic resumes change the wire
    /// splits).
    pub trace: bool,
}

impl DrillCfg {
    /// A tiny quadratic-source scenario (sized for test/soak budgets).
    pub fn quick(method: MethodCfg, workers: usize, steps: usize, kill_at: usize) -> Self {
        assert!(kill_at > 0 && kill_at < steps, "kill_at must be mid-run");
        Self {
            method,
            spec: ModelSpec::proxy(200, 32, 64, 2, 2),
            workers,
            steps,
            kill_at,
            seed: 11,
            noise: 0.01,
            hyper: AdamHyper {
                lr: 0.05,
                weight_decay: 0.0,
                scale: 1.0,
                ..Default::default()
            },
            topo: Topology::multi_node(2, workers.div_ceil(2)),
            exec: ExecBackend::Sequential,
            trace: false,
        }
    }
}

/// Outcome of one kill + resume, against the uninterrupted reference.
#[derive(Clone, Debug)]
pub struct DrillReport {
    pub method: String,
    /// World size the run resumed at.
    pub resume_workers: usize,
    /// Whether this was an elastic (changed world size) resume.
    pub elastic: bool,
    /// Deterministic metrics JSONs byte-identical (the §9 contract).
    pub bitwise: bool,
    /// `Some(ok)` when the drill was traced: whether the resumed
    /// trace's tail equals the full run's (the §16 resume-boundary
    /// contract, via [`analyze::tail_after`]). `None` untraced.
    pub trace_tail_match: Option<bool>,
    pub full_final_loss: f64,
    pub resumed_final_loss: f64,
    /// Mean relative loss deviation over the post-resume steps:
    /// `mean_t |l_res[t] − l_full[t]| / (mean_t |l_full[t]| + ε)`.
    pub traj_delta_rel: f64,
}

impl DrillReport {
    /// Panic unless the applicable verification tier holds: same-world
    /// resumes must be bitwise; elastic resumes must stay within `tol`
    /// relative trajectory deviation.
    pub fn assert_contract(&self, tol: f64) {
        if self.elastic {
            assert!(
                self.traj_delta_rel < tol,
                "{}: elastic resume at {} workers drifted {:.4} rel (tol {tol})",
                self.method,
                self.resume_workers,
                self.traj_delta_rel,
            );
        } else {
            assert!(
                self.bitwise,
                "{}: same-world resume at {} workers broke the bitwise contract",
                self.method,
                self.resume_workers,
            );
            if let Some(ok) = self.trace_tail_match {
                assert!(
                    ok,
                    "{}: same-world resume at {} workers broke the trace resume-boundary contract",
                    self.method,
                    self.resume_workers,
                );
            }
        }
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj(vec![
            ("method", Json::str(self.method.clone())),
            ("resume_workers", Json::num(self.resume_workers as f64)),
            ("elastic", Json::Bool(self.elastic)),
            ("bitwise", Json::Bool(self.bitwise)),
            ("full_final_loss", Json::num(self.full_final_loss)),
            ("resumed_final_loss", Json::num(self.resumed_final_loss)),
            ("post_resume_loss_delta", Json::num(self.traj_delta_rel)),
        ]);
        if let Some(ok) = self.trace_tail_match {
            j.set("trace_tail_match", Json::Bool(ok));
        }
        j
    }
}

/// A prepared kill: the uninterrupted reference run's outputs plus the
/// manifest text that survived the "process death". `resume` can then
/// be called repeatedly (same or changed world size) — the manifest is
/// re-parsed from text each time, as a restart would.
pub struct Drill {
    cfg: DrillCfg,
    /// Uninterrupted run: deterministic metrics JSON + loss trajectory.
    full_json: String,
    full_losses: Vec<f32>,
    /// The checkpoint manifest as serialized text — all that's left of
    /// the killed run.
    ckpt_text: String,
    /// Reference run's deterministic trace records (traced drills only).
    full_trace: Option<Vec<Json>>,
}

impl Drill {
    fn setup(
        cfg: &DrillCfg,
        workers: usize,
    ) -> (QuadraticSim, Box<dyn DistOptimizer>, Vec<Matrix>) {
        let intrinsic = (cfg.spec.hidden / 2).max(8);
        let sim = QuadraticSim::new(&cfg.spec, workers, intrinsic, cfg.noise, cfg.seed);
        let blocks = sim.blocks().to_vec();
        let opt = cfg.method.build(&blocks, cfg.hyper, workers);
        let params = sim.init_params(cfg.seed ^ 0xF00D);
        (sim, opt, params)
    }

    fn trainer(cfg: &DrillCfg) -> Trainer {
        Trainer::new(cfg.topo.clone(), LrSchedule::paper(cfg.steps)).with_backend(cfg.exec)
    }

    /// Run the uninterrupted reference AND the killed run (to
    /// `kill_at`), capturing the manifest through a full JSON text
    /// round trip and dropping every live object.
    pub fn prepare(cfg: DrillCfg) -> Self {
        // Reference: the run nothing ever happened to (traced drills
        // attach a deterministic tracer to its ledger).
        let (mut sim, mut opt, mut params) = Self::setup(&cfg, cfg.workers);
        let tracer = if cfg.trace { Tracer::new() } else { Tracer::default() };
        tracer.meta(opt.name(), cfg.workers);
        let mut ledger0 = CommLedger::new();
        ledger0.set_tracer(tracer.clone());
        let (metrics, ledger) = Self::trainer(&cfg).run_from(
            &mut sim,
            opt.as_mut(),
            &mut params,
            0,
            cfg.steps,
            RunMetrics::new(opt.name()),
            ledger0,
        );
        let full_json = metrics.to_json_deterministic(&ledger, &params).to_string_pretty();
        let full_losses = metrics.loss.clone();
        let full_trace = cfg.trace.then(|| tracer.records());
        drop((sim, opt, params, metrics, ledger));

        // The victim: killed at kill_at, surviving only as manifest text.
        let (mut sim, mut opt, mut params) = Self::setup(&cfg, cfg.workers);
        let (metrics, ledger) =
            Self::trainer(&cfg).run(&mut sim, opt.as_mut(), &mut params, cfg.kill_at);
        let ck = Checkpoint::capture(
            cfg.kill_at as u64,
            cfg.workers,
            &params,
            opt.as_ref(),
            &sim,
            &metrics,
            &ledger,
            Json::Null,
        );
        let ckpt_text = ck.to_json().to_string_pretty();
        drop((sim, opt, params, metrics, ledger));

        Self {
            cfg,
            full_json,
            full_losses,
            ckpt_text,
            full_trace,
        }
    }

    /// The uninterrupted run's deterministic metrics JSON.
    pub fn full_json(&self) -> &str {
        &self.full_json
    }

    /// The uninterrupted run's trace records (traced drills only).
    pub fn full_trace(&self) -> Option<&[Json]> {
        self.full_trace.as_deref()
    }

    /// Resume the killed run at `resume_workers` (the "new process":
    /// everything rebuilt from scratch plus the manifest text) and
    /// compare against the uninterrupted reference.
    pub fn resume(&self, resume_workers: usize) -> DrillReport {
        let cfg = &self.cfg;
        let ck = Checkpoint::from_json(&Json::parse(&self.ckpt_text).expect("manifest parses"))
            .expect("manifest loads");
        assert_eq!(ck.step, cfg.kill_at as u64);

        let (mut sim, mut opt, _) = Self::setup(cfg, resume_workers);
        assert_eq!(opt.name(), ck.method, "method guard");
        opt.load_state(&ck.opt_state, resume_workers)
            .expect("optimizer state restores");
        sim.load_state(&ck.source_state).expect("source state restores");
        let mut params = ck.params.clone();
        let metrics = RunMetrics::state_from_json(&ck.metrics).expect("metrics restore");
        let mut ledger = CommLedger::from_json(&ck.ledger).expect("ledger restores");
        // Trace state is never serialized into manifests: the "new
        // process" re-attaches a fresh tracer and marks the boundary.
        let tracer = if cfg.trace { Tracer::new() } else { Tracer::default() };
        tracer.meta(opt.name(), resume_workers);
        tracer.resume(cfg.kill_at as u64, resume_workers);
        ledger.set_tracer(tracer.clone());
        let (metrics, ledger) = Self::trainer(cfg).run_from(
            &mut sim,
            opt.as_mut(),
            &mut params,
            cfg.kill_at,
            cfg.steps,
            metrics,
            ledger,
        );
        let resumed_json = metrics.to_json_deterministic(&ledger, &params).to_string_pretty();

        // Post-resume trajectory deviation (f64, order-stable sums).
        let mut dev = 0.0f64;
        let mut mag = 0.0f64;
        for t in cfg.kill_at..cfg.steps {
            let f = self.full_losses[t] as f64;
            let r = metrics.loss[t] as f64;
            dev += (r - f).abs();
            mag += f.abs();
        }
        let n = (cfg.steps - cfg.kill_at) as f64;
        let traj_delta_rel = (dev / n) / (mag / n + 1e-12);

        // Elastic resumes change the wire splits, so the tail contract
        // only applies (and is only reported) at the same world size.
        let trace_tail_match = self.full_trace.as_ref().filter(|_| resume_workers == cfg.workers).map(
            |full| {
                analyze::tail_after(&tracer.records(), cfg.kill_at as u64)
                    == analyze::tail_after(full, cfg.kill_at as u64)
            },
        );

        DrillReport {
            method: cfg.method.label(),
            resume_workers,
            elastic: resume_workers != cfg.workers,
            bitwise: resumed_json == self.full_json,
            trace_tail_match,
            full_final_loss: {
                let mut m = RunMetrics::new("full");
                m.loss = self.full_losses.clone();
                m.final_loss() as f64
            },
            resumed_final_loss: metrics.final_loss() as f64,
            traj_delta_rel,
        }
    }
}

/// The elastic partner world size drilled alongside a same-world
/// resume: shrink by one worker (grow when too small to shrink), so
/// every drill exercises the mean-reshard path with a different — and
/// for odd sizes ragged — shard split.
pub fn elastic_partner(workers: usize) -> usize {
    if workers < 4 {
        workers + 1
    } else {
        workers - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elastic_partner_always_differs_and_stays_positive() {
        for w in 1..=16 {
            let p = elastic_partner(w);
            assert_ne!(p, w);
            assert!(p >= 1);
        }
    }

    #[test]
    fn same_world_drill_is_bitwise_for_adamw() {
        let drill = Drill::prepare(DrillCfg::quick(MethodCfg::Adam, 2, 9, 4));
        let report = drill.resume(2);
        assert!(!report.elastic);
        assert!(report.bitwise);
        assert_eq!(report.traj_delta_rel, 0.0);
        report.assert_contract(0.5);
    }
}
