//! PJRT runtime: load AOT artifacts (HLO text) and execute them from the
//! Rust step loop. Python never runs here — `make artifacts` is the only
//! place JAX executes.
//!
//! Interchange is HLO *text*: jax ≥ 0.5 serializes HloModuleProto with
//! 64-bit instruction ids that the image's xla_extension 0.5.1 rejects;
//! the text parser reassigns ids (see /opt/xla-example/README.md).

use super::manifest::Manifest;
use crate::linalg::Matrix;

pub struct Engine {
    client: xla::PjRtClient,
}

impl Engine {
    pub fn cpu() -> Result<Self, String> {
        Ok(Self {
            client: xla::PjRtClient::cpu().map_err(|e| e.to_string())?,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact.
    pub fn load_hlo(&self, path: &std::path::Path) -> Result<Executable, String> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| format!("parse {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| format!("compile {}: {e}", path.display()))?;
        Ok(Executable { exe })
    }

    /// Load a train-step model described by a manifest.
    pub fn load_model(&self, manifest: Manifest) -> Result<TrainStepModel, String> {
        let exe = self.load_hlo(&manifest.hlo)?;
        Ok(TrainStepModel { exe, manifest })
    }
}

pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with literal inputs; outputs are flattened from the
    /// (return_tuple=True) single tuple result.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>, String> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| e.to_string())?;
        let lit = result[0][0].to_literal_sync().map_err(|e| e.to_string())?;
        lit.to_tuple().map_err(|e| e.to_string())
    }
}

/// The lowered L2 train step: (params..., tokens) → (loss, grads...).
pub struct TrainStepModel {
    exe: Executable,
    pub manifest: Manifest,
}

impl TrainStepModel {
    /// Run one worker's forward+backward. `tokens` is the flat
    /// `[batch, seq+1]` block from the batcher.
    pub fn step(&self, params: &[Matrix], tokens: &[u32]) -> Result<(f32, Vec<Matrix>), String> {
        let m = &self.manifest;
        assert_eq!(params.len(), m.params.len(), "param arity mismatch");
        assert_eq!(tokens.len(), m.batch * (m.seq + 1), "token block size");

        let mut inputs = Vec::with_capacity(params.len() + 1);
        for (mat, info) in params.iter().zip(&m.params) {
            inputs.push(matrix_to_literal(mat, &info.shape)?);
        }
        let tok_i32: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        let tok_lit = xla::Literal::vec1(&tok_i32)
            .reshape(&[m.batch as i64, (m.seq + 1) as i64])
            .map_err(|e| e.to_string())?;
        inputs.push(tok_lit);

        let outs = self.exe.run(&inputs)?;
        if outs.len() != 1 + params.len() {
            return Err(format!(
                "expected 1+{} outputs, got {}",
                params.len(),
                outs.len()
            ));
        }
        let loss = outs[0]
            .to_vec::<f32>()
            .map_err(|e| e.to_string())?
            .first()
            .copied()
            .ok_or("empty loss literal")?;
        let mut grads = Vec::with_capacity(params.len());
        for (lit, info) in outs[1..].iter().zip(&m.params) {
            grads.push(literal_to_matrix(lit, &info.shape)?);
        }
        Ok((loss, grads))
    }
}

fn matrix_to_literal(mat: &Matrix, shape: &[usize]) -> Result<xla::Literal, String> {
    let lit = xla::Literal::vec1(&mat.data);
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    let expect: usize = shape.iter().product();
    if expect != mat.numel() {
        return Err(format!("shape {shape:?} vs matrix {}x{}", mat.rows, mat.cols));
    }
    lit.reshape(&dims).map_err(|e| e.to_string())
}

fn literal_to_matrix(lit: &xla::Literal, shape: &[usize]) -> Result<Matrix, String> {
    let data = lit.to_vec::<f32>().map_err(|e| e.to_string())?;
    let (rows, cols) = match shape.len() {
        1 => (1, shape[0]),
        2 => (shape[0], shape[1]),
        d => return Err(format!("unsupported rank {d}")),
    };
    if data.len() != rows * cols {
        return Err(format!("literal size {} vs {rows}x{cols}", data.len()));
    }
    Ok(Matrix::from_vec(rows, cols, data))
}
