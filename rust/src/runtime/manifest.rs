//! Artifact manifest — the contract between `python/compile/aot.py`
//! (which lowers the JAX model and writes `artifacts/manifest.json`) and
//! the Rust runtime (which loads the HLO and marshals parameters).

use crate::comm::LayerClass;
use crate::model::BlockSpec;
use crate::util::json::Json;
use std::path::{Path, PathBuf};

#[derive(Clone, Debug)]
pub struct ParamInfo {
    pub name: String,
    /// Original tensor shape as lowered ([n] for vectors, [m, n] for mats).
    pub shape: Vec<usize>,
    pub class: LayerClass,
}

impl ParamInfo {
    /// As a 2-D block (vectors become 1×n).
    pub fn as_block(&self) -> BlockSpec {
        let (rows, cols) = match self.shape.len() {
            1 => (1, self.shape[0]),
            2 => (self.shape[0], self.shape[1]),
            d => panic!("unsupported param rank {d} for {}", self.name),
        };
        BlockSpec {
            name: self.name.clone(),
            rows,
            cols,
            class: self.class,
        }
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub name: String,
    /// HLO text file, relative to the manifest's directory.
    pub hlo: PathBuf,
    pub vocab: usize,
    pub hidden: usize,
    pub layers: usize,
    pub batch: usize,
    pub seq: usize,
    pub params: Vec<ParamInfo>,
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Self, String> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        let json = Json::parse(&text).map_err(|e| format!("{}: {e}", path.display()))?;
        let dir = path.parent().unwrap_or(Path::new("."));
        Self::from_json(&json, dir)
    }

    pub fn from_json(json: &Json, dir: &Path) -> Result<Self, String> {
        let params = json
            .get("params")
            .as_arr()
            .ok_or("manifest missing 'params'")?
            .iter()
            .map(|p| {
                let name = p.get_str("name", "?").to_string();
                let shape: Vec<usize> = p
                    .get("shape")
                    .as_arr()
                    .ok_or_else(|| format!("param {name} missing shape"))?
                    .iter()
                    .map(|d| d.as_usize().unwrap_or(0))
                    .collect();
                let class = match p.get_str("class", "linear") {
                    "embedding" => LayerClass::Embedding,
                    "vector" => LayerClass::Vector,
                    _ => LayerClass::Linear,
                };
                Ok(ParamInfo { name, shape, class })
            })
            .collect::<Result<Vec<_>, String>>()?;
        Ok(Self {
            name: json.get_str("name", "model").to_string(),
            hlo: dir.join(json.get_str("hlo", "model.hlo.txt")),
            vocab: json.get_usize("vocab", 0),
            hidden: json.get_usize("hidden", 0),
            layers: json.get_usize("layers", 0),
            batch: json.get_usize("batch", 0),
            seq: json.get_usize("seq", 0),
            params,
        })
    }

    pub fn blocks(&self) -> Vec<BlockSpec> {
        self.params.iter().map(|p| p.as_block()).collect()
    }

    pub fn param_count(&self) -> usize {
        self.params
            .iter()
            .map(|p| p.shape.iter().product::<usize>())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "name": "tiny", "hlo": "tiny.hlo.txt",
        "vocab": 256, "hidden": 64, "layers": 2, "batch": 8, "seq": 32,
        "params": [
            {"name": "embed_tokens", "shape": [256, 64], "class": "embedding"},
            {"name": "layers.0.attn.q_proj", "shape": [64, 64], "class": "linear"},
            {"name": "final_norm", "shape": [64], "class": "vector"}
        ]
    }"#;

    #[test]
    fn parses_sample() {
        let j = Json::parse(SAMPLE).unwrap();
        let m = Manifest::from_json(&j, Path::new("/tmp/artifacts")).unwrap();
        assert_eq!(m.name, "tiny");
        assert_eq!(m.hlo, Path::new("/tmp/artifacts/tiny.hlo.txt"));
        assert_eq!(m.params.len(), 3);
        let blocks = m.blocks();
        assert_eq!(blocks[0].class, LayerClass::Embedding);
        assert_eq!(blocks[2].rows, 1);
        assert_eq!(blocks[2].cols, 64);
        assert_eq!(m.param_count(), 256 * 64 + 64 * 64 + 64);
    }

    #[test]
    fn missing_params_is_error() {
        let j = Json::parse(r#"{"name": "x"}"#).unwrap();
        assert!(Manifest::from_json(&j, Path::new(".")).is_err());
    }
}
