//! PJRT runtime: HLO-text artifact loading and execution (the bridge to
//! the L2 JAX model and L1 Pallas kernels compiled by `make artifacts`).

pub mod engine;
pub mod manifest;

pub use engine::{Engine, Executable, TrainStepModel};
pub use manifest::{Manifest, ParamInfo};
