//! Deterministic adversity models for the discrete-event engine:
//! stragglers and link jitter (DESIGN.md §11).
//!
//! Both models are *seeded and pure* — the same configuration always
//! produces the same perturbation, so every `tsr soak` sweep (and the
//! CI leg that runs it twice and diffs the JSON) stays byte-identical.
//!
//! * [`StragglerModel`] — per-worker compute-time multipliers `m_w ≥ 1`.
//!   Data-parallel collectives are synchronous, so a degraded worker
//!   (preempted, thermally throttled, failing HBM) paces the whole
//!   group: gradients become ready at `max_w m_w` × the nominal time,
//!   and every ring step waits on the slow participant's injection, so
//!   per-bucket collective cost scales by the same factor. The healthy
//!   workers' wasted capacity is reported as `straggler_idle_secs`.
//! * [`JitterModel`] — per-step multiplicative α–β perturbations of the
//!   [`Topology`] channels (bandwidth divided, latency multiplied by a
//!   factor in `[1, 1+amp]`), resampled deterministically per step from
//!   `(seed, t)`. Jitter is adversarial: `amp = 0` reproduces the clean
//!   timeline bit-for-bit, `amp > 0` can only slow a step down.

use crate::comm::Topology;
use crate::util::rng::Xoshiro256;

/// Per-worker compute-time multipliers (`1.0` = nominal speed).
#[derive(Clone, Debug)]
pub struct StragglerModel {
    pub mults: Vec<f64>,
}

impl StragglerModel {
    /// Every worker at nominal speed.
    pub fn none(workers: usize) -> Self {
        Self {
            mults: vec![1.0; workers.max(1)],
        }
    }

    /// One straggler (worker 0) at `mult` × nominal compute time.
    pub fn single(workers: usize, mult: f64) -> Self {
        let mut m = Self::none(workers);
        m.mults[0] = mult.max(1.0);
        m
    }

    /// Seeded heterogeneous fleet: worker `w` draws `1 + max_extra·u³`
    /// with `u ~ U[0,1)` from `for_stream(seed, w)` — a heavy-ish tail
    /// where most workers are near-nominal and a few lag.
    pub fn seeded(workers: usize, seed: u64, max_extra: f64) -> Self {
        let mults = (0..workers.max(1))
            .map(|w| {
                let u = Xoshiro256::for_stream(seed, w as u64).next_f64();
                1.0 + max_extra.max(0.0) * u * u * u
            })
            .collect();
        Self { mults }
    }

    /// The pacing multiplier: synchronous data parallelism runs at the
    /// slowest worker's speed.
    pub fn pace(&self) -> f64 {
        self.mults.iter().fold(1.0f64, |a, &b| a.max(b))
    }

    /// Mean over workers of `pace − m_w`: idle compute-capacity seconds
    /// per second of nominal backward time (0 for a homogeneous fleet).
    pub fn idle_frac(&self) -> f64 {
        let pace = self.pace();
        let sum: f64 = self.mults.iter().map(|&m| pace - m).sum();
        sum / self.mults.len() as f64
    }
}

/// Seeded per-step α–β link jitter. Factors are log-free multiplicative
/// perturbations in `[1, 1+amp]`, drawn per `(seed, step)`; within a
/// step every bucket sees the same perturbed channels.
#[derive(Clone, Copy, Debug)]
pub struct JitterModel {
    pub seed: u64,
    /// Worst-case fractional slowdown per channel parameter (≥ 0).
    pub amp: f64,
}

impl JitterModel {
    /// The four per-link factors for step `t`, in a fixed draw order:
    /// `[intra_bw_div, inter_bw_div, intra_lat_mult, inter_lat_mult]`.
    pub fn factors(&self, t: u64) -> [f64; 4] {
        let mut rng = Xoshiro256::for_stream(self.seed, t);
        let amp = self.amp.max(0.0);
        [(); 4].map(|_| 1.0 + amp * rng.next_f64())
    }

    /// Channel-perturbed copy of `topo` for step `t`. With `amp = 0`
    /// every factor is exactly `1.0` and the copy is bit-identical.
    pub fn perturb(&self, topo: &Topology, t: u64) -> Topology {
        let [ibw, xbw, ilat, xlat] = self.factors(t);
        topo.perturb_channels(ibw, xbw, ilat, xlat)
    }
}

/// Everything misbehaving about the cluster for one simulated run.
#[derive(Clone, Debug)]
pub struct Adversity {
    pub straggler: StragglerModel,
    pub jitter: Option<JitterModel>,
}

impl Adversity {
    /// A well-behaved cluster: the engine's adversity-aware paths
    /// reproduce the clean timeline bit-for-bit under this value.
    pub fn clean(workers: usize) -> Self {
        Self {
            straggler: StragglerModel::none(workers),
            jitter: None,
        }
    }

    /// CLI-knob constructor: `straggler_mult > 1` puts one straggler at
    /// that multiplier, `jitter_amp > 0` enables seeded link jitter.
    pub fn from_knobs(workers: usize, straggler_mult: f64, jitter_amp: f64, seed: u64) -> Self {
        Self {
            straggler: if straggler_mult > 1.0 {
                StragglerModel::single(workers, straggler_mult)
            } else {
                StragglerModel::none(workers)
            },
            jitter: if jitter_amp > 0.0 {
                Some(JitterModel {
                    seed,
                    amp: jitter_amp,
                })
            } else {
                None
            },
        }
    }

    pub fn is_clean(&self) -> bool {
        self.straggler.pace() == 1.0 && self.jitter.is_none()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pace_is_max_and_idle_frac_means_the_rest() {
        let s = StragglerModel {
            mults: vec![1.0, 2.0, 1.5, 1.0],
        };
        assert_eq!(s.pace(), 2.0);
        // (1 + 0 + 0.5 + 1) / 4
        assert!((s.idle_frac() - 0.625).abs() < 1e-15);
        assert_eq!(StragglerModel::none(4).idle_frac(), 0.0);
    }

    #[test]
    fn single_puts_the_multiplier_on_worker_zero() {
        let s = StragglerModel::single(3, 2.5);
        assert_eq!(s.mults, vec![2.5, 1.0, 1.0]);
        // Sub-nominal multipliers clamp to 1 (stragglers only slow down).
        assert_eq!(StragglerModel::single(2, 0.5).pace(), 1.0);
    }

    #[test]
    fn seeded_is_deterministic_and_bounded() {
        let a = StragglerModel::seeded(8, 7, 1.5);
        let b = StragglerModel::seeded(8, 7, 1.5);
        assert_eq!(a.mults, b.mults);
        assert!(a.mults.iter().all(|&m| (1.0..2.5).contains(&m)));
        assert_ne!(a.mults, StragglerModel::seeded(8, 8, 1.5).mults);
    }

    #[test]
    fn zero_amp_jitter_is_bitwise_identity() {
        let topo = Topology::multi_node(2, 4);
        let j = JitterModel { seed: 3, amp: 0.0 };
        let p = j.perturb(&topo, 5);
        assert_eq!(p.intra_bw.to_bits(), topo.intra_bw.to_bits());
        assert_eq!(p.inter_bw.to_bits(), topo.inter_bw.to_bits());
        assert_eq!(p.intra_lat.to_bits(), topo.intra_lat.to_bits());
        assert_eq!(p.inter_lat.to_bits(), topo.inter_lat.to_bits());
    }

    #[test]
    fn jitter_is_per_step_deterministic_and_adversarial() {
        let topo = Topology::ethernet(2, 2);
        let j = JitterModel { seed: 11, amp: 0.5 };
        let a = j.perturb(&topo, 3);
        let b = j.perturb(&topo, 3);
        assert_eq!(a.inter_bw.to_bits(), b.inter_bw.to_bits());
        // Adversarial: bandwidth never rises, latency never falls.
        assert!(a.inter_bw <= topo.inter_bw && a.intra_bw <= topo.intra_bw);
        assert!(a.inter_lat >= topo.inter_lat && a.intra_lat >= topo.intra_lat);
        // Factors vary across steps (not a frozen perturbation).
        assert_ne!(j.factors(0), j.factors(1));
    }
}
