//! Gradient bucketing (PyTorch-DDP style).
//!
//! Small per-block collectives are latency-bound — exactly the paper's
//! r×r core regime, where the α term dominates and halving bytes barely
//! changes the time. Data-parallel frameworks therefore fuse per-block
//! payloads into fixed-capacity buckets and launch one collective per
//! bucket, in the order gradients become ready during the backward pass
//! (reverse forward order).

use crate::optim::SyncPlan;

/// One fused collective: a contiguous run of blocks in gradient-ready
/// order, carrying their combined payload.
#[derive(Clone, Debug)]
pub struct Bucket {
    /// Block indices (forward-order ids) in the order they become ready.
    pub blocks: Vec<usize>,
    /// Fused payload bytes.
    pub bytes: usize,
}

/// A step's bucket schedule for one method.
#[derive(Clone, Debug)]
pub struct BucketPlan {
    pub buckets: Vec<Bucket>,
    pub cap_bytes: usize,
}

impl BucketPlan {
    /// Fuse `plan`'s per-block payloads into buckets of at most
    /// `cap_bytes`, walking blocks in reverse forward order (the order
    /// the backward pass produces gradients). A single block larger than
    /// the capacity gets a bucket of its own; zero-byte items ride along
    /// with their neighbours. `cap_bytes == 0` disables fusion (one
    /// bucket per block).
    pub fn build(plan: &SyncPlan, cap_bytes: usize) -> Self {
        let mut buckets: Vec<Bucket> = Vec::new();
        let mut current = Bucket {
            blocks: Vec::new(),
            bytes: 0,
        };
        for item in plan.items.iter().rev() {
            let overflows = !current.blocks.is_empty()
                && (cap_bytes == 0 || current.bytes + item.bytes > cap_bytes);
            if overflows {
                buckets.push(std::mem::replace(
                    &mut current,
                    Bucket {
                        blocks: Vec::new(),
                        bytes: 0,
                    },
                ));
            }
            current.blocks.push(item.block);
            current.bytes += item.bytes;
        }
        if !current.blocks.is_empty() {
            buckets.push(current);
        }
        Self { buckets, cap_bytes }
    }

    pub fn total_bytes(&self) -> usize {
        self.buckets.iter().map(|b| b.bytes).sum()
    }

    pub fn len(&self) -> usize {
        self.buckets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buckets.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::LayerClass;
    use crate::optim::SyncItem;

    fn plan(bytes: &[usize]) -> SyncPlan {
        SyncPlan {
            items: bytes
                .iter()
                .enumerate()
                .map(|(b, &n)| SyncItem {
                    block: b,
                    class: LayerClass::Linear,
                    bytes: n,
                    fmt: crate::comm::ElemFmt::F32,
                    refresh: false,
                })
                .collect(),
        }
    }

    #[test]
    fn fuses_in_reverse_order_up_to_capacity() {
        let p = plan(&[100, 200, 300, 50]);
        let bp = BucketPlan::build(&p, 400);
        // Reverse order: 50, 300 (fits: 350), then 200, 100 (300).
        assert_eq!(bp.len(), 2);
        assert_eq!(bp.buckets[0].blocks, vec![3, 2]);
        assert_eq!(bp.buckets[0].bytes, 350);
        assert_eq!(bp.buckets[1].blocks, vec![1, 0]);
        assert_eq!(bp.buckets[1].bytes, 300);
        assert_eq!(bp.total_bytes(), 650);
    }

    #[test]
    fn oversized_block_gets_own_bucket() {
        let p = plan(&[10, 5000, 10]);
        let bp = BucketPlan::build(&p, 100);
        assert_eq!(bp.len(), 3);
        assert_eq!(bp.buckets[1].blocks, vec![1]);
        assert_eq!(bp.buckets[1].bytes, 5000);
    }

    #[test]
    fn zero_capacity_disables_fusion() {
        let p = plan(&[1, 2, 3]);
        let bp = BucketPlan::build(&p, 0);
        assert_eq!(bp.len(), 3);
    }

    #[test]
    fn every_block_appears_exactly_once() {
        let p = plan(&[7; 13]);
        let bp = BucketPlan::build(&p, 20);
        let mut seen: Vec<usize> = bp.buckets.iter().flat_map(|b| b.blocks.clone()).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..13).collect::<Vec<_>>());
        assert_eq!(bp.total_bytes(), 7 * 13);
    }

    #[test]
    fn huge_capacity_gives_single_bucket() {
        let p = plan(&[10, 20, 30]);
        let bp = BucketPlan::build(&p, usize::MAX);
        assert_eq!(bp.len(), 1);
        assert_eq!(bp.buckets[0].bytes, 60);
    }
}
