//! Discrete-event step-time engine.
//!
//! Simulates one training step as two interleaved timelines:
//!
//! * **compute** — the backward pass produces block gradients in reverse
//!   forward order; block `b`'s gradient costs `4·numel·tokens / flops`
//!   seconds (two GEMMs — grad-input and grad-weight — at 2·mn FLOPs per
//!   token each);
//! * **communication** — a single in-order stream (NCCL semantics)
//!   drains buckets as they become ready. A bucket is ready when the
//!   last of its blocks has a gradient; its collective costs the
//!   two-level α–β time of [`collective_secs`].
//!
//! With overlap on, bucket `i` starts at `max(ready_i, end_{i−1})`;
//! exposed communication is whatever the step spends past the end of
//! backward compute. With overlap off, all communication serializes
//! after compute — the classic no-overlap model, and the configuration
//! in which the engine reproduces `Topology::allreduce_time` exactly
//! (the documented closed-form oracle; see `tests/sim_engine.rs`).

use crate::comm::Topology;
use crate::model::BlockSpec;
use crate::optim::{DistOptimizer, SyncPlan};
use crate::sim::adversity::Adversity;
use crate::sim::bucket::BucketPlan;

/// Engine configuration: cluster compute rate + bucketing + toggles.
#[derive(Clone, Debug)]
pub struct SimCfg {
    /// Bucket capacity in bytes (PyTorch DDP defaults to 25 MiB).
    pub bucket_bytes: usize,
    /// Per-worker accelerator throughput for the backward pass, FLOP/s.
    pub flops: f64,
    /// Tokens per worker per step (micro-batch × sequence length).
    pub tokens_per_step: usize,
    /// Overlap bucket communication with backward compute.
    pub overlap: bool,
    /// Use the two-level hierarchical collective schedule (flat ring
    /// otherwise).
    pub hierarchical: bool,
}

impl Default for SimCfg {
    fn default() -> Self {
        Self {
            bucket_bytes: 25 << 20,
            flops: 312e12, // A100 bf16 peak
            tokens_per_step: 8192,
            overlap: true,
            hierarchical: true,
        }
    }
}

/// Timings of one simulated step.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepTimeline {
    /// End of the backward pass.
    pub compute_secs: f64,
    /// Total time the comm stream is busy.
    pub comm_busy_secs: f64,
    /// Communication not hidden behind compute: `step − compute`.
    pub exposed_comm_secs: f64,
    /// Predicted step wall-clock.
    pub step_secs: f64,
    /// Fraction of comm-busy time hidden behind compute.
    pub overlap_frac: f64,
    pub buckets: usize,
    /// Compute capacity wasted waiting for stragglers: mean over
    /// workers of `(pace − m_w)` × the nominal backward time
    /// (`sim::adversity::StragglerModel`). Zero on a clean cluster.
    pub straggler_idle_secs: f64,
}

/// Backward-compute seconds for one block.
pub fn backward_secs(block: &BlockSpec, cfg: &SimCfg) -> f64 {
    4.0 * block.numel() as f64 * cfg.tokens_per_step as f64 / cfg.flops
}

/// α–β seconds for one all-reduce of `bytes` over the cluster.
///
/// Flat (single-level) topologies use `Topology::allreduce_time`
/// verbatim — that closed form is the degenerate-case oracle. A genuine
/// two-level shape pays three phases: intra-node reduce-scatter, the
/// cross-node ring over each node's chunk, and the intra-node
/// all-gather/broadcast.
///
/// Rail assumption: the `g` per-chunk cross-node rings are modeled as
/// concurrent, i.e. `inter_bw` is per-GPU-rail bandwidth (DGX-style
/// multi-NIC nodes, one rail per local rank). On a single-NIC node the
/// rings share the link and the inter term is ~g× larger — which is the
/// flat-ring figure; compare against `--flat` for that regime.
pub fn collective_secs(topo: &Topology, cfg: &SimCfg, bytes: usize) -> f64 {
    let n = topo.nodes;
    let g = topo.gpus_per_node;
    if topo.workers() <= 1 {
        return 0.0;
    }
    if !cfg.hierarchical || n <= 1 || g <= 1 {
        return topo.allreduce_time(bytes);
    }
    let b = bytes as f64;
    let gf = g as f64;
    let nf = n as f64;
    // Intra reduce-scatter and all-gather: (g−1)/g · B each way.
    let intra = 2.0 * ((gf - 1.0) / gf * b / topo.intra_bw + (gf - 1.0) * topo.intra_lat);
    // Inter ring all-reduce over the per-chunk groups: payload B/g.
    let inter = 2.0 * (nf - 1.0) / nf * (b / gf) / topo.inter_bw
        + 2.0 * (nf - 1.0) * topo.inter_lat;
    intra + inter
}

/// Simulate one step of `plan` on a well-behaved `topo` — the clean
/// special case of [`simulate_step_adv`] (kept as the stable entry
/// point for the oracle-equality contract and existing callers).
pub fn simulate_step(
    blocks: &[BlockSpec],
    plan: &SyncPlan,
    topo: &Topology,
    cfg: &SimCfg,
) -> StepTimeline {
    simulate_step_adv(blocks, plan, topo, cfg, &Adversity::clean(topo.workers()), 0)
}

/// [`simulate_step`] under an [`Adversity`] model at step index `t`
/// (the jitter resample key).
///
/// Straggler semantics: synchronous data parallelism runs at the
/// slowest worker's speed, so the pacing multiplier
/// `StragglerModel::pace()` scales BOTH gradient readiness (the
/// backward timeline) and each bucket's collective cost (every ring
/// step waits on the degraded worker's injection). Jitter perturbs the
/// per-link α–β channels once per step. A clean adversity multiplies
/// by exactly `1.0` everywhere, reproducing the plain timeline
/// bit-for-bit — the oracle-equality test still holds through this
/// path.
pub fn simulate_step_adv(
    blocks: &[BlockSpec],
    plan: &SyncPlan,
    topo: &Topology,
    cfg: &SimCfg,
    adv: &Adversity,
    t: u64,
) -> StepTimeline {
    let pace = adv.straggler.pace();
    let jittered;
    let topo = match &adv.jitter {
        Some(j) => {
            jittered = j.perturb(topo, t);
            &jittered
        }
        None => topo,
    };
    // Backward compute finishes block-by-block in reverse forward order,
    // paced by the slowest worker. `base_clock` tracks the nominal
    // (unstraggled) backward time for the idle-capacity report.
    let nblocks = blocks.len();
    let mut compute_end = vec![0.0f64; nblocks];
    let mut clock = 0.0f64;
    let mut base_clock = 0.0f64;
    for b in (0..nblocks).rev() {
        let base = backward_secs(&blocks[b], cfg);
        base_clock += base;
        clock += base * pace;
        compute_end[b] = clock;
    }
    let compute_secs = clock;

    let bp = BucketPlan::build(plan, cfg.bucket_bytes);
    let mut comm_busy = 0.0f64;
    let mut stream_free = 0.0f64;
    let mut last_end = 0.0f64;
    for bucket in &bp.buckets {
        let cost = collective_secs(topo, cfg, bucket.bytes) * pace;
        comm_busy += cost;
        if cfg.overlap {
            let ready = bucket
                .blocks
                .iter()
                .map(|&b| compute_end[b])
                .fold(0.0f64, f64::max);
            let start = ready.max(stream_free);
            stream_free = start + cost;
            last_end = stream_free;
        }
    }
    let (step_secs, exposed) = if cfg.overlap {
        let step = compute_secs.max(last_end);
        (step, step - compute_secs)
    } else {
        // All communication serializes after the backward pass; exposed
        // is comm_busy itself (kept exact — the oracle-equality test in
        // tests/sim_engine.rs relies on bit-for-bit f64 agreement).
        (compute_secs + comm_busy, comm_busy)
    };
    let overlap_frac = if comm_busy > 0.0 {
        (1.0 - exposed / comm_busy).clamp(0.0, 1.0)
    } else {
        0.0
    };
    StepTimeline {
        compute_secs,
        comm_busy_secs: comm_busy,
        exposed_comm_secs: exposed,
        step_secs,
        overlap_frac,
        buckets: bp.len(),
        straggler_idle_secs: adv.straggler.idle_frac() * base_clock,
    }
}

/// Averaged timings over a horizon of steps (covers refresh cadences).
#[derive(Clone, Copy, Debug, Default)]
pub struct MethodTimeline {
    pub avg_step_secs: f64,
    pub avg_compute_secs: f64,
    pub avg_comm_busy_secs: f64,
    pub avg_exposed_secs: f64,
    /// Worst single step (the refresh spike).
    pub peak_step_secs: f64,
    /// Hidden fraction of all comm-busy time over the horizon.
    pub overlap_frac: f64,
    pub avg_payload_bytes: f64,
    /// Mean wasted compute capacity per step (see
    /// [`StepTimeline::straggler_idle_secs`]).
    pub avg_straggler_idle_secs: f64,
}

/// Simulate `steps` consecutive steps of `opt`'s payload schedule and
/// average. `steps` should cover one refresh period to amortize spikes
/// the way the byte profiles do.
pub fn simulate_method(
    opt: &dyn DistOptimizer,
    blocks: &[BlockSpec],
    topo: &Topology,
    cfg: &SimCfg,
    steps: usize,
) -> MethodTimeline {
    let plans: Vec<SyncPlan> = (0..steps.max(1)).map(|t| opt.sync_plan(t as u64)).collect();
    simulate_plans(&plans, blocks, topo, cfg)
}

/// Average a pre-extracted schedule horizon. Schedules depend only on
/// shapes and cadence, so callers sweeping topologies or link speeds
/// (e.g. `exp::simtime`) extract them once per method, drop the
/// optimizer (its moments/error buffers are model-scale), and reuse the
/// plans across every sweep point.
pub fn simulate_plans(
    plans: &[SyncPlan],
    blocks: &[BlockSpec],
    topo: &Topology,
    cfg: &SimCfg,
) -> MethodTimeline {
    simulate_plans_adv(plans, blocks, topo, cfg, &Adversity::clean(topo.workers()))
}

/// [`simulate_plans`] under an [`Adversity`] model. The plan index is
/// the jitter resample key, so a jittered horizon sees per-step channel
/// perturbations (and its peak step reflects the worst draw).
pub fn simulate_plans_adv(
    plans: &[SyncPlan],
    blocks: &[BlockSpec],
    topo: &Topology,
    cfg: &SimCfg,
    adv: &Adversity,
) -> MethodTimeline {
    let steps = plans.len().max(1);
    let mut out = MethodTimeline::default();
    let mut busy = 0.0f64;
    let mut exposed = 0.0f64;
    for (t, plan) in plans.iter().enumerate() {
        let tl = simulate_step_adv(blocks, plan, topo, cfg, adv, t as u64);
        out.avg_step_secs += tl.step_secs;
        out.avg_compute_secs += tl.compute_secs;
        out.avg_comm_busy_secs += tl.comm_busy_secs;
        out.avg_exposed_secs += tl.exposed_comm_secs;
        out.peak_step_secs = out.peak_step_secs.max(tl.step_secs);
        out.avg_payload_bytes += plan.total_bytes() as f64;
        out.avg_straggler_idle_secs += tl.straggler_idle_secs;
        busy += tl.comm_busy_secs;
        exposed += tl.exposed_comm_secs;
    }
    let inv = 1.0 / steps as f64;
    out.avg_step_secs *= inv;
    out.avg_compute_secs *= inv;
    out.avg_comm_busy_secs *= inv;
    out.avg_exposed_secs *= inv;
    out.avg_payload_bytes *= inv;
    out.avg_straggler_idle_secs *= inv;
    out.overlap_frac = if busy > 0.0 {
        (1.0 - exposed / busy).clamp(0.0, 1.0)
    } else {
        0.0
    };
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::LayerClass;
    use crate::optim::SyncItem;
    use crate::sim::adversity::{JitterModel, StragglerModel};

    fn blocks3() -> Vec<BlockSpec> {
        vec![
            BlockSpec {
                name: "emb".into(),
                rows: 100,
                cols: 32,
                class: LayerClass::Embedding,
            },
            BlockSpec {
                name: "w".into(),
                rows: 32,
                cols: 64,
                class: LayerClass::Linear,
            },
            BlockSpec {
                name: "b".into(),
                rows: 1,
                cols: 64,
                class: LayerClass::Vector,
            },
        ]
    }

    fn dense_plan(blocks: &[BlockSpec]) -> SyncPlan {
        SyncPlan {
            items: blocks
                .iter()
                .enumerate()
                .map(|(b, s)| SyncItem {
                    block: b,
                    class: s.class,
                    bytes: s.numel() * 4,
                    fmt: crate::comm::ElemFmt::F32,
                    refresh: false,
                })
                .collect(),
        }
    }

    #[test]
    fn compute_runs_in_reverse_order() {
        let blocks = blocks3();
        let cfg = SimCfg::default();
        let plan = dense_plan(&blocks);
        let tl = simulate_step(&blocks, &plan, &Topology::single_node(4), &cfg);
        let expect: f64 = blocks.iter().map(|b| backward_secs(b, &cfg)).sum();
        assert!((tl.compute_secs - expect).abs() < 1e-18);
    }

    #[test]
    fn overlap_never_slower_and_no_overlap_is_additive() {
        let blocks = blocks3();
        let plan = dense_plan(&blocks);
        let topo = Topology::multi_node(2, 2);
        let mut cfg = SimCfg {
            bucket_bytes: 0,
            ..Default::default()
        };
        cfg.overlap = false;
        let serial = simulate_step(&blocks, &plan, &topo, &cfg);
        assert_eq!(serial.step_secs, serial.compute_secs + serial.comm_busy_secs);
        assert_eq!(serial.overlap_frac, 0.0);
        cfg.overlap = true;
        let over = simulate_step(&blocks, &plan, &topo, &cfg);
        assert!(over.step_secs <= serial.step_secs);
        assert!(over.exposed_comm_secs <= serial.exposed_comm_secs);
        assert!(over.overlap_frac >= 0.0 && over.overlap_frac <= 1.0);
    }

    #[test]
    fn bigger_buckets_amortize_latency() {
        // Many tiny payloads: fused sync pays α once, unfused pays it
        // per block — the r×r-core regime effect bucketing exists for.
        let blocks: Vec<BlockSpec> = (0..40)
            .map(|i| BlockSpec {
                name: format!("w{i}"),
                rows: 4,
                cols: 4,
                class: LayerClass::Linear,
            })
            .collect();
        let plan = dense_plan(&blocks);
        let topo = Topology::multi_node(4, 2);
        let base = SimCfg {
            overlap: false,
            ..Default::default()
        };
        let unfused = simulate_step(
            &blocks,
            &plan,
            &topo,
            &SimCfg {
                bucket_bytes: 0,
                ..base.clone()
            },
        );
        let fused = simulate_step(&blocks, &plan, &topo, &base);
        assert_eq!(fused.buckets, 1);
        assert_eq!(unfused.buckets, 40);
        assert!(
            fused.comm_busy_secs < 0.5 * unfused.comm_busy_secs,
            "{} vs {}",
            fused.comm_busy_secs,
            unfused.comm_busy_secs
        );
    }

    #[test]
    fn clean_adversity_reproduces_plain_timeline_bitwise() {
        let blocks = blocks3();
        let plan = dense_plan(&blocks);
        let topo = Topology::multi_node(2, 4);
        let cfg = SimCfg::default();
        let plain = simulate_step(&blocks, &plan, &topo, &cfg);
        let adv = simulate_step_adv(&blocks, &plan, &topo, &cfg, &Adversity::clean(8), 3);
        assert_eq!(plain.step_secs.to_bits(), adv.step_secs.to_bits());
        assert_eq!(plain.compute_secs.to_bits(), adv.compute_secs.to_bits());
        assert_eq!(plain.comm_busy_secs.to_bits(), adv.comm_busy_secs.to_bits());
        assert_eq!(
            plain.exposed_comm_secs.to_bits(),
            adv.exposed_comm_secs.to_bits()
        );
        assert_eq!(adv.straggler_idle_secs, 0.0);
    }

    #[test]
    fn straggler_paces_the_whole_step_and_reports_idle_capacity() {
        let blocks = blocks3();
        let plan = dense_plan(&blocks);
        let topo = Topology::multi_node(2, 4);
        let cfg = SimCfg::default();
        let clean = simulate_step(&blocks, &plan, &topo, &cfg);
        let adv = Adversity {
            straggler: StragglerModel::single(8, 2.0),
            jitter: None,
        };
        let slow = simulate_step_adv(&blocks, &plan, &topo, &cfg, &adv, 0);
        // Compute and collectives both scale by the pacing multiplier,
        // so the step is 2× (up to fp association) — strictly slower.
        assert!(slow.step_secs > 1.99 * clean.step_secs);
        assert!(slow.step_secs < 2.01 * clean.step_secs);
        // 7 of 8 workers idle (2−1)× the nominal backward time.
        let expect_idle = 7.0 / 8.0 * clean.compute_secs;
        assert!((slow.straggler_idle_secs - expect_idle).abs() < 1e-12 * expect_idle.max(1.0));
    }

    #[test]
    fn jitter_only_slows_steps_down() {
        let blocks = blocks3();
        let topo = Topology::ethernet(2, 4);
        let cfg = SimCfg::default();
        let plans: Vec<SyncPlan> = (0..10).map(|_| dense_plan(&blocks)).collect();
        let clean = simulate_plans(&plans, &blocks, &topo, &cfg);
        let adv = Adversity {
            straggler: StragglerModel::none(8),
            jitter: Some(JitterModel { seed: 5, amp: 0.5 }),
        };
        let jit = simulate_plans_adv(&plans, &blocks, &topo, &cfg, &adv);
        assert!(jit.avg_step_secs >= clean.avg_step_secs);
        assert!(jit.avg_exposed_secs >= clean.avg_exposed_secs);
        // amp = 0 is a bitwise identity end to end.
        let zero = Adversity {
            straggler: StragglerModel::none(8),
            jitter: Some(JitterModel { seed: 5, amp: 0.0 }),
        };
        let z = simulate_plans_adv(&plans, &blocks, &topo, &cfg, &zero);
        assert_eq!(z.avg_step_secs.to_bits(), clean.avg_step_secs.to_bits());
    }

    #[test]
    fn hierarchical_beats_flat_ring_on_slow_inter_links() {
        // 2(N−1)/N of the payload over the slow link (flat) vs only
        // 2(n−1)/n of a 1/g chunk (hierarchical).
        let topo = Topology::multi_node(4, 8);
        let bytes = 64 << 20;
        let hier = collective_secs(&topo, &SimCfg::default(), bytes);
        let flat = collective_secs(
            &topo,
            &SimCfg {
                hierarchical: false,
                ..Default::default()
            },
            bytes,
        );
        assert!(hier < flat, "{hier} vs {flat}");
    }
}
