//! Discrete-event cluster simulator: from bytes to step time.
//!
//! The byte ledger (`comm/accounting`) answers *how much* each method
//! synchronizes; this subsystem answers *how long a training step takes*
//! on a two-level cluster, which is what the paper's motivation is
//! actually about — on NVLink-vs-PCIe hierarchies the slow link
//! dominates step time, and in the r×r core regime latency (α) matters
//! as much as bandwidth (β).
//!
//! Three pieces:
//!
//! * [`bucket`] — PyTorch-DDP-style gradient bucketing: per-block
//!   payloads from an optimizer's [`SyncPlan`](crate::optim::SyncPlan)
//!   are fused, in gradient-ready (reverse forward) order, into
//!   configurable-size buckets so α is paid once per bucket instead of
//!   once per block.
//! * [`engine`] — the event timeline: backward compute produces block
//!   gradients in reverse order while a single in-order communication
//!   stream drains ready buckets through per-link α–β channels
//!   (hierarchical reduce-scatter → leader ring → broadcast). Reports
//!   predicted step time, exposed (non-overlapped) communication, and
//!   the overlap fraction.
//! * [`adversity`] — deterministic, seeded cluster-misbehaviour models
//!   (per-worker compute stragglers; per-step α–β link jitter) threaded
//!   through the engine's `*_adv` entry points. A clean adversity is a
//!   bitwise no-op, so the oracle contract below survives the plumbing.
//! * the closed-form `Topology::allreduce_time` remains the documented
//!   degenerate-case oracle: flat ring + single bucket + no overlap
//!   reproduces it exactly (`tests/sim_engine.rs`).
//!
//! Surfaced as the `tsr simtime` / `tsr soak` CLI experiments
//! (`exp::simtime`, `exp::soak`), the `sim_step` bench, and `Trainer`'s
//! optional per-run time prediction.

pub mod adversity;
pub mod bucket;
pub mod engine;

pub use adversity::{Adversity, JitterModel, StragglerModel};
pub use bucket::{Bucket, BucketPlan};
pub use engine::{
    simulate_method, simulate_plans, simulate_plans_adv, simulate_step, simulate_step_adv,
    MethodTimeline, SimCfg, StepTimeline,
};
