//! Synthetic sequence-classification fine-tuning (GLUE substitute,
//! Table 4; see DESIGN.md §6).
//!
//! A real learnable task with real gradients, computed by manual
//! backprop in Rust: inputs are token bags, the label depends on which
//! "signal" tokens appear; the model is
//!     h = mean_t E[x_t];  a = tanh(h·W1);  logits = a·W2
//! so the trainable blocks exercise both the Embedding class (sparse,
//! tall V×d gradients — §3.6) and Linear blocks. Metric parity between
//! dense Adam and TSR on these tasks is the structural analogue of the
//! paper's GLUE table; the Bytes/Step column is computed exactly on
//! RoBERTa-base shapes by the table harness.

use super::GradSource;
use crate::comm::LayerClass;
use crate::linalg::Matrix;
use crate::model::BlockSpec;
use crate::util::rng::Xoshiro256;

pub struct ClassifyTask {
    pub vocab: usize,
    pub dim: usize,
    pub hidden: usize,
    pub classes: usize,
    pub seq: usize,
    /// signal_tokens[c] — tokens whose presence votes for class c.
    signal: Vec<Vec<u32>>,
    blocks: Vec<BlockSpec>,
    workers: usize,
    batch: usize,
    rng: Xoshiro256,
    eval_set: Vec<(Vec<u32>, usize)>,
}

impl ClassifyTask {
    pub fn new(
        vocab: usize,
        dim: usize,
        hidden: usize,
        classes: usize,
        seq: usize,
        workers: usize,
        batch: usize,
        seed: u64,
    ) -> Self {
        let rng = Xoshiro256::new(seed);
        let per_class = 8.min(vocab / classes).max(1);
        let signal = (0..classes)
            .map(|c| {
                (0..per_class)
                    .map(|i| ((c * per_class + i) % vocab) as u32)
                    .collect()
            })
            .collect();
        let blocks = vec![
            BlockSpec {
                name: "embed".into(),
                rows: vocab,
                cols: dim,
                class: LayerClass::Embedding,
            },
            BlockSpec {
                name: "w1".into(),
                rows: dim,
                cols: hidden,
                class: LayerClass::Linear,
            },
            BlockSpec {
                name: "w2".into(),
                rows: hidden,
                cols: classes,
                class: LayerClass::Linear,
            },
        ];
        let mut task = Self {
            vocab,
            dim,
            hidden,
            classes,
            seq,
            signal,
            blocks,
            workers,
            batch,
            rng,
            eval_set: Vec::new(),
        };
        task.eval_set = (0..256).map(|_| task.sample_example()).collect();
        task
    }

    fn sample_example(&mut self) -> (Vec<u32>, usize) {
        let label = self.rng.next_below(self.classes as u64) as usize;
        let mut toks = Vec::with_capacity(self.seq);
        for _ in 0..self.seq {
            if self.rng.next_f64() < 0.35 {
                // Signal token for the true class.
                let s = &self.signal[label];
                toks.push(s[self.rng.next_below(s.len() as u64) as usize]);
            } else {
                toks.push(self.rng.next_below(self.vocab as u64) as u32);
            }
        }
        (toks, label)
    }

    /// Forward pass; returns (loss, probability vector, pooled h, act a).
    fn forward(
        &self,
        params: &[Matrix],
        toks: &[u32],
        label: usize,
    ) -> (f32, Vec<f32>, Vec<f32>, Vec<f32>) {
        let e = &params[0];
        let w1 = &params[1];
        let w2 = &params[2];
        // Mean-pool embeddings.
        let mut h = vec![0.0f32; self.dim];
        for &t in toks {
            let row = e.row(t as usize);
            for (hd, &v) in h.iter_mut().zip(row) {
                *hd += v;
            }
        }
        let inv = 1.0 / toks.len() as f32;
        for v in h.iter_mut() {
            *v *= inv;
        }
        // a = tanh(h·W1)
        let mut a = vec![0.0f32; self.hidden];
        for (j, aj) in a.iter_mut().enumerate() {
            let mut s = 0.0f32;
            for (d, &hv) in h.iter().enumerate() {
                s += hv * w1.at(d, j);
            }
            *aj = s.tanh();
        }
        // logits = a·W2, softmax CE.
        let mut logits = vec![0.0f32; self.classes];
        for (c, lc) in logits.iter_mut().enumerate() {
            let mut s = 0.0f32;
            for (j, &av) in a.iter().enumerate() {
                s += av * w2.at(j, c);
            }
            *lc = s;
        }
        let maxl = logits.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let exps: Vec<f32> = logits.iter().map(|&l| (l - maxl).exp()).collect();
        let z: f32 = exps.iter().sum();
        let probs: Vec<f32> = exps.iter().map(|&e| e / z).collect();
        let loss = -probs[label].max(1e-12).ln();
        (loss, probs, h, a)
    }

    /// Fine-tune initialization (DESIGN.md §6): bit-copy a pretrained
    /// token-embedding table into the `embed` block and start the task
    /// head (w1, w2) fresh from `seed` — the transfer step of the
    /// pretrain → finetune pipeline (`tsr finetune --from <ckpt>`).
    pub fn init_params_pretrained(&self, seed: u64, embedding: &Matrix) -> Vec<Matrix> {
        assert_eq!(
            (embedding.rows, embedding.cols),
            (self.vocab, self.dim),
            "pretrained embedding is {}x{}, task expects {}x{}",
            embedding.rows,
            embedding.cols,
            self.vocab,
            self.dim
        );
        let mut params = self.init_params(seed);
        params[0] = embedding.clone();
        params
    }

    /// Held-out accuracy with current params.
    pub fn accuracy(&self, params: &[Matrix]) -> f32 {
        let mut correct = 0usize;
        for (toks, label) in &self.eval_set {
            let (_, probs, _, _) = self.forward(params, toks, *label);
            let pred = probs
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if pred == *label {
                correct += 1;
            }
        }
        correct as f32 / self.eval_set.len() as f32
    }
}

impl GradSource for ClassifyTask {
    fn blocks(&self) -> &[BlockSpec] {
        &self.blocks
    }

    fn workers(&self) -> usize {
        self.workers
    }

    fn compute(&mut self, params: &[Matrix], _step: usize, grads: &mut [Vec<Matrix>]) -> f32 {
        let mut total_loss = 0.0f32;
        for w in 0..self.workers {
            for g in grads[w].iter_mut() {
                g.fill(0.0);
            }
            let inv_b = 1.0 / self.batch as f32;
            for _ in 0..self.batch {
                let (toks, label) = self.sample_example();
                let (loss, probs, h, a) = self.forward(params, &toks, label);
                total_loss += loss;
                // dlogits = p − onehot(y)
                let mut dlogits = probs;
                dlogits[label] -= 1.0;
                let w1 = &params[1];
                let w2 = &params[2];
                // dW2 = aᵀ dlogits
                {
                    let g2 = &mut grads[w][2];
                    for j in 0..self.hidden {
                        for c in 0..self.classes {
                            *g2.at_mut(j, c) += inv_b * a[j] * dlogits[c];
                        }
                    }
                }
                // da = dlogits W2ᵀ ; dz = da ∘ (1−a²)
                let mut dz = vec![0.0f32; self.hidden];
                for j in 0..self.hidden {
                    let mut s = 0.0f32;
                    for c in 0..self.classes {
                        s += dlogits[c] * w2.at(j, c);
                    }
                    dz[j] = s * (1.0 - a[j] * a[j]);
                }
                // dW1 = hᵀ dz
                {
                    let g1 = &mut grads[w][1];
                    for d in 0..self.dim {
                        for j in 0..self.hidden {
                            *g1.at_mut(d, j) += inv_b * h[d] * dz[j];
                        }
                    }
                }
                // dh = dz W1ᵀ ; dE[tok] += dh / L
                let mut dh = vec![0.0f32; self.dim];
                for d in 0..self.dim {
                    let mut s = 0.0f32;
                    for j in 0..self.hidden {
                        s += dz[j] * w1.at(d, j);
                    }
                    dh[d] = s;
                }
                let inv_l = 1.0 / toks.len() as f32;
                let ge = &mut grads[w][0];
                for &t in &toks {
                    let row = ge.row_mut(t as usize);
                    for (rv, &dv) in row.iter_mut().zip(&dh) {
                        *rv += inv_b * inv_l * dv;
                    }
                }
            }
        }
        total_loss / (self.workers * self.batch) as f32
    }

    fn init_params(&self, seed: u64) -> Vec<Matrix> {
        let mut rng = Xoshiro256::new(seed);
        self.blocks
            .iter()
            .map(|b| {
                let scale = 1.0 / (b.rows as f32).sqrt().max(1.0);
                Matrix::gaussian(b.rows, b.cols, scale.max(0.05), &mut rng)
            })
            .collect()
    }

    /// The only mutable state is the sampling RNG: the signal-token map
    /// and eval set are pure functions of the constructor arguments (the
    /// eval draws replay from the same seed), so a resumed task only
    /// needs the RNG position to reproduce every remaining batch
    /// bit-for-bit (DESIGN.md §9).
    fn save_state(&self) -> crate::util::json::Json {
        use crate::checkpoint::codec;
        let (s, spare) = self.rng.snapshot();
        codec::rng_to_json(&s, spare)
    }

    fn load_state(&mut self, state: &crate::util::json::Json) -> Result<(), String> {
        use crate::checkpoint::codec;
        let (s, spare) = codec::rng_from_json(state, "classify-task")?;
        self.rng = Xoshiro256::from_snapshot(s, spare);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Topology;
    use crate::optim::{AdamHyper, DenseAdamW, LrSchedule};
    use crate::train::Trainer;

    #[test]
    fn dense_adam_learns_the_task() {
        let mut task = ClassifyTask::new(128, 16, 24, 3, 12, 2, 16, 7);
        let blocks = task.blocks().to_vec();
        let mut params = task.init_params(1);
        let acc0 = task.accuracy(&params);
        let mut opt = DenseAdamW::new(
            &blocks,
            AdamHyper {
                lr: 0.02,
                ..Default::default()
            },
        );
        let trainer = Trainer::new(Topology::single_node(2), LrSchedule::constant());
        let (_m, _l) = trainer.run(&mut task, &mut opt, &mut params, 120);
        let acc1 = task.accuracy(&params);
        assert!(
            acc1 > acc0 + 0.25 && acc1 > 0.6,
            "accuracy {acc0} -> {acc1}"
        );
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let task = ClassifyTask::new(32, 6, 8, 2, 5, 1, 4, 3);
        let blocks = task.blocks().to_vec();
        let params = task.init_params(2);
        let mut grads = crate::optim::alloc_worker_grads(&blocks, 1);
        // Use a fixed RNG state for both evaluations by re-seeding.
        let mut t1 = ClassifyTask::new(32, 6, 8, 2, 5, 1, 64, 3);
        t1.compute(&params, 0, &mut grads);
        // Check dW2[0,0] by central differences on the SAME batch: rebuild
        // the task to replay the identical sample stream.
        let eps = 1e-3;
        let mut p_plus = params.clone();
        *p_plus[2].at_mut(0, 0) += eps;
        let mut p_minus = params.clone();
        *p_minus[2].at_mut(0, 0) -= eps;
        let mut ta = ClassifyTask::new(32, 6, 8, 2, 5, 1, 64, 3);
        let mut tb = ClassifyTask::new(32, 6, 8, 2, 5, 1, 64, 3);
        let mut dump = crate::optim::alloc_worker_grads(&blocks, 1);
        let lp = ta.compute(&p_plus, 0, &mut dump);
        let lm = tb.compute(&p_minus, 0, &mut dump);
        let fd = (lp - lm) / (2.0 * eps);
        let an = grads[0][2].at(0, 0);
        assert!(
            (fd - an).abs() < 0.05 * (an.abs().max(fd.abs()).max(0.05)),
            "fd {fd} vs analytic {an}"
        );
    }

    #[test]
    fn rng_state_resumes_the_sample_stream_exactly() {
        use crate::util::json::Json;
        let mk = || ClassifyTask::new(64, 8, 8, 2, 6, 2, 4, 9);
        let mut task = mk();
        let blocks = task.blocks().to_vec();
        let params = task.init_params(1);
        let mut grads = crate::optim::alloc_worker_grads(&blocks, 2);
        task.compute(&params, 0, &mut grads);
        // Round-trip through text, exactly as a checkpoint manifest does.
        let state = Json::parse(&task.save_state().to_string_pretty()).unwrap();
        let expect = task.compute(&params, 1, &mut grads);

        let mut resumed = mk();
        resumed.load_state(&state).unwrap();
        let mut grads2 = crate::optim::alloc_worker_grads(&blocks, 2);
        let got = resumed.compute(&params, 1, &mut grads2);
        assert_eq!(expect.to_bits(), got.to_bits());
        for w in 0..2 {
            for (a, b) in grads[w].iter().zip(&grads2[w]) {
                assert_eq!(a.data, b.data);
            }
        }
    }

    #[test]
    fn pretrained_embedding_transfers_bitwise_and_head_starts_fresh() {
        let task = ClassifyTask::new(32, 6, 8, 2, 5, 1, 4, 3);
        let mut rng = Xoshiro256::new(77);
        let emb = Matrix::gaussian(32, 6, 1.0, &mut rng);
        let p = task.init_params_pretrained(7, &emb);
        let fresh = task.init_params(7);
        assert_eq!(p[0].data, emb.data, "embedding must be a bit-copy");
        assert_ne!(p[0].data, fresh[0].data);
        assert_eq!(p[1].data, fresh[1].data, "head init must match fresh seed");
        assert_eq!(p[2].data, fresh[2].data);
    }

    #[test]
    #[should_panic(expected = "pretrained embedding")]
    fn pretrained_embedding_shape_mismatch_panics() {
        let task = ClassifyTask::new(32, 6, 8, 2, 5, 1, 4, 3);
        let mut rng = Xoshiro256::new(1);
        let wrong = Matrix::gaussian(32, 7, 1.0, &mut rng);
        task.init_params_pretrained(7, &wrong);
    }

    #[test]
    fn embedding_gradient_is_sparse_in_rows() {
        let mut task = ClassifyTask::new(64, 8, 8, 2, 4, 1, 2, 9);
        let blocks = task.blocks().to_vec();
        let params = task.init_params(4);
        let mut grads = crate::optim::alloc_worker_grads(&blocks, 1);
        task.compute(&params, 0, &mut grads);
        let ge = &grads[0][0];
        let touched = (0..64)
            .filter(|&i| ge.row(i).iter().any(|&v| v != 0.0))
            .count();
        // 2 examples × 4 tokens → at most 8 distinct rows.
        assert!(touched <= 8, "{touched} rows touched");
    }
}
