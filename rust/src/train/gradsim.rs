//! Synthetic training objective with controlled low-rank gradient
//! structure — the large-scale substitute for real A100 pre-training runs
//! (DESIGN.md §6).
//!
//! Per matrix block W we define
//!     f(W) = ½ ‖ Aᵀ (W − W*) B ‖²_F
//! with thin factors A ∈ R^{m×d}, B ∈ R^{n×d} (intrinsic dimension d), so
//!     ∇f = A Aᵀ (W − W*) B Bᵀ
//! has rank ≤ d — mirroring the empirically low intrinsic dimension of
//! transformer gradients that makes TSR's approximation floor Δ̄ small
//! (Remark 1). Workers see the gradient plus i.i.d. mini-batch noise.

use super::GradSource;
use crate::comm::LayerClass;
use crate::linalg::{matmul, matmul_nt, matmul_tn, Matrix};
use crate::model::{BlockSpec, ModelSpec};
use crate::util::rng::Xoshiro256;

struct BlockObjective {
    /// Left/right curvature factors (empty for Vector blocks → identity).
    a: Option<Matrix>,
    b: Option<Matrix>,
    target: Matrix,
}

pub struct QuadraticSim {
    blocks: Vec<BlockSpec>,
    objectives: Vec<BlockObjective>,
    workers: usize,
    /// Std-dev of per-worker gradient noise (mini-batch stochasticity).
    pub noise: f32,
    rng: Xoshiro256,
}

impl QuadraticSim {
    /// Build for an arbitrary model spec with intrinsic dimension `d`.
    pub fn new(spec: &ModelSpec, workers: usize, intrinsic_dim: usize, noise: f32, seed: u64) -> Self {
        let blocks = spec.blocks();
        let mut rng = Xoshiro256::new(seed);
        let objectives = blocks
            .iter()
            .map(|bs| {
                let target = Matrix::gaussian(bs.rows, bs.cols, 0.5, &mut rng);
                if bs.class == LayerClass::Vector {
                    BlockObjective {
                        a: None,
                        b: None,
                        target,
                    }
                } else {
                    let d = intrinsic_dim.min(bs.rows).min(bs.cols);
                    // Normalize factors so gradient magnitudes are O(1).
                    let scale_a = 1.0 / (bs.rows as f32).sqrt();
                    let scale_b = 1.0 / (bs.cols as f32).sqrt();
                    BlockObjective {
                        a: Some(Matrix::gaussian(bs.rows, d, scale_a, &mut rng)),
                        b: Some(Matrix::gaussian(bs.cols, d, scale_b, &mut rng)),
                        target,
                    }
                }
            })
            .collect();
        Self {
            blocks,
            objectives,
            workers,
            noise,
            rng,
        }
    }

    /// A small default used across unit tests.
    pub fn small_proxy(workers: usize, noise: f32, seed: u64) -> Self {
        let spec = ModelSpec::proxy(64, 16, 32, 2, 2);
        Self::new(&spec, workers, 6, noise, seed)
    }
}

impl GradSource for QuadraticSim {
    fn blocks(&self) -> &[BlockSpec] {
        &self.blocks
    }

    fn workers(&self) -> usize {
        self.workers
    }

    fn compute(&mut self, params: &[Matrix], _step: usize, grads: &mut [Vec<Matrix>]) -> f32 {
        let mut loss = 0.0f64;
        for (b, obj) in self.objectives.iter().enumerate() {
            // Residual W − W*.
            let mut resid = params[b].clone();
            resid.axpy(-1.0, &obj.target);
            let (grad_mean, block_loss) = match (&obj.a, &obj.b) {
                (Some(a), Some(bm)) => {
                    // core = Aᵀ (W−W*) B  (d×d)
                    let left = matmul_tn(a, &resid); // d×n
                    let core = matmul(&left, bm); // d×d
                    let l = 0.5 * (core.frob_norm() as f64).powi(2);
                    // ∇ = A core Bᵀ
                    let ac = matmul(a, &core); // m×d
                    (matmul_nt(&ac, bm), l)
                }
                _ => {
                    let l = 0.5 * (resid.frob_norm() as f64).powi(2);
                    (resid.clone(), l)
                }
            };
            loss += block_loss;
            for w in 0..self.workers {
                let g = &mut grads[w][b];
                g.data.copy_from_slice(&grad_mean.data);
                if self.noise > 0.0 {
                    for v in g.data.iter_mut() {
                        *v += self.noise * self.rng.next_gaussian_f32();
                    }
                }
            }
        }
        loss as f32
    }

    fn init_params(&self, seed: u64) -> Vec<Matrix> {
        let mut rng = Xoshiro256::new(seed);
        self.blocks
            .iter()
            .map(|b| Matrix::gaussian(b.rows, b.cols, 0.2, &mut rng))
            .collect()
    }

    /// The only mutable state is the noise RNG: objectives are a pure
    /// function of (spec, intrinsic_dim, seed), so a resumed sim only
    /// needs the stream position to reproduce every remaining noise
    /// draw bit-for-bit.
    fn save_state(&self) -> crate::util::json::Json {
        use crate::checkpoint::codec;
        let (s, spare) = self.rng.snapshot();
        codec::rng_to_json(&s, spare)
    }

    fn load_state(&mut self, state: &crate::util::json::Json) -> Result<(), String> {
        use crate::checkpoint::codec;
        let (s, spare) = codec::rng_from_json(state, "quad-sim")?;
        self.rng = Xoshiro256::from_snapshot(s, spare);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gradients_have_low_rank() {
        let mut sim = QuadraticSim::small_proxy(1, 0.0, 3);
        let params = sim.init_params(1);
        let blocks = sim.blocks().to_vec();
        let mut grads = crate::optim::alloc_worker_grads(&blocks, 1);
        sim.compute(&params, 0, &mut grads);
        // Check a matrix block's gradient: singular values beyond d≈6
        // must vanish.
        let idx = blocks
            .iter()
            .position(|b| b.class == LayerClass::Linear)
            .unwrap();
        let (_, s, _) = crate::linalg::svd_jacobi(&grads[0][idx]);
        assert!(s[6] < 1e-4 * s[0].max(1e-12), "σ7={} σ1={}", s[6], s[0]);
    }

    #[test]
    fn zero_loss_at_target() {
        let mut sim = QuadraticSim::small_proxy(1, 0.0, 4);
        let blocks = sim.blocks().to_vec();
        let targets: Vec<Matrix> = sim.objectives.iter().map(|o| o.target.clone()).collect();
        let mut grads = crate::optim::alloc_worker_grads(&blocks, 1);
        let loss = sim.compute(&targets, 0, &mut grads);
        assert!(loss < 1e-8);
        for g in &grads[0] {
            assert!(g.frob_norm() < 1e-6);
        }
    }

    #[test]
    fn worker_noise_differs_but_mean_is_clean() {
        let mut sim = QuadraticSim::small_proxy(4, 0.1, 5);
        let params = sim.init_params(2);
        let blocks = sim.blocks().to_vec();
        let mut grads = crate::optim::alloc_worker_grads(&blocks, 4);
        sim.compute(&params, 0, &mut grads);
        assert!(grads[0][0].dist(&grads[1][0]) > 0.0);
    }
}
