//! Gradient source backed by the native pure-Rust transformer LM
//! (`nn/`, DESIGN.md §10): each simulated worker runs one manual
//! fwd+bwd pass per step on its own deterministic shard of the
//! synthetic corpus — the first runnable path in the repo whose loss
//! curves come from a *real* transformer, and the first to feed the
//! optimizers genuinely row-sparse embedding gradients.

use super::GradSource;
use crate::data::{Batcher, SyntheticCorpus};
use crate::linalg::Matrix;
use crate::model::{BlockSpec, ModelSpec};
use crate::nn::TransformerLm;
use crate::util::json::Json;

pub struct LmSource {
    model: TransformerLm,
    batcher: Batcher,
}

impl LmSource {
    /// Build the LM and its per-worker data sharding. `seed` fixes both
    /// the corpus structure and (xored with a stream constant) the
    /// batcher streams, so two sources constructed with the same
    /// arguments replay identical token blocks.
    pub fn new(spec: &ModelSpec, workers: usize, batch: usize, seq: usize, seed: u64) -> Self {
        let corpus = SyntheticCorpus::new(spec.vocab, seed);
        let batcher = Batcher::new(corpus, workers, batch, seq, seed ^ 0xDA7A);
        Self {
            model: TransformerLm::new(spec),
            batcher,
        }
    }

    /// The 64-vocab / 2-layer model at the `--source lm` CLI defaults
    /// (batch 4), used by unit tests and the `lm_step` bench. The
    /// quality acceptance run (`tests/lm_train.rs`) uses the same model
    /// via `exp::lm_curves::LmCurvesCfg` at batch 8 × 4 workers.
    pub fn small(workers: usize, seed: u64) -> Self {
        Self::new(&ModelSpec::proxy(64, 32, 64, 2, 2), workers, 4, 16, seed)
    }

    pub fn model(&self) -> &TransformerLm {
        &self.model
    }
}

impl GradSource for LmSource {
    fn blocks(&self) -> &[BlockSpec] {
        self.model.blocks()
    }

    fn workers(&self) -> usize {
        self.batcher.workers()
    }

    fn compute(&mut self, params: &[Matrix], _step: usize, grads: &mut [Vec<Matrix>]) -> f32 {
        let workers = self.batcher.workers();
        let batch = self.batcher.batch;
        let mut sum = 0.0f64;
        // Fixed worker order: the loss mean and every stream advance are
        // identical across runs and execution backends.
        for w in 0..workers {
            let tokens = self.batcher.next_block(w);
            sum += self.model.step_into(params, &tokens, batch, &mut grads[w]) as f64;
        }
        (sum / workers as f64) as f32
    }

    fn init_params(&self, seed: u64) -> Vec<Matrix> {
        self.model.init_params(seed)
    }

    /// The only mutable state is the batcher: the model is a pure
    /// function of the spec and the corpus a pure function of
    /// (vocab, seed), so a resumed source only needs the per-worker
    /// stream positions to reproduce every remaining token block
    /// bit-for-bit (DESIGN.md §9).
    fn save_state(&self) -> Json {
        use crate::checkpoint::codec;
        let streams = self
            .batcher
            .snapshot_streams()
            .iter()
            .map(|(s, spare, prev)| {
                let mut o = codec::rng_to_json(s, *spare);
                o.set("prev", Json::num(*prev as f64));
                o
            })
            .collect();
        Json::obj(vec![("streams", Json::Arr(streams))])
    }

    fn load_state(&mut self, state: &Json) -> Result<(), String> {
        use crate::checkpoint::codec;
        let arr = state.get("streams").as_arr().ok_or("lm-source: missing streams")?;
        if arr.len() != self.batcher.workers() {
            return Err(format!(
                "lm-source: checkpoint has {} data streams but this run has {} workers \
                 (elastic resume is not supported for --source lm: per-worker token \
                 streams cannot be re-sharded)",
                arr.len(),
                self.batcher.workers()
            ));
        }
        let mut states = Vec::with_capacity(arr.len());
        for (i, s) in arr.iter().enumerate() {
            let (w4, spare) = codec::rng_from_json(s, &format!("lm-source.streams[{i}]"))?;
            let prev = s
                .get("prev")
                .as_u64()
                .ok_or_else(|| format!("lm-source.streams[{i}]: missing prev"))?
                as u32;
            states.push((w4, spare, prev));
        }
        self.batcher.restore_streams(&states)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::LayerClass;
    use crate::optim::alloc_worker_grads;

    fn tiny() -> LmSource {
        LmSource::new(&ModelSpec::proxy(16, 8, 12, 2, 1), 2, 2, 6, 11)
    }

    #[test]
    fn compute_is_deterministic_across_constructions() {
        let mut a = tiny();
        let mut b = tiny();
        let params = a.init_params(3);
        let blocks = a.blocks().to_vec();
        let mut ga = alloc_worker_grads(&blocks, 2);
        let mut gb = alloc_worker_grads(&blocks, 2);
        for step in 0..3 {
            let la = a.compute(&params, step, &mut ga);
            let lb = b.compute(&params, step, &mut gb);
            assert_eq!(la.to_bits(), lb.to_bits(), "step {step}");
            for w in 0..2 {
                for (x, y) in ga[w].iter().zip(&gb[w]) {
                    for (p, q) in x.data.iter().zip(&y.data) {
                        assert_eq!(p.to_bits(), q.to_bits());
                    }
                }
            }
        }
    }

    #[test]
    fn embedding_gradient_is_row_sparse_head_is_dense() {
        let mut src = tiny();
        let params = src.init_params(4);
        let blocks = src.blocks().to_vec();
        let mut grads = alloc_worker_grads(&blocks, 2);
        src.compute(&params, 0, &mut grads);
        let embed_idx = blocks.iter().position(|b| b.name == "embed_tokens").unwrap();
        let head_idx = blocks.iter().position(|b| b.name == "lm_head").unwrap();
        assert_eq!(blocks[embed_idx].class, LayerClass::Embedding);
        let ge = &grads[0][embed_idx];
        let touched = (0..ge.rows)
            .filter(|&i| ge.row(i).iter().any(|&v| v != 0.0))
            .count();
        // Worker 0 saw batch·seq = 12 input positions → ≤ 12 distinct rows.
        assert!(touched <= 12, "{touched} embedding rows touched");
        assert!(touched > 0);
        // The untied head carries the dense softmax gradient instead.
        let gh = &grads[0][head_idx];
        let head_rows = (0..gh.rows)
            .filter(|&i| gh.row(i).iter().any(|&v| v != 0.0))
            .count();
        assert!(head_rows > touched, "head rows {head_rows} vs embed rows {touched}");
    }

    #[test]
    fn save_load_state_resumes_the_token_streams_exactly() {
        let mut src = tiny();
        let params = src.init_params(5);
        let blocks = src.blocks().to_vec();
        let mut grads = alloc_worker_grads(&blocks, 2);
        src.compute(&params, 0, &mut grads);
        src.compute(&params, 1, &mut grads);
        let state = Json::parse(&src.save_state().to_string_pretty()).unwrap();
        let expect = src.compute(&params, 2, &mut grads);

        let mut resumed = tiny();
        resumed.load_state(&state).unwrap();
        let mut grads2 = alloc_worker_grads(&blocks, 2);
        let got = resumed.compute(&params, 2, &mut grads2);
        assert_eq!(expect.to_bits(), got.to_bits());
        for w in 0..2 {
            for (x, y) in grads[w].iter().zip(&grads2[w]) {
                for (p, q) in x.data.iter().zip(&y.data) {
                    assert_eq!(p.to_bits(), q.to_bits());
                }
            }
        }
    }

    #[test]
    fn load_state_rejects_worker_mismatch() {
        let src = tiny();
        let state = src.save_state();
        let mut three = LmSource::new(&ModelSpec::proxy(16, 8, 12, 2, 1), 3, 2, 6, 11);
        let err = three.load_state(&state).unwrap_err();
        assert!(err.contains("elastic"), "{err}");
    }
}
