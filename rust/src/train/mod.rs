//! Training loop: wires a gradient source (real PJRT transformer or
//! synthetic objective), a distributed optimizer, the LR schedule, and
//! the communication ledger into one run.

pub mod finetune;
pub mod gradsim;
pub mod lm_source;
pub mod pjrt_source;

use crate::checkpoint::Checkpoint;
use crate::comm::{CommLedger, Topology};
use crate::exec::ExecBackend;
use crate::linalg::Matrix;
use crate::metrics::RunMetrics;
use crate::model::BlockSpec;
use crate::optim::{DistOptimizer, LrSchedule, StepCtx};
use crate::sim::{engine, SimCfg};
use crate::util::json::Json;
use std::time::Instant;

/// Anything that can produce per-worker gradients for the current params.
pub trait GradSource {
    fn blocks(&self) -> &[BlockSpec];
    fn workers(&self) -> usize;

    /// Fill `grads[w][b]` with worker w's local gradient for block b at
    /// the given parameters; return the mean training loss across workers.
    fn compute(&mut self, params: &[Matrix], step: usize, grads: &mut [Vec<Matrix>]) -> f32;

    /// Initialize parameters (model-appropriate init).
    fn init_params(&self, seed: u64) -> Vec<Matrix>;

    /// Source-side mutable state for checkpointing (e.g. the mini-batch
    /// noise RNG position). `Json::Null` for stateless sources; a
    /// source whose gradients depend only on `(params, step)` can keep
    /// the default and still resume bitwise.
    fn save_state(&self) -> Json {
        Json::Null
    }

    /// Restore state produced by [`Self::save_state`]. The default
    /// accepts only the stateless `Null` marker.
    fn load_state(&mut self, state: &Json) -> Result<(), String> {
        match state {
            Json::Null => Ok(()),
            _ => Err("this gradient source cannot restore checkpoint state".into()),
        }
    }
}

pub struct Trainer {
    pub topo: Topology,
    pub schedule: LrSchedule,
    pub log_every: usize,
    pub verbose: bool,
    /// When set, each step's payload schedule is also run through the
    /// discrete-event engine, accumulating predicted step time and
    /// exposed-communication time into the run metrics.
    pub sim: Option<SimCfg>,
    /// Execution backend for collectives and hot-path parallelism
    /// (DESIGN.md §8, §12). Defaults to `TSR_BACKEND` (else
    /// sequential); `tsr train --backend threaded|process` overrides
    /// it. All three backends are bitwise-identical, so any run is
    /// reproducible across them.
    pub exec: ExecBackend,
    /// When set, a checkpoint manifest is written every
    /// `ckpt.every` completed steps (DESIGN.md §9).
    pub ckpt: Option<CkptCfg>,
}

/// Periodic-checkpoint configuration for [`Trainer`].
#[derive(Clone, Debug)]
pub struct CkptCfg {
    /// Save after every `every` completed steps (0 disables saving;
    /// the final step is not saved — the run's own output covers it).
    pub every: usize,
    /// Directory receiving `ckpt_step<N>.json` manifests.
    pub dir: std::path::PathBuf,
    /// Run-config echo stored in every manifest; the CLI resume path
    /// rebuilds the setup from this instead of re-typed flags.
    pub config: Json,
}

impl Trainer {
    pub fn new(topo: Topology, schedule: LrSchedule) -> Self {
        Self {
            topo,
            schedule,
            log_every: 50,
            verbose: false,
            sim: None,
            exec: ExecBackend::from_env(),
            ckpt: None,
        }
    }

    /// Builder-style backend override.
    pub fn with_backend(mut self, exec: ExecBackend) -> Self {
        self.exec = exec;
        self
    }

    /// Run `steps` optimizer steps; returns per-step metrics + the ledger.
    pub fn run(
        &self,
        source: &mut dyn GradSource,
        opt: &mut dyn DistOptimizer,
        params: &mut Vec<Matrix>,
        steps: usize,
    ) -> (RunMetrics, CommLedger) {
        let metrics = RunMetrics::new(opt.name());
        self.run_from(source, opt, params, 0, steps, metrics, CommLedger::new())
    }

    /// Run steps `[start_step, steps)` of a run whose first
    /// `start_step` steps already happened, continuing the given
    /// `metrics` and `ledger` (both freshly constructed for
    /// `start_step == 0`). The caller positions optimizer, parameters,
    /// and source at `start_step` beforehand — `DistOptimizer::
    /// load_state` / `GradSource::load_state` from a
    /// [`Checkpoint`], or `DistOptimizer::seek` for a weights-only
    /// start. A run interrupted at any step and resumed this way is
    /// bitwise-identical to the uninterrupted run (same world size,
    /// either backend — DESIGN.md §9).
    #[allow(clippy::too_many_arguments)]
    pub fn run_from(
        &self,
        source: &mut dyn GradSource,
        opt: &mut dyn DistOptimizer,
        params: &mut Vec<Matrix>,
        start_step: usize,
        steps: usize,
        mut metrics: RunMetrics,
        mut ledger: CommLedger,
    ) -> (RunMetrics, CommLedger) {
        let workers = source.workers();
        if self.exec.is_process() {
            // Spawn the worker group before step 0: the spawn cost
            // lands outside the step timings, and a broken environment
            // (unresolvable worker binary, exhausted ports) fails
            // loudly at startup instead of at the first collective.
            crate::exec::process::ensure_group(workers);
        }
        let mut grads = crate::optim::alloc_worker_grads(source.blocks(), workers);
        let tracer = ledger.tracer().clone();

        for t in start_step..steps {
            tracer.set_step(t as u64);
            let loss = {
                crate::span!(tracer, "grad_compute");
                source.compute(params, t, &mut grads)
            };
            let t0 = Instant::now();
            {
                crate::span!(tracer, "optimizer_step");
                let mut ctx = StepCtx {
                    params,
                    grads: &mut grads,
                    ledger: &mut ledger,
                    topo: &self.topo,
                    lr_mult: self.schedule.multiplier(t),
                    exec: &self.exec,
                };
                opt.step(&mut ctx);
            }
            let dt = t0.elapsed().as_secs_f64();
            ledger.end_step();

            if let Some(cfg) = &self.sim {
                let plan = opt.sync_plan(t as u64);
                let tl = engine::simulate_step(source.blocks(), &plan, &self.topo, cfg);
                metrics.predicted_step_secs += tl.step_secs;
                metrics.exposed_comm_secs += tl.exposed_comm_secs;
            }

            metrics.loss.push(loss);
            metrics.step_secs.push(dt);

            if let Some(c) = &self.ckpt {
                if c.every > 0 && (t + 1) % c.every == 0 && t + 1 < steps {
                    let ck = Checkpoint::capture(
                        (t + 1) as u64,
                        workers,
                        params,
                        opt,
                        source,
                        &metrics,
                        &ledger,
                        c.config.clone(),
                    );
                    let path = ck.save(&c.dir).expect("write checkpoint");
                    // Step-addressed (not path-addressed) so a resumed
                    // run checkpointing into a different directory still
                    // matches the full run's trace tail.
                    tracer.event("checkpoint", vec![("at", Json::num((t + 1) as f64))]);
                    if self.verbose {
                        println!("checkpoint -> {}", path.display());
                    }
                }
            }

            if self.verbose && (t % self.log_every == 0 || t + 1 == steps) {
                let cum = ledger.cumulative().last().copied().unwrap_or(0);
                println!(
                    "step {t:>5}  loss {loss:>8.4}  lr_mult {:>6.3}  cum_bytes {}",
                    self.schedule.multiplier(t),
                    crate::util::bench::fmt_bytes(cum as f64),
                );
            }
        }
        metrics.cum_bytes = ledger.cumulative();
        metrics.sim_comm_secs = ledger.sim_time;
        (metrics, ledger)
    }
}

#[cfg(test)]
mod tests {
    use super::gradsim::QuadraticSim;
    use super::*;
    use crate::optim::{AdamHyper, DenseAdamW};

    #[test]
    fn trainer_reduces_quadratic_loss() {
        let mut sim = QuadraticSim::small_proxy(2, 0.01, 42);
        let blocks = sim.blocks().to_vec();
        let mut opt = DenseAdamW::new(
            &blocks,
            AdamHyper {
                lr: 0.05,
                ..Default::default()
            },
        );
        let mut params = sim.init_params(0);
        let trainer = Trainer::new(Topology::single_node(2), LrSchedule::constant());
        let (m, ledger) = trainer.run(&mut sim, &mut opt, &mut params, 80);
        assert!(m.loss[79] < 0.3 * m.loss[0], "{} -> {}", m.loss[0], m.loss[79]);
        assert_eq!(ledger.num_steps(), 80);
        assert_eq!(m.cum_bytes.len(), 80);
        assert!(m.cum_bytes[79] > 0);
    }
}
