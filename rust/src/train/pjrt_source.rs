//! Gradient source backed by the AOT-compiled JAX transformer (L2/L1):
//! each simulated worker executes the PJRT train-step artifact on its own
//! data shard. This is the path that proves all three layers compose.

use super::GradSource;
use crate::data::Batcher;
use crate::linalg::Matrix;
use crate::model::BlockSpec;
use crate::runtime::TrainStepModel;
use crate::util::rng::Xoshiro256;

pub struct PjrtSource {
    model: TrainStepModel,
    batcher: Batcher,
    blocks: Vec<BlockSpec>,
}

impl PjrtSource {
    pub fn new(model: TrainStepModel, batcher: Batcher) -> Self {
        let blocks = model.manifest.blocks();
        assert_eq!(
            batcher.batch * (batcher.seq + 1),
            model.manifest.batch * (model.manifest.seq + 1),
            "batcher must match artifact batch/seq"
        );
        Self {
            model,
            batcher,
            blocks,
        }
    }
}

impl GradSource for PjrtSource {
    fn blocks(&self) -> &[BlockSpec] {
        &self.blocks
    }

    fn workers(&self) -> usize {
        self.batcher.workers()
    }

    fn compute(&mut self, params: &[Matrix], _step: usize, grads: &mut [Vec<Matrix>]) -> f32 {
        let workers = self.batcher.workers();
        let mut loss_sum = 0.0f32;
        for w in 0..workers {
            let tokens = self.batcher.next_block(w);
            let (loss, g) = self
                .model
                .step(params, &tokens)
                .unwrap_or_else(|e| panic!("pjrt step failed (worker {w}): {e}"));
            loss_sum += loss;
            for (dst, src) in grads[w].iter_mut().zip(g.into_iter()) {
                *dst = src;
            }
        }
        loss_sum / workers as f32
    }

    fn init_params(&self, seed: u64) -> Vec<Matrix> {
        let mut rng = Xoshiro256::new(seed);
        self.blocks
            .iter()
            .map(|b| init_block(b, &mut rng))
            .collect()
    }
}

/// Standard transformer init: norms → 1, embeddings → N(0, 0.02),
/// linear → N(0, 1/√fan_in).
pub fn init_block(b: &BlockSpec, rng: &mut Xoshiro256) -> Matrix {
    use crate::comm::LayerClass::*;
    match b.class {
        Vector => {
            // RMSNorm weights start at 1.
            let mut m = Matrix::zeros(b.rows, b.cols);
            m.fill(1.0);
            m
        }
        Embedding => Matrix::gaussian(b.rows, b.cols, 0.02, rng),
        Linear => {
            let scale = 1.0 / (b.rows as f32).sqrt();
            Matrix::gaussian(b.rows, b.cols, scale, rng)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::LayerClass;

    #[test]
    fn init_rules() {
        let mut rng = Xoshiro256::new(0);
        let norm = init_block(
            &BlockSpec {
                name: "norm".into(),
                rows: 1,
                cols: 8,
                class: LayerClass::Vector,
            },
            &mut rng,
        );
        assert!(norm.data.iter().all(|&v| v == 1.0));
        let emb = init_block(
            &BlockSpec {
                name: "e".into(),
                rows: 100,
                cols: 32,
                class: LayerClass::Embedding,
            },
            &mut rng,
        );
        assert!(emb.frob_norm() > 0.0 && emb.max_abs() < 0.2);
    }
}
