//! Micro-benchmark harness (no `criterion` in the offline universe).
//!
//! Used by the `rust/benches/*.rs` targets (declared with
//! `harness = false`). Provides warmup, adaptive iteration counts,
//! and robust statistics (median + MAD), printing criterion-style lines:
//!
//! ```text
//! bench_name              time: [median 1.234 ms]  (n=52, mad 0.8%)
//! ```

use crate::util::json::Json;
use std::time::{Duration, Instant};

/// One collected result: the printed name, the p50 (or raw scalar)
/// value, and the label set active when it was recorded.
struct Entry {
    name: String,
    value: f64,
    labels: Vec<(String, String)>,
}

pub struct Bencher {
    /// Minimum total measurement time per benchmark.
    pub measure_time: Duration,
    pub warmup_time: Duration,
    results: Vec<Entry>,
    /// Labels stamped onto subsequent results ([`Self::set_labels`]):
    /// method/fmt/scale cell coordinates, so `ci/bench_regression.py`
    /// can refuse to diff unlike cells.
    labels: Vec<(String, String)>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Self {
        Self {
            measure_time: Duration::from_millis(
                std::env::var("BENCH_MS")
                    .ok()
                    .and_then(|v| v.parse().ok())
                    .unwrap_or(600),
            ),
            warmup_time: Duration::from_millis(150),
            results: Vec::new(),
            labels: Vec::new(),
        }
    }

    /// Set the labels (`[("method", "tsr"), ("fmt", "f32")]`-style cell
    /// coordinates) attached to every subsequently recorded result.
    /// Call with `&[]` to clear.
    pub fn set_labels(&mut self, labels: &[(&str, &str)]) {
        self.labels = labels
            .iter()
            .map(|(k, v)| (k.to_string(), v.to_string()))
            .collect();
    }

    fn push(&mut self, name: &str, value: f64) {
        self.results.push(Entry {
            name: name.to_string(),
            value,
            labels: self.labels.clone(),
        });
    }

    /// Benchmark `f`, which should perform one unit of work per call.
    /// Returns the median time per call in seconds.
    pub fn bench(&mut self, name: &str, mut f: impl FnMut()) -> f64 {
        // Warmup + estimate per-iteration cost.
        let warm_start = Instant::now();
        let mut iters = 0u64;
        while warm_start.elapsed() < self.warmup_time || iters < 1 {
            f();
            iters += 1;
        }
        let per_iter = warm_start.elapsed().as_secs_f64() / iters as f64;

        // Aim for ~30 samples within the measurement budget; batch cheap
        // functions so each sample is at least ~100 µs.
        let batch = ((1e-4 / per_iter).ceil() as u64).max(1);
        let target_samples = 30usize;
        let mut samples = Vec::with_capacity(target_samples);
        let meas_start = Instant::now();
        while samples.len() < target_samples && meas_start.elapsed() < self.measure_time {
            let t = Instant::now();
            for _ in 0..batch {
                f();
            }
            samples.push(t.elapsed().as_secs_f64() / batch as f64);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = samples[samples.len() / 2];
        let mad = {
            let mut dev: Vec<f64> = samples.iter().map(|s| (s - median).abs()).collect();
            dev.sort_by(|a, b| a.partial_cmp(b).unwrap());
            dev[dev.len() / 2]
        };
        println!(
            "{:<44} time: [{:>12}]  (n={}, batch={}, mad {:.1}%)",
            name,
            fmt_time(median),
            samples.len(),
            batch,
            100.0 * mad / median.max(1e-30),
        );
        self.push(name, median);
        median
    }

    /// Report a pre-measured scalar (e.g. simulated time or bytes) in the
    /// same table format.
    pub fn report(&mut self, name: &str, value: f64, unit: &str) {
        println!("{:<44} value: {:>14.4} {}", name, value, unit);
        self.push(name, value);
    }

    pub fn results(&self) -> Vec<(String, f64)> {
        self.results.iter().map(|e| (e.name.clone(), e.value)).collect()
    }

    /// Write the collected results (p50 medians from [`Self::bench`],
    /// raw scalars from [`Self::report`]) as a `BENCH_<name>.json`
    /// artifact under the `BENCH_JSON_DIR` directory. Returns `None`
    /// (and writes nothing) when the env var is unset — local runs stay
    /// print-only; CI's bench-smoke job sets it and uploads the files,
    /// which `ci/bench_regression.py` then compares against a baseline.
    pub fn write_json(&self, bench: &str) -> Option<std::path::PathBuf> {
        let dir = std::env::var("BENCH_JSON_DIR").ok()?;
        let entries: Vec<Json> = self
            .results
            .iter()
            .map(|e| {
                let mut o = Json::obj(vec![
                    ("name", Json::str(e.name.clone())),
                    ("value", Json::num(e.value)),
                ]);
                if !e.labels.is_empty() {
                    o.set(
                        "labels",
                        Json::Obj(
                            e.labels
                                .iter()
                                .map(|(k, v)| (k.clone(), Json::str(v.clone())))
                                .collect(),
                        ),
                    );
                }
                o
            })
            .collect();
        // Artifact-level labels: the execution backend every entry ran
        // under (bench binaries honor TSR_BACKEND), so the regression
        // gate can refuse to diff a threaded artifact against a
        // sequential baseline.
        let j = Json::obj(vec![
            ("bench", Json::str(bench)),
            ("stat", Json::str("p50")),
            (
                "labels",
                Json::obj(vec![(
                    "backend",
                    Json::str(crate::exec::ExecBackend::from_env().name()),
                )]),
            ),
            ("results", Json::Arr(entries)),
        ]);
        std::fs::create_dir_all(&dir).ok()?;
        let path = std::path::Path::new(&dir).join(format!("BENCH_{bench}.json"));
        std::fs::write(&path, j.to_string_pretty()).ok()?;
        println!("-> wrote {}", path.display());
        Some(path)
    }
}

pub fn fmt_time(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} µs", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

/// Human-readable byte count (GiB as "G" to match the paper's tables).
pub fn fmt_bytes(bytes: f64) -> String {
    const G: f64 = 1024.0 * 1024.0 * 1024.0;
    const M: f64 = 1024.0 * 1024.0;
    const K: f64 = 1024.0;
    if bytes >= 0.01 * G {
        format!("{:.3}G", bytes / G)
    } else if bytes >= M {
        format!("{:.2}M", bytes / M)
    } else if bytes >= K {
        format!("{:.1}K", bytes / K)
    } else {
        format!("{bytes:.0}B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_positive_time() {
        let mut b = Bencher::new();
        b.measure_time = Duration::from_millis(30);
        b.warmup_time = Duration::from_millis(5);
        let t = b.bench("noop-ish", || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(t > 0.0);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn write_json_is_gated_on_env_and_roundtrips() {
        let mut b = Bencher::new();
        b.set_labels(&[("method", "tsr"), ("fmt", "f32")]);
        b.report("x.y", 1.25, "s");
        b.set_labels(&[]);
        b.report("unlabeled", 2.0, "s");
        if std::env::var("BENCH_JSON_DIR").is_err() {
            assert!(b.write_json("unit_test_nowrite").is_none());
        }
        let dir = std::env::temp_dir().join("tsr_bench_json_test");
        std::env::set_var("BENCH_JSON_DIR", &dir);
        let p = b.write_json("unit_test").expect("written");
        std::env::remove_var("BENCH_JSON_DIR");
        let s = std::fs::read_to_string(&p).unwrap();
        let j = Json::parse(&s).unwrap();
        assert_eq!(j.get("bench").as_str(), Some("unit_test"));
        // Artifact carries the backend label; entries carry their cell
        // labels (and unlabeled entries stay label-free).
        assert!(j.get("labels").get("backend").as_str().is_some());
        let entries = j.get("results").as_arr().unwrap();
        assert_eq!(entries[0].get("labels").get("method").as_str(), Some("tsr"));
        assert_eq!(entries[0].get("labels").get("fmt").as_str(), Some("f32"));
        assert_eq!(entries[1].get("labels"), &Json::Null);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_time(2.0), "2.000 s");
        assert!(fmt_time(2e-3).contains("ms"));
        assert!(fmt_time(2e-6).contains("µs"));
        assert!(fmt_bytes(1.5 * 1024.0 * 1024.0 * 1024.0).ends_with('G'));
        assert!(fmt_bytes(2.0 * 1024.0 * 1024.0).ends_with('M'));
    }
}
