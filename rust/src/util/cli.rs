//! Tiny command-line argument parser (no `clap` in the offline universe).
//!
//! Supports `program SUBCOMMAND [--flag] [--key value] [positional...]`.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse(items: impl Iterator<Item = String>) -> Self {
        let mut out = Args::default();
        let mut items = items.peekable();
        while let Some(item) = items.next() {
            if let Some(name) = item.strip_prefix("--") {
                // `--key=value`, `--key value`, or bare `--flag`.
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if items
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = items.next().unwrap();
                    out.options.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(item);
            } else {
                out.positional.push(item);
            }
        }
        out
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        // NOTE: a bare `--flag` followed by a non-dashed token would
        // consume it as a value; positionals therefore come first.
        let a = parse("train pos1 --config cfg.json --steps 100 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("config"), Some("cfg.json"));
        assert_eq!(a.get_usize("steps", 0), 100);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn key_equals_value() {
        let a = parse("bench --rank=256 --method=tsr");
        assert_eq!(a.get_usize("rank", 0), 256);
        assert_eq!(a.get("method"), Some("tsr"));
    }

    #[test]
    fn trailing_flag() {
        let a = parse("run --fast");
        assert!(a.flag("fast"));
        assert_eq!(a.get("fast"), None);
    }
}
