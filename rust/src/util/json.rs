//! Minimal JSON parser/serializer.
//!
//! The offline crate universe has no `serde`/`serde_json`, so the repo
//! carries its own small JSON implementation. It is used for:
//! * experiment configs (`configs/*.json`),
//! * the artifact manifest written by `python/compile/aot.py`,
//! * machine-readable results emitted by the bench harness.
//!
//! Supports the full JSON data model with f64 numbers; good enough for
//! configuration-sized documents (not a streaming parser).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---------- accessors ----------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|n| n as u64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]`-style access; returns Null for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Typed getters with defaults — the common config pattern.
    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).as_f64().unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).as_usize().unwrap_or(default)
    }

    pub fn get_bool(&self, key: &str, default: bool) -> bool {
        self.get(key).as_bool().unwrap_or(default)
    }

    pub fn get_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).as_str().unwrap_or(default)
    }

    // ---------- constructors ----------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    /// Insert or replace `key` in an object document (no-op on
    /// non-objects) — the config-echo update pattern.
    pub fn set(&mut self, key: &str, v: Json) {
        if let Json::Obj(o) = self {
            o.insert(key.to_string(), v);
        }
    }

    // ---------- file IO ----------

    /// Parse a JSON document from a file.
    pub fn read_file(path: impl AsRef<std::path::Path>) -> Result<Json, String> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        Json::parse(&text).map_err(|e| format!("parse {}: {e}", path.display()))
    }

    /// Write the pretty-printed document atomically: serialize into a
    /// sibling `*.tmp` file, then rename over the target, so a reader
    /// (or a killed writer — the checkpoint use case) never observes a
    /// half-written manifest.
    pub fn write_file_atomic(&self, path: impl AsRef<std::path::Path>) -> Result<(), String> {
        write_text_atomic(path, &self.to_string_pretty())
    }

    // ---------- parse ----------

    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let b = input.as_bytes();
        let mut p = Parser { b, i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---------- serialize ----------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

/// Atomic text-file write: create the parent directory, serialize into
/// a sibling `*.tmp`, rename over the target. Every failure names the
/// path it failed on. Shared by checkpoint manifests
/// ([`Json::write_file_atomic`]), trace JSONL artifacts
/// (`obs::Tracer::write_jsonl`), and `RunMetrics::write_csv`.
pub fn write_text_atomic(path: impl AsRef<std::path::Path>, text: &str) -> Result<(), String> {
    let path = path.as_ref();
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir).map_err(|e| format!("mkdir {}: {e}", dir.display()))?;
    }
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, text).map_err(|e| format!("write {}: {e}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .map_err(|e| format!("rename {} -> {}: {e}", tmp.display(), path.display()))
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.i,
        }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a run of plain bytes (fast path, keeps UTF-8 intact).
                    let start = self.i;
                    while matches!(self.peek(), Some(c) if c != b'"' && c != b'\\') {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut o = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(o));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            o.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(o));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e3}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").as_f64(), Some(1.0));
        assert_eq!(v.get("b").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").get("d").as_f64(), Some(-2500.0));
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn pretty_roundtrip() {
        let v = Json::obj(vec![
            ("name", Json::str("tsr")),
            ("ranks", Json::Arr(vec![Json::num(64.0), Json::num(128.0)])),
        ]);
        let re = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("{} x").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn set_inserts_and_replaces_keys() {
        let mut v = Json::obj(vec![("a", Json::num(1.0))]);
        v.set("a", Json::num(2.0));
        v.set("b", Json::str("x"));
        assert_eq!(v.get("a").as_f64(), Some(2.0));
        assert_eq!(v.get("b").as_str(), Some("x"));
        let mut arr = Json::arr(vec![]);
        arr.set("a", Json::num(1.0)); // no-op, no panic
        assert_eq!(arr, Json::arr(vec![]));
    }

    #[test]
    fn file_roundtrip_atomic() {
        let v = Json::obj(vec![
            ("step", Json::num(13.0)),
            ("arr", Json::arr(vec![Json::num(1.0), Json::Bool(true)])),
        ]);
        let dir = std::env::temp_dir().join("tsr_json_io_test");
        let p = dir.join("doc.json");
        v.write_file_atomic(&p).unwrap();
        assert_eq!(Json::read_file(&p).unwrap(), v);
        // No .tmp file left behind.
        assert!(!p.with_extension("tmp").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn typed_getters_defaults() {
        let v = Json::parse(r#"{"n": 4}"#).unwrap();
        assert_eq!(v.get_usize("n", 0), 4);
        assert_eq!(v.get_usize("missing", 7), 7);
        assert_eq!(v.get_str("missing", "dflt"), "dflt");
    }
}
