//! Self-contained utility substrates (the offline crate universe contains
//! only `xla` + its deps, so RNG, JSON, threading, CLI parsing, benching
//! and property testing are all implemented here).

pub mod bench;
pub mod cli;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
