//! Scoped thread pool.
//!
//! The coordinator simulates N data-parallel workers in-process and the
//! linear-algebra kernels parallelize over row blocks. With no `rayon` in
//! the offline crate universe we provide a small scoped parallel-for built
//! on `std::thread::scope` with static chunking — adequate because our
//! workloads are regular (equal-sized tiles / equal-sized workers).

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use, clamped to available parallelism.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

/// Run `f(i)` for every `i in 0..n`, distributing indices over up to
/// `threads` OS threads via an atomic work counter (dynamic scheduling —
/// robust when iterations are uneven, e.g. mixed layer sizes).
pub fn parallel_for(n: usize, threads: usize, f: impl Fn(usize) + Sync) {
    if n == 0 {
        return;
    }
    let t = threads.min(n).max(1);
    if t == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let counter = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..t {
            scope.spawn(|| loop {
                let i = counter.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Map `f` over `0..n` in parallel, collecting results in index order.
pub fn parallel_map<T: Send>(n: usize, threads: usize, f: impl Fn(usize) -> T + Sync) -> Vec<T> {
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    {
        let slots: Vec<SendPtr<Option<T>>> =
            out.iter_mut().map(|s| SendPtr(s as *mut Option<T>)).collect();
        let slots = &slots;
        parallel_for(n, threads, move |i| {
            // SAFETY: each index i is visited exactly once, and slot i is
            // only written by the thread that claimed i.
            unsafe { slots[i].0.write(Some(f(i))) };
        });
    }
    out.into_iter().map(|v| v.expect("slot filled")).collect()
}

struct SendPtr<T>(*mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn visits_every_index_once() {
        let hits = AtomicU64::new(0);
        parallel_for(1000, 8, |i| {
            hits.fetch_add(i as u64 + 1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 500_500);
    }

    #[test]
    fn map_preserves_order() {
        let v = parallel_map(257, 5, |i| i * i);
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i * i);
        }
    }

    #[test]
    fn handles_zero_and_one() {
        parallel_for(0, 4, |_| panic!("should not run"));
        let v = parallel_map(1, 4, |i| i + 41);
        assert_eq!(v, vec![41]);
    }
}
