//! Lightweight property-testing helper (no `proptest` in the offline
//! universe).
//!
//! Runs a property over many randomly generated cases with a fixed base
//! seed; on failure it reports the failing seed so the case can be
//! reproduced with `check_with_seed`. Used for coordinator/linalg
//! invariants (orthonormality, all-reduce identities, byte accounting).

use crate::util::rng::Xoshiro256;

pub const DEFAULT_CASES: usize = 64;

/// Run `prop(rng)` for `cases` random cases. `prop` should panic (e.g.
/// via assert!) on violation; we re-panic with the offending seed.
pub fn check(name: &str, cases: usize, prop: impl Fn(&mut Xoshiro256)) {
    let base = 0xC0FF_EE00_D15E_A5Eu64;
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64);
        let mut rng = Xoshiro256::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property '{name}' failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Reproduce a single failing case.
pub fn check_with_seed(seed: u64, prop: impl Fn(&mut Xoshiro256)) {
    let mut rng = Xoshiro256::new(seed);
    prop(&mut rng);
}

/// Helpers for generating common shapes.
pub fn dim(rng: &mut Xoshiro256, lo: usize, hi: usize) -> usize {
    lo + rng.next_below((hi - lo + 1) as u64) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("u64 parity", 32, |rng| {
            let x = rng.next_u64();
            assert_eq!(x % 2, x & 1);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn reports_failing_seed() {
        check("always fails", 4, |_| panic!("boom"));
    }

    #[test]
    fn dim_in_range() {
        check("dim bounds", 64, |rng| {
            let d = dim(rng, 3, 17);
            assert!((3..=17).contains(&d));
        });
    }
}
