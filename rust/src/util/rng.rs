//! Deterministic pseudo-random number generation.
//!
//! TSR's randomized-SVD refresh (paper §3.5, Algorithm 1) requires every
//! worker to draw the *same* Gaussian sketch matrix Ω from a shared seed.
//! We therefore need a small, fully deterministic, splittable RNG that is
//! identical across workers and across runs. No external crates are
//! available in this build environment, so this module implements:
//!
//! * [`SplitMix64`] — seed expander (Steele et al., 2014).
//! * [`Xoshiro256`] — xoshiro256** main generator (Blackman & Vigna).
//! * Box–Muller standard normals for Gaussian sketches.

/// SplitMix64: used to expand a single `u64` seed into generator state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — fast, high-quality 64-bit generator.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
    /// Cached second Box–Muller output.
    gauss_spare: Option<f64>,
}

impl Xoshiro256 {
    /// Construct from a seed; the state is expanded with SplitMix64 so any
    /// seed (including 0) yields a well-mixed state.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            gauss_spare: None,
        }
    }

    /// Derive a stream-specific generator: identical (seed, stream) pairs
    /// produce identical streams on every worker. Used for the shared
    /// sketch Ω (stream = (layer id, refresh index)).
    pub fn for_stream(seed: u64, stream: u64) -> Self {
        // Mix the stream id through SplitMix so streams are decorrelated.
        let mut sm = SplitMix64::new(seed ^ stream.wrapping_mul(0xA24B_AED4_963E_E407));
        Self::new(sm.next_u64())
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits → [0,1) double.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        // Lemire-style rejection-free for our (non-crypto) purposes.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Standard normal via Box–Muller (caches the second deviate).
    pub fn next_gaussian(&mut self) -> f64 {
        if let Some(g) = self.gauss_spare.take() {
            return g;
        }
        loop {
            let u1 = self.next_f64();
            let u2 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.gauss_spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    #[inline]
    pub fn next_gaussian_f32(&mut self) -> f32 {
        self.next_gaussian() as f32
    }

    /// Fill a slice with i.i.d. N(0, 1) f32 values.
    pub fn fill_gaussian(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.next_gaussian_f32();
        }
    }

    /// Snapshot the full generator state — the 256-bit xoshiro state plus
    /// the cached Box–Muller spare — for checkpointing. Restoring via
    /// [`Self::from_snapshot`] continues the stream at exactly the same
    /// position, so a resumed run draws the identical tail of deviates.
    pub fn snapshot(&self) -> ([u64; 4], Option<f64>) {
        (self.s, self.gauss_spare)
    }

    /// Rebuild a generator from a [`Self::snapshot`].
    pub fn from_snapshot(s: [u64; 4], gauss_spare: Option<f64>) -> Self {
        Self { s, gauss_spare }
    }

    /// Sample from a categorical distribution given cumulative weights
    /// (ascending, last element = total mass). Returns the index.
    pub fn next_categorical(&mut self, cumulative: &[f64]) -> usize {
        let total = *cumulative.last().expect("empty categorical");
        let x = self.next_f64() * total;
        match cumulative.binary_search_by(|c| c.partial_cmp(&x).unwrap()) {
            Ok(i) => (i + 1).min(cumulative.len() - 1),
            Err(i) => i.min(cumulative.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Xoshiro256::new(42);
        let mut b = Xoshiro256::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Xoshiro256::for_stream(7, 0);
        let mut b = Xoshiro256::for_stream(7, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2, "streams should be decorrelated");
    }

    #[test]
    fn uniform_range() {
        let mut r = Xoshiro256::new(1);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Xoshiro256::new(3);
        let n = 200_000;
        let (mut sum, mut sumsq) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let g = r.next_gaussian();
            sum += g;
            sumsq += g * g;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn snapshot_restore_continues_stream_exactly() {
        let mut r = Xoshiro256::new(77);
        // Advance by an ODD number of gaussians so the Box–Muller spare
        // is populated — the snapshot must carry it.
        for _ in 0..7 {
            r.next_gaussian();
        }
        let (s, spare) = r.snapshot();
        assert!(spare.is_some(), "odd draw count must leave a spare");
        let mut resumed = Xoshiro256::from_snapshot(s, spare);
        for _ in 0..100 {
            assert_eq!(r.next_gaussian().to_bits(), resumed.next_gaussian().to_bits());
        }
        for _ in 0..100 {
            assert_eq!(r.next_u64(), resumed.next_u64());
        }
    }

    #[test]
    fn next_below_in_range() {
        let mut r = Xoshiro256::new(9);
        for _ in 0..10_000 {
            assert!(r.next_below(17) < 17);
        }
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Xoshiro256::new(11);
        let cum = vec![0.1, 0.1 + 0.7, 1.0];
        let mut counts = [0usize; 3];
        for _ in 0..50_000 {
            counts[r.next_categorical(&cum)] += 1;
        }
        assert!(counts[1] > counts[0] * 3);
        assert!(counts[1] > counts[2] * 2);
    }
}
