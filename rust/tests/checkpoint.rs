//! Checkpoint / resume integration tests (DESIGN.md §9).
//!
//! The headline contract: a run interrupted at ANY step and resumed
//! from its checkpoint — through a full JSON text round trip — is
//! **bitwise identical** to the uninterrupted run: same deterministic
//! metrics JSON, same final-weight fingerprint, same ledger columns,
//! for every method. Plus: manifest file round trips, and elastic
//! world-size resumes re-shard error-feedback state (ragged numel
//! included).

use tsr::checkpoint::Checkpoint;
use tsr::comm::{CommLedger, Topology};
use tsr::exec::ExecBackend;
use tsr::exp::MethodCfg;
use tsr::linalg::Matrix;
use tsr::metrics::RunMetrics;
use tsr::model::ModelSpec;
use tsr::optim::onesided::OneSidedRefresh;
use tsr::optim::{AdamHyper, DistOptimizer, LrSchedule, TsrConfig};
use tsr::train::gradsim::QuadraticSim;
use tsr::train::lm_source::LmSource;
use tsr::train::{GradSource, Trainer};
use tsr::util::json::Json;

fn all_nine(k: usize) -> Vec<MethodCfg> {
    let tsr = TsrConfig {
        rank: 8,
        rank_emb: 4,
        refresh_every: k,
        refresh_emb: k,
        oversample: 3,
        ..Default::default()
    };
    vec![
        MethodCfg::Adam,
        MethodCfg::OneSided {
            rank: 6,
            k,
            refresh: OneSidedRefresh::ExactSvd,
        },
        MethodCfg::Tsr(tsr.clone()),
        MethodCfg::TsrSgd(tsr),
        MethodCfg::PowerSgd { rank: 5 },
        MethodCfg::Sign { k_var: k },
        MethodCfg::TopK { keep_frac: 0.03 },
        // Local-update methods: the cuts below (7, 10) land mid-local-
        // phase for these cadences, exercising the phase counters in
        // their checkpoints.
        MethodCfg::DesLoc { k_p: 2, k_m: 4, k_v: 8 },
        MethodCfg::Lordo { rank: 6, h: 5 },
    ]
}

const WORKERS: usize = 2;

/// Process backend with the worker binary pinned to the real `tsr`
/// executable (this test harness binary cannot re-exec as a worker).
fn process_exec() -> ExecBackend {
    tsr::exec::process::set_worker_binary(std::path::PathBuf::from(env!("CARGO_BIN_EXE_tsr")));
    ExecBackend::process()
}

fn fresh_setup(m: &MethodCfg) -> (QuadraticSim, Box<dyn DistOptimizer>, Vec<Matrix>) {
    let spec = ModelSpec::proxy(300, 24, 48, 2, 2);
    let sim = QuadraticSim::new(&spec, WORKERS, 6, 0.01, 11);
    let blocks = sim.blocks().to_vec();
    let opt = m.build(&blocks, AdamHyper::default(), WORKERS);
    let params = sim.init_params(1);
    (sim, opt, params)
}

fn trainer(total_steps: usize) -> Trainer {
    Trainer::new(Topology::multi_node(2, 1), LrSchedule::paper(total_steps))
}

/// Run the full `[0, steps)` range uninterrupted.
fn run_uninterrupted(m: &MethodCfg, steps: usize) -> String {
    let (mut sim, mut opt, mut params) = fresh_setup(m);
    let (metrics, ledger) = trainer(steps).run(&mut sim, opt.as_mut(), &mut params, steps);
    metrics.to_json_deterministic(&ledger, &params).to_string_pretty()
}

/// Run `[0, cut)`, checkpoint through a full JSON **text** round trip,
/// rebuild every object from scratch, resume `[cut, steps)`.
fn run_interrupted(m: &MethodCfg, cut: usize, steps: usize) -> String {
    let (mut sim, mut opt, mut params) = fresh_setup(m);
    let (metrics, ledger) = trainer(steps).run(&mut sim, opt.as_mut(), &mut params, cut);
    let ck = Checkpoint::capture(
        cut as u64,
        WORKERS,
        &params,
        opt.as_ref(),
        &sim,
        &metrics,
        &ledger,
        Json::Null,
    );
    let text = ck.to_json().to_string_pretty();
    drop((sim, opt, params, metrics, ledger));

    // The "new process": everything rebuilt from config + manifest.
    let ck = Checkpoint::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(ck.step, cut as u64);
    let (mut sim, mut opt, _) = fresh_setup(m);
    assert_eq!(opt.name(), ck.method);
    opt.load_state(&ck.opt_state, WORKERS).unwrap();
    sim.load_state(&ck.source_state).unwrap();
    let mut params = ck.params.clone();
    let metrics = RunMetrics::state_from_json(&ck.metrics).unwrap();
    let ledger = CommLedger::from_json(&ck.ledger).unwrap();
    let (metrics, ledger) = trainer(steps).run_from(
        &mut sim,
        opt.as_mut(),
        &mut params,
        cut,
        steps,
        metrics,
        ledger,
    );
    metrics.to_json_deterministic(&ledger, &params).to_string_pretty()
}

/// Tentpole: interrupt at a MID-PERIOD step (cut=7, refresh k=5) and
/// at a refresh boundary (cut=10); both resumes must be byte-identical
/// to the uninterrupted run for all nine methods.
#[test]
fn resumed_run_is_byte_identical_to_uninterrupted_for_every_method() {
    let k = 5;
    let steps = 17;
    for m in all_nine(k) {
        let full = run_uninterrupted(&m, steps);
        for cut in [7usize, 10] {
            let resumed = run_interrupted(&m, cut, steps);
            assert_eq!(
                full,
                resumed,
                "{}: resume at step {cut} diverged from the uninterrupted run",
                m.label()
            );
        }
    }
}

/// Backend-crossing resume (DESIGN.md §9, §12): a checkpoint written
/// by a **Sequential** run, round-tripped through JSON text, then
/// resumed under the **Process** backend (real child processes, socket
/// ring collectives) must be byte-identical to the all-sequential
/// uninterrupted run — manifests are backend-portable, and the socket
/// rings keep every post-resume step on the same bit trajectory.
#[test]
fn seq_written_checkpoint_resumes_bitwise_under_process_backend() {
    let k = 5;
    let steps = 17;
    let cut = 7;
    for m in all_nine(k) {
        // Reference: the uninterrupted run, fully sequential.
        let full = {
            let (mut sim, mut opt, mut params) = fresh_setup(&m);
            let (metrics, ledger) = trainer(steps)
                .with_backend(ExecBackend::Sequential)
                .run(&mut sim, opt.as_mut(), &mut params, steps);
            metrics.to_json_deterministic(&ledger, &params).to_string_pretty()
        };

        // [0, cut) sequential, checkpoint through a JSON text round
        // trip, resume [cut, steps) on the process backend.
        let (mut sim, mut opt, mut params) = fresh_setup(&m);
        let (metrics, ledger) = trainer(steps)
            .with_backend(ExecBackend::Sequential)
            .run(&mut sim, opt.as_mut(), &mut params, cut);
        let ck = Checkpoint::capture(
            cut as u64,
            WORKERS,
            &params,
            opt.as_ref(),
            &sim,
            &metrics,
            &ledger,
            Json::Null,
        );
        let text = ck.to_json().to_string_pretty();
        drop((sim, opt, params, metrics, ledger));

        let ck = Checkpoint::from_json(&Json::parse(&text).unwrap()).unwrap();
        let (mut sim, mut opt, _) = fresh_setup(&m);
        opt.load_state(&ck.opt_state, WORKERS).unwrap();
        sim.load_state(&ck.source_state).unwrap();
        let mut params = ck.params.clone();
        let metrics = RunMetrics::state_from_json(&ck.metrics).unwrap();
        let ledger = CommLedger::from_json(&ck.ledger).unwrap();
        let (metrics, ledger) = trainer(steps).with_backend(process_exec()).run_from(
            &mut sim,
            opt.as_mut(),
            &mut params,
            cut,
            steps,
            metrics,
            ledger,
        );
        let resumed = metrics.to_json_deterministic(&ledger, &params).to_string_pretty();
        assert_eq!(
            full,
            resumed,
            "{}: sequential-written checkpoint diverged when resumed under the process backend",
            m.label()
        );
    }
}

// ---------- native-LM source (--source lm) ----------

fn fresh_lm_setup(m: &MethodCfg) -> (LmSource, Box<dyn DistOptimizer>, Vec<Matrix>) {
    let spec = ModelSpec::proxy(32, 16, 24, 2, 2);
    let src = LmSource::new(&spec, WORKERS, 2, 8, 21);
    let blocks = src.blocks().to_vec();
    let opt = m.build(&blocks, AdamHyper::default(), WORKERS);
    let params = src.init_params(4);
    (src, opt, params)
}

fn run_lm_uninterrupted(m: &MethodCfg, steps: usize) -> String {
    let (mut src, mut opt, mut params) = fresh_lm_setup(m);
    let (metrics, ledger) = trainer(steps).run(&mut src, opt.as_mut(), &mut params, steps);
    metrics.to_json_deterministic(&ledger, &params).to_string_pretty()
}

fn run_lm_interrupted(m: &MethodCfg, cut: usize, steps: usize) -> String {
    let (mut src, mut opt, mut params) = fresh_lm_setup(m);
    let (metrics, ledger) = trainer(steps).run(&mut src, opt.as_mut(), &mut params, cut);
    let ck = Checkpoint::capture(
        cut as u64,
        WORKERS,
        &params,
        opt.as_ref(),
        &src,
        &metrics,
        &ledger,
        Json::Null,
    );
    let text = ck.to_json().to_string_pretty();
    drop((src, opt, params, metrics, ledger));

    let ck = Checkpoint::from_json(&Json::parse(&text).unwrap()).unwrap();
    let (mut src, mut opt, _) = fresh_lm_setup(m);
    opt.load_state(&ck.opt_state, WORKERS).unwrap();
    src.load_state(&ck.source_state).unwrap();
    let mut params = ck.params.clone();
    let metrics = RunMetrics::state_from_json(&ck.metrics).unwrap();
    let ledger = CommLedger::from_json(&ck.ledger).unwrap();
    let (metrics, ledger) = trainer(steps).run_from(
        &mut src,
        opt.as_mut(),
        &mut params,
        cut,
        steps,
        metrics,
        ledger,
    );
    metrics.to_json_deterministic(&ledger, &params).to_string_pretty()
}

/// `--source lm` leg of the bitwise-resume contract: the LM source's
/// state is its per-worker token-stream positions; killed mid-period
/// (cut 3, k=4) or at a boundary (cut 8) and resumed through a full
/// JSON text round trip must be byte-identical to the uninterrupted
/// run — for the dense baseline, for TSR (whose refresh cadence must
/// restart mid-period correctly), and for an error-feedback method.
#[test]
fn lm_resumed_run_is_byte_identical_to_uninterrupted() {
    let k = 4;
    let methods = vec![
        MethodCfg::Adam,
        MethodCfg::Tsr(TsrConfig {
            rank: 6,
            rank_emb: 4,
            refresh_every: k,
            refresh_emb: k,
            oversample: 3,
            ..Default::default()
        }),
        MethodCfg::TopK { keep_frac: 0.05 },
    ];
    let steps = 11;
    for m in methods {
        let full = run_lm_uninterrupted(&m, steps);
        for cut in [3usize, 8] {
            let resumed = run_lm_interrupted(&m, cut, steps);
            assert_eq!(
                full,
                resumed,
                "{} (lm source): resume at step {cut} diverged from the uninterrupted run",
                m.label()
            );
        }
    }
}

/// Manifest file round trip: save to disk, load, bitwise params and
/// field equality.
#[test]
fn manifest_file_roundtrip_is_bitwise() {
    let m = MethodCfg::Tsr(TsrConfig {
        rank: 8,
        rank_emb: 4,
        refresh_every: 5,
        refresh_emb: 5,
        oversample: 3,
        ..Default::default()
    });
    let (mut sim, mut opt, mut params) = fresh_setup(&m);
    let (metrics, ledger) = trainer(9).run(&mut sim, opt.as_mut(), &mut params, 6);
    let ck = Checkpoint::capture(
        6,
        WORKERS,
        &params,
        opt.as_ref(),
        &sim,
        &metrics,
        &ledger,
        Json::obj(vec![("source", Json::str("quad"))]),
    );
    let dir = std::env::temp_dir().join("tsr_ckpt_file_test");
    let path = ck.save(&dir).unwrap();
    assert!(path.ends_with("ckpt_step6.json"));
    let back = Checkpoint::load(&path).unwrap();
    assert_eq!(back.step, 6);
    assert_eq!(back.workers, WORKERS);
    assert_eq!(back.method, "tsr-adam");
    assert_eq!(back.config.get_str("source", "?"), "quad");
    assert_eq!(back.params.len(), ck.params.len());
    for (a, b) in ck.params.iter().zip(&back.params) {
        for (x, y) in a.data.iter().zip(&b.data) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }
    assert_eq!(back.opt_state, ck.opt_state);
    assert_eq!(back.ledger, ck.ledger);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Elastic restart with ragged shards: error-feedback methods saved at
/// one world size must load at another (numel % workers != 0), with
/// the re-sharded buffers accounted in state_elements and the run
/// still training.
#[test]
fn elastic_resume_reshards_error_feedback_on_ragged_numel() {
    use tsr::model::BlockSpec;
    // 5×7 = 35 elements: ragged for both 3 and 2 workers.
    let blocks = vec![BlockSpec {
        name: "w".into(),
        rows: 5,
        cols: 7,
        class: tsr::comm::LayerClass::Linear,
    }];
    for m in [MethodCfg::TopK { keep_frac: 0.1 }, MethodCfg::Sign { k_var: 4 }] {
        // Train at W=3 so the per-worker residuals are nonzero.
        let mut opt3 = m.build(&blocks, AdamHyper::default(), 3);
        let mut params = vec![Matrix::zeros(5, 7)];
        let topo3 = Topology::single_node(3);
        let mut ledger = CommLedger::new();
        let mut rng = tsr::util::rng::Xoshiro256::new(8);
        for _ in 0..3 {
            let mut grads: Vec<Vec<Matrix>> = (0..3)
                .map(|_| vec![Matrix::gaussian(5, 7, 1.0, &mut rng)])
                .collect();
            opt3.step(&mut tsr::optim::StepCtx {
                params: &mut params,
                grads: &mut grads,
                ledger: &mut ledger,
                topo: &topo3,
                lr_mult: 1.0,
                exec: &tsr::exec::ExecBackend::Sequential,
            });
            ledger.end_step();
        }
        let saved = opt3.save_state().to_string_pretty();
        let state = Json::parse(&saved).unwrap();

        // Same world size: bit-exact restore.
        let mut opt_same = m.build(&blocks, AdamHyper::default(), 3);
        opt_same.load_state(&state, 3).unwrap();
        assert_eq!(opt_same.save_state(), opt3.save_state(), "{}", m.label());

        // Elastic W=3 -> W'=2: re-sharded, fewer EF elements held.
        let mut opt2 = m.build(&blocks, AdamHyper::default(), 2);
        opt2.load_state(&state, 2).unwrap();
        assert_eq!(
            opt2.state_elements(),
            opt3.state_elements() - 35,
            "{}: one fewer 35-element EF buffer after re-shard",
            m.label()
        );
        // The resumed optimizer keeps training without structural issues.
        let topo2 = Topology::single_node(2);
        let mut grads: Vec<Vec<Matrix>> = (0..2)
            .map(|_| vec![Matrix::gaussian(5, 7, 1.0, &mut rng)])
            .collect();
        opt2.step(&mut tsr::optim::StepCtx {
            params: &mut params,
            grads: &mut grads,
            ledger: &mut ledger,
            topo: &topo2,
            lr_mult: 1.0,
            exec: &tsr::exec::ExecBackend::Sequential,
        });
        ledger.end_step();
        for p in &params {
            assert!(p.data.iter().all(|v| v.is_finite()), "{}", m.label());
        }
    }
}

/// Distance in units-in-the-last-place between two f32s. Equal values
/// (including +0 vs -0) are 0; differing signs are "far".
fn ulp_dist(a: f32, b: f32) -> u32 {
    if a == b {
        return 0;
    }
    if a.is_sign_negative() != b.is_sign_negative() {
        return u32::MAX;
    }
    a.to_bits().abs_diff(b.to_bits())
}

/// Satellite: elastic EF re-shard across NON-power-of-two world sizes,
/// at the codec level. 3 -> 5 (ragged: 42 % 5 != 0): each restored
/// element is `fl(fl(5c)/5)` — two f32 roundings — so the across-worker
/// mean of the restored buffers is within 2 ulp of the canonical mean.
/// 7 -> 2: x2 and /2 are exact in binary floating point, so the
/// restored mean is bitwise the canonical mean.
#[test]
fn elastic_reshard_mean_tracks_canonical_across_odd_world_sizes() {
    use tsr::checkpoint::{errors_from_json, errors_to_json};
    let mut rng = tsr::util::rng::Xoshiro256::new(77);
    for (w_save, w_load, max_ulp) in [(3usize, 5usize, 2u32), (7, 2, 0)] {
        let errors: Vec<Matrix> =
            (0..w_save).map(|_| Matrix::gaussian(6, 7, 1.0, &mut rng)).collect();
        // Canonical mean, summed in worker order exactly like the codec.
        let mut canon = errors[0].clone();
        for e in &errors[1..] {
            canon.add_assign(e);
        }
        canon.scale(1.0 / w_save as f32);
        let restored = errors_from_json(&errors_to_json(&errors), 6, 7, w_load, "ef").unwrap();
        assert_eq!(restored.len(), w_load);
        for i in 0..canon.numel() {
            // Exactly one worker owns element i; the others hold +0,
            // so this sum is the owner's stored value, exactly.
            let sum: f32 = restored.iter().map(|m| m.data[i]).sum();
            let got = sum / w_load as f32;
            assert!(
                ulp_dist(got, canon.data[i]) <= max_ulp,
                "{w_save}->{w_load} elem {i}: {got} vs {} ({} ulp)",
                canon.data[i],
                ulp_dist(got, canon.data[i])
            );
        }
    }
}

/// Satellite: the elastic-resume matrix extended to non-power-of-two
/// world sizes through the real optimizers: error-feedback methods
/// saved at W=3 resume at W'=5 (growing), and saved at W=7 resume at
/// W'=2 (shrinking), re-sharding their buffers to the NEW world size
/// and continuing to train on finite numbers.
#[test]
fn elastic_resume_covers_non_power_of_two_world_sizes() {
    use tsr::model::BlockSpec;
    // 6x7 = 42 elements: ragged for 5 workers (42 % 5 = 2).
    let blocks = vec![BlockSpec {
        name: "w".into(),
        rows: 6,
        cols: 7,
        class: tsr::comm::LayerClass::Linear,
    }];
    for (w_save, w_load) in [(3usize, 5usize), (7, 2)] {
        for m in [MethodCfg::TopK { keep_frac: 0.1 }, MethodCfg::Sign { k_var: 4 }] {
            let mut opt = m.build(&blocks, AdamHyper::default(), w_save);
            let mut params = vec![Matrix::zeros(6, 7)];
            let topo = Topology::single_node(w_save);
            let mut ledger = CommLedger::new();
            let mut rng = tsr::util::rng::Xoshiro256::new(9);
            for _ in 0..3 {
                let mut grads: Vec<Vec<Matrix>> = (0..w_save)
                    .map(|_| vec![Matrix::gaussian(6, 7, 1.0, &mut rng)])
                    .collect();
                opt.step(&mut tsr::optim::StepCtx {
                    params: &mut params,
                    grads: &mut grads,
                    ledger: &mut ledger,
                    topo: &topo,
                    lr_mult: 1.0,
                    exec: &tsr::exec::ExecBackend::Sequential,
                });
                ledger.end_step();
            }
            let state = Json::parse(&opt.save_state().to_string_pretty()).unwrap();
            let mut re = m.build(&blocks, AdamHyper::default(), w_load);
            re.load_state(&state, w_load).unwrap();
            // One 42-element EF buffer per worker of the NEW world size.
            let delta = (w_load as i64 - w_save as i64) * 42;
            assert_eq!(
                re.state_elements() as i64,
                opt.state_elements() as i64 + delta,
                "{}: {w_save}->{w_load} EF element accounting",
                m.label()
            );
            let topo2 = Topology::single_node(w_load);
            let mut grads: Vec<Vec<Matrix>> = (0..w_load)
                .map(|_| vec![Matrix::gaussian(6, 7, 1.0, &mut rng)])
                .collect();
            re.step(&mut tsr::optim::StepCtx {
                params: &mut params,
                grads: &mut grads,
                ledger: &mut ledger,
                topo: &topo2,
                lr_mult: 1.0,
                exec: &tsr::exec::ExecBackend::Sequential,
            });
            ledger.end_step();
            for p in &params {
                assert!(
                    p.data.iter().all(|v| v.is_finite()),
                    "{}: {w_save}->{w_load}",
                    m.label()
                );
            }
        }
    }
}

/// Satellite: manifest robustness — three distinct corruptions fail
/// loudly with three DISTINCT error messages (no panics, no silent
/// fallback): a truncated file, an unknown `version`, and a
/// structurally valid tensor entry whose declared shape contradicts
/// its payload length.
#[test]
fn corrupt_manifests_fail_loudly_with_distinct_errors() {
    let (mut sim, mut opt, mut params) = fresh_setup(&MethodCfg::Adam);
    let (metrics, ledger) = trainer(6).run(&mut sim, opt.as_mut(), &mut params, 4);
    let ck = Checkpoint::capture(
        4,
        WORKERS,
        &params,
        opt.as_ref(),
        &sim,
        &metrics,
        &ledger,
        Json::Null,
    );
    let text = ck.to_json().to_string_pretty();

    // (a) Truncated file: must surface a parse error, not a panic.
    let dir = std::env::temp_dir().join("tsr_ckpt_corrupt_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("ckpt_trunc.json");
    std::fs::write(&path, &text[..text.len() / 2]).unwrap();
    let err_trunc = Checkpoint::load(&path).unwrap_err();
    let _ = std::fs::remove_dir_all(&dir);

    // (b) Unknown version: names both the found and supported versions.
    let mut j = Json::parse(&text).unwrap();
    j.set("version", Json::num(99.0));
    let err_version = Checkpoint::from_json(&j).unwrap_err();
    assert!(
        err_version.contains("version 99") && err_version.contains("reads 1"),
        "unhelpful version error: {err_version}"
    );

    // (c) Structurally valid JSON whose declared rows/cols no longer
    // match the hex payload length.
    let mut j = Json::parse(&text).unwrap();
    let mut arr = j.get("params").as_arr().unwrap().to_vec();
    let rows = arr[0].get("rows").as_u64().unwrap();
    arr[0].set("rows", Json::num((rows + 1) as f64));
    j.set("params", Json::Arr(arr));
    let err_shape = Checkpoint::from_json(&j).unwrap_err();
    assert!(err_shape.contains("payload has"), "unhelpful shape error: {err_shape}");

    // Three different failures, three different diagnoses.
    assert_ne!(err_trunc, err_version);
    assert_ne!(err_version, err_shape);
    assert_ne!(err_trunc, err_shape);
}

/// Structural guards: wrong method, wrong block count, wrong shapes
/// must be rejected, not silently mis-restored.
#[test]
fn load_state_rejects_structural_mismatch() {
    let k = 5;
    let spec = ModelSpec::proxy(300, 24, 48, 2, 2);
    let sim = QuadraticSim::new(&spec, WORKERS, 6, 0.01, 11);
    let blocks = sim.blocks().to_vec();
    let tsr_state = MethodCfg::Tsr(TsrConfig {
        rank: 8,
        rank_emb: 4,
        refresh_every: k,
        refresh_emb: k,
        oversample: 3,
        ..Default::default()
    })
    .build(&blocks, AdamHyper::default(), WORKERS)
    .save_state();

    // Same block layout, different method family.
    let mut adam = MethodCfg::Adam.build(&blocks, AdamHyper::default(), WORKERS);
    assert!(adam.load_state(&tsr_state, WORKERS).is_err());

    // Same method, different rank -> shape mismatch.
    let mut other_rank = MethodCfg::Tsr(TsrConfig {
        rank: 6,
        rank_emb: 4,
        refresh_every: k,
        refresh_emb: k,
        oversample: 3,
        ..Default::default()
    })
    .build(&blocks, AdamHyper::default(), WORKERS);
    assert!(other_rank.load_state(&tsr_state, WORKERS).is_err());
}

/// Tentpole pipeline leg (DESIGN.md §6, §9, §14): a bf16-core TSR
/// fine-tune from a *pretrained* embedding, killed mid-refresh-period
/// (cut 7, k 5 — live error-feedback residuals in the manifest) and
/// resumed through a full JSON text round trip, is byte-identical to
/// the uninterrupted fine-tune: same deterministic metrics JSON, same
/// final-weight fingerprint, same ledger columns.
#[test]
fn bf16_finetune_kill_resume_is_byte_identical() {
    use tsr::exp::finetune::{finetune_tsr_cfg, pretrain_embedding};
    use tsr::train::finetune::ClassifyTask;

    let spec = ModelSpec::proxy(64, 32, 64, 2, 2);
    let emb = pretrain_embedding(&spec, 5, WORKERS, 21);
    let m = MethodCfg::Tsr(finetune_tsr_cfg(4, 5, tsr::comm::ElemFmt::Bf16));
    let (cut, steps) = (7, 12);
    let mk = || ClassifyTask::new(64, 32, 16, 3, 8, WORKERS, 4, 9);

    let full = {
        let mut task = mk();
        let blocks = task.blocks().to_vec();
        let mut opt = m.build(&blocks, AdamHyper::default(), WORKERS);
        let mut params = task.init_params_pretrained(1, &emb);
        let (metrics, ledger) = trainer(steps).run(&mut task, opt.as_mut(), &mut params, steps);
        metrics.to_json_deterministic(&ledger, &params).to_string_pretty()
    };

    let resumed = {
        let mut task = mk();
        let blocks = task.blocks().to_vec();
        let mut opt = m.build(&blocks, AdamHyper::default(), WORKERS);
        let mut params = task.init_params_pretrained(1, &emb);
        let (metrics, ledger) = trainer(steps).run(&mut task, opt.as_mut(), &mut params, cut);
        let ck = Checkpoint::capture(
            cut as u64,
            WORKERS,
            &params,
            opt.as_ref(),
            &task,
            &metrics,
            &ledger,
            Json::Null,
        );
        let text = ck.to_json().to_string_pretty();
        // Vacuity guard: the manifest must carry quantization residuals —
        // a cut that lands with empty EF would not test the bf16 path.
        assert!(text.contains("\"ef\""), "no error-feedback state at cut {cut}");
        drop((task, opt, params, metrics, ledger));

        let ck = Checkpoint::from_json(&Json::parse(&text).unwrap()).unwrap();
        let mut task = mk();
        let blocks = task.blocks().to_vec();
        let mut opt = m.build(&blocks, AdamHyper::default(), WORKERS);
        assert_eq!(opt.name(), ck.method);
        opt.load_state(&ck.opt_state, WORKERS).unwrap();
        task.load_state(&ck.source_state).unwrap();
        let mut params = ck.params.clone();
        let metrics = RunMetrics::state_from_json(&ck.metrics).unwrap();
        let ledger = CommLedger::from_json(&ck.ledger).unwrap();
        let (metrics, ledger) = trainer(steps).run_from(
            &mut task,
            opt.as_mut(),
            &mut params,
            cut,
            steps,
            metrics,
            ledger,
        );
        metrics.to_json_deterministic(&ledger, &params).to_string_pretty()
    };

    assert_eq!(full, resumed, "bf16 finetune kill+resume diverged");
}
