//! Byte-accounting unit tests: `CommLedger` peak/average bookkeeping and
//! the ring all-reduce volume against the closed-form 2(w−1)/w formula
//! across multi-node topology shapes.

use tsr::comm::{
    collective, hier_volume_bytes, ring_volume_bytes, CommLedger, LayerClass, Topology, BYTES_F32,
};
use tsr::linalg::Matrix;
use tsr::util::prop;
use tsr::util::rng::Xoshiro256;

/// Ledger average/peak/cumulative agree on a hand-built step sequence
/// with refresh spikes.
#[test]
fn ledger_peak_and_average_with_refresh_spikes() {
    let mut l = CommLedger::new();
    // 4 steady steps of 1000 B + one refresh step of 5000 B.
    for t in 0..5 {
        l.record_bytes(LayerClass::Linear, 1000);
        if t == 2 {
            l.record_bytes(LayerClass::Embedding, 4000);
            l.mark_refresh();
        }
        l.end_step();
    }
    assert_eq!(l.num_steps(), 5);
    assert_eq!(l.peak_bytes(), 5000);
    assert_eq!(l.bytes_per_step(), 9000.0 / 5.0);
    assert_eq!(l.cumulative(), vec![1000, 2000, 7000, 8000, 9000]);
    let (refresh_avg, steady_avg) = l.refresh_split();
    assert_eq!(refresh_avg, 5000.0);
    assert_eq!(steady_avg, 1000.0);
    let (emb, lin, vec_b) = l.breakdown();
    assert_eq!((emb, lin, vec_b), (4000, 5000, 0));
}

/// bytes_per_step is the exact integer-sum-over-steps divided by the
/// step count — the contract the analytic profiles rely on for
/// bit-exact comparison.
#[test]
fn ledger_average_is_exact_integer_division() {
    prop::check("ledger mean == Σ/n", 32, |rng| {
        let steps = prop::dim(rng, 1, 20);
        let mut l = CommLedger::new();
        let mut total = 0u64;
        for _ in 0..steps {
            let b = prop::dim(rng, 0, 100_000);
            l.record_bytes(LayerClass::Linear, b);
            total += b as u64;
            l.end_step();
        }
        assert_eq!(l.bytes_per_step(), total as f64 / steps as f64);
    });
}

/// `ring_volume_bytes` matches the closed-form 2(w−1)/w · numel · 4 on
/// divisible payloads, for every worker count arising from the
/// `Topology::multi_node` shapes the experiments use.
#[test]
fn ring_volume_matches_closed_form_across_topologies() {
    let shapes = [(1usize, 1usize), (1, 4), (2, 1), (2, 2), (2, 4), (4, 4), (4, 8)];
    for (nodes, gpus) in shapes {
        let topo = Topology::multi_node(nodes, gpus);
        let w = topo.workers();
        assert_eq!(w, nodes * gpus);
        // Divisible payload: the integer formula is exact.
        let numel = w * 123;
        let expect = if w > 1 {
            // 2(w−1)/w · numel elements, 4 B each.
            2 * (w - 1) * numel / w * BYTES_F32
        } else {
            0
        };
        assert_eq!(ring_volume_bytes(numel, w), expect, "{nodes}x{gpus}");
        // And the actual collective reports exactly that volume.
        let mut rng = Xoshiro256::new(7);
        let mut ws: Vec<Matrix> = (0..w)
            .map(|_| Matrix::gaussian(3, 41, 1.0, &mut rng))
            .collect();
        let reported = collective::ring_allreduce_mean(&mut ws);
        assert_eq!(reported, ring_volume_bytes(3 * 41, w), "{nodes}x{gpus}");
    }
}

/// The ring volume is monotone in workers and approaches 2× the payload:
/// the standard bandwidth-optimality property the α–β cost model assumes.
#[test]
fn ring_volume_approaches_twice_payload() {
    let numel = 1 << 12;
    let payload = numel * BYTES_F32;
    let mut last = 0usize;
    for w in [2usize, 4, 8, 16, 64] {
        let v = ring_volume_bytes(numel, w);
        assert!(v > last, "volume must grow with w");
        assert!(v < 2 * payload);
        last = v;
    }
    // At w=64: 2·63/64 ≈ 1.97× payload.
    assert!(last as f64 > 1.9 * payload as f64);
}

/// `ring_volume_bytes` is computed from actual chunk boundaries: for a
/// ragged payload the busiest worker moves more than the truncating
/// 2(w−1)/w closed form admits. Regression for the integer-division
/// rounding bug (numel % n != 0 truncated before the ×4).
#[test]
fn ring_volume_ragged_payload_counts_real_chunks() {
    // numel=10, n=3 → chunks 3,3,4; busiest worker: 2·10 − 3 − 3 = 14.
    assert_eq!(ring_volume_bytes(10, 3), 14 * BYTES_F32);
    let old_truncating = 2 * (3 - 1) * 10 / 3 * BYTES_F32;
    assert!(ring_volume_bytes(10, 3) > old_truncating);
    // The collective reports the boundary-exact figure.
    let mut rng = Xoshiro256::new(5);
    let mut ws: Vec<Matrix> = (0..3).map(|_| Matrix::gaussian(2, 5, 1.0, &mut rng)).collect();
    assert_eq!(collective::ring_allreduce_mean(&mut ws), 14 * BYTES_F32);
    // Divisible payloads still match the closed form exactly.
    assert_eq!(ring_volume_bytes(12, 3), 2 * 2 * 12 / 3 * BYTES_F32);
}

/// The hierarchical collective matches the direct-mean oracle across
/// `Topology::multi_node` shapes, and its metered intra/inter bytes
/// match the closed-form per-level 2(w−1)/w decomposition — summing to
/// the flat ring's aggregate volume (the hierarchy re-routes bytes, it
/// does not add any).
#[test]
fn hierarchical_allreduce_matches_oracle_and_level_decomposition() {
    let shapes = [(1usize, 4usize), (2, 2), (2, 4), (3, 2), (4, 4), (4, 1)];
    let mut rng = Xoshiro256::new(17);
    for (nodes, gpus) in shapes {
        let topo = Topology::multi_node(nodes, gpus);
        let w = topo.workers();
        for (rows, cols) in [(6, 8), (3, 13)] {
            let numel = rows * cols;
            let mut ws: Vec<Matrix> = (0..w)
                .map(|_| Matrix::gaussian(rows, cols, 1.0, &mut rng))
                .collect();
            let mut oracle = ws.clone();
            let mut ledger = CommLedger::new();
            // from_env: the TSR_BACKEND=threaded CI pass exercises the
            // rendezvous rings against the same closed forms.
            collective::sync_mean(
                &mut ws,
                LayerClass::Linear,
                &mut ledger,
                &topo,
                &tsr::exec::ExecBackend::from_env(),
            );
            ledger.end_step();
            collective::direct_allreduce_mean(&mut oracle);
            for (a, b) in ws.iter().zip(&oracle) {
                assert!(a.dist(b) < 1e-4 * numel as f32, "{nodes}x{gpus} {rows}x{cols}");
            }
            // Per-level closed forms (aggregate over workers):
            //   intra = 2·nodes·(g−1)·numel·4, inter = 2·(nodes−1)·numel·4.
            let expect = hier_volume_bytes(numel, nodes, gpus);
            assert_eq!(ledger.step(0).intra, expect.intra_bytes, "{nodes}x{gpus}");
            assert_eq!(ledger.step(0).inter, expect.inter_bytes, "{nodes}x{gpus}");
            // Conservation against the flat ring aggregate 2(N−1)·numel.
            if w > 1 {
                assert_eq!(
                    ledger.step(0).intra + ledger.step(0).inter,
                    2 * (w - 1) * numel * BYTES_F32,
                    "{nodes}x{gpus}"
                );
            }
            // Payload metering is untouched by the hierarchy.
            assert_eq!(ledger.step(0).total, numel * BYTES_F32);
        }
    }
}

/// allreduce_time is consistent with the volume formula: doubling the
/// payload doubles the bandwidth term (latency fixed).
#[test]
fn topology_time_consistent_with_volume() {
    let topo = Topology::multi_node(2, 4);
    let n = topo.workers();
    let lat = 2.0 * (n as f64 - 1.0) * 25e-6;
    let t1 = topo.allreduce_time(1 << 24) - lat;
    let t2 = topo.allreduce_time(1 << 25) - lat;
    assert!((t2 / t1 - 2.0).abs() < 1e-9, "bandwidth term ratio {}", t2 / t1);
}
