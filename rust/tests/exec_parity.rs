//! Cross-backend parity suite (DESIGN.md §8, §12): for every method
//! and topology, the `Threaded` execution backend (one OS thread per
//! simulated worker, rendezvous ring collectives) AND the `Process`
//! backend (one OS process per worker, socket rings over localhost
//! TCP) must produce **bitwise-identical** final weights and
//! **identical ledger byte columns** to the `Sequential` reference
//! loop — the keystone invariant that makes CI's determinism gate and
//! the BENCH_* trajectory meaningful. Runs cover a full refresh period
//! so both the steady-state core syncs and the refresh collectives
//! (sketches / dense SVD payloads) cross the thread and process
//! boundaries at least once.

use std::path::PathBuf;

use tsr::comm::{CommLedger, LayerClass, Topology};
use tsr::exec::ExecBackend;
use tsr::exp::MethodCfg;
use tsr::linalg::Matrix;
use tsr::model::{BlockSpec, ModelSpec};
use tsr::optim::onesided::OneSidedRefresh;
use tsr::optim::{AdamHyper, LrSchedule, StepCtx, TsrAdam, TsrConfig};
use tsr::train::gradsim::QuadraticSim;
use tsr::train::{GradSource, Trainer};
use tsr::util::rng::Xoshiro256;

/// Process backend with the worker binary pinned to the real `tsr`
/// executable (this test harness binary cannot re-exec as a worker).
fn process_exec() -> ExecBackend {
    tsr::exec::process::set_worker_binary(PathBuf::from(env!("CARGO_BIN_EXE_tsr")));
    ExecBackend::process()
}

/// The backends under test: the sequential reference plus both real
/// execution backends.
fn all_backends() -> [ExecBackend; 3] {
    [ExecBackend::Sequential, ExecBackend::threaded(), process_exec()]
}

/// All nine methods at parity-test scale, refresh period 4.
fn all_methods() -> Vec<MethodCfg> {
    let tsr_cfg = TsrConfig {
        rank: 8,
        rank_emb: 8,
        refresh_every: 4,
        refresh_emb: 4,
        oversample: 4,
        ..Default::default()
    };
    vec![
        MethodCfg::Adam,
        MethodCfg::OneSided {
            rank: 8,
            k: 4,
            refresh: OneSidedRefresh::RandomizedSvd,
        },
        MethodCfg::Tsr(tsr_cfg.clone()),
        MethodCfg::TsrSgd(tsr_cfg),
        MethodCfg::PowerSgd { rank: 8 },
        MethodCfg::Sign { k_var: 4 },
        MethodCfg::TopK { keep_frac: 0.05 },
        // Local-update methods: the 6-step runs cover zero-byte local
        // steps, partial-state syncs (m at t=4) and the full t=0 sync.
        MethodCfg::DesLoc { k_p: 2, k_m: 4, k_v: 4 },
        MethodCfg::Lordo { rank: 8, h: 3 },
    ]
}

fn weight_bits(params: &[Matrix]) -> Vec<Vec<u32>> {
    params
        .iter()
        .map(|p| p.data.iter().map(|v| v.to_bits()).collect())
        .collect()
}

/// Ledger equality across every byte column, per step.
fn assert_ledgers_equal(a: &CommLedger, b: &CommLedger, label: &str) {
    assert_eq!(a.num_steps(), b.num_steps(), "{label}: step count");
    for t in 0..a.num_steps() {
        let (x, y) = (a.step(t), b.step(t));
        assert_eq!(x.total, y.total, "{label}: total @ step {t}");
        assert_eq!(x.embedding, y.embedding, "{label}: embedding @ step {t}");
        assert_eq!(x.linear, y.linear, "{label}: linear @ step {t}");
        assert_eq!(x.vector, y.vector, "{label}: vector @ step {t}");
        assert_eq!(x.intra, y.intra, "{label}: intra wire @ step {t}");
        assert_eq!(x.inter, y.inter, "{label}: inter wire @ step {t}");
        assert_eq!(x.refresh, y.refresh, "{label}: refresh flag @ step {t}");
    }
}

/// One full training run on the quadratic proxy under `exec`.
fn run_once(
    method: &MethodCfg,
    topo: Topology,
    exec: ExecBackend,
    steps: usize,
) -> (Vec<Matrix>, CommLedger) {
    let spec = ModelSpec::proxy(200, 32, 64, 2, 2);
    let workers = topo.workers();
    let mut sim = QuadraticSim::new(&spec, workers, 16, 0.01, 33);
    let blocks = sim.blocks().to_vec();
    let hyper = AdamHyper {
        lr: 0.05,
        weight_decay: 0.0,
        scale: 1.0,
        ..Default::default()
    };
    let mut opt = method.build(&blocks, hyper, workers);
    let mut params = sim.init_params(7);
    let trainer = Trainer::new(topo, LrSchedule::paper(steps)).with_backend(exec);
    let (_metrics, ledger) = trainer.run(&mut sim, opt.as_mut(), &mut params, steps);
    (params, ledger)
}

fn assert_backend_parity(method: &MethodCfg, topo: Topology, steps: usize, label: &str) {
    let (w_seq, l_seq) = run_once(method, topo.clone(), ExecBackend::Sequential, steps);
    for exec in [ExecBackend::threaded(), process_exec()] {
        let bname = exec.name();
        let (w_other, l_other) = run_once(method, topo.clone(), exec, steps);
        assert_eq!(
            weight_bits(&w_seq),
            weight_bits(&w_other),
            "{label}/{bname}: weights must be bitwise identical"
        );
        assert_ledgers_equal(&l_seq, &l_other, &format!("{label}/{bname}"));
    }
    // Sanity: the run actually communicated.
    assert!(l_seq.step(0).total > 0, "{label}: no bytes metered");
}

/// The full matrix: all 9 methods × {single_node, multi_node}, one
/// refresh period (K = 4) plus two steady steps each.
#[test]
fn all_methods_bitwise_identical_across_backends() {
    for method in &all_methods() {
        for (tname, topo) in [
            ("single_node", Topology::single_node(4)),
            ("multi_node", Topology::multi_node(2, 2)),
        ] {
            let label = format!("{}/{tname}", method.label());
            assert_backend_parity(method, topo, 6, &label);
        }
    }
}

/// Worker count that does not tile the topology (3 workers on a 2×2
/// cluster): `sync_mean` takes its flat-ring fallback on every backend
/// — parity must hold there too, byte columns included.
#[test]
fn shape_mismatch_fallback_parity() {
    for method in [
        MethodCfg::Adam,
        MethodCfg::Tsr(TsrConfig {
            rank: 8,
            rank_emb: 8,
            refresh_every: 3,
            refresh_emb: 3,
            oversample: 4,
            ..Default::default()
        }),
    ] {
        let spec = ModelSpec::proxy(200, 32, 64, 2, 2);
        let mut outs = Vec::new();
        for exec in all_backends() {
            // 3 workers under a 4-worker topology shape.
            let mut sim = QuadraticSim::new(&spec, 3, 16, 0.01, 21);
            let blocks = sim.blocks().to_vec();
            let mut opt = method.build(&blocks, AdamHyper::default(), 3);
            let mut params = sim.init_params(9);
            let trainer =
                Trainer::new(Topology::multi_node(2, 2), LrSchedule::constant()).with_backend(exec);
            let (_m, ledger) = trainer.run(&mut sim, opt.as_mut(), &mut params, 4);
            outs.push((params, ledger));
        }
        for (i, (w, l)) in outs.iter().enumerate().skip(1) {
            let label = format!("{}/fallback/{}", method.label(), all_backends()[i].name());
            assert_eq!(weight_bits(&outs[0].0), weight_bits(w), "{label}");
            assert_ledgers_equal(&outs[0].1, l, &label);
        }
    }
}

/// Ragged-shard regression: a 7×11 block (numel 77) over 3 or 4 workers
/// leaves unequal ring chunks at every level — single-node flat ring,
/// leader-ring (gpus_per_node = 1), and the true two-level schedule.
/// Both the threaded pull schedule and the process push schedule must
/// bit-match the sequential one anyway.
#[test]
fn ragged_shard_numel_not_divisible_by_workers() {
    let blocks = vec![BlockSpec {
        name: "w".into(),
        rows: 7,
        cols: 11,
        class: LayerClass::Linear,
    }];
    let cfg = TsrConfig {
        rank: 3,
        rank_emb: 3,
        refresh_every: 3,
        refresh_emb: 3,
        oversample: 2,
        ..Default::default()
    };
    for topo in [
        Topology::single_node(3),
        Topology::multi_node(3, 1),
        Topology::multi_node(2, 2),
    ] {
        let workers = topo.workers();
        let mut outs = Vec::new();
        for exec in all_backends() {
            let mut opt = TsrAdam::new(&blocks, AdamHyper::default(), cfg.clone());
            let mut params = vec![Matrix::from_fn(7, 11, |i, j| ((i * 3 + j) % 5) as f32 * 0.1)];
            let mut ledger = CommLedger::new();
            let mut rng = Xoshiro256::new(55);
            for _ in 0..6 {
                let mut grads: Vec<Vec<Matrix>> = (0..workers)
                    .map(|_| vec![Matrix::gaussian(7, 11, 1.0, &mut rng)])
                    .collect();
                opt.step(&mut StepCtx {
                    params: &mut params,
                    grads: &mut grads,
                    ledger: &mut ledger,
                    topo: &topo,
                    lr_mult: 1.0,
                    exec: &exec,
                });
                ledger.end_step();
            }
            outs.push((params, ledger));
        }
        for (i, (w, l)) in outs.iter().enumerate().skip(1) {
            let label = format!(
                "ragged {}x{}/{}",
                topo.nodes,
                topo.gpus_per_node,
                all_backends()[i].name()
            );
            assert_eq!(weight_bits(&outs[0].0), weight_bits(w), "{label}");
            assert_ledgers_equal(&outs[0].1, l, &label);
        }
    }
}
