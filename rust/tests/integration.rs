//! Cross-module integration tests.
//!
//! Ties the layers together: simulated optimizers must reproduce the
//! analytic byte profiles exactly; the PJRT runtime must load the AOT
//! artifact and drive real training (skipped gracefully when
//! `make artifacts` hasn't run); methods must preserve the paper's
//! qualitative orderings end to end.

use tsr::comm::{CommLedger, ElemFmt, LayerClass, Topology};
use tsr::exp::{
    adamw_profile, desloc_profile, lordo_profile, lordo_profile_fmt, onesided_profile,
    onesided_profile_fmt, sign_profile, topk_profile, tsr_profile, tsr_profile_fmt, MethodCfg,
    TsrParams,
};
use tsr::linalg::Matrix;
use tsr::model::ModelSpec;
use tsr::optim::onesided::OneSidedRefresh;
use tsr::optim::{AdamHyper, LrSchedule, StepCtx, TsrConfig};
use tsr::train::gradsim::QuadraticSim;
use tsr::train::{GradSource, Trainer};
use tsr::util::rng::Xoshiro256;

fn run_ledger(spec: &ModelSpec, method: &MethodCfg, steps: usize, workers: usize) -> CommLedger {
    let mut sim = QuadraticSim::new(spec, workers, 6, 0.01, 11);
    let blocks = sim.blocks().to_vec();
    let mut opt = method.build(&blocks, AdamHyper::default(), workers);
    let mut params = sim.init_params(1);
    let mut grads = tsr::optim::alloc_worker_grads(&blocks, workers);
    let topo = Topology::multi_node(2, workers.div_ceil(2));
    let mut ledger = CommLedger::new();
    for t in 0..steps {
        sim.compute(&params, t, &mut grads);
        opt.step(&mut StepCtx {
            params: &mut params,
            grads: &mut grads,
            ledger: &mut ledger,
            topo: &topo,
            lr_mult: 1.0,
            exec: &tsr::exec::ExecBackend::Sequential,
        });
        ledger.end_step();
    }
    ledger
}

/// The simulated optimizers' metered bytes must equal the closed-form
/// profiles — the property that makes the Table 3 reproduction exact.
/// Every profile averages over one full refresh period with the same
/// integer-sum-then-divide arithmetic as the ledger, so equality here is
/// bit-for-bit, not approximate.
#[test]
fn simulated_bytes_match_analytic_profiles() {
    let spec = ModelSpec::proxy(300, 24, 48, 2, 2);
    let k = 5usize;

    // Dense AdamW.
    let ledger = run_ledger(&spec, &MethodCfg::Adam, 3, 2);
    let expect = adamw_profile(&spec).bytes_per_step;
    assert_eq!(ledger.bytes_per_step(), expect);

    // One-sided with refresh every k: average over one full period.
    let m = MethodCfg::OneSided {
        rank: 8,
        k,
        refresh: OneSidedRefresh::ExactSvd,
    };
    let ledger = run_ledger(&spec, &m, k, 2);
    let expect = onesided_profile(&spec, 8, k);
    assert_eq!(ledger.bytes_per_step(), expect.bytes_per_step);
    assert_eq!(ledger.peak_bytes() as f64, expect.peak_bytes);

    // TSR with both ranks refreshing every k.
    let cfg = TsrConfig {
        rank: 8,
        rank_emb: 6,
        refresh_every: k,
        refresh_emb: k,
        oversample: 4,
        ..Default::default()
    };
    let ledger = run_ledger(&spec, &MethodCfg::Tsr(cfg), k, 2);
    let expect = tsr_profile(
        &spec,
        TsrParams {
            rank: 8,
            k_refresh: k,
            rank_emb: 6,
            k_refresh_emb: k,
            oversample: 4,
        },
    );
    assert_eq!(ledger.bytes_per_step(), expect.bytes_per_step);
    assert_eq!(ledger.peak_bytes() as f64, expect.peak_bytes);

    // SignAdam: signs every step + dense variance sync every k_var.
    let ledger = run_ledger(&spec, &MethodCfg::Sign { k_var: k }, k, 2);
    let expect = sign_profile(&spec, k);
    assert_eq!(ledger.bytes_per_step(), expect.bytes_per_step);
    assert_eq!(ledger.peak_bytes() as f64, expect.peak_bytes);

    // TopKAdam: flat (index, value) traffic — any horizon averages exactly.
    let frac = 0.02;
    let ledger = run_ledger(&spec, &MethodCfg::TopK { keep_frac: frac }, 4, 2);
    let expect = topk_profile(&spec, frac);
    assert_eq!(ledger.bytes_per_step(), expect.bytes_per_step);
    assert_eq!(ledger.peak_bytes() as f64, expect.peak_bytes);
}

fn run_ledger_fmt(
    spec: &ModelSpec,
    method: &MethodCfg,
    steps: usize,
    workers: usize,
    fmt: ElemFmt,
) -> CommLedger {
    let mut sim = QuadraticSim::new(spec, workers, 6, 0.01, 11);
    let blocks = sim.blocks().to_vec();
    let mut opt = method.build_with_fmt(&blocks, AdamHyper::default(), workers, fmt);
    let mut params = sim.init_params(1);
    let mut grads = tsr::optim::alloc_worker_grads(&blocks, workers);
    let topo = Topology::multi_node(2, workers.div_ceil(2));
    let mut ledger = CommLedger::new();
    for t in 0..steps {
        sim.compute(&params, t, &mut grads);
        opt.step(&mut StepCtx {
            params: &mut params,
            grads: &mut grads,
            ledger: &mut ledger,
            topo: &topo,
            lr_mult: 1.0,
            exec: &tsr::exec::ExecBackend::Sequential,
        });
        ledger.end_step();
    }
    ledger
}

/// Tentpole acceptance (DESIGN.md §14): with narrow core formats the
/// metered ledger still equals the format-aware analytic profile with
/// exact f64 equality, for all three fmt-capable methods — and the TSR
/// steady-state core payload is *exactly* half the f32 run's at bf16.
#[test]
fn narrow_format_bytes_match_analytic_profiles() {
    let spec = ModelSpec::proxy(300, 24, 48, 2, 2);
    let k = 5usize;

    let cfg = TsrConfig {
        rank: 8,
        rank_emb: 6,
        refresh_every: k,
        refresh_emb: k,
        oversample: 4,
        core_fmt: ElemFmt::Bf16,
        ..Default::default()
    };
    let ledger = run_ledger(&spec, &MethodCfg::Tsr(cfg.clone()), k, 2);
    let p = TsrParams {
        rank: 8,
        k_refresh: k,
        rank_emb: 6,
        k_refresh_emb: k,
        oversample: 4,
    };
    let expect = tsr_profile_fmt(&spec, p, ElemFmt::Bf16);
    assert_eq!(ledger.bytes_per_step(), expect.bytes_per_step);
    assert_eq!(ledger.peak_bytes() as f64, expect.peak_bytes);
    // Steady-state core payload (embedding + linear columns on a
    // non-refresh step) is exactly half the f32 run's; the always-f32
    // vector column is untouched.
    let f32_ledger = run_ledger(
        &spec,
        &MethodCfg::Tsr(TsrConfig {
            core_fmt: ElemFmt::F32,
            ..cfg
        }),
        k,
        2,
    );
    let (s16, s32) = (ledger.step(1), f32_ledger.step(1));
    assert_eq!(2 * (s16.embedding + s16.linear), s32.embedding + s32.linear);
    assert_eq!(s16.vector, s32.vector);

    // One-sided, bf16 steady factor: exact over one refresh period.
    let m = MethodCfg::OneSided {
        rank: 8,
        k,
        refresh: OneSidedRefresh::ExactSvd,
    };
    let ledger = run_ledger_fmt(&spec, &m, k, 2, ElemFmt::Bf16);
    let expect = onesided_profile_fmt(&spec, 8, k, ElemFmt::Bf16);
    assert_eq!(ledger.bytes_per_step(), expect.bytes_per_step);
    assert_eq!(ledger.peak_bytes() as f64, expect.peak_bytes);

    // LoRDO, int8 delta factors: exact over one h-round, local steps
    // still metering exactly zero.
    let (rank, h) = (6usize, 4u64);
    let ledger = run_ledger_fmt(&spec, &MethodCfg::Lordo { rank, h }, h as usize, 2, ElemFmt::I8);
    let expect = lordo_profile_fmt(&spec, rank, h, ElemFmt::I8);
    assert_eq!(ledger.bytes_per_step(), expect.bytes_per_step);
    assert_eq!(ledger.peak_bytes() as f64, expect.peak_bytes);
    for t in 1..h as usize {
        assert_eq!(ledger.step(t).total, 0, "lordo local step {t} must meter zero");
    }
}

/// The TSR embedding-specific rank path (§3.6): with rank_emb ≠ rank and
/// K_emb ≠ K, metered bytes still equal the analytic profile exactly
/// when averaged over lcm(K, K_emb) steps.
#[test]
fn tsr_embedding_rank_path_bytes_exact() {
    let spec = ModelSpec::proxy(400, 24, 48, 2, 2);
    let cfg = TsrConfig {
        rank: 10,
        rank_emb: 4,
        refresh_every: 4,
        refresh_emb: 8,
        oversample: 3,
        ..Default::default()
    };
    // lcm(4, 8) = 8 steps: linear sketches paid twice, embedding once.
    let ledger = run_ledger(&spec, &MethodCfg::Tsr(cfg), 8, 2);
    let expect = tsr_profile(
        &spec,
        TsrParams {
            rank: 10,
            k_refresh: 4,
            rank_emb: 4,
            k_refresh_emb: 8,
            oversample: 3,
        },
    );
    assert_eq!(ledger.bytes_per_step(), expect.bytes_per_step);
    assert_eq!(ledger.peak_bytes() as f64, expect.peak_bytes);
    // Non-refresh embedding steps carry exactly the r_emb² core.
    assert_eq!(ledger.step(1).embedding, 4 * 4 * 4);
    // Step 4 refreshes linears only: embedding stays at its core payload.
    assert_eq!(ledger.step(4).embedding, 4 * 4 * 4);
    assert!(ledger.step(4).refresh);
    assert!(ledger.step(4).linear > ledger.step(1).linear);
}

/// Tentpole acceptance: the local-update methods' metered bytes equal
/// their closed-form profiles with exact f64 equality, over a window
/// that contains purely-local (zero-byte) steps, partial-state syncs
/// (DES-LOC params-only and params+m steps) and the full t=0 sync.
/// Both sides sum the same integers and divide once, so `==` on f64 is
/// the right comparison — any drift is a real schedule bug.
#[test]
fn local_update_bytes_match_analytic_profiles_over_one_period() {
    let spec = ModelSpec::proxy(300, 24, 48, 2, 2);

    // DES-LOC cadences 2/4/8: lcm period = 8 steps. Syncs land at
    // t=0 (p+m+v), t=2 (p), t=4 (p+m), t=6 (p); t odd is zero-byte.
    let (k_p, k_m, k_v) = (2u64, 4u64, 8u64);
    let m = MethodCfg::DesLoc { k_p, k_m, k_v };
    let ledger = run_ledger(&spec, &m, 8, 2);
    let expect = desloc_profile(&spec, k_p, k_m, k_v);
    assert_eq!(ledger.bytes_per_step(), expect.bytes_per_step);
    assert_eq!(ledger.peak_bytes() as f64, expect.peak_bytes);
    for t in [1usize, 3, 5, 7] {
        assert_eq!(ledger.step(t).total, 0, "desloc local step {t} must meter zero");
    }
    assert!(ledger.step(2).total > 0 && ledger.step(2).total < ledger.step(0).total);
    assert!(ledger.step(4).total > ledger.step(2).total, "p+m > p-only sync");

    // LoRDO h=4: one sync step (t=0) then three exactly-zero steps.
    let (rank, h) = (6usize, 4u64);
    let ledger = run_ledger(&spec, &MethodCfg::Lordo { rank, h }, h as usize, 2);
    let expect = lordo_profile(&spec, rank, h);
    assert_eq!(ledger.bytes_per_step(), expect.bytes_per_step);
    assert_eq!(ledger.peak_bytes() as f64, expect.peak_bytes);
    for t in 1..h as usize {
        assert_eq!(ledger.step(t).total, 0, "lordo local step {t} must meter zero");
    }
}

/// The compressed-communication baselines keep their qualitative byte
/// signatures end to end: sign ≈ dense/32 steady with dense peaks; top-k
/// perfectly flat.
#[test]
fn compressed_baseline_byte_signatures() {
    let spec = ModelSpec::proxy(300, 24, 48, 2, 2);
    let dense = adamw_profile(&spec).bytes_per_step;

    let ledger = run_ledger(&spec, &MethodCfg::Sign { k_var: 6 }, 12, 2);
    // Steady (non-refresh) steps are ~32× below dense matrix traffic.
    let steady = ledger.step(1).total;
    assert!((steady as f64) < 0.2 * dense, "sign steady {steady}");
    // Refresh steps spike by the full dense matrix payload.
    assert!(ledger.step(6).total > ledger.step(1).total);
    assert!(ledger.step(6).refresh && !ledger.step(1).refresh);

    let ledger = run_ledger(&spec, &MethodCfg::TopK { keep_frac: 0.01 }, 6, 2);
    for t in 1..6 {
        assert_eq!(ledger.step(t).total, ledger.step(0).total);
    }
    assert!((ledger.peak_bytes() as f64) < 0.1 * dense);
}

/// Satellite (property): plan == ledger byte parity survives randomized
/// mid-period `seek()` points AND ragged shards. Odd vocab/hidden make
/// every matrix block's numel odd, so the even worker counts always
/// split `numel % workers != 0` — the shard-boundary case the ring
/// collectives and EF-buffer bookkeeping must agree on.
#[test]
fn prop_plan_ledger_parity_on_ragged_shards_and_random_seek() {
    use tsr::util::prop::{check, dim};
    check("plan==ledger ragged+seek", 6, |rng| {
        let vocab = 2 * dim(rng, 100, 160) + 1;
        let hidden = 2 * dim(rng, 8, 14) + 1;
        let spec = ModelSpec::proxy(vocab, hidden, 2 * hidden, 1, 2);
        let workers = if dim(rng, 0, 1) == 0 { 2 } else { 4 };
        let k = dim(rng, 2, 6);
        let t0 = dim(rng, 0, 2 * k + 1);
        let steps = t0 + k + 2;
        let tsr = TsrConfig {
            rank: 8,
            rank_emb: 4,
            refresh_every: k,
            refresh_emb: k,
            oversample: 3,
            ..Default::default()
        };
        let methods = vec![
            MethodCfg::Adam,
            MethodCfg::OneSided {
                rank: 6,
                k,
                refresh: OneSidedRefresh::ExactSvd,
            },
            MethodCfg::Tsr(tsr.clone()),
            MethodCfg::TsrSgd(tsr.clone()),
            MethodCfg::PowerSgd { rank: 5 },
            MethodCfg::Sign { k_var: k },
            MethodCfg::TopK { keep_frac: 0.03 },
            MethodCfg::DesLoc {
                k_p: k as u64,
                k_m: 2 * k as u64,
                k_v: 2 * k as u64,
            },
            MethodCfg::Lordo {
                rank: 6,
                h: k as u64,
            },
        ];
        for m in methods {
            let mut sim = QuadraticSim::new(&spec, workers, 6, 0.01, 11);
            let blocks = sim.blocks().to_vec();
            assert!(
                blocks.iter().any(|b| b.numel() % workers != 0),
                "generator must produce ragged shards"
            );
            let mut opt = m.build(&blocks, AdamHyper::default(), workers);
            opt.seek(t0 as u64);
            let plans: Vec<_> = (t0..steps).map(|t| opt.sync_plan(t as u64)).collect();
            let mut params = sim.init_params(1);
            let mut grads = tsr::optim::alloc_worker_grads(&blocks, workers);
            let topo = Topology::multi_node(2, workers.div_ceil(2));
            let mut ledger = CommLedger::new();
            for t in t0..steps {
                sim.compute(&params, t, &mut grads);
                opt.step(&mut StepCtx {
                    params: &mut params,
                    grads: &mut grads,
                    ledger: &mut ledger,
                    topo: &topo,
                    lr_mult: 1.0,
                    exec: &tsr::exec::ExecBackend::Sequential,
                });
                ledger.end_step();
            }
            for (i, plan) in plans.iter().enumerate() {
                assert_eq!(
                    plan.total_bytes(),
                    ledger.step(i).total,
                    "{} V={vocab} H={hidden} W={workers} k={k} t0={t0} step {}",
                    m.label(),
                    t0 + i
                );
                assert_eq!(
                    plan.has_refresh(),
                    ledger.step(i).refresh,
                    "{} V={vocab} H={hidden} W={workers} k={k} t0={t0} step {} refresh",
                    m.label(),
                    t0 + i
                );
            }
        }
    });
}

/// Satellite (property): the generalized step/sync contract for the
/// local-update family. Under randomized cadences, ragged shards and
/// mid-period `seek()` points, (a) `sync_plan(t).total_bytes()` is
/// **exactly zero** precisely on the steps where no state's cadence
/// fires (`sync_due` is the single source of truth for both sides),
/// and (b) the executed ledger column equals the planned column
/// byte-for-byte from the seek point onward.
#[test]
fn prop_local_update_zero_byte_steps_and_plan_ledger_parity() {
    use tsr::optim::sync_due;
    use tsr::util::prop::{check, dim};
    check("local-update zero-byte+parity", 8, |rng| {
        let vocab = 2 * dim(rng, 80, 140) + 1;
        let hidden = 2 * dim(rng, 8, 14) + 1;
        let spec = ModelSpec::proxy(vocab, hidden, 2 * hidden, 1, 2);
        let workers = if dim(rng, 0, 1) == 0 { 2 } else { 4 };
        let k_p = dim(rng, 2, 5) as u64;
        let k_m = k_p * dim(rng, 2, 3) as u64;
        let k_v = k_m * dim(rng, 2, 3) as u64;
        let h = dim(rng, 2, 6) as u64;
        let t0 = dim(rng, 0, 2 * k_v as usize) as u64;
        let window = (k_v + 2).max(h + 2);
        let desloc = MethodCfg::DesLoc { k_p, k_m, k_v };
        let lordo = MethodCfg::Lordo {
            rank: dim(rng, 3, 8),
            h,
        };
        for m in [desloc, lordo] {
            let mut sim = QuadraticSim::new(&spec, workers, 6, 0.01, 11);
            let blocks = sim.blocks().to_vec();
            assert!(
                blocks.iter().any(|b| b.numel() % workers != 0),
                "generator must produce ragged shards"
            );
            let mut opt = m.build(&blocks, AdamHyper::default(), workers);
            opt.seek(t0);
            let due = |t: u64| match m {
                MethodCfg::DesLoc { k_p, k_m, k_v } => {
                    sync_due(k_p, t) || sync_due(k_m, t) || sync_due(k_v, t)
                }
                MethodCfg::Lordo { h, .. } => sync_due(h, t),
                _ => unreachable!(),
            };
            let plans: Vec<_> = (t0..t0 + window).map(|t| opt.sync_plan(t)).collect();
            for (i, plan) in plans.iter().enumerate() {
                let t = t0 + i as u64;
                if due(t) {
                    assert!(
                        plan.total_bytes() > 0,
                        "{} k=({k_p},{k_m},{k_v}) h={h} t={t}: sync step plans 0 bytes",
                        m.label()
                    );
                } else {
                    assert_eq!(
                        plan.total_bytes(),
                        0,
                        "{} k=({k_p},{k_m},{k_v}) h={h} t={t}: local step must plan EXACTLY 0",
                        m.label()
                    );
                }
                // Local steps still enumerate every block (the timing
                // engine buckets per block even at zero payload).
                assert_eq!(plan.items.len(), blocks.len());
            }
            let mut params = sim.init_params(1);
            let mut grads = tsr::optim::alloc_worker_grads(&blocks, workers);
            let topo = Topology::multi_node(2, workers.div_ceil(2));
            let mut ledger = CommLedger::new();
            for t in t0..t0 + window {
                sim.compute(&params, t as usize, &mut grads);
                opt.step(&mut StepCtx {
                    params: &mut params,
                    grads: &mut grads,
                    ledger: &mut ledger,
                    topo: &topo,
                    lr_mult: 1.0,
                    exec: &tsr::exec::ExecBackend::Sequential,
                });
                ledger.end_step();
            }
            for (i, plan) in plans.iter().enumerate() {
                assert_eq!(
                    plan.total_bytes(),
                    ledger.step(i).total,
                    "{} V={vocab} H={hidden} W={workers} t0={t0} step {}",
                    m.label(),
                    t0 + i as u64
                );
            }
        }
    });
}

/// Paper orderings hold end-to-end on a real (simulated-gradient) run:
/// bytes TSR < one-sided < dense; peak randomized < dense-refresh; and
/// all three reach comparable loss on a low-intrinsic-dim objective.
#[test]
fn qualitative_orderings_hold_end_to_end() {
    let spec = ModelSpec::proxy(400, 48, 96, 2, 3);
    let steps = 120;
    let workers = 4;
    let tsr_cfg = TsrConfig {
        rank: 16,
        rank_emb: 8,
        refresh_every: 30,
        refresh_emb: 30,
        oversample: 6,
        ..Default::default()
    };

    let mut outs = Vec::new();
    for m in [
        MethodCfg::Adam,
        MethodCfg::OneSided {
            rank: 16,
            k: 30,
            refresh: OneSidedRefresh::RandomizedSvd,
        },
        MethodCfg::Tsr(tsr_cfg),
    ] {
        let mut sim = QuadraticSim::new(&spec, workers, 6, 0.02, 5);
        let blocks = sim.blocks().to_vec();
        let mut opt = m.build(
            &blocks,
            AdamHyper {
                lr: 0.03,
                ..Default::default()
            },
            workers,
        );
        let mut params = sim.init_params(9);
        let trainer = Trainer::new(Topology::multi_node(2, 2), LrSchedule::paper(steps));
        let (metrics, ledger) = trainer.run(&mut sim, opt.as_mut(), &mut params, steps);
        outs.push((m.label(), metrics, ledger));
    }
    let bytes: Vec<f64> = outs.iter().map(|o| o.2.bytes_per_step()).collect();
    assert!(bytes[2] < bytes[1] && bytes[1] < bytes[0], "{bytes:?}");
    // All methods reach much-better-than-initial loss (comparable quality).
    for (label, metrics, _) in &outs {
        assert!(
            metrics.final_loss() < 0.25 * metrics.loss[0],
            "{label}: {} -> {}",
            metrics.loss[0],
            metrics.final_loss()
        );
    }
}

/// Per-link-class wire metering: on a 2×2 cluster every synchronized
/// object is moved by the hierarchical collective (or, for the
/// in-process compressed payloads of Sign/TopK, metered by the matching
/// virtual sync), so each step's intra/inter wire bytes are exact
/// multiples of its payload bytes — intra = 2·nodes·(g−1)·payload and
/// inter = 2·(nodes−1)·payload — and their sum equals the flat ring's
/// aggregate 2(N−1)·payload. Combined with
/// `simulated_bytes_match_analytic_profiles`, the per-class split
/// therefore still sums to the analytic profiles with exact f64
/// equality.
#[test]
fn wire_bytes_decompose_per_level_for_every_method() {
    let spec = ModelSpec::proxy(300, 24, 48, 2, 2);
    let k = 5usize;
    let workers = 4; // == multi_node(2, 2): nodes=2, g=2
    let cfg = TsrConfig {
        rank: 8,
        rank_emb: 6,
        refresh_every: k,
        refresh_emb: k,
        oversample: 4,
        ..Default::default()
    };
    for m in [
        MethodCfg::Adam,
        MethodCfg::Tsr(cfg),
        MethodCfg::Sign { k_var: k },
        MethodCfg::TopK { keep_frac: 0.02 },
    ] {
        let mut sim = QuadraticSim::new(&spec, workers, 6, 0.01, 11);
        let blocks = sim.blocks().to_vec();
        let mut opt = m.build(&blocks, AdamHyper::default(), workers);
        let mut params = sim.init_params(1);
        let mut grads = tsr::optim::alloc_worker_grads(&blocks, workers);
        let topo = Topology::multi_node(2, 2);
        let mut ledger = CommLedger::new();
        for t in 0..k {
            sim.compute(&params, t, &mut grads);
            opt.step(&mut StepCtx {
                params: &mut params,
                grads: &mut grads,
                ledger: &mut ledger,
                topo: &topo,
                lr_mult: 1.0,
                exec: &tsr::exec::ExecBackend::Sequential,
            });
            ledger.end_step();
        }
        for t in 0..k {
            let s = ledger.step(t);
            assert_eq!(s.intra, 4 * s.total, "{} step {t}", m.label());
            assert_eq!(s.inter, 2 * s.total, "{} step {t}", m.label());
            assert_eq!(s.intra + s.inter, 2 * (workers - 1) * s.total);
        }
        let (intra, inter) = ledger.link_totals();
        assert!(intra > inter, "fast links must carry more wire bytes");
    }
}

/// Shared-seed sketches: two workers independently construct Ω for the
/// same (layer, refresh) stream and must agree bit-for-bit — the
/// precondition for Algorithm 1's seed-based Ω broadcast elision.
#[test]
fn shared_seed_sketches_agree_across_workers() {
    for stream in [0u64, 7, 1 << 40] {
        let mut w1 = Xoshiro256::for_stream(0x7512_AD, stream);
        let mut w2 = Xoshiro256::for_stream(0x7512_AD, stream);
        let a = Matrix::gaussian(64, 24, 1.0, &mut w1);
        let b = Matrix::gaussian(64, 24, 1.0, &mut w2);
        assert_eq!(a, b);
    }
}

/// Embedding-specific ranks flow through: the embedding block's steady
/// core is r_emb², independent of the linear rank (§3.6).
#[test]
fn embedding_rank_decoupled_from_linear_rank() {
    let spec = ModelSpec::proxy(500, 32, 64, 2, 1);
    let cfg = TsrConfig {
        rank: 24,
        rank_emb: 4,
        refresh_every: 1000,
        refresh_emb: 1000,
        oversample: 4,
        ..Default::default()
    };
    let ledger = run_ledger(&spec, &MethodCfg::Tsr(cfg), 3, 2);
    // Step 1 (post-init): embedding bytes = r_emb² × 4.
    let emb = ledger.step(1).embedding;
    assert_eq!(emb, 4 * 4 * 4);
}

/// PJRT integration: load the tiny artifact, check loss ≈ ln(V) at init,
/// train briefly with TSR-Adam and require a loss drop. Skips when
/// artifacts are missing (CI without `make artifacts`).
#[test]
fn pjrt_artifact_trains_end_to_end() {
    let manifest_path = std::path::Path::new("artifacts/tiny_manifest.json");
    if !manifest_path.exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let manifest = tsr::runtime::Manifest::load(manifest_path).unwrap();
    let engine = tsr::runtime::Engine::cpu().unwrap();
    let model = engine.load_model(manifest.clone()).unwrap();
    let corpus = tsr::data::SyntheticCorpus::new(manifest.vocab, 3);
    let batcher = tsr::data::Batcher::new(corpus, 2, manifest.batch, manifest.seq, 4);
    let mut source = tsr::train::pjrt_source::PjrtSource::new(model, batcher);
    let blocks = source.blocks().to_vec();

    // Block layout must match the Rust registry convention.
    assert_eq!(blocks[0].class, LayerClass::Embedding);
    assert!(blocks.iter().any(|b| b.class == LayerClass::Vector));

    let cfg = TsrConfig {
        rank: 16,
        rank_emb: 8,
        refresh_every: 10,
        refresh_emb: 10,
        oversample: 4,
        ..Default::default()
    };
    let mut opt = MethodCfg::Tsr(cfg).build(
        &blocks,
        AdamHyper {
            lr: 0.02,
            ..Default::default()
        },
        2,
    );
    let mut params = source.init_params(42);
    let trainer = Trainer::new(Topology::single_node(2), LrSchedule::constant());
    let (metrics, ledger) = trainer.run(&mut source, opt.as_mut(), &mut params, 80);

    let ln_v = (manifest.vocab as f32).ln();
    assert!(
        (metrics.loss[0] - ln_v).abs() < 0.8,
        "init loss {} vs ln(V) {ln_v}",
        metrics.loss[0]
    );
    assert!(
        metrics.final_loss() < metrics.loss[0] - 0.1,
        "no learning: {} -> {}",
        metrics.loss[0],
        metrics.final_loss()
    );
    assert!(ledger.bytes_per_step() > 0.0);
}

/// The standalone L1 kernel artifacts load and execute from Rust, and
/// the Pallas core projection matches the Rust-native implementation.
#[test]
fn pallas_core_kernel_matches_rust_linalg() {
    let path = std::path::Path::new("artifacts/core_project.hlo.txt");
    if !path.exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let engine = tsr::runtime::Engine::cpu().unwrap();
    let exe = engine.load_hlo(path).unwrap();
    let (m, n, r) = (256usize, 128usize, 16usize);
    let mut rng = Xoshiro256::new(8);
    let u = Matrix::gaussian(m, r, 1.0, &mut rng);
    let g = Matrix::gaussian(m, n, 1.0, &mut rng);
    let v = Matrix::gaussian(n, r, 1.0, &mut rng);
    let lit = |mat: &Matrix, rows: usize, cols: usize| {
        xla::Literal::vec1(&mat.data)
            .reshape(&[rows as i64, cols as i64])
            .unwrap()
    };
    let outs = exe
        .run(&[lit(&u, m, r), lit(&g, m, n), lit(&v, n, r)])
        .unwrap();
    let got = outs[0].to_vec::<f32>().unwrap();
    let want = tsr::linalg::core_project(&u, &g, &v);
    assert_eq!(got.len(), r * r);
    for (a, b) in got.iter().zip(&want.data) {
        assert!((a - b).abs() < 1e-2 * want.frob_norm(), "{a} vs {b}");
    }
}
