//! Property tests for the linear-algebra substrate (via `util::prop`).
//!
//! The TSR optimizer family is only as trustworthy as its factorization
//! primitives: every refresh runs qr_thin/orth on sketches, svd_gram on
//! the reduced matrix, and the baselines rely on rsvd/svd_jacobi. These
//! properties pin the numerical contracts — orthonormality defects,
//! Eckart–Young-style reconstruction bounds, and cross-implementation
//! spectrum agreement — over hundreds of random shapes.

use tsr::linalg::{
    matmul, orth, ortho_defect, qr_thin, rsvd, svd_gram, svd_jacobi, svd_truncated, Matrix,
};
use tsr::util::prop;
use tsr::util::rng::Xoshiro256;

fn low_rank_plus_noise(
    m: usize,
    n: usize,
    d: usize,
    noise: f32,
    rng: &mut Xoshiro256,
) -> Matrix {
    let a = Matrix::gaussian(m, d, 1.0, rng);
    let b = Matrix::gaussian(d, n, 1.0, rng);
    let mut x = matmul(&a, &b);
    if noise > 0.0 {
        x.add_assign(&Matrix::gaussian(m, n, noise, rng));
    }
    x
}

/// `qr_thin` and `orth` produce orthonormal columns (defect < 1e-4) and
/// an exact A = Q·R reconstruction across random tall shapes.
#[test]
fn prop_qr_orthonormality_and_reconstruction() {
    prop::check("qr_thin orthonormal + reconstructs", 64, |rng| {
        let k = prop::dim(rng, 1, 16);
        let m = k + prop::dim(rng, 0, 48);
        let a = Matrix::gaussian(m, k, 1.0, rng);
        let (q, r) = qr_thin(&a);
        let defect = ortho_defect(&q);
        assert!(defect < 1e-4, "defect {defect} for {m}x{k}");
        let qr = matmul(&q, &r);
        assert!(
            qr.dist(&a) < 1e-3 * (m as f32).max(1.0),
            "{}x{} reconstruction {}",
            m,
            k,
            qr.dist(&a)
        );
        // orth is the Q factor.
        assert!(ortho_defect(&orth(&a)) < 1e-4);
    });
}

/// Randomized SVD reconstruction error is bounded by a small constant
/// times the exact truncated-SVD tail (Halko–Martinsson–Tropp): on
/// low-rank-plus-noise matrices, rank-d rsvd with oversampling and two
/// power iterations lands within 3× of the optimal rank-d error.
#[test]
fn prop_rsvd_error_bounded_by_exact_tail() {
    prop::check("rsvd within constant of exact tail", 24, |rng| {
        let d = prop::dim(rng, 2, 5);
        let m = d + prop::dim(rng, 6, 24);
        let n = d + prop::dim(rng, 6, 24);
        let a = low_rank_plus_noise(m, n, d, 0.02, rng);
        // Optimal rank-d error: the exact SVD tail √(Σ_{i>d} σ_i²).
        let (_, sigma, _) = svd_jacobi(&a);
        let tail: f32 = sigma[d.min(sigma.len())..]
            .iter()
            .map(|s| s * s)
            .sum::<f32>()
            .sqrt();
        let approx = rsvd(&a, d, 5, 2, rng);
        let err = approx.reconstruct().dist(&a);
        assert!(
            err <= 3.0 * tail + 1e-3,
            "{m}x{n} d={d}: rsvd err {err} vs exact tail {tail}"
        );
        // The factors themselves must be orthonormal.
        assert!(ortho_defect(&approx.u) < 1e-3);
        assert!(ortho_defect(&approx.v) < 1e-3);
    });
}

/// `svd_gram` (the fast refresh path) agrees with `svd_jacobi` (the
/// oracle) on the singular spectrum of random wide matrices.
#[test]
fn prop_svd_gram_matches_jacobi_spectrum() {
    prop::check("svd_gram spectrum == jacobi", 32, |rng| {
        let k = prop::dim(rng, 2, 10);
        let n = k + prop::dim(rng, 0, 40);
        let b = Matrix::gaussian(k, n, 1.0, rng);
        let (_, s_jac, _) = svd_jacobi(&b);
        let (_, s_gram, _) = svd_gram(&b);
        assert_eq!(s_jac.len(), s_gram.len());
        let s0 = s_jac[0].max(1e-6);
        for i in 0..k {
            assert!(
                (s_jac[i] - s_gram[i]).abs() < 1e-2 * s0 + 1e-4,
                "{k}x{n} σ{i}: jacobi {} vs gram {}",
                s_jac[i],
                s_gram[i]
            );
        }
    });
}

/// `svd_truncated` at full rank reproduces the matrix; at the intrinsic
/// rank of a low-rank matrix it is (numerically) lossless.
#[test]
fn prop_truncated_svd_lossless_at_intrinsic_rank() {
    prop::check("svd_truncated exact at intrinsic rank", 16, |rng| {
        let d = prop::dim(rng, 1, 4);
        let m = d + prop::dim(rng, 4, 20);
        let n = d + prop::dim(rng, 4, 20);
        let a = low_rank_plus_noise(m, n, d, 0.0, rng);
        let rec = svd_truncated(&a, d).reconstruct();
        assert!(
            rec.dist(&a) < 1e-2 * a.frob_norm().max(1e-3),
            "{m}x{n} d={d}: {}",
            rec.dist(&a)
        );
    });
}
